"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written in
plain ``jax.numpy`` with no Pallas constructs. All arithmetic is uint32 with
wrapping semantics, so kernel-vs-reference comparisons are **bit-exact** —
the pytest suite asserts array equality, not allclose.

The three kernels model the datapath compute of the paper's accelerator zoo
(§5.4's end-to-end prototypes):

- :func:`chacha_ref` — ARX counter-mode stream cipher (the AES-128-CBC /
  IPSec encryption role, re-thought for TPU-style vector lanes: AES's
  table-based S-boxes are hostile to the VPU; an ARX cipher is pure
  add/rotate/xor over 32-bit lanes).
- :func:`treehash_ref` — tree-structured keyed digest with a fixed 64 B
  output (the SHA1-HMAC / SHA-3-512 role; fixed egress regardless of input
  size, the paper's R-taxonomy example).
- :func:`fletcher_ref` — position-weighted checksum (the RocksDB CRC32C
  offload role in Table 4).

Payload layout: a message is padded to 64-byte blocks and viewed as a
``(blocks, 16)`` uint32 array — one row per 64 B block, matching the
paper's 256-bit datapath beat structure (two beats per row).
"""

import jax.numpy as jnp

# ChaCha constants: "expa" "nd 3" "2-by" "te k" as little-endian u32.
CHACHA_CONST = (0x61707865, 0x3320646E, 0x79622D32, 0x6B206574)

# Number of ChaCha double rounds (ChaCha20 = 10).
DOUBLE_ROUNDS = 10

U32 = jnp.uint32


def rotl(x, n):
    """Rotate-left each uint32 lane by ``n`` bits."""
    x = x.astype(U32)
    return (x << U32(n)) | (x >> U32(32 - n))


def _quarter_round(a, b, c, d):
    a = a + b
    d = rotl(d ^ a, 16)
    c = c + d
    b = rotl(b ^ c, 12)
    a = a + b
    d = rotl(d ^ a, 8)
    c = c + d
    b = rotl(b ^ c, 7)
    return a, b, c, d


def chacha_block(key, counter, nonce):
    """Keystream block(s) for uint32 ``counter`` (scalar or vector).

    key: (8,) uint32; nonce: (3,) uint32; counter: (...,) uint32.
    Returns (..., 16) uint32 keystream.
    """
    key = key.astype(U32)
    nonce = nonce.astype(U32)
    counter = jnp.asarray(counter, U32)
    batch = counter.shape
    ones = jnp.ones(batch, U32)

    # State lanes 0..15, each shaped like `counter`.
    s = [ones * U32(c) for c in CHACHA_CONST]
    s += [ones * key[i] for i in range(8)]
    s += [counter]
    s += [ones * nonce[i] for i in range(3)]
    init = list(s)

    for _ in range(DOUBLE_ROUNDS):
        # Column rounds.
        s[0], s[4], s[8], s[12] = _quarter_round(s[0], s[4], s[8], s[12])
        s[1], s[5], s[9], s[13] = _quarter_round(s[1], s[5], s[9], s[13])
        s[2], s[6], s[10], s[14] = _quarter_round(s[2], s[6], s[10], s[14])
        s[3], s[7], s[11], s[15] = _quarter_round(s[3], s[7], s[11], s[15])
        # Diagonal rounds.
        s[0], s[5], s[10], s[15] = _quarter_round(s[0], s[5], s[10], s[15])
        s[1], s[6], s[11], s[12] = _quarter_round(s[1], s[6], s[11], s[12])
        s[2], s[7], s[8], s[13] = _quarter_round(s[2], s[7], s[8], s[13])
        s[3], s[4], s[9], s[14] = _quarter_round(s[3], s[4], s[9], s[14])

    out = [s[i] + init[i] for i in range(16)]
    return jnp.stack(out, axis=-1)


def chacha_ref(payload, key, nonce, counter0=0):
    """Counter-mode encrypt/decrypt ``payload`` (blocks, 16) uint32.

    Row ``i`` is XORed with the keystream block at counter ``counter0 + i``.
    Involution: applying twice returns the payload.
    """
    payload = payload.astype(U32)
    n = payload.shape[0]
    counters = U32(counter0) + jnp.arange(n, dtype=U32)
    ks = chacha_block(key, counters, nonce)
    return payload ^ ks


def mix_rows(a, b):
    """Combine two (?, 16) digest rows with an ARX mix."""
    x = a + rotl(b, 7)
    y = b ^ rotl(x, 13)
    z = x + rotl(y, 17)
    return z ^ (y >> U32(3))


def treehash_ref(payload, key):
    """Tree-structured keyed digest of ``payload`` (blocks, 16) uint32.

    Each row is first whitened with the key and its row index; rows are then
    pairwise-combined in a binary tree until one 16-lane (64 B) digest
    remains. Rows must be a power of two (the model layer pads).
    """
    payload = payload.astype(U32)
    n = payload.shape[0]
    assert n & (n - 1) == 0, "treehash rows must be a power of two"
    idx = jnp.arange(n, dtype=U32)[:, None]
    lane = jnp.arange(16, dtype=U32)[None, :]
    key16 = jnp.tile(key.astype(U32), 2)
    rows = payload ^ key16[None, :]
    rows = mix_rows(rows, idx * U32(0x9E3779B9) + lane)
    while rows.shape[0] > 1:
        rows = mix_rows(rows[0::2], rows[1::2])
    return stir(rows[0])


def roll_lanes(x, n):
    """Rotate the 16 lanes of a (..., 16) array by ``n`` positions."""
    return jnp.concatenate([x[..., -n:], x[..., :-n]], axis=-1)


def stir(d):
    """Cross-lane finalization: four mix rounds against lane rotations by
    1/2/4/8 fully diffuse every lane into every other (mix_rows itself is
    lane-wise, which keeps the tree reduction cheap on the VPU)."""
    for n in (1, 2, 4, 8):
        d = mix_rows(d[None, :], roll_lanes(rotl(d, 11), n)[None, :])[0]
    return d


def fletcher_ref(payload):
    """Position-weighted checksum of ``payload`` (blocks, 16) uint32.

    Returns (2,) uint32: ``s1`` = wrapping sum of all words, ``s2`` = the
    position-weighted sum ``sum((N - i) * x_i)`` (equal to the sum of
    prefix sums) — the classic Fletcher structure on u32 lanes.
    """
    x = payload.astype(U32).reshape(-1)
    n = x.shape[0]
    s1 = jnp.sum(x, dtype=U32)
    weights = (U32(n) - jnp.arange(n, dtype=U32)).astype(U32)
    s2 = jnp.sum(weights * x, dtype=U32)
    return jnp.stack([s1, s2])


def pad_to_blocks(data: bytes, min_blocks: int = 1):
    """Pack raw bytes into the (blocks, 16) uint32 layout (zero-padded)."""
    import numpy as np

    blocks = max((len(data) + 63) // 64, min_blocks)
    buf = np.zeros(blocks * 64, dtype=np.uint8)
    buf[: len(data)] = np.frombuffer(data, dtype=np.uint8)
    return jnp.asarray(buf.view(np.uint32).reshape(blocks, 16))
