"""Pallas tree-hash kernel: keyed digest with a fixed 64 B output.

Models the paper's hash/digest accelerators (SHA1-HMAC, SHA-3-512) — the
R-taxonomy case where egress size is fixed no matter how large the input
(§2.2). The digest is a binary tree over 64 B rows: leaves are whitened with
the key and their global row index, then adjacent rows combine pairwise
(ARX mix) until one row remains.

Tiling: each grid step tree-reduces one contiguous ``TILE_ROWS`` tile to a
single row in VMEM (that subtree only touches its own tile — no cross-tile
traffic); the wrapper then recursively reduces the per-tile digests. Because
the tree pairs *adjacent* rows, tile-local subtrees + a tree over tile
digests is exactly the same tree as the flat reference.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE_ROWS = 256

U32 = jnp.uint32


def _tree_reduce(rows):
    """Pairwise-combine (T, 16) rows down to (1, 16); T a power of two."""
    while rows.shape[0] > 1:
        rows = ref.mix_rows(rows[0::2], rows[1::2])
    return rows


def _leaf_kernel(payload_ref, key_ref, idx_ref, out_ref):
    rows = payload_ref[...]
    key16 = jnp.tile(key_ref[...], 2)
    idx = idx_ref[...][:, None]
    lane = jnp.arange(16, dtype=U32)[None, :]
    rows = rows ^ key16[None, :]
    rows = ref.mix_rows(rows, idx * U32(0x9E3779B9) + lane)
    out_ref[...] = _tree_reduce(rows)


def _internal_kernel(rows_ref, out_ref):
    out_ref[...] = _tree_reduce(rows_ref[...])


def treehash(payload, key):
    """Keyed 16-lane (64 B) digest of ``payload`` (B, 16) uint32.

    B must be a power of two (the model layer pads to one).
    """
    b = payload.shape[0]
    assert b & (b - 1) == 0, "treehash rows must be a power of two"
    tile = min(b, TILE_ROWS)
    grid = b // tile
    idx = jnp.arange(b, dtype=U32)
    # Leaf pass: whiten + reduce each tile to one digest row.
    rows = pl.pallas_call(
        _leaf_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile, 16), lambda i: (i, 0)),
            pl.BlockSpec((8,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 16), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid, 16), jnp.uint32),
        interpret=True,
    )(payload.astype(U32), key.astype(U32), idx)
    # Internal passes: reduce per-tile digests the same way.
    while rows.shape[0] > 1:
        n = rows.shape[0]
        t = min(n, TILE_ROWS)
        g = n // t
        rows = pl.pallas_call(
            _internal_kernel,
            grid=(g,),
            in_specs=[pl.BlockSpec((t, 16), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1, 16), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((g, 16), jnp.uint32),
            interpret=True,
        )(rows)
    # Final cross-lane stir (glue ops; they lower into the same HLO module).
    return ref.stir(rows[0])
