"""Pallas ARX stream-cipher kernel (counter mode).

The paper's encryption accelerators (AES-128-CBC, IPSec/ESP) are
table-based designs mapped onto FPGA LUTs. §Hardware-Adaptation (DESIGN.md):
on a TPU-style target the same datapath role — keystream generation + XOR at
line rate — is best served by an ARX cipher: pure add/rotate/xor over
32-bit vector lanes, no gather/scatter, so the whole round function is VPU
element-wise work and the kernel is memory-bound (stream each tile exactly
once).

Layout: payload is ``(blocks, 16)`` uint32 — one row per 64 B ChaCha block.
BlockSpec tiles ``TILE_ROWS`` rows per grid step: payload tile + keystream
live in VMEM (TILE_ROWS×64 B ≤ 16 KiB/tile), the key/nonce are tiny
broadcast operands, and each tile is read and written exactly once —
the HBM↔VMEM schedule the FPGA expressed with AXI streaming.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; numerics are identical.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Rows (64 B blocks) per grid step. 256 rows = 16 KiB payload tile; with
# payload + keystream + output live that is ~48 KiB of VMEM — comfortably
# inside a TPU core's ~16 MiB even with double-buffering.
TILE_ROWS = 256

U32 = jnp.uint32


def _keystream(key, nonce, counters):
    """ChaCha keystream rows for a vector of counters — same math as
    :func:`ref.chacha_block`, expressed over the tile in VMEM."""
    ones = jnp.ones_like(counters)
    s = [ones * U32(c) for c in ref.CHACHA_CONST]
    s += [ones * key[i] for i in range(8)]
    s += [counters]
    s += [ones * nonce[i] for i in range(3)]
    init = list(s)
    for _ in range(ref.DOUBLE_ROUNDS):
        s[0], s[4], s[8], s[12] = ref._quarter_round(s[0], s[4], s[8], s[12])
        s[1], s[5], s[9], s[13] = ref._quarter_round(s[1], s[5], s[9], s[13])
        s[2], s[6], s[10], s[14] = ref._quarter_round(s[2], s[6], s[10], s[14])
        s[3], s[7], s[11], s[15] = ref._quarter_round(s[3], s[7], s[11], s[15])
        s[0], s[5], s[10], s[15] = ref._quarter_round(s[0], s[5], s[10], s[15])
        s[1], s[6], s[11], s[12] = ref._quarter_round(s[1], s[6], s[11], s[12])
        s[2], s[7], s[8], s[13] = ref._quarter_round(s[2], s[7], s[8], s[13])
        s[3], s[4], s[9], s[14] = ref._quarter_round(s[3], s[4], s[9], s[14])
    return jnp.stack([s[i] + init[i] for i in range(16)], axis=-1)


def _chacha_tile_kernel(payload_ref, key_ref, nonce_ref, ctr_ref, out_ref):
    rows = payload_ref[...]
    key = key_ref[...]
    nonce = nonce_ref[...]
    counters = ctr_ref[...]
    out_ref[...] = rows ^ _keystream(key, nonce, counters)


def chacha_encrypt(payload, key, nonce, counters):
    """Counter-mode encrypt/decrypt.

    payload: (B, 16) uint32, B a multiple of TILE_ROWS or < TILE_ROWS.
    key: (8,) uint32. nonce: (3,) uint32. counters: (B,) uint32 — one per
    row (the model layer assigns message-unique counter ranges).
    """
    b = payload.shape[0]
    tile = min(b, TILE_ROWS)
    assert b % tile == 0, f"batch {b} not a multiple of tile {tile}"
    grid = b // tile
    return pl.pallas_call(
        _chacha_tile_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile, 16), lambda i: (i, 0)),
            pl.BlockSpec((8,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile, 16), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 16), jnp.uint32),
        interpret=True,
    )(payload.astype(U32), key.astype(U32), nonce.astype(U32), counters.astype(U32))
