"""Pallas Fletcher-style checksum kernel.

Models the RocksDB CRC32C offload of Table 4: ``s1`` is the wrapping sum of
all uint32 words, ``s2`` the position-weighted sum — both accumulate tile by
tile across the grid (the classic Pallas reduction pattern: initialize the
accumulator on the first grid step, add on every step). Each payload tile is
streamed through VMEM exactly once.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 256

U32 = jnp.uint32


def _fletcher_kernel(payload_ref, rowbase_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros((2,), U32)

    x = payload_ref[...]
    # Weight of word (row r, lane l) is rowbase[r] - l, where the wrapper
    # sets rowbase[r] = total_words - r*16 so the weight is N - global_idx.
    lane = jnp.arange(16, dtype=U32)[None, :]
    w = rowbase_ref[...][:, None] - lane
    s1 = jnp.sum(x, dtype=U32)
    s2 = jnp.sum(w * x, dtype=U32)
    out_ref[...] = out_ref[...] + jnp.stack([s1, s2])


def fletcher(payload):
    """Checksum of ``payload`` (B, 16) uint32 → (2,) uint32 [s1, s2]."""
    b = payload.shape[0]
    tile = min(b, TILE_ROWS)
    assert b % tile == 0, f"batch {b} not a multiple of tile {tile}"
    grid = b // tile
    total_words = U32(b * 16)
    rowbase = total_words - jnp.arange(b, dtype=U32) * U32(16)
    return pl.pallas_call(
        _fletcher_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile, 16), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.uint32),
        interpret=True,
    )(payload.astype(U32), rowbase)
