"""AOT compiler: lower the L2 models to HLO text artifacts.

Interchange format is HLO **text**, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Each model entry point is lowered at a fixed set of batch shapes (the
dynamic batcher in the Rust server pads to the nearest compiled shape). The
output directory gets one ``<name>_b<B>.hlo.txt`` per (entry, batch) plus a
``manifest.txt`` the Rust runtime parses — a simple line format (no JSON
dependency on the Rust side)::

    # name kind batch outputs
    encdig_b256 encrypt_digest 256 2

Usage: ``python -m compile.aot --out ../artifacts``
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Batch shapes (64 B blocks per call) compiled per entry point. The server
# picks the smallest shape that fits a batch and pads.
BATCH_SHAPES = (64, 256, 1024)

# (group, blocks) shapes for the grouped variants: G requests of B blocks
# each per executable call (1 KB and 4 KB request classes).
GROUP_SHAPES = ((8, 16), (32, 16), (8, 64))

U32 = jnp.uint32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entries(batch):
    """(name, fn, example_args, n_outputs) per entry point at one batch."""
    payload = jax.ShapeDtypeStruct((batch, 16), U32)
    key = jax.ShapeDtypeStruct((8,), U32)
    nonce = jax.ShapeDtypeStruct((3,), U32)
    counters = jax.ShapeDtypeStruct((batch,), U32)
    return [
        (
            f"encdig_b{batch}",
            "encrypt_digest",
            model.encrypt_digest,
            (payload, key, nonce, counters),
            2,
        ),
        (f"digest_b{batch}", "digest_only", model.digest_only, (payload, key), 1),
        (f"checksum_b{batch}", "checksum_block", model.checksum_block, (payload,), 1),
    ]


def group_entries(group, batch):
    """Grouped entry points at one (G, B) shape."""
    payloads = jax.ShapeDtypeStruct((group, batch, 16), U32)
    keys = jax.ShapeDtypeStruct((group, 8), U32)
    nonces = jax.ShapeDtypeStruct((group, 3), U32)
    counters = jax.ShapeDtypeStruct((group, batch), U32)
    return [
        (
            f"encdig_g{group}_b{batch}",
            "encrypt_digest_many",
            model.encrypt_digest_many,
            (payloads, keys, nonces, counters),
            2,
        ),
        (
            f"checksum_g{group}_b{batch}",
            "checksum_many",
            model.checksum_many,
            (payloads,),
            1,
        ),
    ]


def build(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []

    def emit(name, kind, fn, args, group, batch, n_out):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        manifest.append(f"{name} {kind} {group} {batch} {n_out}")
        print(f"  {name}: {len(text)} chars")

    for batch in BATCH_SHAPES:
        for name, kind, fn, args, n_out in entries(batch):
            emit(name, kind, fn, args, 1, batch, n_out)
    for group, batch in GROUP_SHAPES:
        for name, kind, fn, args, n_out in group_entries(group, batch):
            emit(name, kind, fn, args, group, batch, n_out)
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("# name kind group batch outputs\n")
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts + manifest to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
