"""L2: accelerator datapaths in JAX, composed from the Pallas kernels.

Each function here is one *accelerator variant* the Rust coordinator serves
through PJRT — the compute the paper's FPGA engines performed. They are
batched over the ``(blocks, 16)`` uint32 payload layout (one row per 64 B
block; see ``kernels/ref.py``) and lowered once per batch shape by
``aot.py`` into ``artifacts/*.hlo.txt``. Python never runs at serve time.

Entry points:

- :func:`encrypt_digest` — the secure-KV / IPSec datapath: counter-mode ARX
  encryption plus a keyed 64 B authentication digest over the ciphertext
  (encrypt-then-MAC).
- :func:`digest_only` — the SHA1-HMAC / SHA-3-512 role (fixed egress).
- :func:`checksum_block` — the RocksDB block-checksum offload (Table 4).
"""

import jax
import jax.numpy as jnp

from .kernels.chacha import chacha_encrypt
from .kernels.fletcher import fletcher
from .kernels.treehash import treehash

U32 = jnp.uint32


def encrypt_digest(payload, key, nonce, counters):
    """Encrypt ``payload`` (B, 16) and MAC the ciphertext.

    Returns ``(ciphertext (B, 16), tag (16,))`` — R = 1 egress for the
    cipher plus a fixed 64 B digest, matching the paper's AES+HMAC pairing
    (Fig 11a). Decryption is the same function (XOR involution); the caller
    re-derives the tag over the ciphertext it received to authenticate.
    """
    cipher = chacha_encrypt(payload, key, nonce, counters)
    tag = treehash(cipher, key)
    return cipher, tag


def digest_only(payload, key):
    """Keyed 64 B digest of ``payload`` (B, 16) — fixed-egress accelerator."""
    return (treehash(payload, key),)


def checksum_block(payload):
    """Fletcher checksum of ``payload`` (B, 16) → (2,) uint32."""
    return (fletcher(payload),)


# ---- Grouped variants (the server's dynamic batcher packs G same-class
# requests into one executable call; empty slots are zero-padded) ----------


def encrypt_digest_many(payloads, keys, nonces, counters):
    """Vectorized :func:`encrypt_digest` over a request group.

    payloads: (G, B, 16); keys: (G, 8); nonces: (G, 3); counters: (G, B).
    Returns (ciphers (G, B, 16), tags (G, 16)) — one tag per request, so
    requests batched together keep independent authentication.
    """
    return jax.vmap(encrypt_digest)(payloads, keys, nonces, counters)


def digest_many(payloads, keys):
    """Vectorized :func:`digest_only`: (G, B, 16) × (G, 8) → ((G, 16),)."""
    return jax.vmap(digest_only)(payloads, keys)


def checksum_many(payloads):
    """Vectorized :func:`checksum_block`: (G, B, 16) → ((G, 2),)."""
    return jax.vmap(checksum_block)(payloads)
