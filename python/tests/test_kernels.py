"""Kernel-vs-oracle correctness: the CORE L1 signal.

All kernels are integer (uint32, wrapping), so every comparison is exact
array equality — no tolerances. Hypothesis sweeps shapes and values.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.chacha import chacha_encrypt
from compile.kernels.fletcher import fletcher
from compile.kernels.treehash import treehash

U32 = np.uint32


def rand_payload(rng, blocks):
    return jnp.asarray(rng.integers(0, 2**32, size=(blocks, 16), dtype=np.uint32))


def rand_key(rng):
    return jnp.asarray(rng.integers(0, 2**32, size=(8,), dtype=np.uint32))


def rand_nonce(rng):
    return jnp.asarray(rng.integers(0, 2**32, size=(3,), dtype=np.uint32))


# ---- chacha ---------------------------------------------------------------


@pytest.mark.parametrize("blocks", [1, 2, 16, 256, 512, 1024])
def test_chacha_matches_ref(blocks):
    rng = np.random.default_rng(blocks)
    p = rand_payload(rng, blocks)
    k, n = rand_key(rng), rand_nonce(rng)
    ctr = jnp.arange(blocks, dtype=jnp.uint32) + jnp.uint32(7)
    got = chacha_encrypt(p, k, n, ctr)
    want = p ^ ref.chacha_block(k, ctr, n)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_chacha_ref_counter_layout():
    # chacha_ref assigns counters counter0 + i; the kernel takes explicit
    # counters — they agree when given the same range.
    rng = np.random.default_rng(1)
    p = rand_payload(rng, 64)
    k, n = rand_key(rng), rand_nonce(rng)
    ctr = jnp.uint32(100) + jnp.arange(64, dtype=jnp.uint32)
    got = chacha_encrypt(p, k, n, ctr)
    want = ref.chacha_ref(p, k, n, counter0=100)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_chacha_involution():
    rng = np.random.default_rng(2)
    p = rand_payload(rng, 128)
    k, n = rand_key(rng), rand_nonce(rng)
    ctr = jnp.arange(128, dtype=jnp.uint32)
    back = chacha_encrypt(chacha_encrypt(p, k, n, ctr), k, n, ctr)
    assert (np.asarray(back) == np.asarray(p)).all()


def test_chacha_rfc7539_vector():
    # RFC 7539 §2.3.2 test vector: key = 00 01 .. 1f, nonce =
    # 00:00:00:09:00:00:00:4a:00:00:00:00 (LE u32 lanes), counter 1.
    key = jnp.asarray(np.frombuffer(bytes(range(32)), dtype=np.uint32).copy())
    nonce_bytes = bytes([0, 0, 0, 9, 0, 0, 0, 0x4A, 0, 0, 0, 0])
    nonce = jnp.asarray(np.frombuffer(nonce_bytes, dtype=np.uint32).copy())
    ks = ref.chacha_block(key, jnp.uint32(1), nonce)
    expect = np.array(
        [
            0xE4E7F110, 0x15593BD1, 0x1FDD0F50, 0xC47120A3,
            0xC7F4D1C7, 0x0368C033, 0x9AAA2204, 0x4E6CD4C3,
            0x466482D2, 0x09AA9F07, 0x05D7C214, 0xA2028BD9,
            0xD19C12B5, 0xB94E16DE, 0xE883D0CB, 0x4E3C50A2,
        ],
        dtype=np.uint32,
    )
    assert (np.asarray(ks) == expect).all()


@settings(max_examples=25, deadline=None)
@given(
    blocks_log2=st.integers(min_value=0, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31),
    ctr0=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_chacha_property_sweep(blocks_log2, seed, ctr0):
    blocks = 1 << blocks_log2
    rng = np.random.default_rng(seed)
    p = rand_payload(rng, blocks)
    k, n = rand_key(rng), rand_nonce(rng)
    ctr = (jnp.uint32(ctr0) + jnp.arange(blocks, dtype=jnp.uint32)).astype(jnp.uint32)
    got = np.asarray(chacha_encrypt(p, k, n, ctr))
    want = np.asarray(ref.chacha_ref(p, k, n, counter0=ctr0))
    assert (got == want).all()
    # Keystream must differ from payload (collision probability ~ 2^-512).
    assert (got != np.asarray(p)).any()


# ---- treehash --------------------------------------------------------------


@pytest.mark.parametrize("blocks", [1, 2, 4, 64, 256, 512, 1024, 4096])
def test_treehash_matches_ref(blocks):
    rng = np.random.default_rng(blocks + 100)
    p = rand_payload(rng, blocks)
    k = rand_key(rng)
    got = treehash(p, k)
    want = ref.treehash_ref(p, k)
    assert (np.asarray(got) == np.asarray(want)).all()
    assert got.shape == (16,)


def test_treehash_bitflip_changes_digest():
    rng = np.random.default_rng(3)
    p = np.asarray(rand_payload(rng, 256)).copy()
    k = rand_key(rng)
    d0 = np.asarray(treehash(jnp.asarray(p), k))
    p[137, 5] ^= 1
    d1 = np.asarray(treehash(jnp.asarray(p), k))
    assert (d0 != d1).any()


def test_treehash_key_dependence():
    rng = np.random.default_rng(4)
    p = rand_payload(rng, 64)
    k1, k2 = rand_key(rng), rand_key(rng)
    d1 = np.asarray(treehash(p, k1))
    d2 = np.asarray(treehash(p, k2))
    assert (d1 != d2).any()


@settings(max_examples=20, deadline=None)
@given(
    blocks_log2=st.integers(min_value=0, max_value=10),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_treehash_property_sweep(blocks_log2, seed):
    blocks = 1 << blocks_log2
    rng = np.random.default_rng(seed)
    p = rand_payload(rng, blocks)
    k = rand_key(rng)
    assert (np.asarray(treehash(p, k)) == np.asarray(ref.treehash_ref(p, k))).all()


# ---- fletcher --------------------------------------------------------------


@pytest.mark.parametrize("blocks", [1, 2, 64, 256, 512, 1024, 2048])
def test_fletcher_matches_ref(blocks):
    rng = np.random.default_rng(blocks + 200)
    p = rand_payload(rng, blocks)
    got = fletcher(p)
    want = ref.fletcher_ref(p)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_fletcher_detects_swap():
    # Position weighting: swapping two different words changes s2.
    rng = np.random.default_rng(5)
    p = np.asarray(rand_payload(rng, 64)).copy()
    assert p[3, 2] != p[40, 9]
    q = p.copy()
    q[3, 2], q[40, 9] = p[40, 9], p[3, 2]
    s_p = np.asarray(fletcher(jnp.asarray(p)))
    s_q = np.asarray(fletcher(jnp.asarray(q)))
    assert s_p[0] == s_q[0]  # plain sum unchanged
    assert s_p[1] != s_q[1]  # weighted sum catches the swap


def test_fletcher_zero_payload():
    p = jnp.zeros((256, 16), jnp.uint32)
    s = np.asarray(fletcher(p))
    assert (s == 0).all()


@settings(max_examples=20, deadline=None)
@given(
    blocks_log2=st.integers(min_value=0, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_fletcher_property_sweep(blocks_log2, seed):
    blocks = 1 << blocks_log2
    rng = np.random.default_rng(seed)
    p = rand_payload(rng, blocks)
    assert (np.asarray(fletcher(p)) == np.asarray(ref.fletcher_ref(p))).all()


# ---- byte packing -----------------------------------------------------------


def test_pad_to_blocks_roundtrip():
    data = bytes(range(256)) * 3  # 768 bytes = 12 blocks
    arr = ref.pad_to_blocks(data)
    assert arr.shape == (12, 16)
    flat = np.asarray(arr).view(np.uint8).reshape(-1)[: len(data)]
    assert bytes(flat) == data


def test_pad_to_blocks_pads_zero():
    arr = ref.pad_to_blocks(b"\xff" * 65)  # 2 blocks, 63 pad bytes
    assert arr.shape == (2, 16)
    flat = np.asarray(arr).view(np.uint8).reshape(-1)
    assert (flat[65:] == 0).all()
