//! Secure key-value store over the real PJRT serving path (Fig 11a's
//! application, end to end).
//!
//! Two tenants store encrypted, authenticated values through the shared
//! accelerator server: every PUT runs the ARX cipher + tree-MAC kernels
//! compiled from Pallas (`make artifacts`), shaped per tenant by the
//! provider's wall-clock token buckets. GETs verify tags; a tampered
//! ciphertext is rejected.
//!
//! Run: `make artifacts && cargo run --release --example secure_kv`

use std::sync::Arc;
use std::time::Instant;

use arcus::apps::SecureKv;
use arcus::server::{Server, ServerConfig};

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        dir.join("manifest.txt").exists(),
        "run `make artifacts` first"
    );
    // gold is shaped at 4× bronze's byte rate (provider-programmed; both
    // below the engine's capacity so the buckets — not the engine — decide).
    let server = Arc::new(Server::start(
        ServerConfig::new(dir)
            .tenant("gold", Some(8e6))
            .tenant("bronze", Some(2e6)),
    )?);
    let gold = SecureKv::new(server.clone(), 0, [0xA5; 8], [1, 2, 3]);
    let bronze = SecureKv::new(server.clone(), 1, [0x5A; 8], [4, 5, 6]);

    // Warm the executable cache (XLA compiles lazily per batch shape):
    // the 1 KB class for the KV values and the 4 KB class for the burst.
    println!("compiling kernels (first touch) ...");
    gold.put(b"warm", &[0u8; 1024]).unwrap();
    let _ = gold.get(b"warm");
    let _ = server.submit_blocking(
        0,
        arcus::server::Work::EncryptDigest {
            data: vec![0; 4096],
            key: [1; 8],
            nonce: [2; 3],
            counter0: 0,
        },
    );

    println!("loading 400 × 1 KB values per tenant through the cipher+MAC kernels ...");
    let value = vec![0xC3u8; 1024];
    let t0 = Instant::now();
    for i in 0..400u32 {
        gold.put(format!("g{i}").as_bytes(), &value).unwrap();
        bronze.put(format!("b{i}").as_bytes(), &value).unwrap();
    }
    let load = t0.elapsed();

    // Reads verify the MAC before decrypting.
    let t0 = Instant::now();
    for i in (0..400u32).step_by(7) {
        assert_eq!(gold.get(format!("g{i}").as_bytes()).unwrap(), value);
        assert_eq!(bronze.get(format!("b{i}").as_bytes()).unwrap(), value);
    }
    let read = t0.elapsed();

    // Tamper with one stored ciphertext: authentication must catch it.
    assert!(bronze.tamper(b"b7", 100));
    let verdict = bronze.get(b"b7");
    println!("tampered value read: {verdict:?} (expected Err(AuthFailed))");
    assert!(verdict.is_err());

    // Burst phase: both tenants flood concurrently; the provider's token
    // buckets (80 vs 20 MB/s) decide who gets what.
    println!("\nburst phase: 600 concurrent 4 KB encrypts per tenant ...");
    use arcus::server::Work;
    let t0 = Instant::now();
    let mut per_tenant: [Vec<_>; 2] = [Vec::new(), Vec::new()];
    for i in 0..600u32 {
        for tenant in [0usize, 1] {
            per_tenant[tenant].push(server.submit(
                tenant,
                Work::EncryptDigest {
                    data: vec![i as u8; 4096],
                    key: [tenant as u32 + 1; 8],
                    nonce: [9; 3],
                    counter0: i * 64,
                },
            ));
        }
    }
    // Equal work, different paid rates: each tenant's *drain time* shows
    // the shaping (gold should finish ~4× sooner).
    let mut bytes = [0u64; 2];
    let mut done_at = [0f64; 2];
    for (tenant, rxs) in per_tenant.into_iter().enumerate() {
        for rx in rxs {
            bytes[tenant] += rx.recv().unwrap().bytes as u64;
        }
        done_at[tenant] = t0.elapsed().as_secs_f64();
    }
    let g = bytes[0] as f64 / done_at[0] / 1e6;
    let b = bytes[1] as f64 / done_at[1] / 1e6;
    println!(
        "  gold {:.1} MB/s (drained in {:.0} ms) vs bronze {:.1} MB/s ({:.0} ms) — rate ratio {:.2} (shaped 4:1)",
        g,
        done_at[0] * 1e3,
        b,
        done_at[1] * 1e3,
        g / b.max(1e-9)
    );

    let stats = server.stats();
    println!("\ntenant   completed   goodput        p50        p99");
    for (name, t) in ["gold", "bronze"].iter().zip(stats.tenants.iter()) {
        println!(
            "{:<8} {:>9} {:>9.2}MB/s {:>8.1}µs {:>9.1}µs",
            name,
            t.completed,
            t.goodput() / 1e6,
            t.latency_ns.percentile(50.0) as f64 / 1e3,
            t.latency_ns.percentile(99.0) as f64 / 1e3,
        );
    }
    println!(
        "\nload: {:.2}s  verified reads: {:.2}s  batches: {} (mean fill {:.1})",
        load.as_secs_f64(),
        read.as_secs_f64(),
        stats.batches,
        stats.mean_group_fill()
    );
    println!("gold's shaped rate is 4× bronze's — check the goodput ratio above.");
    Ok(())
}
