//! Storage SLO protection (the Fig 11b scenario, as a runnable demo).
//!
//! A read-heavy tenant (1 KB random reads, SLO 2 M IOPS) shares a 4-drive
//! RAID-0 with a write-heavy tenant (4 KB sequential writes, SLO 25 K
//! IOPS). SSD-internal read/write interference means unshaped writes
//! poison reads; Arcus shapes the write stream to its SLO and the reads
//! survive.
//!
//! Run: `cargo run --release --example storage_slo`

use arcus::storage::SsdConfig;
use arcus::system::{run, ExperimentSpec, Mode};
use arcus::util::units::{MILLIS};
use arcus::workload::{fio_read_flow, fio_write_flow, FioJob};

fn main() {
    let flows = vec![
        fio_read_flow(
            0,
            FioJob { vm: 0, bs: 1024, offered_iops: 2_300_000.0, slo_iops: 2_000_000.0 },
        ),
        fio_write_flow(
            1,
            FioJob { vm: 1, bs: 4096, offered_iops: 50_000.0, slo_iops: 25_000.0 },
        ),
    ];
    println!("reads: SLO 2M IOPS (1KB random)   writes: SLO 25K IOPS (4KB seq, 50K offered)\n");
    for mode in [Mode::Arcus, Mode::HostNoTs] {
        let spec = ExperimentSpec::new(mode, vec![], flows.clone())
            .with_duration(20 * MILLIS)
            .with_warmup(2 * MILLIS)
            .with_raid(4, SsdConfig::samsung_983dct());
        let r = run(&spec);
        let rd = &r.per_flow[0];
        let wr = &r.per_flow[1];
        println!("=== {} ===", r.mode);
        println!(
            "  reads : {:>8.0} KIOPS ({:>5.1}% of SLO)  p99 {:.2} ms",
            rd.iops / 1e3,
            rd.slo_attainment().unwrap_or(0.0) * 100.0,
            rd.lat_p99 as f64 / 1e9
        );
        println!(
            "  writes: {:>8.1} KIOPS ({:>5.1}% of SLO)",
            wr.iops / 1e3,
            wr.slo_attainment().unwrap_or(0.0) * 100.0
        );
        println!("  total : {:>8.0} KIOPS\n", (rd.iops + wr.iops) / 1e3);
    }
    println!("Unshaped writes run at 2× their SLO and the SSDs' read/write interference");
    println!("collapses read throughput; shaping the writes protects the read tenant.");
}
