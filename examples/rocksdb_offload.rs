//! END-TO-END DRIVER: the full three-layer stack on a real workload.
//!
//! This is the repository's proof that all layers compose (Table 4's
//! experiment as a living system):
//!
//!   L1  Pallas kernels (ARX cipher / tree hash / Fletcher) — compiled once
//!       by `make artifacts` into HLO text;
//!   L2  JAX models batching them over request groups;
//!   L3  the Rust server: per-tenant wall-clock token buckets, dynamic
//!       batcher, PJRT engine thread — serving a mini-LSM storage engine
//!       that offloads every SST block's checksum (and compression to the
//!       offload pool) while a secure-KV tenant shares the same engines.
//!
//! Reported: serving latency/throughput per tenant, batching efficiency,
//! LSM write throughput + app-thread CPU vs the all-CPU baseline, and a
//! correctness audit (read-back + checksum verification) at the end.
//!
//! Run: `make artifacts && cargo run --release --example rocksdb_offload`

use std::sync::Arc;
use std::time::Instant;

use arcus::apps::{thread_cpu_seconds, Backend, CompressorPool, MiniLsm, MiniLsmConfig, SecureKv};
use arcus::server::{Server, ServerConfig};

fn lsm_cfg() -> MiniLsmConfig {
    MiniLsmConfig { memtable_bytes: 512 * 1024, block_bytes: 4096, l0_compact_at: 4 }
}

fn row(i: u32) -> (Vec<u8>, Vec<u8>) {
    // Mildly compressible serialized rows, like real LSM payloads.
    let key = format!("user{:010}", i * 7919 % 1_000_000);
    let val = format!(
        "{{\"id\":{i},\"name\":\"user-{i}\",\"flags\":\"{}\",\"pad\":\"{}\"}}",
        "abcdefgh".repeat(4),
        "x".repeat(100 + (i % 64) as usize)
    );
    (key.into_bytes(), val.into_bytes())
}

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(dir.join("manifest.txt").exists(), "run `make artifacts` first");

    println!("== arcus end-to-end driver: LSM offload + secure KV on one PJRT engine ==\n");

    // ---- Baseline: everything on the application thread. ----------------
    let n_rows = 60_000u32;
    let mut baseline = MiniLsm::new(lsm_cfg(), Backend::Cpu);
    let cpu0 = thread_cpu_seconds();
    let t0 = Instant::now();
    for i in 0..n_rows {
        let (k, v) = row(i);
        baseline.put(&k, &v);
    }
    baseline.flush();
    let base_wall = t0.elapsed().as_secs_f64();
    let base_cpu = thread_cpu_seconds() - cpu0;
    let logical_mb = baseline.stats.logical_bytes as f64 / 1e6;

    // ---- Arcus-enabled: checksums through PJRT, compression offloaded, --
    //      plus a co-located secure-KV tenant on the same engine. ---------
    let server = Arc::new(Server::start(
        ServerConfig::new(&dir)
            .tenant("rocksdb", None)
            .tenant("securekv", Some(30e6))
            .with_queue_cap(1 << 16),
    )?);
    // Warm the executable cache outside the measured window.
    let _ = server.submit_blocking(0, arcus::server::Work::Checksum { data: vec![0; 4096] });
    let _ = server.submit_blocking(
        1,
        arcus::server::Work::EncryptDigest { data: vec![0; 1024], key: [1; 8], nonce: [2; 3], counter0: 0 },
    );
    let pool = Arc::new(CompressorPool::new(6));
    let mut lsm = MiniLsm::new(
        lsm_cfg(),
        Backend::Offload { server: server.clone(), tenant: 0, pool },
    );
    let kv = SecureKv::new(server.clone(), 1, [0xAB; 8], [7, 8, 9]);

    // The KV tenant hums along on another thread while the LSM writes.
    let kv = Arc::new(kv);
    let kv_thread = {
        let kv = kv.clone();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let stop2 = stop.clone();
        let h = std::thread::spawn(move || {
            let mut n = 0u64;
            let val = vec![0xEE; 512];
            while !stop2.load(std::sync::atomic::Ordering::Relaxed) {
                let k = format!("kv{}", n % 512);
                kv.put(k.as_bytes(), &val).unwrap();
                if n % 8 == 0 {
                    let _ = kv.get(k.as_bytes());
                }
                n += 1;
            }
            n
        });
        (stop, h)
    };

    let cpu0 = thread_cpu_seconds();
    let t0 = Instant::now();
    for i in 0..n_rows {
        let (k, v) = row(i);
        lsm.put(&k, &v);
    }
    lsm.flush();
    let off_wall = t0.elapsed().as_secs_f64();
    let off_cpu = thread_cpu_seconds() - cpu0;

    kv_thread.0.store(true, std::sync::atomic::Ordering::Relaxed);
    let kv_ops = kv_thread.1.join().unwrap();

    // ---- Correctness audit: read back through the verified path. --------
    let t0 = Instant::now();
    let mut audited = 0u32;
    for i in (0..n_rows).step_by(997) {
        let (k, v) = row(i);
        // Later rows may have overwritten earlier ones (keys repeat by
        // construction); only assert when this i produced the last write.
        if let Some(got) = lsm.get(&k) {
            if got == v {
                audited += 1;
            }
        }
    }
    let audit = t0.elapsed().as_secs_f64();
    assert_eq!(lsm.stats.checksum_failures, 0, "no corruption in the verified path");

    // ---- Report. ---------------------------------------------------------
    let stats = server.stats();
    println!("LSM write path ({logical_mb:.1} MB logical, write-amp {:.2}):",
        lsm.stats.pipeline_bytes as f64 / lsm.stats.logical_bytes as f64);
    println!("{:<24} {:>12} {:>16}", "", "thr (MB/s)", "app-CPU (s/GB)");
    println!("{:<24} {:>12.1} {:>16.2}", "  ext4-style (CPU)", logical_mb / base_wall, base_cpu / (logical_mb / 1e3));
    println!("{:<24} {:>12.1} {:>16.2}", "  Arcus-enabled", logical_mb / off_wall, off_cpu / (logical_mb / 1e3));
    println!(
        "  → throughput {:.2}×, app-thread CPU savings {:.1}%  (paper Table 4: 1.43×, 58.9%)",
        (logical_mb / off_wall) / (logical_mb / base_wall),
        (1.0 - off_cpu / base_cpu.max(1e-9)) * 100.0
    );

    println!("\nServing engine:");
    println!(
        "  batches {}  mean group fill {:.1} requests/call",
        stats.batches,
        stats.mean_group_fill()
    );
    for (name, t) in ["rocksdb", "securekv"].iter().zip(stats.tenants.iter()) {
        println!(
            "  {:<9} {:>8} reqs  {:>8.2} MB/s  p50 {:>7.1} µs  p99 {:>8.1} µs",
            name,
            t.completed,
            t.goodput() / 1e6,
            t.latency_ns.percentile(50.0) as f64 / 1e3,
            t.latency_ns.percentile(99.0) as f64 / 1e3
        );
    }
    println!("  securekv co-tenant completed {kv_ops} ops while the LSM wrote");
    println!("\nAudit: {audited} sampled keys verified through checksum+decompress in {audit:.2}s;");
    println!("checksum failures: {} (every block re-verified through the PJRT kernels).", lsm.stats.checksum_failures);
    Ok(())
}
