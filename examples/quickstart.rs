//! Quickstart: two tenants, one 32 Gbps IPSec engine, SLOs of 10 and 12 Gbps.
//!
//! Both tenants offer ~16 Gbps (oversubscribed). Under Arcus, per-flow
//! hardware token buckets fetch each tenant's DMA buffer at exactly the SLO
//! pace (PatternA → PatternA′); the unshaped baseline splits the engine by
//! arbitration luck.
//!
//! Run: `cargo run --release --example quickstart`

use arcus::accel::AccelModel;
use arcus::flow::{FlowSpec, Path, Slo, TrafficPattern};
use arcus::system::{run, ExperimentSpec, Mode};
use arcus::util::units::{Rate, MILLIS};

fn main() {
    let line = Rate::gbps(32.0);
    let flows = vec![
        FlowSpec::new(
            0,
            0,
            Path::FunctionCall,
            TrafficPattern::fixed(1500, 0.5, line),
            Slo::gbps(10.0),
            0,
        ),
        FlowSpec::new(
            1,
            1,
            Path::FunctionCall,
            TrafficPattern::fixed(1500, 0.5, line),
            Slo::gbps(12.0),
            0,
        ),
    ];

    println!("tenant SLOs: 10 Gbps and 12 Gbps; both offer ~16 Gbps\n");
    for mode in [Mode::Arcus, Mode::HostNoTs] {
        let spec = ExperimentSpec::new(mode, vec![AccelModel::ipsec_32g()], flows.clone())
            .with_duration(20 * MILLIS)
            .with_warmup(2 * MILLIS);
        let report = run(&spec);
        println!("=== {} ===", mode.name());
        for f in &report.per_flow {
            println!(
                "  tenant {}: {:>7.2} Gbps  (SLO attainment {:>5.1}%, window CV {:.2}%)",
                f.vm,
                f.goodput.as_gbps(),
                f.slo_attainment().unwrap_or(0.0) * 100.0,
                f.sampler.cv() * 100.0
            );
        }
        println!();
    }
    println!("Arcus: both tenants land on their SLO with <1% variance.");
    println!("Baseline: the engine splits evenly — whoever paid for more loses it.");
}
