//! Chaos recovery — SLO behavior under fault & adversary injection.
//!
//! The paper's evaluation stops at healthy hardware; this bench opens the
//! degraded regime ("SLO beyond the hardware isolation limits"): the same
//! Arcus-vs-baseline grid is swept across the fault-injection axis
//! (accelerator dip, PCIe link cut, deep link flap, adversarial tenant,
//! control-plane outage) and reports the fault-era attainment floor plus
//! the post-fault recovery time the control plane achieves.
//!
//! Run: `cargo bench --bench chaos_recovery` (ARCUS_BENCH_FAST=1 for CI).

#[path = "common.rs"]
mod common;

use arcus::flow::pattern::Burstiness;
use arcus::flow::Path;
use arcus::sweep::{aggregate, ControlKind, FaultProfile, GridBase, SizeMix, SweepGrid, SweepRunner};
use arcus::system::Mode;
use arcus::util::units::Rate;
use common::*;

fn main() {
    banner("Chaos recovery: fault-era attainment floor + recovery time by fault profile");
    // 3 tenants at 70% tightness: healthy attainment is ~1.0 with slack,
    // so every dip below is the fault's doing, not oversubscription.
    let base = || {
        SweepGrid::new(GridBase {
            duration: bench_duration(),
            warmup: warmup(),
            line_rate: Rate::gbps(32.0),
            load: 0.9,
            path: Path::FunctionCall,
            seed: 1,
        })
        .tenants(vec![3])
        .mixes(vec![SizeMix::Mtu])
        .bursts(vec![Burstiness::Poisson])
        .tightness(vec![0.7])
        .faults(FaultProfile::ALL.to_vec())
        .accels(vec![arcus::accel::AccelModel::ipsec_32g()])
        .seeds(vec![1, 2])
    };
    // The three static management architectures, plus the closed-loop
    // adaptive plane as a fourth profile (adaptive only wraps the Arcus
    // runtime, so it sweeps as its own Arcus-mode grid rather than a
    // control axis over the unmanaged baselines). The combined aggregate
    // renders a [by control] static-vs-adaptive comparison.
    let static_grid = base().modes(vec![Mode::Arcus, Mode::HostNoTs, Mode::BypassedPanic]);
    static_grid.validate().expect("chaos grid is well-formed");
    let adaptive_grid = base().modes(vec![Mode::Arcus]).control(vec![ControlKind::Adaptive]);
    adaptive_grid.validate().expect("adaptive chaos grid is well-formed");
    let runner = SweepRunner::new();
    let mut outcomes = runner.run(&static_grid);
    outcomes.extend(runner.run(&adaptive_grid));
    let agg = aggregate(&outcomes);
    print!("{}", agg.render());
    println!();
    banner("Per-scenario fault metrics (att.min during fault era; recovery µs)");
    println!(
        "{:<52} {:>8} {:>9} {:>6}",
        "scenario", "f.att", "rec(us)", "unrec"
    );
    for s in &agg.scenarios {
        let opt = |v: Option<f64>, p: usize| {
            v.map(|x| format!("{x:.p$}")).unwrap_or_else(|| "-".into())
        };
        println!(
            "{:<52} {:>8} {:>9} {:>6}",
            s.key.label(),
            opt(s.fault_att_min, 3),
            opt(s.recovery_us_max, 1),
            s.unrecovered
        );
    }
    println!();
    println!("Reading: Arcus's reaction paths (reshape, BE refresh, over-commit");
    println!("reconciliation) bound the fault-era damage and recover within a few");
    println!("control periods; the unmanaged baselines neither clamp adversaries");
    println!("nor re-plan around degradation.");
}
