//! Fig 11(b) — inline P2P mode: storage reads vs writes on shared RAID-0.
//!
//! User1 runs 1 KB random reads (SLO 2 M IOPS), user2 runs 4 KB sequential
//! writes (SLO 25 K IOPS) on a 4-drive RAID-0. The paper reports:
//!   - Arcus realizes both IOPS SLOs with 99th% latency < 2 ms;
//!   - the baseline lets writes over-provision (up to 50 K IOPS) while
//!     reads fall to 44% of their SLO — internal SSD read/write
//!     interference makes unshaped writes poison reads — degrading overall
//!     RAID throughput 2.2×.

#[path = "common.rs"]
mod common;

use arcus::storage::SsdConfig;
use arcus::system::{ExperimentSpec, Mode};
use arcus::util::units::MILLIS;
use arcus::workload::{fio_read_flow, fio_write_flow, FioJob};
use common::*;

fn spec(mode: Mode) -> ExperimentSpec {
    let flows = vec![
        fio_read_flow(
            0,
            FioJob { vm: 0, bs: 1024, offered_iops: 2_300_000.0, slo_iops: 2_000_000.0 },
        ),
        fio_write_flow(
            1,
            FioJob { vm: 1, bs: 4096, offered_iops: 50_000.0, slo_iops: 25_000.0 },
        ),
    ];
    ExperimentSpec::new(mode, vec![], flows)
        .with_duration(bench_duration())
        .with_warmup(warmup())
        .with_raid(4, SsdConfig::samsung_983dct())
}

fn main() {
    let modes = [Mode::Arcus, Mode::HostNoTs];
    let reports = parallel_sweep(modes.iter().map(|&m| spec(m)).collect());

    banner("Fig 11(b): 1KB random reads (SLO 2M IOPS) + 4KB seq writes (SLO 25K IOPS), RAID-0 ×4");
    println!(
        "{:<16} {:>12} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "system", "read KIOPS", "read att.%", "write KIOPS", "write att.%", "read p99 ms", "total KIOPS"
    );
    for (m, r) in modes.iter().zip(reports.iter()) {
        let rd = &r.per_flow[0];
        let wr = &r.per_flow[1];
        println!(
            "{:<16} {:>12.0} {:>11.1}% {:>12.1} {:>11.1}% {:>12.2} {:>12.0}",
            m.name(),
            rd.iops / 1e3,
            pct(rd.slo_attainment().unwrap_or(0.0)),
            wr.iops / 1e3,
            pct(wr.slo_attainment().unwrap_or(0.0)),
            rd.lat_p99 as f64 / MILLIS as f64,
            (rd.iops + wr.iops) / 1e3,
        );
    }
    let arcus_total = reports[0].per_flow[0].iops + reports[0].per_flow[1].iops;
    let base_total = reports[1].per_flow[0].iops + reports[1].per_flow[1].iops;
    println!(
        "\nOverall RAID throughput: Arcus {:.0}K vs baseline {:.0}K IOPS — degradation {:.2}×  (paper: 2.2×)",
        arcus_total / 1e3,
        base_total / 1e3,
        arcus_total / base_total.max(1.0)
    );
    println!("Paper shape: baseline writes over-provision to ~50K while reads fall to ~44% of SLO;");
    println!("Arcus shapes writes to exactly 25K, protecting reads from SSD-internal interference.");
}
