//! Shared bench scaffolding: parallel sweeps + paper-style table printing.
//!
//! Every bench binary regenerates one table/figure of the paper: it builds
//! the experiment specs, runs them (sweep points are independent, so they
//! fan out over threads), and prints the same rows/series the paper
//! reports. `ARCUS_BENCH_FAST=1` shortens the virtual duration for smoke
//! runs (CI); absolute numbers shift slightly but the shapes hold.

#![allow(dead_code)]

use arcus::system::{ExperimentSpec, SystemReport};
use arcus::util::units::{Time, MILLIS};

/// Measured virtual duration for sweeps.
pub fn bench_duration() -> Time {
    if fast_mode() {
        4 * MILLIS
    } else {
        20 * MILLIS
    }
}

pub fn warmup() -> Time {
    if fast_mode() {
        MILLIS
    } else {
        2 * MILLIS
    }
}

pub fn fast_mode() -> bool {
    std::env::var("ARCUS_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Run a set of independent experiment specs across threads.
///
/// Thin wrapper over the library's scenario-sweep engine
/// ([`arcus::sweep::run_specs`]): benches and tests share one parallel
/// execution substrate, and reports come back in input order.
pub fn parallel_sweep(specs: Vec<ExperimentSpec>) -> Vec<SystemReport> {
    arcus::sweep::run_specs(specs)
}

/// Section header in the output.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

/// Print a row of f64 cells after a label.
pub fn row(label: &str, cells: &[f64], fmt_width: usize, precision: usize) {
    print!("{label:<28}");
    for c in cells {
        print!(" {c:>fmt_width$.precision$}");
    }
    println!();
}

/// Print a header row.
pub fn header(label: &str, cells: &[String], width: usize) {
    print!("{label:<28}");
    for c in cells {
        print!(" {c:>width$}");
    }
    println!();
}

/// Percent formatting helper.
pub fn pct(x: f64) -> f64 {
    x * 100.0
}
