//! Shared bench scaffolding: parallel sweeps + paper-style table printing.
//!
//! Every bench binary regenerates one table/figure of the paper: it builds
//! the experiment specs, runs them (sweep points are independent, so they
//! fan out over threads), and prints the same rows/series the paper
//! reports. `ARCUS_BENCH_FAST=1` shortens the virtual duration for smoke
//! runs (CI); absolute numbers shift slightly but the shapes hold.

#![allow(dead_code)]

use arcus::system::{run, ExperimentSpec, SystemReport};
use arcus::util::units::{Time, MILLIS};

/// Measured virtual duration for sweeps.
pub fn bench_duration() -> Time {
    if fast_mode() {
        4 * MILLIS
    } else {
        20 * MILLIS
    }
}

pub fn warmup() -> Time {
    if fast_mode() {
        MILLIS
    } else {
        2 * MILLIS
    }
}

pub fn fast_mode() -> bool {
    std::env::var("ARCUS_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Run a set of independent experiment specs across threads.
pub fn parallel_sweep(specs: Vec<ExperimentSpec>) -> Vec<SystemReport> {
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(specs.len().max(1));
    let specs = std::sync::Arc::new(std::sync::Mutex::new(
        specs.into_iter().enumerate().collect::<Vec<_>>(),
    ));
    let results = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
    let handles: Vec<_> = (0..threads)
        .map(|_| {
            let specs = specs.clone();
            let results = results.clone();
            std::thread::spawn(move || loop {
                let job = specs.lock().unwrap().pop();
                match job {
                    Some((idx, spec)) => {
                        let report = run(&spec);
                        results.lock().unwrap().push((idx, report));
                    }
                    None => return,
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("sweep worker");
    }
    let mut out = std::sync::Arc::try_unwrap(results)
        .ok()
        .expect("all workers joined")
        .into_inner()
        .unwrap();
    out.sort_by_key(|(i, _)| *i);
    out.into_iter().map(|(_, r)| r).collect()
}

/// Section header in the output.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

/// Print a row of f64 cells after a label.
pub fn row(label: &str, cells: &[f64], fmt_width: usize, precision: usize) {
    print!("{label:<28}");
    for c in cells {
        print!(" {c:>fmt_width$.precision$}");
    }
    println!();
}

/// Print a header row.
pub fn header(label: &str, cells: &[String], width: usize) {
    print!("{label:<28}");
    for c in cells {
        print!(" {c:>width$}");
    }
    println!();
}

/// Percent formatting helper.
pub fn pct(x: f64) -> f64 {
    x * 100.0
}
