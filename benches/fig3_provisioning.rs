//! Fig 3 — inaccurate accelerator resource provisioning (§3.1).
//!
//! CaseT 1–4: two VMs share one 32 Gbps IPSec engine behind a PANIC-style
//! interface (no shaping); VM2's load sweeps 0.1–0.9. The paper's
//! observations to reproduce:
//!   (b) tiny-message mixtures hold the engine to 18–32% of 32 Gbps,
//!   (-) SLOs (10/20 G) are violated in all four cases,
//!   (-) fairness points drift with the size mixture,
//!   (e) one VM's rising load can shrink *or* grow its neighbour's share.
//!
//! CaseP: each VM owns a private 50 Gbps synthetic accelerator; contention
//! is purely PCIe. Same-path (both inline-NIC RX, both loading the Up
//! direction) vs multi-path (function call + RX, exploiting full duplex):
//! the paper reports ~4× unfairness same-path and ~85% of the PCIe ideal
//! multi-path.

#[path = "common.rs"]
mod common;

use arcus::accel::AccelModel;
use arcus::flow::{FlowSpec, Path, Slo, TrafficPattern};
use arcus::system::{ExperimentSpec, Mode};
use arcus::util::units::{Rate, KB};
use common::*;

const LOADS: [f64; 9] = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];

fn caset_spec(vm1_size: u64, vm2_size: u64, vm2_load: f64) -> ExperimentSpec {
    let line = Rate::gbps(32.0);
    let flows = vec![
        FlowSpec::new(
            0,
            0,
            Path::FunctionCall,
            TrafficPattern::fixed(vm1_size, 0.1, line),
            Slo::gbps(10.0),
            0,
        ),
        FlowSpec::new(
            1,
            1,
            Path::FunctionCall,
            TrafficPattern::fixed(vm2_size, vm2_load, line),
            Slo::gbps(20.0),
            0,
        ),
    ];
    ExperimentSpec::new(Mode::BypassedPanic, vec![AccelModel::ipsec_32g()], flows)
        .with_duration(bench_duration())
        .with_warmup(warmup())
}

fn casep_spec(same_path: bool, vm2_load: f64) -> ExperimentSpec {
    let line = Rate::gbps(50.0);
    // Multi-path: VM1's invocations load the host→device (Down) direction
    // (payload fetched by DMA read, result leaves on the wire) while VM2's
    // RX traffic loads device→host (Up) — the full-duplex split the paper
    // attributes to mixing Function Call with Inline RX.
    let vm1_path = if same_path { Path::InlineNicRx } else { Path::InlineNicTx };
    let flows = vec![
        FlowSpec::new(
            0,
            0,
            vm1_path,
            TrafficPattern::fixed(4 * KB, 0.4, line),
            Slo::gbps(50.0),
            0,
        ),
        FlowSpec::new(
            1,
            1,
            Path::InlineNicRx,
            TrafficPattern::fixed(64, vm2_load, line),
            Slo::gbps(50.0),
            1,
        ),
    ];
    ExperimentSpec::new(
        Mode::HostNoTs,
        vec![
            AccelModel::synthetic(Rate::gbps(50.0)),
            AccelModel::synthetic(Rate::gbps(50.0)),
        ],
        flows,
    )
    .with_duration(bench_duration())
    .with_warmup(warmup())
}

fn main() {
    banner("Fig 3(b–e): CaseT — traffic-pattern mixtures on a shared 32G IPSec (PANIC, no shaping)");
    let cases: [(&str, u64, u64); 4] = [
        ("CaseT1 {256B} vs {64B}", 256, 64),
        ("CaseT2 {256B} vs {512B}", 256, 512),
        ("CaseT3 {128B} vs {512B}", 128, 512),
        ("CaseT4 {1500B} vs {512B}", 1500, 512),
    ];
    let loads: Vec<f64> = LOADS.to_vec();
    for (name, s1, s2) in cases {
        let specs: Vec<_> = loads.iter().map(|&l| caset_spec(s1, s2, l)).collect();
        let reports = parallel_sweep(specs);
        banner(name);
        header(
            "VM2 load",
            &loads.iter().map(|l| format!("{l:.1}")).collect::<Vec<_>>(),
            7,
        );
        row(
            "VM1 Gbps (SLO 10)",
            &reports.iter().map(|r| r.per_flow[0].goodput.as_gbps()).collect::<Vec<_>>(),
            7,
            2,
        );
        row(
            "VM2 Gbps (SLO 20)",
            &reports.iter().map(|r| r.per_flow[1].goodput.as_gbps()).collect::<Vec<_>>(),
            7,
            2,
        );
        row(
            "overall / 32G (%)",
            &reports
                .iter()
                .map(|r| pct(r.total_goodput().as_gbps() / 32.0))
                .collect::<Vec<_>>(),
            7,
            1,
        );
    }

    banner("Fig 3(f): CaseP — PCIe path contention (per-VM 50G synthetic accelerators)");
    for (name, same) in [("CaseP_same_path  (RX+RX)", true), ("CaseP_multi_path (FC+RX)", false)] {
        let specs: Vec<_> = loads.iter().map(|&l| casep_spec(same, l)).collect();
        let reports = parallel_sweep(specs);
        banner(name);
        header(
            "VM2 load",
            &loads.iter().map(|l| format!("{l:.1}")).collect::<Vec<_>>(),
            7,
        );
        row(
            "VM1 Gbps (4KB)",
            &reports.iter().map(|r| r.per_flow[0].goodput.as_gbps()).collect::<Vec<_>>(),
            7,
            2,
        );
        row(
            "VM2 Gbps (64B)",
            &reports.iter().map(|r| r.per_flow[1].goodput.as_gbps()).collect::<Vec<_>>(),
            7,
            2,
        );
        row(
            "overall Gbps",
            &reports.iter().map(|r| r.total_goodput().as_gbps()).collect::<Vec<_>>(),
            7,
            2,
        );
        row(
            "VM1/VM2 ratio",
            &reports
                .iter()
                .map(|r| r.per_flow[0].goodput.0 / r.per_flow[1].goodput.0.max(1.0))
                .collect::<Vec<_>>(),
            7,
            2,
        );
        row(
            "PCIe Up util (%)",
            &reports.iter().map(|r| pct(r.pcie_up_util)).collect::<Vec<_>>(),
            7,
            1,
        );
        row(
            "PCIe Down util (%)",
            &reports.iter().map(|r| pct(r.pcie_down_util)).collect::<Vec<_>>(),
            7,
            1,
        );
    }
    println!("\nPaper shapes to check: CaseT1 overall 18–32% of 32G; fairness points drift per case;");
    println!("CaseP same-path VM1≫VM2 (paper ~4×) with overall ≈55% of multi-path; multi-path uses both directions.");
}
