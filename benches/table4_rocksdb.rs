//! Table 4 — RocksDB-style LSM throughput with checksum+compression
//! offload (function-call mode), CPU vs Arcus-enabled.
//!
//! This bench runs on the REAL serving path: the offload backend sends
//! every SST block's checksum through the PJRT engine (grouped executable
//! calls) and its compression to the offload pool; the baseline does both
//! on the application thread. Reported: sustained write throughput (MB/s)
//! and the application thread's CPU seconds per logical GB — the paper's
//! 1.43× throughput / 58.9% CPU-savings claim, scaled to this testbed.

#[path = "common.rs"]
mod common;

use std::sync::Arc;
use std::time::Instant;

use arcus::apps::{thread_cpu_seconds, Backend, CompressorPool, MiniLsm, MiniLsmConfig};
use arcus::server::{Server, ServerConfig};
use common::banner;

fn workload(lsm: &mut MiniLsm, mb: usize) -> (f64, f64, f64) {
    // Write `mb` MB of mildly-compressible rows, measuring wall time and
    // this thread's CPU time.
    let value: Vec<u8> = (0..800u32)
        .map(|i| if i % 5 == 0 { (i % 251) as u8 } else { b'x' })
        .collect();
    let n = mb * 1024 * 1024 / (value.len() + 16);
    let cpu0 = thread_cpu_seconds();
    let t0 = Instant::now();
    for i in 0..n {
        lsm.put(format!("key-{i:012}").as_bytes(), &value);
    }
    lsm.flush();
    let wall = t0.elapsed().as_secs_f64();
    let cpu = thread_cpu_seconds() - cpu0;
    let logical_mb = lsm.stats.logical_bytes as f64 / 1e6;
    (logical_mb / wall, cpu, logical_mb)
}

fn main() {
    let fast = common::fast_mode();
    let mb = if fast { 24 } else { 96 };
    let cfg = || MiniLsmConfig {
        memtable_bytes: 1024 * 1024,
        block_bytes: 4096,
        l0_compact_at: 4,
    };

    banner("Table 4: LSM write path, ext4-style CPU baseline vs Arcus-enabled offload");

    // CPU baseline.
    let mut base = MiniLsm::new(cfg(), Backend::Cpu);
    let (base_thr, base_cpu, logical_mb) = workload(&mut base, mb);

    // Offload: checksum via PJRT server, compression via the pool.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("(skipping offload run: run `make artifacts` first)");
        return;
    }
    // Compaction fans an entire level's blocks into the checksum engine at
    // once: size the submission queue accordingly.
    let server = Arc::new(
        Server::start(
            ServerConfig::new(dir).tenant("rocksdb", None).with_queue_cap(1 << 16),
        )
        .expect("server"),
    );
    // Warm the executable cache outside the measured window.
    let _ = server.submit_blocking(0, arcus::server::Work::Checksum { data: vec![0; 4096] });
    // The offload device runs its own parallel compression engines (the
    // paper's 16 Gbps compressor); 6 pool threads stand in for them.
    let pool = Arc::new(CompressorPool::new(6));
    let mut off = MiniLsm::new(cfg(), Backend::Offload { server: server.clone(), tenant: 0, pool });
    let (off_thr, off_cpu, _) = workload(&mut off, mb);
    let stats = server.stats();

    println!("{:<22} {:>12} {:>16} {:>14}", "", "thr (MB/s)", "app-CPU (s/GB)", "write-amp");
    println!(
        "{:<22} {:>12.1} {:>16.3} {:>14.2}",
        "ext4 (CPU)",
        base_thr,
        base_cpu / (logical_mb / 1e3),
        base.stats.pipeline_bytes as f64 / base.stats.logical_bytes as f64
    );
    println!(
        "{:<22} {:>12.1} {:>16.3} {:>14.2}",
        "Arcus-enabled",
        off_thr,
        off_cpu / (logical_mb / 1e3),
        off.stats.pipeline_bytes as f64 / off.stats.logical_bytes as f64
    );
    println!(
        "\nBenefits: throughput {:.2}×  app-thread CPU savings {:.1}%   (paper: 1.43× and 58.9%)",
        off_thr / base_thr,
        (1.0 - off_cpu / base_cpu.max(1e-9)) * 100.0
    );
    println!(
        "Offload engine: {} checksum batches, mean group fill {:.1} requests/call, compression ratio {:.2}",
        stats.batches,
        stats.mean_group_fill(),
        off.compression_ratio()
    );
}
