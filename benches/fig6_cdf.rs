//! Fig 6 + Table 3 + §5.2 tail latency — SLO guarantee on storage.
//!
//! Two users send 4 KB random reads to the SSD; SLO_user1 = 300 K IOPS,
//! SLO_user2 = 200 K IOPS under 99th% guarantee; throughput sampled every
//! 500 requests. The paper's results to reproduce:
//!   - Fig 6: Arcus's per-window throughput CDF is a step at the SLO;
//!     Host_TS_reflex / Host_TS_firecracker smear (CPU interference makes
//!     software token buckets imprecise).
//!   - Table 3: quantile deviation from the SLO — Arcus within ±1%,
//!     ReFlex −11.7%…+8.7%, Firecracker −6.7%…+24.3%.
//!   - §5.2: Arcus cuts 95/99/99.9th latency by 18.75/31.09/45.82% vs
//!     ReFlex.

#[path = "common.rs"]
mod common;

use arcus::flow::FlowKind;
use arcus::system::{ExperimentSpec, Mode, SystemReport};
use arcus::util::units::MICROS;
use arcus::workload::{fio_read_flow, FioJob};
use arcus::storage::SsdConfig;
use common::*;

fn spec(mode: Mode) -> ExperimentSpec {
    // Open-loop users demanding slightly above their paid rate (Poisson):
    // the shaper is the active bottleneck, so shaping precision — not the
    // SSD — decides each window. A small driver queue (typical NVMe QD)
    // bounds the queueing so latency reflects the shaping path.
    let jobs = [
        FioJob { vm: 0, bs: 4096, offered_iops: 345_000.0, slo_iops: 300_000.0 },
        FioJob { vm: 1, bs: 4096, offered_iops: 230_000.0, slo_iops: 200_000.0 },
    ];
    let flows = vec![fio_read_flow(0, jobs[0]), fio_read_flow(1, jobs[1])];
    debug_assert!(flows.iter().all(|f| f.kind == FlowKind::StorageRead));
    let mut spec = ExperimentSpec::new(mode, vec![], flows)
        .with_duration(2 * bench_duration())
        .with_warmup(warmup())
        // Two enterprise SSDs carry the 500K IOPS aggregate the way the
        // paper's array does.
        .with_raid(2, SsdConfig::samsung_983dct());
    spec.queue_cap = 48;
    spec
}

fn cdf_points(r: &SystemReport, flow: usize) -> Vec<(f64, f64)> {
    let mut v = r.per_flow[flow].sampler.raw.clone();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len().max(1) as f64;
    v.iter().enumerate().map(|(i, &x)| (x, (i + 1) as f64 / n)).collect()
}

fn main() {
    let modes = [Mode::Arcus, Mode::HostTsReflex, Mode::HostTsFirecracker];
    let reports = parallel_sweep(modes.iter().map(|&m| spec(m)).collect());

    banner("Fig 6: per-window throughput CDF (KIOPS at CDF 10/25/50/75/90/99%)");
    for (flow, slo) in [(0usize, 300.0), (1usize, 200.0)] {
        println!("\nuser{} (SLO {slo:.0}K IOPS):", flow + 1);
        println!("{:<22} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}", "system", "10%", "25%", "50%", "75%", "90%", "99%");
        for (m, r) in modes.iter().zip(reports.iter()) {
            let cdf = cdf_points(r, flow);
            let q = |p: f64| -> f64 {
                if cdf.is_empty() {
                    return 0.0;
                }
                let idx = ((p * (cdf.len() - 1) as f64).round() as usize).min(cdf.len() - 1);
                cdf[idx].0 / 1e3
            };
            println!(
                "{:<22} {:>8.1} {:>8.1} {:>8.1} {:>8.1} {:>8.1} {:>8.1}",
                m.name(),
                q(0.10),
                q(0.25),
                q(0.50),
                q(0.75),
                q(0.90),
                q(0.99)
            );
        }
    }

    banner("Table 3: user1 window-throughput deviation from the 300K IOPS target");
    println!("{:<22} {:>8} {:>8} {:>8} {:>8} {:>8}", "system", "25th%", "50th%", "75th%", "99th%", "CV%");
    for (m, r) in modes.iter().zip(reports.iter()) {
        let s = &r.per_flow[0].sampler;
        println!(
            "{:<22} {:>+7.1}% {:>+7.1}% {:>+7.1}% {:>+7.1}% {:>8.2}",
            m.name(),
            pct(s.quantile_deviation(0.25, 300_000.0)),
            pct(s.quantile_deviation(0.50, 300_000.0)),
            pct(s.quantile_deviation(0.75, 300_000.0)),
            pct(s.quantile_deviation(0.99, 300_000.0)),
            pct(s.cv()),
        );
    }

    banner("§5.2 tail latency (user1, µs)");
    println!("{:<22} {:>8} {:>8} {:>8} {:>8}", "system", "mean", "95th%", "99th%", "99.9th%");
    for (m, r) in modes.iter().zip(reports.iter()) {
        let f = &r.per_flow[0];
        println!(
            "{:<22} {:>8.1} {:>8.1} {:>8.1} {:>8.1}",
            m.name(),
            f.lat_mean / MICROS as f64,
            f.lat_p95 as f64 / MICROS as f64,
            f.lat_p99 as f64 / MICROS as f64,
            f.lat_p999 as f64 / MICROS as f64,
        );
    }
    let arcus = &reports[0].per_flow[0];
    let reflex = &reports[1].per_flow[0];
    println!(
        "\nArcus vs ReFlex tail reduction: p95 {:.1}%  p99 {:.1}%  p99.9 {:.1}%   (paper: 18.75 / 31.09 / 45.82%)",
        (1.0 - arcus.lat_p95 as f64 / reflex.lat_p95.max(1) as f64) * 100.0,
        (1.0 - arcus.lat_p99 as f64 / reflex.lat_p99.max(1) as f64) * 100.0,
        (1.0 - arcus.lat_p999 as f64 / reflex.lat_p999.max(1) as f64) * 100.0,
    );
}
