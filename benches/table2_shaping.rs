//! Table 2 — token-bucket parameters for accurate shaping, 1→1000 Gbps.
//!
//! For each SLO row the paper reports the (Refill_Rate, Bkt_Size, Interval)
//! register values that realize the rate. We derive registers with the same
//! recipe (fix one, sweep the other), then *measure* the achieved rate by
//! replaying a saturating mixed-size stream through the cycle-stepped
//! hardware bucket, reporting the deviation.

#[path = "common.rs"]
mod common;

use arcus::shaping::{replay, ShapeMode, Shaper, TokenBucket, TokenBucketParams};
use arcus::util::units::{Rate, SECONDS};
use common::banner;

fn measure(gbps: f64) -> (TokenBucketParams, f64) {
    let target = Rate::gbps(gbps).as_bits_per_sec() / 8.0; // bytes/s
    let mut tb = TokenBucket::for_rate(target, ShapeMode::Gbps);
    let params = tb.params();
    // Saturating arrivals, mixed sizes (bursts + MTU + jumbo).
    let mut arrivals = Vec::new();
    let sizes = [64u64, 256, 1500, 4096, 9216];
    let total_bytes = (target / 50.0) as u64; // ~20 ms of traffic
    let mut sum = 0u64;
    let mut i = 0usize;
    while sum < total_bytes.max(20_000_000) {
        let s = sizes[i % sizes.len()];
        arrivals.push((0u64, s));
        sum += s;
        i += 1;
    }
    let (admitted, last) = replay(&mut tb, &arrivals);
    let rate = admitted as f64 * SECONDS as f64 / last as f64;
    (params, (rate - target) / target)
}

fn main() {
    banner("Table 2: token-bucket registers for accurate shaping (measured on a saturating mixed-size stream)");
    println!(
        "{:>9} {:>12} {:>12} {:>10} {:>12} {:>10}",
        "SLO", "Refill_Rate", "Bkt_Size", "Interval", "nominal", "error"
    );
    for gbps in [1.0, 10.0, 100.0, 1000.0] {
        let (p, err) = measure(gbps);
        println!(
            "{:>7}G {:>12} {:>12} {:>7}cyc {:>10.2}G {:>9.3}%",
            gbps,
            p.refill_rate,
            p.bkt_size,
            p.interval_cycles,
            p.nominal_rate() * 8.0 / 1e9,
            err * 100.0
        );
    }
    println!("\nPaper shape: every row within a fraction of a percent; Interval stays ≥64 cycles even at 1 Tbps.");

    banner("IOPS mode (Fig 6's 300K/200K IOPS rows)");
    println!("{:>10} {:>12} {:>12} {:>10} {:>10}", "SLO", "Refill_Rate", "Bkt_Size", "Interval", "error");
    for iops in [200_000.0, 300_000.0, 1_000_000.0, 2_000_000.0] {
        let mut tb = TokenBucket::for_rate(iops, ShapeMode::Iops);
        let p = tb.params();
        let arrivals: Vec<(u64, u64)> = (0..(iops as u64 / 25).max(50_000)).map(|_| (0, 4096)).collect();
        let n = arrivals.len() as f64;
        let (_admitted, last) = replay(&mut tb, &arrivals);
        let rate = n * SECONDS as f64 / last as f64;
        println!(
            "{:>9.0}K {:>12} {:>12} {:>7}cyc {:>9.3}%",
            iops / 1e3,
            p.refill_rate,
            p.bkt_size,
            p.interval_cycles,
            (rate - iops) / iops * 100.0
        );
    }
}
