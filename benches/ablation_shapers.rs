//! §4.2 ablation — the shaping-mechanism design space.
//!
//! The paper picked the token bucket after rejecting the sliding-window log
//! (accurate but memory-hungry), fixed-window counter and leaky bucket
//! (resource-efficient but burst-hostile). This bench regenerates that
//! comparison: long-run accuracy, burst friendliness (how much of a
//! line-rate burst is admitted without delay), window-level variance, and
//! per-flow state memory — plus the software token bucket the `Host_TS_*`
//! baselines run, whose timer/interference error anchors the hardware rows.
//!
//! Message sizes come from the scenario grid's shared [`SizeMix`]
//! vocabulary, and the per-mechanism measurements fan out over the sweep
//! engine's [`run_parallel`] work queue.

#[path = "common.rs"]
mod common;

use arcus::shaping::{
    replay, FixedWindow, LeakyBucket, ShapeMode, Shaper, SlidingLog, SoftwareShaper,
    SoftwareShaperConfig, TokenBucket, Verdict,
};
use arcus::sweep::{run_parallel, SizeMix};
use arcus::util::units::{Rate, Time, MICROS, SECONDS};
use common::banner;

const N_MECHANISMS: usize = 5;

fn shapers(rate: f64) -> Vec<Box<dyn Shaper>> {
    vec![
        Box::new(TokenBucket::for_rate(rate, ShapeMode::Gbps)),
        Box::new(LeakyBucket::new(rate)),
        Box::new(FixedWindow::new(rate, 10 * MICROS)),
        Box::new(SlidingLog::new(rate, 100 * MICROS)),
        Box::new(SoftwareShaper::new(
            rate,
            ShapeMode::Gbps,
            SoftwareShaperConfig::reflex(),
            7,
        )),
    ]
}

/// Long-run accuracy on a saturating stream drawn from the `Mixed` size
/// vocabulary (64 B / 256 B / MTU / 4 KB).
fn accuracy(s: &mut dyn Shaper, rate: f64) -> f64 {
    let dist = SizeMix::Mixed.dist();
    let mut rng = arcus::util::Rng::new(41);
    let mut arrivals = Vec::new();
    let mut total = 0u64;
    while total < (rate / 50.0) as u64 {
        let sz = dist.sample(&mut rng);
        arrivals.push((0u64, sz));
        total += sz;
    }
    let (admitted, last) = replay(s, &arrivals);
    let got = admitted as f64 * SECONDS as f64 / last as f64;
    (got - rate) / rate
}

/// Bytes of a sudden line-rate burst admitted with zero delay.
fn burst_tolerance(s: &mut dyn Shaper) -> u64 {
    // Idle for 1 ms (tokens accrue where the design allows), then burst.
    let now: Time = 1_000_000_000;
    let mut admitted = 0u64;
    loop {
        match s.try_acquire(now, 1500) {
            Verdict::Admit => admitted += 1500,
            Verdict::RetryAt(_) => break,
        }
        if admitted > 100_000_000 {
            break; // unshaped
        }
    }
    admitted
}

/// Window-level variance on Poisson-ish MTU arrivals at 80% load.
fn window_cv(s: &mut dyn Shaper, rate: f64) -> f64 {
    let size = SizeMix::Mtu.mean_bytes();
    let mut rng = arcus::util::Rng::new(7);
    let mut arrivals = Vec::new();
    let mut t = 0u64;
    for _ in 0..60_000 {
        let gap = rng.exponential(size as f64 / (0.8 * rate) * SECONDS as f64);
        t += gap as u64;
        arrivals.push((t, size));
    }
    let mut admit_times = Vec::new();
    let mut now = 0u64;
    for &(at, cost) in &arrivals {
        now = now.max(at);
        loop {
            match s.try_acquire(now, cost) {
                Verdict::Admit => {
                    admit_times.push(now);
                    break;
                }
                Verdict::RetryAt(r) => now = r,
            }
        }
    }
    let window = 500;
    let rates: Vec<f64> = admit_times
        .chunks(window)
        .filter(|c| c.len() == window)
        .map(|c| {
            (window - 1) as f64 * size as f64 * SECONDS as f64 / (c[window - 1] - c[0]) as f64
        })
        .collect();
    let mean = rates.iter().sum::<f64>() / rates.len().max(1) as f64;
    let var = rates.iter().map(|r| (r - mean) * (r - mean)).sum::<f64>()
        / rates.len().max(1) as f64;
    var.sqrt() / mean.max(1.0)
}

fn main() {
    let rate = Rate::gbps(10.0).as_bits_per_sec() / 8.0;
    banner("§4.2 ablation: shaping mechanisms at a 10 Gbps target");
    println!(
        "{:<22} {:>11} {:>14} {:>12} {:>12}",
        "mechanism", "accuracy", "burst admit", "window CV", "state bytes"
    );
    // One job per mechanism, fanned out on the sweep engine's work queue;
    // results come back in mechanism order.
    let jobs: Vec<_> = (0..N_MECHANISMS)
        .map(|mk| {
            move || {
                let mut s = shapers(rate).remove(mk);
                let acc = accuracy(s.as_mut(), rate);
                let mut s2 = shapers(rate).remove(mk);
                let burst = burst_tolerance(s2.as_mut());
                // Memory measured on the *loaded* shaper — the sliding
                // log's state grows with the events inside its window.
                let mut s3 = shapers(rate).remove(mk);
                let cv = window_cv(s3.as_mut(), rate);
                (s3.name(), acc, burst, cv, s3.state_bytes())
            }
        })
        .collect();
    for (name, acc, burst, cv, state_bytes) in run_parallel(jobs, N_MECHANISMS) {
        println!(
            "{:<22} {:>+10.2}% {:>12}KB {:>11.2}% {:>12}",
            name,
            acc * 100.0,
            burst / 1024,
            cv * 100.0,
            state_bytes
        );
    }
    println!("\nPaper's design rationale to check: the token bucket is accurate AND burst-friendly at");
    println!("O(1) state; the sliding log matches accuracy but needs orders-of-magnitude more memory;");
    println!("fixed window / leaky bucket are tiny but burst-hostile (leaky) or sloppy at edges (fixed);");
    println!("the software bucket matches long-run rate but smears every window (Table 3's deviations).");
}
