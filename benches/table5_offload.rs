//! Table 5 — acceleration opportunities: CPU cost of datacenter-tax tasks
//! vs their offloaded throughput on this testbed.
//!
//! The paper's survey lists the CPU share of (de)compression, hashing,
//! encryption, etc., and the accelerator that absorbs each. Here we measure
//! the actual CPU cost of each task on this machine (single thread) and the
//! throughput the Arcus serving runtime sustains for the same task through
//! PJRT, giving the measured offload opportunity.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use arcus::apps::offload::compress_cpu;
use arcus::runtime::{fletcher_native, pack_bytes};
use arcus::server::{Output, Server, ServerConfig, Work};
use common::banner;

fn cpu_rate<F: FnMut() -> usize>(mut f: F, min_secs: f64) -> f64 {
    let t0 = Instant::now();
    let mut bytes = 0usize;
    while t0.elapsed().as_secs_f64() < min_secs {
        bytes += f();
    }
    bytes as f64 / t0.elapsed().as_secs_f64() / 1e6
}

fn main() {
    let fast = common::fast_mode();
    let secs = if fast { 0.5 } else { 2.0 };
    let block = vec![0x5Au8; 4096];
    let compressible: Vec<u8> = (0..4096u32).map(|i| (i % 13) as u8).collect();

    banner("Table 5 (measured): CPU cost of datacenter-tax tasks on one core");
    println!("{:<26} {:>14}", "task", "MB/s per core");
    let checksum_rate = cpu_rate(
        || {
            let w = pack_bytes(&block);
            std::hint::black_box(fletcher_native(&w));
            block.len()
        },
        secs,
    );
    println!("{:<26} {:>14.0}", "checksum (fletcher)", checksum_rate);
    let crc_rate = cpu_rate(
        || {
            std::hint::black_box(crc32fast::hash(&block));
            block.len()
        },
        secs,
    );
    println!("{:<26} {:>14.0}", "checksum (crc32c/sse)", crc_rate);
    let compress_rate = cpu_rate(
        || {
            std::hint::black_box(compress_cpu(&compressible));
            compressible.len()
        },
        secs,
    );
    println!("{:<26} {:>14.0}", "compression (deflate)", compress_rate);
    let sha_rate = cpu_rate(
        || {
            use sha2::Digest;
            std::hint::black_box(sha2::Sha256::digest(&block));
            block.len()
        },
        secs,
    );
    println!("{:<26} {:>14.0}", "hashing (sha256)", sha_rate);
    let aes_rate = cpu_rate(
        || {
            use aes::cipher::{generic_array::GenericArray, BlockEncrypt, KeyInit};
            let cipher = aes::Aes128::new(GenericArray::from_slice(&[7u8; 16]));
            let mut b = *GenericArray::from_slice(&block[..16]);
            for _ in 0..(block.len() / 16) {
                cipher.encrypt_block(&mut b);
            }
            std::hint::black_box(b);
            block.len()
        },
        secs,
    );
    println!("{:<26} {:>14.0}", "encryption (aes128 sw)", aes_rate);

    banner("Offloaded throughput through the Arcus serving runtime (PJRT engine)");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("(skipping: run `make artifacts` first)");
        return;
    }
    let server = Server::start(ServerConfig::new(dir).tenant("t", None)).expect("server");
    // Warm executable caches.
    let _ = server.submit_blocking(0, Work::Checksum { data: block.clone() });
    let _ = server.submit_blocking(
        0,
        Work::EncryptDigest { data: block.clone(), key: [1; 8], nonce: [2; 3], counter0: 0 },
    );

    for (name, mk) in [
        ("checksum offload", 0usize),
        ("encrypt+MAC offload", 1usize),
    ] {
        let t0 = Instant::now();
        let mut bytes = 0usize;
        let n = if fast { 400 } else { 2000 };
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                bytes += block.len();
                if mk == 0 {
                    server.submit(0, Work::Checksum { data: block.clone() })
                } else {
                    server.submit(
                        0,
                        Work::EncryptDigest {
                            data: block.clone(),
                            key: [1; 8],
                            nonce: [2; 3],
                            counter0: i as u32 * 64,
                        },
                    )
                }
            })
            .collect();
        let mut ok = 0;
        for rx in rxs {
            match rx.recv().unwrap().output {
                Output::Rejected(_) => {}
                _ => ok += 1,
            }
        }
        let rate = bytes as f64 / t0.elapsed().as_secs_f64() / 1e6;
        println!("{:<26} {:>11.0} MB/s  ({ok}/{n} ok)", name, rate);
    }
    println!("\nPaper shape: each task consumes whole cores in software (Table 5's 1–15% fleet");
    println!("shares) while the offload sustains it on the accelerator with ~0 application CPU.");
}
