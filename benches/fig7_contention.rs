//! Fig 7 — contention studies with combined factors.
//!
//! (a) Accelerator heterogeneity: throughput-vs-size curves for the three
//!     representative shapes (logarithmic/saturating, exponential, ad-hoc).
//! (b) Scalability: overall throughput from 1 to 16 flows — near-full with
//!     low per-flow overhead.
//! (c) Combined-factor characterization: VM1 with 16 1 KB flows (NIC RX)
//!     vs VM2 with 4 4 KB flows — the control plane classifies whether the
//!     combination can sustain a 50/50 split (SLO-Friendly) or not.

#[path = "common.rs"]
mod common;

use arcus::accel::AccelModel;
use arcus::coordinator::ProfileTable;
use arcus::flow::pattern::Burstiness;
use arcus::flow::{FlowSpec, Path, Slo, TrafficPattern};
use arcus::pcie::fabric::FabricConfig;
use arcus::sweep::{aggregate, GridBase, SizeMix, SweepGrid, SweepRunner};
use arcus::system::{ExperimentSpec, Mode};
use arcus::util::units::{Rate, KB};
use common::*;

fn main() {
    banner("Fig 7(a): accelerator heterogeneity — effective throughput vs message size (Gbps)");
    let sizes = [64u64, 256, 1024, 4096, 16384, 65536, 262144, 524288];
    println!(
        "{:<14} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "accelerator", "64B", "256B", "1KB", "4KB", "16KB", "64KB", "256KB", "512KB"
    );
    for m in [
        AccelModel::ipsec_32g(),     // saturating (logarithmic-ish)
        AccelModel::sha3_512(),      // exponential
        AccelModel::compress(),      // uniquely ad-hoc (block-boundary dip)
        AccelModel::decompress(),
        AccelModel::checksum(),
    ] {
        print!("{:<14}", m.name);
        for &s in &sizes {
            print!(" {:>8.2}", m.effective_rate(s).as_gbps());
        }
        println!();
    }

    banner("Fig 7(b): scalability — overall throughput, 1 → 16 equal flows (Arcus)");
    // The scenario grid expresses the paper's sweep directly: n equal
    // tenants splitting a 28 Gbps aggregate SLO (tightness = 28 G over the
    // engine's effective 4 KB capacity) at 0.95 × 32 G offered load.
    let counts = [1usize, 2, 4, 8, 16];
    let eff_4k = AccelModel::ipsec_32g().effective_rate(4 * KB).as_gbps();
    let grid = SweepGrid::new(GridBase {
        duration: bench_duration(),
        warmup: warmup(),
        line_rate: Rate::gbps(32.0),
        load: 0.95,
        path: Path::FunctionCall,
        seed: 1,
    })
    .modes(vec![Mode::Arcus])
    .tenants(counts.to_vec())
    .mixes(vec![SizeMix::Bulk])
    .bursts(vec![Burstiness::Paced])
    .tightness(vec![28.0 / eff_4k])
    .accels(vec![AccelModel::ipsec_32g()])
    .seeds(vec![1]);
    let outcomes = SweepRunner::new().run(&grid);
    let reports: Vec<_> = outcomes.iter().map(|o| &o.report).collect();
    header("flows", &counts.iter().map(|c| c.to_string()).collect::<Vec<_>>(), 8);
    row(
        "overall Gbps",
        &reports.iter().map(|r| r.total_goodput().as_gbps()).collect::<Vec<_>>(),
        8,
        2,
    );
    row(
        "vs 1-flow (%)",
        &reports
            .iter()
            .map(|r| pct(r.total_goodput().0 / reports[0].total_goodput().0))
            .collect::<Vec<_>>(),
        8,
        1,
    );
    row(
        "accel util (%)",
        &reports.iter().map(|r| pct(r.accel_util[0])).collect::<Vec<_>>(),
        8,
        1,
    );
    println!("\nper-axis aggregate (worst-flow attainment, tails, variance):");
    print!("{}", aggregate(&outcomes).render());

    banner("Fig 7(c): combined factors — VM1 16×1KB (RX) + VM2 4×4KB (RX) on one 32G engine");
    let line = Rate::gbps(50.0);
    let mut flows = Vec::new();
    for i in 0..16 {
        flows.push(FlowSpec::new(
            i,
            0,
            Path::InlineNicRx,
            TrafficPattern::fixed(KB, 1.0 / 16.0 * 0.40, line),
            Slo::gbps(14.0 / 16.0),
            0,
        ));
    }
    for i in 16..20 {
        flows.push(FlowSpec::new(
            i,
            1,
            Path::InlineNicRx,
            TrafficPattern::fixed(4 * KB, 1.0 / 4.0 * 0.40, line),
            Slo::gbps(14.0 / 4.0),
            0,
        ));
    }
    let spec = ExperimentSpec::new(Mode::Arcus, vec![AccelModel::ipsec_32g()], flows)
        .with_duration(bench_duration())
        .with_warmup(warmup());
    let r = arcus::system::run(&spec);
    let vm1 = r.vm_goodput(0).as_gbps();
    let vm2 = r.vm_goodput(1).as_gbps();
    println!("VM1 (16×1KB): {vm1:.2} Gbps   VM2 (4×4KB): {vm2:.2} Gbps   ratio {:.2}", vm1 / vm2.max(1e-9));
    println!("(paper: the control plane classifies this mixture as able to sustain a 50/50 split — y ≈ 1)");

    banner("Fig 7(c) continued: the profile table's classification for those contexts");
    let profile = ProfileTable::learn(&[AccelModel::ipsec_32g()], &FabricConfig::gen3_x8());
    for (label, size, n) in [("1KB × 16 flows", 1024u64, 16usize), ("4KB × 4 flows", 4096, 4)] {
        let e = profile.capacity("ipsec", Path::InlineNicRx, size, n).unwrap();
        println!(
            "{label:<16}: capacity {:>8.2} Gbps  bound_by {:?}  tag {}",
            e.capacity.as_gbps(),
            e.bound_by,
            if e.slo_friendly { "SLO-Friendly" } else { "SLO-Violating" }
        );
    }
    for (label, size, n) in [("64B × 16 flows", 64u64, 16usize), ("256B × 8 flows", 256, 8)] {
        let e = profile.capacity("ipsec", Path::InlineNicRx, size, n).unwrap();
        println!(
            "{label:<16}: capacity {:>8.2} Gbps  bound_by {:?}  tag {}",
            e.capacity.as_gbps(),
            e.bound_by,
            if e.slo_friendly { "SLO-Friendly" } else { "SLO-Violating" }
        );
    }
}
