//! §5.3.1 micro-benchmarks + §Perf instrumentation.
//!
//! - Shaping decision cost: the paper measures 36 ns in hardware vs >10 µs
//!   for software shaping. Here: wall-clock nanoseconds per
//!   `try_acquire` on the hardware-model token bucket (the L3 serving
//!   path's gate) and per software-shaper decision including its modeled
//!   timing error handling.
//! - Reconfiguration: `set_rate` cost (the paper's 10 µs is PCIe MMIO
//!   round-trips; ours is the register-derivation compute).
//! - DES throughput: events/second on a reference two-flow experiment —
//!   the simulator's §Perf headline.
//! - Serving-path dispatch: end-to-end request latency through the real
//!   server at batch sizes 1 and 32.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use arcus::accel::AccelModel;
use arcus::flow::{FlowSpec, Path, Slo, TrafficPattern};
use arcus::shaping::{ShapeMode, Shaper, SoftwareShaper, SoftwareShaperConfig, TokenBucket};
use arcus::system::{run, ExperimentSpec, Mode};
use arcus::util::units::{Rate, MILLIS};
use common::banner;

fn main() {
    banner("Shaping decision cost (wall-clock per try_acquire)");
    let rate = Rate::gbps(100.0).as_bits_per_sec() / 8.0;
    let mut tb = TokenBucket::for_rate(rate, ShapeMode::Gbps);
    let n = 5_000_000u64;
    let t0 = Instant::now();
    let mut admitted = 0u64;
    for i in 0..n {
        if matches!(tb.try_acquire(i * 200_000, 1500), arcus::shaping::Verdict::Admit) {
            admitted += 1;
        }
    }
    let per = t0.elapsed().as_nanos() as f64 / n as f64;
    println!("hardware token bucket: {per:.1} ns/decision ({admitted} admits)   paper HW: 36 ns");

    let mut sw = SoftwareShaper::new(rate, ShapeMode::Gbps, SoftwareShaperConfig::reflex(), 1);
    let t0 = Instant::now();
    let mut admitted = 0u64;
    for i in 0..n {
        if matches!(sw.try_acquire(i * 200_000, 1500), arcus::shaping::Verdict::Admit) {
            admitted += 1;
        }
    }
    let per_sw = t0.elapsed().as_nanos() as f64 / n as f64;
    println!("software shaper model:  {per_sw:.1} ns/decision ({admitted} admits)   paper SW: >10 µs *modeled in virtual time*");

    banner("Reconfiguration (ReshapeDecision → register write)");
    let t0 = Instant::now();
    let m = 100_000;
    for i in 0..m {
        tb.set_rate(i * 1_000_000, rate * (1.0 + (i % 7) as f64 * 0.01));
    }
    println!(
        "set_rate (derive registers + reprogram): {:.2} µs/call   paper end-to-end reconfig: 10 µs of PCIe MMIO",
        t0.elapsed().as_micros() as f64 / m as f64
    );

    banner("DES throughput (§Perf L3 target)");
    let line = Rate::gbps(32.0);
    let flows = vec![
        FlowSpec::new(0, 0, Path::FunctionCall, TrafficPattern::fixed(1500, 0.6, line), Slo::gbps(10.0), 0),
        FlowSpec::new(1, 1, Path::FunctionCall, TrafficPattern::fixed(1500, 0.6, line), Slo::gbps(12.0), 0),
    ];
    let spec = ExperimentSpec::new(Mode::Arcus, vec![AccelModel::ipsec_32g()], flows)
        .with_duration(20 * MILLIS)
        .with_warmup(2 * MILLIS);
    let r = run(&spec);
    println!(
        "two-flow Arcus reference: {} events in {:.2}s wall = {:.2} M events/s",
        r.events,
        r.wall_secs,
        r.events_per_sec() / 1e6
    );

    banner("Serving path dispatch (real PJRT engine)");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("(skipping: run `make artifacts` first)");
        return;
    }
    use arcus::server::{Server, ServerConfig, Work};
    let server = Server::start(ServerConfig::new(dir).tenant("t", None)).expect("server");
    let _ = server.submit_blocking(0, Work::Checksum { data: vec![0; 1024] });
    // Sequential (batch of 1).
    let n = if common::fast_mode() { 200 } else { 1000 };
    let t0 = Instant::now();
    for _ in 0..n {
        let _ = server.submit_blocking(0, Work::Checksum { data: vec![7; 1024] });
    }
    let seq = t0.elapsed().as_micros() as f64 / n as f64;
    // Pipelined (batcher can group).
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n).map(|_| server.submit(0, Work::Checksum { data: vec![7; 1024] })).collect();
    for rx in rxs {
        let _ = rx.recv().unwrap();
    }
    let piped = t0.elapsed().as_micros() as f64 / n as f64;
    let stats = server.stats();
    println!(
        "sequential: {seq:.0} µs/req   pipelined: {piped:.1} µs/req amortized (mean group fill {:.1})",
        stats.mean_group_fill()
    );
}
