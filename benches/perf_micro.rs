//! §5.3.1 micro-benchmarks + §Perf instrumentation.
//!
//! - Shaping decision cost: the paper measures 36 ns in hardware vs >10 µs
//!   for software shaping. Here: wall-clock nanoseconds per
//!   `try_acquire` on the hardware-model token bucket (the L3 serving
//!   path's gate) and per software-shaper decision including its modeled
//!   timing error handling.
//! - Reconfiguration: `set_rate` cost (the paper's 10 µs is PCIe MMIO
//!   round-trips; ours is the register-derivation compute).
//! - **Event-core micro**: the boxed-closure event loop (the pre-refactor
//!   design, reimplemented here as the measured baseline) vs the typed
//!   zero-allocation core on every queue discipline — the before/after
//!   numbers behind the `arcus bench` trajectory.
//! - **Long-horizon chaos schedule**: fault-window-style events landing
//!   milliseconds out, where the flat calendar's overflow heap churns and
//!   the hierarchical wheel's upper levels engage — the head-to-head
//!   behind adopting `HierWheel`.
//! - DES throughput: events/second on the committed bench presets
//!   (`arcus bench` emits the same numbers as BENCH_<name>.json).
//! - Serving-path dispatch: end-to-end request latency through the real
//!   server at batch sizes 1 and 32.

#[path = "common.rs"]
mod common;

use std::collections::BinaryHeap;
use std::time::Instant;

use arcus::perf::{self, QueueKind};
use arcus::shaping::{ShapeMode, Shaper, SoftwareShaper, SoftwareShaperConfig, TokenBucket};
use arcus::sim::{BinaryHeapQueue, CalendarQueue, EventQueue, Handler, HierWheel, Sim};
use arcus::util::units::{Rate, NANOS};
use common::banner;

// ---------------------------------------------------------------------------
// Boxed-closure baseline: a faithful miniature of the pre-refactor DES core
// (`Box<dyn FnOnce>` actions on one binary heap with (time, seq) ordering).
// Kept here, not in the library, so the baseline stays measurable after the
// production core moved to typed events.
// ---------------------------------------------------------------------------

type BoxedAction = Box<dyn FnOnce(&mut BoxedSim)>;

struct BoxedEntry {
    time: u64,
    seq: u64,
    action: BoxedAction,
}

impl PartialEq for BoxedEntry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for BoxedEntry {}
impl PartialOrd for BoxedEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for BoxedEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

#[derive(Default)]
struct BoxedSim {
    now: u64,
    seq: u64,
    count: u64,
    queue: BinaryHeap<BoxedEntry>,
}

impl BoxedSim {
    fn at(&mut self, time: u64, action: BoxedAction) {
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(BoxedEntry { time, seq, action });
    }

    fn run(&mut self) {
        while let Some(e) = self.queue.pop() {
            self.now = e.time;
            (e.action)(self);
        }
    }
}

/// The self-rescheduling chain each boxed event runs: bump the counter and
/// re-arm until the budget is spent — the minimal shape of the engine's
/// fetch/wake chains (alloc + virtual dispatch + heap op per event).
fn boxed_chain(budget: u64) -> BoxedAction {
    Box::new(move |s: &mut BoxedSim| {
        s.count += 1;
        if budget > 0 {
            // 40-118 ns steps: the engine's event spacing (TLP times,
            // shaper refill edges), so the calendar queue's wheel — not a
            // single bucket — is what gets measured.
            let t = s.now + (40 + (s.count % 7) * 13) * NANOS;
            s.at(t, boxed_chain(budget - 1));
        }
    })
}

/// Typed-event twin of the boxed chain.
#[derive(Clone, Copy)]
enum MicroEv {
    Chain { budget: u64 },
}

#[derive(Default)]
struct MicroWorld {
    count: u64,
}

impl Handler<MicroEv> for MicroWorld {
    fn handle<Q: EventQueue<MicroEv>>(&mut self, sim: &mut Sim<MicroEv, Q>, ev: MicroEv) {
        match ev {
            MicroEv::Chain { budget } => {
                self.count += 1;
                if budget > 0 {
                    let t = sim.now() + (40 + (self.count % 7) * 13) * NANOS;
                    sim.at(t, MicroEv::Chain { budget: budget - 1 });
                }
            }
        }
    }
}

/// Events/sec through the boxed-closure baseline core.
fn run_boxed(chains: u64, budget: u64) -> f64 {
    let mut sim = BoxedSim::default();
    for i in 0..chains {
        sim.at(i, boxed_chain(budget));
    }
    let t0 = Instant::now();
    sim.run();
    sim.count as f64 / t0.elapsed().as_secs_f64()
}

/// Events/sec through the typed core on queue discipline `Q`.
fn run_typed<Q: EventQueue<MicroEv> + Default>(chains: u64, budget: u64) -> f64 {
    let mut sim: Sim<MicroEv, Q> = Sim::new();
    let mut w = MicroWorld::default();
    for i in 0..chains {
        sim.at(i, MicroEv::Chain { budget });
    }
    let t0 = Instant::now();
    sim.run(&mut w, u64::MAX);
    w.count as f64 / t0.elapsed().as_secs_f64()
}

/// Events/sec on a raw queue driven with a chaos-style schedule: dense
/// 40–118 ns chains with a ~3% tail of events 1–50 ms out (the fault
/// window / deep-retry shape). Exercised directly on the `EventQueue`
/// so the measurement isolates queue cost, not handler cost.
fn run_chaos<Q: EventQueue<u32> + Default>(n_events: u64) -> f64 {
    let mut q = Q::default();
    let mut rng = arcus::util::Rng::new(0x1234);
    let mut now = 0u64;
    let mut seq = 0u64;
    let t0 = Instant::now();
    while seq < n_events || !q.is_empty() {
        for _ in 0..3 {
            if seq < n_events {
                let t = if rng.range_u64(0, 99) < 3 {
                    now + rng.range_u64(1, 50) * 1_000_000 * NANOS
                } else {
                    now + rng.range_u64(40, 118) * NANOS
                };
                q.push(t, seq, seq as u32);
                seq += 1;
            }
        }
        for _ in 0..3 {
            if let Some((t, _, _)) = q.pop() {
                now = t;
            } else {
                break;
            }
        }
    }
    // One event = one push + one pop lifecycle.
    n_events as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    banner("Shaping decision cost (wall-clock per try_acquire)");
    let rate = Rate::gbps(100.0).as_bits_per_sec() / 8.0;
    let mut tb = TokenBucket::for_rate(rate, ShapeMode::Gbps);
    let n = if common::fast_mode() { 500_000u64 } else { 5_000_000u64 };
    let t0 = Instant::now();
    let mut admitted = 0u64;
    for i in 0..n {
        if matches!(tb.try_acquire(i * 200_000, 1500), arcus::shaping::Verdict::Admit) {
            admitted += 1;
        }
    }
    let per = t0.elapsed().as_nanos() as f64 / n as f64;
    println!("hardware token bucket: {per:.1} ns/decision ({admitted} admits)   paper HW: 36 ns");

    let mut sw = SoftwareShaper::new(rate, ShapeMode::Gbps, SoftwareShaperConfig::reflex(), 1);
    let t0 = Instant::now();
    let mut admitted = 0u64;
    for i in 0..n {
        if matches!(sw.try_acquire(i * 200_000, 1500), arcus::shaping::Verdict::Admit) {
            admitted += 1;
        }
    }
    let per_sw = t0.elapsed().as_nanos() as f64 / n as f64;
    println!("software shaper model:  {per_sw:.1} ns/decision ({admitted} admits)   paper SW: >10 µs *modeled in virtual time*");

    banner("Reconfiguration (ReshapeDecision → register write)");
    let t0 = Instant::now();
    let m = if common::fast_mode() { 10_000 } else { 100_000 };
    for i in 0..m {
        tb.set_rate(i * 1_000_000, rate * (1.0 + (i % 7) as f64 * 0.01));
    }
    println!(
        "set_rate (derive registers + reprogram): {:.2} µs/call   paper end-to-end reconfig: 10 µs of PCIe MMIO",
        t0.elapsed().as_micros() as f64 / m as f64
    );

    banner("Event-core micro: boxed closures vs typed events");
    let (chains, budget) = if common::fast_mode() { (64, 5_000) } else { (64, 40_000) };
    let total = chains * (budget + 1);
    let boxed = run_boxed(chains, budget);
    let typed_heap = run_typed::<BinaryHeapQueue<MicroEv>>(chains, budget);
    let typed_cal = run_typed::<CalendarQueue<MicroEv>>(chains, budget);
    let typed_wheel = run_typed::<HierWheel<MicroEv>>(chains, budget);
    println!("({total} events, {chains} interleaved self-rescheduling chains)");
    println!("boxed-closure heap (pre-refactor core): {:>8.2} M ev/s", boxed / 1e6);
    println!(
        "typed events + binary heap:             {:>8.2} M ev/s   ({:.2}x boxed)",
        typed_heap / 1e6,
        typed_heap / boxed
    );
    println!(
        "typed events + calendar queue:          {:>8.2} M ev/s   ({:.2}x boxed)",
        typed_cal / 1e6,
        typed_cal / boxed
    );
    println!(
        "typed events + hierarchical wheel:      {:>8.2} M ev/s   ({:.2}x boxed)",
        typed_wheel / 1e6,
        typed_wheel / boxed
    );

    banner("Long-horizon chaos schedule (fault windows ms out)");
    // The shape that degrades the flat calendar: dense near-future chains
    // with a sparse tail of far-future events forcing overflow churn.
    let far_budget = if common::fast_mode() { 50_000u64 } else { 400_000u64 };
    let chaos_heap = run_chaos::<BinaryHeapQueue<u32>>(far_budget);
    let chaos_cal = run_chaos::<CalendarQueue<u32>>(far_budget);
    let chaos_wheel = run_chaos::<HierWheel<u32>>(far_budget);
    println!("reference heap:     {:>8.2} M ev/s", chaos_heap / 1e6);
    println!(
        "calendar queue:     {:>8.2} M ev/s   ({:.2}x heap)",
        chaos_cal / 1e6,
        chaos_cal / chaos_heap
    );
    println!(
        "hierarchical wheel: {:>8.2} M ev/s   ({:.2}x heap, {:.2}x calendar)",
        chaos_wheel / 1e6,
        chaos_wheel / chaos_heap,
        chaos_wheel / chaos_cal
    );

    banner("DES throughput on the committed bench presets (§Perf L3 target)");
    let presets: &[&str] = if common::fast_mode() { &["small"] } else { &["small", "medium", "large"] };
    for name in presets {
        let p = perf::preset_by_name(name).unwrap();
        for q in [QueueKind::Heap, QueueKind::Calendar, QueueKind::Wheel] {
            let r = perf::run_preset(&p, q);
            println!(
                "{:<7} {:<11} {:>9} events  {:>7.2} M ev/s  wall {:>8.1} ms  peakq {}",
                r.scenario,
                r.queue,
                r.events_executed,
                r.events_per_sec / 1e6,
                r.wall_ms,
                r.peak_queue_depth
            );
        }
    }
    println!("(`arcus bench` writes these as BENCH_<preset>.json)");

    banner("Serving path dispatch (real PJRT engine)");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("(skipping: run `make artifacts` first)");
        return;
    }
    use arcus::server::{Server, ServerConfig, Work};
    let server = Server::start(ServerConfig::new(dir).tenant("t", None)).expect("server");
    let _ = server.submit_blocking(0, Work::Checksum { data: vec![0; 1024] });
    // Sequential (batch of 1).
    let n = if common::fast_mode() { 200 } else { 1000 };
    let t0 = Instant::now();
    for _ in 0..n {
        let _ = server.submit_blocking(0, Work::Checksum { data: vec![7; 1024] });
    }
    let seq = t0.elapsed().as_micros() as f64 / n as f64;
    // Pipelined (batcher can group).
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n).map(|_| server.submit(0, Work::Checksum { data: vec![7; 1024] })).collect();
    for rx in rxs {
        let _ = rx.recv().unwrap();
    }
    let piped = t0.elapsed().as_micros() as f64 / n as f64;
    let stats = server.stats();
    println!(
        "sequential: {seq:.0} µs/req   pipelined: {piped:.1} µs/req amortized (mean group fill {:.1})",
        stats.mean_group_fill()
    );
}
