//! Fig 9 — Use case 2: bursty tiny messages vs an MTU stream.
//!
//! VM1: one bursty 64 B flow, latency-critical (99th% ≤ 1 µs). VM2: one
//! 1500 B stream with a 32 Gbps throughput SLO. Both on the NIC RX path of
//! one engine. The paper's claims:
//!   - Arcus holds VM1 at ~0.5 µs average / ≤0.74 µs 99th (up to 1.9×
//!     better than the bypassed baseline) and keeps VM2 pinned at 32 G;
//!   - the baseline lets VM2 overload the system (>32 G spikes) which
//!     inflates VM1's tail.
//! Output: time-series (100 µs windows) of VM2 throughput and VM1 99th%
//! latency, plus the summary statistics.

#[path = "common.rs"]
mod common;

use arcus::accel::AccelModel;
use arcus::flow::pattern::{Burstiness, SizeDist};
use arcus::flow::{FlowSpec, Path, Slo, TrafficPattern};
use arcus::system::{ExperimentSpec, Mode, SystemReport};
use arcus::util::units::{Rate, Time, MICROS, MTU, NANOS};
use common::*;

fn spec(mode: Mode) -> ExperimentSpec {
    let line = Rate::gbps(50.0);
    let flows = vec![
        FlowSpec {
            id: 0,
            vm: 0,
            path: Path::InlineNicRx,
            pattern: TrafficPattern {
                sizes: SizeDist::Fixed(64),
                load: 0.02, // 1 Gbps of tiny RPCs
                line_rate: line,
                burst: Burstiness::OnOff { burst_len: 16 },
            },
            slo: Slo::Latency { max_ps: MICROS, percentile: 99.0 },
            accel: 0,
            kind: arcus::flow::FlowKind::Accel,
            priority: 0,
        },
        FlowSpec {
            id: 1,
            vm: 1,
            path: Path::InlineNicRx,
            pattern: TrafficPattern {
                sizes: SizeDist::Fixed(MTU),
                load: 0.72, // 36 Gbps offered — above the 32 G SLO
                line_rate: line,
                burst: Burstiness::Poisson,
            },
            slo: Slo::gbps(32.0),
            accel: 0,
            kind: arcus::flow::FlowKind::Accel,
            priority: 1,
        },
    ];
    // Engine headroom above the 32G SLO but below VM2's bursts; both flows
    // share the bump-in-the-wire port (the paper's prototype).
    ExperimentSpec::new(mode, vec![AccelModel::synthetic(Rate::gbps(40.0))], flows)
        .with_duration(bench_duration())
        .with_warmup(warmup())
        .with_trace()
        .with_shared_port()
}

/// Windowed series from a trace: (window end µs, VM2 Gbps, VM1 p99 µs).
fn series(r: &SystemReport, window: Time) -> Vec<(f64, f64, f64)> {
    let t0 = r.per_flow[0]
        .trace
        .first()
        .map(|&(t, _, _)| t)
        .unwrap_or(0)
        .min(r.per_flow[1].trace.first().map(|&(t, _, _)| t).unwrap_or(0));
    let t_end = r.per_flow[0]
        .trace
        .last()
        .map(|&(t, _, _)| t)
        .unwrap_or(0)
        .max(r.per_flow[1].trace.last().map(|&(t, _, _)| t).unwrap_or(0));
    let mut out = Vec::new();
    let mut w_start = t0;
    while w_start < t_end {
        let w_end = w_start + window;
        let vm2_bytes: u64 = r.per_flow[1]
            .trace
            .iter()
            .filter(|&&(t, _, _)| t >= w_start && t < w_end)
            .map(|&(_, _, b)| b)
            .sum();
        let mut lats: Vec<u64> = r.per_flow[0]
            .trace
            .iter()
            .filter(|&&(t, _, _)| t >= w_start && t < w_end)
            .map(|&(_, l, _)| l)
            .collect();
        lats.sort_unstable();
        let p99 = if lats.is_empty() {
            0.0
        } else {
            lats[((lats.len() - 1) as f64 * 0.99) as usize] as f64 / MICROS as f64
        };
        out.push((
            (w_end - t0) as f64 / MICROS as f64,
            vm2_bytes as f64 * 8.0 / window as f64 * 1e12 / 1e9,
            p99,
        ));
        w_start = w_end;
    }
    out
}

fn main() {
    let modes = [Mode::Arcus, Mode::BypassedPanic];
    let reports = parallel_sweep(modes.iter().map(|&m| spec(m)).collect());

    banner("Fig 9 summary — VM1 64B latency-critical, VM2 1500B stream (SLO 32G)");
    println!(
        "{:<16} {:>10} {:>10} {:>10} {:>12} {:>12} {:>10}",
        "system", "VM1 avg", "VM1 p99", "VM1 p99.9", "VM2 Gbps", "VM2 cv%", "NIC drops"
    );
    for (m, r) in modes.iter().zip(reports.iter()) {
        let f0 = &r.per_flow[0];
        let f1 = &r.per_flow[1];
        println!(
            "{:<16} {:>8.2}us {:>8.2}us {:>8.2}us {:>12.2} {:>12.2} {:>10}",
            m.name(),
            f0.lat_mean / MICROS as f64,
            f0.lat_p99 as f64 / MICROS as f64,
            f0.lat_p999 as f64 / MICROS as f64,
            f1.goodput.as_gbps(),
            pct(f1.sampler.cv()),
            r.nic_rx_dropped,
        );
    }
    let a = &reports[0].per_flow[0];
    let b = &reports[1].per_flow[0];
    println!(
        "\nArcus p99 improvement over bypassed: {:.2}×   (paper: up to 1.9×; Arcus p99 ≤ 0.74 µs)",
        b.lat_p99 as f64 / a.lat_p99.max(1) as f64
    );

    banner("Fig 9 time series (first 10 windows of 100 µs): VM2 Gbps | VM1 p99 µs");
    print!("{:<10}", "t (µs)");
    let s0 = series(&reports[0], 100 * MICROS);
    let s1 = series(&reports[1], 100 * MICROS);
    for (t, _, _) in s0.iter().take(10) {
        print!(" {t:>9.0}");
    }
    println!();
    for (name, s) in [("arcus", &s0), ("bypassed", &s1)] {
        print!("{:<10}", format!("{name} VM2"));
        for (_, g, _) in s.iter().take(10) {
            print!(" {g:>9.2}");
        }
        println!();
        print!("{:<10}", format!("{name} p99"));
        for (_, _, p) in s.iter().take(10) {
            print!(" {p:>9.2}");
        }
        println!();
    }
    let _ = NANOS;
}
