//! Fig 8 — Use case 1: streaming large messages.
//!
//! VM1 sends 4 KB accelerator I/Os; VM2's message size sweeps 1 KB → 512 KB
//! (both bi-directional function-call flows into one engine). Arcus should
//! hold a precise 50/50 split at every size; the unshaped baseline lets the
//! large-message VM steal throughput by congesting PCIe and device buffers
//! (paper: VM1 loses 36–67% beyond 4 KB; VM1 steals 60% at 1 KB).

#[path = "common.rs"]
mod common;

use arcus::accel::AccelModel;
use arcus::flow::{FlowSpec, Path, Slo, TrafficPattern};
use arcus::system::{ExperimentSpec, Mode};
use arcus::util::units::{Rate, KB};
use common::*;

const VM2_SIZES: [u64; 10] = [
    KB,
    2 * KB,
    4 * KB,
    8 * KB,
    16 * KB,
    32 * KB,
    64 * KB,
    128 * KB,
    256 * KB,
    512 * KB,
];

fn spec(mode: Mode, vm2_size: u64) -> ExperimentSpec {
    // A fast linear engine so the bottleneck is communication + interface,
    // split 50/50 by SLO.
    let accel = AccelModel::synthetic(Rate::gbps(40.0));
    let line = Rate::gbps(50.0);
    let flows = vec![
        FlowSpec::new(
            0,
            0,
            Path::FunctionCall,
            TrafficPattern::fixed(4 * KB, 0.5, line),
            Slo::gbps(14.0),
            0,
        ),
        FlowSpec::new(
            1,
            1,
            Path::FunctionCall,
            TrafficPattern::fixed(vm2_size, 0.5, line),
            Slo::gbps(14.0),
            0,
        ),
    ];
    ExperimentSpec::new(mode, vec![accel], flows)
        .with_duration(bench_duration())
        .with_warmup(warmup())
}

fn main() {
    let labels: Vec<String> = VM2_SIZES.iter().map(|s| format!("{}K", s / KB)).collect();
    for mode in [Mode::Arcus, Mode::HostNoTs] {
        let specs: Vec<_> = VM2_SIZES.iter().map(|&s| spec(mode, s)).collect();
        let reports = parallel_sweep(specs);
        banner(&format!("Fig 8 — {} (VM1 fixed 4KB, VM2 size sweeps; SLO 14G each)", mode.name()));
        header("VM2 size", &labels, 7);
        row(
            "VM1 Gbps",
            &reports.iter().map(|r| r.per_flow[0].goodput.as_gbps()).collect::<Vec<_>>(),
            7,
            2,
        );
        row(
            "VM2 Gbps",
            &reports.iter().map(|r| r.per_flow[1].goodput.as_gbps()).collect::<Vec<_>>(),
            7,
            2,
        );
        row(
            "VM1 share (%)",
            &reports
                .iter()
                .map(|r| {
                    pct(r.per_flow[0].goodput.0
                        / (r.per_flow[0].goodput.0 + r.per_flow[1].goodput.0).max(1.0))
                })
                .collect::<Vec<_>>(),
            7,
            1,
        );
    }
    println!("\nPaper shape: Arcus 50/50 at every size; baseline VM1 loses share as VM2's messages");
    println!("grow past 4KB (36–67% loss) and steals when VM2 sends 1KB.");
}
