//! Fig 11(a) — inline NIC mode: MICA + live migration.
//!
//! Two MICA users (64 B and 256 B values, 50/50 GET/SET) share the
//! SHA1-HMAC and AES-128-CBC engines of a secure-KV deployment; a live
//! migration job (1500 B bulk stream) co-runs on the AES engine as a
//! best-effort background task. The paper reports:
//!   - Arcus hits both users' SLOs accurately;
//!   - the PANIC baseline over-provisions user1 by 48% while user2 loses
//!     61% (pattern mixture in the interface + PCIe), despite MICA being
//!     prioritized over LM;
//!   - under Arcus the LM stream harvests leftover capacity safely.

#[path = "common.rs"]
mod common;

use arcus::accel::AccelModel;
use arcus::system::{ExperimentSpec, Mode, SystemReport};
use arcus::util::units::MICROS;
use arcus::workload::{live_migration_flow, mica_flows, renumber, MicaUser};
use arcus::flow::Slo;
use common::*;

fn spec(mode: Mode) -> ExperimentSpec {
    // Engine indices: 0 = AES-128-CBC, 1 = SHA1-HMAC.
    // Offered rates carry ~10% headroom over the SLOs (users demand at
    // least their paid rate; the SLO is the guaranteed floor).
    let users = [
        MicaUser { vm: 0, value_bytes: 64, mops: 3.0, slo: Slo::gbps(2.2) },
        MicaUser { vm: 1, value_bytes: 256, mops: 2.0, slo: Slo::gbps(4.2) },
    ];
    let mut flows = mica_flows(&users, 0, 1);
    let lm = live_migration_flow(flows.len(), 2, 0, 25.0);
    flows.push(lm);
    let flows = renumber(flows);
    ExperimentSpec::new(
        mode,
        vec![AccelModel::aes_128(), AccelModel::sha1_hmac()],
        flows,
    )
    .with_duration(bench_duration())
    .with_warmup(warmup())
}

fn mops(r: &SystemReport, vm: usize, msg_bytes: f64) -> f64 {
    // Each user has two flows (AES + SHA) carrying the same stream; count
    // the AES flow's completions as the request rate.
    r.per_flow
        .iter()
        .filter(|f| f.vm == vm)
        .map(|f| f.iops)
        .fold(0.0, f64::max)
        / 1e6
        * (msg_bytes / msg_bytes) // keep signature obvious
}

fn main() {
    let modes = [Mode::Arcus, Mode::BypassedPanic];
    let reports = parallel_sweep(modes.iter().map(|&m| spec(m)).collect());

    banner("Fig 11(a): secure MICA ×2 + live migration sharing AES + SHA1-HMAC engines");
    println!(
        "{:<16} {:>11} {:>11} {:>11} {:>11} {:>11} {:>11}",
        "system", "u1 Mops", "u1 att.%", "u2 Mops", "u2 att.%", "LM Gbps", "u1 p99 µs"
    );
    for (m, r) in modes.iter().zip(reports.iter()) {
        let u1 = mops(r, 0, 104.0);
        let u2 = mops(r, 1, 296.0);
        let u1_att = r
            .per_flow
            .iter()
            .filter(|f| f.vm == 0)
            .filter_map(|f| f.slo_attainment())
            .fold(f64::INFINITY, f64::min);
        let u2_att = r
            .per_flow
            .iter()
            .filter(|f| f.vm == 1)
            .filter_map(|f| f.slo_attainment())
            .fold(f64::INFINITY, f64::min);
        let lm = r.vm_goodput(2).as_gbps();
        let p99 = r
            .per_flow
            .iter()
            .filter(|f| f.vm == 0)
            .map(|f| f.lat_p99)
            .max()
            .unwrap_or(0) as f64
            / MICROS as f64;
        println!(
            "{:<16} {:>11.2} {:>10.1}% {:>11.2} {:>10.1}% {:>11.2} {:>11.1}",
            m.name(),
            u1,
            pct(u1_att),
            u2,
            pct(u2_att),
            lm,
            p99
        );
    }
    println!("\nPer-flow detail (goodput Gbps / SLO attainment):");
    for (m, r) in modes.iter().zip(reports.iter()) {
        print!("  {:<14}", m.name());
        for f in &r.per_flow {
            print!(
                " [vm{} acc{}: {:>5.2}G{}]",
                f.vm,
                r.accel_util.len().min(2), // keep line compact
                f.goodput.as_gbps(),
                match f.slo_attainment() {
                    Some(a) => format!(" {:>4.0}%", pct(a)),
                    None => " (BE)".into(),
                }
            );
        }
        println!();
    }
    println!("\nPaper shape: Arcus ≈100% attainment for both users with LM harvesting leftovers;");
    println!("PANIC over-serves user1 (+48%) and starves user2 (−61%), LM interferes despite priority.");
}
