//! Golden determinism across event-queue disciplines.
//!
//! The DES core's contract: the pop order over any pending-event set is the
//! total order `(time, seq)` — independent of queue implementation. These
//! tests pin it at two levels:
//!
//! 1. **Queue level**: property-style random schedules (seeded, via the
//!    in-tree testkit RNG) driven through `BinaryHeapQueue` and
//!    `CalendarQueue` side by side, including schedules engineered to cross
//!    many timing-wheel rollover boundaries, must pop identically.
//! 2. **System level**: a fixed two-tenant scenario (the *golden* scenario,
//!    with mid-run renegotiation so control-plane, reshape, and dataplane
//!    events all interleave) run end-to-end on both queues must produce
//!    byte-identical canonical `SystemReport`s.

use arcus::accel::AccelModel;
use arcus::flow::{FlowSpec, Path, Slo, TrafficPattern};
use arcus::sim::{BinaryHeapQueue, CalendarQueue, EventQueue};
use arcus::system::{run_with, EngineEvent, ExperimentSpec, LifecycleEvent, Mode};
use arcus::util::units::{Rate, Time, MILLIS, NANOS};
use arcus::util::Rng;

// ---------------------------------------------------------------------------
// Queue-level properties
// ---------------------------------------------------------------------------

/// Drive the same randomized push/pop schedule through both queues and
/// assert identical pop sequences. Pushes respect the simulator's clock
/// monotonicity contract (never below the last popped time).
fn drive_schedule(seed: u64, horizon_ns: u64, n_events: usize, pop_burst: usize) {
    let mut heap: BinaryHeapQueue<u32> = BinaryHeapQueue::default();
    let mut cal: CalendarQueue<u32> = CalendarQueue::default();
    let mut rng = Rng::new(seed);
    let mut seq = 0u64;
    let mut now: Time = 0;
    let mut pushed = 0usize;
    let mut heap_out = Vec::new();
    let mut cal_out = Vec::new();
    while pushed < n_events || !heap.is_empty() {
        // Push a burst of events at or after `now`.
        let burst = rng.range_u64(1, 8) as usize;
        for _ in 0..burst.min(n_events - pushed) {
            let t = now + rng.range_u64(0, horizon_ns) * NANOS;
            heap.push(t, seq, seq as u32);
            cal.push(t, seq, seq as u32);
            seq += 1;
            pushed += 1;
        }
        // Pop a burst, tracking the clock.
        for _ in 0..pop_burst {
            let a = heap.pop();
            let b = cal.pop();
            assert_eq!(a, b, "pop divergence at seed {seed}");
            match a {
                Some((t, s, v)) => {
                    assert!(t >= now, "time went backwards");
                    now = t;
                    heap_out.push((t, s));
                    cal_out.push((t, s));
                    let _ = v;
                }
                None => break,
            }
        }
    }
    assert_eq!(heap_out, cal_out);
    // The combined sequence is sorted by (time, seq).
    let mut sorted = heap_out.clone();
    sorted.sort();
    assert_eq!(heap_out, sorted, "pop order is not (time, seq) at seed {seed}");
}

#[test]
fn queues_agree_on_random_schedules() {
    for seed in [1u64, 7, 42, 1337, 0xA5C5] {
        // Horizon well beyond the calendar's 131 µs wheel span: exercises
        // overflow migration alongside dense in-wheel traffic.
        drive_schedule(seed, 500_000, 4_000, 3);
    }
}

#[test]
fn queues_agree_on_dense_near_future_schedules() {
    for seed in [3u64, 99, 2024] {
        // Everything lands inside one wheel rotation: the engine's dense
        // phase (TLP completions + shaper wakeups tens of ns apart).
        drive_schedule(seed, 2, 4_000, 2);
    }
}

#[test]
fn calendar_ordering_survives_wheel_rollover_boundaries() {
    // Events placed symmetrically around multiples of the wheel span, in
    // scrambled order, must come out time-sorted with FIFO tie-breaks.
    // Use an explicitly tiny wheel so dozens of rollovers happen.
    let mut cal: CalendarQueue<u32> = CalendarQueue::with_geometry(100, 8);
    let span = 100 * 8;
    let mut rng = Rng::new(5);
    let mut expect = Vec::new();
    let mut seq = 0u64;
    for rot in 0..64u64 {
        for _ in 0..4 {
            // ±1 tick around the rollover edge, plus a mid-bucket point.
            let offs = [span * rot, span * rot + 1, span * rot + 57];
            let t = offs[rng.range_u64(0, 2) as usize];
            cal.push(t, seq, seq as u32);
            expect.push((t, seq));
            seq += 1;
        }
    }
    // Equal times must pop in seq order: sort expectation by (time, seq).
    expect.sort();
    let mut got = Vec::new();
    while let Some((t, s, _)) = cal.pop() {
        got.push((t, s));
    }
    assert_eq!(got, expect);
}

#[test]
fn ties_at_wheel_edges_keep_fifo_order() {
    let mut cal: CalendarQueue<u32> = CalendarQueue::with_geometry(50, 4);
    let edge = 50 * 4 * 3; // a bucket-0 boundary after three rotations
    for i in 0..32u64 {
        cal.push(edge, i, i as u32);
    }
    let mut seqs = Vec::new();
    while let Some((t, s, _)) = cal.pop() {
        assert_eq!(t, edge);
        seqs.push(s);
    }
    assert_eq!(seqs, (0..32).collect::<Vec<_>>());
}

// ---------------------------------------------------------------------------
// System-level golden scenario
// ---------------------------------------------------------------------------

/// The golden scenario: two Arcus tenants on one IPSec engine, both
/// oversubscribed (shaper wakeups dominate), with a mid-run renegotiation
/// so reconfiguration directives land while the dataplane runs, and traces
/// on so the comparison covers every completion timestamp.
fn golden_spec() -> ExperimentSpec {
    let line = Rate::gbps(32.0);
    let flows = vec![
        FlowSpec::new(
            0,
            0,
            Path::FunctionCall,
            TrafficPattern::fixed(1500, 0.55, line),
            Slo::gbps(10.0),
            0,
        ),
        FlowSpec::new(
            1,
            1,
            Path::FunctionCall,
            TrafficPattern::fixed(1500, 0.45, line),
            Slo::gbps(12.0),
            0,
        ),
    ];
    ExperimentSpec::new(Mode::Arcus, vec![AccelModel::ipsec_32g()], flows)
        .with_duration(5 * MILLIS)
        .with_warmup(MILLIS)
        .with_event(LifecycleEvent::Renegotiate {
            flow: 0,
            at: 3 * MILLIS,
            slo: Slo::gbps(11.0),
        })
        .with_trace()
}

#[test]
fn golden_scenario_reports_byte_identical_across_queues() {
    let spec = golden_spec();
    let heap = run_with::<BinaryHeapQueue<EngineEvent>>(&spec);
    let cal = run_with::<CalendarQueue<EngineEvent>>(&spec);
    assert_eq!(heap.queue, "binary_heap");
    assert_eq!(cal.queue, "calendar");
    assert_eq!(
        heap.canonical(),
        cal.canonical(),
        "SystemReports diverge between queue disciplines"
    );
    // The canonical form covers events + per-flow outcomes; spot-check the
    // perf counters match too (identical event sequences executed).
    assert_eq!(heap.events, cal.events);
    assert_eq!(heap.peak_queue_depth, cal.peak_queue_depth);
    assert!(heap.events > 100_000, "golden run too small: {}", heap.events);
}

#[test]
fn golden_scenario_is_stable_across_repeat_runs() {
    let spec = golden_spec();
    let a = run_with::<CalendarQueue<EngineEvent>>(&spec);
    let b = run_with::<CalendarQueue<EngineEvent>>(&spec);
    assert_eq!(a.canonical(), b.canonical());
}
