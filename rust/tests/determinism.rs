//! Golden determinism across event-queue disciplines.
//!
//! The DES core's contract: the pop order over any pending-event set is the
//! total order `(time, seq)` — independent of queue implementation. These
//! tests pin it at two levels:
//!
//! 1. **Queue level**: property-style random schedules (seeded, via the
//!    in-tree testkit RNG) driven through `BinaryHeapQueue`,
//!    `CalendarQueue`, and `HierWheel` side by side — including schedules
//!    engineered to cross many timing-wheel rollover boundaries and
//!    long-horizon schedules that park events far past the wheels' L0
//!    span (fault windows, deep `RetryAt` wakeups) — must pop identically.
//! 2. **System level**: fixed scenarios (the *golden* renegotiating
//!    scenario, plus a fault-heavy one whose `FaultStart`/`FaultEnd`
//!    events sit milliseconds past the 131 µs L0 horizon) run end-to-end
//!    on all three queues must produce byte-identical canonical
//!    `SystemReport`s.

use arcus::accel::AccelModel;
use arcus::faults::{FaultKind, FaultSpec};
use arcus::flow::{FlowSpec, Path, Slo, TrafficPattern};
use arcus::sim::{BinaryHeapQueue, CalendarQueue, EventQueue, HierWheel};
use arcus::system::{run_with, EngineEvent, ExperimentSpec, LifecycleEvent, Mode};
use arcus::util::units::{Rate, Time, MILLIS, NANOS};
use arcus::util::Rng;

// ---------------------------------------------------------------------------
// Queue-level properties
// ---------------------------------------------------------------------------

/// Drive the same randomized push/pop schedule through all three queues
/// and assert identical pop sequences. Pushes respect the simulator's
/// clock monotonicity contract (never below the last popped time). When
/// `far_events` is set, a few percent of pushes land milliseconds — and a
/// few far beyond the hierarchical wheel's top span, seconds — ahead,
/// exercising overflow migration and multi-level cascades.
fn drive_schedule(seed: u64, horizon_ns: u64, n_events: usize, pop_burst: usize, far_events: bool) {
    let mut heap: BinaryHeapQueue<u32> = BinaryHeapQueue::default();
    let mut cal: CalendarQueue<u32> = CalendarQueue::default();
    let mut wheel: HierWheel<u32> = HierWheel::default();
    let mut rng = Rng::new(seed);
    let mut seq = 0u64;
    let mut now: Time = 0;
    let mut pushed = 0usize;
    let mut out = Vec::new();
    while pushed < n_events || !heap.is_empty() {
        // Push a burst of events at or after `now`.
        let burst = rng.range_u64(1, 8) as usize;
        for _ in 0..burst.min(n_events - pushed) {
            let roll = rng.range_u64(0, 99);
            let t = if far_events && roll < 5 {
                // Fault-window / deep-retry scale: 1–50 ms out.
                now + rng.range_u64(1, 50) * MILLIS
            } else if far_events && roll < 7 {
                // Beyond even the wheel's ~34 s top span: overflow.
                now + rng.range_u64(1, 100) * 1_000 * MILLIS
            } else {
                now + rng.range_u64(0, horizon_ns) * NANOS
            };
            heap.push(t, seq, seq as u32);
            cal.push(t, seq, seq as u32);
            wheel.push(t, seq, seq as u32);
            seq += 1;
            pushed += 1;
        }
        // Pop a burst, tracking the clock.
        for _ in 0..pop_burst {
            let a = heap.pop();
            let b = cal.pop();
            let c = wheel.pop();
            assert_eq!(a, b, "heap/calendar divergence at seed {seed}");
            assert_eq!(a, c, "heap/wheel divergence at seed {seed}");
            match a {
                Some((t, s, _)) => {
                    assert!(t >= now, "time went backwards");
                    now = t;
                    out.push((t, s));
                }
                None => break,
            }
        }
    }
    // The combined sequence is sorted by (time, seq).
    let mut sorted = out.clone();
    sorted.sort();
    assert_eq!(out, sorted, "pop order is not (time, seq) at seed {seed}");
}

#[test]
fn queues_agree_on_random_schedules() {
    for seed in [1u64, 7, 42, 1337, 0xA5C5] {
        // Horizon well beyond the calendar's 131 µs wheel span: exercises
        // overflow migration alongside dense in-wheel traffic.
        drive_schedule(seed, 500_000, 4_000, 3, false);
    }
}

#[test]
fn queues_agree_on_dense_near_future_schedules() {
    for seed in [3u64, 99, 2024] {
        // Everything lands inside one wheel rotation: the engine's dense
        // phase (TLP completions + shaper wakeups tens of ns apart).
        drive_schedule(seed, 2, 4_000, 2, false);
    }
}

#[test]
fn queues_agree_on_long_horizon_chaos_schedules() {
    // The chaos shape: mostly dense near-future traffic with a sparse
    // long-horizon tail (fault windows ms out, extreme retries seconds
    // out). This is exactly where the flat calendar's single overflow
    // heap degrades and the hierarchical wheel's upper levels engage.
    for seed in [11u64, 555, 4096, 0xBEEF] {
        drive_schedule(seed, 200_000, 3_000, 2, true);
    }
}

#[test]
fn calendar_ordering_survives_wheel_rollover_boundaries() {
    // Events placed symmetrically around multiples of the wheel span, in
    // scrambled order, must come out time-sorted with FIFO tie-breaks.
    // Use an explicitly tiny wheel so dozens of rollovers happen.
    let mut cal: CalendarQueue<u32> = CalendarQueue::with_geometry(100, 8);
    let span = 100 * 8;
    let mut rng = Rng::new(5);
    let mut expect = Vec::new();
    let mut seq = 0u64;
    for rot in 0..64u64 {
        for _ in 0..4 {
            // ±1 tick around the rollover edge, plus a mid-bucket point.
            let offs = [span * rot, span * rot + 1, span * rot + 57];
            let t = offs[rng.range_u64(0, 2) as usize];
            cal.push(t, seq, seq as u32);
            expect.push((t, seq));
            seq += 1;
        }
    }
    // Equal times must pop in seq order: sort expectation by (time, seq).
    expect.sort();
    let mut got = Vec::new();
    while let Some((t, s, _)) = cal.pop() {
        got.push((t, s));
    }
    assert_eq!(got, expect);
}

#[test]
fn wheel_ordering_survives_cascade_and_rollover_boundaries() {
    // The hierarchical analogue: events scrambled around multiples of the
    // L0 span of a tiny wheel, so most arrive via upper-level cascades and
    // every L0 slot is reused dozens of times.
    let mut wheel: HierWheel<u32> = HierWheel::with_geometry(100, 3, 2);
    let span = 100 * 8; // L0 span: 8 buckets × 100 ps
    let mut rng = Rng::new(5);
    let mut expect = Vec::new();
    let mut seq = 0u64;
    for rot in 0..64u64 {
        for _ in 0..4 {
            let offs = [span * rot, span * rot + 1, span * rot + 57];
            let t = offs[rng.range_u64(0, 2) as usize];
            wheel.push(t, seq, seq as u32);
            expect.push((t, seq));
            seq += 1;
        }
    }
    expect.sort();
    let mut got = Vec::new();
    while let Some((t, s, _)) = wheel.pop() {
        got.push((t, s));
    }
    assert_eq!(got, expect);
}

#[test]
fn wheel_cascades_preserve_order_under_interleaved_pops() {
    // Push clusters at every level of a tiny hierarchy while draining, so
    // cascades happen with the cursor mid-rotation (the hard case: slot
    // reuse across rotations must not mix windows). Reference: a heap.
    let mut wheel: HierWheel<u32> = HierWheel::with_geometry(10, 2, 2);
    let mut heap: BinaryHeapQueue<u32> = BinaryHeapQueue::default();
    let mut rng = Rng::new(77);
    let mut now: Time = 0;
    let mut seq = 0u64;
    for _ in 0..400 {
        // Geometry spans: L0 ends at 40 ps, L1 160, L2 640, L3 2_560.
        let t = now
            + match rng.range_u64(0, 3) {
                0 => rng.range_u64(0, 39),          // L0
                1 => rng.range_u64(40, 639),        // L1/L2
                2 => rng.range_u64(640, 2_559),     // L3
                _ => rng.range_u64(2_560, 100_000), // overflow
            };
        wheel.push(t, seq, seq as u32);
        heap.push(t, seq, seq as u32);
        seq += 1;
        if rng.range_u64(0, 1) == 0 {
            let a = heap.pop();
            let b = wheel.pop();
            assert_eq!(a, b);
            if let Some((t, _, _)) = a {
                now = t;
            }
        }
    }
    loop {
        let a = heap.pop();
        let b = wheel.pop();
        assert_eq!(a, b);
        if a.is_none() {
            break;
        }
    }
}

#[test]
fn ties_at_wheel_edges_keep_fifo_order() {
    let mut cal: CalendarQueue<u32> = CalendarQueue::with_geometry(50, 4);
    let mut wheel: HierWheel<u32> = HierWheel::with_geometry(50, 2, 2);
    let edge = 50 * 4 * 3; // a bucket-0 boundary after three rotations
    for i in 0..32u64 {
        cal.push(edge, i, i as u32);
        wheel.push(edge, i, i as u32);
    }
    let mut cal_seqs = Vec::new();
    while let Some((t, s, _)) = cal.pop() {
        assert_eq!(t, edge);
        cal_seqs.push(s);
    }
    let mut wheel_seqs = Vec::new();
    while let Some((t, s, _)) = wheel.pop() {
        assert_eq!(t, edge);
        wheel_seqs.push(s);
    }
    assert_eq!(cal_seqs, (0..32).collect::<Vec<_>>());
    assert_eq!(wheel_seqs, (0..32).collect::<Vec<_>>());
}

// ---------------------------------------------------------------------------
// System-level golden scenarios
// ---------------------------------------------------------------------------

/// The golden scenario: two Arcus tenants on one IPSec engine, both
/// oversubscribed (shaper wakeups dominate), with a mid-run renegotiation
/// so reconfiguration directives land while the dataplane runs, and traces
/// on so the comparison covers every completion timestamp.
fn golden_spec() -> ExperimentSpec {
    let line = Rate::gbps(32.0);
    let flows = vec![
        FlowSpec::new(
            0,
            0,
            Path::FunctionCall,
            TrafficPattern::fixed(1500, 0.55, line),
            Slo::gbps(10.0),
            0,
        ),
        FlowSpec::new(
            1,
            1,
            Path::FunctionCall,
            TrafficPattern::fixed(1500, 0.45, line),
            Slo::gbps(12.0),
            0,
        ),
    ];
    ExperimentSpec::new(Mode::Arcus, vec![AccelModel::ipsec_32g()], flows)
        .with_duration(5 * MILLIS)
        .with_warmup(MILLIS)
        .with_event(LifecycleEvent::Renegotiate {
            flow: 0,
            at: 3 * MILLIS,
            slo: Slo::gbps(11.0),
        })
        .with_trace()
}

/// The fault-heavy golden scenario: the fault windows sit milliseconds
/// out, so at the moment each `FaultStart`/`FaultEnd` is scheduled it lies
/// far past the 131 µs L0 horizon of both wheel disciplines — in the
/// calendar's overflow heap and in the hierarchical wheel's upper levels
/// (the slowdown window is ~23 L0 spans deep, the outage ~46).
fn golden_fault_heavy_spec() -> ExperimentSpec {
    let line = Rate::gbps(32.0);
    let flows = vec![
        FlowSpec::new(
            0,
            0,
            Path::FunctionCall,
            TrafficPattern::fixed(1500, 0.5, line),
            Slo::gbps(9.0),
            0,
        ),
        FlowSpec::new(
            1,
            1,
            Path::FunctionCall,
            TrafficPattern::fixed(1500, 0.4, line),
            Slo::gbps(8.0),
            0,
        ),
    ];
    ExperimentSpec::new(Mode::Arcus, vec![AccelModel::ipsec_32g()], flows)
        .with_duration(10 * MILLIS)
        .with_warmup(MILLIS)
        .with_fault(FaultSpec::new(
            FaultKind::AccelSlowdown {
                unit: 0,
                factor: 0.5,
            },
            3 * MILLIS,
            6 * MILLIS,
        ))
        .with_fault(FaultSpec::new(FaultKind::ControlOutage, 6 * MILLIS, 8 * MILLIS))
        .with_trace()
}

fn assert_three_way_identical(spec: &ExperimentSpec, label: &str) {
    let heap = run_with::<BinaryHeapQueue<EngineEvent>>(spec);
    let cal = run_with::<CalendarQueue<EngineEvent>>(spec);
    let wheel = run_with::<HierWheel<EngineEvent>>(spec);
    assert_eq!(heap.queue, "binary_heap");
    assert_eq!(cal.queue, "calendar");
    assert_eq!(wheel.queue, "hier_wheel");
    assert_eq!(
        heap.canonical(),
        cal.canonical(),
        "{label}: SystemReports diverge between heap and calendar"
    );
    assert_eq!(
        heap.canonical(),
        wheel.canonical(),
        "{label}: SystemReports diverge between heap and hierarchical wheel"
    );
    // The canonical form covers events + per-flow outcomes; spot-check the
    // perf counters match too (identical event sequences executed).
    assert_eq!(heap.events, cal.events);
    assert_eq!(heap.events, wheel.events);
    assert_eq!(heap.peak_queue_depth, cal.peak_queue_depth);
    assert_eq!(heap.peak_queue_depth, wheel.peak_queue_depth);
    assert!(heap.events > 100_000, "{label} run too small: {}", heap.events);
}

#[test]
fn golden_scenario_reports_byte_identical_across_queues() {
    assert_three_way_identical(&golden_spec(), "golden");
}

#[test]
fn golden_fault_heavy_scenario_byte_identical_across_queues() {
    assert_three_way_identical(&golden_fault_heavy_spec(), "fault-heavy");
}

#[test]
fn golden_scenario_is_stable_across_repeat_runs() {
    let spec = golden_spec();
    let a = run_with::<CalendarQueue<EngineEvent>>(&spec);
    let b = run_with::<CalendarQueue<EngineEvent>>(&spec);
    assert_eq!(a.canonical(), b.canonical());
    let c = run_with::<HierWheel<EngineEvent>>(&spec);
    let d = run_with::<HierWheel<EngineEvent>>(&spec);
    assert_eq!(c.canonical(), d.canonical());
}
