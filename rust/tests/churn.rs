//! Churn integration: the acceptance contract for the control-plane API.
//!
//! - A flow arriving mid-run is admitted or rejected per Algorithm 1,
//!   reached only through the `ControlPlane` trait (the engine holds no
//!   coordinator tables).
//! - Departures release committed capacity that later arrivals claim.
//! - Static (no-churn) sweep cells are unaffected by the churn axis: same
//!   labels, same seeds, same per-flow results, byte-identical aggregate
//!   tables.

use arcus::accel::AccelModel;
use arcus::api::{ApiError, ArcusControlPlane, ControlPlane, RegisterRequest};
use arcus::coordinator::planner::{PlannerConfig, RejectReason};
use arcus::flow::pattern::Burstiness;
use arcus::flow::{FlowKind, FlowSpec, Path, Slo, TrafficPattern};
use arcus::pcie::fabric::FabricConfig;
use arcus::sweep::{aggregate, Churn, GridBase, SizeMix, SweepGrid, SweepRunner};
use arcus::system::{run, ExperimentSpec, LifecycleEvent, Mode};
use arcus::util::units::{Rate, MILLIS};

fn flow(id: usize, slo_gbps: f64, load: f64) -> FlowSpec {
    FlowSpec::new(
        id,
        id,
        Path::FunctionCall,
        TrafficPattern::fixed(1500, load, Rate::gbps(32.0)),
        Slo::gbps(slo_gbps),
        0,
    )
}

fn base(flows: Vec<FlowSpec>) -> ExperimentSpec {
    ExperimentSpec::new(Mode::Arcus, vec![AccelModel::ipsec_32g()], flows)
        .with_duration(10 * MILLIS)
        .with_warmup(MILLIS)
}

/// Mid-run arrival within leftover capacity: admitted, runs at its SLO,
/// and the incumbents' attainment holds.
#[test]
fn mid_run_arrival_admitted_within_capacity() {
    let spec = base(vec![flow(0, 9.0, 0.4), flow(1, 8.0, 0.4), flow(2, 6.0, 0.4)])
        .with_event(LifecycleEvent::Arrive { flow: 2, at: 4 * MILLIS });
    let r = run(&spec);
    let late = &r.per_flow[2];
    assert!(!late.rejected, "9 + 8 + 6 fits the ~24.6 G budget");
    assert_eq!(late.arrived_at, 4 * MILLIS);
    assert!(late.completed > 1000, "late flow completed {}", late.completed);
    // Goodput is measured first-to-last completion, so the late arrival is
    // judged over its own lifetime.
    let g = late.goodput.as_gbps();
    assert!((g - 6.0).abs() / 6.0 < 0.1, "late flow {g:.2} Gbps");
    for f in &r.per_flow[..2] {
        let att = f.slo_attainment().unwrap();
        assert!(att > 0.93, "incumbent {} attainment {att:.3}", f.flow);
    }
}

/// Mid-run arrival beyond leftover capacity: rejected, zero completions,
/// incumbents untouched.
#[test]
fn mid_run_arrival_rejected_over_capacity() {
    let spec = base(vec![flow(0, 9.0, 0.4), flow(1, 8.0, 0.4), flow(2, 10.0, 0.4)])
        .with_event(LifecycleEvent::Arrive { flow: 2, at: 4 * MILLIS });
    let r = run(&spec);
    assert!(r.per_flow[2].rejected, "9 + 8 + 10 exceeds the budget");
    assert_eq!(r.per_flow[2].completed, 0);
    for f in &r.per_flow[..2] {
        let att = f.slo_attainment().unwrap();
        assert!(att > 0.93, "incumbent {} attainment {att:.3}", f.flow);
    }
}

/// A departure releases committed capacity; the identical later arrival
/// that is inadmissible without the departure is admitted with it.
#[test]
fn departure_releases_capacity_for_later_arrival() {
    let roster = || vec![flow(0, 10.0, 0.4), flow(1, 10.0, 0.4), flow(2, 10.0, 0.4)];
    // Without the departure: 10 + 10 committed, +10 requested → rejected.
    let without = base(roster())
        .with_event(LifecycleEvent::Arrive { flow: 2, at: 6 * MILLIS });
    let r = run(&without);
    assert!(r.per_flow[2].rejected, "control: arrival must fail while flow 0 holds 10 G");
    // With flow 0 departing first, the same arrival is admitted.
    let with = base(roster())
        .with_event(LifecycleEvent::Depart { flow: 0, at: 4 * MILLIS })
        .with_event(LifecycleEvent::Arrive { flow: 2, at: 6 * MILLIS });
    let r = run(&with);
    assert_eq!(r.per_flow[0].departed_at, Some(4 * MILLIS));
    assert!(!r.per_flow[2].rejected, "freed capacity admits the arrival");
    let g = r.per_flow[2].goodput.as_gbps();
    assert!((g - 10.0).abs() / 10.0 < 0.1, "late flow {g:.2} Gbps");
    // The survivor incumbent held its SLO across both transitions.
    assert!(r.per_flow[1].slo_attainment().unwrap() > 0.93);
}

/// The churn axis leaves static cells untouched: per-flow results and the
/// aggregate tables of the static subset match a legacy (churn-free) grid
/// byte for byte.
#[test]
fn static_cells_unchanged_by_churn_axis() {
    let grid = |churn: Vec<Churn>| {
        SweepGrid::new(GridBase {
            duration: 2 * MILLIS,
            warmup: MILLIS / 2,
            line_rate: Rate::gbps(32.0),
            load: 0.9,
            path: Path::FunctionCall,
            seed: 11,
        })
        .modes(vec![Mode::Arcus, Mode::HostNoTs])
        .tenants(vec![1, 2])
        .mixes(vec![SizeMix::Mtu])
        .bursts(vec![Burstiness::Paced, Burstiness::Poisson])
        .tightness(vec![0.7])
        .churn(churn)
        .accels(vec![AccelModel::ipsec_32g()])
        .seeds(vec![1])
    };
    let runner = SweepRunner::with_threads(4);
    let legacy = runner.run(&grid(vec![Churn::Static]));
    let churned = runner.run(&grid(vec![Churn::Static, Churn::Arrivals, Churn::Departures]));
    assert_eq!(churned.len(), 3 * legacy.len());
    // Match static cells by label: identical seeds and per-flow results.
    for l in &legacy {
        let c = churned
            .iter()
            .find(|c| c.key.label() == l.key.label())
            .expect("static cell present in the churned grid");
        assert!(matches!(c.key.churn, Churn::Static));
        assert_eq!(l.report.per_flow.len(), c.report.per_flow.len());
        for (x, y) in l.report.per_flow.iter().zip(c.report.per_flow.iter()) {
            assert_eq!(x.completed, y.completed, "{}", l.key.label());
            assert_eq!(x.bytes, y.bytes, "{}", l.key.label());
            assert_eq!(x.lat_p99, y.lat_p99, "{}", l.key.label());
            assert_eq!(x.dropped, y.dropped, "{}", l.key.label());
        }
    }
    // And the aggregate over the static subset renders byte-identically.
    let static_subset: Vec<_> = churned
        .into_iter()
        .filter(|c| matches!(c.key.churn, Churn::Static))
        .collect();
    assert_eq!(aggregate(&legacy).render(), aggregate(&static_subset).render());
}

/// Churned cells differ from static ones (the axis is live), and every
/// churned scenario still completes with a sane report.
#[test]
fn churn_axis_produces_live_distinct_cells() {
    let grid = SweepGrid::new(GridBase {
        duration: 4 * MILLIS,
        warmup: MILLIS,
        line_rate: Rate::gbps(32.0),
        load: 0.9,
        path: Path::FunctionCall,
        seed: 3,
    })
    .modes(vec![Mode::Arcus])
    .tenants(vec![4])
    .mixes(vec![SizeMix::Mtu])
    .bursts(vec![Burstiness::Paced])
    .tightness(vec![0.6])
    .churn(vec![Churn::Static, Churn::Arrivals, Churn::Departures, Churn::Renegotiation, Churn::Mixed])
    .accels(vec![AccelModel::ipsec_32g()])
    .seeds(vec![1]);
    let outcomes = SweepRunner::with_threads(4).run(&grid);
    assert_eq!(outcomes.len(), 5);
    for o in &outcomes {
        let total: u64 = o.report.per_flow.iter().map(|f| f.completed).sum();
        assert!(total > 1000, "{}: only {total} completions", o.key.label());
    }
    let static_total: u64 = outcomes[0].report.per_flow.iter().map(|f| f.completed).sum();
    let arrivals = &outcomes[1];
    assert!(arrivals.key.label().contains("arrivals"));
    let arrivals_total: u64 =
        arrivals.report.per_flow.iter().map(|f| f.completed).sum();
    // Late arrivals offer less total traffic than the always-on roster.
    assert!(
        arrivals_total < static_total,
        "arrivals {arrivals_total} !< static {static_total}"
    );
    // Departing tenants stop completing.
    let departures = &outcomes[2];
    assert!(departures.report.per_flow[0].departed_at.is_some());
}

/// Admission failures surface as typed [`ApiError::Rejection`] variants:
/// capacity pressure is transient (carries a `retry_after` hint, and the
/// identical request succeeds once a departure frees the capacity), while
/// an unprofiled context is structural (no hint — retrying is pointless).
#[test]
fn rejection_variants_carry_typed_reason_and_retry_hint() {
    let req = |flow: usize, accel_name: &str, slo: Slo| RegisterRequest {
        flow,
        vm: flow,
        path: Path::FunctionCall,
        accel: 0,
        accel_name: accel_name.into(),
        kind: FlowKind::Accel,
        slo,
        size_hint: 1500,
    };
    let mut cp = ArcusControlPlane::from_models(
        &[AccelModel::ipsec_32g()],
        &FabricConfig::gen3_x8(),
        PlannerConfig::default(),
    );
    cp.register_flow(&req(0, "ipsec", Slo::gbps(9.0))).expect("9 G fits");
    cp.register_flow(&req(1, "ipsec", Slo::gbps(8.0))).expect("9 + 8 G fits");

    // Transient: over-capacity carries a machine-consumable retry hint.
    let e = cp.register_flow(&req(2, "ipsec", Slo::gbps(10.0))).unwrap_err();
    match e {
        ApiError::Rejection {
            reason: RejectReason::CapacityExceeded { budget, committed, requested },
            retry_after: Some(hint),
        } => {
            assert!(hint > 0, "retry hint must be a forward delay");
            assert!(
                committed + requested > budget,
                "reason fields explain the refusal: {committed:.3e} + {requested:.3e} \
                 vs {budget:.3e}"
            );
        }
        other => panic!("expected transient capacity rejection, got {other:?}"),
    }

    // Structural: an unprofiled accelerator context has no retry hint.
    let e = cp.register_flow(&req(3, "zstd", Slo::gbps(1.0))).unwrap_err();
    assert!(
        matches!(
            e,
            ApiError::Rejection {
                reason: RejectReason::UnprofiledContext { .. },
                retry_after: None,
            }
        ),
        "expected structural unprofiled rejection, got {e:?}"
    );

    // The transient hint is honest: after a departure frees capacity, the
    // exact request that was refused is admitted.
    cp.deregister_flow(0).expect("flow 0 registered");
    cp.register_flow(&req(2, "ipsec", Slo::gbps(10.0)))
        .expect("freed capacity admits the retried flow");
}
