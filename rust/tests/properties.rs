//! Property-based tests (via the in-tree `testkit`) on the coordinator's
//! invariants: shaping conservation, admission soundness, arbiter work
//! conservation, batcher bounds, and the observability plane's mergeable
//! histograms and tick-indexed series rings.

use arcus::api::{
    AdaptiveConfig, AdaptiveControlPlane, ArcusControlPlane, ControlPlane, DirectiveKind,
    RegisterRequest, TickContext,
};
use arcus::coordinator::planner::{admission_control, tenant_aggregates, Admission, PlannerConfig};
use arcus::coordinator::status::{FlowStatus, MeasuredWindow, PerFlowStatusTable};
use arcus::coordinator::ProfileTable;
use arcus::dma::{Arbiter, Policy};
use arcus::flow::{FlowKind, Path, Slo};
use arcus::metrics::Histogram;
use arcus::obs::{ObsConfig, ObsPlane, SeriesRing};
use arcus::pcie::fabric::FabricConfig;
use arcus::accel::AccelModel;
use arcus::shaping::{ShapeMode, Shaper, TokenBucket, Verdict};
use arcus::testkit::{forall_cfg, Config, OneOf, PairOf, TripleOf, U64Range, VecOf};
use arcus::util::units::{MICROS, MILLIS, SECONDS};

fn cfg(cases: u32) -> Config {
    Config { cases, ..Default::default() }
}

/// Token bucket conservation: on any arrival pattern, admitted bytes never
/// exceed initial burst + rate × elapsed (no free bandwidth, ever).
#[test]
fn prop_token_bucket_never_overspends() {
    let gen = PairOf(
        VecOf { elem: PairOf(U64Range(0, 2_000_000), U64Range(64, 9000)), min_len: 1, max_len: 400 },
        OneOf(vec![1.0f64, 5.0, 25.0]),
    );
    forall_cfg(&cfg(128), &gen, |(arrivals, gbps)| {
        let rate = gbps * 1e9 / 8.0;
        let mut tb = TokenBucket::for_rate(rate, ShapeMode::Gbps);
        let burst = tb.params().bkt_size * tb.params().token_unit;
        let mut arrivals: Vec<(u64, u64)> = arrivals.iter().map(|&(t, s)| (t * 1000, s)).collect();
        arrivals.sort_by_key(|&(t, _)| t);
        let mut admitted = 0u64;
        let mut last_t = 0u64;
        for &(t, size) in &arrivals {
            if let Verdict::Admit = tb.try_acquire(t, size) {
                admitted += size;
                last_t = last_t.max(t);
            }
        }
        let budget = burst as f64 + rate * (last_t as f64 / SECONDS as f64) + 9000.0;
        admitted as f64 <= budget
    });
}

/// Admission soundness: however registrations arrive, the sum of committed
/// SLO rates on an accelerator never exceeds the profiled capacity budget.
#[test]
fn prop_admission_never_overcommits() {
    let profile = ProfileTable::learn(&[AccelModel::ipsec_32g()], &FabricConfig::gen3_x8());
    let pcfg = PlannerConfig::default();
    let gen = VecOf {
        elem: PairOf(U64Range(1, 20), OneOf(vec![256u64, 1024, 1500, 4096])),
        min_len: 1,
        max_len: 24,
    };
    forall_cfg(&cfg(128), &gen, |requests| {
        let mut status = PerFlowStatusTable::default();
        for (i, &(gbps, size)) in requests.iter().enumerate() {
            let slo = Slo::gbps(gbps as f64);
            match admission_control(
                &pcfg,
                &profile,
                &status,
                0,
                "ipsec",
                Path::FunctionCall,
                size,
                &slo,
            ) {
                Admission::Accept { rate, .. } => {
                    let mut row = FlowStatus::new(i, i, Path::FunctionCall, 0, "ipsec", slo, size);
                    row.shaped_rate = Some(rate);
                    status.register(row);
                }
                Admission::Reject { .. } => {}
            }
        }
        // Invariant: the committed byte-rate fits the TIGHTEST context any
        // admitted flow imposes on the engine.
        let committed =
            arcus::coordinator::planner::committed_bytes_per_sec(&status, 0);
        let tightest = status
            .iter()
            .filter_map(|r| {
                profile
                    .capacity("ipsec", Path::FunctionCall, r.size_hint, status.len())
                    .map(|e| e.capacity.as_bits_per_sec() / 8.0)
            })
            .fold(f64::INFINITY, f64::min);
        status.is_empty() || committed <= tightest + 1.0
    });
}

/// Arbiter work conservation: every pushed message is eventually popped,
/// exactly once, regardless of policy.
#[test]
fn prop_arbiters_conserve_messages() {
    let gen = PairOf(
        VecOf { elem: PairOf(U64Range(0, 3), U64Range(1, 9000)), min_len: 0, max_len: 300 },
        OneOf(vec![0usize, 1, 2, 3]),
    );
    forall_cfg(&cfg(128), &gen, |(pushes, policy_idx)| {
        let policy = match policy_idx {
            0 => Policy::RoundRobin,
            1 => Policy::WeightedRoundRobin(vec![1, 2, 3, 4]),
            2 => Policy::Priority(vec![0, 1, 1, 2]),
            _ => Policy::DeficitRoundRobin { weights: vec![1, 1, 2, 2], quantum: 1500 },
        };
        let mut arb: Arbiter<usize> = Arbiter::new(4, policy);
        for (i, &(q, cost)) in pushes.iter().enumerate() {
            arb.push(q as usize, cost, i);
        }
        let mut seen = vec![false; pushes.len()];
        while let Some((_, _, id)) = arb.pop() {
            if seen[id] {
                return false; // double pop
            }
            seen[id] = true;
        }
        arb.is_empty() && seen.iter().all(|&s| s)
    });
}

/// Shaper monotonicity: RetryAt hints strictly advance virtual time, so the
/// engine's fetch loop can never livelock.
#[test]
fn prop_retry_hints_advance_time() {
    let gen = PairOf(
        VecOf { elem: U64Range(64, 65536), min_len: 1, max_len: 200 },
        OneOf(vec![0.5f64, 2.0, 10.0]),
    );
    forall_cfg(&cfg(128), &gen, |(sizes, gbps)| {
        let mut tb = TokenBucket::for_rate(gbps * 1e9 / 8.0, ShapeMode::Gbps);
        let mut now = 0u64;
        for &size in sizes {
            let mut guard = 0;
            loop {
                match tb.try_acquire(now, size) {
                    Verdict::Admit => break,
                    Verdict::RetryAt(t) => {
                        if t <= now {
                            return false;
                        }
                        now = t;
                    }
                }
                guard += 1;
                if guard > 10_000 {
                    return false;
                }
            }
        }
        true
    });
}

/// Build a log-bucketed histogram from a sample slice.
fn hist(xs: &[u64]) -> Histogram {
    let mut h = Histogram::new();
    for &x in xs {
        h.record(x);
    }
    h
}

/// Histogram mergeability: merging two histograms is bucket-for-bucket
/// identical to a histogram of the concatenated samples. This is the law
/// the observability plane's tenant→engine fold and the sweep's
/// cross-thread pooling rely on — a merge never drops, duplicates, or
/// re-buckets a sample. Checked through derived `Eq` (all buckets plus
/// total/sum/min/max) and through the quantile surface.
#[test]
fn prop_histogram_merge_equals_concat() {
    let samples = || VecOf {
        elem: U64Range(0, 10_000_000_000),
        min_len: 0,
        max_len: 200,
    };
    let gen = PairOf(samples(), samples());
    forall_cfg(&cfg(128), &gen, |(a, b)| {
        let mut merged = hist(a);
        merged.merge(&hist(b));
        let concat: Vec<u64> = a.iter().chain(b.iter()).copied().collect();
        let both = hist(&concat);
        merged == both
            && merged.count() == (a.len() + b.len()) as u64
            && merged.percentile(50.0) == both.percentile(50.0)
            && merged.percentile(99.0) == both.percentile(99.0)
    });
}

/// Merge is commutative and associative, so any fold order over per-thread
/// or per-tenant shards produces the same pooled histogram — the reason
/// the sweep aggregate can pool engine histograms in grid-expansion order
/// and still be independent of how the scenario work was scheduled.
#[test]
fn prop_histogram_merge_commutative_associative() {
    let samples = || VecOf {
        elem: U64Range(0, 1_000_000_000),
        min_len: 0,
        max_len: 64,
    };
    let gen = TripleOf(samples(), samples(), samples());
    forall_cfg(&cfg(128), &gen, |(a, b, c)| {
        let (ha, hb, hc) = (hist(a), hist(b), hist(c));
        let mut ab = ha.clone();
        ab.merge(&hb);
        let mut ba = hb.clone();
        ba.merge(&ha);
        if ab != ba {
            return false;
        }
        let mut ab_c = ab.clone();
        ab_c.merge(&hc);
        let mut bc = hb.clone();
        bc.merge(&hc);
        let mut a_bc = ha.clone();
        a_bc.merge(&bc);
        ab_c == a_bc
    });
}

/// SeriesRing wrap-around exactness: for any capacity, start tick, and
/// monotone push pattern with gaps, every retained tick's `get` matches a
/// dense carry-filled reference, evicted/future ticks return `None`, and
/// `first_tick`/`next_tick`/`len`/`latest`/`iter` agree with the trailing
/// window of the reference — including at exact capacity boundaries and
/// across the gap-larger-than-capacity fast-fill path.
#[test]
fn prop_series_ring_wraparound_keeps_tick_indexing_exact() {
    let gen = TripleOf(
        U64Range(1, 12),  // requested capacity (rounds up to 1..=16)
        U64Range(0, 50),  // first tick
        VecOf { elem: PairOf(U64Range(0, 20), U64Range(0, 1_000_000)), min_len: 1, max_len: 64 },
    );
    forall_cfg(&cfg(128), &gen, |(cap, t0, pushes)| {
        let (cap, t0) = (*cap, *t0);
        let mut r = SeriesRing::new(cap as usize);
        // Dense reference: dense[k] is the value at tick t0 + k, with
        // skipped ticks carry-filled from the previous sample.
        let mut dense: Vec<u64> = Vec::new();
        let mut tick = t0;
        for (i, &(gap, v)) in pushes.iter().enumerate() {
            if i > 0 {
                tick += 1 + gap;
                let carry = *dense.last().unwrap();
                for _ in 0..gap {
                    dense.push(carry);
                }
            }
            dense.push(v);
            r.push_at(tick, v);
        }
        let retained = dense.len().min(r.capacity());
        let next = t0 + dense.len() as u64;
        if r.len() != retained
            || r.next_tick() != next
            || r.first_tick() != next - retained as u64
            || r.latest() != dense.last().copied()
        {
            return false;
        }
        for (k, &want) in dense.iter().enumerate() {
            let t = t0 + k as u64;
            let expect = if t >= r.first_tick() { Some(want) } else { None };
            if r.get(t) != expect {
                return false;
            }
        }
        if t0 > 0 && r.get(t0 - 1).is_some() {
            return false;
        }
        if r.get(next).is_some() {
            return false;
        }
        let tail = dense.len() - retained;
        r.iter()
            .eq(dense[tail..]
                .iter()
                .enumerate()
                .map(|(k, &v)| (t0 + (tail + k) as u64, v)))
    });
}

/// Adaptive envelope soundness: whatever the telemetry says — any mix of
/// meeting/violating windows, any queue-depth trajectory, any roster and
/// tenant packing — every per-flow `SetRate` the adaptive plane emits
/// stays inside `[SLO guarantee, min(max_ceiling × SLO, tenant aggregate
/// envelope)]`. The fast tier may never shape a flow below its contract,
/// and may never hand a leaf more than its tenant's committed aggregate.
#[test]
fn prop_adaptive_nudges_stay_within_guarantee_and_tenant_envelope() {
    let gen = TripleOf(
        U64Range(1, 3), // tenants (flows pack round-robin onto them)
        VecOf { elem: U64Range(1, 8), min_len: 2, max_len: 5 }, // per-flow SLO, Gbps
        // Per control tick: (telemetry window kB, queue depth). 0..200 kB
        // spans deep violation to comfortable attainment for every SLO in
        // range; 0..600 spans drained to far-beyond-backlog queues.
        VecOf { elem: PairOf(U64Range(0, 200), U64Range(0, 600)), min_len: 4, max_len: 32 },
    );
    forall_cfg(&cfg(48), &gen, |(tenants, slos, ticks)| {
        let tenants = *tenants as usize;
        let inner = ArcusControlPlane::from_models(
            &[AccelModel::ipsec_32g()],
            &FabricConfig::gen3_x8(),
            PlannerConfig::default(),
        )
        .with_hierarchy(true);
        let mut cp = AdaptiveControlPlane::new(inner, AdaptiveConfig::default());
        let mut admitted: Vec<(usize, f64)> = Vec::new(); // (flow, SLO bytes/s)
        for (f, &gbps) in slos.iter().enumerate() {
            let req = RegisterRequest {
                flow: f,
                vm: f % tenants,
                path: Path::FunctionCall,
                accel: 0,
                accel_name: "ipsec".into(),
                kind: FlowKind::Accel,
                slo: Slo::gbps(gbps as f64),
                size_hint: 1500,
            };
            if cp.register_flow(&req).is_ok() {
                admitted.push((f, gbps as f64 * 1e9 / 8.0));
            }
        }
        if admitted.is_empty() {
            return true;
        }
        // The envelope under test, from the committed roster: guarantee
        // floor per flow, tenant-aggregate (with shaping headroom) and
        // max_ceiling caps above.
        let headroom = cp.inner().planner_cfg().shaping_headroom;
        let max_ceiling = cp.adaptive_cfg().max_ceiling;
        let aggs: std::collections::BTreeMap<(usize, usize), f64> =
            tenant_aggregates(cp.inner().status_table())
                .into_iter()
                .map(|(a, v, s)| ((a, v), s * headroom))
                .collect();
        let bounds: std::collections::BTreeMap<usize, (f64, f64)> = admitted
            .iter()
            .map(|&(f, slo_rate)| {
                let mut cap = slo_rate * max_ceiling;
                if let Some(&agg) = aggs.get(&(0, f % tenants)) {
                    cap = cap.min(agg);
                }
                (f, (slo_rate, cap.max(slo_rate)))
            })
            .collect();
        let homes: Vec<(usize, usize)> = (0..slos.len()).map(|f| (f % tenants, 0)).collect();
        let mut obs = ObsPlane::new(
            ObsConfig {
                control_period: 100 * MICROS,
                duration: 10 * MILLIS,
                retention: 64,
                sample_every: 1,
            },
            &homes,
            tenants,
            1,
            None,
        );
        for &(f, _) in &admitted {
            obs.set_flow_slo(f, Slo::gbps(slos[f] as f64));
        }
        for (t, &(kb, depth)) in ticks.iter().enumerate() {
            let t = t as u64;
            let obs_bytes = kb * 1_000;
            // Hardware windows report comfortably-meeting attainment so the
            // static planner stays quiescent: every SetRate below is the
            // closed loop's own doing, keyed off the obs-series telemetry.
            let mut windows: Vec<(usize, MeasuredWindow)> = Vec::new();
            for &(f, slo_rate) in &admitted {
                obs.on_complete(f, (t + 1) * 100 * MICROS, 1_000, obs_bytes);
                obs.on_control_sample(
                    t,
                    f,
                    100 * MICROS,
                    obs_bytes,
                    1,
                    Some(1_000),
                    depth as usize,
                    0,
                );
                let meet = (slo_rate * 1.2 * (100 * MICROS) as f64 / SECONDS as f64) as u64;
                windows.push((
                    f,
                    MeasuredWindow {
                        span: 100 * MICROS,
                        bytes: meet,
                        ops: meet / 1500 + 1,
                        p99_latency: None,
                    },
                ));
            }
            obs.on_tick_done(t);
            let ds = cp.tick(&TickContext::new(t * 100 * MICROS, &windows).with_obs(&obs));
            for d in &ds {
                if let DirectiveKind::SetRate { flow, rate } = d.kind {
                    let Some(&(floor, cap)) = bounds.get(&flow) else {
                        return false; // directive for a never-admitted flow
                    };
                    if rate < floor * (1.0 - 1e-6) || rate > cap * (1.0 + 1e-6) {
                        eprintln!(
                            "flow {flow}: rate {rate:.4e} outside [{floor:.4e}, {cap:.4e}] \
                             (tick {t}, kb {kb}, depth {depth})"
                        );
                        return false;
                    }
                }
            }
        }
        true
    });
}

/// Batch classes never emit more than `group` tickets and preserve FIFO.
#[test]
fn prop_batcher_bounds_and_fifo() {
    use arcus::server::batcher::{BatchClass, WorkKind};
    use std::time::Instant;
    let gen = PairOf(U64Range(1, 64), U64Range(1, 200));
    forall_cfg(&cfg(128), &gen, |&(group, n)| {
        let mut c: BatchClass<u64> = BatchClass::new(WorkKind::Checksum, group as usize, 16);
        let now = Instant::now();
        for i in 0..n {
            c.stage(i, 8, now);
        }
        let mut expected = 0u64;
        loop {
            let g = c.take_group();
            if g.is_empty() {
                break;
            }
            if g.len() > group as usize {
                return false;
            }
            for s in g {
                if s.ticket != expected {
                    return false;
                }
                expected += 1;
            }
        }
        expected == n
    });
}
