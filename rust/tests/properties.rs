//! Property-based tests (via the in-tree `testkit`) on the coordinator's
//! invariants: shaping conservation, admission soundness, arbiter work
//! conservation, and batcher bounds.

use arcus::coordinator::planner::{admission_control, Admission, PlannerConfig};
use arcus::coordinator::status::{FlowStatus, PerFlowStatusTable};
use arcus::coordinator::ProfileTable;
use arcus::dma::{Arbiter, Policy};
use arcus::flow::{Path, Slo};
use arcus::pcie::fabric::FabricConfig;
use arcus::accel::AccelModel;
use arcus::shaping::{ShapeMode, Shaper, TokenBucket, Verdict};
use arcus::testkit::{forall_cfg, Config, OneOf, PairOf, U64Range, VecOf};
use arcus::util::units::SECONDS;

fn cfg(cases: u32) -> Config {
    Config { cases, ..Default::default() }
}

/// Token bucket conservation: on any arrival pattern, admitted bytes never
/// exceed initial burst + rate × elapsed (no free bandwidth, ever).
#[test]
fn prop_token_bucket_never_overspends() {
    let gen = PairOf(
        VecOf { elem: PairOf(U64Range(0, 2_000_000), U64Range(64, 9000)), min_len: 1, max_len: 400 },
        OneOf(vec![1.0f64, 5.0, 25.0]),
    );
    forall_cfg(&cfg(128), &gen, |(arrivals, gbps)| {
        let rate = gbps * 1e9 / 8.0;
        let mut tb = TokenBucket::for_rate(rate, ShapeMode::Gbps);
        let burst = tb.params().bkt_size * tb.params().token_unit;
        let mut arrivals: Vec<(u64, u64)> = arrivals.iter().map(|&(t, s)| (t * 1000, s)).collect();
        arrivals.sort_by_key(|&(t, _)| t);
        let mut admitted = 0u64;
        let mut last_t = 0u64;
        for &(t, size) in &arrivals {
            if let Verdict::Admit = tb.try_acquire(t, size) {
                admitted += size;
                last_t = last_t.max(t);
            }
        }
        let budget = burst as f64 + rate * (last_t as f64 / SECONDS as f64) + 9000.0;
        admitted as f64 <= budget
    });
}

/// Admission soundness: however registrations arrive, the sum of committed
/// SLO rates on an accelerator never exceeds the profiled capacity budget.
#[test]
fn prop_admission_never_overcommits() {
    let profile = ProfileTable::learn(&[AccelModel::ipsec_32g()], &FabricConfig::gen3_x8());
    let pcfg = PlannerConfig::default();
    let gen = VecOf {
        elem: PairOf(U64Range(1, 20), OneOf(vec![256u64, 1024, 1500, 4096])),
        min_len: 1,
        max_len: 24,
    };
    forall_cfg(&cfg(128), &gen, |requests| {
        let mut status = PerFlowStatusTable::default();
        for (i, &(gbps, size)) in requests.iter().enumerate() {
            let slo = Slo::gbps(gbps as f64);
            match admission_control(
                &pcfg,
                &profile,
                &status,
                0,
                "ipsec",
                Path::FunctionCall,
                size,
                &slo,
            ) {
                Admission::Accept { rate, .. } => {
                    let mut row = FlowStatus::new(i, i, Path::FunctionCall, 0, "ipsec", slo, size);
                    row.shaped_rate = Some(rate);
                    status.register(row);
                }
                Admission::Reject { .. } => {}
            }
        }
        // Invariant: the committed byte-rate fits the TIGHTEST context any
        // admitted flow imposes on the engine.
        let committed =
            arcus::coordinator::planner::committed_bytes_per_sec(&status, 0);
        let tightest = status
            .iter()
            .filter_map(|r| {
                profile
                    .capacity("ipsec", Path::FunctionCall, r.size_hint, status.len())
                    .map(|e| e.capacity.as_bits_per_sec() / 8.0)
            })
            .fold(f64::INFINITY, f64::min);
        status.is_empty() || committed <= tightest + 1.0
    });
}

/// Arbiter work conservation: every pushed message is eventually popped,
/// exactly once, regardless of policy.
#[test]
fn prop_arbiters_conserve_messages() {
    let gen = PairOf(
        VecOf { elem: PairOf(U64Range(0, 3), U64Range(1, 9000)), min_len: 0, max_len: 300 },
        OneOf(vec![0usize, 1, 2, 3]),
    );
    forall_cfg(&cfg(128), &gen, |(pushes, policy_idx)| {
        let policy = match policy_idx {
            0 => Policy::RoundRobin,
            1 => Policy::WeightedRoundRobin(vec![1, 2, 3, 4]),
            2 => Policy::Priority(vec![0, 1, 1, 2]),
            _ => Policy::DeficitRoundRobin { weights: vec![1, 1, 2, 2], quantum: 1500 },
        };
        let mut arb: Arbiter<usize> = Arbiter::new(4, policy);
        for (i, &(q, cost)) in pushes.iter().enumerate() {
            arb.push(q as usize, cost, i);
        }
        let mut seen = vec![false; pushes.len()];
        while let Some((_, _, id)) = arb.pop() {
            if seen[id] {
                return false; // double pop
            }
            seen[id] = true;
        }
        arb.is_empty() && seen.iter().all(|&s| s)
    });
}

/// Shaper monotonicity: RetryAt hints strictly advance virtual time, so the
/// engine's fetch loop can never livelock.
#[test]
fn prop_retry_hints_advance_time() {
    let gen = PairOf(
        VecOf { elem: U64Range(64, 65536), min_len: 1, max_len: 200 },
        OneOf(vec![0.5f64, 2.0, 10.0]),
    );
    forall_cfg(&cfg(128), &gen, |(sizes, gbps)| {
        let mut tb = TokenBucket::for_rate(gbps * 1e9 / 8.0, ShapeMode::Gbps);
        let mut now = 0u64;
        for &size in sizes {
            let mut guard = 0;
            loop {
                match tb.try_acquire(now, size) {
                    Verdict::Admit => break,
                    Verdict::RetryAt(t) => {
                        if t <= now {
                            return false;
                        }
                        now = t;
                    }
                }
                guard += 1;
                if guard > 10_000 {
                    return false;
                }
            }
        }
        true
    });
}

/// Batch classes never emit more than `group` tickets and preserve FIFO.
#[test]
fn prop_batcher_bounds_and_fifo() {
    use arcus::server::batcher::{BatchClass, WorkKind};
    use std::time::Instant;
    let gen = PairOf(U64Range(1, 64), U64Range(1, 200));
    forall_cfg(&cfg(128), &gen, |&(group, n)| {
        let mut c: BatchClass<u64> = BatchClass::new(WorkKind::Checksum, group as usize, 16);
        let now = Instant::now();
        for i in 0..n {
            c.stage(i, 8, now);
        }
        let mut expected = 0u64;
        loop {
            let g = c.take_group();
            if g.is_empty() {
                break;
            }
            if g.len() > group as usize {
                return false;
            }
            for s in g {
                if s.ticket != expected {
                    return false;
                }
                expected += 1;
            }
        }
        expected == n
    });
}
