//! Integration tests for the scenario-sweep engine: the acceptance smoke
//! grid (≥48 scenarios across ≥3 modes and ≥2 tenant counts) and the
//! determinism-under-threading contract — two parallel runs of the same
//! grid must produce byte-identical aggregate tables.

use arcus::accel::AccelModel;
use arcus::flow::pattern::Burstiness;
use arcus::flow::Path;
use arcus::sweep::{aggregate, GridBase, SizeMix, SweepGrid, SweepRunner};
use arcus::system::Mode;
use arcus::testkit::{forall_cfg, Config, OneOf, PairOf};
use arcus::util::units::{Rate, MILLIS};

fn smoke_grid() -> SweepGrid {
    SweepGrid::new(GridBase {
        duration: 2 * MILLIS,
        warmup: MILLIS / 2,
        line_rate: Rate::gbps(32.0),
        load: 0.9,
        path: Path::FunctionCall,
        seed: 11,
    })
    .modes(vec![Mode::Arcus, Mode::HostNoTs, Mode::BypassedPanic])
    .tenants(vec![1, 2])
    .mixes(vec![SizeMix::Mtu, SizeMix::Bulk])
    .bursts(vec![Burstiness::Paced, Burstiness::Poisson])
    .tightness(vec![0.7])
    .accels(vec![AccelModel::ipsec_32g()])
    .seeds(vec![1, 2])
}

#[test]
fn sweep_smoke_expands_48_scenarios_and_threading_is_deterministic() {
    let grid = smoke_grid();
    // Acceptance shape: ≥48 scenarios over ≥3 modes and ≥2 tenant counts.
    assert!(grid.modes.len() >= 3);
    assert!(grid.tenants.len() >= 2);
    assert_eq!(grid.cardinality(), 48);
    let scenarios = grid.expand();
    assert_eq!(scenarios.len(), 48);

    // Two runs with different worker counts: reports must match flow-wise
    // and the aggregate tables must be byte-identical.
    let a = SweepRunner::with_threads(4).run(&grid);
    let b = SweepRunner::with_threads(2).run(&grid);
    assert_eq!(a.len(), 48);
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.index, y.index);
        assert_eq!(x.key.label(), y.key.label());
        assert_eq!(x.report.per_flow.len(), y.report.per_flow.len());
        for (fx, fy) in x.report.per_flow.iter().zip(y.report.per_flow.iter()) {
            assert_eq!(fx.completed, fy.completed, "{}", x.key.label());
            assert_eq!(fx.bytes, fy.bytes);
            assert_eq!(fx.lat_p999, fy.lat_p999);
            assert_eq!(fx.dropped, fy.dropped);
        }
    }
    let ta = aggregate(&a).render();
    let tb = aggregate(&b).render();
    assert_eq!(ta, tb, "aggregate tables diverged across thread counts");

    // The tables actually compare the swept axes...
    assert!(ta.contains("[by mode]"), "{ta}");
    assert!(ta.contains("[by tenants]"));
    assert!(ta.contains("arcus"));
    // ...and every scenario moved real traffic.
    for o in &a {
        let completed: u64 = o.report.per_flow.iter().map(|f| f.completed).sum();
        assert!(completed > 100, "{} completed only {completed}", o.key.label());
    }
}

#[test]
fn arcus_attains_slos_across_the_smoke_grid() {
    // On the Arcus slice of the smoke grid, every committed flow that
    // passed admission lands near its SLO — the paper's core claim, held
    // across mixtures rather than at one hand-picked point.
    let grid = smoke_grid().modes(vec![Mode::Arcus]);
    let outcomes = SweepRunner::new().run(&grid);
    for o in &outcomes {
        for f in o.report.per_flow.iter().filter(|f| !f.rejected) {
            let att = f.slo_attainment().expect("grid flows carry throughput SLOs");
            assert!(
                (0.85..1.25).contains(&att),
                "{} flow {}: attainment {att:.3}",
                o.key.label(),
                f.flow
            );
        }
    }
}

/// Satellite property (b): identical grids yield byte-identical aggregated
/// reports across two parallel runs, over randomized small grids.
#[test]
fn prop_random_grids_aggregate_identically_across_parallel_runs() {
    let gen = PairOf(OneOf(vec![1usize, 2, 3]), OneOf(vec![0usize, 1]));
    forall_cfg(&Config { cases: 4, ..Default::default() }, &gen, |&(tenants, mix_idx)| {
        let mix = [SizeMix::Mtu, SizeMix::Bulk][mix_idx];
        let grid = SweepGrid::new(GridBase {
            duration: MILLIS,
            warmup: MILLIS / 4,
            line_rate: Rate::gbps(32.0),
            load: 0.6,
            path: Path::FunctionCall,
            seed: 5,
        })
        .modes(vec![Mode::Arcus, Mode::HostNoTs])
        .tenants(vec![tenants])
        .mixes(vec![mix])
        .bursts(vec![Burstiness::Paced])
        .tightness(vec![0.6])
        .accels(vec![AccelModel::ipsec_32g()])
        .seeds(vec![1]);
        let a = SweepRunner::with_threads(2).run(&grid);
        let b = SweepRunner::with_threads(3).run(&grid);
        aggregate(&a).render() == aggregate(&b).render()
            && aggregate(&a).render_scenarios() == aggregate(&b).render_scenarios()
    });
}
