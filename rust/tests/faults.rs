//! Golden fault-conformance tests and fault-reaction properties.
//!
//! The fault-injection subsystem's central contract: **determinism
//! survives injection**. Fault start/heal events ride the same
//! `(time, seq)`-ordered queue as the dataplane, so a faulted run must be
//! byte-identical across event-queue disciplines exactly like a healthy
//! one — pinned here on a 3-tenant scenario with an accelerator
//! degradation window. The same scenario demonstrates the per-era
//! metrics: attainment dips during the fault era and recovers after it,
//! with a finite recovery time.
//!
//! The property section covers the two reaction paths the faults stress:
//! token-bucket conservation across reprogramming *and* link-bandwidth
//! cuts, and planner soundness under mis-estimated profiles (AccTable
//! skew): once the table heals and the first renegotiation directives
//! land, the programmed rate sum never exceeds the true capacity budget.

use arcus::accel::AccelModel;
use arcus::api::{ArcusControlPlane, ControlPlane, RegisterRequest, TickContext};
use arcus::config::{spec_from_document, Document};
use arcus::coordinator::planner::PlannerConfig;
use arcus::faults::{FaultKind, FaultSpec};
use arcus::flow::{FlowKind, FlowSpec, Path, Slo, TrafficPattern};
use arcus::pcie::fabric::FabricConfig;
use arcus::shaping::{ShapeMode, Shaper, TokenBucket, Verdict};
use arcus::sim::{BinaryHeapQueue, CalendarQueue, HierWheel};
use arcus::system::{run_with, EngineEvent, ExperimentSpec, Mode};
use arcus::testkit::{forall_cfg, Config, OneOf, TripleOf, U64Range, VecOf};
use arcus::util::units::{Rate, Time, MILLIS, SECONDS};

// ---------------------------------------------------------------------------
// Golden fault scenario
// ---------------------------------------------------------------------------

/// Three Arcus tenants on one IPSec engine; the engine's throughput drops
/// to 40% across [4, 7) ms of a 12 ms run — deep enough that every
/// tenant's equal share sits well under its SLO during the window. Traces
/// are on so the queue-discipline comparison covers every completion
/// timestamp.
fn golden_fault_spec() -> ExperimentSpec {
    let line = Rate::gbps(32.0);
    let flow = |id: usize, slo: f64, load: f64| {
        FlowSpec::new(
            id,
            id,
            Path::FunctionCall,
            TrafficPattern::fixed(1500, load, line),
            Slo::gbps(slo),
            0,
        )
    };
    ExperimentSpec::new(
        Mode::Arcus,
        vec![AccelModel::ipsec_32g()],
        vec![flow(0, 9.0, 0.45), flow(1, 8.0, 0.45), flow(2, 6.0, 0.35)],
    )
    .with_duration(12 * MILLIS)
    .with_warmup(2 * MILLIS)
    .with_fault(FaultSpec::new(
        FaultKind::AccelSlowdown { unit: 0, factor: 0.4 },
        4 * MILLIS,
        7 * MILLIS,
    ))
    .with_trace()
}

#[test]
fn golden_fault_scenario_byte_identical_across_queues() {
    let spec = golden_fault_spec();
    let heap = run_with::<BinaryHeapQueue<EngineEvent>>(&spec);
    let cal = run_with::<CalendarQueue<EngineEvent>>(&spec);
    let wheel = run_with::<HierWheel<EngineEvent>>(&spec);
    assert_eq!(heap.queue, "binary_heap");
    assert_eq!(cal.queue, "calendar");
    assert_eq!(wheel.queue, "hier_wheel");
    assert_eq!(
        heap.canonical(),
        cal.canonical(),
        "faulted SystemReports diverge between queue disciplines"
    );
    assert_eq!(
        heap.canonical(),
        wheel.canonical(),
        "faulted SystemReports diverge on the hierarchical wheel"
    );
    assert_eq!(heap.events, cal.events);
    assert_eq!(heap.events, wheel.events);
    assert_eq!(heap.peak_queue_depth, cal.peak_queue_depth);
    assert_eq!(heap.peak_queue_depth, wheel.peak_queue_depth);
    assert!(heap.events > 100_000, "golden run too small: {}", heap.events);
}

#[test]
fn golden_fault_scenario_dips_and_recovers() {
    let report = run_with::<BinaryHeapQueue<EngineEvent>>(&golden_fault_spec());
    assert_eq!(report.fault_window, Some((4 * MILLIS, 7 * MILLIS)));
    for f in &report.per_flow {
        let fr = f.fault.expect("fault metrics must be present");
        let pre = fr.pre.attainment.expect("pre-era attainment");
        let during = fr.during.attainment.expect("fault-era attainment");
        let post = fr.post.attainment.expect("post-era attainment");
        // 9 + 8 + 6 = 23 Gbps committed on an engine degraded to ~13: the
        // fault era must sit well below both healthy eras.
        assert!(pre > 0.9, "flow {} pre-fault attainment {pre:.3}", f.flow);
        assert!(
            during < pre * 0.85,
            "flow {}: fault-era attainment {during:.3} should dip below pre {pre:.3}",
            f.flow
        );
        assert!(post > 0.9, "flow {} post-fault attainment {post:.3}", f.flow);
        // And every tenant is measurably back on SLO: a finite recovery
        // time, inside the post-fault era.
        let rec = fr.recovery_time.unwrap_or_else(|| {
            panic!("flow {} never recovered after the fault window", f.flow)
        });
        assert!(rec < 5 * MILLIS, "flow {} recovery {rec} ps too slow", f.flow);
        assert!(fr.worst_era_p99() >= fr.during.p99);
    }
}

// ---------------------------------------------------------------------------
// Consistency: obs-plane era accounting vs a trace-derived oracle
// ---------------------------------------------------------------------------

/// `FlowReport.fault` is derived from the obs plane's boundary snapshots
/// and windowed recovery tracker (`rust/src/obs/plane.rs`), not from the
/// completion trace. Rebuild every number independently from
/// `FlowReport.trace` — era assignment by completion time, per-era
/// log-bucketed p99, attainment through `EraReport::new`, and a verbatim
/// replay of the windowed recovery rule — and assert exact equality, so
/// replacing the old bespoke era counters with series-derived accounting
/// is observationally invisible on the golden fault scenario.
#[test]
fn fault_report_matches_trace_derived_oracle() {
    use arcus::metrics::Histogram;
    use arcus::obs::RECOVERY_FRACTION;
    use arcus::system::EraReport;

    let spec = golden_fault_spec();
    let report = run_with::<BinaryHeapQueue<EngineEvent>>(&spec);
    let (fs, fe) = report.fault_window.expect("fault window");
    assert_eq!((fs, fe), (4 * MILLIS, 7 * MILLIS));
    // Every golden flow arrives at 0 and never departs, so the era spans
    // clamp to exactly [warmup, fs), [fs, fe), [fe, duration).
    let spans = [fs - spec.warmup, fe - fs, spec.duration - fe];
    for f in &report.per_flow {
        assert!(!f.trace.is_empty(), "flow {} produced no trace", f.flow);
        // Era counters and per-era latency histograms from the trace
        // alone. The same log-bucketed `Histogram` must be used: the
        // plane's p99 is quantized to its bucket boundaries.
        let mut bytes = [0u64; 3];
        let mut ops = [0u64; 3];
        let mut lat = [Histogram::new(), Histogram::new(), Histogram::new()];
        for &(at, l, b) in &f.trace {
            let era = if at < fs {
                0
            } else if at < fe {
                1
            } else {
                2
            };
            bytes[era] += b;
            ops[era] += 1;
            lat[era].record(l);
        }
        let fr = f.fault.expect("fault metrics present");
        let got = [fr.pre, fr.during, fr.post];
        for k in 0..3 {
            let want =
                EraReport::new(bytes[k], ops[k], spans[k], lat[k].percentile(99.0), &f.slo);
            assert_eq!(got[k].bytes, want.bytes, "flow {} era {k} bytes", f.flow);
            assert_eq!(got[k].ops, want.ops, "flow {} era {k} ops", f.flow);
            assert_eq!(got[k].span, want.span, "flow {} era {k} span", f.flow);
            assert_eq!(got[k].p99, want.p99, "flow {} era {k} p99", f.flow);
            assert_eq!(
                got[k].attainment, want.attainment,
                "flow {} era {k} attainment",
                f.flow
            );
        }
        // Recovery replay: fixed control-period windows starting at the
        // fault end, recovered once a full window achieves
        // RECOVERY_FRACTION of the SLO rate; the compliant window's own
        // closing completion is not accumulated. Statement-for-statement
        // mirror of `ObsPlane::track_recovery`.
        let (rate, mode) = f.slo.required_rate().expect("throughput SLO");
        let period = spec.control_period;
        let mut win_start = fe;
        let (mut wb, mut wo) = (0u64, 0u64);
        let mut recovered_at = None;
        'replay: for &(at, _, b) in f.trace.iter().filter(|&&(at, _, _)| at >= fe) {
            while at >= win_start + period {
                let achieved = match mode {
                    ShapeMode::Gbps => wb as f64 * SECONDS as f64 / period as f64,
                    ShapeMode::Iops => wo as f64 * SECONDS as f64 / period as f64,
                };
                if achieved >= rate * RECOVERY_FRACTION {
                    recovered_at = Some(win_start + period);
                    break 'replay;
                }
                win_start += period;
                wb = 0;
                wo = 0;
            }
            wb += b;
            wo += 1;
        }
        assert!(
            recovered_at.is_some(),
            "flow {}: oracle replay never recovered",
            f.flow
        );
        assert_eq!(
            fr.recovery_time,
            recovered_at.map(|t| t - fe),
            "flow {} recovery time diverges from the trace replay",
            f.flow
        );
    }
}

#[test]
fn degraded_exemplar_config_runs_with_fault_metrics() {
    // The committed exemplar (CI's chaos-smoke input) must parse, run, and
    // produce per-era metrics for all three tenants.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/degraded.toml");
    let doc = Document::from_file(&path).expect("degraded.toml parses");
    let spec = spec_from_document(&doc).expect("degraded.toml builds a spec");
    assert_eq!(spec.faults.len(), 2);
    let report = run_with::<BinaryHeapQueue<EngineEvent>>(&spec);
    assert_eq!(report.per_flow.len(), 3);
    assert!(report.fault_window.is_some());
    assert!(report.per_flow.iter().all(|f| f.fault.is_some()));
    let table = report.render_fault_eras();
    assert!(table.contains("fault window"), "{table}");
    // The rogue tenant was clamped at the interface at least once.
    assert!(report.per_flow[2].reconfigs > 0, "rogue tenant never clamped");
}

// ---------------------------------------------------------------------------
// Sweep integration: the faults axis composes without perturbing history
// ---------------------------------------------------------------------------

#[test]
fn healthy_cells_unchanged_by_faults_axis_end_to_end() {
    use arcus::flow::pattern::Burstiness;
    use arcus::sweep::{aggregate, FaultProfile, GridBase, SizeMix, SweepGrid, SweepRunner};
    let grid = |faults: Vec<FaultProfile>| {
        SweepGrid::new(GridBase {
            duration: 2 * MILLIS,
            warmup: MILLIS / 2,
            line_rate: Rate::gbps(32.0),
            load: 0.9,
            path: Path::FunctionCall,
            seed: 11,
        })
        .modes(vec![Mode::Arcus])
        .tenants(vec![2])
        .mixes(vec![SizeMix::Mtu])
        .bursts(vec![Burstiness::Paced, Burstiness::Poisson])
        .tightness(vec![0.7])
        .faults(faults)
        .accels(vec![AccelModel::ipsec_32g()])
        .seeds(vec![1])
    };
    let runner = SweepRunner::with_threads(4);
    let legacy = runner.run(&grid(vec![FaultProfile::Healthy]));
    let faulted = runner.run(&grid(vec![
        FaultProfile::Healthy,
        FaultProfile::AccelDip,
        FaultProfile::Rogue,
    ]));
    assert_eq!(faulted.len(), 3 * legacy.len());
    for l in &legacy {
        let f = faulted
            .iter()
            .find(|f| f.key.label() == l.key.label())
            .expect("healthy cell present in the faulted grid");
        assert!(matches!(f.key.faults, FaultProfile::Healthy));
        for (x, y) in l.report.per_flow.iter().zip(f.report.per_flow.iter()) {
            assert_eq!(x.completed, y.completed, "{}", l.key.label());
            assert_eq!(x.bytes, y.bytes, "{}", l.key.label());
            assert_eq!(x.lat_p99, y.lat_p99, "{}", l.key.label());
        }
        assert!(f.report.fault_window.is_none());
    }
    // Faulted cells carry metrics and surface in the aggregate's axis
    // table.
    let agg = aggregate(&faulted);
    assert!(agg.render().contains("[by faults]"));
    let dip = faulted
        .iter()
        .find(|f| matches!(f.key.faults, FaultProfile::AccelDip))
        .unwrap();
    assert!(dip.report.fault_window.is_some());
    assert!(dip.report.per_flow.iter().all(|f| f.fault.is_some()));
}

// ---------------------------------------------------------------------------
// Property: token-bucket conservation across set_rate and link cuts
// ---------------------------------------------------------------------------

/// Drive a saturated token bucket era by era. Each era reprograms the rate
/// (`set_rate` mid-flight) and caps the *arrival* feed at a degraded line
/// rate — the fault-era link-bandwidth cut: during a deep cut the bucket
/// idles below its rate and banks at most one bucket of credit. In every
/// era, shaped bytes never exceed committed rate × era length plus one
/// bucket of carried burst.
#[test]
fn prop_token_bucket_conserves_across_rate_changes_and_link_cuts() {
    let era_gen = TripleOf(
        OneOf(vec![1.0f64, 4.0, 10.0, 25.0]), // committed rate, Gbps
        U64Range(1, 4),                       // era length, ms
        OneOf(vec![1.0f64, 0.5, 0.1]),        // link factor (1.0 = healthy)
    );
    let gen = VecOf { elem: era_gen, min_len: 1, max_len: 6 };
    forall_cfg(&Config { cases: 48, ..Default::default() }, &gen, |eras| {
        let first_rate = eras[0].0 * 1e9 / 8.0;
        let mut tb = TokenBucket::for_rate(first_rate, ShapeMode::Gbps);
        let mut now: Time = 0;
        for &(gbps, era_ms, link_factor) in eras {
            let rate = gbps * 1e9 / 8.0; // bytes/sec
            tb.set_rate(now, rate);
            let bucket_bytes = (tb.params().bkt_size * tb.params().token_unit) as f64;
            let era_end = now + era_ms * MILLIS;
            // The degraded link delivers 1500 B frames no faster than
            // `link_factor` × 40 Gbps — the feed the shaper sees.
            let line_bps = 40e9 / 8.0 * link_factor;
            let gap = (1500.0 * SECONDS as f64 / line_bps) as Time;
            let mut admitted = 0u64;
            while now < era_end {
                match tb.try_acquire(now, 1500) {
                    Verdict::Admit => {
                        admitted += 1500;
                        now += gap;
                    }
                    Verdict::RetryAt(t) => {
                        if t >= era_end {
                            break;
                        }
                        now = t;
                    }
                }
            }
            let era_secs = era_ms as f64 * MILLIS as f64 / SECONDS as f64;
            let budget = rate * era_secs + bucket_bytes + 2.0 * 1500.0;
            if admitted as f64 > budget {
                eprintln!(
                    "era ({gbps} Gbps, {era_ms} ms, link {link_factor}): \
                     admitted {admitted} > budget {budget:.0}"
                );
                return false;
            }
            now = now.max(era_end);
        }
        true
    });
}

// ---------------------------------------------------------------------------
// Property: planner soundness under mis-estimated profiles
// ---------------------------------------------------------------------------

/// With AccTable/profile skew injected, admission over-commits; once the
/// table heals, the first renegotiation directives (the over-commit
/// reconciliation reshape) must bring the total programmed rate under the
/// true (unskewed) capacity — for any roster size, skew, and SLO split.
#[test]
fn prop_skewed_profile_never_survives_first_rebalance() {
    let gen = TripleOf(
        U64Range(2, 6),                        // tenants
        OneOf(vec![1.25f64, 1.5, 2.0, 3.0]),   // capacity over-estimate
        U64Range(2, 9),                        // per-tenant SLO, Gbps
    );
    forall_cfg(&Config { cases: 64, ..Default::default() }, &gen, |&(n, skew, gbps)| {
        let mut cp = ArcusControlPlane::from_models(
            &[AccelModel::ipsec_32g()],
            &FabricConfig::gen3_x8(),
            PlannerConfig::default(),
        );
        cp.set_profile_skew("ipsec", skew);
        let mut admitted = Vec::new();
        for f in 0..n as usize {
            let req = RegisterRequest {
                flow: f,
                vm: f,
                path: Path::FunctionCall,
                accel: 0,
                accel_name: "ipsec".into(),
                kind: FlowKind::Accel,
                slo: Slo::gbps(gbps as f64),
                size_hint: 1500,
            };
            if cp.register_flow(&req).is_ok() {
                admitted.push(f);
            }
        }
        if admitted.is_empty() {
            return true; // nothing committed, nothing to reconcile
        }
        // Re-profiling heals the table; the first tick emits the
        // reconciliation directives and applies them to its own registry.
        cp.set_profile_skew("ipsec", 1.0);
        let _ = cp.tick(&TickContext::new(0, &[]));
        let programmed: f64 = admitted
            .iter()
            .filter_map(|&f| cp.query_status(f).and_then(|v| v.shaped_rate))
            .sum();
        let true_capacity = cp
            .profile()
            .capacity("ipsec", Path::FunctionCall, 1500, admitted.len())
            .expect("profiled context")
            .capacity
            .as_bits_per_sec()
            / 8.0;
        if programmed > true_capacity * 1.001 {
            eprintln!(
                "n={n} skew={skew} slo={gbps}G: programmed {programmed:.3e} \
                 > true capacity {true_capacity:.3e}"
            );
            return false;
        }
        true
    });
}
