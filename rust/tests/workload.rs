//! Conformance suite for the population workload layer.
//!
//! Three layers of pinning, matching the generator's contract:
//!
//! 1. **Statistical conformance** — the generated streams must actually
//!    follow the configured laws, checked against closed forms over large
//!    samples (not just "the code ran"): Zipf rank-frequency slope,
//!    Pareto tail index (Hill estimator over 100k draws), diurnal
//!    envelope mean tracking, and flash-crowd rate multiplication for
//!    the burst tenant only. Tolerances are documented at each assertion
//!    and sit many standard deviations out, so the fixed-seed draws pass
//!    deterministically while a wrong exponent, a mis-scaled envelope,
//!    or a tenant-leaked burst still fails loudly.
//! 2. **Determinism goldens** — a population-driven run must produce a
//!    byte-identical canonical `SystemReport` across all three event
//!    queue disciplines and across sweep thread counts, and a recorded
//!    trace must replay to the byte-identical report (`arcus trace
//!    record` → `replay --verify`'s contract).
//! 3. **Codec properties** — the ARCT trace format round-trips
//!    randomized traces exactly, every truncated prefix fails loudly
//!    (never panics, never silently decodes short), and varint
//!    encodings that would overflow a u64 are rejected.

use std::f64::consts::PI;

use arcus::accel::AccelModel;
use arcus::flow::pattern::Burstiness;
use arcus::flow::{FlowSpec, Path, Slo, TrafficPattern};
use arcus::sim::{BinaryHeapQueue, CalendarQueue, HierWheel};
use arcus::sweep::{aggregate, GridBase, SizeMix, SweepGrid, SweepRunner};
use arcus::system::{
    record_population_trace, run, run_replay, run_with, EngineEvent, ExperimentSpec, Mode,
};
use arcus::util::units::{Rate, MICROS, MILLIS, NANOS};
use arcus::util::Rng;
use arcus::workload::trace::{read, write, OP_INJECT};
use arcus::workload::{
    build_population, user_block, PopTables, PopulationConfig, TraceData, TraceRecord,
};

// ---------------------------------------------------------------------------
// Statistical conformance
// ---------------------------------------------------------------------------

/// Ordinary least-squares slope of `y` over `x`.
fn least_squares_slope(points: &[(f64, f64)]) -> f64 {
    let n = points.len() as f64;
    let mx = points.iter().map(|p| p.0).sum::<f64>() / n;
    let my = points.iter().map(|p| p.1).sum::<f64>() / n;
    let num: f64 = points.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
    let den: f64 = points.iter().map(|p| (p.0 - mx) * (p.0 - mx)).sum();
    num / den
}

/// Rank-frequency over 100k draws must follow the configured Zipf law:
/// log(count) regressed on log(rank) over the well-populated head
/// (ranks 1–30, every count in the hundreds) has slope ≈ −s.
///
/// Tolerance: with ~400+ draws at rank 30 the per-point log-count noise
/// is under 5% and the fitted slope's standard error is ~0.01, so ±0.12
/// is >10σ of sampling slack yet far tighter than any off-by-one in the
/// exponent (s = 1.1 vs 1.0 shifts the slope by 0.1).
#[test]
fn zipf_rank_frequency_slope_matches_configured_exponent() {
    let cfg = PopulationConfig { users: 1000, zipf_s: 1.1, ..Default::default() };
    cfg.validate(1).unwrap();
    let mut gens = build_population(&cfg, 42, 100 * MILLIS, &[(0, Rate::gbps(5.0))]);
    let mut counts = vec![0u64; cfg.users];
    for _ in 0..100_000 {
        // Single flow: user id == popularity rank (base 0).
        counts[gens[0].next().user as usize] += 1;
    }
    let head = 30;
    for (r, &c) in counts.iter().take(head).enumerate() {
        assert!(c > 100, "rank {} drew only {c} of 100k — not Zipf(1.1)", r + 1);
    }
    let points: Vec<(f64, f64)> = (0..head)
        .map(|r| (((r + 1) as f64).ln(), (counts[r] as f64).ln()))
        .collect();
    let slope = least_squares_slope(&points);
    assert!(
        (slope + cfg.zipf_s).abs() < 0.12,
        "rank-frequency slope {slope:.3} should be ≈ -{} (±0.12)",
        cfg.zipf_s
    );
}

/// The Hill estimator over the top 500 of 100k size draws must recover
/// the configured Pareto tail index. The clamp is pushed to the 16 MiB
/// cap so it bites with probability ~1e-7 per draw (clamped draws are
/// excluded anyway); integer flooring at the top-500 threshold (~3.8 KiB)
/// is sub-0.1%.
///
/// Tolerance: Hill's standard error is α/√k ≈ 0.06 at k = 500, so ±0.25
/// is >4σ of sampling slack while α = 1.3 vs the adjacent presets
/// (1.2 / 1.5) differs by at least 0.1 in truth — a swapped or inverted
/// shape parameter (1/α bugs produce ≈ 0.77) fails by a wide margin.
#[test]
fn pareto_tail_index_matches_alpha_via_hill_estimator() {
    let cfg = PopulationConfig {
        users: 1000,
        pareto_alpha: 1.3,
        pareto_xm: 64,
        max_bytes: 16 * 1024 * 1024,
        ..Default::default()
    };
    cfg.validate(1).unwrap();
    let mut gens = build_population(&cfg, 7, 100 * MILLIS, &[(0, Rate::gbps(5.0))]);
    let mut draws: Vec<f64> = (0..100_000)
        .map(|_| gens[0].next().bytes as f64)
        .filter(|&b| b < cfg.max_bytes as f64)
        .collect();
    assert!(draws.len() > 99_000, "clamp should be negligible at a 16 MiB cap");
    draws.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let k = 500;
    let threshold = draws[k];
    let hill = draws[..k].iter().map(|x| (x / threshold).ln()).sum::<f64>() / k as f64;
    let alpha_hat = 1.0 / hill;
    assert!(
        (alpha_hat - cfg.pareto_alpha).abs() < 0.25,
        "Hill tail index {alpha_hat:.3} should be ≈ {} (±0.25)",
        cfg.pareto_alpha
    );
}

/// Arrival counts must track the diurnal envelope's closed form: with
/// envelope 1 + d·sin(2πt/P), the mean rate over the first half-period
/// is 1 + 2d/π and over the second 1 − 2d/π, so the count ratio between
/// phase halves is (π + 2d)/(π − 2d) ≈ 1.93 at d = 0.5.
///
/// Tolerance: ~18k arrivals split ~2:1 gives ~1.5% count noise, and the
/// piecewise rate approximation (gap ~0.44 µs against a 2 ms period)
/// biases the ratio by well under 0.1%, so ±17% of the closed form is
/// both deterministic-safe and tight enough that a depth of 0.25 instead
/// of 0.5 (ratio 1.38) or an unapplied envelope (ratio 1.0) fails.
#[test]
fn diurnal_envelope_modulates_arrival_rate_by_the_closed_form() {
    let period = 2 * MILLIS;
    let depth = 0.5;
    let cfg = PopulationConfig {
        users: 1000,
        diurnal_period: period,
        diurnal_depth: depth,
        ..Default::default()
    };
    cfg.validate(1).unwrap();
    let duration = 8 * MILLIS;
    let mut gens = build_population(&cfg, 3, duration, &[(0, Rate::gbps(5.0))]);
    let arrivals = gens[0].take_until(duration);
    assert!(arrivals.len() > 10_000, "need a dense sample, got {}", arrivals.len());
    let (mut rising, mut falling) = (0u64, 0u64);
    for a in &arrivals {
        if a.at % period < period / 2 {
            rising += 1;
        } else {
            falling += 1;
        }
    }
    let expect = (PI + 2.0 * depth) / (PI - 2.0 * depth);
    let ratio = rising as f64 / falling as f64;
    assert!(
        (ratio / expect - 1.0).abs() < 0.17,
        "half-period count ratio {ratio:.3} should be ≈ {expect:.3} (±17%)"
    );
}

/// Flash-crowd epochs must multiply the burst tenant's arrival rate by
/// the configured factor inside their windows — and leave the other
/// tenant's rate flat, since epochs are tenant-scoped (round-robin).
///
/// The epoch schedule is rebuilt via the same `PopTables::build`
/// parameters `build_population` uses (same seed ⇒ same stream ⇒ same
/// windows), and window measures are taken by 100 ns sampling (boundary
/// error ≤ 0.8 µs against ≥500 µs windows).
///
/// Tolerances: in-window counts are in the thousands, so the 8x ratio is
/// measured to a few percent — (6, 10.5) catches a factor applied as
/// 2x/16x or to the wrong envelope term; the cross-tenant ratio bound
/// (0.7, 1.4) catches any tenant leak (a leak would read ≈ 8).
#[test]
fn burst_epochs_multiply_their_tenants_rate_and_leave_others_flat() {
    let cfg = PopulationConfig {
        users: 2000,
        burst_epochs: 4,
        burst_factor: 8.0,
        burst_span: 500 * MICROS,
        ..Default::default()
    };
    let duration = 10 * MILLIS;
    let seed = 9;
    let homes = [(0u32, Rate::gbps(5.0)), (1u32, Rate::gbps(5.0))];
    cfg.validate(homes.len()).unwrap();
    let mut gens = build_population(&cfg, seed, duration, &homes);
    let max_block = user_block(cfg.users, homes.len(), 0).1;
    let tables = PopTables::build(&cfg, seed, 2, duration, max_block);
    assert_eq!(tables.epochs().len(), 4);
    for (e, ep) in tables.epochs().iter().enumerate() {
        assert_eq!(ep.tenant, (e % 2) as u32, "epochs round-robin tenants");
        assert!(ep.end <= duration && ep.end - ep.start == cfg.burst_span);
    }

    // Window measures by sampling (counts of 100 ns steps).
    let step = 100 * NANOS;
    let (mut m_in0, mut m_out0, mut m_only0, mut m_neither) = (0u64, 0u64, 0u64, 0u64);
    let mut t = 0;
    while t < duration {
        let b0 = tables.in_burst(t, 0);
        let b1 = tables.in_burst(t, 1);
        if b0 {
            m_in0 += 1;
        } else {
            m_out0 += 1;
        }
        if b0 && !b1 {
            m_only0 += 1;
        }
        if !b0 && !b1 {
            m_neither += 1;
        }
        t += step;
    }
    assert!(m_in0 > 0 && m_out0 > 0);

    // Tenant 0's flow surges ≈ 8x inside tenant-0 windows.
    let a0 = gens[0].take_until(duration);
    let (mut in0, mut out0) = (0u64, 0u64);
    for a in &a0 {
        if tables.in_burst(a.at, 0) {
            in0 += 1;
        } else {
            out0 += 1;
        }
    }
    let surge = (in0 as f64 / m_in0 as f64) / (out0 as f64 / m_out0 as f64);
    assert!(
        (6.0..10.5).contains(&surge),
        "tenant-0 in/out rate ratio {surge:.2} should be ≈ {}",
        cfg.burst_factor
    );

    // Tenant 1's flow is flat across tenant-0-only windows (guarded: the
    // random schedule could in principle bury tenant-0 windows inside
    // tenant-1's, leaving no clean probe interval).
    if m_only0 * step >= 200 * MICROS && m_neither > 0 {
        let a1 = gens[1].take_until(duration);
        let (mut leak_in, mut leak_out) = (0u64, 0u64);
        for a in &a1 {
            let b0 = tables.in_burst(a.at, 0);
            let b1 = tables.in_burst(a.at, 1);
            if b0 && !b1 {
                leak_in += 1;
            }
            if !b0 && !b1 {
                leak_out += 1;
            }
        }
        let leak = (leak_in as f64 / m_only0 as f64) / (leak_out as f64 / m_neither as f64);
        assert!(
            (0.7..1.4).contains(&leak),
            "tenant-1 rate ratio {leak:.2} across tenant-0 windows should be ≈ 1"
        );
    }
}

// ---------------------------------------------------------------------------
// Determinism goldens
// ---------------------------------------------------------------------------

/// The golden population scenario: two tenants on one IPSec engine with
/// every generator feature on (Zipf popularity, Pareto sizes, diurnal
/// envelope, flash crowds) and traces enabled, so the canonical report
/// covers every completion timestamp and the fairness line.
fn population_spec() -> ExperimentSpec {
    let line = Rate::gbps(32.0);
    let flows = vec![
        FlowSpec::new(
            0,
            0,
            Path::FunctionCall,
            TrafficPattern::fixed(1500, 0.3, line),
            Slo::gbps(8.0),
            0,
        ),
        FlowSpec::new(
            1,
            1,
            Path::FunctionCall,
            TrafficPattern::fixed(1500, 0.3, line),
            Slo::gbps(8.0),
            0,
        ),
    ];
    ExperimentSpec::new(Mode::Arcus, vec![AccelModel::ipsec_32g()], flows)
        .with_duration(4 * MILLIS)
        .with_warmup(MILLIS)
        .with_population(PopulationConfig {
            users: 5000,
            diurnal_period: 2 * MILLIS,
            diurnal_depth: 0.3,
            burst_epochs: 2,
            burst_factor: 4.0,
            ..Default::default()
        })
        .with_trace()
}

#[test]
fn population_report_is_byte_identical_across_queue_disciplines() {
    let spec = population_spec();
    let heap = run_with::<BinaryHeapQueue<EngineEvent>>(&spec);
    let cal = run_with::<CalendarQueue<EngineEvent>>(&spec);
    let wheel = run_with::<HierWheel<EngineEvent>>(&spec);
    assert_eq!(
        heap.canonical(),
        cal.canonical(),
        "population reports diverge between heap and calendar"
    );
    assert_eq!(
        heap.canonical(),
        wheel.canonical(),
        "population reports diverge between heap and hierarchical wheel"
    );
    // The run actually exercised the population path: fairness is reported
    // on the canonical surface with sane bounds.
    assert!(heap.canonical().contains("fairness="));
    let fr = heap.fairness.expect("population runs report fairness");
    assert_eq!(fr.users, 5000);
    assert!(fr.active_users > 0 && fr.active_users <= fr.users);
    assert!(fr.jain_ppm > 0 && fr.jain_ppm <= 1_000_000);
    assert!(fr.total_bytes > 0 && fr.top_user_bytes <= fr.total_bytes);
}

#[test]
fn population_sweep_is_byte_identical_across_thread_counts() {
    let grid = SweepGrid::new(GridBase {
        duration: 2 * MILLIS,
        warmup: MILLIS / 2,
        line_rate: Rate::gbps(32.0),
        load: 0.5,
        path: Path::FunctionCall,
        seed: 11,
    })
    .modes(vec![Mode::Arcus])
    .tenants(vec![2])
    .mixes(vec![SizeMix::Mtu])
    .bursts(vec![Burstiness::Paced])
    .tightness(vec![0.7])
    .accels(vec![AccelModel::ipsec_32g()])
    .seeds(vec![1])
    .population(vec![None, Some(2000)]);
    grid.validate().expect("population grid is admissible");
    assert_eq!(grid.cardinality(), 2);

    let a = SweepRunner::with_threads(1).run(&grid);
    let b = SweepRunner::with_threads(4).run(&grid);
    assert_eq!(a.len(), 2);
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.key.label(), y.key.label());
        assert_eq!(
            x.report.canonical(),
            y.report.canonical(),
            "{} diverges between 1 and 4 sweep threads",
            x.key.label()
        );
    }
    assert_eq!(aggregate(&a).render(), aggregate(&b).render());

    // The two cells differ exactly by the population axis: the pattern
    // cell carries no fairness surface, the population cell does.
    let base = a.iter().find(|o| o.key.population.is_none()).expect("pattern cell");
    let pop = a.iter().find(|o| o.key.population == Some(2000)).expect("population cell");
    assert!(pop.key.label().contains("/u2000/"));
    assert!(!base.key.label().contains("u2000"));
    assert!(base.report.fairness.is_none());
    assert!(!base.report.canonical().contains("fairness="));
    let fr = pop.report.fairness.expect("population cell reports fairness");
    assert_eq!(fr.users, 2000);
}

#[test]
fn recorded_trace_replays_to_a_byte_identical_report() {
    let spec = population_spec();
    let records = record_population_trace(&spec).expect("spec carries a population");
    assert!(records.len() > 1_000, "golden scenario should record a dense trace");
    for w in records.windows(2) {
        assert!(w[0].at <= w[1].at, "recorded traces are time-sorted");
    }

    // Round-trip through the on-disk format, exactly as `arcus trace
    // record` writes and `arcus trace replay` reads.
    let users = spec.population.as_ref().unwrap().users as u64;
    let buf = write(users, spec.flows.len() as u64, &records).expect("encode");
    let data = read(&buf).expect("decode");
    assert_eq!(data.records, records, "codec must round-trip the recording exactly");

    let replayed = run_replay(&spec, &data).expect("replay");
    let direct = run(&spec);
    assert_eq!(
        replayed.canonical(),
        direct.canonical(),
        "record → replay must reproduce the generator run byte-for-byte"
    );

    // Header mismatches fail loudly instead of replaying a trace against
    // the wrong population.
    let bad = TraceData { users: users + 1, ..data };
    assert!(run_replay(&spec, &bad).unwrap_err().contains("recorded for"));

    // Recording without a population table is an error, not an empty trace.
    let no_pop = ExperimentSpec::new(
        Mode::Arcus,
        vec![AccelModel::ipsec_32g()],
        population_spec().flows,
    );
    assert!(record_population_trace(&no_pop).unwrap_err().contains("population"));
}

// ---------------------------------------------------------------------------
// Codec properties
// ---------------------------------------------------------------------------

fn random_trace(case: u64) -> (u64, u64, Vec<TraceRecord>) {
    let mut rng = Rng::for_stream(0xC0DEC, case);
    let users = rng.range_u64(1, 1 << 20);
    let flows = rng.range_u64(1, 256);
    let n = rng.range_u64(0, 200) as usize;
    let mut at = 0u64;
    let records = (0..n)
        .map(|_| {
            at += rng.range_u64(0, 10 * MICROS);
            TraceRecord {
                at,
                user: rng.range_u64(0, users - 1) as u32,
                flow: rng.range_u64(0, flows - 1) as u32,
                op: OP_INJECT,
                // Bias toward large values so multi-byte varints are common.
                bytes: rng.range_u64(0, u64::from(u32::MAX)) << rng.range_u64(0, 20),
            }
        })
        .collect();
    (users, flows, records)
}

#[test]
fn trace_codec_round_trips_randomized_traces() {
    for case in 0..16 {
        let (users, flows, records) = random_trace(case);
        let buf = write(users, flows, &records).expect("encode");
        let data = read(&buf).expect("decode");
        assert_eq!(data.users, users, "case {case}");
        assert_eq!(data.flows, flows, "case {case}");
        assert_eq!(data.records, records, "case {case}");
    }
}

#[test]
fn every_truncated_prefix_of_a_trace_fails_loudly() {
    // Every strict prefix must surface an error — a cut mid-varint reads
    // "truncated varint", a cut between fields trips the record loop or
    // the trailing-bytes check. None may panic or silently decode short.
    for case in [1u64, 2, 3] {
        let (users, flows, records) = random_trace(case);
        let buf = write(users, flows, &records).expect("encode");
        for cut in 0..buf.len() {
            assert!(
                read(&buf[..cut]).is_err(),
                "case {case}: prefix of {cut}/{} bytes must fail loudly",
                buf.len()
            );
        }
        assert!(read(&buf).is_ok());
    }
}

#[test]
fn trace_decode_rejects_overlong_varint_encodings() {
    let header = |tail: &[u8]| {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"ARCT");
        buf.extend_from_slice(&1u16.to_le_bytes());
        buf.extend_from_slice(tail);
        buf
    };
    // Users field: nine continuation bytes put the decoder at shift 63;
    // a tenth byte carrying payload past bit 63 must error, not truncate
    // to a silently wrong population size.
    let mut overflow = vec![0xffu8; 9];
    overflow.push(0x7f);
    overflow.extend_from_slice(&[1, 0]); // flows / count, never reached
    let err = read(&header(&overflow)).unwrap_err();
    assert!(err.contains("overflow"), "expected a varint overflow, got: {err}");
    // Eleven continuation bytes promise payload groups past bit 64.
    assert!(read(&header(&[0xffu8; 11])).is_err());
    // The boundary stays valid: u64::MAX (nine 0xff + 0x01) decodes as a
    // legal — if absurd — population size, then fails on truncation, not
    // on the varint itself.
    let mut max = vec![0xffu8; 9];
    max.push(0x01);
    let err = read(&header(&max)).unwrap_err();
    assert!(!err.contains("overflow"), "u64::MAX is a valid varint, got: {err}");
}
