//! Hierarchical shaper tree, end to end (§5 "precise **and** scalable").
//!
//! Three contracts are pinned here:
//!
//! 1. **Flat→tree regression guard**: a tree with a single unconstrained
//!    child delegates verdicts to the bare child shaper byte-for-byte
//!    (the property also lives next to the implementation; this is the
//!    black-box replay form).
//! 2. **Determinism at scale**: a multi-tenant hierarchical scenario —
//!    tree ticks, aggregate installs, renegotiation directives, and
//!    dataplane events all interleaving — produces byte-identical
//!    canonical `SystemReport`s on all three event-queue disciplines.
//! 3. **Hierarchy semantics**: min-guarantees hold under full contention,
//!    idle sibling budget is borrowed (work conservation), and a scaled
//!    sweep cell (hundreds of flows under a handful of tenant aggregates)
//!    attains its committed SLOs.

use arcus::accel::AccelModel;
use arcus::flow::{FlowSpec, Path, Slo, TrafficPattern};
use arcus::shaping::{replay, ShapeMode, ShaperTree, TokenBucket, TreeConfig, TreeVerdict};
use arcus::sim::{BinaryHeapQueue, CalendarQueue};
use arcus::sweep::{GridBase, Scale, SweepGrid, SweepRunner};
use arcus::system::{run_with, EngineEvent, ExperimentSpec, LifecycleEvent, Mode, SystemReport};
use arcus::util::units::{Rate, Time, MILLIS, SECONDS};

// ---------------------------------------------------------------------------
// 1. Flat→tree regression guard
// ---------------------------------------------------------------------------

/// `shaping::replay`, but through a tree leaf. Panics on `AwaitTick`: an
/// unconstrained leaf must never engage the pacing machinery.
fn tree_replay(tree: &mut ShaperTree, arrivals: &[(Time, u64)]) -> (u64, Time) {
    let mut admitted = 0u64;
    let mut last = 0;
    let mut free_at: Time = 0;
    for &(t, cost) in arrivals {
        let mut now = t.max(free_at);
        loop {
            match tree.try_acquire(0, now, cost) {
                TreeVerdict::Admit => {
                    admitted += cost;
                    last = now;
                    free_at = now;
                    break;
                }
                TreeVerdict::RetryAt(at) => {
                    assert!(at > now);
                    now = at;
                }
                TreeVerdict::AwaitTick => panic!("unconstrained leaf awaited a tick"),
            }
        }
    }
    (admitted, last)
}

#[test]
fn single_child_tree_replays_byte_identical_to_bare_shaper() {
    for (gbps, size) in [(4.0, 1500u64), (10.0, 64), (40.0, 4096)] {
        let bytes_per_sec = Rate::gbps(gbps).as_bits_per_sec() / 8.0;
        // 2x-oversubscribed paced arrivals for ~5 ms.
        let mut arrivals = Vec::new();
        let mut t = 0u64;
        let mut sent = 0u64;
        while sent < (bytes_per_sec * 0.005) as u64 {
            arrivals.push((t, size));
            sent += size;
            t += (size as f64 / (2.0 * bytes_per_sec) * SECONDS as f64) as u64;
        }
        let mut bare = TokenBucket::for_rate(bytes_per_sec, ShapeMode::Gbps);
        let (bare_admitted, bare_last) = replay(&mut bare, &arrivals);

        let mut tree = ShaperTree::new(1, TreeConfig::default());
        tree.install_flat_leaf(
            0,
            0,
            Some(Box::new(TokenBucket::for_rate(bytes_per_sec, ShapeMode::Gbps))),
            ShapeMode::Gbps,
        );
        let (tree_admitted, tree_last) = tree_replay(&mut tree, &arrivals);
        assert_eq!(tree_admitted, bare_admitted, "{gbps} Gbps / {size} B");
        assert_eq!(tree_last, bare_last, "{gbps} Gbps / {size} B");
        // And the wrapped shaper still reports the programmed rate.
        let rate = tree.leaf_rate(0).unwrap();
        assert!((rate - bytes_per_sec).abs() / bytes_per_sec < 0.01);
    }
}

// ---------------------------------------------------------------------------
// 2. Determinism with the tree enabled
// ---------------------------------------------------------------------------

/// Hierarchical golden scenario: 2 tenant VMs × 8 flows each on one IPSec
/// engine, everyone oversubscribed (tree ticks dominate pacing), with a
/// mid-run renegotiation so SetAggregate/InstallProgram directives land
/// while the pacing passes run.
fn tree_spec() -> ExperimentSpec {
    let line = Rate::gbps(32.0);
    let flows: Vec<FlowSpec> = (0..16)
        .map(|i| {
            FlowSpec::new(
                i,
                i % 2,
                Path::FunctionCall,
                TrafficPattern::fixed(1500, 0.05, line),
                Slo::gbps(1.2),
                0,
            )
        })
        .collect();
    ExperimentSpec::new(Mode::Arcus, vec![AccelModel::ipsec_32g()], flows)
        .with_duration(4 * MILLIS)
        .with_warmup(MILLIS)
        .with_event(LifecycleEvent::Renegotiate {
            flow: 0,
            at: 2 * MILLIS,
            slo: Slo::gbps(2.0),
        })
        .with_hierarchy()
}

#[test]
fn hierarchical_scenario_reports_byte_identical_across_queues() {
    let spec = tree_spec();
    let heap = run_with::<BinaryHeapQueue<EngineEvent>>(&spec);
    let cal = run_with::<CalendarQueue<EngineEvent>>(&spec);
    assert_eq!(heap.queue, "binary_heap");
    assert_eq!(cal.queue, "calendar");
    assert_eq!(
        heap.canonical(),
        cal.canonical(),
        "tree-enabled SystemReports diverge between queue disciplines"
    );
    assert_eq!(heap.events, cal.events);
    assert_eq!(heap.peak_queue_depth, cal.peak_queue_depth);
    // All 16 flows admitted and completing.
    for f in &heap.per_flow {
        assert!(!f.rejected, "flow {} rejected", f.flow);
        assert!(f.completed > 100, "flow {} completed {}", f.flow, f.completed);
    }
}

#[test]
fn hierarchical_scenario_is_stable_across_repeat_runs() {
    let spec = tree_spec();
    let a = run_with::<CalendarQueue<EngineEvent>>(&spec);
    let b = run_with::<CalendarQueue<EngineEvent>>(&spec);
    assert_eq!(a.canonical(), b.canonical());
}

// ---------------------------------------------------------------------------
// 3. Hierarchy semantics through the whole engine
// ---------------------------------------------------------------------------

fn committed_spec(loads: [f64; 4]) -> ExperimentSpec {
    // 2 VMs × 2 flows, each committing 5 Gbps (20 G total under the
    // ~24.6 G budget); per-flow offered load set by the caller.
    let line = Rate::gbps(32.0);
    let flows: Vec<FlowSpec> = (0..4)
        .map(|i| {
            FlowSpec::new(
                i,
                i / 2, // flows 0,1 → VM 0; flows 2,3 → VM 1
                Path::FunctionCall,
                TrafficPattern::fixed(1500, loads[i], line),
                Slo::gbps(5.0),
                0,
            )
        })
        .collect();
    ExperimentSpec::new(Mode::Arcus, vec![AccelModel::ipsec_32g()], flows)
        .with_duration(6 * MILLIS)
        .with_warmup(MILLIS)
        .with_hierarchy()
}

fn total_goodput_gbps(r: &SystemReport) -> f64 {
    r.per_flow.iter().map(|f| f.goodput.as_gbps()).sum()
}

#[test]
fn hierarchy_holds_committed_slos_under_oversubscription() {
    // Everyone offers 8 G against a 5 G guarantee: each flow must attain
    // its SLO (guarantee first; the leftover budget is borrowed evenly, so
    // attainment lands at or above 1.0), and the aggregate stays inside
    // the engine.
    let report = run_with::<BinaryHeapQueue<EngineEvent>>(&committed_spec([0.25; 4]));
    for f in &report.per_flow {
        assert!(!f.rejected, "flow {} rejected", f.flow);
        let att = f.slo_attainment().unwrap();
        assert!(att > 0.92, "flow {} attainment {att:.3}", f.flow);
    }
    let total = total_goodput_gbps(&report);
    assert!(total < 27.0, "aggregate {total:.1} G exceeds the engine");
}

#[test]
fn hierarchy_borrows_idle_sibling_budget() {
    // VM 0's flows stay hungry while VM 1 offers almost nothing: the
    // work-conserving borrow must push VM 0 well past its guarantees,
    // without exceeding the engine budget.
    let report = run_with::<BinaryHeapQueue<EngineEvent>>(
        &committed_spec([0.45, 0.45, 0.01, 0.01]),
    );
    for f in report.per_flow.iter().take(2) {
        let gbps = f.goodput.as_gbps();
        assert!(
            gbps > 5.0 * 1.3,
            "flow {} got {gbps:.2} G — idle sibling budget was not borrowed",
            f.flow
        );
    }
    // The near-idle flows still complete what they offer (~0.3 G each).
    for f in report.per_flow.iter().skip(2) {
        assert!(f.completed > 50, "flow {} completed {}", f.flow, f.completed);
    }
    let total = total_goodput_gbps(&report);
    assert!(total < 27.0, "aggregate {total:.1} G exceeds the engine");
}

#[test]
fn departed_tenant_budget_is_reclaimed_by_siblings() {
    // Both VMs saturate; VM 1's flows depart mid-run. After the control
    // plane's SetAggregate catches up, VM 0 borrows the freed budget: its
    // post-departure rate must exceed its pre-departure rate.
    let mut spec = committed_spec([0.4; 4]).with_trace();
    spec = spec
        .with_duration(10 * MILLIS)
        .with_event(LifecycleEvent::Depart { flow: 2, at: 5 * MILLIS })
        .with_event(LifecycleEvent::Depart { flow: 3, at: 5 * MILLIS });
    let report = run_with::<BinaryHeapQueue<EngineEvent>>(&spec);
    let rate_in = |f: usize, lo: Time, hi: Time| -> f64 {
        let bytes: u64 = report.per_flow[f]
            .trace
            .iter()
            .filter(|&&(at, _, _)| at >= lo && at < hi)
            .map(|&(_, _, b)| b)
            .sum();
        bytes as f64 * 8.0 / (hi - lo) as f64 * (SECONDS as f64 / 1e9)
    };
    let before = rate_in(0, 2 * MILLIS, 5 * MILLIS);
    let after = rate_in(0, 7 * MILLIS, 10 * MILLIS);
    assert!(
        after > before * 1.25,
        "flow 0: {before:.2} G before the departures vs {after:.2} G after — \
         freed tenant budget was not reclaimed"
    );
}

#[test]
fn scaled_sweep_cell_attains_committed_slos() {
    // One scaled grid cell: 128 flows under 4 tenant aggregates, shaped by
    // the tree (the cell sets `hierarchy` itself). Committed sum = 0.6 ×
    // capacity, split over all 128 flows.
    let grid = SweepGrid::new(GridBase {
        duration: 3 * MILLIS,
        warmup: MILLIS,
        ..GridBase::default()
    })
    .modes(vec![Mode::Arcus])
    .tenants(vec![4])
    .mixes(vec![arcus::sweep::SizeMix::Mtu])
    .bursts(vec![arcus::flow::pattern::Burstiness::Paced])
    .tightness(vec![0.6])
    .scale(vec![Scale::Flows(128)])
    .accels(vec![AccelModel::ipsec_32g()])
    .seeds(vec![1]);
    grid.validate().expect("scaled grid validates");
    let scenarios = grid.expand();
    assert_eq!(scenarios.len(), 1);
    assert!(scenarios[0].spec.hierarchy);
    assert_eq!(scenarios[0].spec.flows.len(), 128);
    let outcomes = SweepRunner::with_threads(2).run(&grid);
    let report = &outcomes[0].report;
    assert_eq!(report.per_flow.len(), 128);
    let mut attained = 0usize;
    let mut rejected = 0usize;
    for f in &report.per_flow {
        if f.rejected {
            rejected += 1;
            continue;
        }
        if f.slo_attainment().unwrap_or(0.0) > 0.85 {
            attained += 1;
        }
    }
    assert_eq!(rejected, 0, "admission rejected {rejected} of 128 at 0.6 tightness");
    assert!(
        attained >= 120,
        "only {attained}/128 flows attained ≥85% of their committed SLO"
    );
}
