//! Fleet-tier integration: the multi-host golden fault scenario must be
//! byte-identical across all three event-queue disciplines AND across
//! host thread counts, and delayed/dropped directive distribution (stale
//! fleet config) must measurably degrade fault-era SLO attainment.

use arcus::accel::AccelModel;
use arcus::faults::{FaultKind, FaultSpec};
use arcus::fleet::{run_with, FleetConfig};
use arcus::flow::{FlowSpec, Path, Slo, TrafficPattern};
use arcus::sim::{BinaryHeapQueue, CalendarQueue, HierWheel};
use arcus::system::{EngineEvent, ExperimentSpec, Mode};
use arcus::util::units::{Rate, MICROS, MILLIS};

/// The fleet golden scenario: four tenants (two per host under `hosts =
/// 2`), two engines per host, every flow oversubscribed so shaping binds.
/// The fault plan mixes both partitioning classes: component faults
/// (accel slowdown, then a control outage) strike host 0's hardware,
/// while a rogue tenant rides on host 1 — so both hosts execute
/// non-trivial, *different* fault schedules.
fn golden_fleet_spec() -> ExperimentSpec {
    let line = Rate::gbps(32.0);
    let flows: Vec<FlowSpec> = (0..8)
        .map(|i| {
            FlowSpec::new(
                i,
                i / 2,
                Path::FunctionCall,
                TrafficPattern::fixed(1500, 0.45, line),
                Slo::gbps(8.0),
                i % 2,
            )
        })
        .collect();
    ExperimentSpec::new(
        Mode::Arcus,
        vec![AccelModel::ipsec_32g(), AccelModel::ipsec_32g()],
        flows,
    )
    .with_duration(8 * MILLIS)
    .with_warmup(MILLIS)
    .with_hierarchy()
    .with_fault(FaultSpec::new(
        FaultKind::AccelSlowdown { unit: 0, factor: 0.5 },
        3 * MILLIS,
        5 * MILLIS,
    ))
    .with_fault(FaultSpec::new(FaultKind::ControlOutage, 5 * MILLIS, 6 * MILLIS))
    // Flow 3 belongs to vm 1 → host 1 under hosts = 2.
    .with_fault(FaultSpec::new(
        FaultKind::RogueTenant { flow: 3 },
        3 * MILLIS,
        5 * MILLIS,
    ))
}

fn golden_cfg(threads: usize) -> FleetConfig {
    FleetConfig {
        hosts: 2,
        threads,
        propagation_delay: 20 * MICROS,
        ..FleetConfig::default()
    }
}

#[test]
fn golden_fleet_scenario_byte_identical_across_queues_and_threads() {
    let spec = golden_fleet_spec();
    let heap = run_with::<BinaryHeapQueue<EngineEvent>>(&spec, &golden_cfg(1));
    let cal = run_with::<CalendarQueue<EngineEvent>>(&spec, &golden_cfg(1));
    let wheel = run_with::<HierWheel<EngineEvent>>(&spec, &golden_cfg(1));
    assert_eq!(heap.queue, "binary_heap");
    assert_eq!(cal.queue, "calendar");
    assert_eq!(wheel.queue, "hier_wheel");
    assert_eq!(
        heap.canonical(),
        cal.canonical(),
        "fleet golden: heap vs calendar diverge"
    );
    assert_eq!(
        heap.canonical(),
        wheel.canonical(),
        "fleet golden: heap vs hierarchical wheel diverge"
    );
    // One advance thread per host must replay the serial schedule exactly.
    let threaded = run_with::<BinaryHeapQueue<EngineEvent>>(&spec, &golden_cfg(0));
    assert_eq!(
        heap.canonical(),
        threaded.canonical(),
        "fleet golden: 1 vs N host threads diverge"
    );
    // The canonical form pins the distribution ledger and per-host rollups,
    // so a staleness or rollup regression can never slip past this gate.
    assert!(heap.canonical().contains("directive_staleness_max="));
    assert_eq!(heap.host_rollups.len(), 2);
    assert!(heap.events > 100_000, "fleet golden run too small: {}", heap.events);
    // Propagation was delayed, so the ledger must have recorded it.
    assert_eq!(heap.directive_staleness_max, 20 * MICROS);
}

#[test]
fn golden_fleet_scenario_stable_across_repeat_runs() {
    let spec = golden_fleet_spec();
    let a = run_with::<CalendarQueue<EngineEvent>>(&spec, &golden_cfg(0));
    let b = run_with::<CalendarQueue<EngineEvent>>(&spec, &golden_cfg(0));
    assert_eq!(a.canonical(), b.canonical());
}

/// Stale config degrades fault recovery: the same faulted fleet runs once
/// with instant distribution and once with a propagation delay plus a
/// drop window spanning the fault — the boost envelopes the planner
/// publishes when attainment collapses then arrive only *after* the
/// window, so post-fault catch-up runs at the tight ceiling for longer
/// and fault-era attainment is strictly worse.
#[test]
fn delayed_propagation_degrades_fault_era_attainment() {
    let line = Rate::gbps(32.0);
    let flows: Vec<FlowSpec> = (0..8)
        .map(|i| {
            FlowSpec::new(
                i,
                i / 2,
                Path::FunctionCall,
                TrafficPattern::fixed(1500, 0.45, line),
                Slo::gbps(8.0),
                i % 2,
            )
        })
        .collect();
    let spec = ExperimentSpec::new(
        Mode::Arcus,
        vec![AccelModel::ipsec_32g(), AccelModel::ipsec_32g()],
        flows,
    )
    .with_duration(12 * MILLIS)
    .with_warmup(MILLIS)
    .with_hierarchy()
    .with_fault(FaultSpec::new(
        FaultKind::AccelSlowdown { unit: 0, factor: 0.5 },
        4 * MILLIS,
        7 * MILLIS,
    ));

    let fresh = run_with::<BinaryHeapQueue<EngineEvent>>(
        &spec,
        &FleetConfig { hosts: 2, threads: 1, ..FleetConfig::default() },
    );
    let stale = run_with::<BinaryHeapQueue<EngineEvent>>(
        &spec,
        &FleetConfig {
            hosts: 2,
            threads: 1,
            propagation_delay: 300 * MICROS,
            // Every delivery landing inside [4, 9) ms is lost: the boost
            // published when the fault bites cannot arrive before 9 ms,
            // two milliseconds into the post-fault era.
            drop_windows: vec![(4 * MILLIS, 9 * MILLIS)],
            ..FleetConfig::default()
        },
    );

    assert!(
        stale.directive_staleness_max > fresh.directive_staleness_max,
        "drop window must show up as staleness: stale {} vs fresh {}",
        stale.directive_staleness_max,
        fresh.directive_staleness_max
    );
    // Staleness is ledgered by the distribution tier, not smeared into the
    // in-host apply lag.
    assert!(stale.directive_lag_max <= spec.reconfig_latency);

    // Fault-era attainment over the flows the slowdown actually hit
    // (host 0's engine-0 flows: vms 0 and 2 → global flows 0 and 4).
    let era_sum = |r: &arcus::system::SystemReport| -> f64 {
        [0usize, 4]
            .iter()
            .map(|&i| {
                let fr = r.per_flow[i].fault.expect("faulted run carries era reports");
                fr.during.attainment.unwrap_or(0.0) + fr.post.attainment.unwrap_or(0.0)
            })
            .sum()
    };
    let fresh_att = era_sum(&fresh);
    let stale_att = era_sum(&stale);
    assert!(
        stale_att < fresh_att,
        "stale config must cost fault-era attainment: stale {stale_att:.4} \
         vs fresh {fresh_att:.4}"
    );
}
