//! Integration tests for the streaming observability plane: the
//! Prometheus text exporter's format guarantees, the binary series dump's
//! round-trip through the `arcus top` renderer, the retention knobs, and
//! the series digest's place in the deterministic canonical report.

use std::collections::HashMap;

use arcus::accel::AccelModel;
use arcus::flow::{FlowSpec, Path, Slo, TrafficPattern};
use arcus::obs::{dump, prom, top, ObsSnapshot, GAUGE_NONE};
use arcus::sim::{BinaryHeapQueue, CalendarQueue, HierWheel};
use arcus::system::{run_with, EngineEvent, ExperimentSpec, Mode};
use arcus::util::units::{Rate, Time, MILLIS};

/// Two Arcus tenants on one IPSec engine — small enough to run in every
/// test, busy enough that every flow completes work and the control plane
/// ticks many times.
fn small_spec(duration: Time) -> ExperimentSpec {
    let line = Rate::gbps(32.0);
    let flow = |id: usize, slo: f64, load: f64| {
        FlowSpec::new(
            id,
            id,
            Path::FunctionCall,
            TrafficPattern::fixed(1500, load, line),
            Slo::gbps(slo),
            0,
        )
    };
    ExperimentSpec::new(
        Mode::Arcus,
        vec![AccelModel::ipsec_32g()],
        vec![flow(0, 9.0, 0.4), flow(1, 6.0, 0.3)],
    )
    .with_duration(duration)
    .with_warmup(MILLIS)
}

// ---------------------------------------------------------------------------
// Prometheus exporter format contract
// ---------------------------------------------------------------------------

/// Assert the structural rules of the text exposition format that the CI
/// `obs-smoke` job also greps for: every family announces `# HELP` then
/// `# TYPE` before its first sample, `_total` families are counters and
/// everything else a gauge, and every sample line parses.
fn check_prom_format(text: &str) {
    let mut typed: HashMap<&str, &str> = HashMap::new();
    let mut helped: Vec<&str> = Vec::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().expect("HELP names a family");
            assert!(!typed.contains_key(name), "HELP must precede TYPE for {name}");
            helped.push(name);
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().expect("TYPE names a family");
            let kind = it.next().expect("TYPE carries a kind");
            assert!(helped.contains(&name), "TYPE without HELP for {name}");
            let expect = if name.ends_with("_total") { "counter" } else { "gauge" };
            assert_eq!(kind, expect, "family {name} has the wrong type");
            typed.insert(name, kind);
        } else if !line.is_empty() {
            let name = line
                .split(|c| c == '{' || c == ' ')
                .next()
                .expect("sample line starts with a family name");
            assert!(typed.contains_key(name), "sample before its TYPE header: {line}");
            let value = line.rsplit(' ').next().unwrap();
            assert!(
                value.parse::<f64>().is_ok() || value == "NaN",
                "unparseable sample value in: {line}"
            );
        }
    }
    assert!(!typed.is_empty(), "exposition document rendered no families");
}

#[test]
fn prom_export_is_well_formed_and_escapes_labels() {
    let report = run_with::<BinaryHeapQueue<EngineEvent>>(&small_spec(4 * MILLIS));
    let label = "smoke \"run\"\\v1".to_string();
    let text = prom::render(&[(label, &report)]);
    check_prom_format(&text);
    // The scenario label survives with exposition-format escaping.
    assert!(
        text.contains("scenario=\"smoke \\\"run\\\"\\\\v1\""),
        "escaped label missing:\n{text}"
    );
    // Core families from both the per-flow report and the obs rollups.
    for family in [
        "arcus_flow_bytes_total",
        "arcus_flow_attainment",
        "arcus_tenant_bytes_total",
        "arcus_engine_bytes_total",
        "arcus_events_total",
    ] {
        assert!(text.contains(&format!("# TYPE {family} ")), "{family} missing");
    }
    // Both flows exported under both labels sets.
    assert!(text.contains("flow=\"0\",vm=\"0\""));
    assert!(text.contains("flow=\"1\",vm=\"1\""));
}

#[test]
fn prom_counters_are_monotone_across_scrapes() {
    // Two scrapes of "the same system later": a longer run of the same
    // spec. Every counter sample in the second document must be >= its
    // counterpart in the first — the property that makes the cumulative
    // export safe for Prometheus `rate()`.
    let early = run_with::<BinaryHeapQueue<EngineEvent>>(&small_spec(3 * MILLIS));
    let late = run_with::<BinaryHeapQueue<EngineEvent>>(&small_spec(6 * MILLIS));
    let scrape = |r| prom::render(&[("s".to_string(), r)]);
    let counters = |text: &str| -> HashMap<String, f64> {
        text.lines()
            .filter(|l| !l.starts_with('#') && !l.is_empty())
            .filter_map(|l| {
                let (series, value) = l.rsplit_once(' ')?;
                if series.split('{').next()?.ends_with("_total") {
                    Some((series.to_string(), value.parse().ok()?))
                } else {
                    None
                }
            })
            .collect()
    };
    let a = counters(&scrape(&early));
    let b = counters(&scrape(&late));
    assert!(!a.is_empty());
    for (series, &va) in &a {
        let vb = b.get(series).unwrap_or_else(|| panic!("{series} vanished"));
        assert!(*vb >= va, "{series} went backwards: {va} -> {vb}");
    }
}

// ---------------------------------------------------------------------------
// Binary dump -> `arcus top`
// ---------------------------------------------------------------------------

#[test]
fn series_dump_round_trips_through_reader() {
    let report = run_with::<BinaryHeapQueue<EngineEvent>>(&small_spec(4 * MILLIS));
    let bytes = dump::write(&report.obs);
    let data = dump::read(&bytes).expect("dump parses");
    assert_eq!(data.control_period, report.obs.control_period);
    assert_eq!(data.sample_every, report.obs.sample_every);
    assert_eq!(data.flows.len(), report.obs.flows.len());
    for (got, want) in data.flows.iter().zip(report.obs.flows.iter()) {
        assert_eq!(got.flow, want.flow);
        assert_eq!(got.vm, want.vm);
        assert_eq!(got.engine, want.engine);
        for (g, w) in got.signals().iter().zip(want.signals().iter()) {
            assert!(g.iter().eq(w.iter()), "flow {} series diverged", want.flow);
        }
        // The run actually sampled: cumulative bytes grew, and the gauge
        // sentinel never leaked into the counter rings.
        assert!(want.bytes.latest().unwrap_or(0) > 0, "flow {} never sampled", want.flow);
        assert!(want.bytes.iter().all(|(_, v)| v != GAUGE_NONE));
    }
    // Truncated input fails loudly instead of misparsing.
    assert!(dump::read(&bytes[..bytes.len() / 2]).is_err());
    assert!(dump::read(b"BOGUS").is_err());
}

/// Decode → re-encode is the identity on bytes. The dump only carries the
/// header clocks and per-flow series, so rebuilding a snapshot from the
/// decoded [`dump::DumpData`] and writing it again must reproduce the
/// original dump bit-for-bit — the property that lets `arcus top` (or any
/// other consumer) archive a dump it has read without loss.
#[test]
fn series_dump_reencode_is_byte_identical() {
    let report = run_with::<BinaryHeapQueue<EngineEvent>>(&small_spec(4 * MILLIS));
    let bytes = dump::write(&report.obs);
    let data = dump::read(&bytes).expect("dump parses");
    let rebuilt = ObsSnapshot {
        control_period: data.control_period,
        sample_every: data.sample_every,
        flows: data.flows,
        ..Default::default()
    };
    assert_eq!(
        dump::write(&rebuilt),
        bytes,
        "re-encoding a decoded dump must be byte-identical"
    );
}

/// Every strict prefix of a valid dump must decode to an error — never a
/// panic, never a silently short parse. Truncation can only land inside a
/// varint (whose kept bytes still carry continuation bits) or at a field
/// boundary (where the next read runs off the end), so the decoder's
/// bounds checks — including the remaining-bytes guards on ring lengths
/// and the flow count — must catch all of them. This sweep is exhaustive
/// over the real dump, not a handful of spot lengths.
#[test]
fn series_dump_truncation_sweep_every_prefix_errors() {
    let report = run_with::<BinaryHeapQueue<EngineEvent>>(&small_spec(3 * MILLIS));
    let bytes = dump::write(&report.obs);
    assert!(bytes.len() > 100, "dump too small to sweep: {}", bytes.len());
    assert!(dump::read(&bytes).is_ok(), "full dump must parse");
    for n in 0..bytes.len() {
        match dump::read(&bytes[..n]) {
            Err(_) => {}
            Ok(_) => panic!(
                "prefix of {n}/{} bytes parsed instead of erroring",
                bytes.len()
            ),
        }
    }
}

#[test]
fn top_renders_worst_flows_from_dump() {
    let report = run_with::<BinaryHeapQueue<EngineEvent>>(&small_spec(4 * MILLIS));
    let data = dump::read(&dump::write(&report.obs)).expect("dump parses");
    let out = top::render_top(&data, 10);
    assert!(out.contains("worst flows by attainment / p99"), "{out}");
    assert!(out.contains("worst tenants"), "{out}");
    // Both flows appear; limit=1 trims to the single worst.
    assert!(out.lines().any(|l| l.trim_start().starts_with("0 ")), "{out}");
    assert!(out.lines().any(|l| l.trim_start().starts_with("1 ")), "{out}");
    let trimmed = top::render_top(&data, 1);
    let flow_rows = |s: &str| {
        s.lines()
            .take_while(|l| !l.contains("worst tenants"))
            .filter(|l| {
                l.trim_start().starts_with("0 ") || l.trim_start().starts_with("1 ")
            })
            .count()
    };
    assert_eq!(flow_rows(&trimmed), 1, "{trimmed}");
    assert_eq!(flow_rows(&out), 2, "{out}");
}

// ---------------------------------------------------------------------------
// Retention knobs
// ---------------------------------------------------------------------------

#[test]
fn retention_zero_disables_series_but_keeps_counters() {
    let spec = small_spec(4 * MILLIS).with_obs(0, 1);
    let report = run_with::<BinaryHeapQueue<EngineEvent>>(&spec);
    for f in &report.obs.flows {
        assert!(f.bytes.is_empty(), "flow {} sampled with retention 0", f.flow);
    }
    // The rollup counters and histograms still ran.
    assert!(report.obs.tenants.iter().any(|t| t.bytes > 0));
    assert!(report.obs.engines.iter().any(|e| !e.lat.is_empty()));
    // And the digest still pins the (empty-series) surface.
    assert!(report.canonical().contains("series_digest="));
}

#[test]
fn sample_every_thins_the_series() {
    let dense = run_with::<BinaryHeapQueue<EngineEvent>>(&small_spec(4 * MILLIS));
    let thin_spec = small_spec(4 * MILLIS).with_obs(256, 4);
    let thin = run_with::<BinaryHeapQueue<EngineEvent>>(&thin_spec);
    let dense_len = dense.obs.flows[0].bytes.len();
    let thin_len = thin.obs.flows[0].bytes.len();
    assert!(dense_len > 0 && thin_len > 0);
    assert!(
        thin_len <= dense_len / 2,
        "sample_every=4 retained {thin_len} of {dense_len} dense samples"
    );
    // Thinning changes only the cadence, not the values: every retained
    // thin sample (at ring index tick/4) equals the dense sample taken at
    // that same control tick.
    let d = &dense.obs.flows[0].bytes;
    for (idx, v) in thin.obs.flows[0].bytes.iter() {
        assert_eq!(
            Some(v),
            d.get(idx * 4),
            "thin sample at tick {} diverges from the dense run",
            idx * 4
        );
    }
}

// ---------------------------------------------------------------------------
// Determinism: the digest is part of the canonical report
// ---------------------------------------------------------------------------

#[test]
fn series_digest_identical_across_queue_disciplines() {
    let spec = small_spec(4 * MILLIS);
    let heap = run_with::<BinaryHeapQueue<EngineEvent>>(&spec);
    let cal = run_with::<CalendarQueue<EngineEvent>>(&spec);
    let wheel = run_with::<HierWheel<EngineEvent>>(&spec);
    assert!(heap.series_digest != 0, "digest degenerated to zero");
    assert_eq!(heap.series_digest, cal.series_digest);
    assert_eq!(heap.series_digest, wheel.series_digest);
    assert!(heap
        .canonical()
        .contains(&format!("series_digest={:016x}", heap.series_digest)));
    assert_eq!(heap.canonical(), cal.canonical());
    assert_eq!(heap.canonical(), wheel.canonical());
    // The digest is recomputable from the snapshot the report carries.
    assert_eq!(heap.obs.digest(), heap.series_digest);
}
