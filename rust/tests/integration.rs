//! Cross-module integration tests: config → spec → simulator → report,
//! all five management architectures, and the coordinator's end-to-end
//! guarantees on multi-component topologies.

use arcus::accel::AccelModel;
use arcus::config::{spec_from_document, Document};
use arcus::flow::{FlowKind, FlowSpec, Path, Slo, TrafficPattern};
use arcus::storage::SsdConfig;
use arcus::system::{run, ExperimentSpec, Mode};
use arcus::util::units::{Rate, MILLIS};
use arcus::workload::{fio_read_flow, fio_write_flow, live_migration_flow, mica_flows, renumber, FioJob, MicaUser};

#[test]
fn config_file_roundtrip_drives_simulation() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/quickstart.toml");
    let doc = Document::from_file(&path).expect("parse shipped config");
    let mut spec = spec_from_document(&doc).expect("typed spec");
    spec.duration = 5 * MILLIS;
    spec.warmup = MILLIS;
    let report = run(&spec);
    assert_eq!(report.per_flow.len(), 2);
    for f in &report.per_flow {
        assert!(!f.rejected);
        let att = f.slo_attainment().unwrap();
        assert!((0.9..1.2).contains(&att), "flow {} attainment {att:.2}", f.flow);
    }
}

#[test]
fn all_five_modes_run_the_same_topology() {
    let line = Rate::gbps(32.0);
    let flows = vec![
        FlowSpec::new(0, 0, Path::FunctionCall, TrafficPattern::fixed(1500, 0.3, line), Slo::gbps(8.0), 0),
        FlowSpec::new(1, 1, Path::InlineNicRx, TrafficPattern::fixed(512, 0.2, line), Slo::gbps(4.0), 0),
    ];
    for mode in [
        Mode::Arcus,
        Mode::HostNoTs,
        Mode::HostTsReflex,
        Mode::HostTsFirecracker,
        Mode::BypassedPanic,
    ] {
        let spec = ExperimentSpec::new(mode, vec![AccelModel::ipsec_32g()], flows.clone())
            .with_duration(4 * MILLIS)
            .with_warmup(MILLIS);
        let report = run(&spec);
        for f in &report.per_flow {
            assert!(f.completed > 100, "{}: flow {} completed {}", mode.name(), f.flow, f.completed);
        }
    }
}

#[test]
fn arcus_protects_committed_flows_from_best_effort_background() {
    // A committed flow + a greedy best-effort flow on one engine: the
    // committed flow must attain its SLO; the background must not be dead.
    let line = Rate::gbps(32.0);
    let flows = vec![
        FlowSpec::new(0, 0, Path::FunctionCall, TrafficPattern::fixed(4096, 0.4, line), Slo::gbps(10.0), 0),
        FlowSpec::new(1, 1, Path::FunctionCall, TrafficPattern::fixed(4096, 0.9, line), Slo::BestEffort, 0),
    ];
    let spec = ExperimentSpec::new(Mode::Arcus, vec![AccelModel::ipsec_32g()], flows)
        .with_duration(10 * MILLIS)
        .with_warmup(2 * MILLIS);
    let report = run(&spec);
    let committed = report.per_flow[0].slo_attainment().unwrap();
    assert!(committed > 0.95, "committed attainment {committed:.2}");
    let be = report.per_flow[1].goodput.as_gbps();
    assert!(be > 1.0, "best-effort should harvest leftovers, got {be:.2} G");
}

#[test]
fn mixed_storage_and_accel_flows_coexist() {
    // Fig 11 union: a MICA pair, a live-migration stream, and a storage
    // read/write pair all in one experiment.
    let users = [
        MicaUser { vm: 0, value_bytes: 64, mops: 1.0, slo: Slo::gbps(0.7) },
        MicaUser { vm: 1, value_bytes: 256, mops: 1.0, slo: Slo::gbps(2.0) },
    ];
    let mut flows = mica_flows(&users, 0, 1);
    flows.push(live_migration_flow(flows.len(), 2, 0, 10.0));
    flows.push(fio_read_flow(
        flows.len(),
        FioJob { vm: 3, bs: 4096, offered_iops: 120_000.0, slo_iops: 100_000.0 },
    ));
    flows.push(fio_write_flow(
        flows.len(),
        FioJob { vm: 4, bs: 4096, offered_iops: 24_000.0, slo_iops: 20_000.0 },
    ));
    let flows = renumber(flows);
    let spec = ExperimentSpec::new(
        Mode::Arcus,
        vec![AccelModel::aes_128(), AccelModel::sha1_hmac()],
        flows,
    )
    .with_duration(8 * MILLIS)
    .with_warmup(2 * MILLIS)
    .with_raid(4, SsdConfig::samsung_983dct());
    let report = run(&spec);
    // Every committed flow lands near its SLO.
    for f in &report.per_flow {
        if f.rejected {
            continue;
        }
        match f.slo {
            Slo::BestEffort => assert!(f.completed > 0),
            _ => {
                let att = f.slo_attainment().unwrap();
                assert!(
                    att > 0.85,
                    "flow {} (vm {}) attainment {att:.2}",
                    f.flow,
                    f.vm
                );
            }
        }
    }
    // Storage flows actually used the RAID.
    assert!(report.per_flow[3].kind_is_storage());
}

/// Helper lives on the report side: storage flows report IOPS.
trait KindIsStorage {
    fn kind_is_storage(&self) -> bool;
}
impl KindIsStorage for arcus::system::FlowReport {
    fn kind_is_storage(&self) -> bool {
        self.iops > 0.0
    }
}

#[test]
fn reshape_reacts_to_violation_within_control_periods() {
    // A flow shaped below a suddenly-contended engine recovers via the
    // control loop: compare attainment with a very slow control plane vs
    // the default 100 µs period.
    let line = Rate::gbps(32.0);
    let flows = vec![
        FlowSpec::new(0, 0, Path::FunctionCall, TrafficPattern::fixed(1500, 0.45, line), Slo::gbps(11.0), 0),
        FlowSpec::new(1, 1, Path::FunctionCall, TrafficPattern::fixed(1500, 0.45, line), Slo::gbps(11.0), 0),
    ];
    let mut slow = ExperimentSpec::new(Mode::Arcus, vec![AccelModel::ipsec_32g()], flows.clone())
        .with_duration(6 * MILLIS)
        .with_warmup(MILLIS);
    slow.control_period = 50 * MILLIS; // effectively never ticks
    let fast = ExperimentSpec::new(Mode::Arcus, vec![AccelModel::ipsec_32g()], flows)
        .with_duration(6 * MILLIS)
        .with_warmup(MILLIS);
    let r_slow = run(&slow);
    let r_fast = run(&fast);
    let att = |r: &arcus::system::SystemReport| {
        r.per_flow.iter().map(|f| f.slo_attainment().unwrap()).fold(f64::INFINITY, f64::min)
    };
    // Both should be close here (initial shaping is already right); the
    // fast control plane must never be WORSE, and reconfigs only happen
    // with a live control plane.
    assert!(att(&r_fast) >= att(&r_slow) - 0.02);
    assert!(r_fast.per_flow.iter().map(|f| f.reconfigs).sum::<u32>()
        >= r_slow.per_flow.iter().map(|f| f.reconfigs).sum::<u32>());
}

#[test]
fn deterministic_reports_across_identical_runs() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/latency_critical.toml");
    let doc = Document::from_file(&path).unwrap();
    let mut spec = spec_from_document(&doc).unwrap();
    spec.duration = 3 * MILLIS;
    let a = run(&spec);
    let b = run(&spec);
    for (x, y) in a.per_flow.iter().zip(b.per_flow.iter()) {
        assert_eq!(x.completed, y.completed);
        assert_eq!(x.bytes, y.bytes);
        assert_eq!(x.lat_p999, y.lat_p999);
        assert_eq!(x.dropped, y.dropped);
    }
}
