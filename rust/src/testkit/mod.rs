//! Minimal property-based testing kit.
//!
//! `proptest` is not available in the offline registry, so this module
//! provides the subset we need: seeded generators, a `forall` driver that
//! runs N random cases, and greedy input shrinking for failing cases. It is
//! used by the coordinator/shaping property tests (routing, batching,
//! token-bucket conservation, admission-control soundness).

use crate::util::Rng;

/// A generator of random values of `T` plus a shrinker.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller inputs, most aggressive first. Default: none.
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Uniform u64 in [lo, hi].
pub struct U64Range(pub u64, pub u64);
impl Gen for U64Range {
    type Value = u64;
    fn generate(&self, rng: &mut Rng) -> u64 {
        rng.range_u64(self.0, self.1)
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0); // jump to minimum
            out.push(self.0 + (*v - self.0) / 2); // halve the distance
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Uniform f64 in [lo, hi).
pub struct F64Range(pub f64, pub f64);
impl Gen for F64Range {
    type Value = f64;
    fn generate(&self, rng: &mut Rng) -> f64 {
        rng.range_f64(self.0, self.1)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2.0);
        }
        out
    }
}

/// Vector of values from an element generator with length in [min_len, max_len].
///
/// Shrinking is *recursive*: besides dropping halves and single elements
/// (at every position, not just the tail), each element is shrunk in place
/// through the element generator — which itself may be a combinator
/// ([`PairOf`]/[`TripleOf`]/nested `VecOf`), so minimal counterexamples
/// shrink all the way down the structure.
pub struct VecOf<G: Gen> {
    pub elem: G,
    pub min_len: usize,
    pub max_len: usize,
}
impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Rng) -> Vec<G::Value> {
        let len = rng.range_u64(self.min_len as u64, self.max_len as u64) as usize;
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        // 1. Structural: first half, then each single-element removal
        //    (front removals first: earlier elements often set up state).
        if v.len() > self.min_len {
            let half = (v.len() / 2).max(self.min_len);
            if half < v.len() {
                out.push(v[..half].to_vec());
            }
            for i in 0..v.len() {
                let mut minus_one = v.clone();
                minus_one.remove(i);
                out.push(minus_one);
            }
        }
        // 2. Recursive: shrink each element in place through the element
        //    generator (one position at a time keeps candidates focused).
        for (i, x) in v.iter().enumerate() {
            for smaller in self.elem.shrink(x) {
                let mut copy = v.clone();
                copy[i] = smaller;
                out.push(copy);
            }
        }
        out
    }
}

/// Pair of independent generators.
pub struct PairOf<A: Gen, B: Gen>(pub A, pub B);
impl<A: Gen, B: Gen> Gen for PairOf<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, (a, b): &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(a)
            .into_iter()
            .map(|a2| (a2, b.clone()))
            .collect();
        out.extend(self.1.shrink(b).into_iter().map(|b2| (a.clone(), b2)));
        out
    }
}

/// Triple of independent generators (tuple combinator; composes
/// recursively with [`VecOf`]/[`PairOf`] for structured inputs).
pub struct TripleOf<A: Gen, B: Gen, C: Gen>(pub A, pub B, pub C);
impl<A: Gen, B: Gen, C: Gen> Gen for TripleOf<A, B, C> {
    type Value = (A::Value, B::Value, C::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng), self.2.generate(rng))
    }
    fn shrink(&self, (a, b, c): &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(a)
            .into_iter()
            .map(|a2| (a2, b.clone(), c.clone()))
            .collect();
        out.extend(self.1.shrink(b).into_iter().map(|b2| (a.clone(), b2, c.clone())));
        out.extend(self.2.shrink(c).into_iter().map(|c2| (a.clone(), b.clone(), c2)));
        out
    }
}

/// Choose uniformly from a fixed set of values.
pub struct OneOf<T: Clone + std::fmt::Debug>(pub Vec<T>);
impl<T: Clone + std::fmt::Debug> Gen for OneOf<T> {
    type Value = T;
    fn generate(&self, rng: &mut Rng) -> T {
        self.0[rng.below(self.0.len() as u64) as usize].clone()
    }
}

/// Configuration for a property run.
pub struct Config {
    pub cases: u32,
    pub seed: u64,
    pub max_shrink_steps: u32,
}

/// Resolve the default case count from an `ARCUS_PROPTEST_CASES`-style
/// value (e.g. a nightly CI lane exports 10x the default). Zero or garbage
/// falls back to the built-in 256. Pure so it is testable without mutating
/// the process environment (which would race concurrently running tests).
pub fn cases_from_env(value: Option<String>) -> u32 {
    value
        .and_then(|s| s.parse().ok())
        .filter(|&c| c > 0)
        .unwrap_or(256)
}

impl Default for Config {
    fn default() -> Self {
        // Seed is fixed for reproducibility; override via ARCUS_PROP_SEED.
        let seed = std::env::var("ARCUS_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xA5C5_2024);
        // Case count scales via ARCUS_PROPTEST_CASES. Properties that pass
        // an explicit count keep it; the env only moves the default.
        Config {
            cases: cases_from_env(std::env::var("ARCUS_PROPTEST_CASES").ok()),
            seed,
            max_shrink_steps: 500,
        }
    }
}

/// Run `prop` on `cfg.cases` random inputs; on failure, shrink greedily and
/// panic with the minimal failing input and the seed to reproduce.
pub fn forall<G, F>(gen: &G, prop: F)
where
    G: Gen,
    F: FnMut(&G::Value) -> bool,
{
    forall_cfg(&Config::default(), gen, prop)
}

/// Like [`forall`] with explicit configuration.
pub fn forall_cfg<G, F>(cfg: &Config, gen: &G, mut prop: F)
where
    G: Gen,
    F: FnMut(&G::Value) -> bool,
{
    for case in 0..cfg.cases {
        let mut rng = Rng::for_stream(cfg.seed, case as u64);
        let input = gen.generate(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(cfg, gen, &mut prop, input);
            panic!(
                "property failed (seed={:#x}, case={case}); minimal input: {minimal:?}",
                cfg.seed
            );
        }
    }
}

fn shrink_loop<G, F>(cfg: &Config, gen: &G, prop: &mut F, mut failing: G::Value) -> G::Value
where
    G: Gen,
    F: FnMut(&G::Value) -> bool,
{
    let mut steps = 0;
    'outer: while steps < cfg.max_shrink_steps {
        for candidate in gen.shrink(&failing) {
            steps += 1;
            if !prop(&candidate) {
                failing = candidate;
                continue 'outer;
            }
            if steps >= cfg.max_shrink_steps {
                break;
            }
        }
        break; // no candidate failed: local minimum
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(&U64Range(0, 1000), |&x| x <= 1000);
    }

    #[test]
    fn failing_property_shrinks_to_boundary() {
        let result = std::panic::catch_unwind(|| {
            forall(&U64Range(0, 1_000_000), |&x| x < 500_000);
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().expect("panic payload"),
            Ok(()) => panic!("property should have failed"),
        };
        // Greedy halving from any failing point lands near the boundary.
        assert!(msg.contains("minimal input"), "msg={msg}");
        let num: u64 = msg
            .rsplit(": ")
            .next()
            .unwrap()
            .trim()
            .parse()
            .expect("numeric minimal input");
        assert!(num >= 500_000 && num < 1_000_000, "shrunk to {num}");
        assert!(num < 800_000, "should have shrunk substantially: {num}");
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let g = VecOf {
            elem: U64Range(1, 9),
            min_len: 2,
            max_len: 6,
        };
        forall(&g, |v| {
            v.len() >= 2 && v.len() <= 6 && v.iter().all(|&x| (1..=9).contains(&x))
        });
    }

    #[test]
    fn pair_gen_generates_both() {
        let g = PairOf(U64Range(0, 10), F64Range(0.5, 1.5));
        forall(&g, |&(a, b)| a <= 10 && (0.5..1.5).contains(&b));
    }

    #[test]
    fn triple_gen_generates_and_shrinks_componentwise() {
        let g = TripleOf(U64Range(0, 10), F64Range(0.5, 1.5), U64Range(3, 9));
        forall(&g, |&(a, b, c)| a <= 10 && (0.5..1.5).contains(&b) && (3..=9).contains(&c));
        let shrinks = g.shrink(&(10, 1.4, 9));
        assert!(shrinks.iter().any(|&(a, _, _)| a < 10));
        assert!(shrinks.iter().any(|&(_, b, _)| b < 1.4));
        assert!(shrinks.iter().any(|&(_, _, c)| c < 9));
    }

    #[test]
    fn vec_shrink_is_recursive_and_positional() {
        // A failing property over vectors of pairs must shrink to the
        // minimal structure: one element, first component at the failure
        // boundary, second at its generator minimum — exercising element
        // removal at any position AND recursive element shrinking.
        let g = VecOf {
            elem: PairOf(U64Range(0, 1000), U64Range(5, 50)),
            min_len: 1,
            max_len: 8,
        };
        let result = std::panic::catch_unwind(|| {
            forall_cfg(
                &Config { cases: 64, max_shrink_steps: 5000, ..Default::default() },
                &g,
                |v| v.iter().all(|&(a, _)| a < 100),
            );
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().expect("panic payload"),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(
            msg.contains("[(100, 5)]"),
            "expected fully-shrunk minimal input, got: {msg}"
        );
    }

    #[test]
    fn proptest_cases_resolution() {
        // Tested through the pure helper — mutating the real environment
        // would race sibling tests reading Config::default() concurrently.
        assert_eq!(cases_from_env(Some("7".into())), 7);
        assert_eq!(cases_from_env(Some("2560".into())), 2560);
        // Zero, garbage, or absence falls back to the built-in default.
        assert_eq!(cases_from_env(Some("0".into())), 256);
        assert_eq!(cases_from_env(Some("lots".into())), 256);
        assert_eq!(cases_from_env(None), 256);
    }

    #[test]
    fn one_of_only_choices() {
        let g = OneOf(vec![64u64, 256, 1500, 4096]);
        forall(&g, |&x| [64, 256, 1500, 4096].contains(&x));
    }

    #[test]
    fn reproducible_given_same_seed() {
        let cfg = Config {
            cases: 16,
            seed: 1234,
            max_shrink_steps: 10,
        };
        let g = U64Range(0, u64::MAX);
        let mut first = Vec::new();
        forall_cfg(&cfg, &g, |&x| {
            first.push(x);
            true
        });
        let mut second = Vec::new();
        forall_cfg(&cfg, &g, |&x| {
            second.push(x);
            true
        });
        assert_eq!(first, second);
    }
}
