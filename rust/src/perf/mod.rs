//! The `arcus bench` performance pipeline.
//!
//! Seeds and maintains the repo's perf trajectory: scenario presets
//! (small / medium / large / xlarge) run on the three event-queue
//! disciplines (reference heap, flat calendar, hierarchical wheel), measuring
//! **events/sec**, **wall-clock per simulated millisecond**, and **peak
//! event-queue depth**, emitted as machine-readable `BENCH_<name>.json`.
//! CI's `perf-smoke` job runs the quick variant and gates merges on a
//! committed events/sec floor (`rust/configs/perf_floor.toml`, set with
//! generous slack so runner jitter never flakes).
//!
//! JSON schema (one object per preset × queue):
//!
//! ```json
//! {
//!   "scenario": "large",
//!   "queue": "calendar",
//!   "events_executed": 123456789,
//!   "events_per_sec": 15200000.0,
//!   "wall_ms": 8120.5,
//!   "sim_ms": 50.0,
//!   "wall_ms_per_sim_ms": 162.4,
//!   "peak_queue_depth": 412,
//!   "rss_hint_kb": 24576,
//!   "allocs_per_event": 0.012
//! }
//! ```
//!
//! `rss_hint_kb` is the process-lifetime `VmHWM` sampled after the run —
//! monotone across entries of one invocation (see [`rss_hint_kb`]); run a
//! single preset × queue per invocation to isolate a scenario's footprint.
//! `allocs_per_event` is 0.0 unless the binary was built with
//! `--features bench-alloc` (the counting allocator, [`alloc`]); when
//! measured it gates against `[floor] max_allocs_per_event`.

pub mod alloc;

use crate::accel::AccelModel;
use crate::flow::{FlowSpec, Path, Slo, TrafficPattern};
use crate::sim::{BinaryHeapQueue, CalendarQueue, HierWheel};
use crate::system::{run_with, EngineEvent, ExperimentSpec, Mode};
use crate::util::units::{Rate, MILLIS};

/// One bench scenario preset.
#[derive(Debug, Clone, Copy)]
pub struct Preset {
    pub name: &'static str,
    /// Tenant VMs the flows are grouped under.
    pub tenants: usize,
    /// Flows in total, spread round-robin across VMs and accelerators.
    pub flows: usize,
    /// IPSec engines on the device (32 Gbps class each).
    pub accels: usize,
    pub duration_ms: u64,
    pub warmup_ms: u64,
    /// Run the hierarchical shaper tree (the 10k-flow scale presets; flat
    /// per-flow buckets otherwise).
    pub hierarchy: bool,
    /// Fleet size: 1 runs the plain single-world engine; > 1 shards the
    /// roster over [`crate::fleet::FleetPlane`] hosts (one advance thread
    /// per host) with the default directive-distribution config.
    pub hosts: usize,
    /// Population size: 0 runs the per-flow pattern generators; > 0 drives
    /// every flow from the user-population workload layer
    /// ([`crate::workload::PopulationConfig`] with default shape knobs) and
    /// grows per-user fairness accounting in the report.
    pub population: usize,
}

/// The committed presets. Tenancy and duration scale together so the
/// large preset reaches the millions-of-events regime the multi-tenant
/// sweeps (PR 1/2) need; `xlarge` is the 10,000-flow scale point the
/// shaper hierarchy exists for — its whole roster shares eight trees, so
/// the event queue stays shallow no matter how many flows block. `fleet`
/// shards a 64-flow roster over four fleet hosts (one advance thread
/// each) to size the per-barrier interchange overhead of the
/// distribution tier. `population` multiplexes 100,000 users onto a
/// 64-flow roster through the heavy-tailed workload generator — the
/// scale point for the flyweight per-user state (O(users × few words)
/// memory, no per-arrival allocation).
pub const PRESETS: [Preset; 6] = [
    Preset {
        name: "small",
        tenants: 2,
        flows: 2,
        accels: 1,
        duration_ms: 5,
        warmup_ms: 1,
        hierarchy: false,
        hosts: 1,
        population: 0,
    },
    Preset {
        name: "medium",
        tenants: 4,
        flows: 4,
        accels: 2,
        duration_ms: 20,
        warmup_ms: 2,
        hierarchy: false,
        hosts: 1,
        population: 0,
    },
    Preset {
        name: "large",
        tenants: 8,
        flows: 8,
        accels: 4,
        duration_ms: 50,
        warmup_ms: 5,
        hierarchy: false,
        hosts: 1,
        population: 0,
    },
    Preset {
        name: "xlarge",
        tenants: 64,
        flows: 10_000,
        accels: 8,
        duration_ms: 3,
        warmup_ms: 1,
        hierarchy: true,
        hosts: 1,
        population: 0,
    },
    Preset {
        name: "fleet",
        tenants: 8,
        flows: 64,
        accels: 2,
        duration_ms: 10,
        warmup_ms: 2,
        hierarchy: true,
        hosts: 4,
        population: 0,
    },
    Preset {
        name: "population",
        tenants: 8,
        flows: 64,
        accels: 2,
        duration_ms: 10,
        warmup_ms: 2,
        hierarchy: true,
        hosts: 1,
        population: 100_000,
    },
];

pub fn preset_by_name(name: &str) -> Option<Preset> {
    PRESETS.iter().copied().find(|p| p.name == name)
}

/// Event-queue discipline selector for a bench run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueKind {
    Heap,
    Calendar,
    Wheel,
}

impl QueueKind {
    pub fn name(self) -> &'static str {
        match self {
            QueueKind::Heap => "binary_heap",
            QueueKind::Calendar => "calendar",
            QueueKind::Wheel => "hier_wheel",
        }
    }

    pub fn parse(s: &str) -> Result<Vec<QueueKind>, String> {
        match s {
            "heap" => Ok(vec![QueueKind::Heap]),
            "calendar" => Ok(vec![QueueKind::Calendar]),
            "wheel" | "hier_wheel" => Ok(vec![QueueKind::Wheel]),
            // `both` predates the hierarchical wheel; kept for scripts.
            "both" => Ok(vec![QueueKind::Heap, QueueKind::Calendar]),
            "all" => Ok(vec![QueueKind::Heap, QueueKind::Calendar, QueueKind::Wheel]),
            other => Err(format!(
                "unknown queue `{other}` (valid: heap, calendar, wheel, both, all)"
            )),
        }
    }
}

/// The experiment a preset describes: an oversubscribed multi-tenant
/// function-call workload — every flow's shaper is active (token-bucket
/// wakeups dominate the event mix, the distribution the calendar queue is
/// tuned for), and every completion crosses the PCIe fabric model.
pub fn spec_for(p: &Preset) -> ExperimentSpec {
    let line = Rate::gbps(32.0);
    let per_accel = p.flows.div_ceil(p.accels);
    // ~24.6 G admission budget per engine at MTU: stay safely under it so
    // every flow admits, while offering ~40% more than the SLO so the
    // shaper is always the binding constraint.
    let slo_gbps = 20.0 / per_accel as f64;
    let load = (slo_gbps * 1.4 / 32.0).min(0.95);
    let flows: Vec<FlowSpec> = (0..p.flows)
        .map(|i| {
            FlowSpec::new(
                i,
                i % p.tenants,
                Path::FunctionCall,
                TrafficPattern::fixed(1500, load, line),
                Slo::gbps(slo_gbps),
                i % p.accels,
            )
        })
        .collect();
    let accels = (0..p.accels).map(|_| AccelModel::ipsec_32g()).collect();
    let mut spec = ExperimentSpec::new(Mode::Arcus, accels, flows)
        .with_duration(p.duration_ms * MILLIS)
        .with_warmup(p.warmup_ms * MILLIS);
    if p.hierarchy {
        spec = spec.with_hierarchy();
    }
    if p.population > 0 {
        spec = spec.with_population(crate::workload::PopulationConfig {
            users: p.population,
            ..Default::default()
        });
    }
    spec
}

/// One measured bench outcome.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub scenario: String,
    pub queue: &'static str,
    pub events_executed: u64,
    pub events_per_sec: f64,
    pub wall_ms: f64,
    pub sim_ms: f64,
    pub peak_queue_depth: usize,
    pub rss_hint_kb: u64,
    /// Heap allocations (+ reallocs) per executed event; 0.0 when the
    /// counting allocator is not installed (`bench-alloc` feature off).
    pub allocs_per_event: f64,
}

impl BenchResult {
    /// Wall milliseconds per simulated millisecond (lower is better).
    pub fn wall_ms_per_sim_ms(&self) -> f64 {
        if self.sim_ms <= 0.0 {
            0.0
        } else {
            self.wall_ms / self.sim_ms
        }
    }

    pub fn to_json(&self) -> String {
        format!(
            "{{\"scenario\":\"{}\",\"queue\":\"{}\",\"events_executed\":{},\
             \"events_per_sec\":{:.1},\"wall_ms\":{:.3},\"sim_ms\":{:.3},\
             \"wall_ms_per_sim_ms\":{:.3},\"peak_queue_depth\":{},\"rss_hint_kb\":{},\
             \"allocs_per_event\":{:.4}}}",
            json_escape(&self.scenario),
            json_escape(self.queue),
            self.events_executed,
            self.events_per_sec,
            self.wall_ms,
            self.sim_ms,
            self.wall_ms_per_sim_ms(),
            self.peak_queue_depth,
            self.rss_hint_kb,
            self.allocs_per_event,
        )
    }
}

/// Escape a string for embedding in a JSON string literal. The bench
/// pipeline interpolates scenario/queue labels into `BENCH_*.json`; a
/// label containing `"` or `\` (or a control character) must not emit
/// invalid JSON.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Render a result list as a JSON array (the `BENCH_*.json` payload).
pub fn to_json(results: &[BenchResult]) -> String {
    let mut out = String::from("[\n");
    for (i, r) in results.iter().enumerate() {
        out.push_str("  ");
        out.push_str(&r.to_json());
        if i + 1 < results.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("]\n");
    out
}

/// Measure one report-producing run under a `scenario` label — the shared
/// substrate behind the preset runs, the fleet preset, and the adaptive
/// profile.
fn measure_run(
    scenario: &str,
    sim_ms: u64,
    run: impl FnOnce() -> crate::system::SystemReport,
) -> (BenchResult, crate::system::SystemReport) {
    let a0 = alloc::alloc_count();
    let report = run();
    let allocs = alloc::alloc_count().saturating_sub(a0);
    let result = BenchResult {
        scenario: scenario.to_string(),
        queue: report.queue,
        events_executed: report.events,
        events_per_sec: report.events_per_sec(),
        wall_ms: report.wall_secs * 1e3,
        sim_ms: sim_ms as f64,
        peak_queue_depth: report.peak_queue_depth,
        rss_hint_kb: rss_hint_kb(),
        allocs_per_event: if report.events > 0 {
            allocs as f64 / report.events as f64
        } else {
            0.0
        },
    };
    (result, report)
}

/// Measure one spec on one queue discipline under a `scenario` label.
fn measure(
    scenario: &str,
    sim_ms: u64,
    spec: &ExperimentSpec,
    queue: QueueKind,
) -> (BenchResult, crate::system::SystemReport) {
    measure_run(scenario, sim_ms, || match queue {
        QueueKind::Heap => run_with::<BinaryHeapQueue<EngineEvent>>(spec),
        QueueKind::Calendar => run_with::<CalendarQueue<EngineEvent>>(spec),
        QueueKind::Wheel => run_with::<HierWheel<EngineEvent>>(spec),
    })
}

/// Run one preset on one queue discipline, returning the measurement and
/// the full report (whose [`crate::system::SystemReport::canonical`] form
/// backs `arcus bench --verify`'s cross-queue byte-identity check).
/// Presets with `hosts > 1` run the fleet tier (one advance thread per
/// host); `events_per_sec` then measures aggregate fleet throughput.
pub fn run_preset_report(
    p: &Preset,
    queue: QueueKind,
) -> (BenchResult, crate::system::SystemReport) {
    let spec = spec_for(p);
    if p.hosts > 1 {
        let cfg = crate::fleet::FleetConfig { hosts: p.hosts, ..Default::default() };
        return measure_run(p.name, p.duration_ms, || match queue {
            QueueKind::Heap => {
                crate::fleet::run_with::<BinaryHeapQueue<EngineEvent>>(&spec, &cfg)
            }
            QueueKind::Calendar => {
                crate::fleet::run_with::<CalendarQueue<EngineEvent>>(&spec, &cfg)
            }
            QueueKind::Wheel => crate::fleet::run_with::<HierWheel<EngineEvent>>(&spec, &cfg),
        });
    }
    measure(p.name, p.duration_ms, &spec, queue)
}

/// Run one preset on one queue discipline.
pub fn run_preset(p: &Preset, queue: QueueKind) -> BenchResult {
    run_preset_report(p, queue).0
}

/// The preset backing the closed-loop overhead profile: `medium` is the
/// smallest preset whose event count makes a back-to-back throughput
/// ratio stable on shared CI runners.
pub const ADAPTIVE_PROFILE_PRESET: &str = "medium";

/// The closed-loop overhead profile: the [`ADAPTIVE_PROFILE_PRESET`]
/// scenario run twice on the reference heap — once under the static
/// planner (`adaptive_off`), once wrapped in the adaptive control plane
/// (`adaptive_on`). The pair backs the `min_adaptive_ev_ratio` gate: the
/// per-tick AIMD bookkeeping must not tax event throughput by more than
/// the committed fraction.
pub fn run_adaptive_profile() -> (BenchResult, BenchResult) {
    let p = preset_by_name(ADAPTIVE_PROFILE_PRESET).expect("committed preset");
    let st = measure("adaptive_off", p.duration_ms, &spec_for(&p), QueueKind::Heap).0;
    let spec = spec_for(&p).with_adaptive(crate::api::AdaptiveConfig::default());
    let ad = measure("adaptive_on", p.duration_ms, &spec, QueueKind::Heap).0;
    (st, ad)
}

/// Peak resident-set hint in KiB (`VmHWM` on Linux; 0 where unavailable).
///
/// `VmHWM` is the *process-lifetime* high-water mark: it is monotone
/// across the presets a single `arcus bench` invocation runs, so within
/// one run only the first entry (and single-preset invocations like
/// `bench --preset large --queue calendar`) isolates a scenario's own
/// footprint. It is a hint for cross-commit trajectory, not a
/// per-scenario measurement — hence the name.
pub fn rss_hint_kb() -> u64 {
    if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                let digits: String =
                    rest.chars().filter(|c| c.is_ascii_digit()).collect();
                if let Ok(kb) = digits.parse() {
                    return kb;
                }
            }
        }
    }
    0
}

/// Read the committed events/sec floor from a `perf_floor.toml`
/// (`[floor] min_events_per_sec = ...`).
pub fn load_floor(path: &std::path::Path) -> anyhow::Result<f64> {
    let doc = crate::config::Document::from_file(path)?;
    doc.get("floor", "min_events_per_sec")
        .and_then(crate::config::Value::as_float)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "{}: missing `min_events_per_sec` under [floor]",
                path.display()
            )
        })
}

/// Per-preset floor: `min_events_per_sec_<preset>` when committed (the
/// 10k-flow `xlarge` scenario has a different per-event cost profile than
/// the flat presets), falling back to the shared `min_events_per_sec`.
pub fn load_floor_for(path: &std::path::Path, preset: &str) -> anyhow::Result<f64> {
    let doc = crate::config::Document::from_file(path)?;
    let specific = format!("min_events_per_sec_{preset}");
    if let Some(f) = doc.get("floor", &specific).and_then(crate::config::Value::as_float) {
        return Ok(f);
    }
    load_floor(path)
}

/// Optional allocation-count ceiling: `[floor] max_allocs_per_event`.
/// `None` when the file commits no ceiling; the gate additionally skips
/// results whose `allocs_per_event` is 0.0 (counting allocator absent).
pub fn load_alloc_ceiling(path: &std::path::Path) -> anyhow::Result<Option<f64>> {
    let doc = crate::config::Document::from_file(path)?;
    Ok(doc
        .get("floor", "max_allocs_per_event")
        .and_then(crate::config::Value::as_float))
}

/// Optional closed-loop throughput gate: `[floor] min_adaptive_ev_ratio`.
/// When committed, `arcus bench --floor` runs [`run_adaptive_profile`]
/// and fails if the adaptive run's events/sec falls below this fraction
/// of the static run's. `None` when the file commits no ratio.
pub fn load_adaptive_ratio(path: &std::path::Path) -> anyhow::Result<Option<f64>> {
    let doc = crate::config::Document::from_file(path)?;
    Ok(doc
        .get("floor", "min_adaptive_ev_ratio")
        .and_then(crate::config::Value::as_float))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_admissible_and_distinct() {
        let mut seen = std::collections::HashSet::new();
        for p in &PRESETS {
            assert!(seen.insert(p.name), "duplicate preset {}", p.name);
            let spec = spec_for(p);
            assert_eq!(spec.flows.len(), p.flows);
            assert_eq!(spec.accels.len(), p.accels);
            assert_eq!(spec.hierarchy, p.hierarchy);
            assert!(spec.warmup < spec.duration);
            // SLO sum per engine stays under the ~24.6 G admission budget
            // so every flow admits, at 2 flows and at 10,000 alike.
            let per_accel = p.flows.div_ceil(p.accels);
            let slo_sum = match spec.flows[0].slo {
                crate::flow::Slo::Throughput { target, .. } => {
                    target.as_gbps() * per_accel as f64
                }
                _ => panic!("presets carry throughput SLOs"),
            };
            assert!(slo_sum < 24.6, "{}: {slo_sum:.1} G committed per engine", p.name);
            assert!(p.hosts >= 1, "{}: zero hosts", p.name);
        }
        assert!(preset_by_name("large").is_some());
        assert!(preset_by_name("xlarge").is_some());
        assert_eq!(preset_by_name("xlarge").unwrap().flows, 10_000);
        assert!(preset_by_name("nope").is_none());
        // The fleet preset shards tenants evenly across its hosts, so every
        // host carries the same roster shape (stable per-host throughput).
        let fleet = preset_by_name("fleet").unwrap();
        assert!(fleet.hosts > 1);
        assert_eq!(fleet.tenants % fleet.hosts, 0);
        assert_eq!(fleet.flows % fleet.tenants, 0);
        // The population preset is the 100k-user scale point and stays on
        // the single-world engine (per-user accounting is per-world).
        let pop = preset_by_name("population").unwrap();
        assert_eq!(pop.population, 100_000);
        assert_eq!(pop.hosts, 1);
        assert!(spec_for(&pop).population.is_some());
        assert!(pop.population >= pop.flows, "every flow needs a home user");
    }

    #[test]
    fn population_preset_runs_the_population_generator() {
        // A shortened clone of the committed preset: same roster and
        // population, small duration so the test stays test-suite sized.
        let p = Preset { duration_ms: 2, warmup_ms: 1, ..preset_by_name("population").unwrap() };
        let (r, report) = run_preset_report(&p, QueueKind::Heap);
        assert_eq!(r.scenario, "population");
        assert!(r.events_executed > 10_000, "events {}", r.events_executed);
        // Fairness metrics are the proof the run went through the
        // population layer rather than the pattern generators.
        let fr = report.fairness.expect("population runs carry fairness metrics");
        assert_eq!(fr.users, 100_000);
        assert!(fr.active_users > 0);
        assert!(fr.jain_ppm > 0 && fr.jain_ppm <= 1_000_000);
    }

    #[test]
    fn fleet_preset_runs_the_fleet_tier() {
        let p = preset_by_name("fleet").unwrap();
        let (r, report) = run_preset_report(&p, QueueKind::Heap);
        assert_eq!(r.scenario, "fleet");
        assert_eq!(r.queue, "binary_heap");
        assert!(r.events_executed > 10_000, "events {}", r.events_executed);
        assert!((r.sim_ms - p.duration_ms as f64).abs() < 1e-9);
        // The merged report carries one rollup per host — proof the run
        // actually went through the fleet tier.
        assert_eq!(report.host_rollups.len(), p.hosts);
    }

    #[test]
    fn small_preset_runs_and_reports_on_every_queue() {
        let p = preset_by_name("small").unwrap();
        for q in [QueueKind::Heap, QueueKind::Calendar, QueueKind::Wheel] {
            let r = run_preset(&p, q);
            assert_eq!(r.scenario, "small");
            assert_eq!(r.queue, q.name());
            assert!(r.events_executed > 10_000, "events {}", r.events_executed);
            assert!(r.peak_queue_depth > 0);
            assert!((r.sim_ms - 5.0).abs() < 1e-9);
        }
    }

    #[test]
    fn json_schema_has_required_keys() {
        let r = BenchResult {
            scenario: "small".into(),
            queue: "binary_heap",
            events_executed: 42,
            events_per_sec: 1e6,
            wall_ms: 1.5,
            sim_ms: 5.0,
            peak_queue_depth: 7,
            rss_hint_kb: 1024,
            allocs_per_event: 0.25,
        };
        let js = to_json(&[r]);
        for key in [
            "\"scenario\"",
            "\"queue\"",
            "\"events_executed\"",
            "\"events_per_sec\"",
            "\"wall_ms\"",
            "\"sim_ms\"",
            "\"wall_ms_per_sim_ms\"",
            "\"peak_queue_depth\"",
            "\"rss_hint_kb\"",
            "\"allocs_per_event\"",
        ] {
            assert!(js.contains(key), "missing {key} in {js}");
        }
        assert!(js.trim_start().starts_with('['));
        assert!(js.trim_end().ends_with(']'));
    }

    #[test]
    fn queue_kind_parse_menu() {
        assert_eq!(QueueKind::parse("heap").unwrap(), vec![QueueKind::Heap]);
        assert_eq!(QueueKind::parse("wheel").unwrap(), vec![QueueKind::Wheel]);
        assert_eq!(
            QueueKind::parse("both").unwrap(),
            vec![QueueKind::Heap, QueueKind::Calendar]
        );
        assert_eq!(
            QueueKind::parse("all").unwrap(),
            vec![QueueKind::Heap, QueueKind::Calendar, QueueKind::Wheel]
        );
        let err = QueueKind::parse("fifo").unwrap_err();
        assert!(err.contains("wheel"), "{err}");
    }

    #[test]
    fn json_escapes_hostile_string_fields() {
        let r = BenchResult {
            scenario: "sm\"all\\x\n".into(),
            queue: "binary_heap",
            events_executed: 1,
            events_per_sec: 1.0,
            wall_ms: 1.0,
            sim_ms: 1.0,
            peak_queue_depth: 1,
            rss_hint_kb: 0,
            allocs_per_event: 0.0,
        };
        let js = r.to_json();
        assert!(
            js.contains("\"scenario\":\"sm\\\"all\\\\x\\n\""),
            "unescaped payload: {js}"
        );
        // No raw control characters may survive into the payload.
        assert!(!js.chars().any(|c| (c as u32) < 0x20));
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\tb"), "a\\tb");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn floor_file_parses() {
        let dir = std::env::temp_dir().join("arcus_floor_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("perf_floor.toml");
        std::fs::write(&path, "[floor]\nmin_events_per_sec = 250000\n").unwrap();
        let floor = load_floor(&path).unwrap();
        assert!((floor - 250_000.0).abs() < 1e-9);
        // No ceiling / ratio committed → None, not an error.
        assert_eq!(load_alloc_ceiling(&path).unwrap(), None);
        assert_eq!(load_adaptive_ratio(&path).unwrap(), None);
        std::fs::write(
            &path,
            "[floor]\nmin_events_per_sec = 250000\nmax_allocs_per_event = 0.5\n\
             min_adaptive_ev_ratio = 0.9\n",
        )
        .unwrap();
        assert_eq!(load_alloc_ceiling(&path).unwrap(), Some(0.5));
        assert_eq!(load_adaptive_ratio(&path).unwrap(), Some(0.9));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn adaptive_profile_measures_both_control_loops() {
        let (st, ad) = run_adaptive_profile();
        assert_eq!(st.scenario, "adaptive_off");
        assert_eq!(ad.scenario, "adaptive_on");
        assert_eq!(st.queue, "binary_heap");
        assert_eq!(ad.queue, "binary_heap");
        assert!(st.events_executed > 10_000, "static events {}", st.events_executed);
        assert!(ad.events_executed > 10_000, "adaptive events {}", ad.events_executed);
        assert!(st.events_per_sec > 0.0 && ad.events_per_sec > 0.0);
    }
}
