//! Counting global allocator for the allocation-count regression gate.
//!
//! Built unconditionally so [`alloc_count`] always links, but only
//! *installed* as the global allocator when the binary is compiled with
//! `--features bench-alloc` (see `main.rs`): without the install the
//! counter stays 0 and `allocs_per_event` reports 0.0 ("unmeasured"),
//! which the floor gate skips. The counter is a single relaxed atomic
//! increment per alloc/realloc — cheap enough to leave on for a bench
//! run, but not free, which is why the hot-path events/sec floors are
//! gated on the un-instrumented build.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Forwards to the system allocator, counting allocations and
/// reallocations (frees are not counted: the gate tracks allocation
/// pressure, and every alloc eventually pairs with a free).
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

/// Total allocations + reallocations since process start. Always 0 unless
/// [`CountingAlloc`] is installed as the `#[global_allocator]`.
pub fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Without the feature the allocator is not installed, so the only
    // contract testable here is monotonicity of the raw counter.
    #[test]
    fn counter_is_monotone() {
        let a = alloc_count();
        ALLOCS.fetch_add(3, Ordering::Relaxed);
        assert_eq!(alloc_count(), a + 3);
    }
}
