//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! Rust serving path.
//!
//! Python runs once (`make artifacts`); this module loads the HLO **text**
//! each artifact was lowered to (`HloModuleProto::from_text_file` — the
//! text parser reassigns the 64-bit instruction ids jax ≥ 0.5 emits, which
//! xla_extension 0.5.1's proto path rejects), compiles one executable per
//! (entry point, batch shape) on the PJRT CPU client, and exposes typed
//! call wrappers. The serving hot path never touches Python.
//!
//! Payload layout matches the kernels: a message is padded to 64 B blocks
//! and viewed as `blocks × 16` little-endian u32 words.

pub mod manifest;

pub use manifest::{ArtifactKind, Manifest, ManifestEntry};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

/// A 64 B digest as 16 u32 lanes.
pub type Digest = [u32; 16];

/// PJRT executables for the artifacts in a directory, compiled lazily on
/// first use (XLA compilation of the unrolled cipher takes seconds per
/// batch shape; a serving process usually touches only a few shapes).
///
/// `PjRtClient` is `!Send` (PJRT handles are thread-affine in the `xla`
/// crate), so a runtime lives on ONE thread — the server runs a dedicated
/// engine thread that owns it and feeds it through channels
/// (`crate::server::engine`).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    exes: RefCell<HashMap<(ArtifactKind, usize, usize), Rc<xla::PjRtLoadedExecutable>>>,
    manifest: Manifest,
}

impl PjrtRuntime {
    /// Open the artifact directory (expects `manifest.txt`). Compilation is
    /// deferred to first use per (entry, batch); use [`Self::precompile`]
    /// to front-load it.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtRuntime { client, exes: RefCell::new(HashMap::new()), manifest })
    }

    /// Compile every artifact now (server startup).
    pub fn precompile(&self) -> Result<()> {
        let entries = self.manifest.entries.clone();
        for e in &entries {
            let _ = self.exe(e.kind, e.group, e.batch)?;
        }
        Ok(())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of compiled executables so far.
    pub fn n_executables(&self) -> usize {
        self.exes.borrow().len()
    }

    fn exe(
        &self,
        kind: ArtifactKind,
        group: usize,
        batch: usize,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(&(kind, group, batch)) {
            return Ok(e.clone());
        }
        let entry = self
            .manifest
            .entries
            .iter()
            .find(|e| e.kind == kind && e.group == group && e.batch == batch)
            .with_context(|| {
                format!("no artifact for {} group {group} batch {batch}", kind.name())
            })?;
        let proto = xla::HloModuleProto::from_text_file(
            entry.path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing {}", entry.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", entry.name))?,
        );
        self.exes.borrow_mut().insert((kind, group, batch), exe.clone());
        Ok(exe)
    }

    /// Largest compiled batch for a kind (per-call block capacity).
    pub fn max_batch(&self, kind: ArtifactKind) -> usize {
        self.manifest.batches(kind).last().copied().unwrap_or(0)
    }

    /// Pad `payload` (blocks × 16 words) up to `batch` rows of zeros.
    fn pad(payload: &[u32], batch: usize) -> Vec<u32> {
        debug_assert_eq!(payload.len() % 16, 0);
        let mut v = Vec::with_capacity(batch * 16);
        v.extend_from_slice(payload);
        v.resize(batch * 16, 0);
        v
    }

    /// Encrypt `payload` (len = 16·blocks) and MAC the ciphertext.
    ///
    /// Returns the ciphertext (same length) and the 64 B tag computed over
    /// the *padded* batch (callers must use the same block count to verify).
    /// Counter-mode involution: calling this again on the ciphertext with
    /// the same key/nonce/counter returns the plaintext.
    pub fn encrypt_digest(
        &self,
        payload: &[u32],
        key: &[u32; 8],
        nonce: &[u32; 3],
        counter0: u32,
    ) -> Result<(Vec<u32>, Digest)> {
        let blocks = payload.len() / 16;
        let batch = self
            .manifest
            .pick_batch(ArtifactKind::EncryptDigest, blocks)
            .context("no encrypt_digest artifacts")?;
        anyhow::ensure!(
            blocks <= batch,
            "payload of {blocks} blocks exceeds the largest compiled batch {batch}"
        );
        let exe = self.exe(ArtifactKind::EncryptDigest, 1, batch)?;
        let padded = Self::pad(payload, batch);
        let counters: Vec<u32> = (0..batch as u32).map(|i| counter0.wrapping_add(i)).collect();

        let p = xla::Literal::vec1(&padded).reshape(&[batch as i64, 16])?;
        let k = xla::Literal::vec1(&key[..]);
        let n = xla::Literal::vec1(&nonce[..]);
        let c = xla::Literal::vec1(&counters);
        let result = exe.execute::<xla::Literal>(&[p, k, n, c])?[0][0].to_literal_sync()?;
        let (cipher_lit, tag_lit) = result.to_tuple2()?;
        let mut cipher = cipher_lit.to_vec::<u32>()?;
        cipher.truncate(blocks * 16);
        let tag_v = tag_lit.to_vec::<u32>()?;
        let mut tag = [0u32; 16];
        tag.copy_from_slice(&tag_v);
        Ok((cipher, tag))
    }

    /// Keyed 64 B digest of `payload` (len = 16·blocks).
    pub fn digest(&self, payload: &[u32], key: &[u32; 8]) -> Result<Digest> {
        let blocks = payload.len() / 16;
        let batch = self
            .manifest
            .pick_batch(ArtifactKind::DigestOnly, blocks)
            .context("no digest artifacts")?;
        anyhow::ensure!(
            blocks <= batch,
            "payload of {blocks} blocks exceeds the largest compiled batch {batch}"
        );
        let exe = self.exe(ArtifactKind::DigestOnly, 1, batch)?;
        let padded = Self::pad(payload, batch);
        let p = xla::Literal::vec1(&padded).reshape(&[batch as i64, 16])?;
        let k = xla::Literal::vec1(&key[..]);
        let result = exe.execute::<xla::Literal>(&[p, k])?[0][0].to_literal_sync()?;
        let tag_lit = result.to_tuple1()?;
        let tag_v = tag_lit.to_vec::<u32>()?;
        let mut tag = [0u32; 16];
        tag.copy_from_slice(&tag_v);
        Ok(tag)
    }

    /// Fletcher checksum `(s1, s2)` of `payload` (len = 16·blocks).
    ///
    /// Payloads larger than the biggest compiled batch are chunked and the
    /// partial sums combined exactly (see `combine` below): with chunk
    /// weights `W_b - g` and the chunk placed at word offset `o` in a
    /// message of `N` words, the global weight is
    /// `(N - o - g) = (W_b - g) + (N - o - W_b)`, so
    /// `s2 += s2_chunk + (N - o - W_b) · s1_chunk` (all wrapping).
    pub fn checksum(&self, payload: &[u32]) -> Result<(u32, u32)> {
        let blocks = payload.len() / 16;
        let max = self.max_batch(ArtifactKind::ChecksumBlock);
        anyhow::ensure!(max > 0, "no checksum artifacts");
        let n_words = (blocks * 16) as u32;
        let mut s1: u32 = 0;
        let mut s2: u32 = 0;
        let mut offset_words: u32 = 0;
        for chunk in payload.chunks(max * 16) {
            let chunk_blocks = chunk.len() / 16;
            let batch = self
                .manifest
                .pick_batch(ArtifactKind::ChecksumBlock, chunk_blocks)
                .unwrap();
            let exe = self.exe(ArtifactKind::ChecksumBlock, 1, batch)?;
            let padded = Self::pad(chunk, batch);
            let p = xla::Literal::vec1(&padded).reshape(&[batch as i64, 16])?;
            let result = exe.execute::<xla::Literal>(&[p])?[0][0].to_literal_sync()?;
            let sums = result.to_tuple1()?.to_vec::<u32>()?;
            let (c1, c2) = (sums[0], sums[1]);
            let w_b = (batch * 16) as u32;
            // Zero padding contributes nothing to either sum; only the
            // weight base differs between the chunk and global frames.
            let shift = n_words.wrapping_sub(offset_words).wrapping_sub(w_b);
            s1 = s1.wrapping_add(c1);
            s2 = s2.wrapping_add(c2.wrapping_add(shift.wrapping_mul(c1)));
            offset_words += chunk.len() as u32;
        }
        Ok((s1, s2))
    }
}

/// One request in a grouped `encrypt_digest_many` call.
#[derive(Debug, Clone)]
pub struct EncRequest {
    /// Payload words (16 per 64 B block).
    pub payload: Vec<u32>,
    pub key: [u32; 8],
    pub nonce: [u32; 3],
    pub counter0: u32,
}

impl PjrtRuntime {
    /// Grouped encrypt+MAC: runs up to `group` requests in one executable
    /// call at the given (group, batch) shape (empty slots zero-padded).
    /// Each request keeps its own key/nonce/counters and gets its own tag.
    pub fn encrypt_digest_group(
        &self,
        reqs: &[EncRequest],
        shape: (usize, usize),
    ) -> Result<Vec<(Vec<u32>, Digest)>> {
        let (group, batch) = shape;
        anyhow::ensure!(reqs.len() <= group, "{} requests > group {group}", reqs.len());
        for r in reqs {
            anyhow::ensure!(
                r.payload.len() <= batch * 16,
                "request of {} words exceeds batch {batch}",
                r.payload.len()
            );
        }
        let exe = self.exe(ArtifactKind::EncryptDigestMany, group, batch)?;
        let mut payloads = vec![0u32; group * batch * 16];
        let mut keys = vec![0u32; group * 8];
        let mut nonces = vec![0u32; group * 3];
        let mut counters = vec![0u32; group * batch];
        for (i, r) in reqs.iter().enumerate() {
            payloads[i * batch * 16..i * batch * 16 + r.payload.len()]
                .copy_from_slice(&r.payload);
            keys[i * 8..(i + 1) * 8].copy_from_slice(&r.key);
            nonces[i * 3..(i + 1) * 3].copy_from_slice(&r.nonce);
            for (j, c) in counters[i * batch..(i + 1) * batch].iter_mut().enumerate() {
                *c = r.counter0.wrapping_add(j as u32);
            }
        }
        let p = xla::Literal::vec1(&payloads).reshape(&[group as i64, batch as i64, 16])?;
        let k = xla::Literal::vec1(&keys).reshape(&[group as i64, 8])?;
        let n = xla::Literal::vec1(&nonces).reshape(&[group as i64, 3])?;
        let c = xla::Literal::vec1(&counters).reshape(&[group as i64, batch as i64])?;
        let result = exe.execute::<xla::Literal>(&[p, k, n, c])?[0][0].to_literal_sync()?;
        let (cipher_lit, tag_lit) = result.to_tuple2()?;
        let ciphers = cipher_lit.to_vec::<u32>()?;
        let tags = tag_lit.to_vec::<u32>()?;
        Ok(reqs
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let c = ciphers[i * batch * 16..i * batch * 16 + r.payload.len()].to_vec();
                let mut t = [0u32; 16];
                t.copy_from_slice(&tags[i * 16..(i + 1) * 16]);
                (c, t)
            })
            .collect())
    }

    /// Grouped checksum at the given (group, batch) shape.
    pub fn checksum_group(
        &self,
        payloads_in: &[Vec<u32>],
        shape: (usize, usize),
    ) -> Result<Vec<(u32, u32)>> {
        let (group, batch) = shape;
        anyhow::ensure!(payloads_in.len() <= group, "{} payloads > group {group}", payloads_in.len());
        let exe = self.exe(ArtifactKind::ChecksumMany, group, batch)?;
        let mut payloads = vec![0u32; group * batch * 16];
        for (i, p) in payloads_in.iter().enumerate() {
            anyhow::ensure!(p.len() <= batch * 16, "payload exceeds batch");
            payloads[i * batch * 16..i * batch * 16 + p.len()].copy_from_slice(p);
        }
        let p = xla::Literal::vec1(&payloads).reshape(&[group as i64, batch as i64, 16])?;
        let result = exe.execute::<xla::Literal>(&[p])?[0][0].to_literal_sync()?;
        let sums = result.to_tuple1()?.to_vec::<u32>()?;
        // The kernel weights positions against the padded batch width
        // (W_b = batch·16); shift each slot's s2 back to its own length so
        // grouped results equal the unpadded native checksum:
        //   weight_true = n_i − g = (W_b − g) + (n_i − W_b).
        let w_b = (batch * 16) as u32;
        Ok(payloads_in
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let (s1, s2) = (sums[i * 2], sums[i * 2 + 1]);
                let shift = (p.len() as u32).wrapping_sub(w_b);
                (s1, s2.wrapping_add(shift.wrapping_mul(s1)))
            })
            .collect())
    }
}

/// Native Rust Fletcher oracle (for tests and the CPU-baseline benches):
/// must match the kernel bit-for-bit.
pub fn fletcher_native(payload: &[u32]) -> (u32, u32) {
    let n = payload.len() as u32;
    let mut s1: u32 = 0;
    let mut s2: u32 = 0;
    for (i, &x) in payload.iter().enumerate() {
        s1 = s1.wrapping_add(x);
        s2 = s2.wrapping_add((n.wrapping_sub(i as u32)).wrapping_mul(x));
    }
    (s1, s2)
}

/// Pack raw bytes into the block layout (zero-padded 64 B blocks).
pub fn pack_bytes(data: &[u8]) -> Vec<u32> {
    let blocks = data.len().div_ceil(64).max(1);
    let mut buf = vec![0u8; blocks * 64];
    buf[..data.len()].copy_from_slice(data);
    buf.chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Unpack the first `len` bytes from the block layout.
pub fn unpack_bytes(words: &[u32], len: usize) -> Vec<u8> {
    let mut out: Vec<u8> = words.iter().flat_map(|w| w.to_le_bytes()).collect();
    out.truncate(len);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Executables compile lazily, so a per-test runtime only pays for the
    /// batch shapes the test actually touches.
    fn runtime() -> Option<PjrtRuntime> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(PjrtRuntime::load(&dir).expect("artifact load"))
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let data: Vec<u8> = (0..=255).collect();
        let words = pack_bytes(&data);
        assert_eq!(words.len() % 16, 0);
        assert_eq!(unpack_bytes(&words, data.len()), data);
    }

    #[test]
    fn fletcher_native_basic() {
        assert_eq!(fletcher_native(&[0, 0, 0]), (0, 0));
        // n=2: s1 = 3+5 = 8, s2 = 2*3 + 1*5 = 11.
        assert_eq!(fletcher_native(&[3, 5]), (8, 11));
    }

    #[test]
    fn artifacts_load_and_report() {
        let Some(rt) = runtime() else { return };
        assert_eq!(rt.manifest().entries.len(), 15, "3 kinds × 3 batches + 2 grouped kinds × 3 shapes");
        assert!(rt.platform().to_lowercase().contains("cpu"));
        assert_eq!(rt.max_batch(ArtifactKind::EncryptDigest), 1024);
    }

    #[test]
    fn encrypt_is_involution() {
        let Some(rt) = runtime() else { return };
        let payload = pack_bytes(b"the paper's dataplane protocol decouples PatternA from PatternA'");
        let key = [1u32, 2, 3, 4, 5, 6, 7, 8];
        let nonce = [9u32, 10, 11];
        let (cipher, tag1) = rt.encrypt_digest(&payload, &key, &nonce, 100).unwrap();
        assert_ne!(cipher, payload);
        let (back, _) = rt.encrypt_digest(&cipher, &key, &nonce, 100).unwrap();
        assert_eq!(back, payload);
        // Tag is deterministic.
        let (_, tag2) = rt.encrypt_digest(&payload, &key, &nonce, 100).unwrap();
        assert_eq!(tag1, tag2);
    }

    #[test]
    fn different_keys_different_ciphertexts() {
        let Some(rt) = runtime() else { return };
        let payload = pack_bytes(&[0xAB; 256]);
        let nonce = [0u32, 0, 0];
        let (c1, t1) = rt.encrypt_digest(&payload, &[1; 8], &nonce, 0).unwrap();
        let (c2, t2) = rt.encrypt_digest(&payload, &[2; 8], &nonce, 0).unwrap();
        assert_ne!(c1, c2);
        assert_ne!(t1, t2);
    }

    #[test]
    fn digest_avalanche() {
        let Some(rt) = runtime() else { return };
        let mut payload = pack_bytes(&[0x55; 512]);
        let key = [7u32; 8];
        let d1 = rt.digest(&payload, &key).unwrap();
        payload[3] ^= 1;
        let d2 = rt.digest(&payload, &key).unwrap();
        assert_ne!(d1, d2);
        // Roughly half the bits should flip (avalanche): sanity band.
        let flipped: u32 = d1
            .iter()
            .zip(d2.iter())
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        assert!((128..=384).contains(&flipped), "flipped {flipped} of 512");
    }

    #[test]
    fn checksum_matches_native_including_chunked() {
        let Some(rt) = runtime() else { return };
        // Small (one batch) and large (chunked beyond the 1024 max batch).
        // 1500 and 3000 blocks exceed the 1024 max batch: chunked combine.
        for blocks in [1usize, 64, 100, 129, 200, 1500, 3000] {
            let payload: Vec<u32> = (0..blocks * 16).map(|i| (i as u32).wrapping_mul(0x9E37_79B9)).collect();
            let (s1, s2) = rt.checksum(&payload).unwrap();
            let (n1, n2) = fletcher_native(&payload);
            assert_eq!((s1, s2), (n1, n2), "blocks={blocks}");
        }
    }

    #[test]
    fn grouped_encrypt_matches_involution_and_varies_per_slot() {
        let Some(rt) = runtime() else { return };
        let shape = rt.manifest().pick_group_shape(ArtifactKind::EncryptDigestMany, 16, 3).unwrap();
        let reqs: Vec<EncRequest> = (0..3)
            .map(|i| EncRequest {
                payload: pack_bytes(&vec![i as u8 + 1; 700]),
                key: [i as u32 + 1; 8],
                nonce: [9, 9, 9],
                counter0: i as u32 * 1000,
            })
            .collect();
        let out = rt.encrypt_digest_group(&reqs, shape).unwrap();
        assert_eq!(out.len(), 3);
        // Distinct keys → distinct tags.
        assert_ne!(out[0].1, out[1].1);
        // Involution per slot.
        let back: Vec<EncRequest> = reqs
            .iter()
            .zip(out.iter())
            .map(|(r, (c, _))| EncRequest { payload: c.clone(), ..r.clone() })
            .collect();
        let out2 = rt.encrypt_digest_group(&back, shape).unwrap();
        for (r, (p, _)) in reqs.iter().zip(out2.iter()) {
            assert_eq!(&r.payload, p);
        }
    }

    #[test]
    fn grouped_checksum_matches_native_per_slot() {
        let Some(rt) = runtime() else { return };
        let shape = rt.manifest().pick_group_shape(ArtifactKind::ChecksumMany, 16, 4).unwrap();
        let payloads: Vec<Vec<u32>> = (0..4u32)
            .map(|i| (0..16 * 16).map(|j| i.wrapping_mul(77).wrapping_add(j)).collect())
            .collect();
        let sums = rt.checksum_group(&payloads, shape).unwrap();
        for (p, &(s1, s2)) in payloads.iter().zip(sums.iter()) {
            // Grouped results are shift-corrected to the unpadded length:
            // they must equal the native oracle exactly.
            assert_eq!((s1, s2), fletcher_native(p));
        }
    }

    #[test]
    fn padding_does_not_change_results() {
        let Some(rt) = runtime() else { return };
        // 10 blocks runs on the 64-batch executable; the 54 pad rows must
        // not affect the ciphertext of the 10 real rows.
        let payload = pack_bytes(&[0x42; 640]);
        let key = [3u32; 8];
        let nonce = [1u32, 2, 3];
        let (cipher, _) = rt.encrypt_digest(&payload, &key, &nonce, 0).unwrap();
        assert_eq!(cipher.len(), payload.len());
        let (back, _) = rt.encrypt_digest(&cipher, &key, &nonce, 0).unwrap();
        assert_eq!(back, payload);
    }
}
