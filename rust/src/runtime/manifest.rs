//! Artifact manifest parsing.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt` in a simple line
//! format (`name kind batch outputs`, `#` comments) so the Rust side needs
//! no JSON dependency.

use std::path::{Path, PathBuf};

/// Which L2 entry point an artifact implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// `encrypt_digest(payload, key, nonce, counters) -> (cipher, tag)`.
    EncryptDigest,
    /// `digest_only(payload, key) -> (tag,)`.
    DigestOnly,
    /// `checksum_block(payload) -> (sums,)`.
    ChecksumBlock,
    /// Grouped `encrypt_digest` over G requests (the dynamic batcher's
    /// target): `(G,B,16) × (G,8) × (G,3) × (G,B) -> ((G,B,16), (G,16))`.
    EncryptDigestMany,
    /// Grouped checksum: `(G,B,16) -> ((G,2),)`.
    ChecksumMany,
}

impl ArtifactKind {
    pub fn by_name(s: &str) -> Option<Self> {
        Some(match s {
            "encrypt_digest" => ArtifactKind::EncryptDigest,
            "digest_only" => ArtifactKind::DigestOnly,
            "checksum_block" => ArtifactKind::ChecksumBlock,
            "encrypt_digest_many" => ArtifactKind::EncryptDigestMany,
            "checksum_many" => ArtifactKind::ChecksumMany,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            ArtifactKind::EncryptDigest => "encrypt_digest",
            ArtifactKind::DigestOnly => "digest_only",
            ArtifactKind::ChecksumBlock => "checksum_block",
            ArtifactKind::EncryptDigestMany => "encrypt_digest_many",
            ArtifactKind::ChecksumMany => "checksum_many",
        }
    }
}

/// One compiled artifact.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    pub kind: ArtifactKind,
    /// Request group size (1 for the ungrouped entries).
    pub group: usize,
    /// Compiled batch size in 64 B blocks per request.
    pub batch: usize,
    /// Number of tuple outputs.
    pub outputs: usize,
    /// Path to the HLO text file.
    pub path: PathBuf,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Parse `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> anyhow::Result<Manifest> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 5 {
                anyhow::bail!("manifest line {}: expected 5 fields, got {}", lineno + 1, f.len());
            }
            let kind = ArtifactKind::by_name(f[1])
                .ok_or_else(|| anyhow::anyhow!("manifest line {}: unknown kind {}", lineno + 1, f[1]))?;
            entries.push(ManifestEntry {
                name: f[0].to_string(),
                kind,
                group: f[2].parse()?,
                batch: f[3].parse()?,
                outputs: f[4].parse()?,
                path: dir.join(format!("{}.hlo.txt", f[0])),
            });
        }
        Ok(Manifest { entries })
    }

    /// Compiled batch sizes for a kind, ascending.
    pub fn batches(&self, kind: ArtifactKind) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.kind == kind)
            .map(|e| e.batch)
            .collect();
        v.sort_unstable();
        v
    }

    /// Smallest compiled batch that fits `blocks`, or the largest batch if
    /// none fits (the caller chunks).
    pub fn pick_batch(&self, kind: ArtifactKind, blocks: usize) -> Option<usize> {
        let batches = self.batches(kind);
        batches
            .iter()
            .find(|&&b| b >= blocks)
            .copied()
            .or_else(|| batches.last().copied())
    }

    /// Available (group, batch) shapes for a grouped kind.
    pub fn group_shapes(&self, kind: ArtifactKind) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .entries
            .iter()
            .filter(|e| e.kind == kind)
            .map(|e| (e.group, e.batch))
            .collect();
        v.sort_unstable();
        v
    }

    /// Best (group, batch) for `n_requests` requests of at most `blocks`
    /// blocks each: the smallest batch that fits the blocks, then the
    /// smallest group that fits the request count (or the largest group if
    /// none does — the caller splits the batch).
    pub fn pick_group_shape(
        &self,
        kind: ArtifactKind,
        blocks: usize,
        n_requests: usize,
    ) -> Option<(usize, usize)> {
        let shapes = self.group_shapes(kind);
        let fitting_batch = shapes
            .iter()
            .filter(|&&(_, b)| b >= blocks)
            .map(|&(_, b)| b)
            .min()
            .or_else(|| shapes.iter().map(|&(_, b)| b).max())?;
        let groups: Vec<usize> = shapes
            .iter()
            .filter(|&&(_, b)| b == fitting_batch)
            .map(|&(g, _)| g)
            .collect();
        let group = groups
            .iter()
            .find(|&&g| g >= n_requests)
            .or_else(|| groups.iter().max())
            .copied()?;
        Some((group, fitting_batch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# name kind group batch outputs
encdig_b64 encrypt_digest 1 64 2
encdig_b256 encrypt_digest 1 256 2
checksum_b64 checksum_block 1 64 1
encdig_g8_b16 encrypt_digest_many 8 16 2
";

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(SAMPLE, Path::new("/x")).unwrap();
        assert_eq!(m.entries.len(), 4);
        assert_eq!(m.entries[0].kind, ArtifactKind::EncryptDigest);
        assert_eq!(m.entries[0].group, 1);
        assert_eq!(m.entries[0].batch, 64);
        assert_eq!(m.entries[0].outputs, 2);
        assert_eq!(m.entries[0].path, Path::new("/x/encdig_b64.hlo.txt"));
        assert_eq!(m.entries[3].kind, ArtifactKind::EncryptDigestMany);
        assert_eq!(m.entries[3].group, 8);
    }

    #[test]
    fn pick_batch_prefers_smallest_fit() {
        let m = Manifest::parse(SAMPLE, Path::new("/x")).unwrap();
        assert_eq!(m.pick_batch(ArtifactKind::EncryptDigest, 10), Some(64));
        assert_eq!(m.pick_batch(ArtifactKind::EncryptDigest, 64), Some(64));
        assert_eq!(m.pick_batch(ArtifactKind::EncryptDigest, 65), Some(256));
        // Bigger than every compiled batch: take the largest (caller chunks).
        assert_eq!(m.pick_batch(ArtifactKind::EncryptDigest, 5000), Some(256));
        assert_eq!(m.pick_batch(ArtifactKind::DigestOnly, 1), None);
    }

    #[test]
    fn group_shape_selection() {
        let text = "\
a encrypt_digest_many 8 16 2
b encrypt_digest_many 32 16 2
c encrypt_digest_many 8 64 2
";
        let m = Manifest::parse(text, Path::new("/x")).unwrap();
        // 1 KB request (16 blocks), 5 requests → (8, 16).
        assert_eq!(m.pick_group_shape(ArtifactKind::EncryptDigestMany, 16, 5), Some((8, 16)));
        // 20 requests → (32, 16).
        assert_eq!(m.pick_group_shape(ArtifactKind::EncryptDigestMany, 16, 20), Some((32, 16)));
        // 100 requests: no group fits, take the largest (caller splits).
        assert_eq!(m.pick_group_shape(ArtifactKind::EncryptDigestMany, 16, 100), Some((32, 16)));
        // 4 KB request → the (8, 64) shape.
        assert_eq!(m.pick_group_shape(ArtifactKind::EncryptDigestMany, 64, 3), Some((8, 64)));
        // Oversized blocks: largest batch.
        assert_eq!(m.pick_group_shape(ArtifactKind::EncryptDigestMany, 500, 3), Some((8, 64)));
        assert_eq!(m.pick_group_shape(ArtifactKind::ChecksumMany, 16, 1), None);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse("bogus line", Path::new("/x")).is_err());
        assert!(Manifest::parse("a unknown_kind 1 64 1", Path::new("/x")).is_err());
    }

    #[test]
    fn kind_name_roundtrip() {
        for k in [
            ArtifactKind::EncryptDigest,
            ArtifactKind::DigestOnly,
            ArtifactKind::ChecksumBlock,
            ArtifactKind::EncryptDigestMany,
            ArtifactKind::ChecksumMany,
        ] {
            assert_eq!(ArtifactKind::by_name(k.name()), Some(k));
        }
    }
}
