//! Host-software traffic shaper — the `Host_TS_reflex` / `Host_TS_firecracker`
//! baseline mechanism (§5.1, §5.2).
//!
//! ReFlex- and Firecracker-style rate limiting runs a token bucket *in
//! software on the host CPU*. The paper's measurements attribute their
//! 6.5–11.7% throughput loss, 8.7–24.3% over-provisioning (Table 3), and
//! micro-second-scale shaping latency (>10 µs vs 36 ns, §5.3.1) to three
//! effects, all modeled here on top of the ideal token-bucket arithmetic:
//!
//! 1. **Timer quantization** — software timers fire on a coarse grid (high-
//!    resolution timers still slip to ~1–10 µs under load); a release that
//!    should happen at `t` happens at the next timer edge ≥ `t`.
//! 2. **CPU interference jitter** — the shaping thread shares cores with VM
//!    vCPUs; scheduler preemption adds heavy-tailed (Pareto) delay.
//! 3. **Batched catch-up** — after a delayed wakeup the software releases
//!    everything accumulated, producing over-provisioned windows (the +24.3%
//!    99th-percentile windows of Table 3).
//!
//! Profiles for the two named baselines differ only in constants: ReFlex
//! (polling dataplane) has finer timers but loses whole quanta to its
//! polling loop; Firecracker (interrupt-driven) quantizes coarser.

use super::{ShapeMode, Shaper, TokenBucket, Verdict};
use crate::util::units::{Time, MICROS, NANOS};
use crate::util::Rng;

/// Jitter/quantization profile of a software shaper deployment.
#[derive(Debug, Clone)]
pub struct SoftwareShaperConfig {
    /// Timer grid: releases snap up to multiples of this.
    pub timer_quantum: Time,
    /// Probability a wakeup is preempted by CPU interference.
    pub preempt_prob: f64,
    /// Pareto scale (minimum extra delay) when preempted.
    pub preempt_scale: Time,
    /// Pareto shape; smaller = heavier tail.
    pub preempt_alpha: f64,
    /// Upper bound on one preemption stall (the scheduler does run).
    pub preempt_cap: Time,
    /// Tokens carried across a stall (catch-up burst budget).
    pub catchup_carry: Time,
    /// Per-decision software overhead (syscall + bookkeeping).
    pub decision_overhead: Time,
}

impl SoftwareShaperConfig {
    /// ReFlex-like: polling dataplane, 1 µs quantum, moderate interference
    /// (vCPUs sharing the socket preempt the polling core occasionally).
    pub fn reflex() -> Self {
        SoftwareShaperConfig {
            timer_quantum: MICROS,
            preempt_prob: 0.09,
            preempt_scale: 15 * MICROS,
            preempt_alpha: 1.6,
            preempt_cap: 1_000 * MICROS,
            catchup_carry: 150 * MICROS,
            decision_overhead: 300 * NANOS,
        }
    }

    /// Firecracker-like: interrupt-driven, 4 µs effective quantum, heavier
    /// stalls and burstier catch-up (its larger positive deviations in
    /// Table 3).
    pub fn firecracker() -> Self {
        SoftwareShaperConfig {
            timer_quantum: 4 * MICROS,
            preempt_prob: 0.04,
            preempt_scale: 35 * MICROS,
            preempt_alpha: 1.3,
            preempt_cap: 2_000 * MICROS,
            catchup_carry: 520 * MICROS,
            decision_overhead: 500 * NANOS,
        }
    }
}

/// Software token bucket: ideal arithmetic + OS-level timing error.
#[derive(Debug, Clone)]
pub struct SoftwareShaper {
    inner: TokenBucket,
    cfg: SoftwareShaperConfig,
    rng: Rng,
    /// Next time the software thread actually runs (wakeup edge).
    next_wakeup: Time,
}

impl SoftwareShaper {
    /// A software bucket shaping to `units_per_sec` under `cfg`'s timing
    /// error model, with jitter drawn from a stream seeded by `seed`.
    pub fn new(
        units_per_sec: f64,
        mode: ShapeMode,
        cfg: SoftwareShaperConfig,
        seed: u64,
    ) -> Self {
        // Software buckets accrue during scheduler stalls and release the
        // backlog at the next wakeup ("batched catch-up"): carry up to
        // ~400 µs of tokens across a stall, producing the over-provisioned
        // windows the paper measures (+8.7…+24.3% at the 99th percentile);
        // anything stalled longer is lost rate (the −6.7…−11.7% side).
        let mut params = crate::shaping::TokenBucketParams::for_rate(units_per_sec, mode);
        let carry_units = units_per_sec * (cfg.catchup_carry as f64 / 1e12);
        params.bkt_size = params
            .bkt_size
            .max((carry_units / params.token_unit as f64).ceil() as u64);
        let mut inner = TokenBucket::new(params, mode);
        // Rate limiters initialize empty in software (no free startup burst).
        use crate::shaping::Shaper as _;
        let _ = inner.try_acquire(0, params.bkt_size * params.token_unit);
        SoftwareShaper {
            inner,
            cfg,
            rng: Rng::for_stream(seed, 0x50F7),
            next_wakeup: 0,
        }
    }

    /// Snap `t` to the software timer grid and add interference.
    fn software_delay(&mut self, t: Time) -> Time {
        let q = self.cfg.timer_quantum;
        let snapped = t.div_ceil(q) * q;
        let jitter = if self.rng.chance(self.cfg.preempt_prob) {
            (self
                .rng
                .pareto(self.cfg.preempt_scale as f64, self.cfg.preempt_alpha) as Time)
                .min(self.cfg.preempt_cap)
        } else {
            0
        };
        snapped + jitter + self.cfg.decision_overhead
    }
}

impl Shaper for SoftwareShaper {
    fn try_acquire(&mut self, now: Time, cost: u64) -> Verdict {
        // The shaping thread only observes the world at wakeup edges.
        if now < self.next_wakeup {
            return Verdict::RetryAt(self.next_wakeup);
        }
        match self.inner.try_acquire(now, cost) {
            Verdict::Admit => Verdict::Admit,
            Verdict::RetryAt(ideal) => {
                let actual = self.software_delay(ideal);
                self.next_wakeup = actual;
                Verdict::RetryAt(actual.max(now + 1))
            }
        }
    }

    fn set_rate(&mut self, now: Time, units_per_sec: f64) {
        self.inner.set_rate(now, units_per_sec);
    }

    fn rate(&self) -> f64 {
        self.inner.rate()
    }

    fn state_bytes(&self) -> usize {
        // Software state is cheap; the cost is timing, not memory.
        self.inner.state_bytes() + std::mem::size_of::<SoftwareShaperConfig>()
    }

    fn name(&self) -> &'static str {
        "software_token_bucket"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shaping::replay;
    use crate::util::units::{Rate, SECONDS};

    fn measure_cv(shaper: &mut dyn Shaper, n_msgs: usize, size: u64) -> (f64, f64) {
        // Saturating queue; sample per-500-message window rates (the paper's
        // sampling method) and return (mean_rate, cv).
        let arrivals: Vec<(Time, u64)> = (0..n_msgs).map(|_| (0, size)).collect();
        let mut admit_times = Vec::with_capacity(n_msgs);
        let mut now = 0u64;
        for &(t, cost) in &arrivals {
            now = now.max(t);
            loop {
                match shaper.try_acquire(now, cost) {
                    Verdict::Admit => {
                        admit_times.push(now);
                        break;
                    }
                    Verdict::RetryAt(at) => now = at,
                }
            }
        }
        let window = 500;
        let mut rates = Vec::new();
        for chunk in admit_times.chunks(window) {
            if chunk.len() == window {
                let span = chunk[window - 1] - chunk[0];
                if span > 0 {
                    rates.push(
                        (window as f64 - 1.0) * size as f64 * SECONDS as f64 / span as f64,
                    );
                }
            }
        }
        let mean = rates.iter().sum::<f64>() / rates.len() as f64;
        let var =
            rates.iter().map(|r| (r - mean) * (r - mean)).sum::<f64>() / rates.len() as f64;
        (mean, var.sqrt() / mean)
    }

    #[test]
    fn software_variance_exceeds_hardware() {
        let target = Rate::gbps(10.0).as_bits_per_sec() / 8.0;
        let mut hw = TokenBucket::for_rate(target, ShapeMode::Gbps);
        let mut sw = SoftwareShaper::new(
            target,
            ShapeMode::Gbps,
            SoftwareShaperConfig::firecracker(),
            42,
        );
        let (hw_mean, hw_cv) = measure_cv(&mut hw, 30_000, 4096);
        let (sw_mean, sw_cv) = measure_cv(&mut sw, 30_000, 4096);
        // Hardware: sub-1% variance (the paper's headline). Software: worse.
        assert!(hw_cv < 0.01, "hw cv={hw_cv}");
        assert!(sw_cv > 2.0 * hw_cv, "sw cv={sw_cv} hw cv={hw_cv}");
        // Both still track the mean within a few percent.
        assert!((hw_mean - target).abs() / target < 0.02);
        assert!((sw_mean - target).abs() / target < 0.15, "sw_mean={sw_mean:.3e}");
    }

    #[test]
    fn reflex_tighter_than_firecracker() {
        let target = Rate::gbps(10.0).as_bits_per_sec() / 8.0;
        let mut reflex = SoftwareShaper::new(
            target,
            ShapeMode::Gbps,
            SoftwareShaperConfig::reflex(),
            7,
        );
        let mut fc = SoftwareShaper::new(
            target,
            ShapeMode::Gbps,
            SoftwareShaperConfig::firecracker(),
            7,
        );
        let (_, reflex_cv) = measure_cv(&mut reflex, 30_000, 4096);
        let (_, fc_cv) = measure_cv(&mut fc, 30_000, 4096);
        assert!(
            reflex_cv < fc_cv,
            "reflex cv={reflex_cv} firecracker cv={fc_cv}"
        );
    }

    #[test]
    fn long_run_rate_still_converges() {
        // Software shaping is sloppy per-window but unbiased long-run.
        let target = Rate::gbps(10.0).as_bits_per_sec() / 8.0;
        let mut sw = SoftwareShaper::new(
            target,
            ShapeMode::Gbps,
            SoftwareShaperConfig::reflex(),
            99,
        );
        let arrivals: Vec<(Time, u64)> = (0..40_000).map(|_| (0, 1500)).collect();
        let (admitted, last) = replay(&mut sw, &arrivals);
        let rate = admitted as f64 * SECONDS as f64 / last as f64;
        assert!(((rate - target) / target).abs() < 0.10, "rate={rate:.3e}");
    }
}
