//! Traffic shaping mechanisms (§4.2).
//!
//! The Arcus interface pairs a **hardware token bucket** with every per-flow
//! queue; the runtime programs two MMIO registers (`Bkt_Size`,
//! `Refill_Rate`) plus the refill `Interval`. The paper motivates the token
//! bucket over three alternatives it prototyped or considered — sliding
//! window log (accurate but memory-hungry), fixed window counter and leaky
//! bucket (resource-efficient but burst-hostile). All four are implemented
//! here so the ablation bench can regenerate that design-space comparison,
//! plus the *software* shaper used by the `Host_TS_*` baselines, which adds
//! the timer-quantization and CPU-interference error the paper measures in
//! Fig 6 / Table 3.
//!
//! All shapers answer one question on the simulator's virtual clock: *may
//! this flow fetch a message of `size` units now, and if not, when should it
//! retry?* Units are bytes in Gbps mode or messages in IOPS mode (§4.2: "the
//! only difference is to increase and decrease tokens based on the number of
//! bytes, or the number of messages").
//!
//! At scale, flat per-flow shapers stop being enforceable on their own —
//! 10,000 flows would mean 10,000 independent wakeups. The [`hierarchy`]
//! module composes them into the per-tenant / per-engine [`ShaperTree`]
//! (min-guarantee + ceiling per node, deficit-round-robin with
//! work-conserving borrow among siblings), paced by one tree-wide tick on
//! the event queue instead of per-flow heap entries.

pub mod fixed_window;
pub mod hierarchy;
pub mod leaky_bucket;
pub mod sliding_log;
pub mod software;
pub mod token_bucket;

pub use fixed_window::FixedWindow;
pub use hierarchy::{NodeBudget, ShaperTree, TreeConfig, TreeVerdict};
pub use leaky_bucket::LeakyBucket;
pub use sliding_log::SlidingLog;
pub use software::{SoftwareShaper, SoftwareShaperConfig};
pub use token_bucket::{TokenBucket, TokenBucketParams};

use crate::util::units::Time;

/// Shaping mode: limit bytes/sec (bandwidth SLO) or messages/sec (IOPS SLO).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShapeMode {
    /// Cost units are bytes (bandwidth SLOs).
    Gbps,
    /// Cost units are messages (IOPS SLOs).
    Iops,
}

/// Decision returned by a shaper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// The message may be released now.
    Admit,
    /// Not yet; earliest time the caller should ask again.
    RetryAt(Time),
}

/// A per-flow traffic shaper on virtual time.
///
/// `cost` is bytes (Gbps mode) or 1 (IOPS mode); callers pick per flow.
pub trait Shaper {
    /// Ask to release a message of `cost` units at virtual time `now`.
    fn try_acquire(&mut self, now: Time, cost: u64) -> Verdict;

    /// Reconfigure for a new target rate in units/sec. Used by the control
    /// plane's `ReshapeDecision` (§4.3); must be callable mid-flight without
    /// losing more than one bucket of state.
    fn set_rate(&mut self, now: Time, units_per_sec: f64);

    /// Currently configured rate in units/sec.
    fn rate(&self) -> f64;

    /// Approximate state memory in bytes (for the ablation's memory column).
    fn state_bytes(&self) -> usize;

    /// Mechanism name for reports.
    fn name(&self) -> &'static str;
}

/// Compute the long-run admitted rate of a shaper on a synthetic arrival
/// pattern — shared helper for tests and the ablation bench.
///
/// Arrivals are `(time, cost)` pairs, assumed time-sorted; each message is
/// retried at the shaper's `RetryAt` hint until admitted (i.e. an
/// infinitely patient queue). Returns (admitted units, time of last admit).
pub fn replay<S: Shaper + ?Sized>(shaper: &mut S, arrivals: &[(Time, u64)]) -> (u64, Time) {
    let mut admitted = 0u64;
    let mut last = 0;
    let mut free_at: Time = 0; // head-of-line blocking: FIFO release
    for &(t, cost) in arrivals {
        let mut now = t.max(free_at);
        loop {
            match shaper.try_acquire(now, cost) {
                Verdict::Admit => {
                    admitted += cost;
                    last = now;
                    free_at = now;
                    break;
                }
                Verdict::RetryAt(at) => {
                    debug_assert!(at > now, "retry hint must advance time");
                    now = at;
                }
            }
        }
    }
    (admitted, last)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{Rate, MICROS, MILLIS, SECONDS};

    /// All four hardware-style shapers — plus the software token bucket the
    /// `Host_TS_*` baselines run — should converge to the target rate on a
    /// saturating workload, regardless of message size mix. The software
    /// shaper's timer quantization and CPU-interference jitter make it
    /// sloppy per-window but unbiased long-run, hence its wider tolerance.
    #[test]
    fn all_shapers_converge_to_target_rate() {
        let target_bps = Rate::gbps(10.0); // 10 Gbps => 1.25e9 bytes/s
        let bytes_per_sec = target_bps.as_bits_per_sec() / 8.0;
        let mut rng = crate::util::Rng::new(77);
        // Oversubscribed arrivals: 2x the target, mixed sizes.
        let mut arrivals = Vec::new();
        let mut t = 0u64;
        let mut total = 0u64;
        while total < 2_500_000_000 / 2 {
            let size = *rng.choose(&[64u64, 256, 1500, 4096]);
            arrivals.push((t, size));
            total += size;
            // schedule at 2x target rate
            t += (size as f64 * 8.0 / (2.0 * target_bps.as_bits_per_sec())
                * SECONDS as f64) as u64;
        }
        let horizon = arrivals.last().unwrap().0;

        let shapers: Vec<Box<dyn Shaper>> = vec![
            Box::new(TokenBucket::for_rate(bytes_per_sec, ShapeMode::Gbps)),
            Box::new(LeakyBucket::new(bytes_per_sec)),
            Box::new(FixedWindow::new(bytes_per_sec, 10 * MICROS)),
            Box::new(SlidingLog::new(bytes_per_sec, 100 * MICROS)),
            Box::new(SoftwareShaper::new(
                bytes_per_sec,
                ShapeMode::Gbps,
                SoftwareShaperConfig::reflex(),
                7,
            )),
        ];
        for mut s in shapers {
            let tol = match s.name() {
                "fixed_window" => 0.15,
                "software_token_bucket" => 0.10,
                _ => 0.05,
            };
            let (admitted, last) = replay(s.as_mut(), &arrivals);
            let elapsed = last.max(horizon);
            let rate = admitted as f64 * SECONDS as f64 / elapsed as f64;
            let err = (rate - bytes_per_sec).abs() / bytes_per_sec;
            assert!(
                err < tol,
                "{}: rate {:.3e} vs target {:.3e} (err {:.1}%)",
                s.name(),
                rate,
                bytes_per_sec,
                err * 100.0
            );
        }
    }

    /// Drive a saturated shaper from `from` to `until` with back-to-back
    /// `size`-byte messages; count the bytes admitted strictly before
    /// `until`.
    fn saturate(s: &mut dyn Shaper, from: Time, until: Time, size: u64) -> u64 {
        let mut now = from;
        let mut admitted = 0u64;
        loop {
            if now >= until {
                return admitted;
            }
            match s.try_acquire(now, size) {
                Verdict::Admit => admitted += size,
                Verdict::RetryAt(at) => {
                    debug_assert!(at > now, "{}: retry must advance time", s.name());
                    now = at;
                }
            }
        }
    }

    /// Satellite property: `set_rate` mid-flight honors the `Shaper` trait
    /// contract — a reconfiguration loses (or grants) at most one bucket of
    /// state. After saturating at rate₁ and switching to rate₂, the bytes
    /// admitted over the next window must equal rate₂ × window within one
    /// burst allowance (the largest "bucket" either configuration holds:
    /// ≤ ~100 µs of tokens for the token bucket, one shaping window for
    /// the window-based mechanisms) plus refill granularity.
    #[test]
    fn set_rate_mid_flight_loses_at_most_one_bucket() {
        use crate::testkit::{forall_cfg, Config, OneOf, PairOf};
        let gen = PairOf(
            OneOf(vec![1.0f64, 4.0, 10.0, 40.0]),
            OneOf(vec![2.0f64, 8.0, 25.0, 100.0]),
        );
        forall_cfg(&Config { cases: 24, ..Default::default() }, &gen, |&(g1, g2)| {
            let r1 = Rate::gbps(g1).as_bits_per_sec() / 8.0;
            let r2 = Rate::gbps(g2).as_bits_per_sec() / 8.0;
            let t_switch = 2 * MILLIS;
            let t_end = t_switch + 8 * MILLIS;
            let shapers: Vec<Box<dyn Shaper>> = vec![
                Box::new(TokenBucket::for_rate(r1, ShapeMode::Gbps)),
                Box::new(LeakyBucket::new(r1)),
                Box::new(FixedWindow::new(r1, 10 * MICROS)),
                Box::new(SlidingLog::new(r1, 100 * MICROS)),
            ];
            for mut s in shapers {
                let _ = saturate(s.as_mut(), 0, t_switch, 1500);
                s.set_rate(t_switch, r2);
                if (s.rate() - r2).abs() / r2 > 0.01 {
                    return false; // reprogrammed rate must take effect
                }
                let admitted = saturate(s.as_mut(), t_switch, t_end, 1500) as f64;
                let window_secs = (t_end - t_switch) as f64 / SECONDS as f64;
                let expected = r2 * window_secs;
                // One bucket of state: the larger configuration's burst
                // allowance (~100 µs of traffic for the token bucket and
                // sliding log, plus the token bucket's 8-jumbo-frame floor)
                // plus two messages of quantization.
                let bucket = r1.max(r2) * 250e-6 + 8.0 * 9216.0 + 2.0 * 1500.0;
                // Window-based mechanisms additionally strand up to one
                // message of unusable budget per shaping window — a
                // quantization artifact of the mechanism itself, not a
                // set_rate loss — so grant them that allowance on top.
                let msg_quant = match s.name() {
                    "fixed_window" => 1500.0 * (window_secs / 10e-6),
                    "sliding_log" => 1500.0 * (window_secs / 100e-6),
                    _ => 0.0,
                };
                let slack = bucket + msg_quant + expected * 0.02;
                if (admitted - expected).abs() > slack {
                    eprintln!(
                        "{}: {g1}->{g2} Gbps admitted {admitted:.3e} vs expected {expected:.3e} (slack {slack:.3e})",
                        s.name()
                    );
                    return false;
                }
            }
            true
        });
    }

    /// Under-subscribed traffic must pass through unshaped (work conserving).
    #[test]
    fn undersubscribed_traffic_unthrottled() {
        let bytes_per_sec = Rate::gbps(10.0).as_bits_per_sec() / 8.0;
        let mut tb = TokenBucket::for_rate(bytes_per_sec, ShapeMode::Gbps);
        // 1500B every 10us = 1.2 Gbps << 10 Gbps.
        let mut delayed = 0;
        for i in 0..10_000u64 {
            if let Verdict::RetryAt(_) = tb.try_acquire(i * 10 * MICROS, 1500) {
                delayed += 1;
            }
        }
        assert_eq!(delayed, 0);
    }
}
