//! Hardware token-bucket rate limiter (§4.2, Table 2).
//!
//! The RTL design refills the bucket with `Refill_Rate` tokens every
//! `Interval` FPGA cycles (250 MHz ⇒ 4 ns/cycle) and caps it at `Bkt_Size`.
//! One token buys one *unit* (a byte in Gbps mode; the RTL actually counts
//! 32-byte datapath beats, which we model by a configurable `token_unit`).
//! We reproduce the discrete refill exactly — tokens arrive in steps, not
//! continuously — because that is what makes `Interval` a real design
//! parameter (Table 2 shows 1000 Gbps shaping needs Interval=64 cycles while
//! 1 Gbps works at 1000 cycles).

use super::{ShapeMode, Shaper, Verdict};
use crate::util::units::{cycles, Time, SECONDS};

/// The two MMIO-programmable registers plus the hardware refill interval.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TokenBucketParams {
    /// Tokens added per interval (`Refill_Rate` register).
    pub refill_rate: u64,
    /// Bucket capacity in tokens (`Bkt_Size` register).
    pub bkt_size: u64,
    /// Refill period in FPGA cycles (`Interval`).
    pub interval_cycles: u64,
    /// Units (bytes or messages) per token. The paper's datapath is 256 bits
    /// = 32 B per beat, so one token = 32 B in Gbps mode; 1 message in IOPS.
    pub token_unit: u64,
}

impl TokenBucketParams {
    /// Nominal shaped rate in units/sec implied by these registers.
    pub fn nominal_rate(&self) -> f64 {
        let interval_ps = cycles(self.interval_cycles) as f64;
        self.refill_rate as f64 * self.token_unit as f64 * SECONDS as f64 / interval_ps
    }

    /// Derive registers for a target rate (units/sec), mirroring the
    /// paper's tuning recipe: "fix Bkt_Size to a certain value, then sweep
    /// Refill_Rate". We pick the shortest interval that keeps refill_rate
    /// integral within 0.5% of the target, then size the bucket for ~100 µs
    /// of burst (large buckets make the outcome "insensitive to large bursts
    /// and message size variations", §5.2).
    pub fn for_rate(units_per_sec: f64, mode: ShapeMode) -> Self {
        let token_unit = match mode {
            ShapeMode::Gbps => 32, // one 256-bit datapath beat
            ShapeMode::Iops => 1,
        };
        let tokens_per_sec = units_per_sec / token_unit as f64;
        let cycle_s = cycles(1) as f64 / SECONDS as f64;
        // Sweep Refill_Rate from small to large; for each, the interval is
        // the nearest integer cycle count that realizes the target. Take the
        // smallest register value that lands within 0.2% — exactly the
        // paper's tuning recipe ("fix one parameter, sweep the other").
        // Hardware constraint: keep Interval ≥ 64 cycles (256 ns) so the
        // refill FSM is trivially implementable — Table 2 keeps 64 cycles
        // even for the 1 Tbps row.
        const MIN_INTERVAL: f64 = 64.0;
        let mut best = (1u64, 1u64, f64::INFINITY);
        for refill in 1..=65_536u64 {
            let interval = (refill as f64 / tokens_per_sec / cycle_s)
                .round()
                .max(MIN_INTERVAL);
            let achieved = refill as f64 / (interval * cycle_s);
            let err = (achieved - tokens_per_sec).abs() / tokens_per_sec.max(1e-9);
            if err < best.2 {
                best = (refill, interval as u64, err);
            }
            if err < 0.002 && interval >= MIN_INTERVAL {
                break;
            }
        }
        let (refill_rate, interval_cycles, _) = best;
        // Bucket: ~100 µs of tokens; floor of 8 jumbo frames (Gbps mode) or
        // 8 messages (IOPS mode) so a cold flow can always make progress,
        // and never smaller than one refill chunk (tokens above Bkt_Size
        // are dropped by the hardware — a smaller bucket would leak rate).
        let burst_tokens = (tokens_per_sec * 100e-6).ceil() as u64;
        let floor = match mode {
            ShapeMode::Gbps => 8 * 9216 / token_unit,
            ShapeMode::Iops => 8,
        };
        TokenBucketParams {
            refill_rate,
            bkt_size: burst_tokens.max(floor).max(refill_rate),
            interval_cycles,
            token_unit,
        }
    }
}

/// Cycle-stepped hardware token bucket.
#[derive(Debug, Clone)]
pub struct TokenBucket {
    params: TokenBucketParams,
    mode: ShapeMode,
    /// Tokens currently in the bucket.
    tokens: u64,
    /// Tokens owed by an oversized admission (the hardware splits messages
    /// larger than the bucket across refill intervals; charging the excess
    /// as debt keeps the long-run rate exact without modeling the split).
    debt: u64,
    /// Sub-token byte remainder: 104 B costs 3 tokens + 8 B carried to the
    /// next message, so the long-run byte rate is exact instead of paying a
    /// 32 B-quantization tax per message (a real limiter's byte counter).
    carry: u64,
    /// Virtual time of the last refill edge we accounted for.
    last_refill: Time,
    /// `cycles(params.interval_cycles)` cached: `try_acquire` is the
    /// per-message hot path, and the refill math is all in terms of this
    /// picosecond interval. Kept in sync by `reprogram`.
    interval_ps: Time,
}

impl TokenBucket {
    /// A bucket with explicit register values (resets full, as hardware).
    pub fn new(params: TokenBucketParams, mode: ShapeMode) -> Self {
        TokenBucket {
            tokens: params.bkt_size, // hardware resets with a full bucket
            debt: 0,
            carry: 0,
            interval_ps: cycles(params.interval_cycles),
            params,
            mode,
            last_refill: 0,
        }
    }

    /// Convenience: derive params for a target units/sec rate.
    pub fn for_rate(units_per_sec: f64, mode: ShapeMode) -> Self {
        Self::new(TokenBucketParams::for_rate(units_per_sec, mode), mode)
    }

    /// The register values currently programmed.
    pub fn params(&self) -> TokenBucketParams {
        self.params
    }

    /// Cost-unit mode (bytes vs messages).
    pub fn mode(&self) -> ShapeMode {
        self.mode
    }

    /// Reprogram the two registers (MMIO write; §5.3.1 measures ~10 µs for
    /// the PCIe round trips — that latency is modeled by the caller).
    /// Hardware clamps in-bucket tokens to the new size but does not zero
    /// them, so reconfiguration never stalls an active flow.
    pub fn reprogram(&mut self, now: Time, params: TokenBucketParams) {
        self.sync(now);
        self.params = params;
        self.interval_ps = cycles(params.interval_cycles);
        self.tokens = self.tokens.min(params.bkt_size);
    }

    /// Advance the refill clock to `now` (discrete interval edges).
    ///
    /// Refill is *coalesced*: no periodic refill events exist anywhere —
    /// all the edges since the last sync are accounted in O(1) arithmetic
    /// at the next decision, and a denied flow is woken exactly once, at
    /// the edge that satisfies it ([`Self::time_for_tokens`]).
    #[inline]
    fn sync(&mut self, now: Time) {
        let interval_ps = self.interval_ps;
        if now <= self.last_refill {
            return;
        }
        let elapsed = now - self.last_refill;
        let edges = elapsed / interval_ps;
        if edges > 0 {
            let mut added = edges.saturating_mul(self.params.refill_rate);
            // Refill pays outstanding debt before the bucket sees tokens.
            let pay = added.min(self.debt);
            self.debt -= pay;
            added -= pay;
            self.tokens = (self.tokens.saturating_add(added)).min(self.params.bkt_size);
            self.last_refill += edges * interval_ps;
        }
    }

    /// Tokens needed for a message of `cost` units, applying the byte
    /// carry (callers must call [`Self::apply_carry`] on admit).
    #[inline]
    fn tokens_for(&self, cost: u64) -> u64 {
        (cost + self.carry) / self.params.token_unit
    }

    #[inline]
    fn apply_carry(&mut self, cost: u64) {
        self.carry = (cost + self.carry) % self.params.token_unit;
    }

    /// Earliest time at which `needed` tokens will be available (counting
    /// outstanding debt).
    fn time_for_tokens(&self, needed: u64) -> Time {
        debug_assert!(self.debt + needed > self.tokens);
        let deficit = self.debt + needed - self.tokens;
        let edges = deficit.div_ceil(self.params.refill_rate);
        self.last_refill + edges * self.interval_ps
    }
}

impl Shaper for TokenBucket {
    fn try_acquire(&mut self, now: Time, cost: u64) -> Verdict {
        self.sync(now);
        let needed = match self.mode {
            ShapeMode::Gbps => self.tokens_for(cost),
            ShapeMode::Iops => 1,
        };
        // Oversized messages (> bucket): admit when the bucket is full and
        // charge the excess as debt — the hardware splits such messages
        // across intervals; debt keeps the long-run rate exact.
        let gate = needed.min(self.params.bkt_size);
        if self.debt == 0 && self.tokens >= gate {
            let from_bucket = needed.min(self.tokens);
            self.tokens -= from_bucket;
            self.debt = needed - from_bucket;
            if matches!(self.mode, ShapeMode::Gbps) {
                self.apply_carry(cost);
            }
            Verdict::Admit
        } else {
            Verdict::RetryAt(self.time_for_tokens(gate).max(now + 1))
        }
    }

    fn set_rate(&mut self, now: Time, units_per_sec: f64) {
        let params = TokenBucketParams::for_rate(units_per_sec, self.mode);
        self.reprogram(now, params);
    }

    fn rate(&self) -> f64 {
        self.params.nominal_rate()
    }

    fn state_bytes(&self) -> usize {
        // Two registers + token counter + timestamp: the paper's point is
        // O(1) per flow.
        4 * std::mem::size_of::<u64>()
    }

    fn name(&self) -> &'static str {
        "token_bucket"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shaping::replay;
    use crate::util::units::{Rate, MICROS, NANOS, SECONDS};

    fn saturating_arrivals(size: u64, total_bytes: u64) -> Vec<(Time, u64)> {
        // All arrivals at t=0: the queue is always backlogged.
        (0..total_bytes / size).map(|_| (0, size)).collect()
    }

    #[test]
    fn shapes_10gbps_within_point5_percent() {
        let target = Rate::gbps(10.0).as_bits_per_sec() / 8.0;
        let mut tb = TokenBucket::for_rate(target, ShapeMode::Gbps);
        let (admitted, last) = replay(&mut tb, &saturating_arrivals(1500, 40_000_000));
        let rate = admitted as f64 * SECONDS as f64 / last as f64;
        assert!(
            ((rate - target) / target).abs() < 0.005,
            "rate={rate:.3e} target={target:.3e}"
        );
    }

    #[test]
    fn table2_rates_all_accurate() {
        // Table 2's four SLO rows: 1, 10, 100, 1000 Gbps.
        for gbps in [1.0, 10.0, 100.0, 1000.0] {
            let target = Rate::gbps(gbps).as_bits_per_sec() / 8.0;
            let mut tb = TokenBucket::for_rate(target, ShapeMode::Gbps);
            let total = (target / 25.0) as u64; // ~40 ms of traffic, so the
            // initial full-bucket burst (≤100 µs of tokens) stays <0.3%.
            let (admitted, last) =
                replay(&mut tb, &saturating_arrivals(1500, total.max(15_000_000)));
            let rate = admitted as f64 * SECONDS as f64 / last as f64;
            let err = ((rate - target) / target).abs();
            assert!(err < 0.01, "{gbps} Gbps: err={:.3}%", err * 100.0);
        }
    }

    #[test]
    fn iops_mode_counts_messages_not_bytes() {
        let mut tb = TokenBucket::for_rate(300_000.0, ShapeMode::Iops); // 300K IOPS
        // Large 4KB messages must cost the same as small ones.
        let arrivals: Vec<(Time, u64)> = (0..30_000).map(|_| (0, 4096)).collect();
        let (_admitted, last) = replay(&mut tb, &arrivals);
        let iops = 30_000.0 * SECONDS as f64 / last as f64;
        assert!(
            ((iops - 300_000.0) / 300_000.0).abs() < 0.01,
            "iops={iops:.0}"
        );
    }

    #[test]
    fn burst_up_to_bucket_passes_instantly() {
        let params = TokenBucketParams {
            refill_rate: 100,
            bkt_size: 10_000,
            interval_cycles: 1000,
            token_unit: 32,
        };
        let mut tb = TokenBucket::new(params, ShapeMode::Gbps);
        // 10_000 tokens * 32 B = 320 KB burst admitted with zero delay.
        let mut burst_bytes = 0u64;
        let mut now = 0;
        loop {
            match tb.try_acquire(now, 1500) {
                Verdict::Admit => burst_bytes += 1500,
                Verdict::RetryAt(at) => {
                    now = at;
                    break;
                }
            }
        }
        assert!(burst_bytes >= 318_000, "burst={burst_bytes}");
        assert!(now > 0);
    }

    #[test]
    fn discrete_refill_edges_respected() {
        let params = TokenBucketParams {
            refill_rate: 47, // 47 tokens per 1000 cycles (4 us)
            bkt_size: 47,
            interval_cycles: 1000,
            token_unit: 32,
        };
        let mut tb = TokenBucket::new(params, ShapeMode::Gbps);
        // Drain the initial bucket.
        assert_eq!(tb.try_acquire(0, 47 * 32), Verdict::Admit);
        // Nothing before the first edge.
        match tb.try_acquire(cycles(999), 32) {
            Verdict::RetryAt(at) => assert_eq!(at, cycles(1000)),
            v => panic!("expected retry, got {v:?}"),
        }
        // At the edge tokens appear.
        assert_eq!(tb.try_acquire(cycles(1000), 32), Verdict::Admit);
    }

    #[test]
    fn reprogram_preserves_tokens_and_changes_rate() {
        let target1 = Rate::gbps(1.0).as_bits_per_sec() / 8.0;
        let target2 = Rate::gbps(100.0).as_bits_per_sec() / 8.0;
        let mut tb = TokenBucket::for_rate(target1, ShapeMode::Gbps);
        let _ = tb.try_acquire(0, 1500);
        tb.set_rate(10 * MICROS, target2);
        assert!((tb.rate() - target2).abs() / target2 < 0.01);
        // Still admits immediately (tokens were preserved).
        assert_eq!(tb.try_acquire(10 * MICROS + NANOS, 1500), Verdict::Admit);
    }

    #[test]
    fn nominal_rate_roundtrip() {
        for gbps in [1.0, 5.0, 10.0, 32.0, 100.0, 400.0, 1000.0] {
            let target = Rate::gbps(gbps).as_bits_per_sec() / 8.0;
            let p = TokenBucketParams::for_rate(target, ShapeMode::Gbps);
            let err = (p.nominal_rate() - target).abs() / target;
            assert!(err < 0.005, "{gbps} Gbps: nominal err {:.4}", err);
        }
    }

    #[test]
    fn oversized_message_does_not_deadlock() {
        let params = TokenBucketParams {
            refill_rate: 10,
            bkt_size: 100, // 3200 B max burst
            interval_cycles: 1000,
            token_unit: 32,
        };
        let mut tb = TokenBucket::new(params, ShapeMode::Gbps);
        // 64 KB message exceeds the bucket; must still eventually admit.
        let (admitted, _) = replay(&mut tb, &[(0, 65_536), (0, 65_536)]);
        assert_eq!(admitted, 2 * 65_536);
    }

    #[test]
    fn sync_is_stable_across_long_idle() {
        let target = Rate::gbps(10.0).as_bits_per_sec() / 8.0;
        let mut tb = TokenBucket::for_rate(target, ShapeMode::Gbps);
        // Idle for a second, bucket must cap at bkt_size (no overflow).
        let v = tb.try_acquire(SECONDS, 1500);
        assert_eq!(v, Verdict::Admit);
        assert!(tb.tokens <= tb.params.bkt_size);
    }
}
