//! Leaky-bucket shaper — resource-efficient but burst-hostile (§4.2).
//!
//! Considered and rejected by the paper for bursty request patterns: the
//! bucket drains at a constant rate and every admitted unit occupies bucket
//! space, so a burst larger than the (small) bucket is spread out even when
//! the long-run rate is far below the limit. Implemented as a virtual-time
//! leaky bucket (equivalent to GCRA): `deadline` tracks when the bucket
//! would drain to empty.

use super::{Shaper, Verdict};
use crate::util::units::{Time, SECONDS};

/// Virtual-time leaky bucket (GCRA-equivalent): constant drain, shallow
/// depth.
#[derive(Debug, Clone)]
pub struct LeakyBucket {
    /// Drain rate, units/sec.
    rate: f64,
    /// Bucket depth in units; small by design (the point of the ablation).
    depth: f64,
    /// Virtual drain horizon: the time at which the bucket empties.
    horizon: Time,
}

impl LeakyBucket {
    /// Depth defaults to ~10 µs of traffic — the classic shallow bucket.
    pub fn new(units_per_sec: f64) -> Self {
        LeakyBucket {
            rate: units_per_sec,
            depth: (units_per_sec * 10e-6).max(1.0),
            horizon: 0,
        }
    }

    /// A leaky bucket with an explicit depth in units.
    pub fn with_depth(units_per_sec: f64, depth_units: f64) -> Self {
        LeakyBucket {
            rate: units_per_sec,
            depth: depth_units.max(1.0),
            horizon: 0,
        }
    }

    #[inline]
    fn drain_time(&self, units: u64) -> Time {
        (units as f64 / self.rate * SECONDS as f64).ceil() as Time
    }
}

impl Shaper for LeakyBucket {
    fn try_acquire(&mut self, now: Time, cost: u64) -> Verdict {
        let level_at_now = if self.horizon > now {
            // Units still in the bucket, expressed in time-to-drain.
            (self.horizon - now) as f64 * self.rate / SECONDS as f64
        } else {
            0.0
        };
        if level_at_now + cost as f64 <= self.depth {
            let base = self.horizon.max(now);
            self.horizon = base + self.drain_time(cost);
            Verdict::Admit
        } else {
            // Earliest time the bucket has room for `cost` units.
            let excess = level_at_now + cost as f64 - self.depth;
            let wait = (excess / self.rate * SECONDS as f64).ceil() as Time;
            Verdict::RetryAt(now + wait.max(1))
        }
    }

    fn set_rate(&mut self, _now: Time, units_per_sec: f64) {
        self.rate = units_per_sec;
        self.depth = (units_per_sec * 10e-6).max(1.0);
    }

    fn rate(&self) -> f64 {
        self.rate
    }

    fn state_bytes(&self) -> usize {
        3 * std::mem::size_of::<u64>()
    }

    fn name(&self) -> &'static str {
        "leaky_bucket"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shaping::replay;
    use crate::util::units::{Rate, MICROS, SECONDS};

    #[test]
    fn long_run_rate_converges() {
        let target = Rate::gbps(10.0).as_bits_per_sec() / 8.0;
        let mut lb = LeakyBucket::new(target);
        let arrivals: Vec<(Time, u64)> = (0..20_000).map(|_| (0, 1500)).collect();
        let (admitted, last) = replay(&mut lb, &arrivals);
        let rate = admitted as f64 * SECONDS as f64 / last as f64;
        assert!(((rate - target) / target).abs() < 0.02, "rate={rate:.3e}");
    }

    #[test]
    fn burst_hostile_compared_to_token_bucket() {
        // A 64 KB burst after a long idle: the token bucket absorbs it, the
        // leaky bucket spreads it out. This is the paper's reason for
        // choosing the token bucket.
        let target = Rate::gbps(10.0).as_bits_per_sec() / 8.0;
        let burst: Vec<(Time, u64)> = (0..43).map(|_| (SECONDS, 1500)).collect(); // ~64 KB

        let mut lb = LeakyBucket::new(target);
        let (_, lb_done) = replay(&mut lb, &burst);

        let mut tb =
            crate::shaping::TokenBucket::for_rate(target, crate::shaping::ShapeMode::Gbps);
        let (_, tb_done) = replay(&mut tb, &burst);

        let lb_spread = lb_done - SECONDS;
        let tb_spread = tb_done - SECONDS;
        assert!(
            lb_spread > 4 * tb_spread.max(1),
            "leaky spread {lb_spread} vs token {tb_spread}"
        );
    }

    #[test]
    fn respects_depth_exactly() {
        let mut lb = LeakyBucket::with_depth(1e9, 3000.0); // 1 GB/s, 3000-unit depth
        assert_eq!(lb.try_acquire(0, 1500), Verdict::Admit);
        assert_eq!(lb.try_acquire(0, 1500), Verdict::Admit);
        match lb.try_acquire(0, 1500) {
            Verdict::RetryAt(at) => assert!(at > 0 && at <= 2 * MICROS),
            v => panic!("expected retry, got {v:?}"),
        }
    }
}
