//! Hierarchical shaper tree (§5's "precise **and scalable** traffic
//! shaping" at 10k-flow scale).
//!
//! Every flat shaper in this crate paces one flow and wakes that flow on
//! its own `(time, seq)` event — fine for the paper's 2–6 tenant figures,
//! hopeless at the ROADMAP's "millions of users": 10,000 flows would mean
//! 10,000 pending wakeups and 10,000 independent rate decisions per
//! refill interval. The [`ShaperTree`] composes per-flow shaping into
//! per-tenant and per-engine aggregates instead, the layered enforcement
//! both hardware-QoS surveys and the SLO-beyond-isolation line of work
//! argue is required for enforceability at scale:
//!
//! ```text
//!                   engine root (accelerator / SSD)
//!                   ceiling = profiled budget
//!                  /                          \
//!        tenant aggregate                 tenant aggregate
//!        min-guarantee + ceiling          min-guarantee + ceiling
//!        /        |                          |          \
//!    leaf …     leaf                       leaf …       leaf
//!    (per-flow guarantee/ceiling, or an owned flat `Shaper`)
//! ```
//!
//! Two leaf residencies coexist:
//!
//! - **Flat leaves** own a boxed [`Shaper`] (the hardware token bucket of
//!   §4.2, or the `Host_TS_*` software limiter) and no finite aggregate
//!   constraint anywhere above them. [`ShaperTree::try_acquire`] then
//!   *delegates* verdicts to the owned shaper verbatim — a tree with one
//!   unconstrained child is byte-identical to the bare child shaper (the
//!   regression guard for the flat→tree migration, pinned by a property
//!   test below and by `rust/tests/hierarchy.rs`).
//! - **Paced leaves** carry only a `(guarantee, ceiling)` budget and are
//!   released by the periodic tree pass: once per [`TreeConfig::tick_interval`]
//!   the tree replenishes credit top-down — min-guarantees first, then the
//!   work-conserving remainder by deficit-round-robin among the *waiting*
//!   children at each level, so unused sibling budget is borrowed instead
//!   of stranded. One tick serves the whole tree in O(active children):
//!   blocked flows wait inside the tree (the [`TreeVerdict::AwaitTick`]
//!   verdict), not as per-flow entries in the simulator's event queue.
//!
//! Determinism: the tree holds no RNG and schedules nothing itself — the
//! engine fires one `EngineEvent::ShaperTick` per tree on the shared
//! `(time, seq)` queue at fixed interval boundaries, and every pass
//! iterates waiting leaves in ascending flow id with a persistent DRR
//! cursor, so two runs (and two event-queue disciplines) replay the exact
//! same grant sequence.

use super::{ShapeMode, Shaper, Verdict};
use crate::util::units::{Time, MICROS, SECONDS};

/// Default pacing-pass cadence: fine enough that a 5 ms experiment sees
/// hundreds of replenish opportunities, coarse enough that a 10k-flow run
/// spends its events on traffic, not ticks.
pub const DEFAULT_TICK_INTERVAL: Time = 5 * MICROS;

/// How many ticks of budget a paced leaf may bank as burst credit before
/// grants stop accumulating (bounds burstiness without starving bursts).
const CREDIT_CAP_TICKS: f64 = 4.0;

/// Credit-cap floors so any message can eventually pass regardless of how
/// small the leaf's rate is: messages larger than the cap are admitted at
/// full credit and the excess charged as debt (exactly the oversized-
/// message rule of the hardware token bucket).
const CREDIT_FLOOR_BYTES: f64 = 16384.0;
const CREDIT_FLOOR_OPS: f64 = 8.0;

/// Deficit counters are capped at this many quanta so a child that cannot
/// use its share does not hoard unbounded priority.
const DEFICIT_CAP_QUANTA: f64 = 2.0;

/// Work-conserving borrow passes per tick (classic DRR rounds; the pool is
/// near-empty after two rounds in practice, the cap only bounds the loop).
const MAX_BORROW_ROUNDS: usize = 4;

/// A node's rate envelope: the assured floor and the borrowing cap, both
/// in units/sec (bytes/s in Gbps mode, messages/s in IOPS mode).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeBudget {
    /// Rate the node is guaranteed before any sibling borrows (units/sec).
    pub guarantee: f64,
    /// Rate the node may reach by borrowing unused sibling budget
    /// (units/sec; `f64::INFINITY` = unconstrained).
    pub ceiling: f64,
}

impl NodeBudget {
    /// No floor, no cap — the degenerate budget flat leaves hang under.
    pub const UNCONSTRAINED: NodeBudget = NodeBudget {
        guarantee: 0.0,
        ceiling: f64::INFINITY,
    };

    /// A budget with an assured floor and a borrowing cap.
    pub fn new(guarantee: f64, ceiling: f64) -> Self {
        NodeBudget {
            guarantee: guarantee.max(0.0),
            ceiling: ceiling.max(0.0),
        }
    }
}

/// Tree-level configuration.
#[derive(Debug, Clone, Copy)]
pub struct TreeConfig {
    /// Pacing-pass cadence; ticks fire on multiples of this interval.
    pub tick_interval: Time,
    /// Engine-root ceiling in units/sec (`None` = the physical device is
    /// the only aggregate limit).
    pub root_ceiling: Option<f64>,
}

impl Default for TreeConfig {
    fn default() -> Self {
        TreeConfig {
            tick_interval: DEFAULT_TICK_INTERVAL,
            root_ceiling: None,
        }
    }
}

/// Verdict of a tree admission attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TreeVerdict {
    /// Release the message now.
    Admit,
    /// The leaf's *own* shaper denied; retry at the hinted time (the
    /// caller schedules a per-flow wakeup exactly as with a flat shaper).
    RetryAt(Time),
    /// The aggregate hierarchy lacks credit; the leaf is parked inside the
    /// tree and will be re-driven by the next tree tick — the caller must
    /// ensure a tick is scheduled but must NOT schedule a per-flow event.
    AwaitTick,
}

/// One per-tenant aggregate node.
#[derive(Debug)]
struct TenantNode {
    budget: NodeBudget,
    /// DRR deficit carried across borrow rounds/ticks (units).
    deficit: f64,
}

impl TenantNode {
    fn unconstrained() -> Self {
        TenantNode {
            budget: NodeBudget::UNCONSTRAINED,
            deficit: 0.0,
        }
    }
}

/// One leaf (per-flow) node.
struct Leaf {
    tenant: usize,
    /// Owned flat shaper (hardware token bucket / software limiter);
    /// `None` for purely tree-paced leaves.
    shaper: Option<Box<dyn Shaper>>,
    budget: NodeBudget,
    mode: ShapeMode,
    /// Unspent aggregate credit in units; negative = oversized-message
    /// debt being repaid by future grants.
    credit: f64,
    /// DRR deficit within the tenant's borrow rounds (units).
    deficit: f64,
    /// Units granted in the current pacing pass (caps the per-tick total
    /// at `ceiling × tick` across the guarantee and borrow passes).
    pass_granted: f64,
    /// Leaf hit `AwaitTick` since the last tick and awaits credit.
    waiting: bool,
    /// Installed as a tree-paced leaf (aggregate credit gating applies).
    /// Flat leaves — including deliberately unshaped latency-critical
    /// flows — bypass the pacing machinery entirely, whatever envelopes
    /// their ancestors carry.
    paced: bool,
}

impl Leaf {
    /// Burst cap on banked credit (units): a few ticks of the leaf's
    /// assured rate, floored so one message always fits eventually.
    fn credit_cap(&self, tick_secs: f64) -> f64 {
        let floor = match self.mode {
            ShapeMode::Gbps => CREDIT_FLOOR_BYTES,
            ShapeMode::Iops => CREDIT_FLOOR_OPS,
        };
        let rate = if self.budget.ceiling.is_finite() {
            self.budget.guarantee.max(self.budget.ceiling)
        } else {
            self.budget.guarantee
        };
        (rate * tick_secs * CREDIT_CAP_TICKS).max(floor)
    }
}

impl std::fmt::Debug for Leaf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Leaf")
            .field("tenant", &self.tenant)
            .field("shaper", &self.shaper.as_ref().map(|s| s.name()))
            .field("budget", &self.budget)
            .field("credit", &self.credit)
            .field("waiting", &self.waiting)
            .finish()
    }
}

/// The per-engine shaper hierarchy: leaves (flows) under tenant aggregates
/// under one engine root. See the module docs for the release discipline.
#[derive(Debug)]
pub struct ShaperTree {
    cfg: TreeConfig,
    tenants: Vec<TenantNode>,
    /// Leaves indexed by flow id (dense; `None` = not resident here).
    leaves: Vec<Option<Leaf>>,
    /// Flow ids that returned [`TreeVerdict::AwaitTick`] since the last
    /// pass, in ascending order (maintained by sorted insertion).
    waiting: Vec<usize>,
    /// Rotating DRR start position among waiting tenants.
    tenant_cursor: usize,
    /// Scratch: distinct tenants of the current pass (reused allocation).
    pass_tenants: Vec<usize>,
    /// Scratch: per-pass member lists, aligned with `pass_tenants`.
    pass_members: Vec<Vec<usize>>,
}

impl ShaperTree {
    /// An empty tree for up to `n_flows` leaves.
    pub fn new(n_flows: usize, cfg: TreeConfig) -> Self {
        ShaperTree {
            cfg,
            tenants: Vec::new(),
            leaves: (0..n_flows).map(|_| None).collect(),
            waiting: Vec::new(),
            tenant_cursor: 0,
            pass_tenants: Vec::new(),
            pass_members: Vec::new(),
        }
    }

    /// Pacing-pass cadence.
    pub fn tick_interval(&self) -> Time {
        self.cfg.tick_interval.max(1)
    }

    /// Replace the engine-root ceiling (units/sec; `None` = unconstrained).
    pub fn set_root_ceiling(&mut self, ceiling: Option<f64>) {
        self.cfg.root_ceiling = ceiling;
    }

    /// Install (or overwrite) a tenant aggregate's budget. Tenants not
    /// installed are unconstrained pass-throughs.
    pub fn set_tenant(&mut self, tenant: usize, budget: NodeBudget) {
        self.ensure_tenant(tenant);
        self.tenants[tenant].budget = budget;
    }

    fn ensure_tenant(&mut self, tenant: usize) {
        while self.tenants.len() <= tenant {
            self.tenants.push(TenantNode::unconstrained());
        }
    }

    fn ensure_leaf_slot(&mut self, flow: usize) {
        while self.leaves.len() <= flow {
            self.leaves.push(None);
        }
    }

    /// Install a **flat leaf**: the flow is paced by its own shaper only
    /// (no aggregate constraint of its own). This is the migration path
    /// for every pre-tree program: `try_acquire` delegates verbatim.
    pub fn install_flat_leaf(
        &mut self,
        flow: usize,
        tenant: usize,
        shaper: Option<Box<dyn Shaper>>,
        mode: ShapeMode,
    ) {
        self.ensure_tenant(tenant);
        self.ensure_leaf_slot(flow);
        self.leaves[flow] = Some(Leaf {
            tenant,
            shaper,
            budget: NodeBudget::UNCONSTRAINED,
            mode,
            credit: 0.0,
            deficit: 0.0,
            pass_granted: 0.0,
            waiting: false,
            paced: false,
        });
        self.unwait(flow);
    }

    /// Install a **paced leaf**: released by tree ticks under its own
    /// `(guarantee, ceiling)` and its tenant's aggregate.
    pub fn install_paced_leaf(
        &mut self,
        flow: usize,
        tenant: usize,
        budget: NodeBudget,
        mode: ShapeMode,
    ) {
        self.ensure_tenant(tenant);
        self.ensure_leaf_slot(flow);
        // Reinstallation (renegotiation) keeps earned credit/debt: a new
        // contract must not mint a free burst.
        let (credit, deficit) = match &self.leaves[flow] {
            Some(l) => (l.credit, l.deficit),
            None => (0.0, 0.0),
        };
        self.leaves[flow] = Some(Leaf {
            tenant,
            shaper: None,
            budget,
            mode,
            credit,
            deficit,
            pass_granted: 0.0,
            waiting: false,
            paced: true,
        });
        self.unwait(flow);
    }

    /// Remove a departed flow's leaf entirely.
    pub fn remove_leaf(&mut self, flow: usize) {
        if let Some(slot) = self.leaves.get_mut(flow) {
            *slot = None;
        }
        self.unwait(flow);
    }

    /// Is a leaf resident for this flow?
    pub fn has_leaf(&self, flow: usize) -> bool {
        self.leaves.get(flow).is_some_and(|l| l.is_some())
    }

    /// The rate (units/sec) a leaf is currently programmed to: its own
    /// shaper's register rate for flat leaves, the ceiling (the borrowing
    /// cap — what "the register" limits) for paced leaves.
    pub fn leaf_rate(&self, flow: usize) -> Option<f64> {
        let leaf = self.leaves.get(flow)?.as_ref()?;
        match &leaf.shaper {
            Some(s) => Some(s.rate()),
            None if leaf.budget.ceiling.is_finite() => Some(leaf.budget.ceiling),
            None => None,
        }
    }

    /// Reprogram a leaf to `rate` — the tree analog of writing the
    /// hardware registers. Flat leaves forward to their shaper; paced
    /// leaves cap their ceiling at `rate` (and clamp the guarantee under
    /// it), which preserves the flat semantics every control-plane
    /// directive was written against: after `set_leaf_rate(r)` the flow
    /// cannot exceed `r`. Returns false — and changes nothing — when no
    /// leaf is resident or the leaf is deliberately unshaped (a
    /// latency-critical flow must not acquire a cap by accident).
    pub fn set_leaf_rate(&mut self, flow: usize, now: Time, rate: f64) -> bool {
        let Some(Some(leaf)) = self.leaves.get_mut(flow) else {
            return false;
        };
        match &mut leaf.shaper {
            Some(s) => {
                s.set_rate(now, rate);
                true
            }
            None if leaf.paced => {
                leaf.budget.ceiling = rate.max(0.0);
                leaf.budget.guarantee = leaf.budget.guarantee.min(leaf.budget.ceiling);
                true
            }
            None => false,
        }
    }

    /// Any leaf parked waiting for the next pacing pass?
    pub fn has_waiting(&self) -> bool {
        !self.waiting.is_empty()
    }

    /// The aligned boundary the next pacing pass should fire at: the first
    /// multiple of the tick interval strictly after `now`. Alignment (not
    /// `now + interval`) keeps tick times a pure function of the clock, so
    /// every event-queue discipline schedules identical instants.
    pub fn next_tick_at(&self, now: Time) -> Time {
        let t = self.tick_interval();
        (now / t + 1) * t
    }

    fn unwait(&mut self, flow: usize) {
        if let Ok(i) = self.waiting.binary_search(&flow) {
            self.waiting.remove(i);
        }
    }

    /// Ask to release a message of `cost` units for `flow` at `now`.
    ///
    /// Missing leaves admit (rejected flows never install one and drop
    /// upstream anyway). See [`TreeVerdict`] for the caller contract.
    pub fn try_acquire(&mut self, flow: usize, now: Time, cost: u64) -> TreeVerdict {
        let tick_secs = self.tick_interval() as f64 / SECONDS as f64;
        let Some(Some(leaf)) = self.leaves.get_mut(flow) else {
            return TreeVerdict::Admit;
        };
        if !leaf.paced {
            // Degenerate (flat) path: delegate to the owned shaper —
            // byte-identical to running the bare shaper.
            return match &mut leaf.shaper {
                Some(s) => match s.try_acquire(now, cost) {
                    Verdict::Admit => TreeVerdict::Admit,
                    Verdict::RetryAt(t) => TreeVerdict::RetryAt(t),
                },
                None => TreeVerdict::Admit,
            };
        }
        // Aggregate gate first (pure arithmetic — consumes nothing on
        // deny, so a later own-shaper deny cannot leak aggregate credit).
        let need = cost as f64;
        let cap = leaf.credit_cap(tick_secs);
        let passes = leaf.credit >= need || (need > cap && leaf.credit >= cap);
        if !passes {
            if !leaf.waiting {
                leaf.waiting = true;
                if let Err(i) = self.waiting.binary_search(&flow) {
                    self.waiting.insert(i, flow);
                }
            }
            return TreeVerdict::AwaitTick;
        }
        // Own shaper (hybrid leaves) may still defer with a precise hint.
        if let Some(s) = &mut leaf.shaper {
            if let Verdict::RetryAt(t) = s.try_acquire(now, cost) {
                return TreeVerdict::RetryAt(t);
            }
        }
        leaf.credit -= need; // may go negative: oversized-message debt
        TreeVerdict::Admit
    }

    /// One pacing pass: replenish credit top-down (guarantees first, then
    /// work-conserving DRR borrow at each level, restricted to leaves that
    /// actually waited), then drain the waiting set into `eligible` in
    /// ascending flow id for the caller to re-drive. O(waiting leaves).
    pub fn tick(&mut self, _now: Time, eligible: &mut Vec<usize>) {
        eligible.clear();
        if self.waiting.is_empty() {
            return;
        }
        let tick_secs = self.tick_interval() as f64 / SECONDS as f64;
        std::mem::swap(eligible, &mut self.waiting);
        self.waiting.clear();
        for &flow in eligible.iter() {
            if let Some(Some(leaf)) = self.leaves.get_mut(flow) {
                leaf.waiting = false;
                leaf.pass_granted = 0.0;
            }
        }
        // ---- group the waiting leaves by tenant (ids stay sorted) ----
        // Member lists make every later pass a sweep over exactly one
        // tenant's leaves instead of re-filtering the whole eligible set
        // per tenant (which would be O(waiting × tenants) per tick — real
        // money at 10k flows).
        let mut pass_tenants = std::mem::take(&mut self.pass_tenants);
        pass_tenants.clear();
        for &flow in eligible.iter() {
            let Some(Some(leaf)) = self.leaves.get(flow) else {
                continue;
            };
            if !pass_tenants.contains(&leaf.tenant) {
                pass_tenants.push(leaf.tenant);
            }
        }
        pass_tenants.sort_unstable();
        if pass_tenants.is_empty() {
            self.pass_tenants = pass_tenants;
            return;
        }
        let mut members = std::mem::take(&mut self.pass_members);
        for m in &mut members {
            m.clear();
        }
        while members.len() < pass_tenants.len() {
            members.push(Vec::new());
        }
        for &flow in eligible.iter() {
            let Some(Some(leaf)) = self.leaves.get(flow) else {
                continue;
            };
            let i = pass_tenants
                .binary_search(&leaf.tenant)
                .expect("tenant collected above");
            members[i].push(flow);
        }

        // Per-tenant demand: how much credit its waiting leaves could
        // still bank this pass (leaf rate ceilings and burst caps both
        // bound it), clipped by the tenant's own ceiling.
        let tenant_demand = |tree: &Self, tenant: usize, flows: &[usize]| -> f64 {
            let mut want = 0.0;
            for &flow in flows {
                if let Some(Some(leaf)) = tree.leaves.get(flow) {
                    want += tree.leaf_want(leaf, tick_secs);
                }
            }
            let ceil = tree
                .tenants
                .get(tenant)
                .map_or(f64::INFINITY, |t| t.budget.ceiling);
            want.min(if ceil.is_finite() {
                ceil * tick_secs
            } else {
                f64::INFINITY
            })
        };

        // ---- level 1: root pool → tenant allotments ----
        let mut pool = self
            .cfg
            .root_ceiling
            .map_or(f64::INFINITY, |c| c * tick_secs);
        let mut allot: Vec<f64> = Vec::with_capacity(pass_tenants.len());
        let mut wants: Vec<f64> = Vec::with_capacity(pass_tenants.len());
        for (i, &t) in pass_tenants.iter().enumerate() {
            let want = tenant_demand(self, t, &members[i]);
            let g = self
                .tenants
                .get(t)
                .map_or(0.0, |n| n.budget.guarantee * tick_secs);
            let grant = g.min(want).min(pool.max(0.0));
            pool -= grant;
            allot.push(grant);
            wants.push(want - grant);
        }
        // Work-conserving borrow of the remaining pool: DRR among tenants
        // that still want more, starting at the rotating cursor.
        if pool > 0.0 && wants.iter().any(|&w| w > 0.0) {
            let start = self.tenant_cursor % pass_tenants.len();
            if pool.is_finite() {
                for _ in 0..MAX_BORROW_ROUNDS {
                    let hungry = wants.iter().filter(|&&w| w > 0.0).count();
                    if hungry == 0 || pool <= f64::EPSILON {
                        break;
                    }
                    let quantum = pool / hungry as f64;
                    for k in 0..pass_tenants.len() {
                        let i = (start + k) % pass_tenants.len();
                        if wants[i] <= 0.0 {
                            continue;
                        }
                        let t = pass_tenants[i];
                        let node = &mut self.tenants[t];
                        node.deficit = (node.deficit + quantum)
                            .min(quantum * (1.0 + DEFICIT_CAP_QUANTA));
                        let give = wants[i].min(node.deficit).min(pool);
                        node.deficit -= give;
                        wants[i] -= give;
                        allot[i] += give;
                        pool -= give;
                    }
                }
            } else {
                // No root ceiling: every tenant may fill its own want.
                for i in 0..pass_tenants.len() {
                    allot[i] += wants[i];
                    wants[i] = 0.0;
                }
            }
            self.tenant_cursor = (start + 1) % pass_tenants.len();
        }

        // ---- level 2: tenant allotment → leaf credit ----
        for (a, m) in allot.iter().zip(&members) {
            self.grant_within_tenant(*a, tick_secs, m);
        }
        self.pass_tenants = pass_tenants;
        self.pass_members = members;
    }

    /// How much more credit a leaf could bank this pass: headroom to its
    /// burst cap, bounded by what its rate ceiling leaves of this tick's
    /// allowance (`ceiling × tick − already granted this pass`).
    fn leaf_want(&self, leaf: &Leaf, tick_secs: f64) -> f64 {
        Self::want_of(leaf, tick_secs)
    }

    /// Distribute one tenant's allotment over its waiting leaves (the
    /// pre-grouped `members` list, ascending flow id): guarantees first,
    /// then DRR for the work-conserving remainder.
    fn grant_within_tenant(&mut self, allotment: f64, tick_secs: f64, members: &[usize]) {
        let mut pool = allotment;
        // Guarantee pass.
        let mut member_want = 0usize; // count of leaves still wanting
        for &flow in members {
            let Some(Some(leaf)) = self.leaves.get_mut(flow) else {
                continue;
            };
            let want = Self::want_of(leaf, tick_secs);
            let g = (leaf.budget.guarantee * tick_secs).min(want).min(pool.max(0.0));
            leaf.credit += g;
            leaf.pass_granted += g;
            pool -= g;
            if want - g > 0.0 {
                member_want += 1;
            }
        }
        // Borrow pass: DRR the remainder among leaves that still want.
        if pool <= 0.0 || member_want == 0 || !pool.is_finite() {
            // An infinite pool only occurs with no finite constraint
            // anywhere above, in which case leaves are not paced at all.
            return;
        }
        for _ in 0..MAX_BORROW_ROUNDS {
            let hungry: usize = members
                .iter()
                .filter(|&&flow| {
                    self.leaves
                        .get(flow)
                        .and_then(|l| l.as_ref())
                        .is_some_and(|l| Self::want_of(l, tick_secs) > 0.0)
                })
                .count();
            if hungry == 0 || pool <= f64::EPSILON {
                break;
            }
            let quantum = pool / hungry as f64;
            for &flow in members {
                let Some(Some(leaf)) = self.leaves.get_mut(flow) else {
                    continue;
                };
                let want = Self::want_of(leaf, tick_secs);
                if want <= 0.0 {
                    continue;
                }
                leaf.deficit =
                    (leaf.deficit + quantum).min(quantum * (1.0 + DEFICIT_CAP_QUANTA));
                let give = want.min(leaf.deficit).min(pool);
                leaf.deficit -= give;
                leaf.credit += give;
                leaf.pass_granted += give;
                pool -= give;
            }
        }
    }

    /// [`Self::leaf_want`] as an associated function (no `&self` borrow),
    /// for use while the leaf itself is mutably borrowed.
    fn want_of(leaf: &Leaf, tick_secs: f64) -> f64 {
        let cap = leaf.credit_cap(tick_secs);
        let head = (cap - leaf.credit).max(0.0);
        let rate_cap = if leaf.budget.ceiling.is_finite() {
            (leaf.budget.ceiling * tick_secs - leaf.pass_granted).max(0.0)
        } else {
            f64::INFINITY
        };
        head.min(rate_cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shaping::{replay, TokenBucket};
    use crate::util::units::{Rate, MILLIS};

    /// Drive `acquire` through the tree with the infinitely-patient-queue
    /// discipline `replay` uses, recording every verdict, so flat-leaf
    /// delegation can be compared against the bare shaper *verdict by
    /// verdict*, not just in aggregate.
    fn tree_replay(
        tree: &mut ShaperTree,
        flow: usize,
        arrivals: &[(Time, u64)],
    ) -> (u64, Time, Vec<(Time, bool)>) {
        let mut admitted = 0u64;
        let mut last = 0;
        let mut free_at: Time = 0;
        let mut log = Vec::new();
        for &(t, cost) in arrivals {
            let mut now = t.max(free_at);
            loop {
                match tree.try_acquire(flow, now, cost) {
                    TreeVerdict::Admit => {
                        log.push((now, true));
                        admitted += cost;
                        last = now;
                        free_at = now;
                        break;
                    }
                    TreeVerdict::RetryAt(at) => {
                        log.push((now, false));
                        assert!(at > now, "retry hint must advance time");
                        now = at;
                    }
                    TreeVerdict::AwaitTick => {
                        panic!("unconstrained leaf must never await a tick")
                    }
                }
            }
        }
        (admitted, last, log)
    }

    fn arrivals(rate_bps: f64, secs: f64, size: u64) -> Vec<(Time, u64)> {
        // 2x-oversubscribed paced arrivals of `size`-byte messages.
        let bytes = (rate_bps * secs) as u64;
        let mut out = Vec::new();
        let mut t = 0u64;
        let mut sent = 0u64;
        while sent < bytes {
            out.push((t, size));
            sent += size;
            t += (size as f64 / (2.0 * rate_bps) * SECONDS as f64) as u64;
        }
        out
    }

    /// Satellite regression guard for the flat→tree migration: a tree with
    /// a single unconstrained child must be *byte-identical* to the bare
    /// child shaper — same admits, same retry instants, same totals.
    #[test]
    fn single_child_tree_is_byte_identical_to_bare_shaper() {
        use crate::testkit::{forall_cfg, Config, OneOf, PairOf};
        let gen = PairOf(
            OneOf(vec![1.0f64, 4.0, 10.0, 40.0]),
            OneOf(vec![64u64, 256, 1500, 4096]),
        );
        forall_cfg(&Config { cases: 16, ..Default::default() }, &gen, |&(gbps, size)| {
            let bytes_per_sec = Rate::gbps(gbps).as_bits_per_sec() / 8.0;
            let plan = arrivals(bytes_per_sec, 0.01, size);

            let mut bare = TokenBucket::for_rate(bytes_per_sec, ShapeMode::Gbps);
            let mut bare_log = Vec::new();
            let (bare_admitted, bare_last) = {
                // Mirror tree_replay's logging against the bare shaper.
                let mut admitted = 0u64;
                let mut last = 0;
                let mut free_at: Time = 0;
                for &(t, cost) in &plan {
                    let mut now = t.max(free_at);
                    loop {
                        match bare.try_acquire(now, cost) {
                            Verdict::Admit => {
                                bare_log.push((now, true));
                                admitted += cost;
                                last = now;
                                free_at = now;
                                break;
                            }
                            Verdict::RetryAt(at) => {
                                bare_log.push((now, false));
                                now = at;
                            }
                        }
                    }
                }
                (admitted, last)
            };

            let mut tree = ShaperTree::new(1, TreeConfig::default());
            tree.install_flat_leaf(
                0,
                0,
                Some(Box::new(TokenBucket::for_rate(bytes_per_sec, ShapeMode::Gbps))),
                ShapeMode::Gbps,
            );
            let (admitted, last, log) = tree_replay(&mut tree, 0, &plan);
            admitted == bare_admitted && last == bare_last && log == bare_log
        });
    }

    /// The same guard through the shared `replay` helper: wrapping does
    /// not change the long-run shaped rate.
    #[test]
    fn flat_leaf_matches_bare_shaper_through_replay() {
        let bytes_per_sec = Rate::gbps(10.0).as_bits_per_sec() / 8.0;
        let plan = arrivals(bytes_per_sec, 0.02, 1500);
        let mut bare = TokenBucket::for_rate(bytes_per_sec, ShapeMode::Gbps);
        let (bare_admitted, bare_last) = replay(&mut bare, &plan);
        let mut tree = ShaperTree::new(4, TreeConfig::default());
        tree.install_flat_leaf(
            0,
            0,
            Some(Box::new(TokenBucket::for_rate(bytes_per_sec, ShapeMode::Gbps))),
            ShapeMode::Gbps,
        );
        let (admitted, last, _) = tree_replay(&mut tree, 0, &plan);
        assert_eq!(admitted, bare_admitted);
        assert_eq!(last, bare_last);
    }

    /// Paced-leaf harness: drive saturating demand for `flows` leaves over
    /// `dur`, firing tree ticks exactly as the engine would, and return
    /// bytes admitted per leaf.
    fn run_paced(tree: &mut ShaperTree, flows: &[usize], dur: Time, size: u64) -> Vec<u64> {
        let max_flow = flows.iter().copied().max().unwrap_or(0);
        let mut admitted = vec![0u64; max_flow + 1];
        let mut eligible = Vec::new();
        // Kick everyone once so they park as waiting.
        for &f in flows {
            while tree.try_acquire(f, 0, size) == TreeVerdict::Admit {
                admitted[f] += size;
            }
        }
        let mut now = 0;
        while now < dur {
            now = tree.next_tick_at(now);
            tree.tick(now, &mut eligible);
            for &f in eligible.clone().iter() {
                while tree.try_acquire(f, now, size) == TreeVerdict::Admit {
                    admitted[f] += size;
                }
            }
        }
        admitted
    }

    fn gbps_of(bytes: u64, dur: Time) -> f64 {
        bytes as f64 * 8.0 / dur as f64 * (SECONDS as f64 / 1e9)
    }

    /// Guarantees hold under full contention: two tenants, both
    /// saturating, split the root by their guarantees.
    #[test]
    fn guarantees_enforced_under_contention() {
        let mut tree = ShaperTree::new(4, TreeConfig {
            tick_interval: DEFAULT_TICK_INTERVAL,
            root_ceiling: Some(Rate::gbps(20.0).as_bits_per_sec() / 8.0),
        });
        let g = |gbps: f64| Rate::gbps(gbps).as_bits_per_sec() / 8.0;
        tree.set_tenant(0, NodeBudget::new(g(12.0), g(20.0)));
        tree.set_tenant(1, NodeBudget::new(g(8.0), g(20.0)));
        tree.install_paced_leaf(0, 0, NodeBudget::new(g(12.0), g(20.0)), ShapeMode::Gbps);
        tree.install_paced_leaf(1, 1, NodeBudget::new(g(8.0), g(20.0)), ShapeMode::Gbps);
        let dur = 20 * MILLIS;
        let admitted = run_paced(&mut tree, &[0, 1], dur, 1500);
        let (a0, a1) = (gbps_of(admitted[0], dur), gbps_of(admitted[1], dur));
        assert!((a0 - 12.0).abs() / 12.0 < 0.05, "tenant0 {a0:.2} Gbps");
        assert!((a1 - 8.0).abs() / 8.0 < 0.05, "tenant1 {a1:.2} Gbps");
        // Aggregate never exceeds the root.
        assert!(a0 + a1 <= 20.0 * 1.02, "aggregate {:.2}", a0 + a1);
    }

    /// Work-conserving borrow: when one tenant goes idle, its sibling may
    /// exceed its guarantee up to its ceiling.
    #[test]
    fn idle_sibling_budget_is_borrowed() {
        let g = |gbps: f64| Rate::gbps(gbps).as_bits_per_sec() / 8.0;
        let mut tree = ShaperTree::new(4, TreeConfig {
            tick_interval: DEFAULT_TICK_INTERVAL,
            root_ceiling: Some(g(20.0)),
        });
        tree.set_tenant(0, NodeBudget::new(g(12.0), g(20.0)));
        tree.set_tenant(1, NodeBudget::new(g(8.0), g(20.0)));
        tree.install_paced_leaf(0, 0, NodeBudget::new(g(12.0), g(20.0)), ShapeMode::Gbps);
        tree.install_paced_leaf(1, 1, NodeBudget::new(g(8.0), g(20.0)), ShapeMode::Gbps);
        // Only tenant 0 offers traffic: it should borrow toward the root.
        let dur = 20 * MILLIS;
        let admitted = run_paced(&mut tree, &[0], dur, 1500);
        let a0 = gbps_of(admitted[0], dur);
        assert!(a0 > 12.0 * 1.3, "borrowed rate {a0:.2} Gbps should exceed the guarantee");
        assert!(a0 <= 20.0 * 1.02, "borrowed rate {a0:.2} must respect the root ceiling");
    }

    /// Leaf ceilings cap borrowing below the root.
    #[test]
    fn leaf_ceiling_caps_borrowing() {
        let g = |gbps: f64| Rate::gbps(gbps).as_bits_per_sec() / 8.0;
        let mut tree = ShaperTree::new(2, TreeConfig {
            tick_interval: DEFAULT_TICK_INTERVAL,
            root_ceiling: Some(g(20.0)),
        });
        tree.set_tenant(0, NodeBudget::new(g(5.0), g(20.0)));
        tree.install_paced_leaf(0, 0, NodeBudget::new(g(5.0), g(9.0)), ShapeMode::Gbps);
        let dur = 20 * MILLIS;
        let admitted = run_paced(&mut tree, &[0], dur, 1500);
        let a0 = gbps_of(admitted[0], dur);
        assert!((a0 - 9.0).abs() / 9.0 < 0.05, "ceiling-capped rate {a0:.2} Gbps");
    }

    /// Oversized messages pass via the debt rule instead of deadlocking.
    #[test]
    fn oversized_message_does_not_deadlock_paced_leaf() {
        let g = |gbps: f64| Rate::gbps(gbps).as_bits_per_sec() / 8.0;
        let mut tree = ShaperTree::new(1, TreeConfig {
            tick_interval: DEFAULT_TICK_INTERVAL,
            root_ceiling: Some(g(1.0)),
        });
        tree.install_paced_leaf(0, 0, NodeBudget::new(g(1.0), g(1.0)), ShapeMode::Gbps);
        // 64 KB message on a 1 Gbps leaf whose credit cap is ~16-250 KB.
        let mut eligible = Vec::new();
        let mut now = 0;
        let mut admitted = 0;
        for _ in 0..10_000 {
            match tree.try_acquire(0, now, 65_536) {
                TreeVerdict::Admit => {
                    admitted += 1;
                    if admitted == 4 {
                        break;
                    }
                }
                TreeVerdict::AwaitTick => {
                    now = tree.next_tick_at(now);
                    tree.tick(now, &mut eligible);
                }
                TreeVerdict::RetryAt(t) => now = t,
            }
        }
        assert!(admitted >= 4, "oversized messages starved (admitted {admitted})");
    }

    /// A removed leaf admits freely (drops are handled upstream) and the
    /// waiting set forgets it.
    #[test]
    fn removed_leaf_is_forgotten() {
        let g = |gbps: f64| Rate::gbps(gbps).as_bits_per_sec() / 8.0;
        let mut tree = ShaperTree::new(2, TreeConfig {
            tick_interval: DEFAULT_TICK_INTERVAL,
            root_ceiling: Some(g(1.0)),
        });
        tree.install_paced_leaf(0, 0, NodeBudget::new(0.0, g(1.0)), ShapeMode::Gbps);
        assert_eq!(tree.try_acquire(0, 0, 1_000_000), TreeVerdict::AwaitTick);
        assert!(tree.has_waiting());
        tree.remove_leaf(0);
        assert!(!tree.has_waiting());
        assert_eq!(tree.try_acquire(0, 0, 1_000_000), TreeVerdict::Admit);
    }

    /// `set_leaf_rate` on a paced leaf caps the ceiling (the clamp path
    /// control-plane SetRate directives rely on).
    #[test]
    fn set_leaf_rate_clamps_paced_ceiling() {
        let g = |gbps: f64| Rate::gbps(gbps).as_bits_per_sec() / 8.0;
        let mut tree = ShaperTree::new(1, TreeConfig {
            tick_interval: DEFAULT_TICK_INTERVAL,
            root_ceiling: Some(g(20.0)),
        });
        tree.install_paced_leaf(0, 0, NodeBudget::new(g(10.0), g(20.0)), ShapeMode::Gbps);
        assert!(tree.set_leaf_rate(0, 0, g(4.0)));
        assert_eq!(tree.leaf_rate(0), Some(g(4.0)));
        let dur = 20 * MILLIS;
        let admitted = run_paced(&mut tree, &[0], dur, 1500);
        let a0 = gbps_of(admitted[0], dur);
        assert!((a0 - 4.0).abs() / 4.0 < 0.06, "clamped rate {a0:.2} Gbps");
    }

    /// Tick times are aligned multiples of the interval — a pure function
    /// of the clock, never of who asked.
    #[test]
    fn tick_times_are_aligned() {
        let tree = ShaperTree::new(0, TreeConfig::default());
        let t = tree.tick_interval();
        assert_eq!(tree.next_tick_at(0), t);
        assert_eq!(tree.next_tick_at(1), t);
        assert_eq!(tree.next_tick_at(t), 2 * t);
        assert_eq!(tree.next_tick_at(t + 1), 2 * t);
    }
}
