//! Fixed-window counter shaper — cheap but bursty at window edges (§4.2).
//!
//! The window budget resets at fixed boundaries, so a flow can send a full
//! budget at the end of one window and another at the start of the next:
//! 2× the target rate over a span straddling the edge. The ablation bench
//! quantifies this edge burst, which is why the paper rejects it for SLO
//! shaping despite its tiny state.

use super::{Shaper, Verdict};
use crate::util::units::{Time, SECONDS};

/// Fixed-window counter: a per-window budget that resets at aligned
/// window boundaries.
#[derive(Debug, Clone)]
pub struct FixedWindow {
    rate: f64,
    window: Time,
    /// Units admitted in the current window.
    used: u64,
    /// Start of the current window (multiple of `window`).
    window_start: Time,
}

impl FixedWindow {
    /// A counter shaping to `units_per_sec` over windows of `window` ps.
    pub fn new(units_per_sec: f64, window: Time) -> Self {
        assert!(window > 0);
        FixedWindow {
            rate: units_per_sec,
            window,
            used: 0,
            window_start: 0,
        }
    }

    #[inline]
    fn budget(&self) -> u64 {
        (self.rate * self.window as f64 / SECONDS as f64).floor() as u64
    }

    #[inline]
    fn roll(&mut self, now: Time) {
        if now >= self.window_start + self.window {
            self.window_start = now - (now % self.window);
            self.used = 0;
        }
    }
}

impl Shaper for FixedWindow {
    fn try_acquire(&mut self, now: Time, cost: u64) -> Verdict {
        self.roll(now);
        let budget = self.budget();
        // Oversized costs clamp so a message larger than a whole window's
        // budget still passes (in an otherwise-empty window).
        let cost_clamped = cost.min(budget.max(1));
        if self.used + cost_clamped <= budget {
            self.used += cost_clamped;
            Verdict::Admit
        } else {
            Verdict::RetryAt(self.window_start + self.window)
        }
    }

    fn set_rate(&mut self, now: Time, units_per_sec: f64) {
        self.roll(now);
        self.rate = units_per_sec;
    }

    fn rate(&self) -> f64 {
        self.rate
    }

    fn state_bytes(&self) -> usize {
        3 * std::mem::size_of::<u64>()
    }

    fn name(&self) -> &'static str {
        "fixed_window"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shaping::replay;
    use crate::util::units::{Rate, MICROS, SECONDS};

    #[test]
    fn long_run_rate_converges() {
        let target = Rate::gbps(10.0).as_bits_per_sec() / 8.0;
        let mut fw = FixedWindow::new(target, 10 * MICROS);
        let arrivals: Vec<(Time, u64)> = (0..20_000).map(|_| (0, 1500)).collect();
        let (admitted, last) = replay(&mut fw, &arrivals);
        let rate = admitted as f64 * SECONDS as f64 / last as f64;
        assert!(((rate - target) / target).abs() < 0.05, "rate={rate:.3e}");
    }

    #[test]
    fn edge_burst_doubles_instantaneous_rate() {
        // Demonstrate the window-edge artifact: measure the max units
        // admitted in any half-window span.
        let target = 1e9; // 1 GB/s
        let window = 10 * MICROS;
        let mut fw = FixedWindow::new(target, window);
        let budget = (target * window as f64 / SECONDS as f64) as u64;
        // Idle during the first window, then hammer from 0.9*window.
        let mut admitted_times = Vec::new();
        let mut now = 9 * MICROS;
        let mut sent = 0;
        while sent < 2 * budget {
            match fw.try_acquire(now, 1000) {
                Verdict::Admit => {
                    admitted_times.push(now);
                    sent += 1000;
                }
                Verdict::RetryAt(at) => now = at,
            }
        }
        // Count units inside a 2 us span straddling the boundary at 10 us.
        let in_span = admitted_times
            .iter()
            .filter(|&&t| t >= 9 * MICROS && t < 11 * MICROS)
            .count() as u64
            * 1000;
        // Ideal would be 2 us * 1 GB/s = 2000 units * 1000. The fixed window
        // admits ~2 full budgets (20 us worth) in that span.
        assert!(
            in_span >= budget,
            "edge burst {in_span} should reach ≥1 full window budget {budget}"
        );
    }

    #[test]
    fn window_rolls_align_to_boundaries() {
        let mut fw = FixedWindow::new(1e6, 10 * MICROS);
        let _ = fw.try_acquire(25 * MICROS, 1);
        assert_eq!(fw.window_start, 20 * MICROS);
    }
}
