//! Sliding-window-log shaper — accurate but memory-hungry (§4.2).
//!
//! The paper prototyped this ("accurate by adding caches, but complex and
//! memory-inefficient to implement [in hardware]"): every admission is
//! logged with its timestamp and the rate check sums the log over the
//! trailing window. State grows with rate × window — the ablation bench's
//! memory column shows exactly why the token bucket won.

use super::{Shaper, Verdict};
use crate::util::units::{Time, SECONDS};
use std::collections::VecDeque;

/// Sliding-window log: every admission timestamped, rate checked over the
/// trailing window.
#[derive(Debug, Clone)]
pub struct SlidingLog {
    rate: f64,
    window: Time,
    /// (admit time, units) log over the trailing window.
    log: VecDeque<(Time, u64)>,
    /// Running sum of units in `log`.
    in_window: u64,
    /// High-water mark of log entries (memory accounting).
    peak_entries: usize,
}

impl SlidingLog {
    /// A log shaping to `units_per_sec` over a trailing `window` ps.
    pub fn new(units_per_sec: f64, window: Time) -> Self {
        assert!(window > 0);
        SlidingLog {
            rate: units_per_sec,
            window,
            log: VecDeque::new(),
            in_window: 0,
            peak_entries: 0,
        }
    }

    #[inline]
    fn budget(&self) -> u64 {
        (self.rate * self.window as f64 / SECONDS as f64).floor() as u64
    }

    fn expire(&mut self, now: Time) {
        while let Some(&(t, units)) = self.log.front() {
            // An admission contributes for a full window after it happened.
            if now.saturating_sub(t) > self.window {
                self.log.pop_front();
                self.in_window -= units;
            } else {
                break;
            }
        }
    }

    /// High-water mark of log entries (the ablation's memory column).
    pub fn peak_entries(&self) -> usize {
        self.peak_entries
    }
}

impl Shaper for SlidingLog {
    fn try_acquire(&mut self, now: Time, cost: u64) -> Verdict {
        self.expire(now);
        let budget = self.budget();
        let cost_clamped = cost.min(budget.max(1));
        if self.in_window + cost_clamped <= budget {
            self.log.push_back((now, cost_clamped));
            self.in_window += cost_clamped;
            self.peak_entries = self.peak_entries.max(self.log.len());
            Verdict::Admit
        } else {
            // Room appears when enough old entries age out: walk the log
            // until the freed units cover the deficit.
            let deficit = self.in_window + cost_clamped - budget;
            let mut freed = 0u64;
            for &(t, units) in &self.log {
                freed += units;
                if freed >= deficit {
                    return Verdict::RetryAt((t + self.window + 1).max(now + 1));
                }
            }
            Verdict::RetryAt(now + self.window)
        }
    }

    fn set_rate(&mut self, _now: Time, units_per_sec: f64) {
        self.rate = units_per_sec;
    }

    fn rate(&self) -> f64 {
        self.rate
    }

    fn state_bytes(&self) -> usize {
        // Live log entries: 16 B each. This is the O(rate·window) cost.
        self.log.len() * 16 + 4 * 8
    }

    fn name(&self) -> &'static str {
        "sliding_log"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shaping::replay;
    use crate::util::units::{Rate, MICROS, SECONDS};

    #[test]
    fn long_run_rate_converges() {
        let target = Rate::gbps(10.0).as_bits_per_sec() / 8.0;
        let mut sl = SlidingLog::new(target, 100 * MICROS);
        let arrivals: Vec<(Time, u64)> = (0..20_000).map(|_| (0, 1500)).collect();
        let (admitted, last) = replay(&mut sl, &arrivals);
        let rate = admitted as f64 * SECONDS as f64 / last as f64;
        assert!(((rate - target) / target).abs() < 0.02, "rate={rate:.3e}");
    }

    #[test]
    fn no_window_edge_artifact() {
        // Unlike the fixed window, the sliding log enforces the budget over
        // EVERY trailing window, so the straddle-span admission stays ~1x.
        let target = 1e9;
        let window = 10 * MICROS;
        let mut sl = SlidingLog::new(target, window);
        let budget = (target * window as f64 / SECONDS as f64) as u64;
        let mut now = 9 * MICROS;
        let mut sent = 0u64;
        let mut in_span = 0u64;
        while sent < 3 * budget {
            match sl.try_acquire(now, 1000) {
                Verdict::Admit => {
                    sent += 1000;
                    if now < 11 * MICROS {
                        in_span += 1000;
                    }
                }
                Verdict::RetryAt(at) => now = at,
            }
            if now >= 50 * MICROS {
                break;
            }
        }
        // The 2 us straddle span can admit at most ~1 budget (the window
        // constraint applies continuously).
        assert!(
            in_span <= budget + 1000,
            "in_span={in_span} budget={budget}"
        );
    }

    #[test]
    fn memory_grows_with_rate() {
        let window = 100 * MICROS;
        let mut small = SlidingLog::new(1e8, window);
        let mut large = SlidingLog::new(1e10, window);
        let arrivals: Vec<(Time, u64)> = (0..50_000).map(|_| (0, 64)).collect();
        let _ = replay(&mut small, &arrivals);
        let _ = replay(&mut large, &arrivals);
        assert!(
            large.peak_entries() > 10 * small.peak_entries().max(1),
            "large={} small={}",
            large.peak_entries(),
            small.peak_entries()
        );
    }
}
