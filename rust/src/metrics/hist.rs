//! Log-bucketed latency/throughput histogram with percentile queries.
//!
//! This is an HDR-histogram-style structure (hdrhistogram is not in the
//! offline registry): values are bucketed by (exponent, sub-bucket) with a
//! configurable number of significant-digit bits, giving bounded relative
//! error at every magnitude. All SLO tail metrics in the evaluation
//! (95th/99th/99.9th latency, throughput percentiles of Fig 6 / Table 3)
//! are computed from these histograms.

/// Number of linear sub-buckets per octave; 64 gives <1.6% relative error.
const SUB_BITS: u32 = 6;
const SUB_COUNT: usize = 1 << SUB_BITS;

/// Log-bucketed histogram over u64 values (picoseconds, IOPS, bytes...).
///
/// Equality is bucket-for-bucket (plus the exact total/sum/min/max), which
/// is what the merge property tests in `rust/tests/properties.rs` pin:
/// `merge(a, b)` must equal the histogram of the concatenated samples.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    /// counts[octave][sub]
    counts: Vec<[u64; SUB_COUNT]>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: vec![[0; SUB_COUNT]; 64 - SUB_BITS as usize + 1],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    #[inline]
    fn index(value: u64) -> (usize, usize) {
        if value < SUB_COUNT as u64 {
            return (0, value as usize);
        }
        let octave = 63 - value.leading_zeros(); // position of msb, >= SUB_BITS
        let shift = octave - SUB_BITS + 1;
        let sub = (value >> shift) as usize & (SUB_COUNT - 1);
        ((octave - SUB_BITS + 1) as usize, sub)
    }

    /// Representative (bucket midpoint) value for a bucket.
    ///
    /// For `octave >= 1` the bucket covers `[sub' << octave,
    /// (sub' + 1) << octave)` where `sub' = SUB_COUNT/2 + (sub &
    /// (SUB_COUNT/2 - 1))` — the top `SUB_BITS + 1` bits of the original
    /// value at scale `2^octave`. The midpoint is `(sub' << octave) +
    /// 2^(octave-1)`. Overflow-safety: [`Histogram::index`] caps `octave`
    /// at `64 - SUB_BITS = 58` and `sub' <= SUB_COUNT - 1`, so the
    /// midpoint is at most `(63 << 58) + 2^57 < 2^64`. Only the top
    /// bucket's *upper edge* (exactly `2^64`) would not fit a u64, and it
    /// is never materialized. The round-trip property test below pins
    /// this for random values including `u64::MAX`.
    fn value_at(octave: usize, sub: usize) -> u64 {
        if octave == 0 {
            return sub as u64;
        }
        debug_assert!(octave <= 64 - SUB_BITS as usize, "octave out of range");
        let half = SUB_COUNT as u64 / 2;
        let sub_prime = half + (sub as u64 & (half - 1));
        (sub_prime << octave) + (1u64 << (octave - 1))
    }

    /// Record one observation.
    #[inline]
    pub fn record(&mut self, value: u64) {
        let (o, s) = Self::index(value);
        self.counts[o][s] += 1;
        self.total += 1;
        self.sum += value as u128;
        self.min = self.min.min(value);
        self.max = self.max.max(value);
    }

    /// Record `n` identical observations.
    pub fn record_n(&mut self, value: u64, n: u64) {
        let (o, s) = Self::index(value);
        self.counts[o][s] += n;
        self.total += n;
        self.sum += value as u128 * n as u128;
        self.min = self.min.min(value);
        self.max = self.max.max(value);
    }

    pub fn count(&self) -> u64 {
        self.total
    }
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }
    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> u64 {
        self.max
    }
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Value at quantile `q` in [0,1]. Returns exact min/max at the edges.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        if q <= 0.0 {
            return self.min();
        }
        if q >= 1.0 {
            return self.max;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (o, subs) in self.counts.iter().enumerate() {
            for (s, &c) in subs.iter().enumerate() {
                acc += c;
                if acc >= target {
                    return Self::value_at(o, s).min(self.max).max(self.min);
                }
            }
        }
        self.max
    }

    /// Convenience percentile (`p` in [0, 100]).
    pub fn percentile(&self, p: f64) -> u64 {
        self.quantile(p / 100.0)
    }

    /// Standard deviation of recorded values (approximate: bucket midpoints).
    pub fn std_dev(&self) -> f64 {
        if self.total < 2 {
            return 0.0;
        }
        let mean = self.mean();
        let mut var = 0.0f64;
        for (o, subs) in self.counts.iter().enumerate() {
            for (s, &c) in subs.iter().enumerate() {
                if c > 0 {
                    let v = Self::value_at(o, s) as f64;
                    var += c as f64 * (v - mean) * (v - mean);
                }
            }
        }
        (var / self.total as f64).sqrt()
    }

    /// Coefficient of variation (std/mean) — the paper's "variance" metric
    /// for throughput stability is reported as a relative spread.
    pub fn cv(&self) -> f64 {
        let m = self.mean();
        if m == 0.0 {
            0.0
        } else {
            self.std_dev() / m
        }
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (o, subs) in other.counts.iter().enumerate() {
            for (s, &c) in subs.iter().enumerate() {
                self.counts[o][s] += c;
            }
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Iterate (value, count) over non-empty buckets, ascending.
    pub fn iter(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        self.counts.iter().enumerate().flat_map(|(o, subs)| {
            subs.iter()
                .enumerate()
                .filter(|(_, &c)| c > 0)
                .map(move |(s, &c)| (Self::value_at(o, s), c))
        })
    }

    /// Empirical CDF as (value, cumulative fraction) points, for figures.
    pub fn cdf(&self) -> Vec<(u64, f64)> {
        let mut acc = 0u64;
        self.iter()
            .map(|(v, c)| {
                acc += c;
                (v, acc as f64 / self.total as f64)
            })
            .collect()
    }
}

/// A fixed set of [`Histogram`] windows recorded side by side — the
/// "windowed per-era snapshot" primitive of the observability plane.
///
/// Each observation is routed to an explicit window index (e.g. fault era
/// 0/1/2), so per-window distributions stay queryable individually while
/// [`merged`](WindowedHistogram::merged) folds them back into one — the
/// same `merge` that rolls per-flow histograms up the tenant→engine
/// hierarchy and across sweep threads.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WindowedHistogram {
    windows: Vec<Histogram>,
}

impl WindowedHistogram {
    /// Create `n` empty windows.
    pub fn new(n: usize) -> Self {
        WindowedHistogram {
            windows: (0..n).map(|_| Histogram::new()).collect(),
        }
    }

    /// Number of windows.
    pub fn len(&self) -> usize {
        self.windows.len()
    }

    /// True when there are no windows at all.
    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    /// Record one observation into window `w`.
    #[inline]
    pub fn record(&mut self, w: usize, value: u64) {
        self.windows[w].record(value);
    }

    /// The histogram of window `w`.
    pub fn window(&self, w: usize) -> &Histogram {
        &self.windows[w]
    }

    /// All windows merged into one histogram.
    pub fn merged(&self) -> Histogram {
        let mut out = Histogram::new();
        for w in &self.windows {
            out.merge(w);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn small_values_exact() {
        let mut h = Histogram::new();
        for v in 0..SUB_COUNT as u64 {
            h.record(v);
        }
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), SUB_COUNT as u64 - 1);
        // Small values land in exact buckets.
        assert_eq!(h.quantile(0.0), 0);
    }

    #[test]
    fn relative_error_bounded() {
        let mut h = Histogram::new();
        let v = 1_234_567_890u64;
        h.record(v);
        let q = h.quantile(0.5);
        let err = (q as f64 - v as f64).abs() / v as f64;
        assert!(err < 0.04, "err={err} q={q}");
    }

    #[test]
    fn percentiles_ordered() {
        let mut h = Histogram::new();
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..100_000 {
            h.record(rng.range_u64(100, 1_000_000));
        }
        let p50 = h.percentile(50.0);
        let p90 = h.percentile(90.0);
        let p99 = h.percentile(99.0);
        let p999 = h.percentile(99.9);
        assert!(p50 <= p90 && p90 <= p99 && p99 <= p999);
        assert!(h.min() <= p50 && p999 <= h.max());
    }

    #[test]
    fn uniform_median_close() {
        let mut h = Histogram::new();
        let mut rng = crate::util::Rng::new(8);
        for _ in 0..200_000 {
            h.record(rng.range_u64(0, 1_000_000));
        }
        let p50 = h.percentile(50.0) as f64;
        assert!((p50 - 500_000.0).abs() / 500_000.0 < 0.05, "p50={p50}");
    }

    #[test]
    fn merge_matches_combined() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        let mut rng = crate::util::Rng::new(21);
        for i in 0..10_000 {
            let v = rng.range_u64(1, 1 << 40);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            c.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.percentile(99.0), c.percentile(99.0));
    }

    #[test]
    fn cdf_monotone_ends_at_one() {
        let mut h = Histogram::new();
        for v in [5u64, 10, 10, 200, 3_000_000] {
            h.record(v);
        }
        let cdf = h.cdf();
        for w in cdf.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn index_value_at_round_trip_stays_in_bucket() {
        // Property: for any u64 value — including u64::MAX and the whole
        // top octave, where a careless midpoint reconstruction would
        // overflow — the bucket representative (a) indexes back into the
        // same bucket and (b) sits within the structure's relative-error
        // bound: |rep - v| * SUB_COUNT <= v, i.e. <= 1/64 ≈ 1.6%.
        let mut cases: Vec<u64> = vec![
            0,
            1,
            SUB_COUNT as u64 - 1,
            SUB_COUNT as u64,
            SUB_COUNT as u64 + 1,
            (1 << 62) - 1,
            1 << 62,
            (1 << 63) - 1,
            1 << 63,
            u64::MAX - 1,
            u64::MAX,
        ];
        let mut rng = crate::util::Rng::new(0xC0FFEE);
        for _ in 0..20_000 {
            // Random magnitude first (uniform octave coverage), then
            // random bits below the msb.
            let bits = rng.range_u64(1, 64) as u32;
            let raw = rng.next_u64();
            cases.push((raw >> (64 - bits)) | (1u64 << (bits - 1)));
        }
        for &v in &cases {
            let (o, s) = Histogram::index(v);
            let rep = Histogram::value_at(o, s);
            assert_eq!(
                Histogram::index(rep),
                (o, s),
                "representative {rep} escapes the bucket of {v}"
            );
            if v < SUB_COUNT as u64 {
                assert_eq!(rep, v, "sub-octave buckets are exact");
            } else {
                let err = (rep as i128 - v as i128).unsigned_abs();
                assert!(
                    err * SUB_COUNT as u128 <= v as u128,
                    "representative {rep} off by {err} for {v} (> 1/{SUB_COUNT})"
                );
            }
        }
    }

    #[test]
    fn windowed_histogram_keeps_windows_separate_and_merges() {
        let mut w = WindowedHistogram::new(3);
        w.record(0, 100);
        w.record(0, 200);
        w.record(2, 9_000);
        assert_eq!(w.window(0).count(), 2);
        assert_eq!(w.window(1).count(), 0);
        assert_eq!(w.window(2).count(), 1);
        let mut all = Histogram::new();
        for v in [100u64, 200, 9_000] {
            all.record(v);
        }
        assert_eq!(w.merged(), all);
    }

    #[test]
    fn record_n_equivalent() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record_n(777, 5);
        for _ in 0..5 {
            b.record(777);
        }
        assert_eq!(a.count(), b.count());
        assert_eq!(a.mean(), b.mean());
        assert_eq!(a.percentile(50.0), b.percentile(50.0));
    }
}
