//! Measurement infrastructure: histograms, throughput samplers, flow stats.
//!
//! Every experiment in the paper reports one of three things — a throughput
//! distribution sampled over fixed windows (Fig 6, Table 3), a latency tail
//! (§5.2, Fig 9), or an aggregate achieved-vs-SLO ratio (Fig 3, 8, 11).
//! [`FlowMetrics`] collects all three per flow; [`ThroughputSampler`]
//! implements the paper's "sample throughput every N requests" methodology.

pub mod hist;

pub use hist::{Histogram, WindowedHistogram};

use crate::util::units::{throughput, Rate, Time, SECONDS};

/// Per-flow rolling measurement state.
#[derive(Debug, Clone, Default)]
pub struct FlowMetrics {
    /// End-to-end latency of completed requests (ps).
    pub latency: Histogram,
    /// Completed requests.
    pub completed: u64,
    /// Rejected / dropped requests (admission control or queue overflow).
    pub dropped: u64,
    /// Total payload bytes completed.
    pub bytes: u64,
    /// First/last completion timestamps for aggregate throughput.
    pub first_completion: Option<Time>,
    pub last_completion: Option<Time>,
}

impl FlowMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_complete(&mut self, now: Time, submitted_at: Time, bytes: u64) {
        self.latency.record(now.saturating_sub(submitted_at));
        self.completed += 1;
        self.bytes += bytes;
        if self.first_completion.is_none() {
            self.first_completion = Some(now);
        }
        self.last_completion = Some(now);
    }

    pub fn on_drop(&mut self) {
        self.dropped += 1;
    }

    /// Aggregate goodput over the active window.
    pub fn goodput(&self) -> Rate {
        match (self.first_completion, self.last_completion) {
            (Some(a), Some(b)) if b > a => throughput(self.bytes, b - a),
            _ => Rate::ZERO,
        }
    }

    /// Aggregate operation rate (completions per second).
    pub fn ops_per_sec(&self) -> f64 {
        match (self.first_completion, self.last_completion) {
            (Some(a), Some(b)) if b > a => {
                self.completed as f64 * SECONDS as f64 / (b - a) as f64
            }
            _ => 0.0,
        }
    }
}

/// Samples achieved throughput every `window_requests` completions, as in
/// §5.2 ("we sample the throughput of the two users every 500 requests").
/// The resulting distribution of window rates is the CDF of Fig 6.
#[derive(Debug, Clone)]
pub struct ThroughputSampler {
    window_requests: u64,
    in_window: u64,
    window_bytes: u64,
    window_start: Option<Time>,
    /// Sampled window rates in bits/sec, recorded into a histogram
    /// (value = Kbit/s to keep integer resolution sensible).
    pub samples: Histogram,
    /// Also kept raw for exact CDF plots.
    pub raw: Vec<f64>,
}

impl ThroughputSampler {
    pub fn new(window_requests: u64) -> Self {
        assert!(window_requests > 0);
        ThroughputSampler {
            window_requests,
            in_window: 0,
            window_bytes: 0,
            window_start: None,
            samples: Histogram::new(),
            raw: Vec::new(),
        }
    }

    /// Record a completion; closes the window when full.
    pub fn on_complete(&mut self, now: Time, bytes: u64) {
        if self.window_start.is_none() {
            self.window_start = Some(now);
            return; // first completion anchors the window
        }
        self.in_window += 1;
        self.window_bytes += bytes;
        if self.in_window >= self.window_requests {
            let start = self.window_start.unwrap();
            if now > start {
                let bps = self.window_bytes as f64 * 8.0 * SECONDS as f64
                    / (now - start) as f64;
                self.samples.record((bps / 1e3) as u64); // Kbit/s buckets
                self.raw.push(bps);
            }
            self.in_window = 0;
            self.window_bytes = 0;
            self.window_start = Some(now);
        }
    }

    /// Record a completion counted in operations (IOPS mode): bytes ignored.
    pub fn on_complete_op(&mut self, now: Time) {
        if self.window_start.is_none() {
            self.window_start = Some(now);
            return;
        }
        self.in_window += 1;
        if self.in_window >= self.window_requests {
            let start = self.window_start.unwrap();
            if now > start {
                let iops =
                    self.in_window as f64 * SECONDS as f64 / (now - start) as f64;
                self.samples.record(iops as u64);
                self.raw.push(iops);
            }
            self.in_window = 0;
            self.window_start = Some(now);
        }
    }

    /// Deviation of a quantile of the sampled distribution from `target`,
    /// as a signed fraction — this is exactly Table 3's metric.
    pub fn quantile_deviation(&self, q: f64, target: f64) -> f64 {
        if self.raw.is_empty() || target == 0.0 {
            return 0.0;
        }
        let mut sorted = self.raw.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((q * (sorted.len() - 1) as f64).round() as usize)
            .min(sorted.len() - 1);
        (sorted[idx] - target) / target
    }

    /// Coefficient of variation of sampled window rates ("throughput
    /// variance" headline: Arcus keeps it <1%).
    pub fn cv(&self) -> f64 {
        if self.raw.len() < 2 {
            return 0.0;
        }
        let n = self.raw.len() as f64;
        let mean = self.raw.iter().sum::<f64>() / n;
        if mean == 0.0 {
            return 0.0;
        }
        let var = self.raw.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        var.sqrt() / mean
    }

    /// Mean of sampled window rates (bps or IOPS depending on mode).
    pub fn mean(&self) -> f64 {
        if self.raw.is_empty() {
            return 0.0;
        }
        self.raw.iter().sum::<f64>() / self.raw.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{MICROS, NANOS};

    #[test]
    fn flow_metrics_goodput() {
        let mut m = FlowMetrics::new();
        // 10 completions of 1250 bytes each, 1 us apart => 10 Gbps.
        for i in 0..10u64 {
            m.on_complete(i * MICROS, 0, 1250);
        }
        let g = m.goodput();
        // 9 us window, 12500 bytes... first window anchors at t=0.
        assert!((g.as_gbps() - 12500.0 * 8.0 / 9000.0).abs() < 0.01);
        assert_eq!(m.completed, 10);
    }

    #[test]
    fn sampler_constant_rate_zero_cv() {
        let mut s = ThroughputSampler::new(100);
        // Perfectly paced: 1 KB every 100 ns => 81.92 Gbps.
        for i in 0..5_000u64 {
            s.on_complete(i * 100 * NANOS, 1024);
        }
        assert!(s.raw.len() >= 40);
        assert!(s.cv() < 1e-9, "cv={}", s.cv());
        let bps = s.mean();
        assert!((bps - 1024.0 * 8.0 / 100e-9).abs() / bps < 1e-6);
    }

    #[test]
    fn sampler_deviation_sign() {
        let mut s = ThroughputSampler::new(10);
        for i in 0..200u64 {
            s.on_complete(i * 100 * NANOS, 1024);
        }
        let actual = s.mean();
        assert!(s.quantile_deviation(0.5, actual * 2.0) < 0.0);
        assert!(s.quantile_deviation(0.5, actual / 2.0) > 0.0);
    }

    #[test]
    fn iops_mode_counts_ops() {
        let mut s = ThroughputSampler::new(500);
        // 1 op per microsecond = 1M IOPS.
        for i in 0..5_000u64 {
            s.on_complete_op(i * MICROS);
        }
        assert!(!s.raw.is_empty());
        let iops = s.mean();
        assert!((iops - 1e6).abs() / 1e6 < 0.01, "iops={iops}");
    }
}
