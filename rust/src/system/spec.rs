//! Experiment specification: which management architecture, which devices,
//! which flows, and the flow-lifecycle schedule — the typed form of an
//! experiment config file.

use crate::accel::AccelModel;
use crate::api::AdaptiveConfig;
use crate::faults::FaultSpec;
use crate::flow::{FlowSpec, Slo};
use crate::pcie::fabric::FabricConfig;
use crate::storage::nvme::SsdConfig;
use crate::util::units::{Rate, Time, MICROS, MILLIS};

/// Management architecture under test (§5.1 Configurations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Arcus: per-flow hardware token buckets + SLO-aware control plane.
    Arcus,
    /// Kernel-bypass access, weighted-round-robin arbitration, no shaping.
    HostNoTs,
    /// ReFlex-style on-host software shaping (fine timers, polling).
    HostTsReflex,
    /// Firecracker-style on-host software shaping (coarser timers).
    HostTsFirecracker,
    /// PANIC interface: hypervisor-bypassed, priority + WFQ scheduling at
    /// the accelerator, no shaping, no proactive SLO management.
    BypassedPanic,
}

impl Mode {
    /// Every management architecture, in presentation order.
    pub const ALL: [Mode; 5] = [
        Mode::Arcus,
        Mode::HostNoTs,
        Mode::HostTsReflex,
        Mode::HostTsFirecracker,
        Mode::BypassedPanic,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Mode::Arcus => "arcus",
            Mode::HostNoTs => "host_no_ts",
            Mode::HostTsReflex => "host_ts_reflex",
            Mode::HostTsFirecracker => "host_ts_firecracker",
            Mode::BypassedPanic => "bypassed_panic",
        }
    }

    pub fn by_name(s: &str) -> Option<Mode> {
        Some(match s {
            "arcus" => Mode::Arcus,
            "host_no_ts" => Mode::HostNoTs,
            "host_ts_reflex" => Mode::HostTsReflex,
            "host_ts_firecracker" => Mode::HostTsFirecracker,
            "bypassed_panic" => Mode::BypassedPanic,
            _ => return None,
        })
    }

    /// Parse a mode name, or explain which names are valid — CLI and config
    /// errors must name the menu, not just shrug.
    pub fn parse(s: &str) -> Result<Mode, String> {
        Mode::by_name(s).ok_or_else(|| {
            let valid: Vec<&str> = Mode::ALL.iter().map(|m| m.name()).collect();
            format!("unknown mode `{s}` (valid modes: {})", valid.join(", "))
        })
    }

    /// Does this architecture interpose host software on the data path?
    pub fn host_interposed(self) -> bool {
        matches!(self, Mode::HostTsReflex | Mode::HostTsFirecracker)
    }
}

/// One scheduled flow-lifecycle event (tenant churn / SLO renegotiation —
/// the paper's Scenarios 1–2, §4.3). Flows without an `Arrive` event are
/// registered at t = 0, so an empty schedule reproduces the legacy
/// fixed-roster experiment exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LifecycleEvent {
    /// The flow registers (admission control) and starts offering traffic
    /// at `at` instead of t = 0.
    Arrive { flow: usize, at: Time },
    /// The flow deregisters at `at`, releasing its committed capacity for
    /// later arrivals or renegotiations to claim.
    Depart { flow: usize, at: Time },
    /// The flow renegotiates its SLO at `at`; on rejection the old SLO
    /// stays in force.
    Renegotiate { flow: usize, at: Time, slo: Slo },
}

impl LifecycleEvent {
    pub fn flow(&self) -> usize {
        match *self {
            LifecycleEvent::Arrive { flow, .. }
            | LifecycleEvent::Depart { flow, .. }
            | LifecycleEvent::Renegotiate { flow, .. } => flow,
        }
    }

    pub fn at(&self) -> Time {
        match *self {
            LifecycleEvent::Arrive { at, .. }
            | LifecycleEvent::Depart { at, .. }
            | LifecycleEvent::Renegotiate { at, .. } => at,
        }
    }
}

/// A full experiment.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    pub mode: Mode,
    pub seed: u64,
    /// Virtual duration of the measured run.
    pub duration: Time,
    /// Virtual warmup discarded from metrics.
    pub warmup: Time,
    pub fabric: FabricConfig,
    /// Accelerators on the device (flows reference them by index).
    pub accels: Vec<AccelModel>,
    pub flows: Vec<FlowSpec>,
    /// RAID-0 array present (storage flows require it).
    pub raid: Option<RaidSpec>,
    /// NIC port line rate for inline flows.
    pub nic_rate: Rate,
    /// Control-plane period (Algorithm 1 cadence).
    pub control_period: Time,
    /// Reconfiguration latency (MMIO over PCIe, §5.3.1: ~10 µs).
    pub reconfig_latency: Time,
    /// Throughput sampling window in requests (§5.2: every 500 requests).
    pub sampler_window: u64,
    /// Per-flow software-queue capacity in messages (drop beyond).
    pub queue_cap: usize,
    /// Max outstanding ingress fetches per flow (DMA pipelining).
    pub fetch_pipeline: usize,
    /// Record per-completion traces (time, latency, bytes) for time-series
    /// plots (Fig 9). Off by default: traces cost memory.
    pub trace: bool,
    /// Put every inline flow on NIC port 0 (bump-in-the-wire sharing, Fig 9
    /// / Fig 11a); default spreads flows across the two ports.
    pub shared_port: bool,
    /// Flow-lifecycle schedule: arrivals, departures, and SLO
    /// renegotiations (empty = every flow present for the whole run).
    pub lifecycle: Vec<LifecycleEvent>,
    /// Fault-injection plan ([`crate::faults`]): typed degradation /
    /// adversary windows on the DES clock (empty = healthy run; per-era
    /// fault metrics are reported only when non-empty).
    pub faults: Vec<FaultSpec>,
    /// Hierarchical shaping (Arcus mode only): pace committed flows as
    /// leaves of the per-engine [`crate::shaping::ShaperTree`] under
    /// per-tenant aggregates, instead of flat per-flow token buckets —
    /// the 10k-flow-scale configuration (`scale` sweep axis, `xlarge`
    /// bench preset).
    pub hierarchy: bool,
    /// Shaper-tree pacing cadence (one `ShaperTick` event per tree per
    /// interval while any leaf waits).
    pub shaper_tick: Time,
    /// Observability-plane series retention: how many samples each
    /// per-flow/tenant/engine [`crate::obs::SeriesRing`] keeps (rounded up
    /// to a power of two; rings sized to the run length when shorter).
    /// 0 disables series sampling — counters, histograms, and fault-era
    /// accounting still run.
    pub obs_retention: usize,
    /// Sample the observability series every Nth control tick (≥ 1);
    /// coarser cadence for long runs where per-tick series would churn
    /// the rings.
    pub obs_sample_every: u64,
    /// Closed-loop adaptive control (Arcus mode only): wrap the planner in
    /// the AIMD [`crate::api::AdaptiveControlPlane`] with these gains.
    /// `None` runs the static planner alone.
    pub adaptive: Option<AdaptiveConfig>,
    /// Population workload layer ([`crate::workload::gen`]): replace each
    /// flow's synthetic pattern generator with N users multiplexed onto the
    /// flows (Zipf popularity, Pareto sizes, diurnal + flash-crowd
    /// envelopes) and report per-user fairness. `None` = legacy pattern
    /// generators, byte-identical to the pre-population form.
    pub population: Option<crate::workload::PopulationConfig>,
}

#[derive(Debug, Clone, Copy)]
pub struct RaidSpec {
    pub drives: usize,
    pub ssd: SsdConfig,
}

impl ExperimentSpec {
    /// Sensible defaults matching the paper's testbed constants.
    pub fn new(mode: Mode, accels: Vec<AccelModel>, flows: Vec<FlowSpec>) -> Self {
        ExperimentSpec {
            mode,
            seed: 1,
            duration: 20 * MILLIS,
            warmup: 2 * MILLIS,
            fabric: FabricConfig::gen3_x8(),
            accels,
            flows,
            raid: None,
            nic_rate: Rate::gbps(50.0),
            control_period: 100 * MICROS,
            reconfig_latency: 10 * MICROS,
            sampler_window: 500,
            queue_cap: 4096,
            fetch_pipeline: 16,
            trace: false,
            shared_port: false,
            lifecycle: Vec::new(),
            faults: Vec::new(),
            hierarchy: false,
            shaper_tick: crate::shaping::hierarchy::DEFAULT_TICK_INTERVAL,
            obs_retention: 256,
            obs_sample_every: 1,
            adaptive: None,
            population: None,
        }
    }

    /// Drive the flows from a population workload instead of their synthetic
    /// patterns (each flow's offered rate still scales its share).
    pub fn with_population(mut self, cfg: crate::workload::PopulationConfig) -> Self {
        self.population = Some(cfg);
        self
    }

    /// Enable the closed-loop adaptive control plane (Arcus mode only).
    pub fn with_adaptive(mut self, cfg: AdaptiveConfig) -> Self {
        self.adaptive = Some(cfg);
        self
    }

    /// Set observability-series retention (samples per ring) and sampling
    /// cadence (every Nth control tick).
    pub fn with_obs(mut self, retention: usize, sample_every: u64) -> Self {
        self.obs_retention = retention;
        self.obs_sample_every = sample_every.max(1);
        self
    }

    /// Enable hierarchical shaping (the per-engine shaper tree).
    pub fn with_hierarchy(mut self) -> Self {
        self.hierarchy = true;
        self
    }

    /// Replace the fault-injection plan.
    pub fn with_faults(mut self, faults: Vec<FaultSpec>) -> Self {
        self.faults = faults;
        self
    }

    /// Append one fault.
    pub fn with_fault(mut self, fault: FaultSpec) -> Self {
        self.faults.push(fault);
        self
    }

    /// Replace the flow-lifecycle schedule.
    pub fn with_lifecycle(mut self, events: Vec<LifecycleEvent>) -> Self {
        self.lifecycle = events;
        self
    }

    /// Append one lifecycle event.
    pub fn with_event(mut self, event: LifecycleEvent) -> Self {
        self.lifecycle.push(event);
        self
    }

    /// The time a flow first arrives (registers and starts offering
    /// traffic). A flow is present from t = 0 unless its *earliest*
    /// lifecycle event is an `Arrive` — a flow whose first event is a
    /// `Depart` or `Renegotiate` must have been running already; later
    /// `Arrive` events are re-arrivals after a departure.
    pub fn arrival_time(&self, flow: usize) -> Time {
        match self
            .lifecycle
            .iter()
            .filter(|e| e.flow() == flow)
            .min_by_key(|e| e.at())
        {
            Some(LifecycleEvent::Arrive { at, .. }) => *at,
            _ => 0,
        }
    }

    pub fn with_trace(mut self) -> Self {
        self.trace = true;
        self
    }

    pub fn with_shared_port(mut self) -> Self {
        self.shared_port = true;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
    pub fn with_duration(mut self, d: Time) -> Self {
        self.duration = d;
        self
    }
    pub fn with_warmup(mut self, w: Time) -> Self {
        self.warmup = w;
        self
    }
    pub fn with_raid(mut self, drives: usize, ssd: SsdConfig) -> Self {
        self.raid = Some(RaidSpec { drives, ssd });
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_name_roundtrip() {
        for m in Mode::ALL {
            assert_eq!(Mode::by_name(m.name()), Some(m));
            assert_eq!(Mode::parse(m.name()), Ok(m));
        }
        assert!(Mode::by_name("nope").is_none());
        let err = Mode::parse("nope").unwrap_err();
        assert!(err.contains("unknown mode `nope`"), "{err}");
        // The error lists every valid mode name.
        for m in Mode::ALL {
            assert!(err.contains(m.name()), "{err} missing {}", m.name());
        }
    }

    #[test]
    fn lifecycle_schedule_accessors() {
        use crate::flow::Slo;
        let spec = ExperimentSpec::new(Mode::Arcus, vec![], vec![])
            .with_event(LifecycleEvent::Arrive { flow: 2, at: 3 * MILLIS })
            .with_event(LifecycleEvent::Depart { flow: 0, at: 5 * MILLIS })
            .with_event(LifecycleEvent::Renegotiate {
                flow: 1,
                at: 7 * MILLIS,
                slo: Slo::gbps(4.0),
            });
        assert_eq!(spec.lifecycle.len(), 3);
        assert_eq!(spec.arrival_time(2), 3 * MILLIS);
        assert_eq!(spec.arrival_time(0), 0, "no Arrive event means t = 0");
        assert_eq!(spec.lifecycle[1].flow(), 0);
        assert_eq!(spec.lifecycle[2].at(), 7 * MILLIS);
    }

    #[test]
    fn defaults_match_paper_constants() {
        let spec = ExperimentSpec::new(Mode::Arcus, vec![], vec![]);
        assert_eq!(spec.control_period, 100 * MICROS);
        assert_eq!(spec.reconfig_latency, 10 * MICROS);
        assert_eq!(spec.sampler_window, 500);
    }
}
