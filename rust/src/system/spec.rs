//! Experiment specification: which management architecture, which devices,
//! which flows — the typed form of an experiment config file.

use crate::accel::AccelModel;
use crate::flow::FlowSpec;
use crate::pcie::fabric::FabricConfig;
use crate::storage::nvme::SsdConfig;
use crate::util::units::{Rate, Time, MICROS, MILLIS};

/// Management architecture under test (§5.1 Configurations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Arcus: per-flow hardware token buckets + SLO-aware control plane.
    Arcus,
    /// Kernel-bypass access, weighted-round-robin arbitration, no shaping.
    HostNoTs,
    /// ReFlex-style on-host software shaping (fine timers, polling).
    HostTsReflex,
    /// Firecracker-style on-host software shaping (coarser timers).
    HostTsFirecracker,
    /// PANIC interface: hypervisor-bypassed, priority + WFQ scheduling at
    /// the accelerator, no shaping, no proactive SLO management.
    BypassedPanic,
}

impl Mode {
    pub fn name(self) -> &'static str {
        match self {
            Mode::Arcus => "arcus",
            Mode::HostNoTs => "host_no_ts",
            Mode::HostTsReflex => "host_ts_reflex",
            Mode::HostTsFirecracker => "host_ts_firecracker",
            Mode::BypassedPanic => "bypassed_panic",
        }
    }

    pub fn by_name(s: &str) -> Option<Mode> {
        Some(match s {
            "arcus" => Mode::Arcus,
            "host_no_ts" => Mode::HostNoTs,
            "host_ts_reflex" => Mode::HostTsReflex,
            "host_ts_firecracker" => Mode::HostTsFirecracker,
            "bypassed_panic" => Mode::BypassedPanic,
            _ => return None,
        })
    }

    /// Does this architecture interpose host software on the data path?
    pub fn host_interposed(self) -> bool {
        matches!(self, Mode::HostTsReflex | Mode::HostTsFirecracker)
    }
}

/// A full experiment.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    pub mode: Mode,
    pub seed: u64,
    /// Virtual duration of the measured run.
    pub duration: Time,
    /// Virtual warmup discarded from metrics.
    pub warmup: Time,
    pub fabric: FabricConfig,
    /// Accelerators on the device (flows reference them by index).
    pub accels: Vec<AccelModel>,
    pub flows: Vec<FlowSpec>,
    /// RAID-0 array present (storage flows require it).
    pub raid: Option<RaidSpec>,
    /// NIC port line rate for inline flows.
    pub nic_rate: Rate,
    /// Control-plane period (Algorithm 1 cadence).
    pub control_period: Time,
    /// Reconfiguration latency (MMIO over PCIe, §5.3.1: ~10 µs).
    pub reconfig_latency: Time,
    /// Throughput sampling window in requests (§5.2: every 500 requests).
    pub sampler_window: u64,
    /// Per-flow software-queue capacity in messages (drop beyond).
    pub queue_cap: usize,
    /// Max outstanding ingress fetches per flow (DMA pipelining).
    pub fetch_pipeline: usize,
    /// Record per-completion traces (time, latency, bytes) for time-series
    /// plots (Fig 9). Off by default: traces cost memory.
    pub trace: bool,
    /// Put every inline flow on NIC port 0 (bump-in-the-wire sharing, Fig 9
    /// / Fig 11a); default spreads flows across the two ports.
    pub shared_port: bool,
}

#[derive(Debug, Clone, Copy)]
pub struct RaidSpec {
    pub drives: usize,
    pub ssd: SsdConfig,
}

impl ExperimentSpec {
    /// Sensible defaults matching the paper's testbed constants.
    pub fn new(mode: Mode, accels: Vec<AccelModel>, flows: Vec<FlowSpec>) -> Self {
        ExperimentSpec {
            mode,
            seed: 1,
            duration: 20 * MILLIS,
            warmup: 2 * MILLIS,
            fabric: FabricConfig::gen3_x8(),
            accels,
            flows,
            raid: None,
            nic_rate: Rate::gbps(50.0),
            control_period: 100 * MICROS,
            reconfig_latency: 10 * MICROS,
            sampler_window: 500,
            queue_cap: 4096,
            fetch_pipeline: 16,
            trace: false,
            shared_port: false,
        }
    }

    pub fn with_trace(mut self) -> Self {
        self.trace = true;
        self
    }

    pub fn with_shared_port(mut self) -> Self {
        self.shared_port = true;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
    pub fn with_duration(mut self, d: Time) -> Self {
        self.duration = d;
        self
    }
    pub fn with_warmup(mut self, w: Time) -> Self {
        self.warmup = w;
        self
    }
    pub fn with_raid(mut self, drives: usize, ssd: SsdConfig) -> Self {
        self.raid = Some(RaidSpec { drives, ssd });
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_name_roundtrip() {
        for m in [
            Mode::Arcus,
            Mode::HostNoTs,
            Mode::HostTsReflex,
            Mode::HostTsFirecracker,
            Mode::BypassedPanic,
        ] {
            assert_eq!(Mode::by_name(m.name()), Some(m));
        }
        assert!(Mode::by_name("nope").is_none());
    }

    #[test]
    fn defaults_match_paper_constants() {
        let spec = ExperimentSpec::new(Mode::Arcus, vec![], vec![]);
        assert_eq!(spec.control_period, 100 * MICROS);
        assert_eq!(spec.reconfig_latency, 10 * MICROS);
        assert_eq!(spec.sampler_window, 500);
    }
}
