//! System builder: wires flows, shapers, PCIe fabric, accelerators, NIC
//! ports and storage into one runnable discrete-event experiment, under any
//! of the five management architectures of §5.1 (Arcus + four baselines).
//!
//! The [`spec::ExperimentSpec`] is the typed experiment description; the
//! [`engine::Engine`] executes it on the [`crate::sim`] core and returns a
//! [`report::SystemReport`] with the per-flow metrics every figure in the
//! paper is derived from.

pub mod engine;
pub mod report;
pub mod spec;

pub use engine::{
    record_population_trace, run, run_replay, run_replay_with, run_with, Engine, EngineEvent,
};
pub use report::{EraReport, FaultReport, FlowReport, HostRollup, SystemReport};
pub use spec::{ExperimentSpec, LifecycleEvent, Mode, RaidSpec};
