//! The experiment engine: executes an [`ExperimentSpec`] on the DES core.
//!
//! One [`World`] holds every component; events are **typed** — the
//! [`EngineEvent`] enum names every kind of work the dataplane schedules
//! (packet arrival, shaped fetch wakeup, component pump, directive apply,
//! flow lifecycle), and one `match` in [`Handler::handle`] dispatches them.
//! Scheduling an event is a queue insert of an inline enum value: no heap
//! allocation, no virtual call — the simulator scales to millions of events
//! per run, which the `arcus bench` pipeline measures. The wiring follows
//! the dataplane protocol of §4.1 per path:
//!
//! - **Function call**: VM places payloads in its DMA buffer (the per-flow
//!   software queue); the device *fetches* them (DMA read — request TLP Up,
//!   completion data Down), runs the accelerator, and DMA-writes the result
//!   back (Up). Under Arcus the fetch is gated by the flow's hardware token
//!   bucket — PatternA → PatternA′.
//! - **Inline NIC RX**: frames arrive off the wire into the port's RX
//!   buffer; the device pulls per-flow (shaped under Arcus), runs the
//!   accelerator, DMA-writes results to host memory (Up).
//! - **Inline NIC TX**: payload fetched from host (Down), accelerated, sent
//!   out the wire.
//! - **Inline P2P**: ingress like RX; egress re-shaped into the NVMe
//!   subsystem (fabric write + SSD program) — Fig 5(b)'s PatternC.
//! - **Storage flows** (Fig 6 / 11b): reads = SSD read then data DMA'd Up;
//!   writes = data fetched Down then SSD program.
//!
//! Mode differences (§5.1): Arcus = per-flow hardware token buckets + the
//! Algorithm-1 control loop; Host_TS_* = software token buckets with timer
//! quantization + CPU-interference jitter on both shaping and completion
//! paths; Host_no_TS / Bypassed_PANIC = no shaping, with PANIC using
//! priority scheduling at the accelerator input.
//!
//! Shaping state lives in one [`ShaperTree`] per engine (accelerators +
//! the storage subsystem): flat programs install leaves that own their
//! shaper (verdict-identical to the pre-tree per-flow map), while
//! hierarchical programs ([`ShaperProgram::Hierarchy`], enabled by
//! `ExperimentSpec::hierarchy`) install paced leaves under per-tenant
//! aggregates — released by ONE `ShaperTick` event per tree instead of
//! per-flow wakeups, which is what lets a 10,000-flow run keep its event
//! queue shallow.
//!
//! Control-plane boundary: the engine owns the *dataplane* (queues, shapers,
//! DMA, devices, counters) and talks to the SLO runtime exclusively through
//! the [`ControlPlane`] trait — flow registration, SLO renegotiation,
//! departure, and the periodic Algorithm-1 tick are all API calls; the
//! resulting [`Directive`]s are applied to the hardware after the paper's
//! ~10 µs MMIO reconfiguration latency. The [`ExperimentSpec`]'s
//! [`LifecycleEvent`] schedule drives tenant churn (arrivals mid-run pass
//! admission control against whatever capacity the incumbents left).
//!
//! The engine is generic over the event-queue discipline
//! ([`crate::sim::EventQueue`]): [`run`] uses the reference binary heap,
//! [`run_with`] picks any queue (the bench pipeline and the golden
//! determinism test run both and require byte-identical reports).

use std::collections::VecDeque;

use crate::accel::{AccelUnit, Job};
use crate::api::{
    AdaptiveControlPlane, ApiError, ArcusControlPlane, ControlPlane, Directive, DirectiveKind,
    NoOpControlPlane, RegisterRequest, ShaperProgram, StaticRateControlPlane, TickContext,
};
use crate::coordinator::planner::PlannerConfig;
use crate::coordinator::status::MeasuredWindow;
use crate::dma::Policy;
use crate::faults::{fault_window, FaultKind};
use crate::flow::{FlowKind, Path, Slo, TrafficGen};
use crate::metrics::{FlowMetrics, ThroughputSampler};
use crate::nic::NicPort;
use crate::obs::{ObsConfig, ObsPlane};
use crate::pcie::fabric::{Fabric, OpComplete, OpKind};
use crate::shaping::{
    NodeBudget, ShapeMode, Shaper, ShaperTree, SoftwareShaper, SoftwareShaperConfig, TokenBucket,
    TreeConfig, TreeVerdict,
};
use crate::sim::{BinaryHeapQueue, EventQueue, Handler, Sim};
use crate::storage::nvme::{Io, IoDone, IoKind};
use crate::storage::Raid0;
use crate::util::units::{Time, NANOS};
use crate::util::{Rng, Slab};
use crate::workload::{build_population, PopAccounting, PopArrival, PopArrivals, TraceData};

use super::report::{EraReport, FaultReport, FlowReport, SystemReport};
use super::spec::{ExperimentSpec, LifecycleEvent, Mode};

/// Hardware shaping decision latency (§5.3.1: 36 ns).
const SHAPING_LATENCY: Time = 36 * NANOS;

/// A message travelling through the system.
#[derive(Debug, Clone, Copy)]
pub struct Msg {
    flow: usize,
    bytes: u64,
    born: Time,
    /// Population user that issued the op (0 on pattern-generator runs,
    /// where no per-user accounting exists to read it).
    user: u32,
}

/// Which leg of its journey an in-flight operation is on.
#[derive(Debug, Clone, Copy)]
enum Stage {
    /// DMA read of the ingress payload, or residence in the accelerator.
    Fetch,
    /// DMA write of the accelerator result / storage read data.
    Egress,
    /// Storage read in the SSD.
    SsdRead,
    /// Storage write program in the SSD.
    SsdWrite,
    /// P2P egress crossing PCIe toward the NVMe subsystem.
    P2pStore,
}

#[derive(Debug, Clone, Copy)]
struct OpCtx {
    msg: Msg,
    stage: Stage,
}

/// Every kind of work the engine schedules on the simulator. One inline
/// enum value per event — the zero-allocation replacement for the former
/// per-event `Box<dyn FnOnce>`.
#[derive(Debug, Clone)]
pub enum EngineEvent {
    /// A message leaves its VM (or its frame starts onto the wire).
    Inject { flow: usize, bytes: u64, user: u32 },
    /// A frame's last bit landed: enter the RX buffer or drop.
    RxDeliver {
        port: usize,
        id: u64,
        flow: usize,
        bytes: u64,
        born: Time,
        user: u32,
    },
    /// Shaped fetch-engine wakeup. `gen` voids superseded schedules.
    Fetch { flow: usize, gen: u64 },
    /// An RX payload enters the accelerator after the shaping decision.
    SubmitAccel { accel: usize, msg: Msg },
    /// A TX frame's last bit left the wire.
    TxDone { msg: Msg },
    /// Host-interposed completion-path interference elapsed.
    HostFinish { msg: Msg },
    /// PCIe fabric pump wakeup. `gen` voids superseded schedules.
    WakeFabric { gen: u64 },
    /// Accelerator-unit pump wakeup.
    WakeAccel { unit: usize, gen: u64 },
    /// RAID pump wakeup.
    WakeRaid { gen: u64 },
    /// Algorithm-1 control-plane tick (self-rescheduling).
    ControlTick,
    /// A directive lands after the ~10 µs MMIO reconfiguration latency
    /// (every control-plane decision — reshape, path switch, aggregate
    /// envelope, or renegotiated program — rides this ONE event).
    ApplyDirective(Directive),
    /// Lifecycle: the flow registers and starts offering traffic.
    FlowArrives { flow: usize },
    /// Lifecycle: the flow deregisters, releasing committed capacity.
    FlowDeparts { flow: usize },
    /// Lifecycle: the flow renegotiates its SLO.
    Renegotiate { flow: usize, slo: Slo },
    /// Fault injection: the `idx`-th fault of the plan takes hold.
    FaultStart { idx: usize },
    /// Fault injection: the `idx`-th fault's component heals.
    FaultEnd { idx: usize },
    /// One pacing pass of an engine's shaper tree: replenish aggregate
    /// credit (guarantees + DRR borrow) and re-drive every waiting leaf in
    /// a single O(active-children) sweep — the whole tree shares this ONE
    /// event, so 10,000 blocked flows park inside the tree instead of as
    /// 10,000 queue entries. `gen` voids superseded schedules.
    ShaperTick { tree: usize, gen: u64 },
}

use EngineEvent as Ev;

/// One arrival from whichever source drives a flow.
struct NextArrival {
    at: Time,
    bytes: u64,
    user: u32,
}

/// Per-flow cursor over a recorded trace's arrivals (`arcus trace replay`).
struct TraceCursor {
    records: Vec<PopArrival>,
    idx: usize,
}

impl TraceCursor {
    fn next(&mut self) -> NextArrival {
        match self.records.get(self.idx) {
            Some(r) => {
                self.idx += 1;
                NextArrival { at: r.at, bytes: r.bytes, user: r.user }
            }
            // Exhausted: Time::MAX lands at/after every duration, so the
            // engine's pull loop stops exactly as it does for a generator.
            None => NextArrival { at: Time::MAX, bytes: 0, user: 0 },
        }
    }
}

/// What drives a flow's arrivals: its synthetic traffic pattern (legacy),
/// its user block of the population workload, or a recorded trace. All
/// three share the same pull discipline — `next()` yields nondecreasing
/// arrival times and the engine stops pulling at the run's duration — so
/// swapping sources never perturbs the event loop's structure.
enum ArrivalGen {
    Pattern(TrafficGen),
    Pop(PopArrivals),
    Replay(TraceCursor),
}

impl ArrivalGen {
    fn next(&mut self) -> NextArrival {
        match self {
            ArrivalGen::Pattern(g) => {
                let a = g.next();
                NextArrival { at: a.at, bytes: a.bytes, user: 0 }
            }
            ArrivalGen::Pop(g) => {
                let a = g.next();
                NextArrival { at: a.at, bytes: a.bytes, user: a.user }
            }
            ArrivalGen::Replay(c) => c.next(),
        }
    }
}

/// Per-flow runtime state.
struct FlowState {
    gen: ArrivalGen,
    /// VM-side DMA buffer (function-call / TX / storage paths).
    queue: VecDeque<Msg>,
    /// Cost units for shaping and sampling (bytes vs messages). The
    /// shaper itself lives as this flow's leaf in its engine's
    /// [`ShaperTree`] (flat leaves own a boxed shaper; paced leaves are
    /// released by the tree's pacing pass).
    mode: ShapeMode,
    inflight: usize,
    /// Earliest already-scheduled fetch event (dedupe).
    fetch_scheduled: Time,
    /// Generation token: a scheduled fetch event is void unless its token
    /// matches (prevents superseded events from spawning wake chains).
    fetch_gen: u64,
    admitted: bool,
    /// NIC port for inline paths.
    port: usize,
    /// Current path (can change via SwitchPath).
    path: Path,
    /// Counters at the last control-plane window.
    last_bytes: u64,
    last_ops: u64,
    last_tick: Time,
    /// Latencies completed in the current control window (for p99).
    window_lat: Vec<u64>,
    reconfigs: u32,
    /// Current SLO (diverges from the spec after renegotiation).
    current_slo: Slo,
    /// Virtual time the flow registered (lifecycle arrivals).
    arrived_at: Time,
    /// Set when the flow deregistered mid-run.
    departed_at: Option<Time>,
    /// An arrival-chain inject event is scheduled (guards re-arrival from
    /// spawning a second generator chain alongside a live one).
    arrival_pending: bool,
    /// Renegotiations capacity planning refused.
    renegotiations_rejected: u32,
    /// When the current SLO contract took effect (> 0 after an accepted
    /// renegotiation was applied; attainment is measured from here so
    /// contract eras don't mix).
    contract_start: Time,
    /// Post-warmup bytes/ops completed before the current contract.
    contract_base_bytes: u64,
    contract_base_ops: u64,
    /// Adversary injection: the tenant is currently ignoring its shaper
    /// program (`RogueTenant` fault) — its fetches bypass the shaper tree
    /// entirely. Cleared when the interface clamps it (any program install
    /// / SetRate directive) or the fault window ends, at which point the
    /// untouched leaf state resumes enforcing.
    rogue: bool,
}

/// The component graph.
pub struct World {
    spec: ExperimentSpec,
    flows: Vec<FlowState>,
    /// Per-engine shaper hierarchies: one tree per accelerator plus one
    /// for the storage subsystem (the last index). Every flow's shaper —
    /// flat bucket or tree-paced leaf — lives here.
    trees: Vec<ShaperTree>,
    /// Flow → tree index (its accelerator, or the storage tree).
    flow_tree: Vec<usize>,
    /// Earliest scheduled pacing pass per tree (dedupe, like the pumps).
    tree_tick_scheduled: Vec<Time>,
    /// Generation tokens voiding superseded tree ticks.
    tree_tick_gen: Vec<u64>,
    /// Reused eligible-leaf buffer for tree passes.
    scratch_eligible: Vec<usize>,
    fabric: Fabric,
    fabric_scheduled: Time,
    fabric_gen: u64,
    accels: Vec<AccelUnit>,
    accel_scheduled: Vec<Time>,
    accel_gen: Vec<u64>,
    ports: Vec<NicPort>,
    raid: Option<Raid0>,
    raid_scheduled: Time,
    raid_gen: u64,
    /// In-flight operation contexts, pooled: ids are reused slab slots, so
    /// steady-state operation allocates nothing and the fabric's
    /// `op << 2 | phase` message-id packing stays compact.
    ops: Slab<OpCtx>,
    /// Frame-id counter for RX diagnostics.
    next_frame: u64,
    metrics: Vec<FlowMetrics>,
    samplers: Vec<ThroughputSampler>,
    traces: Vec<Vec<(Time, Time, u64)>>,
    /// Host-software interference model for interposed modes.
    host_cfg: Option<SoftwareShaperConfig>,
    host_rng: Rng,
    /// The SLO runtime. All admission / renegotiation / reshape decisions
    /// cross this trait; the engine never reads coordinator tables.
    ctrl: Box<dyn ControlPlane>,
    /// Reused pump scratch buffers (allocation-free steady state).
    scratch_fabric: Vec<OpComplete>,
    scratch_accel: Vec<crate::accel::JobDone>,
    scratch_raid: Vec<IoDone>,
    /// Union fault window `[start, end)` (None = healthy run; the obs
    /// plane's per-era accounting is active only when set).
    fault_window: Option<(Time, Time)>,
    /// The streaming observability plane: per-flow/tenant/engine counters
    /// and tick-indexed series sampled on `ControlTick`, plus the fault-era
    /// + recovery accounting `FlowReport.fault` is derived from.
    obs: ObsPlane,
    /// Flyweight per-user accounting (population runs only).
    pop: Option<PopAccounting>,
    /// Algorithm-1 ticks are lost while `now` is before this (the
    /// `ControlOutage` fault).
    control_outage_until: Time,
    /// Worst directive-propagation lag seen: max `apply time − issued_at`
    /// over every applied directive (measurable because every [`Directive`]
    /// carries its issue stamp).
    directive_lag_max: Time,
}

impl Handler<EngineEvent> for World {
    fn handle<Q: EventQueue<EngineEvent>>(&mut self, sim: &mut Sim<EngineEvent, Q>, ev: Ev) {
        match ev {
            Ev::Inject { flow, bytes, user } => self.inject(sim, flow, bytes, user),
            Ev::RxDeliver { port, id, flow, bytes, born, user } => {
                let arrived = sim.now();
                if self.ports[port].rx_deliver(id, flow, bytes, born, arrived, user) {
                    self.kick_fetch(sim, flow, arrived);
                } else if arrived >= self.spec.warmup {
                    self.metrics[flow].on_drop();
                    self.obs.on_drop(flow);
                }
            }
            Ev::Fetch { flow, gen } => {
                if self.flows[flow].fetch_gen != gen {
                    return; // superseded
                }
                self.flows[flow].fetch_scheduled = Time::MAX;
                self.ev_fetch(sim, flow);
            }
            Ev::SubmitAccel { accel, msg } => self.submit_accel(sim, accel, msg),
            Ev::TxDone { msg } => {
                let t = sim.now();
                self.complete(sim, msg, t);
            }
            Ev::HostFinish { msg } => {
                let t = sim.now();
                self.finish(sim, msg, t);
            }
            Ev::WakeFabric { gen } => {
                if self.fabric_gen != gen {
                    return; // superseded
                }
                self.fabric_scheduled = Time::MAX;
                self.wake_fabric(sim);
            }
            Ev::WakeAccel { unit, gen } => {
                if self.accel_gen[unit] != gen {
                    return; // superseded
                }
                self.accel_scheduled[unit] = Time::MAX;
                self.wake_accel(sim, unit);
            }
            Ev::WakeRaid { gen } => {
                if self.raid_gen != gen {
                    return; // superseded
                }
                self.raid_scheduled = Time::MAX;
                self.wake_raid(sim);
            }
            Ev::ControlTick => {
                self.ev_control_tick(sim);
                if sim.now() < self.spec.duration {
                    sim.after(self.spec.control_period, Ev::ControlTick);
                }
            }
            Ev::ApplyDirective(d) => self.apply_directive(sim, d),
            Ev::FlowArrives { flow } => self.ev_flow_arrives(sim, flow),
            Ev::FlowDeparts { flow } => self.ev_flow_departs(sim, flow),
            Ev::Renegotiate { flow, slo } => self.ev_renegotiate(sim, flow, slo),
            Ev::FaultStart { idx } => self.ev_fault_start(sim, idx),
            Ev::FaultEnd { idx } => self.ev_fault_end(sim, idx),
            Ev::ShaperTick { tree, gen } => {
                if self.tree_tick_gen[tree] != gen {
                    return; // superseded
                }
                self.tree_tick_scheduled[tree] = Time::MAX;
                self.ev_shaper_tick(sim, tree);
            }
        }
    }
}

impl World {
    /// Build the component graph. `replay` (per-flow arrival lists from a
    /// decoded trace) substitutes trace cursors for the population
    /// generators; [`Engine::build_replay`] validates it against the spec
    /// before it reaches here.
    fn new(spec: ExperimentSpec, replay: Option<Vec<Vec<PopArrival>>>) -> Self {
        let n = spec.flows.len();
        let fabric = Fabric::new(spec.fabric, n.max(1));
        let mut ports = vec![
            NicPort::new(spec.nic_rate, 512 * 1024),
            NicPort::new(spec.nic_rate, 512 * 1024),
        ];
        // Arcus's interface keeps per-flow SRAM queues with backpressure:
        // partition each port's buffer among the inline flows it carries so
        // one tenant's backlog cannot evict another's frames (Fig 4 step 6).
        if spec.mode == Mode::Arcus {
            for (p, port) in ports.iter_mut().enumerate() {
                let inline = spec
                    .flows
                    .iter()
                    .filter(|f| {
                        matches!(f.path, Path::InlineNicRx | Path::InlineP2p)
                            && f.kind == FlowKind::Accel
                            && (if spec.shared_port { 0 } else { f.id % 2 }) == p
                    })
                    .count()
                    .max(1);
                port.set_flow_quota(512 * 1024 / inline as u64);
            }
        }
        let raid = spec
            .raid
            .map(|r| Raid0::new(r.drives, r.ssd, spec.seed ^ 0x0A1D));
        let ctrl: Box<dyn ControlPlane> = match spec.mode {
            Mode::Arcus => {
                let inner = ArcusControlPlane::from_models(
                    &spec.accels,
                    &spec.fabric,
                    PlannerConfig::default(),
                )
                .with_hierarchy(spec.hierarchy);
                match spec.adaptive {
                    Some(cfg) => Box::new(AdaptiveControlPlane::new(inner, cfg)),
                    None => Box::new(inner),
                }
            }
            Mode::HostTsReflex | Mode::HostTsFirecracker => {
                Box::new(StaticRateControlPlane::new())
            }
            Mode::HostNoTs | Mode::BypassedPanic => Box::new(NoOpControlPlane::new()),
        };
        let host_cfg = match spec.mode {
            Mode::HostTsReflex => Some(SoftwareShaperConfig::reflex()),
            Mode::HostTsFirecracker => Some(SoftwareShaperConfig::firecracker()),
            _ => None,
        };

        let policy = match spec.mode {
            Mode::BypassedPanic => {
                Policy::Priority(spec.flows.iter().map(|f| f.priority).collect())
            }
            _ => Policy::RoundRobin,
        };
        let accels: Vec<AccelUnit> = spec
            .accels
            .iter()
            .enumerate()
            .map(|(i, m)| {
                AccelUnit::new(m.clone(), n.max(1), policy.clone(), spec.seed ^ (i as u64 + 1))
            })
            .collect();

        // One shaper tree per engine: accelerators first, storage last.
        // All leaves start absent; registration installs them.
        let n_trees = spec.accels.len() + 1;
        let tree_cfg = TreeConfig {
            tick_interval: spec.shaper_tick,
            root_ceiling: None,
        };
        let trees: Vec<ShaperTree> = (0..n_trees).map(|_| ShaperTree::new(n, tree_cfg)).collect();
        let flow_tree: Vec<usize> = spec
            .flows
            .iter()
            .map(|f| {
                if f.kind == FlowKind::Accel {
                    f.accel
                } else {
                    spec.accels.len()
                }
            })
            .collect();

        // Population workload: validate loudly (config/grid layers validate
        // earlier with context; this backstops programmatic specs), then
        // build one arrival source per flow — generators normally, trace
        // cursors on replay.
        if let Some(cfg) = &spec.population {
            if let Err(e) = cfg.validate(n) {
                panic!("invalid population config: {e}");
            }
        }
        let pop_sources: Option<Vec<ArrivalGen>> = match (&spec.population, replay) {
            (Some(_), Some(per_flow)) => Some(
                per_flow
                    .into_iter()
                    .map(|records| ArrivalGen::Replay(TraceCursor { records, idx: 0 }))
                    .collect(),
            ),
            (Some(cfg), None) => {
                let homes: Vec<_> = spec
                    .flows
                    .iter()
                    .map(|f| (f.vm as u32, f.pattern.offered()))
                    .collect();
                Some(
                    build_population(cfg, spec.seed, spec.duration, &homes)
                        .into_iter()
                        .map(ArrivalGen::Pop)
                        .collect(),
                )
            }
            (None, _) => None,
        };
        let mut pop_iter = pop_sources.map(Vec::into_iter);

        let flows: Vec<FlowState> = spec
            .flows
            .iter()
            .map(|f| FlowState {
                gen: match pop_iter.as_mut().and_then(Iterator::next) {
                    Some(g) => g,
                    None => ArrivalGen::Pattern(TrafficGen::new(
                        f.pattern.clone(),
                        spec.seed,
                        f.id as u64,
                    )),
                },
                queue: VecDeque::new(),
                mode: match f.slo {
                    Slo::Iops { .. } => ShapeMode::Iops,
                    _ => ShapeMode::Gbps,
                },
                inflight: 0,
                fetch_scheduled: Time::MAX,
                fetch_gen: 0,
                admitted: true,
                port: if spec.shared_port { 0 } else { f.id % 2 },
                path: f.path,
                last_bytes: 0,
                last_ops: 0,
                last_tick: 0,
                window_lat: Vec::new(),
                reconfigs: 0,
                current_slo: f.slo,
                arrived_at: 0,
                departed_at: None,
                arrival_pending: false,
                renegotiations_rejected: 0,
                contract_start: 0,
                contract_base_bytes: 0,
                contract_base_ops: 0,
                rogue: false,
            })
            .collect();

        let fw = fault_window(&spec.faults);
        let flow_homes: Vec<(usize, usize)> = spec
            .flows
            .iter()
            .enumerate()
            .map(|(i, f)| (f.vm, flow_tree[i]))
            .collect();
        let n_tenants = spec.flows.iter().map(|f| f.vm + 1).max().unwrap_or(0);
        let obs = ObsPlane::new(
            ObsConfig {
                control_period: spec.control_period,
                duration: spec.duration,
                retention: spec.obs_retention,
                sample_every: spec.obs_sample_every,
            },
            &flow_homes,
            n_tenants,
            n_trees,
            fw,
        );

        World {
            host_rng: Rng::for_stream(spec.seed, 0x4057),
            flows,
            tree_tick_scheduled: vec![Time::MAX; trees.len()],
            tree_tick_gen: vec![0; trees.len()],
            trees,
            flow_tree,
            scratch_eligible: Vec::new(),
            fabric,
            fabric_scheduled: Time::MAX,
            fabric_gen: 0,
            accel_scheduled: vec![Time::MAX; accels.len()],
            accel_gen: vec![0; accels.len()],
            accels,
            ports,
            raid,
            raid_scheduled: Time::MAX,
            raid_gen: 0,
            ops: Slab::with_capacity(64),
            next_frame: 0,
            metrics: (0..n).map(|_| FlowMetrics::new()).collect(),
            samplers: (0..n)
                .map(|_| ThroughputSampler::new(spec.sampler_window))
                .collect(),
            traces: (0..n).map(|_| Vec::new()).collect(),
            host_cfg,
            ctrl,
            scratch_fabric: Vec::new(),
            scratch_accel: Vec::new(),
            scratch_raid: Vec::new(),
            fault_window: fw,
            obs,
            pop: spec.population.as_ref().map(|c| PopAccounting::new(c.users)),
            control_outage_until: 0,
            directive_lag_max: 0,
            spec,
        }
    }

    /// Read-only handle on the control plane (observability / tests).
    pub fn control_plane(&self) -> &dyn ControlPlane {
        self.ctrl.as_ref()
    }

    // ---- Flow lifecycle (through the control-plane API) -----------------

    /// Register one flow with the control plane: admission control plus
    /// initial shaper programming. Failure marks the flow rejected (its
    /// offered traffic is dropped at the interface).
    fn api_register(&mut self, now: Time, flow: usize) {
        let fs = &self.spec.flows[flow];
        let accel_name = if fs.kind == FlowKind::Accel {
            self.spec.accels[fs.accel].name.to_string()
        } else {
            "storage".to_string()
        };
        let req = RegisterRequest {
            flow: fs.id,
            vm: fs.vm,
            path: fs.path,
            accel: fs.accel,
            accel_name,
            kind: fs.kind,
            slo: self.flows[flow].current_slo,
            size_hint: fs.pattern.sizes.mean().round() as u64,
        };
        match self.ctrl.register_flow(&req) {
            Ok(admitted) => {
                self.flows[flow].admitted = true;
                self.install_program(now, flow, admitted.program);
            }
            Err(_) => {
                self.flows[flow].admitted = false;
            }
        }
        // Counter baseline: the first measured window must span the flow's
        // own lifetime, not the pre-arrival era.
        self.flows[flow].last_tick = now;
        self.flows[flow].last_bytes = self.metrics[flow].bytes;
        self.flows[flow].last_ops = self.metrics[flow].completed;
        // A returning tenant that had renegotiated re-anchors its contract
        // era too — the silent departed gap must not dilute attainment.
        if self.flows[flow].contract_start > 0 {
            self.flows[flow].contract_start = now.max(1);
            self.flows[flow].contract_base_bytes = self.metrics[flow].bytes;
            self.flows[flow].contract_base_ops = self.metrics[flow].completed;
        }
        self.flows[flow].arrived_at = now;
        // Mirror the registration into the obs plane: recovery windows and
        // window-attainment gauges judge against the live contract.
        self.obs.note_arrival(flow, now);
        let slo = self.flows[flow].current_slo;
        self.obs.set_flow_slo(flow, slo);
    }

    /// Program the interface hardware (or host limiter) a control-plane
    /// response asked for: every program lands as a leaf of the flow's
    /// engine [`ShaperTree`] — flat leaves own the shaper verbatim (byte-
    /// identical to the pre-tree path), `Hierarchy` programs install a
    /// paced leaf and upsert the tenant/root envelopes they hang from.
    fn install_program(&mut self, now: Time, flow: usize, program: ShaperProgram) {
        // A fresh program supersedes any adversarial unshaped state: the
        // hardware registers are authoritative again.
        self.flows[flow].rogue = false;
        let t = self.flow_tree[flow];
        let vm = self.spec.flows[flow].vm;
        match program {
            ShaperProgram::Unshaped => {
                let mode = self.flows[flow].mode;
                self.trees[t].install_flat_leaf(flow, vm, None, mode);
            }
            ShaperProgram::TokenBucket { params, rate, mode } => {
                let mut tb = TokenBucket::new(params, mode);
                tb.set_rate(now, rate);
                self.trees[t].install_flat_leaf(flow, vm, Some(Box::new(tb)), mode);
                self.flows[flow].mode = mode;
            }
            ShaperProgram::Software { rate, mode } => {
                // Software rate limiting at the SLO's average rate (§5.1:
                // "the average ingress rate can be rate limited on the
                // host"); the engine supplies its CPU-interference model.
                let cfg = self
                    .host_cfg
                    .clone()
                    .unwrap_or_else(SoftwareShaperConfig::reflex);
                let shaper = SoftwareShaper::new(
                    rate,
                    mode,
                    cfg,
                    self.spec.seed ^ (0x50 + flow as u64),
                );
                self.trees[t].install_flat_leaf(flow, vm, Some(Box::new(shaper)), mode);
                self.flows[flow].mode = mode;
            }
            ShaperProgram::Hierarchy {
                tenant,
                guarantee,
                ceiling,
                tenant_guarantee,
                tenant_ceiling,
                engine_ceiling,
                mode,
            } => {
                self.trees[t].set_root_ceiling(if engine_ceiling.is_finite() {
                    Some(engine_ceiling)
                } else {
                    None
                });
                self.trees[t]
                    .set_tenant(tenant, NodeBudget::new(tenant_guarantee, tenant_ceiling));
                self.trees[t].install_paced_leaf(
                    flow,
                    tenant,
                    NodeBudget::new(guarantee, ceiling),
                    mode,
                );
                self.flows[flow].mode = mode;
            }
        }
    }

    /// A lifecycle `Arrive` fires: register with the control plane, then
    /// start the flow's traffic from now on (pre-arrival epochs of the
    /// deterministic generator are skipped, not replayed).
    fn ev_flow_arrives<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, flow: usize) {
        let now = sim.now();
        // A tenant may return after departing: re-arrival clears the
        // departed state so its traffic flows again, and re-registers
        // (re-facing admission control) since the departure released the
        // row. A duplicate Arrive while still registered is a no-op.
        self.flows[flow].departed_at = None;
        if self.ctrl.query_status(flow).is_none() {
            self.api_register(now, flow);
        }
        if !self.flows[flow].arrival_pending {
            self.activate_arrivals(sim, flow);
        }
    }

    /// A lifecycle `Depart` fires: deregister (releasing committed
    /// capacity), stop the generator, and drain the interface state.
    fn ev_flow_departs<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, flow: usize) {
        let _ = self.ctrl.deregister_flow(flow);
        let now = sim.now();
        self.flows[flow].departed_at = Some(now);
        self.trees[self.flow_tree[flow]].remove_leaf(flow);
        self.flows[flow].queue.clear();
    }

    /// A lifecycle `Renegotiate` fires: ask the control plane for a new
    /// contract. Acceptance reprograms the shaper after the reconfiguration
    /// latency; rejection keeps the old SLO in force.
    fn ev_renegotiate<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, flow: usize, slo: Slo) {
        if self.flows[flow].departed_at.is_some() || !self.flows[flow].admitted {
            return;
        }
        match self.ctrl.update_slo(flow, slo) {
            Ok(admitted) => {
                self.flows[flow].current_slo = slo;
                self.obs.set_flow_slo(flow, slo);
                // The new contract's attainment era starts at the decision
                // (the ~10 µs apply skew is negligible, and anchoring here
                // guarantees the era exists even when the run — or the
                // flow — ends inside the reconfiguration window).
                let now = sim.now();
                self.flows[flow].contract_start = now.max(1);
                self.flows[flow].contract_base_bytes = self.metrics[flow].bytes;
                self.flows[flow].contract_base_ops = self.metrics[flow].completed;
                self.schedule_directive(
                    sim,
                    Directive::install_program(now, flow, admitted.program),
                );
            }
            Err(ApiError::Rejection { .. }) => {
                self.flows[flow].renegotiations_rejected += 1;
            }
            // UnknownFlow / ordering errors (e.g. renegotiating before the
            // flow's Arrive event) are not capacity rejections.
            Err(_) => {}
        }
    }

    /// Schedule the flow's first arrival at or after `now`, skipping any
    /// generator epochs before it.
    fn activate_arrivals<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, flow: usize) {
        let now = sim.now();
        loop {
            let a = self.flows[flow].gen.next();
            if a.at >= self.spec.duration {
                return;
            }
            if a.at >= now {
                let (bytes, user) = (a.bytes, a.user);
                self.flows[flow].arrival_pending = true;
                sim.at(a.at, Ev::Inject { flow, bytes, user });
                return;
            }
        }
    }

    // ---- Arrivals --------------------------------------------------------

    fn schedule_next_arrival<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, flow: usize) {
        let a = self.flows[flow].gen.next();
        if a.at >= self.spec.duration {
            return;
        }
        let (bytes, user) = (a.bytes, a.user);
        self.flows[flow].arrival_pending = true;
        sim.at(a.at.max(sim.now()), Ev::Inject { flow, bytes, user });
    }

    /// A message enters the system at `now`.
    fn inject<Q: EventQueue<Ev>>(
        &mut self,
        sim: &mut Sim<Ev, Q>,
        flow: usize,
        bytes: u64,
        user: u32,
    ) {
        self.flows[flow].arrival_pending = false;
        if self.flows[flow].departed_at.is_some() {
            return; // departed: the VM stopped submitting (chain ends here)
        }
        let now = sim.now();
        self.schedule_next_arrival(sim, flow);
        if !self.flows[flow].admitted {
            self.metrics[flow].on_drop();
            self.obs.on_drop(flow);
            return;
        }
        if self.ingress_is_wire(flow) {
            // Frame serializes over the wire, then lands in the RX buffer
            // (or drops there if the shaped puller left it full).
            let port = self.flows[flow].port;
            let id = self.next_frame;
            self.next_frame += 1;
            let done = self.ports[port].rx_begin(now, bytes);
            sim.at(done, Ev::RxDeliver { port, id, flow, bytes, born: now, user });
        } else {
            // VM-side DMA buffer (function call / TX / storage).
            if self.flows[flow].queue.len() >= self.spec.queue_cap {
                if now >= self.spec.warmup {
                    self.metrics[flow].on_drop();
                    self.obs.on_drop(flow);
                }
                return;
            }
            self.flows[flow].queue.push_back(Msg { flow, bytes, born: now, user });
            self.kick_fetch(sim, flow, now);
        }
    }

    /// Does this flow's ingress come off the wire (RX buffer) rather than
    /// host memory?
    fn ingress_is_wire(&self, flow: usize) -> bool {
        matches!(self.flows[flow].path, Path::InlineNicRx | Path::InlineP2p)
            && self.spec.flows[flow].kind == FlowKind::Accel
    }

    // ---- Fetch engine ----------------------------------------------------

    /// Schedule a fetch attempt at `t` unless an earlier one is pending.
    /// A generation token voids superseded events (an event scheduled for a
    /// later time that a newer, earlier schedule replaced must not run, or
    /// stale self-rescheduling chains accumulate).
    fn kick_fetch<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, flow: usize, t: Time) {
        let t = t.max(sim.now());
        if t >= self.flows[flow].fetch_scheduled {
            return;
        }
        self.flows[flow].fetch_scheduled = t;
        self.flows[flow].fetch_gen += 1;
        let gen = self.flows[flow].fetch_gen;
        sim.at(t, Ev::Fetch { flow, gen });
    }

    /// The device-side fetch engine for one flow: gated by the shaper and
    /// the outstanding-fetch pipeline. This is where PatternA becomes
    /// PatternA′ — the decoupling of §4.1.
    fn ev_fetch<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, flow: usize) {
        loop {
            let now = sim.now();
            if self.flows[flow].inflight >= self.spec.fetch_pipeline {
                return; // a completion will re-kick
            }
            let is_rx = self.ingress_is_wire(flow);
            // Size of the next candidate message. Under Arcus the interface
            // keeps per-flow queues (frames demuxed by header); the
            // baselines drain a single FIFO ring, so a flow may only pull
            // when its frame is at the head — the head-of-line blocking the
            // paper attributes to interfaces without per-flow interposition.
            let per_flow_queues = self.spec.mode == Mode::Arcus;
            let bytes = if is_rx {
                let port = self.flows[flow].port;
                if per_flow_queues {
                    match self.ports[port].rx_flow_head(now, flow) {
                        Some(f) => f.bytes,
                        None => {
                            if let Some(ready) = self.ports[port].rx_flow_head_ready(flow) {
                                self.kick_fetch(sim, flow, ready);
                            }
                            return;
                        }
                    }
                } else {
                    match self.ports[port].rx_head() {
                        Some(f) if f.flow == flow && f.arrived <= now => f.bytes,
                        Some(f) if f.flow == flow => {
                            self.kick_fetch(sim, flow, f.arrived);
                            return;
                        }
                        _ => return, // head owned by another flow (or empty)
                    }
                }
            } else {
                match self.flows[flow].queue.front() {
                    Some(m) => m.bytes,
                    None => return,
                }
            };
            let cost = match self.flows[flow].mode {
                ShapeMode::Gbps => bytes,
                ShapeMode::Iops => 1,
            };
            // The shaping decision crosses the flow's engine tree (a rogue
            // tenant bypasses it — the adversary ignores its program until
            // the interface clamps it).
            let tree = self.flow_tree[flow];
            let verdict = if self.flows[flow].rogue {
                TreeVerdict::Admit
            } else {
                self.trees[tree].try_acquire(flow, now, cost)
            };
            match verdict {
                TreeVerdict::Admit => {
                    self.flows[flow].inflight += 1;
                    if is_rx {
                        let port = self.flows[flow].port;
                        let frame = if per_flow_queues {
                            self.ports[port]
                                .rx_pull_flow(now, flow)
                                .expect("head frame vanished")
                        } else {
                            let f = self.ports[port].rx_pull(now).expect("head vanished");
                            debug_assert_eq!(f.flow, flow);
                            // The new FIFO head may belong to another flow.
                            if let Some(next) = self.ports[port].rx_head() {
                                if next.flow != flow {
                                    self.kick_fetch(sim, next.flow, next.arrived.max(now));
                                }
                            }
                            f
                        };
                        let msg =
                            Msg { flow, bytes: frame.bytes, born: frame.born, user: frame.user };
                        // RX ingress data is already on the device: into the
                        // accelerator after the shaping decision latency.
                        let accel = self.spec.flows[flow].accel;
                        sim.at(now + SHAPING_LATENCY, Ev::SubmitAccel { accel, msg });
                    } else {
                        let msg = self.flows[flow].queue.pop_front().unwrap();
                        self.issue_ingress(sim, msg);
                    }
                }
                TreeVerdict::RetryAt(t) => {
                    self.kick_fetch(sim, flow, t);
                    return;
                }
                TreeVerdict::AwaitTick => {
                    // The leaf is parked inside the tree; ONE tree-wide
                    // pacing event re-drives every waiting flow — no
                    // per-flow queue entry.
                    self.ensure_tree_tick(sim, tree);
                    return;
                }
            }
        }
    }

    /// Schedule the next pacing pass for a tree, if any leaf waits and no
    /// earlier pass is pending. Passes fire on aligned interval
    /// boundaries, so the schedule is a pure function of the clock.
    fn ensure_tree_tick<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, tree: usize) {
        if !self.trees[tree].has_waiting() {
            return;
        }
        let at = self.trees[tree].next_tick_at(sim.now());
        if at >= self.tree_tick_scheduled[tree] {
            return;
        }
        self.tree_tick_scheduled[tree] = at;
        self.tree_tick_gen[tree] += 1;
        let gen = self.tree_tick_gen[tree];
        sim.at(at, Ev::ShaperTick { tree, gen });
    }

    /// One pacing pass: replenish aggregate credit and re-drive every
    /// leaf the tree released, in ascending flow id — a single
    /// O(active-children) sweep for the whole engine.
    fn ev_shaper_tick<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, tree: usize) {
        let now = sim.now();
        let mut eligible = std::mem::take(&mut self.scratch_eligible);
        self.trees[tree].tick(now, &mut eligible);
        for &flow in &eligible {
            if self.flows[flow].departed_at.is_none() {
                self.ev_fetch(sim, flow);
            }
        }
        eligible.clear();
        self.scratch_eligible = eligible;
        // Leaves that are still short re-registered during the sweep.
        self.ensure_tree_tick(sim, tree);
    }

    /// Issue the PCIe/SSD leg of a message's ingress per its path/kind.
    fn issue_ingress<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, msg: Msg) {
        let flow = msg.flow;
        match self.spec.flows[flow].kind {
            FlowKind::Accel => {
                // Fetch the payload from host memory: DMA read.
                let op = self.ops.insert(OpCtx { msg, stage: Stage::Fetch });
                self.fabric.read(flow, msg.bytes, op);
                self.wake_fabric(sim);
            }
            FlowKind::StorageRead => {
                // NVMe read: SSD first, then data DMA'd Up to the host.
                let op = self.ops.insert(OpCtx { msg, stage: Stage::SsdRead });
                self.raid
                    .as_mut()
                    .expect("storage flow without RAID")
                    .submit(Io { id: op, kind: IoKind::Read, bytes: msg.bytes });
                self.wake_raid(sim);
            }
            FlowKind::StorageWrite => {
                // NVMe write: fetch the data from host memory (Down), then
                // program the SSD.
                let op = self.ops.insert(OpCtx { msg, stage: Stage::Fetch });
                self.fabric.read(flow, msg.bytes, op);
                self.wake_fabric(sim);
            }
        }
    }

    /// Submit a payload-resident message to an accelerator.
    fn submit_accel<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, accel: usize, msg: Msg) {
        let op = self.ops.insert(OpCtx { msg, stage: Stage::Fetch });
        self.accels[accel].submit(Job { id: op, flow: msg.flow, bytes: msg.bytes });
        self.wake_accel(sim, accel);
    }

    // ---- Component pumps (dedup-scheduled wakes) ------------------------

    fn wake_fabric<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>) {
        let now = sim.now();
        // `take` always yields an empty vec: it is stored back only after
        // `drain` empties it, and reentrant calls see the fresh default.
        let mut done = std::mem::take(&mut self.scratch_fabric);
        debug_assert!(done.is_empty());
        let next = self.fabric.pump_into(now, &mut done);
        for d in done.drain(..) {
            self.on_fabric_op(sim, d);
        }
        self.scratch_fabric = done;
        if let Some(t) = next {
            let t = t.max(now + 1);
            if t < self.fabric_scheduled {
                self.fabric_scheduled = t;
                self.fabric_gen += 1;
                sim.at(t, Ev::WakeFabric { gen: self.fabric_gen });
            }
        }
    }

    fn wake_accel<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, i: usize) {
        let now = sim.now();
        let mut done = std::mem::take(&mut self.scratch_accel);
        debug_assert!(done.is_empty());
        let next = self.accels[i].pump_into(now, &mut done);
        for d in done.drain(..) {
            self.on_accel_done(sim, d.job.id, d.egress_bytes, d.at);
        }
        self.scratch_accel = done;
        if let Some(t) = next {
            let t = t.max(now + 1);
            if t < self.accel_scheduled[i] {
                self.accel_scheduled[i] = t;
                self.accel_gen[i] += 1;
                sim.at(t, Ev::WakeAccel { unit: i, gen: self.accel_gen[i] });
            }
        }
    }

    fn wake_raid<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>) {
        let now = sim.now();
        let Some(raid) = self.raid.as_mut() else { return };
        let mut done = std::mem::take(&mut self.scratch_raid);
        debug_assert!(done.is_empty());
        let next = raid.pump_into(now, &mut done);
        for d in done.drain(..) {
            self.on_raid_done(sim, d.io.id);
        }
        self.scratch_raid = done;
        if let Some(t) = next {
            let t = t.max(now + 1);
            if t < self.raid_scheduled {
                self.raid_scheduled = t;
                self.raid_gen += 1;
                sim.at(t, Ev::WakeRaid { gen: self.raid_gen });
            }
        }
    }

    // ---- Stage transitions ----------------------------------------------

    fn on_fabric_op<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, d: OpComplete) {
        let Some(ctx) = self.ops.remove(d.op) else { return };
        let msg = ctx.msg;
        let flow = msg.flow;
        match (ctx.stage, d.kind) {
            (Stage::Fetch, OpKind::Read) => match self.spec.flows[flow].kind {
                FlowKind::Accel => {
                    let accel = self.spec.flows[flow].accel;
                    self.submit_accel(sim, accel, msg);
                }
                FlowKind::StorageWrite => {
                    let op = self.ops.insert(OpCtx { msg, stage: Stage::SsdWrite });
                    self.raid
                        .as_mut()
                        .expect("storage flow without RAID")
                        .submit(Io { id: op, kind: IoKind::Write, bytes: msg.bytes });
                    self.wake_raid(sim);
                }
                FlowKind::StorageRead => unreachable!("reads start at the SSD"),
            },
            (Stage::Egress, OpKind::Write) => {
                self.complete(sim, msg, d.at);
            }
            (Stage::P2pStore, OpKind::Write) => {
                // Result crossed PCIe into the NVMe buffer: program the SSD.
                let op = self.ops.insert(OpCtx { msg, stage: Stage::SsdWrite });
                self.raid
                    .as_mut()
                    .expect("p2p flow without RAID")
                    .submit(Io { id: op, kind: IoKind::Write, bytes: msg.bytes });
                self.wake_raid(sim);
            }
            (stage, kind) => unreachable!("fabric {kind:?} in stage {stage:?}"),
        }
    }

    fn on_accel_done<Q: EventQueue<Ev>>(
        &mut self,
        sim: &mut Sim<Ev, Q>,
        op: u64,
        egress_bytes: u64,
        at: Time,
    ) {
        let Some(ctx) = self.ops.remove(op) else { return };
        let msg = ctx.msg;
        let flow = msg.flow;
        match self.flows[flow].path {
            Path::FunctionCall | Path::InlineNicRx => {
                // Result DMA-written to host memory (Up).
                let op2 = self.ops.insert(OpCtx { msg, stage: Stage::Egress });
                self.fabric.write(flow, egress_bytes, op2);
                self.wake_fabric(sim);
            }
            Path::InlineNicTx => {
                // Result leaves on the wire.
                let port = self.flows[flow].port;
                let done = self.ports[port].tx_frame(at, egress_bytes);
                sim.at(done.max(sim.now()), Ev::TxDone { msg });
            }
            Path::InlineP2p => {
                // Result shaped into the NVMe subsystem: PCIe write + program.
                let op2 = self.ops.insert(OpCtx { msg, stage: Stage::P2pStore });
                self.fabric.write(flow, egress_bytes, op2);
                self.wake_fabric(sim);
            }
        }
    }

    fn on_raid_done<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, op: u64) {
        let Some(ctx) = self.ops.remove(op) else { return };
        let msg = ctx.msg;
        let flow = msg.flow;
        match ctx.stage {
            Stage::SsdRead => {
                // Data DMA'd Up to the host.
                let op2 = self.ops.insert(OpCtx { msg, stage: Stage::Egress });
                self.fabric.write(flow, msg.bytes, op2);
                self.wake_fabric(sim);
            }
            Stage::SsdWrite => {
                let t = sim.now();
                self.complete(sim, msg, t);
            }
            other => unreachable!("raid completion in stage {other:?}"),
        }
    }

    /// A message finished its device-side journey.
    fn complete<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, msg: Msg, at: Time) {
        // Host-interposed modes pay CPU-interference cost on the completion
        // path (guest notification / vCPU wakeup through the hypervisor).
        if let Some(cfg) = self.host_cfg.clone() {
            let mut extra = cfg.decision_overhead;
            if self.host_rng.chance(cfg.preempt_prob) {
                extra += (self
                    .host_rng
                    .pareto(cfg.preempt_scale as f64, cfg.preempt_alpha)
                    as Time)
                    .min(cfg.preempt_cap);
            }
            if extra > 0 {
                let later = at.max(sim.now()) + extra;
                sim.at(later, Ev::HostFinish { msg });
                return;
            }
        }
        self.finish(sim, msg, at.max(sim.now()));
    }

    fn finish<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, msg: Msg, at: Time) {
        let flow = msg.flow;
        self.flows[flow].inflight = self.flows[flow].inflight.saturating_sub(1);
        if at >= self.spec.warmup {
            self.metrics[flow].on_complete(at, msg.born, msg.bytes);
            match self.flows[flow].mode {
                ShapeMode::Iops => self.samplers[flow].on_complete_op(at),
                ShapeMode::Gbps => self.samplers[flow].on_complete(at, msg.bytes),
            }
            let lat = at.saturating_sub(msg.born);
            self.flows[flow].window_lat.push(lat);
            if self.spec.trace {
                self.traces[flow].push((at, lat, msg.bytes));
            }
            // The obs plane folds the completion into every level — flow
            // counters, tenant/engine histograms, and (on faulted runs) the
            // per-era + recovery accounting `FlowReport.fault` derives
            // from. Completion times arrive monotone here, which is what
            // its era-boundary snapshotting relies on.
            self.obs.on_complete(flow, at, lat, msg.bytes);
            if let Some(pop) = self.pop.as_mut() {
                pop.on_complete(msg.user, lat, msg.bytes);
            }
        }
        // The freed pipeline slot can admit the next message.
        self.kick_fetch(sim, flow, at);
    }

    // ---- Control plane ----------------------------------------------------

    /// One tick of Algorithm 1 (control planes that need ticks only): read
    /// the hardware counters into per-flow windows, hand them to the
    /// control plane, and apply the resulting directives after the
    /// reconfiguration latency (~10 µs of MMIO round trips, §5.3.1) —
    /// without interrupting dataplane operation.
    fn ev_control_tick<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>) {
        let now = sim.now();
        // ControlOutage fault: the tick is lost — counters are not read,
        // so the next surviving tick measures one long window spanning the
        // outage (exactly what a wedged control plane would see).
        if now < self.control_outage_until {
            return;
        }
        // 1. Refresh measured windows from the "hardware counters".
        let tick = now / self.spec.control_period.max(1);
        let mut windows: Vec<(usize, MeasuredWindow)> = Vec::new();
        for i in 0..self.flows.len() {
            if self.ctrl.query_status(i).is_none() {
                continue;
            }
            let m = &self.metrics[i];
            let span = now - self.flows[i].last_tick;
            let bytes = m.bytes - self.flows[i].last_bytes;
            let ops = m.completed - self.flows[i].last_ops;
            let p99 = if self.flows[i].window_lat.is_empty() {
                None
            } else {
                let mut v = std::mem::take(&mut self.flows[i].window_lat);
                v.sort_unstable();
                let idx = ((v.len() - 1) as f64 * 0.99).round() as usize;
                Some(v[idx])
            };
            self.flows[i].last_bytes = m.bytes;
            self.flows[i].last_ops = m.completed;
            self.flows[i].last_tick = now;
            // The obs plane samples its series from the very window the
            // control plane is about to plan on — no re-measurement, no
            // extra events, no allocation. Series are indexed by this
            // deterministic tick number, never wall clock.
            let depth = self.flows[i].queue.len() + self.flows[i].inflight;
            self.obs.on_control_sample(
                tick,
                i,
                span,
                bytes,
                ops,
                p99,
                depth,
                self.flows[i].reconfigs as u64,
            );
            windows.push((i, MeasuredWindow { span, bytes, ops, p99_latency: p99 }));
        }
        self.obs.on_tick_done(tick);
        // 2. Plan through the API (the telemetry-bearing context); 3. apply
        // with the MMIO latency.
        let ctx = TickContext::new(now, &windows).with_obs(&self.obs);
        let directives = self.ctrl.tick(&ctx);
        for d in directives {
            self.schedule_directive(sim, d);
        }
    }

    /// Schedule a directive onto the hardware. This is the ONE place the
    /// reconfiguration latency is charged: every control-plane decision —
    /// reshape, path switch, aggregate envelope, renegotiated program —
    /// lands `spec.reconfig_latency` (~10 µs of MMIO round trips, §5.3.1)
    /// after it was issued, via the same `ApplyDirective` event.
    fn schedule_directive<Q: EventQueue<Ev>>(&self, sim: &mut Sim<Ev, Q>, d: Directive) {
        sim.after(self.spec.reconfig_latency, Ev::ApplyDirective(d));
    }

    /// Apply one control-plane directive to the hardware.
    fn apply_directive<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, d: Directive) {
        let now = sim.now();
        // Propagation lag is measurable because directives carry their
        // issue stamp; under `schedule_directive`'s single rule the max
        // equals the reconfig latency.
        self.directive_lag_max = self.directive_lag_max.max(now.saturating_sub(d.issued_at));
        match d.kind {
            DirectiveKind::SetRate { flow, rate } => {
                // Reprogramming the registers clamps an adversarial tenant
                // too: the tenant can ignore software, not registers —
                // clearing `rogue` puts the (untouched) leaf back in force
                // at the directive's rate.
                let was_rogue = std::mem::replace(&mut self.flows[flow].rogue, false);
                let t = self.flow_tree[flow];
                if self.trees[t].set_leaf_rate(flow, now, rate) || was_rogue {
                    self.flows[flow].reconfigs += 1;
                }
                self.kick_fetch(sim, flow, now);
            }
            DirectiveKind::SwitchPath { flow, to } => {
                self.flows[flow].path = to;
                self.flows[flow].reconfigs += 1;
                self.kick_fetch(sim, flow, now);
            }
            DirectiveKind::SetAggregate { engine, tenant, guarantee, ceiling } => {
                // Tree-install: reprogram a tenant aggregate node. Waiting
                // leaves see the new envelope at the next pacing pass.
                if let Some(tree) = self.trees.get_mut(engine) {
                    tree.set_tenant(tenant, NodeBudget::new(guarantee, ceiling));
                }
            }
            DirectiveKind::InstallProgram { flow, program } => {
                if self.flows[flow].departed_at.is_some() {
                    return; // departed inside the reconfig window
                }
                self.install_program(now, flow, program);
                self.flows[flow].reconfigs += 1;
                self.kick_fetch(sim, flow, now);
            }
        }
    }

    // ---- Fault injection (see crate::faults) ----------------------------

    /// A scheduled fault takes hold: mutate the targeted component. Work
    /// already in flight (the TLP on the wire, the job in the pipeline)
    /// keeps its finish time — injection never rewrites the past, which is
    /// what keeps it deterministic across event-queue disciplines.
    fn ev_fault_start<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, idx: usize) {
        let f = self.spec.faults[idx];
        match f.kind {
            FaultKind::AccelSlowdown { unit, factor } => {
                self.accels[unit].set_slowdown(factor);
            }
            FaultKind::LinkDegrade { factor } => {
                self.fabric.set_link_degradation(factor);
            }
            FaultKind::SsdSlowdown { factor } => {
                if let Some(r) = self.raid.as_mut() {
                    r.set_latency_factor(factor);
                }
            }
            FaultKind::ProfileSkew { accel, factor } => {
                let name = self.spec.accels[accel].name;
                self.ctrl.set_profile_skew(name, factor);
            }
            FaultKind::RogueTenant { flow } => {
                // The tenant stops honoring its program: its fetches
                // bypass the shaper tree until a control-plane directive
                // clamps it (apply_directive / install_program clear the
                // flag, putting the untouched leaf back in force).
                self.flows[flow].rogue = true;
                let now = sim.now();
                self.kick_fetch(sim, flow, now);
            }
            FaultKind::ControlOutage => {
                self.control_outage_until = f.until;
            }
        }
    }

    /// A fault's window ends: the component heals — unless a back-to-back
    /// window on the same component starts at this very instant and its
    /// `FaultStart` already ran (plan order, not time order, breaks the
    /// tie): healing then would clobber the newly applied state. The check
    /// is a pure function of the plan and `now`, so determinism holds.
    fn ev_fault_end<Q: EventQueue<Ev>>(&mut self, sim: &mut Sim<Ev, Q>, idx: usize) {
        let f = self.spec.faults[idx];
        let now = sim.now();
        let target = f.kind.target();
        let superseded = self
            .spec
            .faults
            .iter()
            .enumerate()
            .any(|(j, g)| j != idx && g.kind.target() == target && g.at <= now && now < g.until);
        if superseded {
            return;
        }
        match f.kind {
            FaultKind::AccelSlowdown { unit, .. } => {
                self.accels[unit].set_slowdown(1.0);
                self.wake_accel(sim, unit);
            }
            FaultKind::LinkDegrade { .. } => {
                self.fabric.set_link_degradation(1.0);
                self.wake_fabric(sim);
            }
            FaultKind::SsdSlowdown { .. } => {
                if let Some(r) = self.raid.as_mut() {
                    r.set_latency_factor(1.0);
                }
            }
            FaultKind::ProfileSkew { accel, .. } => {
                // Re-profiling heals the table; the next control tick's
                // over-commit reconciliation reacts to whatever admissions
                // the skewed table allowed.
                let name = self.spec.accels[accel].name;
                self.ctrl.set_profile_skew(name, 1.0);
            }
            FaultKind::RogueTenant { flow } => {
                // If the control plane never clamped the tenant, it gives
                // up at the window's end and resumes its program: the leaf
                // (hardware bucket, host limiter, or tree budget) was
                // never removed, so clearing the bypass restores exactly
                // the pre-fault shaping state.
                if self.flows[flow].rogue {
                    self.flows[flow].rogue = false;
                    self.kick_fetch(sim, flow, now);
                }
            }
            FaultKind::ControlOutage => {
                self.control_outage_until = 0;
            }
        }
    }
}

/// The engine: a [`World`] plus its simulator, generic over the event-queue
/// discipline (the reference binary heap by default).
pub struct Engine<Q: EventQueue<EngineEvent> = BinaryHeapQueue<EngineEvent>> {
    pub sim: Sim<EngineEvent, Q>,
    pub world: World,
}

impl Engine {
    /// Build on the reference binary-heap queue.
    pub fn new(spec: ExperimentSpec) -> Self {
        Self::build(spec)
    }
}

impl<Q: EventQueue<EngineEvent> + Default> Engine<Q> {
    /// Build on queue discipline `Q` (see [`crate::sim::CalendarQueue`]).
    pub fn build(spec: ExperimentSpec) -> Self {
        Self::build_inner(spec, None)
    }

    /// Build with each flow's arrivals driven by a recorded trace instead of
    /// its generator (`arcus trace replay`). The spec must carry the same
    /// `[population]` the trace was recorded under — the header's
    /// user/flow counts are checked here, so a mismatched spec fails loudly
    /// instead of replaying nonsense.
    pub fn build_replay(spec: ExperimentSpec, trace: &TraceData) -> Result<Self, String> {
        let cfg = spec
            .population
            .as_ref()
            .ok_or("trace replay requires the spec's [population] table")?;
        if trace.users != cfg.users as u64 || trace.flows != spec.flows.len() as u64 {
            return Err(format!(
                "trace was recorded for {} users / {} flows but the spec has {} / {}",
                trace.users,
                trace.flows,
                cfg.users,
                spec.flows.len()
            ));
        }
        // Re-partition the time-sorted records into per-flow cursors; each
        // flow's subsequence is nondecreasing in time, which is all the
        // engine's pull discipline needs.
        let mut per_flow: Vec<Vec<PopArrival>> = vec![Vec::new(); spec.flows.len()];
        for r in &trace.records {
            per_flow[r.flow as usize].push(PopArrival {
                at: r.at,
                user: r.user,
                bytes: r.bytes,
            });
        }
        Ok(Self::build_inner(spec, Some(per_flow)))
    }

    fn build_inner(spec: ExperimentSpec, replay: Option<Vec<Vec<PopArrival>>>) -> Self {
        let mut world = World::new(spec, replay);
        let mut sim: Sim<EngineEvent, Q> = Sim::new();
        let n = world.flows.len();
        // A flow is present from t = 0 unless its *earliest* lifecycle
        // event is an Arrive (it joins later). Initially-present flows
        // register through the control plane in id order (the legacy
        // admission sequence) before any sim event fires.
        let present: Vec<bool> = (0..n)
            .map(|i| {
                world
                    .spec
                    .lifecycle
                    .iter()
                    .filter(|e| e.flow() == i)
                    .min_by_key(|e| e.at())
                    .map(|e| !matches!(e, LifecycleEvent::Arrive { .. }))
                    .unwrap_or(true)
            })
            .collect();
        for i in 0..n {
            if present[i] {
                world.api_register(0, i);
            }
        }
        for i in 0..n {
            if present[i] {
                world.activate_arrivals(&mut sim, i);
            }
        }
        // Every lifecycle event is scheduled — including repeat Arrives
        // (a tenant returning after a departure re-faces admission).
        for e in &world.spec.lifecycle {
            debug_assert!(
                e.flow() < n,
                "lifecycle event for unknown flow {} (spec has {n} flows)",
                e.flow()
            );
            match *e {
                LifecycleEvent::Arrive { flow, at } if flow < n => {
                    sim.at(at, Ev::FlowArrives { flow });
                }
                LifecycleEvent::Depart { flow, at } if flow < n => {
                    sim.at(at, Ev::FlowDeparts { flow });
                }
                LifecycleEvent::Renegotiate { flow, at, slo } if flow < n => {
                    sim.at(at, Ev::Renegotiate { flow, slo });
                }
                _ => {}
            }
        }
        // Fault plan: injection and heal events ride the same (time, seq)
        // queue as the dataplane — determinism survives injection.
        for (idx, f) in world.spec.faults.iter().enumerate() {
            debug_assert!(f.at < f.until, "empty fault window {idx}");
            sim.at(f.at, Ev::FaultStart { idx });
            sim.at(f.until, Ev::FaultEnd { idx });
        }
        // Control-plane ticker (Algorithm 1 "run by every client server
        // periodically"); only control planes that plan online need it.
        // The tick event re-arms itself while the run lasts.
        if world.ctrl.needs_ticks() {
            sim.after(world.spec.control_period, Ev::ControlTick);
        }
        Engine { sim, world }
    }

    /// Advance the event core to `t`. Follows `Sim::run_until`'s boundary
    /// contract — events at exactly `t` fire before the clock pins — so
    /// repeated stepped calls (the fleet's interchange barriers) compose to
    /// exactly the same execution as one `run` to the final time.
    pub fn step_to(&mut self, t: Time) {
        self.sim.run_until(&mut self.world, t);
    }

    /// Host spec (read side for external control tiers).
    pub fn spec(&self) -> &ExperimentSpec {
        &self.world.spec
    }

    /// Telemetry read side for external control tiers: wrap in
    /// [`crate::api::ObsView`] to read series without structural access.
    pub fn obs(&self) -> &ObsPlane {
        &self.world.obs
    }

    /// Inject a directive delivered by an external (fleet) control tier: it
    /// lands on the host at `at` (which must not be in the host's past) and
    /// takes effect one reconfiguration latency later, through the same
    /// `ApplyDirective` path as locally planned directives.
    pub fn deliver_directive(&mut self, at: Time, d: Directive) {
        self.sim
            .at(at + self.world.spec.reconfig_latency, Ev::ApplyDirective(d));
    }

    /// Run to the spec's duration and produce the report.
    pub fn run(mut self) -> SystemReport {
        let start = std::time::Instant::now();
        let duration = self.world.spec.duration;
        self.step_to(duration);
        let wall = start.elapsed().as_secs_f64();
        self.finish(wall)
    }

    /// Consume the engine and assemble its report. `wall_secs` is the
    /// caller's wall-clock measurement (`run` measures its own; the fleet
    /// measures across all hosts).
    pub fn finish(self, wall: f64) -> SystemReport {
        let duration = self.world.spec.duration;
        let w = self.world;
        let span = duration - w.spec.warmup;
        let per_flow = w
            .spec
            .flows
            .iter()
            .enumerate()
            .map(|(i, f)| {
                let mut r = FlowReport::from_metrics(
                    f.id,
                    f.vm,
                    w.flows[i].current_slo,
                    !w.flows[i].admitted,
                    &w.metrics[i],
                    w.samplers[i].clone(),
                    w.flows[i].reconfigs,
                    w.traces[i].clone(),
                );
                r.arrived_at = w.flows[i].arrived_at;
                r.departed_at = w.flows[i].departed_at;
                r.renegotiations_rejected = w.flows[i].renegotiations_rejected;
                // Fault-era metrics: attainment per era, worst-era tails,
                // and recovery time (see crate::faults). Era spans are
                // clamped to the flow's own active lifetime so a churn
                // cell's late arrival (or early departure) does not dilute
                // its era rates with time it was absent. (A tenant that
                // departs and re-arrives is judged from its last arrival,
                // like contract attainment.)
                if let Some((fs, fe)) = w.fault_window {
                    let slo = w.flows[i].current_slo;
                    // Era bytes/ops/p99 are *derived from the obs plane's
                    // series counters* (boundary snapshots of the same
                    // cumulative totals the tick series samples), not from
                    // bespoke accounting; `rust/tests/faults.rs` pins them
                    // against a trace-derived oracle.
                    let eras = w.obs.flow_eras(i).expect("faulted run tracks eras");
                    let active_lo = w.flows[i].arrived_at.max(w.spec.warmup);
                    let active_hi = w.flows[i].departed_at.unwrap_or(duration);
                    let overlap = |lo: Time, hi: Time| {
                        hi.min(active_hi).saturating_sub(lo.max(active_lo))
                    };
                    let spans = [
                        overlap(w.spec.warmup, fs),
                        overlap(fs, fe),
                        overlap(fe, duration),
                    ];
                    let era = |k: usize| {
                        let (bytes, ops, p99) = eras[k];
                        EraReport::new(bytes, ops, spans[k], p99, &slo)
                    };
                    r.fault = Some(FaultReport {
                        pre: era(0),
                        during: era(1),
                        post: era(2),
                        recovery_time: w
                            .obs
                            .recovered_at(i)
                            .map(|t| t.saturating_sub(fe)),
                    });
                }
                // Attainment era for renegotiated flows: from the moment
                // the new contract's shaper took effect.
                if w.flows[i].contract_start > 0 {
                    let m = &w.metrics[i];
                    if let Some(last) = m.last_completion {
                        // Metrics only accrue post-warmup: a contract
                        // agreed before warmup must not count the silent
                        // prefix against itself.
                        let start = w.flows[i].contract_start.max(w.spec.warmup);
                        let era = last.saturating_sub(start);
                        if era > 0 {
                            let bytes = m.bytes - w.flows[i].contract_base_bytes;
                            let ops = m.completed - w.flows[i].contract_base_ops;
                            r.contract_goodput =
                                Some(crate::util::units::throughput(bytes, era));
                            r.contract_iops = Some(
                                ops as f64 * crate::util::units::SECONDS as f64
                                    / era as f64,
                            );
                        }
                    }
                }
                r
            })
            .collect();
        use crate::pcie::link::Dir;
        let obs = w.obs.into_snapshot();
        let series_digest = obs.digest();
        SystemReport {
            mode: w.spec.mode.name(),
            per_flow,
            measured_span: span,
            pcie_up_util: w.fabric.link().busy_time(Dir::Up) as f64 / duration as f64,
            pcie_down_util: w.fabric.link().busy_time(Dir::Down) as f64 / duration as f64,
            accel_util: w.accels.iter().map(|a| a.utilization(duration)).collect(),
            nic_rx_dropped: w.ports.iter().map(|p| p.rx_dropped).sum(),
            fault_window: w.fault_window,
            directive_lag_max: w.directive_lag_max,
            directive_staleness_max: 0,
            host_rollups: Vec::new(),
            events: self.sim.executed(),
            peak_queue_depth: self.sim.peak_pending(),
            queue: self.sim.queue_name(),
            wall_secs: wall,
            series_digest,
            obs,
            fairness: w.pop.as_ref().map(|p| p.report()),
        }
    }
}

/// Convenience: build + run on the reference binary-heap queue.
pub fn run(spec: &ExperimentSpec) -> SystemReport {
    Engine::new(spec.clone()).run()
}

/// Build + run on a chosen queue discipline, e.g.
/// `run_with::<CalendarQueue<EngineEvent>>(&spec)`.
pub fn run_with<Q: EventQueue<EngineEvent> + Default>(spec: &ExperimentSpec) -> SystemReport {
    Engine::<Q>::build(spec.clone()).run()
}

/// Build + run with arrivals replayed from a recorded trace (reference
/// binary-heap queue).
pub fn run_replay(spec: &ExperimentSpec, trace: &TraceData) -> Result<SystemReport, String> {
    Ok(Engine::<BinaryHeapQueue<EngineEvent>>::build_replay(spec.clone(), trace)?.run())
}

/// Build + run a trace replay on a chosen queue discipline.
pub fn run_replay_with<Q: EventQueue<EngineEvent> + Default>(
    spec: &ExperimentSpec,
    trace: &TraceData,
) -> Result<SystemReport, String> {
    Ok(Engine::<Q>::build_replay(spec.clone(), trace)?.run())
}

/// Enumerate the arrival trace a population spec implies, without running
/// the engine (`arcus trace record`). Uses the same flow-home construction
/// [`Engine::build`] uses, so replaying the recording against the same
/// spec produces a byte-identical report.
pub fn record_population_trace(
    spec: &ExperimentSpec,
) -> Result<Vec<crate::workload::TraceRecord>, String> {
    let cfg = spec
        .population
        .as_ref()
        .ok_or("trace recording requires the spec's [population] table")?;
    cfg.validate(spec.flows.len())?;
    let homes: Vec<_> = spec
        .flows
        .iter()
        .map(|f| (f.vm as u32, f.pattern.offered()))
        .collect();
    Ok(crate::workload::record_trace(cfg, spec.seed, spec.duration, &homes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::AccelModel;
    use crate::flow::{FlowSpec, TrafficPattern};
    use crate::sim::{CalendarQueue, HierWheel};
    use crate::storage::SsdConfig;
    use crate::util::units::{Rate, MILLIS};

    fn two_flow_spec(mode: Mode, load1: f64, load2: f64) -> ExperimentSpec {
        let line = Rate::gbps(32.0);
        let flows = vec![
            FlowSpec::new(
                0,
                0,
                Path::FunctionCall,
                TrafficPattern::fixed(1500, load1, line),
                Slo::gbps(10.0),
                0,
            ),
            FlowSpec::new(
                1,
                1,
                Path::FunctionCall,
                TrafficPattern::fixed(1500, load2, line),
                Slo::gbps(12.0),
                0,
            ),
        ];
        ExperimentSpec::new(mode, vec![AccelModel::ipsec_32g()], flows)
            .with_duration(3 * MILLIS)
            .with_warmup(MILLIS / 2)
    }

    #[test]
    fn function_call_flow_completes_under_all_modes() {
        for mode in [
            Mode::Arcus,
            Mode::HostNoTs,
            Mode::HostTsReflex,
            Mode::HostTsFirecracker,
            Mode::BypassedPanic,
        ] {
            let report = run(&two_flow_spec(mode, 0.2, 0.2));
            for f in &report.per_flow {
                assert!(
                    f.completed > 1000,
                    "{}: flow {} completed {}",
                    mode.name(),
                    f.flow,
                    f.completed
                );
            }
        }
    }

    #[test]
    fn arcus_shapes_to_slo_under_oversubscription() {
        // Both flows offer 0.5×32 G each (oversubscribed vs their SLOs);
        // Arcus should trim them to ~10 and ~12 Gbps.
        let report = run(&two_flow_spec(Mode::Arcus, 0.5, 0.5));
        let f0 = &report.per_flow[0];
        let f1 = &report.per_flow[1];
        let a0 = f0.goodput.as_gbps();
        let a1 = f1.goodput.as_gbps();
        assert!((a0 - 10.0).abs() / 10.0 < 0.08, "flow0 {a0:.2} Gbps");
        assert!((a1 - 12.0).abs() / 12.0 < 0.08, "flow1 {a1:.2} Gbps");
    }

    #[test]
    fn calendar_queue_produces_identical_report() {
        // The engine-level determinism contract across queue disciplines;
        // the full golden test lives in rust/tests/determinism.rs.
        let spec = two_flow_spec(Mode::Arcus, 0.5, 0.4);
        let heap = run(&spec);
        let cal = run_with::<CalendarQueue<EngineEvent>>(&spec);
        assert_eq!(heap.canonical(), cal.canonical());
        assert_eq!(heap.events, cal.events);
        assert_eq!(heap.peak_queue_depth, cal.peak_queue_depth);
    }

    #[test]
    fn hier_wheel_produces_identical_report() {
        let spec = two_flow_spec(Mode::Arcus, 0.5, 0.4);
        let heap = run(&spec);
        let wheel = run_with::<HierWheel<EngineEvent>>(&spec);
        assert_eq!(wheel.queue, "hier_wheel");
        assert_eq!(heap.canonical(), wheel.canonical());
        assert_eq!(heap.events, wheel.events);
        assert_eq!(heap.peak_queue_depth, wheel.peak_queue_depth);
    }

    #[test]
    fn unshaped_baseline_violates_slo_split() {
        // Same demand, no shaping: flows split the engine ~evenly instead of
        // the 10/12 SLO, and variance is higher.
        let report = run(&two_flow_spec(Mode::HostNoTs, 0.8, 0.8));
        let a0 = report.per_flow[0].goodput.as_gbps();
        let a1 = report.per_flow[1].goodput.as_gbps();
        // Engine sustains ~26 Gbps at 1500 B; equal split ≈ 13/13 — flow 1
        // under-attains its 12 G SLO is false here, but flow 0 *over*-attains
        // 10 G: allocation does not follow SLOs.
        assert!((a0 / a1 - 1.0).abs() < 0.1, "even split expected: {a0:.1}/{a1:.1}");
    }

    #[test]
    fn storage_flows_complete_and_shape() {
        let ssd = SsdConfig::samsung_983dct();
        let flows = vec![
            FlowSpec::storage(
                0,
                0,
                TrafficPattern::fixed(4096, 0.5, Rate::gbps(20.0)),
                Slo::iops(300_000.0),
                FlowKind::StorageRead,
            ),
            FlowSpec::storage(
                1,
                1,
                TrafficPattern::fixed(4096, 0.5, Rate::gbps(20.0)),
                Slo::iops(200_000.0),
                FlowKind::StorageWrite,
            ),
        ];
        let spec = ExperimentSpec::new(Mode::Arcus, vec![], flows)
            .with_duration(10 * MILLIS)
            .with_warmup(MILLIS)
            .with_raid(4, ssd);
        let report = run(&spec);
        assert!(report.per_flow[0].completed > 1000);
        assert!(report.per_flow[1].completed > 100);
        // Reads shaped at 300K IOPS: 0.5×20G/4KB = 305K offered.
        let iops0 = report.per_flow[0].iops;
        assert!(
            (iops0 - 300_000.0).abs() / 300_000.0 < 0.05,
            "read iops {iops0:.0}"
        );
    }

    #[test]
    fn rx_path_flows_complete() {
        let flows = vec![FlowSpec::new(
            0,
            0,
            Path::InlineNicRx,
            TrafficPattern::fixed(1500, 0.4, Rate::gbps(50.0)),
            Slo::gbps(15.0),
            0,
        )];
        let spec = ExperimentSpec::new(Mode::Arcus, vec![AccelModel::aes_128()], flows)
            .with_duration(5 * MILLIS)
            .with_warmup(MILLIS);
        let report = run(&spec);
        assert!(report.per_flow[0].completed > 1000);
        let gbps = report.per_flow[0].goodput.as_gbps();
        assert!((gbps - 15.0).abs() / 15.0 < 0.1, "rx goodput {gbps:.2}");
    }

    #[test]
    fn baseline_fifo_ring_blocks_latency_flow_behind_backlog() {
        // Shared port, a tiny latency flow beside an oversubscribed MTU
        // stream: Arcus (per-flow queues) must beat the FIFO-ring baseline
        // on the tiny flow's tail.
        let line = Rate::gbps(50.0);
        let mk = |mode| {
            let flows = vec![
                FlowSpec {
                    id: 0,
                    vm: 0,
                    path: Path::InlineNicRx,
                    pattern: TrafficPattern::fixed(64, 0.02, line),
                    slo: Slo::Latency { max_ps: crate::util::units::MICROS, percentile: 99.0 },
                    accel: 0,
                    kind: FlowKind::Accel,
                    priority: 0,
                },
                FlowSpec {
                    id: 1,
                    vm: 1,
                    path: Path::InlineNicRx,
                    pattern: {
                        let mut p = TrafficPattern::fixed(1500, 0.72, line);
                        p.burst = crate::flow::pattern::Burstiness::Poisson;
                        p
                    },
                    slo: Slo::gbps(32.0),
                    accel: 0,
                    kind: FlowKind::Accel,
                    priority: 1,
                },
            ];
            ExperimentSpec::new(
                mode,
                vec![AccelModel::synthetic(Rate::gbps(40.0))],
                flows,
            )
            .with_duration(4 * MILLIS)
            .with_warmup(MILLIS)
            .with_shared_port()
        };
        let arcus = run(&mk(Mode::Arcus));
        let base = run(&mk(Mode::BypassedPanic));
        assert!(
            arcus.per_flow[0].lat_p99 < base.per_flow[0].lat_p99,
            "arcus p99 {} !< baseline p99 {}",
            arcus.per_flow[0].lat_p99,
            base.per_flow[0].lat_p99
        );
        // And the stream is pinned at its SLO only under Arcus.
        let a = arcus.per_flow[1].goodput.as_gbps();
        let b = base.per_flow[1].goodput.as_gbps();
        assert!((a - 32.0).abs() < 1.2, "arcus stream {a:.2}");
        assert!(b > 34.0, "baseline overload expected, got {b:.2}");
    }

    #[test]
    fn best_effort_backs_off_when_committed_flow_violates() {
        // A committed flow and a greedy best-effort flow; mid-run the
        // committed flow's demand rises. The BE flow must shrink.
        let line = Rate::gbps(32.0);
        let flows = vec![
            FlowSpec::new(
                0,
                0,
                Path::FunctionCall,
                TrafficPattern::fixed(4096, 0.6, line),
                Slo::gbps(18.0),
                0,
            ),
            FlowSpec::new(
                1,
                1,
                Path::FunctionCall,
                TrafficPattern::fixed(4096, 0.9, line),
                Slo::BestEffort,
                0,
            ),
        ];
        let spec = ExperimentSpec::new(Mode::Arcus, vec![AccelModel::ipsec_32g()], flows)
            .with_duration(8 * MILLIS)
            .with_warmup(2 * MILLIS);
        let r = run(&spec);
        let committed = r.per_flow[0].slo_attainment().unwrap();
        assert!(committed > 0.93, "committed attainment {committed:.2}");
        // Engine ~32 G effective at 4 KB: BE gets the leftover, not more.
        let be = r.per_flow[1].goodput.as_gbps();
        assert!(be < 16.0, "best effort {be:.2} should be bounded by leftovers");
    }

    #[test]
    fn deterministic_across_runs() {
        let mut spec = two_flow_spec(Mode::BypassedPanic, 0.3, 0.4);
        spec.duration = 2 * MILLIS;
        let a = run(&spec);
        let b = run(&spec);
        for (x, y) in a.per_flow.iter().zip(b.per_flow.iter()) {
            assert_eq!(x.completed, y.completed);
            assert_eq!(x.bytes, y.bytes);
            assert_eq!(x.lat_p99, y.lat_p99);
        }
    }

    #[test]
    fn departed_flow_stops_completing_and_releases_capacity() {
        // Flow 0 departs at 1.5 ms; its completions must stop shortly after
        // and flow 1 keeps meeting its SLO.
        let mut spec = two_flow_spec(Mode::Arcus, 0.5, 0.5);
        spec = spec
            .with_duration(6 * MILLIS)
            .with_warmup(MILLIS / 2)
            .with_event(LifecycleEvent::Depart { flow: 0, at: 3 * MILLIS })
            .with_trace();
        let report = run(&spec);
        let last0 = report.per_flow[0]
            .trace
            .iter()
            .map(|&(at, _, _)| at)
            .max()
            .unwrap_or(0);
        assert!(
            last0 < 3 * MILLIS + MILLIS / 2,
            "flow 0 still completing at {last0} after departing at 3 ms"
        );
        assert_eq!(report.per_flow[0].departed_at, Some(3 * MILLIS));
        assert!(report.per_flow[1].departed_at.is_none());
        let a1 = report.per_flow[1].goodput.as_gbps();
        assert!((a1 - 12.0).abs() / 12.0 < 0.08, "flow1 {a1:.2} Gbps");
    }

    #[test]
    fn tenant_re_arrival_after_departure_resumes_traffic() {
        // Flow 0 runs from t = 0 (its earliest event is a Depart), leaves
        // at 3 ms, and returns at 5 ms — re-facing admission and flowing
        // again, with silence in between.
        let mut spec = two_flow_spec(Mode::Arcus, 0.5, 0.5);
        spec = spec
            .with_duration(9 * MILLIS)
            .with_warmup(MILLIS / 2)
            .with_event(LifecycleEvent::Depart { flow: 0, at: 3 * MILLIS })
            .with_event(LifecycleEvent::Arrive { flow: 0, at: 5 * MILLIS })
            .with_trace();
        let r = run(&spec);
        let f0 = &r.per_flow[0];
        assert!(!f0.rejected);
        assert_eq!(f0.arrived_at, 5 * MILLIS, "re-registration time recorded");
        assert!(f0.departed_at.is_none(), "re-arrival clears the departure");
        let gap = f0
            .trace
            .iter()
            .filter(|&&(at, _, _)| at >= 3 * MILLIS + MILLIS / 2 && at < 5 * MILLIS)
            .count();
        assert_eq!(gap, 0, "no completions while departed");
        let tail = f0.trace.iter().filter(|&&(at, _, _)| at >= 6 * MILLIS).count();
        assert!(tail > 1000, "traffic resumed after re-arrival: {tail}");
    }

    #[test]
    fn renegotiated_slo_reshapes_flow_mid_run() {
        // Flow 0 (10 G) renegotiates to 12 G halfway (12 + 12 fits under
        // the ~24.6 G budget); post-renegotiation completions must run near
        // the new target, and the report carries the new SLO.
        let mut spec = two_flow_spec(Mode::Arcus, 0.6, 0.5);
        spec = spec
            .with_duration(8 * MILLIS)
            .with_warmup(MILLIS)
            .with_event(LifecycleEvent::Renegotiate {
                flow: 0,
                at: 4 * MILLIS,
                slo: Slo::gbps(12.0),
            })
            .with_trace();
        let report = run(&spec);
        assert_eq!(report.per_flow[0].slo, Slo::gbps(12.0));
        assert_eq!(report.per_flow[0].renegotiations_rejected, 0);
        // Rate over the final 3 ms (well past the reconfig latency).
        let tail_bytes: u64 = report.per_flow[0]
            .trace
            .iter()
            .filter(|&&(at, _, _)| at >= 5 * MILLIS)
            .map(|&(_, _, b)| b)
            .sum();
        let tail_gbps = tail_bytes as f64 * 8.0 / (3 * MILLIS) as f64 * 1e3;
        assert!(
            (tail_gbps - 12.0).abs() / 12.0 < 0.1,
            "post-renegotiation rate {tail_gbps:.2} Gbps"
        );
        // Attainment judges the new contract over its own era, not the
        // mixed lifetime (which would read ≈0.9 here and look violating).
        let att = report.per_flow[0].slo_attainment().unwrap();
        assert!((att - 1.0).abs() < 0.08, "contract-era attainment {att:.3}");
    }

    #[test]
    fn over_capacity_renegotiation_is_rejected_and_old_slo_kept() {
        let mut spec = two_flow_spec(Mode::Arcus, 0.5, 0.5);
        spec = spec.with_duration(6 * MILLIS).with_event(LifecycleEvent::Renegotiate {
            flow: 0,
            at: 3 * MILLIS,
            slo: Slo::gbps(30.0), // 30 + 12 >> ~26 G capacity
        });
        let report = run(&spec);
        assert_eq!(report.per_flow[0].slo, Slo::gbps(10.0), "old SLO kept");
        assert_eq!(report.per_flow[0].renegotiations_rejected, 1);
        let a0 = report.per_flow[0].goodput.as_gbps();
        assert!((a0 - 10.0).abs() / 10.0 < 0.08, "flow0 {a0:.2} Gbps");
    }

    #[test]
    fn accel_fault_dips_attainment_then_recovers() {
        use crate::faults::{FaultKind, FaultSpec};
        let mut spec = two_flow_spec(Mode::Arcus, 0.5, 0.5);
        spec = spec.with_duration(9 * MILLIS).with_warmup(MILLIS).with_fault(
            FaultSpec::new(
                FaultKind::AccelSlowdown { unit: 0, factor: 0.35 },
                3 * MILLIS,
                6 * MILLIS,
            ),
        );
        let r = run(&spec);
        assert_eq!(r.fault_window, Some((3 * MILLIS, 6 * MILLIS)));
        for f in &r.per_flow {
            let fr = f.fault.expect("fault metrics present");
            let pre = fr.pre.attainment.unwrap();
            let during = fr.during.attainment.unwrap();
            let post = fr.post.attainment.unwrap();
            assert!(pre > 0.9, "flow {} pre {pre:.2}", f.flow);
            assert!(during < pre * 0.85, "flow {} during {during:.2} !< pre {pre:.2}", f.flow);
            assert!(post > 0.9, "flow {} post {post:.2}", f.flow);
            assert!(fr.recovery_time.is_some(), "flow {} never recovered", f.flow);
            assert!(fr.worst_era_p99() >= fr.pre.p99);
        }
    }

    /// The PR-4 fault scenario (two oversubscribed flows, mid-run engine
    /// slowdown) — the golden scenario the adaptive controller is pinned
    /// against.
    fn adaptive_fault_spec() -> ExperimentSpec {
        use crate::faults::{FaultKind, FaultSpec};
        two_flow_spec(Mode::Arcus, 0.5, 0.5)
            .with_duration(9 * MILLIS)
            .with_warmup(MILLIS)
            .with_fault(FaultSpec::new(
                FaultKind::AccelSlowdown { unit: 0, factor: 0.35 },
                3 * MILLIS,
                6 * MILLIS,
            ))
    }

    #[test]
    fn adaptive_report_identical_across_queue_disciplines() {
        // Closed-loop decisions are functions of DES-scheduled state only
        // (tick counter, status table, obs series), so the adaptive golden
        // report must stay byte-identical across queue disciplines.
        let spec = adaptive_fault_spec().with_adaptive(crate::api::AdaptiveConfig::default());
        let heap = run(&spec);
        let cal = run_with::<CalendarQueue<EngineEvent>>(&spec);
        let wheel = run_with::<HierWheel<EngineEvent>>(&spec);
        assert_eq!(heap.canonical(), cal.canonical());
        assert_eq!(heap.canonical(), wheel.canonical());
        assert_eq!(heap.events, cal.events);
        assert_eq!(heap.events, wheel.events);
        assert_eq!(heap.peak_queue_depth, cal.peak_queue_depth);
        assert_eq!(heap.peak_queue_depth, wheel.peak_queue_depth);
    }

    #[test]
    fn adaptive_beats_static_on_fault_recovery() {
        // Same fault, same offered load. During the dip the fast tier backs
        // violating flows off to their guarantees instead of boosting into
        // a degraded engine; afterwards the catch-up ramp drains the fault
        // backlog the static decay would strand at ~SLO rate. Net: the
        // worst era's p99 strictly improves and recovery is no worse.
        let spec = adaptive_fault_spec();
        let st = run(&spec);
        let ad = run(&spec.clone().with_adaptive(crate::api::AdaptiveConfig::default()));
        // Every decision rides the one ApplyDirective path, so the maximum
        // issue-to-apply lag is exactly the documented reconfig charge.
        assert_eq!(ad.directive_lag_max, spec.reconfig_latency);
        let dur = spec.duration;
        for (s, a) in st.per_flow.iter().zip(ad.per_flow.iter()) {
            let sf = s.fault.expect("static fault metrics");
            let af = a.fault.expect("adaptive fault metrics");
            assert!(
                af.worst_era_p99() <= sf.worst_era_p99(),
                "flow {}: adaptive worst-era p99 {} > static {}",
                s.flow,
                af.worst_era_p99(),
                sf.worst_era_p99()
            );
            assert!(
                af.recovery_time.unwrap_or(dur) <= sf.recovery_time.unwrap_or(dur),
                "flow {}: adaptive recovery {:?} worse than static {:?}",
                s.flow,
                af.recovery_time,
                sf.recovery_time
            );
        }
    }

    #[test]
    fn rogue_best_effort_tenant_is_clamped_by_directives() {
        use crate::faults::{FaultKind, FaultSpec};
        let line = Rate::gbps(32.0);
        let flows = vec![
            FlowSpec::new(
                0,
                0,
                Path::FunctionCall,
                TrafficPattern::fixed(4096, 0.6, line),
                Slo::gbps(18.0),
                0,
            ),
            FlowSpec::new(
                1,
                1,
                Path::FunctionCall,
                TrafficPattern::fixed(4096, 0.9, line),
                Slo::BestEffort,
                0,
            ),
        ];
        let spec = ExperimentSpec::new(Mode::Arcus, vec![AccelModel::ipsec_32g()], flows)
            .with_duration(10 * MILLIS)
            .with_warmup(2 * MILLIS)
            .with_fault(FaultSpec::new(
                FaultKind::RogueTenant { flow: 1 },
                4 * MILLIS,
                9 * MILLIS,
            ));
        let r = run(&spec);
        // The committed tenant holds its SLO across the adversary window
        // (the BE-refresh reaction clamps the rogue within a few control
        // periods), and the interface re-armed the rogue's bucket.
        let committed = r.per_flow[0].slo_attainment().unwrap();
        assert!(committed > 0.9, "committed attainment {committed:.2}");
        assert!(r.per_flow[1].reconfigs > 0, "rogue tenant never clamped");
    }

    #[test]
    fn control_outage_suppresses_fault_reaction() {
        use crate::faults::{FaultKind, FaultSpec};
        // An accelerator dip normally triggers a burst of compensation
        // reshapes. With the ticker dark across the dip (and almost to the
        // end of the run), the control plane never reacts in time.
        let mk = |outage: bool| {
            let mut spec = two_flow_spec(Mode::Arcus, 0.5, 0.5)
                .with_duration(5 * MILLIS)
                .with_warmup(MILLIS / 2)
                .with_fault(FaultSpec::new(
                    FaultKind::AccelSlowdown { unit: 0, factor: 0.4 },
                    2 * MILLIS,
                    4 * MILLIS,
                ));
            if outage {
                spec = spec.with_fault(FaultSpec::new(
                    FaultKind::ControlOutage,
                    19 * MILLIS / 10,
                    49 * MILLIS / 10,
                ));
            }
            run(&spec)
        };
        let healthy: u32 = mk(false).per_flow.iter().map(|f| f.reconfigs).sum();
        let dark: u32 = mk(true).per_flow.iter().map(|f| f.reconfigs).sum();
        assert!(
            dark < healthy,
            "outage should suppress the reaction: dark {dark} !< healthy {healthy}"
        );
    }

    #[test]
    fn admission_rejects_oversubscribed_third_flow() {
        let line = Rate::gbps(32.0);
        let mut flows: Vec<FlowSpec> = (0..3)
            .map(|i| {
                FlowSpec::new(
                    i,
                    i,
                    Path::FunctionCall,
                    TrafficPattern::fixed(1500, 0.5, line),
                    Slo::gbps(12.0),
                    0,
                )
            })
            .collect();
        flows[2].slo = Slo::gbps(15.0); // 12+12+15 > ~26G capacity at 1500B
        let spec = ExperimentSpec::new(Mode::Arcus, vec![AccelModel::ipsec_32g()], flows)
            .with_duration(5 * MILLIS);
        let report = run(&spec);
        assert!(!report.per_flow[0].rejected);
        assert!(!report.per_flow[1].rejected);
        assert!(report.per_flow[2].rejected, "third flow should be rejected");
        assert_eq!(report.per_flow[2].completed, 0);
    }
}
