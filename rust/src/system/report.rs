//! Experiment results: per-flow and per-VM measurements.

use crate::flow::Slo;
use crate::metrics::{FlowMetrics, ThroughputSampler};
use crate::obs::ObsSnapshot;
use crate::util::units::{Rate, Time, MICROS, MILLIS, SECONDS};
use crate::workload::FairnessReport;

/// One era's measured outcome for one flow (fault-injection runs split the
/// measured span into pre / during / post eras around the union fault
/// window; see [`crate::faults`]).
#[derive(Debug, Clone, Copy)]
pub struct EraReport {
    /// Payload bytes completed in this era.
    pub bytes: u64,
    /// Requests completed in this era.
    pub ops: u64,
    /// Era length (ps) the rates are measured over.
    pub span: Time,
    /// p99 latency of completions inside the era (ps; 0 when none).
    pub p99: u64,
    /// Achieved / SLO-target ratio over this era. `None` for best-effort
    /// flows or empty eras.
    pub attainment: Option<f64>,
}

impl EraReport {
    /// Build from era counters, deriving the attainment against `slo`.
    pub fn new(bytes: u64, ops: u64, span: Time, p99: u64, slo: &Slo) -> Self {
        let attainment = if span == 0 {
            None
        } else {
            match *slo {
                Slo::Throughput { target, .. } if target.0 > 0.0 => {
                    let achieved = bytes as f64 * 8.0 * SECONDS as f64 / span as f64;
                    Some(achieved / target.as_bits_per_sec())
                }
                Slo::Iops { target, .. } if target > 0.0 => {
                    let achieved = ops as f64 * SECONDS as f64 / span as f64;
                    Some(achieved / target)
                }
                Slo::Latency { max_ps, .. } if ops > 0 => {
                    Some(max_ps as f64 / p99.max(1) as f64)
                }
                _ => None,
            }
        };
        EraReport { bytes, ops, span, p99, attainment }
    }
}

/// Per-flow fault-era metrics, present only on runs with an injection plan.
#[derive(Debug, Clone, Copy)]
pub struct FaultReport {
    /// `[warmup, fault start)`.
    pub pre: EraReport,
    /// `[fault start, fault end)` — the union window over all faults.
    pub during: EraReport,
    /// `[fault end, duration)`.
    pub post: EraReport,
    /// Time from the fault window's end until the flow's windowed rate
    /// (control-period windows) first reached ≥ 95% of its SLO target.
    /// `None`: never recovered inside the run, or no rate SLO to recover
    /// to.
    pub recovery_time: Option<Time>,
}

impl FaultReport {
    /// Worst p99 across the three eras (the "worst-era p99" headline).
    pub fn worst_era_p99(&self) -> u64 {
        self.pre.p99.max(self.during.p99).max(self.post.p99)
    }
}

/// One flow's measured outcome.
#[derive(Debug)]
pub struct FlowReport {
    pub flow: usize,
    pub vm: usize,
    pub slo: Slo,
    /// Rejected by admission control (never ran).
    pub rejected: bool,
    pub completed: u64,
    pub dropped: u64,
    pub bytes: u64,
    /// Goodput over the measured window (post-warmup).
    pub goodput: Rate,
    pub iops: f64,
    /// Latency percentiles in ps.
    pub lat_p50: u64,
    pub lat_p95: u64,
    pub lat_p99: u64,
    pub lat_p999: u64,
    pub lat_mean: f64,
    /// Windowed throughput sampling (Fig 6's CDF, Table 3's deviations).
    pub sampler: ThroughputSampler,
    /// Reconfigurations the control plane applied to this flow.
    pub reconfigs: u32,
    /// Virtual time the flow arrived (0 unless a lifecycle schedule
    /// delayed it).
    pub arrived_at: Time,
    /// Virtual time the flow departed, if it deregistered mid-run.
    pub departed_at: Option<Time>,
    /// SLO renegotiations rejected by capacity planning.
    pub renegotiations_rejected: u32,
    /// Goodput measured over the *current SLO contract's* era only — set
    /// after an accepted mid-run renegotiation so attainment judges the
    /// new target against traffic shaped under it, not the mixed lifetime.
    pub contract_goodput: Option<Rate>,
    /// IOPS over the current contract's era (see `contract_goodput`).
    pub contract_iops: Option<f64>,
    /// Fault-era metrics (pre / during / post attainment, worst-era p99,
    /// recovery time) — `Some` only on runs with an injection plan.
    pub fault: Option<FaultReport>,
    /// Optional completion trace: (completion time, latency, bytes), for
    /// time-series plots (Fig 9).
    pub trace: Vec<(Time, Time, u64)>,
}

impl FlowReport {
    #[allow(clippy::too_many_arguments)]
    pub fn from_metrics(
        flow: usize,
        vm: usize,
        slo: Slo,
        rejected: bool,
        m: &FlowMetrics,
        sampler: ThroughputSampler,
        reconfigs: u32,
        trace: Vec<(Time, Time, u64)>,
    ) -> Self {
        FlowReport {
            flow,
            vm,
            slo,
            rejected,
            completed: m.completed,
            dropped: m.dropped,
            bytes: m.bytes,
            goodput: m.goodput(),
            iops: m.ops_per_sec(),
            lat_p50: m.latency.percentile(50.0),
            lat_p95: m.latency.percentile(95.0),
            lat_p99: m.latency.percentile(99.0),
            lat_p999: m.latency.percentile(99.9),
            lat_mean: m.latency.mean(),
            sampler,
            reconfigs,
            arrived_at: 0,
            departed_at: None,
            renegotiations_rejected: 0,
            contract_goodput: None,
            contract_iops: None,
            fault: None,
            trace,
        }
    }

    /// Achieved / SLO-target ratio (1.0 = exactly the SLO). For flows that
    /// renegotiated mid-run, the achieved rate is measured over the current
    /// contract's era only.
    pub fn slo_attainment(&self) -> Option<f64> {
        match self.slo {
            Slo::Throughput { target, .. } => {
                Some(self.contract_goodput.unwrap_or(self.goodput).0 / target.0)
            }
            Slo::Iops { target, .. } => {
                Some(self.contract_iops.unwrap_or(self.iops) / target)
            }
            Slo::Latency { max_ps, .. } => {
                // Attainment >= 1 means meeting: invert so that 1.0 = at bound.
                Some(max_ps as f64 / self.lat_p99.max(1) as f64)
            }
            Slo::BestEffort => None,
        }
    }
}

/// One host's share of a fleet run, rolled up for the merged report.
///
/// Deterministic fields only (no wall clock): the rollup rows are printed
/// into [`SystemReport::canonical`], so they participate in the
/// byte-identity gates exactly like per-flow lines.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HostRollup {
    /// Host index within the fleet (`vm % hosts` partitioning).
    pub host: usize,
    /// Flows homed on this host.
    pub flows: usize,
    /// Events the host's own event core executed.
    pub events: u64,
    /// Peak pending events on the host's queue.
    pub peak_queue_depth: usize,
    /// NIC RX drops across the host's ports.
    pub nic_rx_dropped: u64,
    /// Worst in-host apply lag (issue → apply) for this host.
    pub directive_lag_max: Time,
    /// Worst publish → first-successful-delivery staleness for batches
    /// addressed to this host.
    pub directive_staleness_max: Time,
    /// Digest of the host's own observability snapshot (pre-merge).
    pub series_digest: u64,
}

/// A full experiment's outcome.
#[derive(Debug)]
pub struct SystemReport {
    pub mode: &'static str,
    pub per_flow: Vec<FlowReport>,
    /// Virtual duration measured (post-warmup).
    pub measured_span: Time,
    /// PCIe wire utilization per direction over the whole run.
    pub pcie_up_util: f64,
    pub pcie_down_util: f64,
    /// Per-accelerator busy fraction.
    pub accel_util: Vec<f64>,
    /// NIC RX drops across ports.
    pub nic_rx_dropped: u64,
    /// Union fault window `[start, end)` when the run injected faults —
    /// the era boundary every `FlowReport::fault` is measured against.
    pub fault_window: Option<(Time, Time)>,
    /// DES events executed (perf accounting).
    pub events: u64,
    /// High-water mark of the pending-event set (perf accounting).
    pub peak_queue_depth: usize,
    /// Event-queue discipline the run used ("binary_heap" / "calendar").
    pub queue: &'static str,
    /// Wall-clock seconds the simulation took (perf accounting).
    pub wall_secs: f64,
    /// Worst directive-propagation lag observed: the maximum `apply time −
    /// issued_at` over every directive the control plane emitted (ps).
    /// Under the single reconfiguration-latency rule this equals
    /// `reconfig_latency` whenever any directive was applied (0 when none
    /// were), so a divergent value flags a second, unaccounted apply path.
    pub directive_lag_max: Time,
    /// Worst config staleness seen by the fleet distribution tier: time
    /// from a directive batch's publication to its first *successful*
    /// delivery (propagation delay + any drop-window re-send rounds).
    /// Always 0 for single-world runs, where directives apply in-process
    /// and only `directive_lag_max` accrues.
    pub directive_staleness_max: Time,
    /// Per-host rollups for fleet runs (empty for single-world runs, which
    /// keeps their canonical reports byte-identical to the pre-fleet form).
    pub host_rollups: Vec<HostRollup>,
    /// FNV-1a digest over the observability plane's snapshot (every series
    /// sample + rollup histogram bucket). Part of the canonical report, so
    /// the determinism suite asserts the whole in-run metrics surface is
    /// byte-identical across event-queue disciplines.
    pub series_digest: u64,
    /// End-of-run snapshot of the in-run observability plane (tick-indexed
    /// series + tenant/engine histogram rollups). Not serialized per-value
    /// into `canonical()` — the digest stands in for it.
    pub obs: ObsSnapshot,
    /// Per-user fairness summary (Jain's index, worst-user p99) — `Some`
    /// only on population-workload runs, which keeps legacy canonical
    /// reports byte-identical to the pre-population form.
    pub fairness: Option<FairnessReport>,
}

impl SystemReport {
    /// Aggregate goodput of all flows of one VM.
    pub fn vm_goodput(&self, vm: usize) -> Rate {
        Rate(self
            .per_flow
            .iter()
            .filter(|f| f.vm == vm)
            .map(|f| f.goodput.0)
            .sum())
    }

    /// Aggregate goodput across all flows.
    pub fn total_goodput(&self) -> Rate {
        Rate(self.per_flow.iter().map(|f| f.goodput.0).sum())
    }

    /// Aggregate IOPS of all flows of one VM.
    pub fn vm_iops(&self, vm: usize) -> f64 {
        self.per_flow
            .iter()
            .filter(|f| f.vm == vm)
            .map(|f| f.iops)
            .sum()
    }

    /// Events per wall-second (simulator performance).
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.events as f64 / self.wall_secs
        }
    }

    /// Canonical deterministic serialization: every virtual-time outcome of
    /// the run, *excluding* wall-clock measurements and the queue label.
    /// Two runs of the same spec — on either event-queue discipline — must
    /// produce byte-identical canonical strings; the golden determinism
    /// test (`rust/tests/determinism.rs`) asserts exactly that.
    pub fn canonical(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "mode={} span={} events={} peak_queue={} pcie_up={:?} pcie_down={:?} \
             accel_util={:?} nic_rx_dropped={} fault_window={:?} directive_lag_max={} \
             directive_staleness_max={} series_digest={:016x}\n",
            self.mode,
            self.measured_span,
            self.events,
            self.peak_queue_depth,
            self.pcie_up_util,
            self.pcie_down_util,
            self.accel_util,
            self.nic_rx_dropped,
            self.fault_window,
            self.directive_lag_max,
            self.directive_staleness_max,
            self.series_digest,
        ));
        // Population runs add one fairness line; legacy runs add nothing.
        if let Some(fr) = &self.fairness {
            out.push_str(&format!("fairness={fr:?}\n"));
        }
        // Fleet runs add one line per host; single-world runs add nothing.
        for h in &self.host_rollups {
            out.push_str(&format!("{h:?}\n"));
        }
        for f in &self.per_flow {
            // Debug formatting of f64 is shortest-roundtrip: byte-stable
            // for identical values, and any numeric divergence shows up.
            out.push_str(&format!("{f:?}\n"));
        }
        out
    }

    /// Render the per-flow fault-era table (`arcus simulate --faults` /
    /// `arcus chaos`). Empty string when the run injected no faults.
    pub fn render_fault_eras(&self) -> String {
        let Some((fs, fe)) = self.fault_window else {
            return String::new();
        };
        let mut out = String::new();
        out.push_str(&format!(
            "fault window [{:.3}, {:.3}) ms — per-era SLO attainment:\n",
            fs as f64 / MILLIS as f64,
            fe as f64 / MILLIS as f64
        ));
        out.push_str("flow  att.pre  att.fault  att.post  worst-p99(us)  recovery(us)\n");
        let dash = || "-".to_string();
        for f in &self.per_flow {
            let Some(fr) = &f.fault else { continue };
            let att = |a: Option<f64>| a.map(|x| format!("{x:.3}")).unwrap_or_else(dash);
            out.push_str(&format!(
                "{:>4} {:>8} {:>10} {:>9} {:>14.2} {:>13}\n",
                f.flow,
                att(fr.pre.attainment),
                att(fr.during.attainment),
                att(fr.post.attainment),
                fr.worst_era_p99() as f64 / MICROS as f64,
                fr.recovery_time
                    .map(|t| format!("{:.1}", t as f64 / MICROS as f64))
                    .unwrap_or_else(dash),
            ));
        }
        out
    }

    /// Pretty-print a compact per-flow table (used by the CLI).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "mode={} span={:.3}ms events={} ({:.2}M ev/s)\n",
            self.mode,
            self.measured_span as f64 / 1e9,
            self.events,
            self.events_per_sec() / 1e6
        ));
        if let Some(fr) = &self.fairness {
            out.push_str(&format!(
                "population: {} users ({} active) jain={:.4} worst-user-p99={:.0}us\n",
                fr.users,
                fr.active_users,
                fr.jain_ppm as f64 / 1e6,
                fr.worst_user_p99_ps as f64 / MICROS as f64
            ));
        }
        out.push_str(
            "flow vm   goodput      iops        p50        p99      p99.9  drops  cv%\n",
        );
        for f in &self.per_flow {
            out.push_str(&format!(
                "{:>4} {:>2} {:>10} {:>9.0} {:>9.2}us {:>9.2}us {:>9.2}us {:>6} {:>5.2}\n",
                f.flow,
                f.vm,
                f.goodput.to_string(),
                f.iops,
                f.lat_p50 as f64 / MICROS as f64,
                f.lat_p99 as f64 / MICROS as f64,
                f.lat_p999 as f64 / MICROS as f64,
                f.dropped,
                f.sampler.cv() * 100.0
            ));
        }
        let _ = SECONDS; // keep the import referenced
        out
    }
}
