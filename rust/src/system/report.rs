//! Experiment results: per-flow and per-VM measurements.

use crate::flow::Slo;
use crate::metrics::{FlowMetrics, ThroughputSampler};
use crate::util::units::{Rate, Time, MICROS, SECONDS};

/// One flow's measured outcome.
#[derive(Debug)]
pub struct FlowReport {
    pub flow: usize,
    pub vm: usize,
    pub slo: Slo,
    /// Rejected by admission control (never ran).
    pub rejected: bool,
    pub completed: u64,
    pub dropped: u64,
    pub bytes: u64,
    /// Goodput over the measured window (post-warmup).
    pub goodput: Rate,
    pub iops: f64,
    /// Latency percentiles in ps.
    pub lat_p50: u64,
    pub lat_p95: u64,
    pub lat_p99: u64,
    pub lat_p999: u64,
    pub lat_mean: f64,
    /// Windowed throughput sampling (Fig 6's CDF, Table 3's deviations).
    pub sampler: ThroughputSampler,
    /// Reconfigurations the control plane applied to this flow.
    pub reconfigs: u32,
    /// Virtual time the flow arrived (0 unless a lifecycle schedule
    /// delayed it).
    pub arrived_at: Time,
    /// Virtual time the flow departed, if it deregistered mid-run.
    pub departed_at: Option<Time>,
    /// SLO renegotiations rejected by capacity planning.
    pub renegotiations_rejected: u32,
    /// Goodput measured over the *current SLO contract's* era only — set
    /// after an accepted mid-run renegotiation so attainment judges the
    /// new target against traffic shaped under it, not the mixed lifetime.
    pub contract_goodput: Option<Rate>,
    /// IOPS over the current contract's era (see `contract_goodput`).
    pub contract_iops: Option<f64>,
    /// Optional completion trace: (completion time, latency, bytes), for
    /// time-series plots (Fig 9).
    pub trace: Vec<(Time, Time, u64)>,
}

impl FlowReport {
    #[allow(clippy::too_many_arguments)]
    pub fn from_metrics(
        flow: usize,
        vm: usize,
        slo: Slo,
        rejected: bool,
        m: &FlowMetrics,
        sampler: ThroughputSampler,
        reconfigs: u32,
        trace: Vec<(Time, Time, u64)>,
    ) -> Self {
        FlowReport {
            flow,
            vm,
            slo,
            rejected,
            completed: m.completed,
            dropped: m.dropped,
            bytes: m.bytes,
            goodput: m.goodput(),
            iops: m.ops_per_sec(),
            lat_p50: m.latency.percentile(50.0),
            lat_p95: m.latency.percentile(95.0),
            lat_p99: m.latency.percentile(99.0),
            lat_p999: m.latency.percentile(99.9),
            lat_mean: m.latency.mean(),
            sampler,
            reconfigs,
            arrived_at: 0,
            departed_at: None,
            renegotiations_rejected: 0,
            contract_goodput: None,
            contract_iops: None,
            trace,
        }
    }

    /// Achieved / SLO-target ratio (1.0 = exactly the SLO). For flows that
    /// renegotiated mid-run, the achieved rate is measured over the current
    /// contract's era only.
    pub fn slo_attainment(&self) -> Option<f64> {
        match self.slo {
            Slo::Throughput { target, .. } => {
                Some(self.contract_goodput.unwrap_or(self.goodput).0 / target.0)
            }
            Slo::Iops { target, .. } => {
                Some(self.contract_iops.unwrap_or(self.iops) / target)
            }
            Slo::Latency { max_ps, .. } => {
                // Attainment >= 1 means meeting: invert so that 1.0 = at bound.
                Some(max_ps as f64 / self.lat_p99.max(1) as f64)
            }
            Slo::BestEffort => None,
        }
    }
}

/// A full experiment's outcome.
#[derive(Debug)]
pub struct SystemReport {
    pub mode: &'static str,
    pub per_flow: Vec<FlowReport>,
    /// Virtual duration measured (post-warmup).
    pub measured_span: Time,
    /// PCIe wire utilization per direction over the whole run.
    pub pcie_up_util: f64,
    pub pcie_down_util: f64,
    /// Per-accelerator busy fraction.
    pub accel_util: Vec<f64>,
    /// NIC RX drops across ports.
    pub nic_rx_dropped: u64,
    /// DES events executed (perf accounting).
    pub events: u64,
    /// High-water mark of the pending-event set (perf accounting).
    pub peak_queue_depth: usize,
    /// Event-queue discipline the run used ("binary_heap" / "calendar").
    pub queue: &'static str,
    /// Wall-clock seconds the simulation took (perf accounting).
    pub wall_secs: f64,
}

impl SystemReport {
    /// Aggregate goodput of all flows of one VM.
    pub fn vm_goodput(&self, vm: usize) -> Rate {
        Rate(self
            .per_flow
            .iter()
            .filter(|f| f.vm == vm)
            .map(|f| f.goodput.0)
            .sum())
    }

    /// Aggregate goodput across all flows.
    pub fn total_goodput(&self) -> Rate {
        Rate(self.per_flow.iter().map(|f| f.goodput.0).sum())
    }

    /// Aggregate IOPS of all flows of one VM.
    pub fn vm_iops(&self, vm: usize) -> f64 {
        self.per_flow
            .iter()
            .filter(|f| f.vm == vm)
            .map(|f| f.iops)
            .sum()
    }

    /// Events per wall-second (simulator performance).
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.events as f64 / self.wall_secs
        }
    }

    /// Canonical deterministic serialization: every virtual-time outcome of
    /// the run, *excluding* wall-clock measurements and the queue label.
    /// Two runs of the same spec — on either event-queue discipline — must
    /// produce byte-identical canonical strings; the golden determinism
    /// test (`rust/tests/determinism.rs`) asserts exactly that.
    pub fn canonical(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "mode={} span={} events={} peak_queue={} pcie_up={:?} pcie_down={:?} \
             accel_util={:?} nic_rx_dropped={}\n",
            self.mode,
            self.measured_span,
            self.events,
            self.peak_queue_depth,
            self.pcie_up_util,
            self.pcie_down_util,
            self.accel_util,
            self.nic_rx_dropped,
        ));
        for f in &self.per_flow {
            // Debug formatting of f64 is shortest-roundtrip: byte-stable
            // for identical values, and any numeric divergence shows up.
            out.push_str(&format!("{f:?}\n"));
        }
        out
    }

    /// Pretty-print a compact per-flow table (used by the CLI).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "mode={} span={:.3}ms events={} ({:.2}M ev/s)\n",
            self.mode,
            self.measured_span as f64 / 1e9,
            self.events,
            self.events_per_sec() / 1e6
        ));
        out.push_str(
            "flow vm   goodput      iops        p50        p99      p99.9  drops  cv%\n",
        );
        for f in &self.per_flow {
            out.push_str(&format!(
                "{:>4} {:>2} {:>10} {:>9.0} {:>9.2}us {:>9.2}us {:>9.2}us {:>6} {:>5.2}\n",
                f.flow,
                f.vm,
                f.goodput.to_string(),
                f.iops,
                f.lat_p50 as f64 / MICROS as f64,
                f.lat_p99 as f64 / MICROS as f64,
                f.lat_p999 as f64 / MICROS as f64,
                f.dropped,
                f.sampler.cv() * 100.0
            ));
        }
        let _ = SECONDS; // keep the import referenced
        out
    }
}
