//! # Arcus — SLO Management for Accelerators in the Cloud with Traffic Shaping
//!
//! A full reproduction of the Arcus system (Zhao et al., 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the Arcus coordinator: per-flow accelerator traffic
//!   shaping (hardware-modeled token buckets, §4.2) composed into the
//!   hierarchical per-tenant / per-engine shaper tree
//!   ([`shaping::ShaperTree`]) that keeps shaping enforceable at 10k-flow
//!   scale (§5), an SLO-aware control plane behind a first-class
//!   flow-lifecycle API ([`api::ControlPlane`]: registration/admission, SLO
//!   renegotiation, departure, periodic re-planning — profiling, capacity
//!   planning, online re-shaping; §4.3's Algorithm 1), a cycle-granular
//!   host–FPGA simulator substrate ([`sim`]: typed zero-allocation DES core;
//!   PCIe, DMA, accelerators, NVMe storage, NICs), all §5.1 baselines, a
//!   fault/adversary injection subsystem ([`faults`]), a streaming
//!   observability plane ([`obs`]: tick-indexed series, mergeable
//!   histograms, Prometheus export, `arcus top`), a parallel
//!   scenario-sweep engine ([`sweep`]) that expands experiment templates
//!   over traffic/tenant/mode/churn/fault/scale/hosts axes, a multi-host
//!   fleet tier ([`fleet`]) that shards the world into per-host engines
//!   coordinated by versioned, ACKed, delta-only directive distribution
//!   ([`api::distribution`], xDS-style), and a wall-clock
//!   serving runtime that executes AOT-compiled accelerator kernels via
//!   PJRT.
//! - **L2 (python/compile/model.py)** — batched accelerator datapaths in JAX,
//!   lowered once to HLO text artifacts.
//! - **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot-spots (stream cipher, tree hash, checksum), verified against
//!   pure-jnp oracles.
//!
//! Python never runs on the request path: `make artifacts` compiles the
//! kernels ahead of time, and the Rust binary loads `artifacts/*.hlo.txt`
//! through the PJRT CPU client.
//!
//! See `README.md` for the quickstart, `DESIGN.md` for the substitution
//! table (the paper's FPGA/PCIe/SSD testbed → this simulator) and the
//! per-experiment index, and `EXPERIMENTS.md` for paper-vs-measured
//! results.

pub mod accel;
// The public shaping/control API carries a scoped `missing_docs` gate:
// every public item in `api` and `shaping` must be documented (enforced
// by CI's `cargo doc` job with `RUSTDOCFLAGS="-D warnings"`).
#[warn(missing_docs)]
pub mod api;
pub mod apps;
pub mod config;
pub mod coordinator;
pub mod dma;
pub mod faults;
#[warn(missing_docs)]
pub mod fleet;
pub mod flow;
pub mod metrics;
pub mod nic;
#[warn(missing_docs)]
pub mod obs;
pub mod pcie;
pub mod perf;
pub mod runtime;
pub mod server;
#[warn(missing_docs)]
pub mod shaping;
pub mod storage;
pub mod sim;
pub mod sweep;
pub mod system;
pub mod testkit;
pub mod util;
pub mod workload;

pub use util::units::{Rate, Time};
