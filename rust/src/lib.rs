//! # Arcus — SLO Management for Accelerators in the Cloud with Traffic Shaping
//!
//! A full reproduction of the Arcus system (Zhao et al., 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the Arcus coordinator: per-flow accelerator traffic
//!   shaping (hardware-modeled token buckets), an SLO-aware control plane
//!   behind a first-class flow-lifecycle API ([`api::ControlPlane`]:
//!   registration/admission, SLO renegotiation, departure, periodic
//!   re-planning — profiling, capacity planning, online re-shaping), a
//!   cycle-granular host–FPGA simulator substrate (PCIe, DMA, accelerators,
//!   NVMe storage, NICs), all paper baselines, a parallel scenario-sweep
//!   engine ([`sweep`]) that expands experiment templates over traffic/
//!   tenant/mode axes, and a wall-clock serving runtime that executes
//!   AOT-compiled accelerator kernels via PJRT.
//! - **L2 (python/compile/model.py)** — batched accelerator datapaths in JAX,
//!   lowered once to HLO text artifacts.
//! - **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot-spots (stream cipher, tree hash, checksum), verified against
//!   pure-jnp oracles.
//!
//! Python never runs on the request path: `make artifacts` compiles the
//! kernels ahead of time, and the Rust binary loads `artifacts/*.hlo.txt`
//! through the PJRT CPU client.
//!
//! See `DESIGN.md` for the substitution table (the paper's FPGA/PCIe/SSD
//! testbed → this simulator) and the per-experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod accel;
pub mod api;
pub mod apps;
pub mod config;
pub mod coordinator;
pub mod dma;
pub mod faults;
pub mod flow;
pub mod metrics;
pub mod nic;
pub mod pcie;
pub mod perf;
pub mod runtime;
pub mod server;
pub mod shaping;
pub mod storage;
pub mod sim;
pub mod sweep;
pub mod system;
pub mod testkit;
pub mod util;
pub mod workload;

pub use util::units::{Rate, Time};
