//! Parallel sweep execution.
//!
//! Scenarios are embarrassingly parallel — each simulation is
//! single-threaded and deterministic given its spec (the per-scenario seed
//! is baked in at expansion time) — so the runner fans a work queue out
//! over `std::thread` workers and reassembles results in expansion order.
//! Parallelism therefore never changes any report: the only nondeterministic
//! field a simulation produces is its wall-clock accounting, which the
//! aggregation layer deliberately ignores.

use std::sync::Mutex;

use crate::system::{self, ExperimentSpec, SystemReport};

use super::grid::{Scenario, ScenarioKey, SweepGrid};

/// Run independent jobs across `threads` workers; results in input order.
///
/// The generic work-queue primitive under [`SweepRunner`], also used
/// directly by bench scaffolding for non-scenario jobs (e.g. the shaper
/// ablation's per-mechanism measurements).
pub fn run_parallel<T, F>(jobs: Vec<F>, threads: usize) -> Vec<T>
where
    F: FnOnce() -> T + Send,
    T: Send,
{
    let threads = threads.max(1).min(jobs.len().max(1));
    let queue: Mutex<Vec<(usize, F)>> = Mutex::new(jobs.into_iter().enumerate().collect());
    let results: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::new());
    // First panic payload from any worker. Jobs run under `catch_unwind` so
    // a panicking scenario can never poison `queue`/`results` — without
    // this, one bad job made every *other* worker die unwrapping a
    // `PoisonError` and the caller saw a scope panic with no trace of the
    // original message.
    let first_panic: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                if first_panic.lock().unwrap().is_some() {
                    return; // a sibling already failed; stop picking up work
                }
                let job = queue.lock().unwrap().pop();
                match job {
                    Some((index, f)) => {
                        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
                            Ok(r) => results.lock().unwrap().push((index, r)),
                            Err(payload) => {
                                let mut slot = first_panic.lock().unwrap();
                                if slot.is_none() {
                                    *slot = Some(payload);
                                }
                                return;
                            }
                        }
                    }
                    None => return,
                }
            });
        }
    });
    if let Some(payload) = first_panic.into_inner().unwrap() {
        std::panic::resume_unwind(payload);
    }
    let mut out = results.into_inner().unwrap();
    out.sort_by_key(|&(index, _)| index);
    out.into_iter().map(|(_, r)| r).collect()
}

/// Default worker count: one per available core.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// One executed scenario: its coordinates plus the simulation report.
#[derive(Debug)]
pub struct ScenarioOutcome {
    /// Position in grid expansion order.
    pub index: usize,
    pub key: ScenarioKey,
    pub report: SystemReport,
}

/// Executes grids (or pre-expanded scenario lists) across worker threads.
#[derive(Debug, Clone, Copy)]
pub struct SweepRunner {
    threads: usize,
}

impl Default for SweepRunner {
    fn default() -> Self {
        Self::new()
    }
}

impl SweepRunner {
    pub fn new() -> Self {
        SweepRunner { threads: default_threads() }
    }

    pub fn with_threads(threads: usize) -> Self {
        SweepRunner { threads: threads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Expand and execute a grid; outcomes in expansion order.
    ///
    /// Panics (with the validation message) on a degenerate grid — e.g.
    /// warmup ≥ duration, which would otherwise surface as a bare
    /// arithmetic panic deep inside a worker thread. Call
    /// [`SweepGrid::validate`] first to handle the error gracefully.
    pub fn run(&self, grid: &SweepGrid) -> Vec<ScenarioOutcome> {
        if let Err(e) = grid.validate() {
            panic!("invalid sweep grid: {e}");
        }
        self.run_scenarios(grid.expand())
    }

    /// Execute pre-expanded scenarios; outcomes in input order.
    ///
    /// Single-host cells run the plain [`system::run`] path (byte-identical
    /// to pre-fleet sweeps); cells with `hosts > 1` run under
    /// [`crate::fleet::run`] with the default distribution config. Fleet
    /// cells pin their host threading to 1 so the sweep's own worker pool
    /// stays the only source of parallelism (no nested oversubscription);
    /// the fleet core is byte-identical at any thread count anyway.
    pub fn run_scenarios(&self, scenarios: Vec<Scenario>) -> Vec<ScenarioOutcome> {
        let jobs: Vec<_> = scenarios
            .into_iter()
            .map(|sc| {
                move || {
                    let report = if sc.key.hosts > 1 {
                        crate::fleet::run(
                            &sc.spec,
                            &crate::fleet::FleetConfig {
                                hosts: sc.key.hosts,
                                threads: 1,
                                ..Default::default()
                            },
                        )
                    } else {
                        system::run(&sc.spec)
                    };
                    ScenarioOutcome { index: sc.index, report, key: sc.key }
                }
            })
            .collect();
        run_parallel(jobs, self.threads)
    }
}

/// Convenience for bench scaffolding: run raw specs in parallel, reports
/// in input order.
pub fn run_specs(specs: Vec<ExperimentSpec>) -> Vec<SystemReport> {
    let jobs: Vec<_> = specs
        .into_iter()
        .map(|spec| move || system::run(&spec))
        .collect();
    run_parallel(jobs, default_threads())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_parallel_preserves_order() {
        let jobs: Vec<_> = (0..64u64)
            .map(|i| {
                move || {
                    // Uneven work so completion order scrambles.
                    let mut x = i;
                    for _ in 0..(i % 7) * 1000 {
                        x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                    }
                    std::hint::black_box(x);
                    i
                }
            })
            .collect();
        let out = run_parallel(jobs, 8);
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn run_parallel_handles_empty_and_single() {
        let empty: Vec<fn() -> u32> = Vec::new();
        assert!(run_parallel(empty, 4).is_empty());
        assert_eq!(run_parallel(vec![|| 7u32], 4), vec![7]);
    }

    #[test]
    fn run_parallel_propagates_original_panic_payload() {
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send>> = (0..16u32)
            .map(|i| {
                Box::new(move || {
                    if i == 5 {
                        panic!("scenario 5 exploded");
                    }
                    i
                }) as Box<dyn FnOnce() -> u32 + Send>
            })
            .collect();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_parallel(jobs, 4);
        }))
        .expect_err("a panicking job must fail the whole run");
        // The caller must see the job's own payload, not a PoisonError
        // unwrap or an anonymous scope panic.
        let msg = err
            .downcast_ref::<&str>()
            .copied()
            .map(str::to_owned)
            .or_else(|| err.downcast_ref::<String>().cloned())
            .expect("payload should be the original panic message");
        assert!(msg.contains("scenario 5 exploded"), "got: {msg}");
    }

    #[test]
    fn more_threads_than_jobs_is_fine() {
        let jobs: Vec<_> = (0..3u32).map(|i| move || i * 2).collect();
        assert_eq!(run_parallel(jobs, 64), vec![0, 2, 4]);
    }
}
