//! Scenario grid: one experiment template expanded over evaluation axes.
//!
//! Arcus's claim is that SLO attainment holds across *diverse, mixed,
//! hard-to-predict* traffic mixtures (§3). A [`SweepGrid`] makes that
//! diversity first-class: it holds one [`GridBase`] template plus a value
//! list per axis — tenant count, management [`Mode`], burstiness,
//! message-size mix, SLO tightness, accelerator model, and seed — and
//! [`SweepGrid::expand`] takes the full cartesian product into a
//! deterministic list of [`Scenario`]s (one [`crate::system::ExperimentSpec`]
//! each). Benches, tests, and the `arcus sweep` subcommand all build their
//! experiments from this one vocabulary, so a "scenario" means the same
//! thing everywhere.
//!
//! Determinism contract: expansion order is the nested-loop order of the
//! axis declarations (mode outermost, seed innermost), and scenario labels
//! AND simulator seeds are pure functions of the axis coordinates (the
//! seed hashes `(grid seed, label)` through FNV-1a + SplitMix64) — two
//! expansions of equal grids are identical element-wise, and the same
//! cell keeps its seed when other axes grow.

use crate::accel::AccelModel;
use crate::flow::pattern::{Burstiness, SizeDist};
use crate::flow::{FlowSpec, Path, Slo};
use crate::flow::TrafficPattern;
use crate::system::{ExperimentSpec, Mode};
use crate::util::rng::splitmix64;
use crate::util::units::{Rate, Time, MILLIS};

/// Named message-size mixtures (Table 1's size axis) — the shared
/// vocabulary for benches, tests, and the `sweep` subcommand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SizeMix {
    /// 64 B RPCs — the mixture that craters fixed-function engines.
    Tiny,
    /// 256 B small messages.
    Small,
    /// MTU-sized (1500 B) — the paper's reference point.
    Mtu,
    /// 4 KB blocks (storage/KV payloads).
    Bulk,
    /// Equal-probability choice over 64/256/1500/4096.
    Mixed,
    /// 90% 64 B RPCs + 10% 4 KB bulk (tiny-RPC + bulk tenants).
    Bimodal,
}

impl SizeMix {
    pub const ALL: [SizeMix; 6] = [
        SizeMix::Tiny,
        SizeMix::Small,
        SizeMix::Mtu,
        SizeMix::Bulk,
        SizeMix::Mixed,
        SizeMix::Bimodal,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SizeMix::Tiny => "tiny",
            SizeMix::Small => "small",
            SizeMix::Mtu => "mtu",
            SizeMix::Bulk => "bulk",
            SizeMix::Mixed => "mixed",
            SizeMix::Bimodal => "bimodal",
        }
    }

    pub fn by_name(s: &str) -> Option<SizeMix> {
        Self::ALL.iter().copied().find(|m| m.name() == s)
    }

    pub fn dist(self) -> SizeDist {
        match self {
            SizeMix::Tiny => SizeDist::Fixed(64),
            SizeMix::Small => SizeDist::Fixed(256),
            SizeMix::Mtu => SizeDist::Fixed(1500),
            SizeMix::Bulk => SizeDist::Fixed(4096),
            SizeMix::Mixed => SizeDist::Choice(vec![64, 256, 1500, 4096]),
            SizeMix::Bimodal => SizeDist::Bimodal { a: 64, b: 4096, p_a: 0.9 },
        }
    }

    /// Mean message size (profiling context / SLO sizing).
    pub fn mean_bytes(self) -> u64 {
        self.dist().mean().round().max(1.0) as u64
    }
}

/// Human label for a burstiness axis value.
pub fn burst_name(b: Burstiness) -> String {
    match b {
        Burstiness::Paced => "paced".to_string(),
        Burstiness::Poisson => "poisson".to_string(),
        Burstiness::OnOff { burst_len } => format!("onoff{burst_len}"),
    }
}

/// Template parameters shared by every scenario in a grid.
#[derive(Debug, Clone)]
pub struct GridBase {
    /// Virtual measured duration per scenario.
    pub duration: Time,
    /// Virtual warmup discarded from metrics.
    pub warmup: Time,
    /// Reference line rate the load fraction is relative to.
    pub line_rate: Rate,
    /// Aggregate offered load across all tenants, as a fraction of
    /// `line_rate` (each tenant offers `load / tenants`).
    pub load: f64,
    /// Invocation path every flow uses.
    pub path: Path,
    /// Base seed every scenario seed is derived from.
    pub seed: u64,
}

impl Default for GridBase {
    fn default() -> Self {
        GridBase {
            duration: 4 * MILLIS,
            warmup: MILLIS,
            line_rate: Rate::gbps(32.0),
            load: 0.9,
            path: Path::FunctionCall,
            seed: 1,
        }
    }
}

/// The grid: a template plus one value list per axis.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    pub base: GridBase,
    pub modes: Vec<Mode>,
    pub tenants: Vec<usize>,
    pub mixes: Vec<SizeMix>,
    pub bursts: Vec<Burstiness>,
    /// SLO tightness: the fraction of the accelerator's effective capacity
    /// (at the mix's mean message size) committed across all tenants.
    /// 1.0 commits the whole engine; >1.0 is deliberately inadmissible.
    pub tightness: Vec<f64>,
    pub accels: Vec<AccelModel>,
    /// Seed axis: replications of every cell with decorrelated randomness.
    pub seeds: Vec<u64>,
}

impl SweepGrid {
    /// A grid with empty axes; fill every axis before expanding.
    pub fn new(base: GridBase) -> Self {
        SweepGrid {
            base,
            modes: Vec::new(),
            tenants: Vec::new(),
            mixes: Vec::new(),
            bursts: Vec::new(),
            tightness: Vec::new(),
            accels: Vec::new(),
            seeds: Vec::new(),
        }
    }

    pub fn modes(mut self, v: Vec<Mode>) -> Self {
        self.modes = v;
        self
    }
    pub fn tenants(mut self, v: Vec<usize>) -> Self {
        self.tenants = v;
        self
    }
    pub fn mixes(mut self, v: Vec<SizeMix>) -> Self {
        self.mixes = v;
        self
    }
    pub fn bursts(mut self, v: Vec<Burstiness>) -> Self {
        self.bursts = v;
        self
    }
    pub fn tightness(mut self, v: Vec<f64>) -> Self {
        self.tightness = v;
        self
    }
    pub fn accels(mut self, v: Vec<AccelModel>) -> Self {
        self.accels = v;
        self
    }
    pub fn seeds(mut self, v: Vec<u64>) -> Self {
        self.seeds = v;
        self
    }

    /// Number of scenarios the grid expands to: the product of axis
    /// lengths (zero if any axis is empty).
    pub fn cardinality(&self) -> usize {
        self.modes.len()
            * self.tenants.len()
            * self.mixes.len()
            * self.bursts.len()
            * self.tightness.len()
            * self.accels.len()
            * self.seeds.len()
    }

    /// Expand the full cartesian product into scenarios, in deterministic
    /// nested-loop order (mode outermost, seed innermost).
    pub fn expand(&self) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(self.cardinality());
        let mut index = 0usize;
        for &mode in &self.modes {
            for &tenants in &self.tenants {
                for &mix in &self.mixes {
                    for &burst in &self.bursts {
                        for &tightness in &self.tightness {
                            for accel in &self.accels {
                                for &seed in &self.seeds {
                                    let key = ScenarioKey {
                                        mode,
                                        tenants,
                                        mix,
                                        burst,
                                        tightness,
                                        accel: accel.name,
                                        seed,
                                    };
                                    let spec = self.scenario_spec(&key, accel);
                                    out.push(Scenario { index, key, spec });
                                    index += 1;
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    fn scenario_spec(&self, key: &ScenarioKey, accel: &AccelModel) -> ExperimentSpec {
        let tenants = key.tenants.max(1);
        // The engine's sustainable ingress rate at this mixture's mean
        // size; `tightness` of it is committed, split evenly per tenant.
        let capacity = accel.effective_rate(key.mix.mean_bytes());
        let per_flow_slo = Rate(capacity.0 * key.tightness / tenants as f64);
        let per_flow_load = self.base.load / tenants as f64;
        let flows: Vec<FlowSpec> = (0..tenants)
            .map(|t| {
                let pattern = TrafficPattern {
                    sizes: key.mix.dist(),
                    load: per_flow_load,
                    line_rate: self.base.line_rate,
                    burst: key.burst,
                };
                FlowSpec::new(
                    t,
                    t,
                    self.base.path,
                    pattern,
                    Slo::Throughput { target: per_flow_slo, percentile: 99.0 },
                    0,
                )
            })
            .collect();
        ExperimentSpec::new(key.mode, vec![accel.clone()], flows)
            .with_duration(self.base.duration)
            .with_warmup(self.base.warmup)
            .with_seed(scenario_seed(self.base.seed, key))
    }
}

/// Derive a scenario's simulator seed from the grid seed and the
/// scenario's axis coordinates (FNV-1a over the label, mixed through
/// SplitMix64). A pure function of the coordinates: the cell labeled
/// `arcus/t02/mtu/paced/x0.7000/ipsec/s1` keeps the same seed no matter
/// which other axis values surround it, so reports stay comparable as a
/// grid grows. Distinct coordinates give decorrelated (and, over 64 bits,
/// distinct) seeds.
pub fn scenario_seed(base: u64, key: &ScenarioKey) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325; // FNV-1a offset basis
    for b in key.label().bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3); // FNV-1a prime
    }
    // The label carries tightness at 4 decimals; fold in the exact bits so
    // tightness values that collide in the label still get distinct seeds.
    h ^= key.tightness.to_bits().wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut s = base ^ h;
    let first = splitmix64(&mut s);
    first ^ splitmix64(&mut s)
}

/// The axis coordinates of one scenario.
#[derive(Debug, Clone)]
pub struct ScenarioKey {
    pub mode: Mode,
    pub tenants: usize,
    pub mix: SizeMix,
    pub burst: Burstiness,
    pub tightness: f64,
    /// Accelerator model name (axis label).
    pub accel: &'static str,
    /// Seed-axis value (not the derived simulator seed).
    pub seed: u64,
}

impl ScenarioKey {
    /// Stable human-readable identifier, e.g.
    /// `arcus/t04/mtu/poisson/x0.7000/ipsec/s2`. Tightness carries four
    /// decimals so nearby swept values keep distinct labels.
    pub fn label(&self) -> String {
        format!(
            "{}/t{:02}/{}/{}/x{:.4}/{}/s{}",
            self.mode.name(),
            self.tenants,
            self.mix.name(),
            burst_name(self.burst),
            self.tightness,
            self.accel,
            self.seed
        )
    }
}

/// One expanded grid cell: coordinates plus the runnable spec.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Position in expansion order.
    pub index: usize,
    pub key: ScenarioKey,
    pub spec: ExperimentSpec,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall_cfg, Config, VecOf, U64Range};
    use std::collections::HashSet;

    /// Build a grid whose seven axis lengths are `lens` (each 1..=4),
    /// taking prefixes of canonical per-axis menus.
    fn grid_with_lens(lens: &[u64]) -> SweepGrid {
        assert_eq!(lens.len(), 7);
        let modes = [Mode::Arcus, Mode::HostNoTs, Mode::HostTsReflex, Mode::BypassedPanic];
        let tenants = [1usize, 2, 3, 4];
        let mixes = [SizeMix::Mtu, SizeMix::Bulk, SizeMix::Tiny, SizeMix::Mixed];
        let bursts = [
            Burstiness::Paced,
            Burstiness::Poisson,
            Burstiness::OnOff { burst_len: 16 },
            Burstiness::OnOff { burst_len: 4 },
        ];
        let tightness = [0.4, 0.6, 0.8, 1.0];
        let accels = [
            AccelModel::ipsec_32g(),
            AccelModel::aes_128(),
            AccelModel::sha1_hmac(),
            AccelModel::synthetic(Rate::gbps(50.0)),
        ];
        let seeds = [1u64, 2, 3, 4];
        SweepGrid::new(GridBase::default())
            .modes(modes[..lens[0] as usize].to_vec())
            .tenants(tenants[..lens[1] as usize].to_vec())
            .mixes(mixes[..lens[2] as usize].to_vec())
            .bursts(bursts[..lens[3] as usize].to_vec())
            .tightness(tightness[..lens[4] as usize].to_vec())
            .accels(accels[..lens[5] as usize].to_vec())
            .seeds(seeds[..lens[6] as usize].to_vec())
    }

    fn lens_gen() -> VecOf<U64Range> {
        VecOf { elem: U64Range(1, 4), min_len: 7, max_len: 7 }
    }

    #[test]
    fn prop_expansion_cardinality_is_axis_product() {
        forall_cfg(&Config { cases: 64, ..Default::default() }, &lens_gen(), |lens| {
            let grid = grid_with_lens(lens);
            let product: u64 = lens.iter().product();
            grid.cardinality() == product as usize
                && grid.expand().len() == grid.cardinality()
        });
    }

    #[test]
    fn prop_scenario_seeds_pairwise_distinct() {
        forall_cfg(&Config { cases: 48, ..Default::default() }, &lens_gen(), |lens| {
            let grid = grid_with_lens(lens);
            let scenarios = grid.expand();
            let seeds: HashSet<u64> = scenarios.iter().map(|s| s.spec.seed).collect();
            seeds.len() == scenarios.len()
        });
    }

    #[test]
    fn prop_labels_unique_and_expansion_deterministic() {
        forall_cfg(&Config { cases: 32, ..Default::default() }, &lens_gen(), |lens| {
            let grid = grid_with_lens(lens);
            let a = grid.expand();
            let b = grid.expand();
            let labels: HashSet<String> = a.iter().map(|s| s.key.label()).collect();
            labels.len() == a.len()
                && a.len() == b.len()
                && a.iter().zip(b.iter()).all(|(x, y)| {
                    x.key.label() == y.key.label()
                        && x.spec.seed == y.spec.seed
                        && x.spec.flows.len() == y.spec.flows.len()
                })
        });
    }

    #[test]
    fn seeds_stable_when_other_axes_grow() {
        // The same coordinate cell must keep its simulator seed no matter
        // which other axis values surround it (cross-run comparability).
        let base = || {
            SweepGrid::new(GridBase::default())
                .modes(vec![Mode::Arcus, Mode::HostNoTs])
                .mixes(vec![SizeMix::Mtu])
                .bursts(vec![Burstiness::Paced])
                .tightness(vec![0.7])
                .accels(vec![AccelModel::ipsec_32g()])
                .seeds(vec![1])
        };
        let small = base().tenants(vec![1, 2]).expand();
        let large = base().tenants(vec![1, 2, 4]).seeds(vec![1, 2]).expand();
        let by_label: std::collections::HashMap<String, u64> =
            large.iter().map(|s| (s.key.label(), s.spec.seed)).collect();
        for s in &small {
            assert_eq!(
                by_label.get(&s.key.label()),
                Some(&s.spec.seed),
                "{} changed seed when the grid grew",
                s.key.label()
            );
        }
    }

    #[test]
    fn empty_axis_empty_grid() {
        let grid = SweepGrid::new(GridBase::default())
            .modes(vec![Mode::Arcus])
            .tenants(vec![2])
            .mixes(vec![SizeMix::Mtu])
            .bursts(vec![])
            .tightness(vec![0.7])
            .accels(vec![AccelModel::ipsec_32g()])
            .seeds(vec![1]);
        assert_eq!(grid.cardinality(), 0);
        assert!(grid.expand().is_empty());
    }

    #[test]
    fn scenario_flows_match_coordinates() {
        let grid = SweepGrid::new(GridBase { load: 0.8, ..GridBase::default() })
            .modes(vec![Mode::Arcus])
            .tenants(vec![4])
            .mixes(vec![SizeMix::Bulk])
            .bursts(vec![Burstiness::Poisson])
            .tightness(vec![0.5])
            .accels(vec![AccelModel::ipsec_32g()])
            .seeds(vec![9]);
        let scenarios = grid.expand();
        assert_eq!(scenarios.len(), 1);
        let spec = &scenarios[0].spec;
        assert_eq!(spec.flows.len(), 4);
        assert_eq!(spec.mode, Mode::Arcus);
        // Per-tenant load splits the aggregate evenly.
        assert!((spec.flows[0].pattern.load - 0.2).abs() < 1e-12);
        // Committed SLO sum = tightness × capacity at the mean size.
        let cap = AccelModel::ipsec_32g().effective_rate(4096);
        let total: f64 = spec
            .flows
            .iter()
            .map(|f| match f.slo {
                Slo::Throughput { target, .. } => target.0,
                _ => panic!("grid scenarios carry throughput SLOs"),
            })
            .sum();
        assert!((total - cap.0 * 0.5).abs() / (cap.0 * 0.5) < 1e-9);
    }

    #[test]
    fn size_mix_roundtrip_and_means() {
        for m in SizeMix::ALL {
            assert_eq!(SizeMix::by_name(m.name()), Some(m));
            assert!(m.mean_bytes() >= 64);
        }
        assert_eq!(SizeMix::Mtu.mean_bytes(), 1500);
        assert!(SizeMix::by_name("jumbo").is_none());
    }
}
