//! Scenario grid: one experiment template expanded over evaluation axes.
//!
//! Arcus's claim is that SLO attainment holds across *diverse, mixed,
//! hard-to-predict* traffic mixtures (§3). A [`SweepGrid`] makes that
//! diversity first-class: it holds one [`GridBase`] template plus a value
//! list per axis — tenant count, management [`Mode`], burstiness,
//! message-size mix, SLO tightness, accelerator model, and seed — and
//! [`SweepGrid::expand`] takes the full cartesian product into a
//! deterministic list of [`Scenario`]s (one [`crate::system::ExperimentSpec`]
//! each). Benches, tests, and the `arcus sweep` subcommand all build their
//! experiments from this one vocabulary, so a "scenario" means the same
//! thing everywhere.
//!
//! Determinism contract: expansion order is the nested-loop order of the
//! axis declarations (mode outermost, seed innermost), and scenario labels
//! AND simulator seeds are pure functions of the axis coordinates (the
//! seed hashes `(grid seed, label)` through FNV-1a + SplitMix64) — two
//! expansions of equal grids are identical element-wise, and the same
//! cell keeps its seed when other axes grow.

use crate::accel::AccelModel;
use crate::api::AdaptiveConfig;
use crate::faults::{validate_faults, FaultKind, FaultSpec};
use crate::flow::pattern::{Burstiness, SizeDist};
use crate::flow::{FlowSpec, Path, Slo};
use crate::flow::TrafficPattern;
use crate::system::{ExperimentSpec, LifecycleEvent, Mode};
use crate::util::rng::splitmix64;
use crate::util::units::{Rate, Time, MILLIS};
use crate::workload::PopulationConfig;

/// Named message-size mixtures (Table 1's size axis) — the shared
/// vocabulary for benches, tests, and the `sweep` subcommand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SizeMix {
    /// 64 B RPCs — the mixture that craters fixed-function engines.
    Tiny,
    /// 256 B small messages.
    Small,
    /// MTU-sized (1500 B) — the paper's reference point.
    Mtu,
    /// 4 KB blocks (storage/KV payloads).
    Bulk,
    /// Equal-probability choice over 64/256/1500/4096.
    Mixed,
    /// 90% 64 B RPCs + 10% 4 KB bulk (tiny-RPC + bulk tenants).
    Bimodal,
}

impl SizeMix {
    pub const ALL: [SizeMix; 6] = [
        SizeMix::Tiny,
        SizeMix::Small,
        SizeMix::Mtu,
        SizeMix::Bulk,
        SizeMix::Mixed,
        SizeMix::Bimodal,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SizeMix::Tiny => "tiny",
            SizeMix::Small => "small",
            SizeMix::Mtu => "mtu",
            SizeMix::Bulk => "bulk",
            SizeMix::Mixed => "mixed",
            SizeMix::Bimodal => "bimodal",
        }
    }

    pub fn by_name(s: &str) -> Option<SizeMix> {
        Self::ALL.iter().copied().find(|m| m.name() == s)
    }

    pub fn dist(self) -> SizeDist {
        match self {
            SizeMix::Tiny => SizeDist::Fixed(64),
            SizeMix::Small => SizeDist::Fixed(256),
            SizeMix::Mtu => SizeDist::Fixed(1500),
            SizeMix::Bulk => SizeDist::Fixed(4096),
            SizeMix::Mixed => SizeDist::Choice(vec![64, 256, 1500, 4096]),
            SizeMix::Bimodal => SizeDist::Bimodal { a: 64, b: 4096, p_a: 0.9 },
        }
    }

    /// Mean message size (profiling context / SLO sizing).
    pub fn mean_bytes(self) -> u64 {
        self.dist().mean().round().max(1.0) as u64
    }

    /// Parse a mix name, or explain which names are valid.
    pub fn parse(s: &str) -> Result<SizeMix, String> {
        SizeMix::by_name(s).ok_or_else(|| {
            let valid: Vec<&str> = SizeMix::ALL.iter().map(|m| m.name()).collect();
            format!("unknown size mix `{s}` (valid mixes: {})", valid.join(", "))
        })
    }
}

/// Tenant-churn pattern: which flow-lifecycle events a scenario schedules
/// (the paper's Scenarios 1–2 — dynamic registration, departure, and SLO
/// renegotiation against the control-plane API).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Churn {
    /// Every tenant present for the whole run (the legacy grid; scenario
    /// labels and seeds are unchanged from pre-churn grids).
    Static,
    /// The later half of the tenant roster arrives staggered mid-run and
    /// must pass admission control against the incumbents' commitments.
    Arrivals,
    /// The earlier half departs staggered mid-run, releasing capacity.
    Departures,
    /// Tenant 0 renegotiates its SLO upward at mid-run.
    Renegotiation,
    /// One arrival, one departure, and one renegotiation in sequence.
    Mixed,
}

impl Churn {
    pub const ALL: [Churn; 5] = [
        Churn::Static,
        Churn::Arrivals,
        Churn::Departures,
        Churn::Renegotiation,
        Churn::Mixed,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Churn::Static => "static",
            Churn::Arrivals => "arrivals",
            Churn::Departures => "departures",
            Churn::Renegotiation => "renegotiation",
            Churn::Mixed => "mixed",
        }
    }

    pub fn by_name(s: &str) -> Option<Churn> {
        Self::ALL.iter().copied().find(|c| c.name() == s)
    }

    /// Parse a churn name, or explain which names are valid.
    pub fn parse(s: &str) -> Result<Churn, String> {
        Churn::by_name(s).ok_or_else(|| {
            let valid: Vec<&str> = Churn::ALL.iter().map(|c| c.name()).collect();
            format!("unknown churn `{s}` (valid churns: {})", valid.join(", "))
        })
    }
}

/// Fault-injection axis: which degradation / adversary plan a scenario
/// schedules (see [`crate::faults`]). Like [`Churn`], the `Healthy` value
/// keeps pre-fault grids byte-identical — labels and derived seeds are
/// unchanged when the axis is absent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultProfile {
    /// No injection (the legacy grid).
    Healthy,
    /// Accelerator 0's throughput dips to 50% across [40%, 70%) of the run.
    AccelDip,
    /// The PCIe link loses half its bandwidth across [40%, 70%).
    LinkCut,
    /// A deep, short link flap: 10% bandwidth across [50%, 55%).
    Flap,
    /// The last tenant goes adversarial (ignores its shaper) across
    /// [40%, 70%) until the control plane clamps it.
    Rogue,
    /// Algorithm-1 ticks are lost across [40%, 70%).
    Outage,
}

impl FaultProfile {
    pub const ALL: [FaultProfile; 6] = [
        FaultProfile::Healthy,
        FaultProfile::AccelDip,
        FaultProfile::LinkCut,
        FaultProfile::Flap,
        FaultProfile::Rogue,
        FaultProfile::Outage,
    ];

    pub fn name(self) -> &'static str {
        match self {
            FaultProfile::Healthy => "healthy",
            FaultProfile::AccelDip => "accel_dip",
            FaultProfile::LinkCut => "link_cut",
            FaultProfile::Flap => "flap",
            FaultProfile::Rogue => "rogue",
            FaultProfile::Outage => "outage",
        }
    }

    pub fn by_name(s: &str) -> Option<FaultProfile> {
        Self::ALL.iter().copied().find(|f| f.name() == s)
    }

    /// Parse a fault-profile name, or explain which names are valid.
    pub fn parse(s: &str) -> Result<FaultProfile, String> {
        FaultProfile::by_name(s).ok_or_else(|| {
            let valid: Vec<&str> = FaultProfile::ALL.iter().map(|f| f.name()).collect();
            format!("unknown fault profile `{s}` (valid profiles: {})", valid.join(", "))
        })
    }
}

/// Control-loop axis: whether Arcus cells run the static planner alone or
/// wrap it in the closed-loop [`crate::api::AdaptiveControlPlane`] (default
/// gains). Like [`Churn`], the `Static` value keeps pre-axis grids
/// byte-identical — labels and derived seeds are unchanged when the axis
/// is absent. Non-Arcus modes ignore the flag (there is no planner to
/// wrap), so sweeping `adaptive` is only meaningful alongside `arcus`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControlKind {
    /// The static Arcus planner alone (the legacy grid).
    Static,
    /// The bi-level AIMD wrapper with [`AdaptiveConfig::default`] gains.
    Adaptive,
}

impl ControlKind {
    /// Every control-axis value, in menu order.
    pub const ALL: [ControlKind; 2] = [ControlKind::Static, ControlKind::Adaptive];

    /// Axis label.
    pub fn name(self) -> &'static str {
        match self {
            ControlKind::Static => "static",
            ControlKind::Adaptive => "adaptive",
        }
    }

    /// Inverse of [`ControlKind::name`].
    pub fn by_name(s: &str) -> Option<ControlKind> {
        Self::ALL.iter().copied().find(|c| c.name() == s)
    }

    /// Parse a control name, or explain which names are valid.
    pub fn parse(s: &str) -> Result<ControlKind, String> {
        ControlKind::by_name(s).ok_or_else(|| {
            let valid: Vec<&str> = ControlKind::ALL.iter().map(|c| c.name()).collect();
            format!("unknown control `{s}` (valid controls: {})", valid.join(", "))
        })
    }
}

/// Flow-population scale axis: how many flows a scenario carries in
/// total. `Flat` is the legacy roster — one flow per tenant — and keeps
/// labels and derived seeds byte-identical to pre-scale grids. A
/// `Flows(n)` cell spreads `n` flows round-robin across the tenant (VM)
/// roster, splits the committed tightness evenly over all `n`, and
/// enables the hierarchical shaper tree
/// ([`crate::shaping::ShaperTree`]) — per-flow shapers do not compose at
/// 4k–10k flows; per-tenant aggregates do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// One flow per tenant (legacy grids; flat per-flow shaping).
    Flat,
    /// `n` flows total, tree-shaped under per-tenant aggregates.
    Flows(usize),
}

impl Scale {
    /// Axis label: `flat`, or `f<n>` for scaled cells.
    pub fn name(self) -> String {
        match self {
            Scale::Flat => "flat".to_string(),
            Scale::Flows(n) => format!("f{n}"),
        }
    }

    /// Parse an axis value: `flat`, a flow count (`256`), or a
    /// `k`-suffixed count (`4k` = 4000, `10k` = 10000).
    pub fn parse(s: &str) -> Result<Scale, String> {
        if s == "flat" {
            return Ok(Scale::Flat);
        }
        let (digits, mul) = match s.strip_suffix('k') {
            Some(d) => (d, 1000usize),
            None => (s, 1usize),
        };
        match digits.parse::<usize>().ok().and_then(|n| n.checked_mul(mul)) {
            Some(n) if n >= 1 => Ok(Scale::Flows(n)),
            _ => Err(format!(
                "unknown scale `{s}` (valid scales: flat, a flow count like 16 or 256, \
                 or a k-suffixed count like 4k / 10k)"
            )),
        }
    }
}

/// The fault plan a profile implies for `tenants` flows over a run of
/// `duration`. Pure arithmetic over the coordinates (no RNG); windows sit
/// past typical warmups and heal before the run ends so recovery is
/// measurable.
pub fn fault_events(profile: FaultProfile, tenants: usize, duration: Time) -> Vec<FaultSpec> {
    let t = tenants.max(1);
    let start = duration * 2 / 5;
    let end = duration * 7 / 10;
    match profile {
        FaultProfile::Healthy => Vec::new(),
        FaultProfile::AccelDip => vec![FaultSpec::new(
            FaultKind::AccelSlowdown { unit: 0, factor: 0.5 },
            start,
            end,
        )],
        FaultProfile::LinkCut => vec![FaultSpec::new(
            FaultKind::LinkDegrade { factor: 0.5 },
            start,
            end,
        )],
        FaultProfile::Flap => vec![FaultSpec::new(
            FaultKind::LinkDegrade { factor: 0.1 },
            duration / 2,
            duration * 11 / 20,
        )],
        FaultProfile::Rogue => vec![FaultSpec::new(
            FaultKind::RogueTenant { flow: t - 1 },
            start,
            end,
        )],
        FaultProfile::Outage => vec![FaultSpec::new(FaultKind::ControlOutage, start, end)],
    }
}

/// Parse a burstiness axis value (`paced`, `poisson`, `onoff<N>`), or
/// explain the vocabulary.
pub fn parse_burst(s: &str) -> Result<Burstiness, String> {
    match s {
        "paced" => Ok(Burstiness::Paced),
        "poisson" => Ok(Burstiness::Poisson),
        _ => {
            if let Some(n) = s.strip_prefix("onoff") {
                if let Ok(len) = n.parse::<u32>() {
                    if len > 0 {
                        return Ok(Burstiness::OnOff { burst_len: len });
                    }
                }
            }
            Err(format!(
                "unknown burst `{s}` (valid bursts: paced, poisson, onoff<N> with N ≥ 1)"
            ))
        }
    }
}

/// Human label for a burstiness axis value.
pub fn burst_name(b: Burstiness) -> String {
    match b {
        Burstiness::Paced => "paced".to_string(),
        Burstiness::Poisson => "poisson".to_string(),
        Burstiness::OnOff { burst_len } => format!("onoff{burst_len}"),
    }
}

/// Template parameters shared by every scenario in a grid.
#[derive(Debug, Clone)]
pub struct GridBase {
    /// Virtual measured duration per scenario.
    pub duration: Time,
    /// Virtual warmup discarded from metrics.
    pub warmup: Time,
    /// Reference line rate the load fraction is relative to.
    pub line_rate: Rate,
    /// Aggregate offered load across all tenants, as a fraction of
    /// `line_rate` (each tenant offers `load / tenants`).
    pub load: f64,
    /// Invocation path every flow uses.
    pub path: Path,
    /// Base seed every scenario seed is derived from.
    pub seed: u64,
}

impl Default for GridBase {
    fn default() -> Self {
        GridBase {
            duration: 4 * MILLIS,
            warmup: MILLIS,
            line_rate: Rate::gbps(32.0),
            load: 0.9,
            path: Path::FunctionCall,
            seed: 1,
        }
    }
}

/// The grid: a template plus one value list per axis.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    pub base: GridBase,
    pub modes: Vec<Mode>,
    pub tenants: Vec<usize>,
    pub mixes: Vec<SizeMix>,
    pub bursts: Vec<Burstiness>,
    /// SLO tightness: the fraction of the accelerator's effective capacity
    /// (at the mix's mean message size) committed across all tenants.
    /// 1.0 commits the whole engine; >1.0 is deliberately inadmissible.
    pub tightness: Vec<f64>,
    /// Tenant-churn axis (defaults to `[Churn::Static]`, so legacy grids
    /// are unchanged).
    pub churn: Vec<Churn>,
    /// Fault-injection axis (defaults to `[FaultProfile::Healthy]`, so
    /// legacy grids are unchanged).
    pub faults: Vec<FaultProfile>,
    /// Flow-population scale axis (defaults to `[Scale::Flat]`, so legacy
    /// grids are unchanged; non-flat cells run the shaper hierarchy).
    pub scale: Vec<Scale>,
    /// Control-loop axis (defaults to `[ControlKind::Static]`, so legacy
    /// grids are unchanged; adaptive cells wrap the Arcus planner).
    pub control: Vec<ControlKind>,
    /// Fleet-size axis (defaults to `[1]`, so legacy grids are unchanged;
    /// multi-host cells run under [`crate::fleet::FleetPlane`] with the
    /// default distribution config).
    pub hosts: Vec<usize>,
    /// Population axis: `None` cells use the per-flow pattern generators
    /// (the legacy grid — labels and seeds unchanged); `Some(users)` cells
    /// drive every flow from the heavy-tailed user-population generator
    /// ([`crate::workload::PopulationConfig`] with default shape knobs)
    /// and grow per-user fairness metrics in the report.
    pub population: Vec<Option<usize>>,
    pub accels: Vec<AccelModel>,
    /// Seed axis: replications of every cell with decorrelated randomness.
    pub seeds: Vec<u64>,
}

impl SweepGrid {
    /// A grid with empty axes (churn defaults to static); fill every other
    /// axis before expanding.
    pub fn new(base: GridBase) -> Self {
        SweepGrid {
            base,
            modes: Vec::new(),
            tenants: Vec::new(),
            mixes: Vec::new(),
            bursts: Vec::new(),
            tightness: Vec::new(),
            churn: vec![Churn::Static],
            faults: vec![FaultProfile::Healthy],
            scale: vec![Scale::Flat],
            control: vec![ControlKind::Static],
            hosts: vec![1],
            population: vec![None],
            accels: Vec::new(),
            seeds: Vec::new(),
        }
    }

    pub fn modes(mut self, v: Vec<Mode>) -> Self {
        self.modes = v;
        self
    }
    pub fn tenants(mut self, v: Vec<usize>) -> Self {
        self.tenants = v;
        self
    }
    pub fn mixes(mut self, v: Vec<SizeMix>) -> Self {
        self.mixes = v;
        self
    }
    pub fn bursts(mut self, v: Vec<Burstiness>) -> Self {
        self.bursts = v;
        self
    }
    pub fn tightness(mut self, v: Vec<f64>) -> Self {
        self.tightness = v;
        self
    }
    pub fn churn(mut self, v: Vec<Churn>) -> Self {
        self.churn = v;
        self
    }
    pub fn faults(mut self, v: Vec<FaultProfile>) -> Self {
        self.faults = v;
        self
    }
    pub fn scale(mut self, v: Vec<Scale>) -> Self {
        self.scale = v;
        self
    }
    pub fn control(mut self, v: Vec<ControlKind>) -> Self {
        self.control = v;
        self
    }
    pub fn hosts(mut self, v: Vec<usize>) -> Self {
        self.hosts = v;
        self
    }
    pub fn population(mut self, v: Vec<Option<usize>>) -> Self {
        self.population = v;
        self
    }
    pub fn accels(mut self, v: Vec<AccelModel>) -> Self {
        self.accels = v;
        self
    }
    pub fn seeds(mut self, v: Vec<u64>) -> Self {
        self.seeds = v;
        self
    }

    /// Number of scenarios the grid expands to: the product of axis
    /// lengths (zero if any axis is empty).
    pub fn cardinality(&self) -> usize {
        self.modes.len()
            * self.tenants.len()
            * self.mixes.len()
            * self.bursts.len()
            * self.tightness.len()
            * self.churn.len()
            * self.faults.len()
            * self.scale.len()
            * self.control.len()
            * self.hosts.len()
            * self.population.len()
            * self.accels.len()
            * self.seeds.len()
    }

    /// Validate the grid before expansion, with actionable errors — the
    /// alternative is a panic (or a silent u64 wrap) deep inside the
    /// engine once a worker thread reaches the first scenario.
    pub fn validate(&self) -> Result<(), String> {
        if self.base.duration == 0 {
            return Err("grid duration must be positive".to_string());
        }
        if self.base.warmup >= self.base.duration {
            return Err(format!(
                "grid warmup ({} ms) must be shorter than its duration ({} ms): \
                 nothing would be measured",
                self.base.warmup as f64 / MILLIS as f64,
                self.base.duration as f64 / MILLIS as f64
            ));
        }
        if self.base.load.is_nan() || self.base.load <= 0.0 {
            return Err(format!("grid load must be positive (got {})", self.base.load));
        }
        if let Some(&t) = self.tenants.iter().find(|&&t| t == 0) {
            return Err(format!("tenant counts must be ≥ 1 (got {t})"));
        }
        if let Some(&x) = self.tightness.iter().find(|&&x| x.is_nan() || x <= 0.0) {
            return Err(format!("tightness values must be positive (got {x})"));
        }
        if self.hosts.iter().any(|&h| h == 0) {
            return Err("host counts must be ≥ 1".to_string());
        }
        if let Some(&h) = self.hosts.iter().find(|&&h| h > 64) {
            return Err(format!(
                "hosts h{h} exceeds the supported ceiling (64 hosts per scenario)"
            ));
        }
        for &s in &self.scale {
            let Scale::Flows(n) = s else { continue };
            if let Some(&t) = self.tenants.iter().find(|&&t| n < t) {
                return Err(format!(
                    "scale f{n} is smaller than the tenant roster ({t}): every tenant \
                     needs at least one flow — raise the scale or drop the tenant count"
                ));
            }
            if n > 50_000 {
                return Err(format!(
                    "scale f{n} exceeds the supported ceiling (50000 flows per scenario)"
                ));
            }
        }
        for &p in &self.population {
            let Some(users) = p else { continue };
            // Per-user accounting lives in the single-world engine; a fleet
            // merge has no way to combine two hosts' user tables.
            if let Some(&h) = self.hosts.iter().find(|&&h| h > 1) {
                return Err(format!(
                    "population u{users} cannot combine with hosts h{h}: per-user \
                     accounting lives in the single-world engine — drop the hosts \
                     axis or the population axis"
                ));
            }
            // Every flow needs at least one home user at every scale ×
            // tenant coordinate the expansion will visit.
            for &s in &self.scale {
                for &t in &self.tenants {
                    let n_flows = match s {
                        Scale::Flat => t,
                        Scale::Flows(n) => n.max(t),
                    };
                    if users < n_flows {
                        return Err(format!(
                            "population u{users} cannot cover the {n_flows} flows of \
                             cell `{} × t{t:02}`: every flow needs at least one home \
                             user — raise the population or shrink the flow roster",
                            s.name()
                        ));
                    }
                }
            }
            PopulationConfig { users, ..PopulationConfig::default() }
                .validate(1)
                .map_err(|e| format!("population u{users}: {e}"))?;
        }
        // Axis interactions: expansion combines every churn pattern with
        // every fault profile at every tenant count, and some combinations
        // are ill-formed even though each axis value is fine alone. Check
        // the generated schedules per combination (cheap: the cross product
        // of three small axes, no simulation).
        for &t in &self.tenants {
            for &fp in &self.faults {
                let faults = fault_events(fp, t, self.base.duration);
                // Windows inside the measured run, factors sane, no overlap
                // on one component — the same rules config-supplied plans
                // face (this also rejects windows starting at/after the
                // duration or inside the warmup).
                validate_faults(&faults, self.base.duration, self.base.warmup, t, 1, false)
                    .map_err(|e| format!("faults `{}` at {t} tenants: {e}", fp.name()))?;
                for &c in &self.churn {
                    let churn = churn_events(c, t, self.base.duration, Rate(1.0));
                    for f in &faults {
                        let FaultKind::RogueTenant { flow } = f.kind else { continue };
                        for e in &churn {
                            let LifecycleEvent::Depart { flow: df, at } = *e else {
                                continue;
                            };
                            if df == flow && at >= f.at && at < f.until {
                                return Err(format!(
                                    "churn `{}` departs tenant {df} at {:.2} ms, inside \
                                     the `{}` fault window [{:.2}, {:.2}) ms targeting \
                                     the same tenant — the departure would race the \
                                     adversary; drop one of the two axis values or \
                                     change the tenant count ({t})",
                                    c.name(),
                                    at as f64 / MILLIS as f64,
                                    fp.name(),
                                    f.at as f64 / MILLIS as f64,
                                    f.until as f64 / MILLIS as f64,
                                ));
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Expand the full cartesian product into scenarios, in deterministic
    /// nested-loop order (mode outermost, seed innermost).
    pub fn expand(&self) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(self.cardinality());
        let mut index = 0usize;
        for &mode in &self.modes {
            for &tenants in &self.tenants {
                for &mix in &self.mixes {
                    for &burst in &self.bursts {
                        for &tightness in &self.tightness {
                            for &churn in &self.churn {
                                for &faults in &self.faults {
                                    for &scale in &self.scale {
                                        for &control in &self.control {
                                            for &hosts in &self.hosts {
                                                for &population in &self.population {
                                                    for accel in &self.accels {
                                                        for &seed in &self.seeds {
                                                            let key = ScenarioKey {
                                                                mode,
                                                                tenants,
                                                                mix,
                                                                burst,
                                                                tightness,
                                                                churn,
                                                                faults,
                                                                scale,
                                                                control,
                                                                hosts,
                                                                population,
                                                                accel: accel.name,
                                                                seed,
                                                            };
                                                            let spec =
                                                                self.scenario_spec(&key, accel);
                                                            out.push(Scenario {
                                                                index,
                                                                key,
                                                                spec,
                                                            });
                                                            index += 1;
                                                        }
                                                    }
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    fn scenario_spec(&self, key: &ScenarioKey, accel: &AccelModel) -> ExperimentSpec {
        let tenants = key.tenants.max(1);
        // Total flow population: the legacy roster is one flow per tenant;
        // a scaled cell spreads `n` flows round-robin over the tenant VMs.
        let n_flows = match key.scale {
            Scale::Flat => tenants,
            Scale::Flows(n) => n.max(tenants),
        };
        // The engine's sustainable ingress rate at this mixture's mean
        // size; `tightness` of it is committed, split evenly per flow.
        let capacity = accel.effective_rate(key.mix.mean_bytes());
        let per_flow_slo = Rate(capacity.0 * key.tightness / n_flows as f64);
        let per_flow_load = self.base.load / n_flows as f64;
        let flows: Vec<FlowSpec> = (0..n_flows)
            .map(|i| {
                let pattern = TrafficPattern {
                    sizes: key.mix.dist(),
                    load: per_flow_load,
                    line_rate: self.base.line_rate,
                    burst: key.burst,
                };
                FlowSpec::new(
                    i,
                    i % tenants,
                    self.base.path,
                    pattern,
                    Slo::Throughput { target: per_flow_slo, percentile: 99.0 },
                    0,
                )
            })
            .collect();
        let mut spec = ExperimentSpec::new(key.mode, vec![accel.clone()], flows)
            .with_duration(self.base.duration)
            .with_warmup(self.base.warmup)
            .with_seed(scenario_seed(self.base.seed, key))
            .with_lifecycle(churn_events(key.churn, tenants, self.base.duration, per_flow_slo))
            .with_faults(fault_events(key.faults, tenants, self.base.duration));
        if key.scale != Scale::Flat {
            // Per-flow shapers do not compose at thousands of flows; the
            // scale axis exists to exercise the hierarchy.
            spec = spec.with_hierarchy();
        }
        if key.control == ControlKind::Adaptive {
            // Only Arcus cells actually grow the closed loop (the engine
            // ignores the config for modes with no planner to wrap).
            spec = spec.with_adaptive(AdaptiveConfig::default());
        }
        if let Some(users) = key.population {
            // Population cells keep the default shape knobs (Zipf 1.1,
            // Pareto 1.3, no diurnal/burst) so the axis varies exactly one
            // thing: how many users the flows' traffic is multiplexed from.
            spec = spec.with_population(PopulationConfig {
                users,
                ..PopulationConfig::default()
            });
        }
        spec
    }
}

/// The lifecycle schedule a churn pattern implies for `tenants` flows over
/// a run of `duration`. Pure arithmetic over the coordinates (no RNG), so
/// expansion stays deterministic; event times sit past typical warmups and
/// are staggered so capacity changes are observable one at a time.
pub fn churn_events(
    churn: Churn,
    tenants: usize,
    duration: Time,
    per_flow_slo: Rate,
) -> Vec<LifecycleEvent> {
    let t = tenants.max(1);
    match churn {
        Churn::Static => Vec::new(),
        Churn::Arrivals => {
            // The later half arrives staggered across [40%, 90%) of the
            // run — the window divides by the mover count so every event
            // lands inside the run at any tenant count.
            let movers = (t / 2).max(1);
            let window = duration / 2;
            (0..movers)
                .map(|k| LifecycleEvent::Arrive {
                    flow: t - movers + k,
                    at: duration * 2 / 5 + k as Time * window / movers as Time,
                })
                .collect()
        }
        Churn::Departures => {
            // The earlier half departs staggered across [50%, 90%).
            let movers = (t / 2).max(1);
            let window = duration * 2 / 5;
            (0..movers)
                .map(|k| LifecycleEvent::Depart {
                    flow: k,
                    at: duration / 2 + k as Time * window / movers as Time,
                })
                .collect()
        }
        Churn::Renegotiation => vec![LifecycleEvent::Renegotiate {
            flow: 0,
            at: duration / 2,
            slo: Slo::Throughput {
                target: Rate(per_flow_slo.0 * 1.25),
                percentile: 99.0,
            },
        }],
        Churn::Mixed => {
            let mut events = vec![LifecycleEvent::Arrive {
                flow: t - 1,
                at: duration * 2 / 5,
            }];
            if t >= 2 {
                events.push(LifecycleEvent::Depart { flow: 0, at: duration * 11 / 20 });
            }
            if t >= 3 {
                events.push(LifecycleEvent::Renegotiate {
                    flow: 1,
                    at: duration * 7 / 10,
                    slo: Slo::Throughput {
                        target: Rate(per_flow_slo.0 * 1.2),
                        percentile: 99.0,
                    },
                });
            }
            events
        }
    }
}

/// Derive a scenario's simulator seed from the grid seed and the
/// scenario's axis coordinates (FNV-1a over the label, mixed through
/// SplitMix64). A pure function of the coordinates: the cell labeled
/// `arcus/t02/mtu/paced/x0.7000/ipsec/s1` keeps the same seed no matter
/// which other axis values surround it, so reports stay comparable as a
/// grid grows. Distinct coordinates give decorrelated (and, over 64 bits,
/// distinct) seeds.
pub fn scenario_seed(base: u64, key: &ScenarioKey) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325; // FNV-1a offset basis
    for b in key.label().bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3); // FNV-1a prime
    }
    // The label carries tightness at 4 decimals; fold in the exact bits so
    // tightness values that collide in the label still get distinct seeds.
    h ^= key.tightness.to_bits().wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut s = base ^ h;
    let first = splitmix64(&mut s);
    first ^ splitmix64(&mut s)
}

/// The axis coordinates of one scenario.
#[derive(Debug, Clone)]
pub struct ScenarioKey {
    pub mode: Mode,
    pub tenants: usize,
    pub mix: SizeMix,
    pub burst: Burstiness,
    pub tightness: f64,
    pub churn: Churn,
    pub faults: FaultProfile,
    pub scale: Scale,
    pub control: ControlKind,
    /// Fleet size (1 = single-world run, no fleet tier).
    pub hosts: usize,
    /// Population-axis value (`None` = per-flow pattern generators).
    pub population: Option<usize>,
    /// Accelerator model name (axis label).
    pub accel: &'static str,
    /// Seed-axis value (not the derived simulator seed).
    pub seed: u64,
}

impl ScenarioKey {
    /// Stable human-readable identifier, e.g.
    /// `arcus/t04/f4000/mtu/poisson/x0.7000/arrivals/accel_dip/adaptive/ipsec/s2`.
    /// Tightness carries four decimals so nearby swept values keep distinct
    /// labels. Static (no-churn) cells omit the churn segment, healthy
    /// cells omit the faults segment, flat cells omit the scale segment,
    /// static-control cells omit the control segment, single-host cells
    /// omit the hosts segment, and pattern-generator cells omit the
    /// population segment (`u<users>`), so their labels — and the
    /// simulator seeds derived from them — are byte-identical to grids
    /// that predate those axes.
    pub fn label(&self) -> String {
        let scale = match self.scale {
            Scale::Flat => String::new(),
            s => format!("{}/", s.name()),
        };
        let churn = match self.churn {
            Churn::Static => String::new(),
            c => format!("{}/", c.name()),
        };
        let faults = match self.faults {
            FaultProfile::Healthy => String::new(),
            f => format!("{}/", f.name()),
        };
        let control = match self.control {
            ControlKind::Static => String::new(),
            c => format!("{}/", c.name()),
        };
        let hosts = match self.hosts {
            0 | 1 => String::new(),
            h => format!("h{h}/"),
        };
        let population = match self.population {
            None => String::new(),
            Some(u) => format!("u{u}/"),
        };
        format!(
            "{}/t{:02}/{}{}/{}/x{:.4}/{}{}{}{}{}{}/s{}",
            self.mode.name(),
            self.tenants,
            scale,
            self.mix.name(),
            burst_name(self.burst),
            self.tightness,
            churn,
            faults,
            control,
            hosts,
            population,
            self.accel,
            self.seed
        )
    }
}

/// One expanded grid cell: coordinates plus the runnable spec.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Position in expansion order.
    pub index: usize,
    pub key: ScenarioKey,
    pub spec: ExperimentSpec,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall_cfg, Config, VecOf, U64Range};
    use std::collections::HashSet;

    /// Build a grid whose seven axis lengths are `lens` (each 1..=4),
    /// taking prefixes of canonical per-axis menus.
    fn grid_with_lens(lens: &[u64]) -> SweepGrid {
        assert_eq!(lens.len(), 7);
        let modes = [Mode::Arcus, Mode::HostNoTs, Mode::HostTsReflex, Mode::BypassedPanic];
        let tenants = [1usize, 2, 3, 4];
        let mixes = [SizeMix::Mtu, SizeMix::Bulk, SizeMix::Tiny, SizeMix::Mixed];
        let bursts = [
            Burstiness::Paced,
            Burstiness::Poisson,
            Burstiness::OnOff { burst_len: 16 },
            Burstiness::OnOff { burst_len: 4 },
        ];
        let tightness = [0.4, 0.6, 0.8, 1.0];
        let accels = [
            AccelModel::ipsec_32g(),
            AccelModel::aes_128(),
            AccelModel::sha1_hmac(),
            AccelModel::synthetic(Rate::gbps(50.0)),
        ];
        let seeds = [1u64, 2, 3, 4];
        SweepGrid::new(GridBase::default())
            .modes(modes[..lens[0] as usize].to_vec())
            .tenants(tenants[..lens[1] as usize].to_vec())
            .mixes(mixes[..lens[2] as usize].to_vec())
            .bursts(bursts[..lens[3] as usize].to_vec())
            .tightness(tightness[..lens[4] as usize].to_vec())
            .accels(accels[..lens[5] as usize].to_vec())
            .seeds(seeds[..lens[6] as usize].to_vec())
    }

    fn lens_gen() -> VecOf<U64Range> {
        VecOf { elem: U64Range(1, 4), min_len: 7, max_len: 7 }
    }

    #[test]
    fn prop_expansion_cardinality_is_axis_product() {
        forall_cfg(&Config { cases: 64, ..Default::default() }, &lens_gen(), |lens| {
            let grid = grid_with_lens(lens);
            let product: u64 = lens.iter().product();
            grid.cardinality() == product as usize
                && grid.expand().len() == grid.cardinality()
        });
    }

    #[test]
    fn prop_scenario_seeds_pairwise_distinct() {
        forall_cfg(&Config { cases: 48, ..Default::default() }, &lens_gen(), |lens| {
            let grid = grid_with_lens(lens);
            let scenarios = grid.expand();
            let seeds: HashSet<u64> = scenarios.iter().map(|s| s.spec.seed).collect();
            seeds.len() == scenarios.len()
        });
    }

    #[test]
    fn prop_labels_unique_and_expansion_deterministic() {
        forall_cfg(&Config { cases: 32, ..Default::default() }, &lens_gen(), |lens| {
            let grid = grid_with_lens(lens);
            let a = grid.expand();
            let b = grid.expand();
            let labels: HashSet<String> = a.iter().map(|s| s.key.label()).collect();
            labels.len() == a.len()
                && a.len() == b.len()
                && a.iter().zip(b.iter()).all(|(x, y)| {
                    x.key.label() == y.key.label()
                        && x.spec.seed == y.spec.seed
                        && x.spec.flows.len() == y.spec.flows.len()
                })
        });
    }

    #[test]
    fn seeds_stable_when_other_axes_grow() {
        // The same coordinate cell must keep its simulator seed no matter
        // which other axis values surround it (cross-run comparability).
        let base = || {
            SweepGrid::new(GridBase::default())
                .modes(vec![Mode::Arcus, Mode::HostNoTs])
                .mixes(vec![SizeMix::Mtu])
                .bursts(vec![Burstiness::Paced])
                .tightness(vec![0.7])
                .accels(vec![AccelModel::ipsec_32g()])
                .seeds(vec![1])
        };
        let small = base().tenants(vec![1, 2]).expand();
        let large = base().tenants(vec![1, 2, 4]).seeds(vec![1, 2]).expand();
        let by_label: std::collections::HashMap<String, u64> =
            large.iter().map(|s| (s.key.label(), s.spec.seed)).collect();
        for s in &small {
            assert_eq!(
                by_label.get(&s.key.label()),
                Some(&s.spec.seed),
                "{} changed seed when the grid grew",
                s.key.label()
            );
        }
    }

    #[test]
    fn empty_axis_empty_grid() {
        let grid = SweepGrid::new(GridBase::default())
            .modes(vec![Mode::Arcus])
            .tenants(vec![2])
            .mixes(vec![SizeMix::Mtu])
            .bursts(vec![])
            .tightness(vec![0.7])
            .accels(vec![AccelModel::ipsec_32g()])
            .seeds(vec![1]);
        assert_eq!(grid.cardinality(), 0);
        assert!(grid.expand().is_empty());
    }

    #[test]
    fn scenario_flows_match_coordinates() {
        let grid = SweepGrid::new(GridBase { load: 0.8, ..GridBase::default() })
            .modes(vec![Mode::Arcus])
            .tenants(vec![4])
            .mixes(vec![SizeMix::Bulk])
            .bursts(vec![Burstiness::Poisson])
            .tightness(vec![0.5])
            .accels(vec![AccelModel::ipsec_32g()])
            .seeds(vec![9]);
        let scenarios = grid.expand();
        assert_eq!(scenarios.len(), 1);
        let spec = &scenarios[0].spec;
        assert_eq!(spec.flows.len(), 4);
        assert_eq!(spec.mode, Mode::Arcus);
        // Per-tenant load splits the aggregate evenly.
        assert!((spec.flows[0].pattern.load - 0.2).abs() < 1e-12);
        // Committed SLO sum = tightness × capacity at the mean size.
        let cap = AccelModel::ipsec_32g().effective_rate(4096);
        let total: f64 = spec
            .flows
            .iter()
            .map(|f| match f.slo {
                Slo::Throughput { target, .. } => target.0,
                _ => panic!("grid scenarios carry throughput SLOs"),
            })
            .sum();
        assert!((total - cap.0 * 0.5).abs() / (cap.0 * 0.5) < 1e-9);
    }

    #[test]
    fn size_mix_roundtrip_and_means() {
        for m in SizeMix::ALL {
            assert_eq!(SizeMix::by_name(m.name()), Some(m));
            assert!(m.mean_bytes() >= 64);
        }
        assert_eq!(SizeMix::Mtu.mean_bytes(), 1500);
        assert!(SizeMix::by_name("jumbo").is_none());
        let err = SizeMix::parse("jumbo").unwrap_err();
        assert!(err.contains("mtu") && err.contains("bimodal"), "{err}");
    }

    #[test]
    fn churn_roundtrip_and_parse_errors_list_menu() {
        for c in Churn::ALL {
            assert_eq!(Churn::by_name(c.name()), Some(c));
            assert_eq!(Churn::parse(c.name()), Ok(c));
        }
        let err = Churn::parse("tidal").unwrap_err();
        for c in Churn::ALL {
            assert!(err.contains(c.name()), "{err} missing {}", c.name());
        }
        assert!(parse_burst("paced").is_ok());
        assert!(parse_burst("onoff8").is_ok());
        let err = parse_burst("lumpy").unwrap_err();
        assert!(err.contains("poisson"), "{err}");
        assert!(parse_burst("onoff0").is_err());
    }

    #[test]
    fn static_labels_and_seeds_unchanged_by_churn_axis() {
        let base = || {
            SweepGrid::new(GridBase::default())
                .modes(vec![Mode::Arcus])
                .tenants(vec![2])
                .mixes(vec![SizeMix::Mtu])
                .bursts(vec![Burstiness::Paced])
                .tightness(vec![0.7])
                .accels(vec![AccelModel::ipsec_32g()])
                .seeds(vec![1])
        };
        let legacy = base().expand();
        let churned = base()
            .churn(vec![Churn::Static, Churn::Arrivals, Churn::Departures])
            .expand();
        assert_eq!(legacy.len(), 1);
        assert_eq!(churned.len(), 3);
        // The static cell keeps the legacy label, seed, and (empty)
        // lifecycle; churned cells get distinct labels and schedules.
        assert_eq!(churned[0].key.label(), legacy[0].key.label());
        assert_eq!(churned[0].spec.seed, legacy[0].spec.seed);
        assert!(churned[0].spec.lifecycle.is_empty());
        assert!(churned[1].key.label().contains("/arrivals/"));
        assert!(!churned[1].spec.lifecycle.is_empty());
        assert_ne!(churned[1].spec.seed, legacy[0].spec.seed);
        let labels: HashSet<String> = churned.iter().map(|s| s.key.label()).collect();
        assert_eq!(labels.len(), 3);
    }

    #[test]
    fn single_host_labels_and_seeds_unchanged_by_hosts_axis() {
        let base = || {
            SweepGrid::new(GridBase::default())
                .modes(vec![Mode::Arcus])
                .tenants(vec![2])
                .mixes(vec![SizeMix::Mtu])
                .bursts(vec![Burstiness::Paced])
                .tightness(vec![0.7])
                .accels(vec![AccelModel::ipsec_32g()])
                .seeds(vec![1])
        };
        let legacy = base().expand();
        let fleet = base().hosts(vec![1, 2, 4]).expand();
        assert_eq!(legacy.len(), 1);
        assert_eq!(fleet.len(), 3);
        // The single-host cell keeps the legacy label and seed — its spec
        // (and therefore its report) is byte-identical to a pre-fleet grid.
        assert_eq!(fleet[0].key.label(), legacy[0].key.label());
        assert_eq!(fleet[0].spec.seed, legacy[0].spec.seed);
        assert!(fleet[1].key.label().contains("/h2/"), "{}", fleet[1].key.label());
        assert!(fleet[2].key.label().contains("/h4/"), "{}", fleet[2].key.label());
        assert_ne!(fleet[1].spec.seed, legacy[0].spec.seed);
        let labels: HashSet<String> = fleet.iter().map(|s| s.key.label()).collect();
        assert_eq!(labels.len(), 3);
    }

    #[test]
    fn hosts_axis_validation() {
        let base = || {
            SweepGrid::new(GridBase::default())
                .modes(vec![Mode::Arcus])
                .tenants(vec![2])
                .mixes(vec![SizeMix::Mtu])
                .bursts(vec![Burstiness::Paced])
                .tightness(vec![0.7])
                .accels(vec![AccelModel::ipsec_32g()])
                .seeds(vec![1])
        };
        assert!(base().hosts(vec![1, 2]).validate().is_ok());
        let err = base().hosts(vec![0]).validate().unwrap_err();
        assert!(err.contains("host counts"), "{err}");
        let err = base().hosts(vec![128]).validate().unwrap_err();
        assert!(err.contains("ceiling"), "{err}");
    }

    #[test]
    fn churn_events_shapes() {
        use crate::system::LifecycleEvent;
        let d = 10 * MILLIS;
        let slo = Rate::gbps(5.0);
        assert!(churn_events(Churn::Static, 4, d, slo).is_empty());
        // Arrivals: later half, staggered, inside the run.
        let ev = churn_events(Churn::Arrivals, 4, d, slo);
        assert_eq!(ev.len(), 2);
        assert!(matches!(ev[0], LifecycleEvent::Arrive { flow: 2, .. }));
        assert!(matches!(ev[1], LifecycleEvent::Arrive { flow: 3, .. }));
        assert!(ev.iter().all(|e| e.at() > 0 && e.at() < d));
        // Departures: earlier half.
        let ev = churn_events(Churn::Departures, 4, d, slo);
        assert!(matches!(ev[0], LifecycleEvent::Depart { flow: 0, .. }));
        // Renegotiation raises tenant 0's target by 25%.
        let ev = churn_events(Churn::Renegotiation, 4, d, slo);
        match ev[..] {
            [LifecycleEvent::Renegotiate { flow: 0, slo: Slo::Throughput { target, .. }, .. }] => {
                assert!((target.0 - slo.0 * 1.25).abs() < 1.0);
            }
            _ => panic!("unexpected renegotiation events: {ev:?}"),
        }
        // Mixed degrades gracefully with the roster size.
        assert_eq!(churn_events(Churn::Mixed, 1, d, slo).len(), 1);
        assert_eq!(churn_events(Churn::Mixed, 2, d, slo).len(), 2);
        assert_eq!(churn_events(Churn::Mixed, 3, d, slo).len(), 3);
        // A single tenant still produces one event for arrivals/departures.
        assert_eq!(churn_events(Churn::Arrivals, 1, d, slo).len(), 1);
        assert_eq!(churn_events(Churn::Departures, 1, d, slo).len(), 1);
        // Every event lands strictly inside the run at any roster size —
        // events past `duration` would silently never fire.
        for t in [1usize, 2, 7, 28, 100] {
            for c in Churn::ALL {
                for e in churn_events(c, t, d, slo) {
                    assert!(
                        e.at() < d,
                        "{c:?} t={t}: event at {} outside run of {d}",
                        e.at()
                    );
                }
            }
        }
    }

    #[test]
    fn fault_profile_roundtrip_and_parse_errors_list_menu() {
        for f in FaultProfile::ALL {
            assert_eq!(FaultProfile::by_name(f.name()), Some(f));
            assert_eq!(FaultProfile::parse(f.name()), Ok(f));
        }
        let err = FaultProfile::parse("meteor").unwrap_err();
        for f in FaultProfile::ALL {
            assert!(err.contains(f.name()), "{err} missing {}", f.name());
        }
    }

    #[test]
    fn fault_events_shapes() {
        use crate::faults::FaultKind;
        let d = 10 * MILLIS;
        assert!(fault_events(FaultProfile::Healthy, 4, d).is_empty());
        let ev = fault_events(FaultProfile::AccelDip, 4, d);
        assert_eq!(ev.len(), 1);
        assert!(matches!(ev[0].kind, FaultKind::AccelSlowdown { unit: 0, .. }));
        assert_eq!((ev[0].at, ev[0].until), (4 * MILLIS, 7 * MILLIS));
        // Rogue targets the last tenant.
        let ev = fault_events(FaultProfile::Rogue, 4, d);
        assert!(matches!(ev[0].kind, FaultKind::RogueTenant { flow: 3 }));
        // A flap is a deep, short link cut.
        let ev = fault_events(FaultProfile::Flap, 2, d);
        assert!(matches!(ev[0].kind, FaultKind::LinkDegrade { factor } if factor < 0.2));
        assert!(ev[0].until - ev[0].at < d / 10);
        // Every profile's windows live inside the run at any tenant count.
        for t in [1usize, 2, 7, 100] {
            for p in FaultProfile::ALL {
                for f in fault_events(p, t, d) {
                    assert!(f.at < f.until && f.until <= d, "{p:?} t={t}: {f:?}");
                }
            }
        }
    }

    #[test]
    fn healthy_labels_and_seeds_unchanged_by_faults_axis() {
        let base = || {
            SweepGrid::new(GridBase::default())
                .modes(vec![Mode::Arcus])
                .tenants(vec![2])
                .mixes(vec![SizeMix::Mtu])
                .bursts(vec![Burstiness::Paced])
                .tightness(vec![0.7])
                .accels(vec![AccelModel::ipsec_32g()])
                .seeds(vec![1])
        };
        let legacy = base().expand();
        let faulted = base()
            .faults(vec![FaultProfile::Healthy, FaultProfile::AccelDip, FaultProfile::Rogue])
            .expand();
        assert_eq!(legacy.len(), 1);
        assert_eq!(faulted.len(), 3);
        assert_eq!(faulted[0].key.label(), legacy[0].key.label());
        assert_eq!(faulted[0].spec.seed, legacy[0].spec.seed);
        assert!(faulted[0].spec.faults.is_empty());
        assert!(faulted[1].key.label().contains("/accel_dip/"));
        assert!(!faulted[1].spec.faults.is_empty());
        assert_ne!(faulted[1].spec.seed, legacy[0].spec.seed);
        let labels: HashSet<String> = faulted.iter().map(|s| s.key.label()).collect();
        assert_eq!(labels.len(), 3);
        // Churn and fault segments compose in one label.
        let both = base()
            .churn(vec![Churn::Arrivals])
            .faults(vec![FaultProfile::LinkCut])
            .expand();
        assert!(both[0].key.label().contains("/arrivals/link_cut/"));
    }

    #[test]
    fn flat_labels_and_seeds_unchanged_by_scale_axis() {
        let base = || {
            SweepGrid::new(GridBase::default())
                .modes(vec![Mode::Arcus])
                .tenants(vec![2])
                .mixes(vec![SizeMix::Mtu])
                .bursts(vec![Burstiness::Paced])
                .tightness(vec![0.7])
                .accels(vec![AccelModel::ipsec_32g()])
                .seeds(vec![1])
        };
        let legacy = base().expand();
        let scaled = base()
            .scale(vec![Scale::Flat, Scale::Flows(16), Scale::Flows(256)])
            .expand();
        assert_eq!(legacy.len(), 1);
        assert_eq!(scaled.len(), 3);
        // The flat cell keeps the legacy label, seed, roster, and flat
        // shaping; scaled cells grow the roster and run the hierarchy.
        assert_eq!(scaled[0].key.label(), legacy[0].key.label());
        assert_eq!(scaled[0].spec.seed, legacy[0].spec.seed);
        assert_eq!(scaled[0].spec.flows.len(), 2);
        assert!(!scaled[0].spec.hierarchy);
        assert!(scaled[1].key.label().contains("/f16/"));
        assert_eq!(scaled[1].spec.flows.len(), 16);
        assert!(scaled[1].spec.hierarchy);
        assert_eq!(scaled[2].spec.flows.len(), 256);
        // Flows spread round-robin across the tenant VMs; the committed
        // sum stays tightness × capacity regardless of scale.
        let vms: HashSet<usize> = scaled[2].spec.flows.iter().map(|f| f.vm).collect();
        assert_eq!(vms.len(), 2);
        let total = |s: &super::Scenario| -> f64 {
            s.spec
                .flows
                .iter()
                .map(|f| match f.slo {
                    Slo::Throughput { target, .. } => target.0,
                    _ => 0.0,
                })
                .sum()
        };
        let t_flat = total(&scaled[0]);
        let t_scaled = total(&scaled[2]);
        assert!((t_flat - t_scaled).abs() / t_flat < 1e-9);
        let labels: HashSet<String> = scaled.iter().map(|s| s.key.label()).collect();
        assert_eq!(labels.len(), 3);
    }

    #[test]
    fn static_labels_and_seeds_unchanged_by_control_axis() {
        let base = || {
            SweepGrid::new(GridBase::default())
                .modes(vec![Mode::Arcus])
                .tenants(vec![2])
                .mixes(vec![SizeMix::Mtu])
                .bursts(vec![Burstiness::Paced])
                .tightness(vec![0.7])
                .accels(vec![AccelModel::ipsec_32g()])
                .seeds(vec![1])
        };
        let legacy = base().expand();
        let swept = base()
            .control(vec![ControlKind::Static, ControlKind::Adaptive])
            .expand();
        assert_eq!(legacy.len(), 1);
        assert_eq!(swept.len(), 2);
        // The static-control cell keeps the legacy label, seed, and (no)
        // adaptive config; the adaptive cell gets the wrapper + a distinct
        // label and seed.
        assert_eq!(swept[0].key.label(), legacy[0].key.label());
        assert_eq!(swept[0].spec.seed, legacy[0].spec.seed);
        assert!(swept[0].spec.adaptive.is_none());
        assert!(swept[1].key.label().contains("/adaptive/"));
        assert!(swept[1].spec.adaptive.is_some());
        assert_ne!(swept[1].spec.seed, legacy[0].spec.seed);
        // Faults and control segments compose in one label.
        let both = base()
            .faults(vec![FaultProfile::AccelDip])
            .control(vec![ControlKind::Adaptive])
            .expand();
        assert!(both[0].key.label().contains("/accel_dip/adaptive/"));
        // Round-trip the axis vocabulary.
        for c in ControlKind::ALL {
            assert_eq!(ControlKind::by_name(c.name()), Some(c));
            assert_eq!(ControlKind::parse(c.name()), Ok(c));
        }
        let err = ControlKind::parse("manual").unwrap_err();
        assert!(err.contains("static") && err.contains("adaptive"), "{err}");
    }

    #[test]
    fn pattern_labels_and_seeds_unchanged_by_population_axis() {
        let base = || {
            SweepGrid::new(GridBase::default())
                .modes(vec![Mode::Arcus])
                .tenants(vec![2])
                .mixes(vec![SizeMix::Mtu])
                .bursts(vec![Burstiness::Paced])
                .tightness(vec![0.7])
                .accels(vec![AccelModel::ipsec_32g()])
                .seeds(vec![1])
        };
        let legacy = base().expand();
        let peopled = base().population(vec![None, Some(5000)]).expand();
        assert_eq!(legacy.len(), 1);
        assert_eq!(peopled.len(), 2);
        // The None cell keeps the legacy label, seed, and (no) population
        // config — its report stays byte-identical to pre-axis grids.
        assert_eq!(peopled[0].key.label(), legacy[0].key.label());
        assert_eq!(peopled[0].spec.seed, legacy[0].spec.seed);
        assert!(peopled[0].spec.population.is_none());
        // The Some cell gets a distinct label segment, a distinct seed, and
        // a default-shaped config at the requested population.
        assert!(peopled[1].key.label().contains("/u5000/"), "{}", peopled[1].key.label());
        assert_ne!(peopled[1].spec.seed, legacy[0].spec.seed);
        let cfg = peopled[1].spec.population.as_ref().expect("population cell carries a config");
        assert_eq!(cfg.users, 5000);
        assert_eq!(cfg.zipf_s, PopulationConfig::default().zipf_s);
    }

    #[test]
    fn population_axis_validation() {
        let base = || {
            SweepGrid::new(GridBase::default())
                .modes(vec![Mode::Arcus])
                .tenants(vec![2])
                .mixes(vec![SizeMix::Mtu])
                .bursts(vec![Burstiness::Paced])
                .tightness(vec![0.7])
                .accels(vec![AccelModel::ipsec_32g()])
                .seeds(vec![1])
        };
        // population × scale: fewer users than flows is rejected up front,
        // naming the offending cell.
        let err = base()
            .scale(vec![Scale::Flows(16)])
            .population(vec![Some(8)])
            .validate()
            .unwrap_err();
        assert!(err.contains("u8") && err.contains("16 flows"), "{err}");
        assert!(base()
            .scale(vec![Scale::Flows(16)])
            .population(vec![Some(100)])
            .validate()
            .is_ok());
        // population × hosts>1: per-user accounting is single-world.
        let err = base().hosts(vec![1, 2]).population(vec![Some(100)]).validate().unwrap_err();
        assert!(err.contains("single-world"), "{err}");
        // A None population never constrains the other axes.
        assert!(base().hosts(vec![1, 2]).population(vec![None]).validate().is_ok());
        // Out-of-range populations reuse the config validator's complaint.
        let err = base().population(vec![Some(100_000_000)]).validate().unwrap_err();
        assert!(err.contains("users"), "{err}");
    }

    #[test]
    fn population_composes_with_churn_and_faults() {
        let base = || {
            SweepGrid::new(GridBase::default())
                .modes(vec![Mode::Arcus])
                .tenants(vec![2])
                .mixes(vec![SizeMix::Mtu])
                .bursts(vec![Burstiness::Paced])
                .tightness(vec![0.7])
                .accels(vec![AccelModel::ipsec_32g()])
                .seeds(vec![1])
        };
        // population × churn: tenant lifecycle is deterministic either way;
        // the cell is allowed and carries both schedules.
        let grid = base().churn(vec![Churn::Arrivals]).population(vec![Some(5000)]);
        assert!(grid.validate().is_ok());
        let cell = &grid.expand()[0];
        assert!(cell.key.label().contains("/arrivals/"), "{}", cell.key.label());
        assert!(cell.key.label().contains("/u5000/"), "{}", cell.key.label());
        assert!(!cell.spec.lifecycle.is_empty());
        assert!(cell.spec.population.is_some());
        // population × faults: a flash-crowd epoch overlapping a fault
        // window is exactly the scenario the axis exists for — allowed,
        // and the label carries both segments.
        let grid = base().faults(vec![FaultProfile::LinkCut]).population(vec![Some(5000)]);
        assert!(grid.validate().is_ok());
        let cell = &grid.expand()[0];
        assert!(cell.key.label().contains("/link_cut/"), "{}", cell.key.label());
        assert!(cell.key.label().contains("/u5000/"), "{}", cell.key.label());
        assert!(!cell.spec.faults.is_empty());
        assert!(cell.spec.population.is_some());
    }

    #[test]
    fn scale_parse_and_validate() {
        assert_eq!(Scale::parse("flat"), Ok(Scale::Flat));
        assert_eq!(Scale::parse("256"), Ok(Scale::Flows(256)));
        assert_eq!(Scale::parse("4k"), Ok(Scale::Flows(4000)));
        assert_eq!(Scale::parse("10k"), Ok(Scale::Flows(10_000)));
        assert!(Scale::parse("big").is_err());
        assert!(Scale::parse("0").is_err());
        // A scale smaller than the tenant roster is rejected up front.
        let grid = grid_with_lens(&[1, 2, 1, 1, 1, 1, 1]).scale(vec![Scale::Flows(1)]);
        let grid = SweepGrid { tenants: vec![4], ..grid };
        let err = grid.validate().unwrap_err();
        assert!(err.contains("tenant roster"), "{err}");
    }

    #[test]
    fn validate_rejects_departure_racing_rogue_fault() {
        // At one tenant, `departures` retires flow 0 at 50% of the run —
        // inside the rogue window [40%, 70%) targeting the same flow.
        let grid = grid_with_lens(&[1, 1, 1, 1, 1, 1, 1])
            .churn(vec![Churn::Departures])
            .faults(vec![FaultProfile::Rogue]);
        let err = grid.validate().unwrap_err();
        assert!(err.contains("race"), "{err}");
        assert!(err.contains("departs tenant 0"), "{err}");
        // The same axes at 4 tenants don't race (rogue targets tenant 3,
        // departures retire tenants 0–1).
        let grid = grid_with_lens(&[1, 2, 1, 1, 1, 1, 1])
            .churn(vec![Churn::Departures])
            .faults(vec![FaultProfile::Rogue]);
        let grid = SweepGrid { tenants: vec![4], ..grid };
        assert!(grid.validate().is_ok());
        // Healthy × departures at 1 tenant is fine (no fault to race).
        let grid = grid_with_lens(&[1, 1, 1, 1, 1, 1, 1]).churn(vec![Churn::Departures]);
        assert!(grid.validate().is_ok());
    }

    #[test]
    fn validate_rejects_degenerate_grids() {
        let good = grid_with_lens(&[1, 1, 1, 1, 1, 1, 1]);
        assert!(good.validate().is_ok());
        // Warmup >= duration is the classic deep-runner panic; it must be
        // caught at grid-build time with an actionable message.
        let mut bad = grid_with_lens(&[1, 1, 1, 1, 1, 1, 1]);
        bad.base.warmup = bad.base.duration;
        let err = bad.validate().unwrap_err();
        assert!(err.contains("warmup"), "{err}");
        let mut bad = grid_with_lens(&[1, 1, 1, 1, 1, 1, 1]);
        bad.base.duration = 0;
        assert!(bad.validate().is_err());
        let mut bad = grid_with_lens(&[1, 1, 1, 1, 1, 1, 1]);
        bad.tenants = vec![0];
        assert!(bad.validate().is_err());
        let mut bad = grid_with_lens(&[1, 1, 1, 1, 1, 1, 1]);
        bad.tightness = vec![-0.5];
        assert!(bad.validate().is_err());
        let mut bad = grid_with_lens(&[1, 1, 1, 1, 1, 1, 1]);
        bad.base.load = 0.0;
        assert!(bad.validate().is_err());
    }
}
