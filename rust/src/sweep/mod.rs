//! Scenario-sweep engine: grid expansion → parallel execution → per-axis
//! aggregation.
//!
//! The paper's evaluation is a family of grids — traffic mixtures × tenant
//! counts × management architectures, each point a full multi-tenant
//! experiment (§5, Figs 3/6/7/8). This subsystem makes that methodology a
//! library:
//!
//! - [`grid`] — [`SweepGrid`] expands one [`GridBase`] template over
//!   thirteen axes (tenant count, [`crate::system::Mode`], burstiness,
//!   message-size mix, SLO tightness, tenant churn, fault injection,
//!   flow-population scale, user-population size (the
//!   [`crate::workload::PopulationConfig`] generator vs the legacy
//!   per-flow patterns), control loop, host count, accelerator model,
//!   seed) into a deterministic scenario list; [`SizeMix`] is the shared message-size
//!   vocabulary, [`Churn`] the tenant-lifecycle one, [`FaultProfile`] the
//!   fault-injection one, [`Scale`] the flow-count one (non-flat cells run
//!   the [`crate::shaping::ShaperTree`] hierarchy), and [`ControlKind`]
//!   the static-vs-adaptive control-loop one.
//! - [`runner`] — [`SweepRunner`] executes scenarios across `std::thread`
//!   workers; each simulation stays single-threaded and deterministic
//!   (seeded per scenario), so threading never changes a result.
//! - [`aggregate`] — folds the resulting [`crate::system::SystemReport`]s
//!   into per-axis comparison tables of the paper's headline metrics
//!   (worst-flow SLO attainment, p99/p99.9 tails, goodput, throughput
//!   variance), with byte-identical rendering across runs.
//!
//! Entry points: `arcus sweep` on the CLI, [`SweepRunner::run`] from code,
//! and [`run_specs`] / [`run_parallel`] as the substrate the paper-figure
//! benches fan out on.

pub mod aggregate;
pub mod grid;
pub mod runner;

pub use aggregate::{aggregate, AxisStats, AxisTable, ScenarioSummary, SweepAggregate};
pub use grid::{
    burst_name, churn_events, fault_events, parse_burst, scenario_seed, Churn, ControlKind,
    FaultProfile, GridBase, Scale, Scenario, ScenarioKey, SizeMix, SweepGrid,
};
pub use runner::{default_threads, run_parallel, run_specs, ScenarioOutcome, SweepRunner};
