//! Aggregation: fold scenario reports into per-axis comparison tables.
//!
//! Every executed scenario is first reduced to a [`ScenarioSummary`] of the
//! paper's headline metrics — worst-flow SLO attainment, p99/p99.9 latency
//! tails, aggregate goodput, windowed-throughput variance (Fig 6/7's
//! metrics) — and then grouped along each grid axis into an [`AxisTable`]
//! (e.g. "attainment by management mode", "p99 by tenant count").
//!
//! Determinism contract: summaries use only deterministic report fields
//! (never wall-clock accounting), grouping is ordered by formatted axis
//! value, and accumulation visits scenarios in expansion order — so
//! [`SweepAggregate::render`] is byte-identical across runs of the same
//! grid, regardless of worker-thread interleaving. Tests assert exactly
//! that.

use std::collections::BTreeMap;

use crate::flow::Slo;
use crate::metrics::Histogram;
use crate::util::units::MICROS;

use super::grid::{burst_name, ScenarioKey};
use super::runner::ScenarioOutcome;

/// One scenario reduced to headline metrics.
#[derive(Debug, Clone)]
pub struct ScenarioSummary {
    pub key: ScenarioKey,
    /// Worst committed-flow attainment (achieved / SLO); 0 when no
    /// committed flow survived admission.
    pub attainment_min: f64,
    /// Mean committed-flow attainment.
    pub attainment_mean: f64,
    /// Worst flow p99 latency, µs.
    pub p99_us: f64,
    /// Worst flow p99.9 latency, µs.
    pub p999_us: f64,
    /// Aggregate goodput, Gbps.
    pub goodput_gbps: f64,
    /// Worst flow windowed-throughput coefficient of variation, %.
    pub cv_pct: f64,
    /// Messages dropped (queue overflow / RX-buffer loss) post-warmup.
    pub dropped: u64,
    /// Flows rejected by admission control.
    pub rejected: usize,
    /// Worst committed-flow attainment *during the fault era* (fault-
    /// injection scenarios only).
    pub fault_att_min: Option<f64>,
    /// Worst committed-flow worst-era p99 latency, µs (the adaptive-vs-
    /// static headline: max over flows of max over pre/during/post eras).
    pub fault_p99_us: Option<f64>,
    /// Slowest committed-flow recovery after the fault window, µs.
    /// `None` when the scenario is healthy or a flow never recovered
    /// inside the run (the distinction is carried by `unrecovered`).
    pub recovery_us_max: Option<f64>,
    /// Committed flows that never got back to their SLO inside the run.
    pub unrecovered: usize,
}

/// Reduce one outcome to its summary.
pub fn summarize(outcome: &ScenarioOutcome) -> ScenarioSummary {
    let r = &outcome.report;
    let mut att = Vec::new();
    let mut rejected = 0usize;
    for f in &r.per_flow {
        if f.rejected {
            rejected += 1;
            continue;
        }
        if matches!(f.slo, Slo::BestEffort) {
            continue;
        }
        if let Some(a) = f.slo_attainment() {
            att.push(a);
        }
    }
    let attainment_min = att.iter().copied().fold(f64::INFINITY, f64::min);
    let attainment_mean = if att.is_empty() {
        0.0
    } else {
        att.iter().sum::<f64>() / att.len() as f64
    };
    let live = r.per_flow.iter().filter(|f| !f.rejected);
    let p99_us = live
        .clone()
        .map(|f| f.lat_p99)
        .max()
        .unwrap_or(0) as f64
        / MICROS as f64;
    let p999_us = live
        .clone()
        .map(|f| f.lat_p999)
        .max()
        .unwrap_or(0) as f64
        / MICROS as f64;
    let cv_pct = live
        .clone()
        .map(|f| f.sampler.cv() * 100.0)
        .fold(0.0f64, f64::max);
    // Fault-era metrics: the during-era floor and the slowest recovery over
    // committed flows (see crate::faults).
    let mut fault_att_min: Option<f64> = None;
    let mut fault_p99_us: Option<f64> = None;
    let mut recovery_us_max: Option<f64> = None;
    let mut unrecovered = 0usize;
    if r.fault_window.is_some() {
        for f in r.per_flow.iter().filter(|f| !f.rejected) {
            if matches!(f.slo, Slo::BestEffort) {
                continue;
            }
            let Some(fr) = &f.fault else { continue };
            if let Some(a) = fr.during.attainment {
                fault_att_min = Some(fault_att_min.map_or(a, |m: f64| m.min(a)));
            }
            let p99 = fr.worst_era_p99() as f64 / MICROS as f64;
            fault_p99_us = Some(fault_p99_us.map_or(p99, |m: f64| m.max(p99)));
            match fr.recovery_time {
                Some(t) => {
                    let us = t as f64 / MICROS as f64;
                    recovery_us_max = Some(recovery_us_max.map_or(us, |m: f64| m.max(us)));
                }
                // Departed flows have nothing to recover, latency-SLO
                // flows have no rate target to recover to, and a fault
                // that ran to the end of the run (zero post-fault span)
                // left no room to recover in; every other flow genuinely
                // failed to get back to SLO inside the run.
                None if f.departed_at.is_none()
                    && f.slo.required_rate().is_some()
                    && fr.post.span > 0 =>
                {
                    unrecovered += 1
                }
                None => {}
            }
        }
    }
    ScenarioSummary {
        key: outcome.key.clone(),
        attainment_min: if attainment_min.is_finite() { attainment_min } else { 0.0 },
        attainment_mean,
        p99_us,
        p999_us,
        goodput_gbps: r.total_goodput().as_gbps(),
        cv_pct,
        dropped: r.per_flow.iter().map(|f| f.dropped).sum(),
        rejected,
        fault_att_min,
        fault_p99_us,
        recovery_us_max,
        unrecovered,
    }
}

/// Aggregated statistics for one axis value.
#[derive(Debug, Clone, Default)]
pub struct AxisStats {
    pub scenarios: usize,
    /// Mean over scenarios of the worst-flow attainment.
    pub attainment_mean: f64,
    /// Worst attainment seen in any scenario of this group.
    pub attainment_worst: f64,
    pub p99_us_mean: f64,
    pub p999_us_mean: f64,
    pub goodput_gbps_mean: f64,
    pub cv_pct_mean: f64,
    pub dropped_total: u64,
    pub rejected_total: usize,
    /// Mean fault-era attainment floor over the group's *faulted*
    /// scenarios (`None` when the group is entirely healthy).
    pub fault_att_mean: Option<f64>,
    /// Mean worst-era p99 (µs) over faulted scenarios.
    pub fault_p99_mean: Option<f64>,
    /// Mean slowest-recovery time (µs) over faulted scenarios that
    /// recovered.
    pub recovery_us_mean: Option<f64>,
    /// Flows across the group that never re-attained their SLO post-fault.
    pub unrecovered_total: usize,
}

impl AxisStats {
    fn fold(group: &[&ScenarioSummary]) -> AxisStats {
        let n = group.len().max(1) as f64;
        let mean_of = |vals: Vec<f64>| {
            if vals.is_empty() {
                None
            } else {
                Some(vals.iter().sum::<f64>() / vals.len() as f64)
            }
        };
        AxisStats {
            scenarios: group.len(),
            attainment_mean: group.iter().map(|s| s.attainment_min).sum::<f64>() / n,
            attainment_worst: group
                .iter()
                .map(|s| s.attainment_min)
                .fold(f64::INFINITY, f64::min)
                .min(f64::MAX),
            p99_us_mean: group.iter().map(|s| s.p99_us).sum::<f64>() / n,
            p999_us_mean: group.iter().map(|s| s.p999_us).sum::<f64>() / n,
            goodput_gbps_mean: group.iter().map(|s| s.goodput_gbps).sum::<f64>() / n,
            cv_pct_mean: group.iter().map(|s| s.cv_pct).sum::<f64>() / n,
            dropped_total: group.iter().map(|s| s.dropped).sum(),
            rejected_total: group.iter().map(|s| s.rejected).sum(),
            fault_att_mean: mean_of(group.iter().filter_map(|s| s.fault_att_min).collect()),
            fault_p99_mean: mean_of(group.iter().filter_map(|s| s.fault_p99_us).collect()),
            recovery_us_mean: mean_of(
                group.iter().filter_map(|s| s.recovery_us_max).collect(),
            ),
            unrecovered_total: group.iter().map(|s| s.unrecovered).sum(),
        }
    }
}

/// One axis's comparison table, rows ordered by formatted axis value.
#[derive(Debug, Clone)]
pub struct AxisTable {
    /// Axis name (`mode`, `tenants`, `mix`, `burst`, `tightness`, `churn`,
    /// `faults`, `scale`, `control`, `accel`, `seed`).
    pub axis: &'static str,
    pub rows: Vec<(String, AxisStats)>,
}

/// The full aggregate: per-scenario summaries plus per-axis tables.
#[derive(Debug, Clone)]
pub struct SweepAggregate {
    /// Summaries in grid expansion order.
    pub scenarios: Vec<ScenarioSummary>,
    pub axes: Vec<AxisTable>,
    /// Completion-latency histogram pooled across every scenario: each
    /// report's per-engine observability histograms, merged in grid
    /// expansion order. Histogram merge is commutative and associative
    /// (property-tested), so this fold is independent of worker-thread
    /// interleaving by construction — but the fixed order makes the
    /// determinism unconditional.
    pub pooled_lat: Histogram,
}

/// Axis label formatters. Numeric labels are zero-padded / fixed-precision
/// so lexicographic BTreeMap order equals numeric order.
fn axis_value(axis: &str, key: &ScenarioKey) -> String {
    match axis {
        "mode" => key.mode.name().to_string(),
        "tenants" => format!("t{:04}", key.tenants),
        "mix" => key.mix.name().to_string(),
        "burst" => burst_name(key.burst),
        // Zero-padded integer part keeps lexicographic == numeric order up
        // to 9999; four decimals keep close CLI-supplied values distinct.
        "tightness" => format!("x{:09.4}", key.tightness),
        "churn" => key.churn.name().to_string(),
        "faults" => key.faults.name().to_string(),
        // Flow-count labels pad to five digits (the 10k-scale axis).
        "scale" => match key.scale {
            crate::sweep::Scale::Flat => "flat".to_string(),
            crate::sweep::Scale::Flows(n) => format!("f{n:05}"),
        },
        "control" => key.control.name().to_string(),
        // Two digits cover the 64-host ceiling enforced by grid validation.
        "hosts" => format!("h{:02}", key.hosts),
        "accel" => key.accel.to_string(),
        "seed" => format!("s{:020}", key.seed),
        other => unreachable!("unknown axis {other}"),
    }
}

const AXES: [&str; 12] = [
    "mode", "tenants", "mix", "burst", "tightness", "churn", "faults", "scale", "control",
    "hosts", "accel", "seed",
];

/// Fold executed scenarios into the aggregate.
pub fn aggregate(outcomes: &[ScenarioOutcome]) -> SweepAggregate {
    let scenarios: Vec<ScenarioSummary> = outcomes.iter().map(summarize).collect();
    let mut pooled_lat = Histogram::new();
    for o in outcomes {
        for e in &o.report.obs.engines {
            pooled_lat.merge(&e.lat);
        }
    }
    let mut axes = Vec::new();
    for axis in AXES {
        let mut groups: BTreeMap<String, Vec<&ScenarioSummary>> = BTreeMap::new();
        for s in &scenarios {
            groups.entry(axis_value(axis, &s.key)).or_default().push(s);
        }
        // Single-valued axes carry no comparison; keep them only when the
        // grid actually sweeps them (or the grid is empty).
        if groups.len() <= 1 {
            continue;
        }
        axes.push(AxisTable {
            axis,
            rows: groups
                .into_iter()
                .map(|(value, group)| (value, AxisStats::fold(&group)))
                .collect(),
        });
    }
    SweepAggregate {
        scenarios,
        axes,
        pooled_lat,
    }
}

impl SweepAggregate {
    /// Render the per-axis comparison tables. Byte-identical across runs
    /// of the same grid (see module docs).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "sweep aggregate: {} scenarios, {} swept axes\n",
            self.scenarios.len(),
            self.axes.len()
        ));
        if !self.pooled_lat.is_empty() {
            let us = |p: f64| self.pooled_lat.percentile(p) as f64 / MICROS as f64;
            out.push_str(&format!(
                "pooled latency (merged engine histograms, {} completions): \
                 p50={:.2}us p99={:.2}us p999={:.2}us\n",
                self.pooled_lat.count(),
                us(50.0),
                us(99.0),
                us(99.9)
            ));
        }
        let opt = |v: Option<f64>, prec: usize| match v {
            Some(x) => format!("{x:.prec$}"),
            None => "-".to_string(),
        };
        for table in &self.axes {
            out.push_str(&format!("\n[by {}]\n", table.axis));
            out.push_str(&format!(
                "{:<22} {:>5} {:>9} {:>9} {:>10} {:>10} {:>9} {:>7} {:>6} {:>5} {:>8} {:>9} {:>9} {:>6}\n",
                "value", "n", "att.mean", "att.min", "p99(us)", "p999(us)", "Gbps", "cv%",
                "drop", "rej", "f.att", "f.p99", "rec(us)", "unrec"
            ));
            for (value, s) in &table.rows {
                out.push_str(&format!(
                    "{:<22} {:>5} {:>9.3} {:>9.3} {:>10.2} {:>10.2} {:>9.2} {:>7.2} {:>6} {:>5} {:>8} {:>9} {:>9} {:>6}\n",
                    value,
                    s.scenarios,
                    s.attainment_mean,
                    s.attainment_worst,
                    s.p99_us_mean,
                    s.p999_us_mean,
                    s.goodput_gbps_mean,
                    s.cv_pct_mean,
                    s.dropped_total,
                    s.rejected_total,
                    opt(s.fault_att_mean, 3),
                    opt(s.fault_p99_mean, 2),
                    opt(s.recovery_us_mean, 1),
                    s.unrecovered_total
                ));
            }
        }
        out
    }

    /// Render every scenario row (the long-form report).
    pub fn render_scenarios(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<44} {:>9} {:>10} {:>9} {:>7} {:>6} {:>5}\n",
            "scenario", "att.min", "p99(us)", "Gbps", "cv%", "drop", "rej"
        ));
        for s in &self.scenarios {
            out.push_str(&format!(
                "{:<44} {:>9.3} {:>10.2} {:>9.2} {:>7.2} {:>6} {:>5}\n",
                s.key.label(),
                s.attainment_min,
                s.p99_us,
                s.goodput_gbps,
                s.cv_pct,
                s.dropped,
                s.rejected
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::pattern::Burstiness;
    use crate::metrics::{FlowMetrics, ThroughputSampler};
    use crate::system::{FlowReport, Mode, SystemReport};
    use crate::sweep::grid::SizeMix;
    use crate::util::units::Rate;

    fn outcome(index: usize, mode: Mode, tenants: usize, goodput_gbps: f64) -> ScenarioOutcome {
        let key = ScenarioKey {
            mode,
            tenants,
            mix: SizeMix::Mtu,
            burst: Burstiness::Paced,
            tightness: 0.7,
            churn: crate::sweep::Churn::Static,
            faults: crate::sweep::FaultProfile::Healthy,
            scale: crate::sweep::Scale::Flat,
            control: crate::sweep::ControlKind::Static,
            hosts: 1,
            population: None,
            accel: "ipsec",
            seed: 1,
        };
        let mut metrics = FlowMetrics::new();
        // Synthesize a goodput: N bytes over 1 ms.
        let bytes = (goodput_gbps * 1e9 / 8.0 * 1e-3) as u64;
        metrics.on_complete(0, 0, 0);
        metrics.on_complete(crate::util::units::MILLIS, 0, bytes);
        let per_flow = vec![FlowReport::from_metrics(
            0,
            0,
            crate::flow::Slo::gbps(goodput_gbps),
            false,
            &metrics,
            ThroughputSampler::new(500),
            0,
            Vec::new(),
        )];
        ScenarioOutcome {
            index,
            key,
            report: SystemReport {
                mode: mode.name(),
                per_flow,
                measured_span: crate::util::units::MILLIS,
                pcie_up_util: 0.0,
                pcie_down_util: 0.0,
                accel_util: vec![0.5],
                nic_rx_dropped: 0,
                fault_window: None,
                directive_lag_max: 0,
                directive_staleness_max: 0,
                host_rollups: Vec::new(),
                events: 10,
                peak_queue_depth: 4,
                queue: "binary_heap",
                wall_secs: 0.001,
                series_digest: 0,
                obs: Default::default(),
                fairness: None,
            },
        }
    }

    #[test]
    fn groups_by_swept_axes_only() {
        let outcomes = vec![
            outcome(0, Mode::Arcus, 1, 10.0),
            outcome(1, Mode::Arcus, 2, 12.0),
            outcome(2, Mode::HostNoTs, 1, 14.0),
            outcome(3, Mode::HostNoTs, 2, 16.0),
        ];
        let agg = aggregate(&outcomes);
        assert_eq!(agg.scenarios.len(), 4);
        let axes: Vec<&str> = agg.axes.iter().map(|t| t.axis).collect();
        assert_eq!(axes, vec!["mode", "tenants"]);
        let mode_table = &agg.axes[0];
        assert_eq!(mode_table.rows.len(), 2);
        assert_eq!(mode_table.rows[0].0, "arcus");
        assert_eq!(mode_table.rows[0].1.scenarios, 2);
    }

    #[test]
    fn render_is_deterministic_and_excludes_wall_clock() {
        let mk = |wall: f64| {
            let mut o = vec![
                outcome(0, Mode::Arcus, 1, 10.0),
                outcome(1, Mode::HostNoTs, 1, 14.0),
            ];
            for x in &mut o {
                x.report.wall_secs = wall;
            }
            o
        };
        let a = aggregate(&mk(0.001)).render();
        let b = aggregate(&mk(9.999)).render();
        assert_eq!(a, b);
        assert!(a.contains("[by mode]"));
    }

    #[test]
    fn fault_metrics_summarized_and_rendered() {
        use crate::system::{EraReport, FaultReport};
        use crate::util::units::{MICROS, MILLIS};
        let mut o = outcome(0, Mode::Arcus, 1, 10.0);
        o.key.faults = crate::sweep::FaultProfile::AccelDip;
        o.report.fault_window = Some((MILLIS, 2 * MILLIS));
        let slo = crate::flow::Slo::gbps(10.0);
        let era = |gbps: f64| {
            EraReport::new((gbps * 1e9 / 8.0 * 1e-3) as u64, 100, MILLIS, 50_000, &slo)
        };
        o.report.per_flow[0].fault = Some(FaultReport {
            pre: era(10.0),
            during: era(4.0),
            post: era(10.0),
            recovery_time: Some(200 * MICROS),
        });
        let healthy = outcome(1, Mode::HostNoTs, 1, 10.0);
        let agg = aggregate(&[o, healthy]);
        let s = &agg.scenarios[0];
        assert!((s.fault_att_min.unwrap() - 0.4).abs() < 0.01, "{s:?}");
        // Era p99s are all 50_000 ps → the worst-era max is 0.05 µs.
        assert!((s.fault_p99_us.unwrap() - 0.05).abs() < 1e-9, "{s:?}");
        assert!((s.recovery_us_max.unwrap() - 200.0).abs() < 1e-9);
        assert_eq!(s.unrecovered, 0);
        assert_eq!(agg.scenarios[1].fault_att_min, None);
        assert_eq!(agg.scenarios[1].fault_p99_us, None);
        let rendered = agg.render();
        assert!(rendered.contains("f.att"));
        assert!(rendered.contains("f.p99"));
        assert!(rendered.contains("[by faults]"));
        // The healthy group renders dashes, not zeros.
        assert!(rendered.contains(" - "), "{rendered}");
    }

    #[test]
    fn pooled_latency_merges_engine_histograms_across_scenarios() {
        use crate::obs::{EngineObs, SeriesRing};
        let mut a = outcome(0, Mode::Arcus, 1, 10.0);
        let mut b = outcome(1, Mode::HostNoTs, 1, 10.0);
        for (o, lat_ps) in [(&mut a, 10_000u64), (&mut b, 90_000u64)] {
            let mut lat = Histogram::new();
            lat.record(lat_ps);
            lat.record(lat_ps);
            o.report.obs.engines.push(EngineObs {
                engine: 0,
                bytes: 0,
                ops: 2,
                lat,
                bytes_series: SeriesRing::new(1),
            });
        }
        let agg = aggregate(&[a, b]);
        assert_eq!(agg.pooled_lat.count(), 4);
        let rendered = agg.render();
        assert!(rendered.contains("pooled latency"), "{rendered}");
        assert!(rendered.contains("4 completions"), "{rendered}");
    }

    #[test]
    fn attainment_reflects_goodput_over_slo() {
        // Goodput == SLO → attainment ≈ 1.
        let o = vec![outcome(0, Mode::Arcus, 1, 10.0), outcome(1, Mode::HostNoTs, 1, 10.0)];
        let agg = aggregate(&o);
        for s in &agg.scenarios {
            assert!((s.attainment_min - 1.0).abs() < 0.05, "{}", s.attainment_min);
        }
        let _ = Rate::gbps(1.0); // keep the import referenced
    }
}
