//! Hierarchical timer wheel for the DES core.
//!
//! The flat [`CalendarQueue`](super::CalendarQueue) keeps one fine-grained
//! wheel (2048 × 64 ns ≈ 131 µs) and dumps everything beyond that horizon
//! into a single overflow heap. That is exactly where the engine's
//! long-horizon events live — `FaultStart`/`FaultEnd` windows milliseconds
//! out, deeply-throttled `RetryAt` wakeups, control-plane ticks during
//! sparse phases — so chaos-style schedules degrade toward the reference
//! heap. `HierWheel` replaces the single overflow level with a hierarchy,
//! kumomta-`timeq` style:
//!
//! - **L0**: `2^l0_bits` buckets (default 2048), each `width` ps wide
//!   (default 64 ns), each an inline `(time, seq)` min-heap.
//! - **L1..L3**: three coarser levels of `2^up_bits` slots each (default
//!   64). A level-`l` slot spans `2^(l0_bits + (l-1)·up_bits)` L0 buckets,
//!   so each level covers ×64 the horizon of the one below: ≈ 8.4 ms,
//!   537 ms, 34 s at the default geometry. Upper slots are plain unsorted
//!   `Vec`s — events there are not popped directly, they **cascade** down
//!   when the cursor enters their span.
//! - **Overflow**: a `(time, seq)` heap for the (rare) residue beyond L3.
//!
//! Per-level occupancy bitmaps (`u64` words + `trailing_zeros`) let `seek`
//! jump straight to the next non-empty bucket instead of probing empty
//! 64 ns buckets one at a time across a 100 µs control-tick gap.
//!
//! # Level placement is *aligned*, not windowed
//!
//! An entry's home is decided by comparing absolute bucket numbers at each
//! level's granularity against the cursor — "does this event fall in the
//! same level-`l` parent bucket the cursor is in?" — not by a relative
//! distance test. With shifts `s_l = l0_bits + l·up_bits`:
//!
//! - L0 if `b >> s_0 == cursor >> s_0` (slot `b & (2^l0_bits - 1)`),
//! - level `l` if `b >> s_l == cursor >> s_l` (slot
//!   `(b >> s_{l-1}) & (2^up_bits - 1)`),
//! - overflow otherwise.
//!
//! Alignment is what makes slot reuse safe: an occupied upper slot is
//! always *strictly ahead* of the cursor's own slot within the shared
//! parent bucket (if it were the cursor's slot, the entry would have
//! matched a finer level), so a slot never holds two rotations at once and
//! the occupancy bitmaps never wrap — plain ascending bit scans suffice.
//!
//! # Seek and cascade
//!
//! `seek` first scans the L0 bitmap from the cursor's slot forward; a hit
//! is the global minimum's bucket (everything in upper levels/overflow is
//! provably later). Otherwise it takes the earliest candidate among the
//! upper levels' next occupied slots and the overflow head, jumps the
//! cursor there, migrates overflow entries that now fall inside the L3
//! parent bucket, and drains the cursor's current slot at each upper level
//! top-down — re-placing every entry, which lands it at a finer level (or
//! L0). The loop repeats until an L0 hit; each jump strictly advances the
//! cursor, and each cascaded entry only ever moves to finer levels, so the
//! work per event is bounded by the number of levels.
//!
//! # Determinism
//!
//! Pop order is exactly ascending `(time, seq)` — byte-identical to
//! [`BinaryHeapQueue`](super::BinaryHeapQueue) — because (a) pops only ever
//! happen from L0 bucket heaps, which are `(time, seq)`-ordered, (b) the
//! seek candidate rule never parks the cursor past a pending event's
//! bucket, and (c) cascade order cannot leak into pop order: upper slots
//! are unsorted, but their entries merge into L0 heaps before any pop.
//! Ties at equal timestamps break by `seq` inside the bucket heap —
//! insertion order, never wheel internals. `rust/tests/determinism.rs`
//! fuzzes random long-horizon schedules 3-ways and pins golden scenarios.

use std::collections::BinaryHeap;

use super::{Entry, EventQueue};
use crate::util::units::{Time, NANOS};

/// Default L0 bucket width: 64 ns, matching the calendar queue — a few TLP
/// times, a quarter of the minimum shaper refill interval.
pub const DEFAULT_WIDTH: Time = 64 * NANOS;

/// Default L0 size: 2^11 = 2048 buckets ≈ 131 µs of fine-grained horizon.
pub const DEFAULT_L0_BITS: u32 = 11;

/// Default upper-level size: 2^6 = 64 slots per level, one `u64` bitmap.
pub const DEFAULT_UP_BITS: u32 = 6;

/// Number of coarse levels above L0. With the default geometry the top
/// level spans ≈ 34 s of virtual time; only events beyond that reach the
/// overflow heap.
const UP_LEVELS: usize = 3;

/// Hierarchical timer wheel event queue. See the module docs.
pub struct HierWheel<E> {
    /// L0 bucket width in picoseconds.
    width: Time,
    /// log2 of the L0 bucket count.
    l0_bits: u32,
    /// log2 of the per-upper-level slot count (≤ 6: one `u64` bitmap).
    up_bits: u32,
    /// L0 buckets: inline `(time, seq)` min-heaps.
    l0: Vec<BinaryHeap<Entry<E>>>,
    /// L0 occupancy, one bit per bucket, `u64` words.
    l0_occ: Vec<u64>,
    /// Upper levels: unsorted slots, drained wholesale on cascade.
    up: [Vec<Vec<Entry<E>>>; UP_LEVELS],
    /// One occupancy word per upper level.
    up_occ: [u64; UP_LEVELS],
    /// Absolute L0 bucket number the cursor is parked on (monotone).
    cursor: u64,
    /// Events beyond the top level's span, ordered by `(time, seq)`.
    overflow: BinaryHeap<Entry<E>>,
    /// Total pending events across all levels and overflow.
    len: usize,
}

impl<E> Default for HierWheel<E> {
    fn default() -> Self {
        Self::with_geometry(DEFAULT_WIDTH, DEFAULT_L0_BITS, DEFAULT_UP_BITS)
    }
}

impl<E> HierWheel<E> {
    /// A wheel with `2^l0_bits` L0 buckets of `width` ps, topped by three
    /// levels of `2^up_bits` slots each.
    pub fn with_geometry(width: Time, l0_bits: u32, up_bits: u32) -> Self {
        assert!(width > 0, "bucket width must be positive");
        assert!((1..=20).contains(&l0_bits), "l0_bits out of range");
        assert!((1..=6).contains(&up_bits), "up_bits must fit a u64 bitmap");
        assert!(
            l0_bits + UP_LEVELS as u32 * up_bits <= 62,
            "total shift must leave headroom in u64 bucket numbers"
        );
        let l0_slots = 1usize << l0_bits;
        let up_slots = 1usize << up_bits;
        HierWheel {
            width,
            l0_bits,
            up_bits,
            l0: (0..l0_slots).map(|_| BinaryHeap::new()).collect(),
            l0_occ: vec![0; l0_slots.div_ceil(64)],
            up: std::array::from_fn(|_| (0..up_slots).map(|_| Vec::new()).collect()),
            up_occ: [0; UP_LEVELS],
            cursor: 0,
            overflow: BinaryHeap::new(),
            len: 0,
        }
    }

    /// Absolute L0 bucket number of a timestamp.
    #[inline]
    fn bucket_of(&self, time: Time) -> u64 {
        time / self.width
    }

    /// Bit shift from an L0 bucket number to a level-`l` parent bucket
    /// number (`l == 0` is the L0 wheel itself).
    #[inline]
    fn shift(&self, l: usize) -> u32 {
        self.l0_bits + l as u32 * self.up_bits
    }

    #[inline]
    fn l0_mask(&self) -> u64 {
        (1u64 << self.l0_bits) - 1
    }

    #[inline]
    fn up_mask(&self) -> u64 {
        (1u64 << self.up_bits) - 1
    }

    /// Route an entry to its level (or overflow) relative to the cursor.
    fn place(&mut self, entry: Entry<E>) {
        // Events for already-passed windows (possible when the clock was
        // pinned forward by `run_until` and the cursor seeked ahead) join
        // the cursor bucket; its heap keeps them ahead of later times.
        let b = self.bucket_of(entry.time).max(self.cursor);
        if b >> self.shift(0) == self.cursor >> self.shift(0) {
            let slot = (b & self.l0_mask()) as usize;
            self.l0[slot].push(entry);
            self.l0_occ[slot >> 6] |= 1u64 << (slot & 63);
            return;
        }
        for l in 1..=UP_LEVELS {
            if b >> self.shift(l) == self.cursor >> self.shift(l) {
                let slot = ((b >> self.shift(l - 1)) & self.up_mask()) as usize;
                self.up[l - 1][slot].push(entry);
                self.up_occ[l - 1] |= 1u64 << slot;
                return;
            }
        }
        self.overflow.push(entry);
    }

    /// Next occupied L0 bucket at or after the cursor, within the cursor's
    /// L1 parent bucket (the bitmap covers exactly one L0 rotation, and
    /// occupancy never wraps behind the cursor — see module docs).
    fn next_l0(&self) -> Option<u64> {
        let p = (self.cursor & self.l0_mask()) as usize;
        let mut word = p >> 6;
        let mut bits = self.l0_occ[word] & (!0u64 << (p & 63));
        loop {
            if bits != 0 {
                let j = (word << 6) + bits.trailing_zeros() as usize;
                return Some(self.cursor - p as u64 + j as u64);
            }
            word += 1;
            if word >= self.l0_occ.len() {
                return None;
            }
            bits = self.l0_occ[word];
        }
    }

    /// Start bucket (L0 granularity) of level `l`'s next occupied slot
    /// strictly after the cursor's slot, if any.
    fn next_up(&self, l: usize) -> Option<u64> {
        // Level-`l` slots are keyed by bucket numbers at `shift(l-1)`
        // granularity.
        let cl = self.cursor >> self.shift(l - 1);
        let k = (cl & self.up_mask()) as u32;
        let bits = self.up_occ[l - 1];
        // Invariant: nothing occupies the cursor's own slot or earlier —
        // such entries would have matched a finer level when placed.
        let at_or_behind = 1u64.checked_shl(k + 1).map_or(u64::MAX, |m| m - 1);
        debug_assert_eq!(bits & at_or_behind, 0, "upper slot at or behind the cursor");
        let ahead = bits & !at_or_behind;
        if ahead == 0 {
            return None;
        }
        let j = ahead.trailing_zeros() as u64;
        Some((cl - k as u64 + j) << self.shift(l - 1))
    }

    /// Advance the cursor to bucket `w`, pull overflow entries that now
    /// fall inside the top level's parent bucket, and cascade the cursor's
    /// current slot at every upper level down to finer levels.
    fn jump_to(&mut self, w: u64) {
        debug_assert!(w > self.cursor, "jump must strictly advance");
        self.cursor = w;
        let top = self.shift(UP_LEVELS);
        while let Some(e) = self.overflow.peek() {
            if self.bucket_of(e.time) >> top != self.cursor >> top {
                break;
            }
            let e = self.overflow.pop().expect("peeked entry");
            self.place(e);
        }
        // Top-down: re-placing an L3 entry may land in the cursor's L1/L2
        // slot only if it belongs to a *later* slot there (a same-slot hit
        // at a finer granularity would have matched that finer level), so
        // lower drains never see freshly re-placed work in their own slot.
        for l in (1..=UP_LEVELS).rev() {
            let slot = ((self.cursor >> self.shift(l - 1)) & self.up_mask()) as usize;
            if self.up_occ[l - 1] & (1u64 << slot) != 0 {
                self.up_occ[l - 1] &= !(1u64 << slot);
                let entries = std::mem::take(&mut self.up[l - 1][slot]);
                for e in entries {
                    self.place(e);
                }
            }
        }
    }

    /// Park the cursor on the L0 bucket holding the global minimum event,
    /// cascading coarse levels as needed. Returns that minimum's time.
    fn seek(&mut self) -> Option<Time> {
        if self.len == 0 {
            return None;
        }
        loop {
            if let Some(b) = self.next_l0() {
                self.cursor = b;
                let slot = (b & self.l0_mask()) as usize;
                return Some(self.l0[slot].peek().expect("occupancy bit set").time);
            }
            // L0 (hence the cursor's entire L1 parent bucket) is empty:
            // the earliest pending event starts some coarser slot or sits
            // in overflow. Jump to the earliest candidate bucket.
            let mut winner = u64::MAX;
            for l in 1..=UP_LEVELS {
                if let Some(c) = self.next_up(l) {
                    winner = winner.min(c);
                }
            }
            if let Some(e) = self.overflow.peek() {
                winner = winner.min(self.bucket_of(e.time));
            }
            debug_assert_ne!(winner, u64::MAX, "len > 0 but no candidate bucket");
            self.jump_to(winner);
        }
    }
}

impl<E> EventQueue<E> for HierWheel<E> {
    fn push(&mut self, time: Time, seq: u64, ev: E) {
        self.place(Entry { time, seq, ev });
        self.len += 1;
    }

    fn pop(&mut self) -> Option<(Time, u64, E)> {
        self.seek()?;
        let slot = (self.cursor & self.l0_mask()) as usize;
        let e = self.l0[slot].pop().expect("seek parked on non-empty bucket");
        if self.l0[slot].is_empty() {
            self.l0_occ[slot >> 6] &= !(1u64 << (slot & 63));
        }
        self.len -= 1;
        Some((e.time, e.seq, e.ev))
    }

    fn next_time(&mut self) -> Option<Time> {
        self.seek()
    }

    fn len(&self) -> usize {
        self.len
    }

    fn name(&self) -> &'static str {
        "hier_wheel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(q: &mut HierWheel<u32>) -> Vec<(Time, u64)> {
        let mut out = Vec::new();
        while let Some((t, s, _)) = q.pop() {
            out.push((t, s));
        }
        out
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        // width 100, 4 L0 buckets, 4-slot upper levels: L0 spans 400 ps,
        // the top level 25_600 ps.
        let mut q: HierWheel<u32> = HierWheel::with_geometry(100, 2, 2);
        q.push(500, 2, 0);
        q.push(500, 1, 0);
        q.push(10, 3, 0);
        q.push(5_000, 0, 0); // upper level
        q.push(1_000_000, 4, 0); // beyond the top span → overflow
        assert_eq!(
            drain(&mut q),
            vec![(10, 3), (500, 1), (500, 2), (5_000, 0), (1_000_000, 4)]
        );
    }

    #[test]
    fn cascade_reuses_slots_without_mixing_windows() {
        // Span many full L0 rotations of a tiny wheel; every event maps to
        // a reused L0 slot and most arrive via an upper-level cascade.
        let mut q: HierWheel<u32> = HierWheel::with_geometry(10, 2, 2);
        let mut seq = 0;
        let mut expect = Vec::new();
        for rot in 0..50u64 {
            for off in [3u64, 7, 9] {
                let t = rot * 40 + off; // 40 ps = one full L0 span
                q.push(t, seq, 0);
                expect.push((t, seq));
                seq += 1;
            }
        }
        expect.sort();
        assert_eq!(drain(&mut q), expect);
    }

    #[test]
    fn deep_event_cascades_through_every_level() {
        // One event per level: L0, L1, L2, L3, overflow. Each must step
        // down through the hierarchy and pop in time order.
        let mut q: HierWheel<u32> = HierWheel::with_geometry(10, 2, 2);
        // L0 spans 40 ps; L1 ends at 160; L2 at 640; L3 at 2_560.
        for (i, t) in [15u64, 100, 500, 2_000, 50_000].iter().enumerate() {
            q.push(*t, i as u64, 0);
        }
        assert_eq!(
            drain(&mut q),
            vec![(15, 0), (100, 1), (500, 2), (2_000, 3), (50_000, 4)]
        );
    }

    #[test]
    fn interleaved_push_pop_respects_monotone_clock() {
        // Mimic the simulator: after popping time t, pushes never go below
        // t. Events pushed for the current (partially drained) bucket must
        // still come out in order; a push at a time whose window already
        // passed clamps into the cursor bucket (straggler clamping).
        let mut q: HierWheel<u32> = HierWheel::with_geometry(100, 2, 2);
        q.push(50, 0, 0);
        q.push(120, 1, 0);
        assert_eq!(q.pop(), Some((50, 0, 0)));
        q.push(60, 2, 0);
        q.push(130, 3, 0);
        q.push(10_000_000, 4, 0); // far beyond the top span → overflow
        assert_eq!(q.pop(), Some((60, 2, 0)));
        assert_eq!(q.pop(), Some((120, 1, 0)));
        assert_eq!(q.pop(), Some((130, 3, 0)));
        assert_eq!(q.next_time(), Some(10_000_000));
        q.push(9_999_999, 5, 0);
        assert_eq!(q.pop(), Some((9_999_999, 5, 0)));
        assert_eq!(q.pop(), Some((10_000_000, 4, 0)));
        assert!(q.pop().is_none());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn overflow_migrates_in_order_across_horizon() {
        let mut q: HierWheel<u32> = HierWheel::with_geometry(10, 2, 2);
        // Mix of upper-level and overflow events (top span = 2_560 ps),
        // shuffled.
        for (i, t) in [900u64, 410, 5_555, 12_000, 402, 90].iter().enumerate() {
            q.push(*t, i as u64, 0);
        }
        let times: Vec<Time> = drain(&mut q).iter().map(|&(t, _)| t).collect();
        assert_eq!(times, vec![90, 402, 410, 900, 5_555, 12_000]);
    }

    #[test]
    fn ties_at_cascade_edges_keep_fifo_order() {
        let mut q: HierWheel<u32> = HierWheel::with_geometry(50, 2, 2);
        let edge = 50 * 4 * 3; // an L0 rollover boundary, reached via L1
        for i in 0..32u64 {
            q.push(edge, i, i as u32);
        }
        let mut seqs = Vec::new();
        while let Some((t, s, _)) = q.pop() {
            assert_eq!(t, edge);
            seqs.push(s);
        }
        assert_eq!(seqs, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn len_tracks_all_levels() {
        let mut q: HierWheel<u32> = HierWheel::with_geometry(10, 2, 2);
        q.push(5, 0, 0); // L0
        q.push(100, 1, 0); // L1
        q.push(2_000, 2, 0); // L3
        q.push(1_000_000, 3, 0); // overflow
        assert_eq!(q.len(), 4);
        let _ = q.pop();
        assert_eq!(q.len(), 3);
        while q.pop().is_some() {}
        assert!(q.is_empty());
    }

    #[test]
    fn default_geometry_matches_calendar_scale() {
        // The default L0 mirrors the calendar queue's wheel exactly; the
        // upper levels extend the structured horizon to ~34 s.
        let q: HierWheel<u32> = HierWheel::default();
        assert_eq!(q.width, 64 * NANOS);
        assert_eq!(q.l0.len(), 2048);
        assert_eq!(q.shift(UP_LEVELS), 29);
    }
}
