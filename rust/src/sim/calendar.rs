//! Hierarchical calendar queue (timing wheel) for the DES core.
//!
//! The engine's event distribution is bimodal: a dense cloud of near-future
//! events (TLP completions every ~40 ns, shaper refill-edge wakeups every
//! ~256 ns, accelerator finishes) and a sparse tail (control-plane ticks at
//! 100 µs, long `RetryAt` horizons from deeply throttled flows). A single
//! binary heap pays O(log n) on the whole pending set for every operation;
//! a calendar queue pays O(log b) on one *bucket* — and buckets in the
//! dense region hold a handful of events.
//!
//! Design: a wheel of `slots` buckets, each `width` picoseconds wide, with
//! each bucket an inline min-heap ordered by `(time, seq)`. Events beyond
//! the wheel's horizon (`slots × width` ahead of the cursor) wait in an
//! overflow heap and migrate into the wheel as the cursor advances — a lazy
//! second hierarchy level. The cursor only ever moves forward (simulation
//! time is monotone), so each event is touched at most twice: once on push
//! (or migration) and once on pop.
//!
//! Determinism: the pop order is exactly ascending `(time, seq)` — the same
//! total order the reference [`BinaryHeapQueue`](super::BinaryHeapQueue)
//! produces — because every bucket is itself `(time, seq)`-ordered, buckets
//! are drained in window order, and the overflow heap only feeds buckets
//! *ahead* of the cursor. Wheel rollover (bucket reuse after `slots`
//! advances) cannot reorder: an event is only placed in a slot when its
//! bucket number lies within `[cursor, cursor + slots)`, so a slot never
//! holds two rotations at once. Property tests in
//! `rust/tests/determinism.rs` drive random schedules across many rollovers
//! and assert byte-identical pop sequences against the reference heap.

use std::collections::BinaryHeap;

use super::{Entry, EventQueue};
use crate::util::units::{Time, NANOS};

/// Default bucket width: 64 ns — a few TLP times, a quarter of the minimum
/// shaper refill interval. Dense-phase buckets stay small (tens of events).
pub const DEFAULT_WIDTH: Time = 64 * NANOS;

/// Default wheel size: 2048 buckets × 64 ns ≈ 131 µs of horizon — wider
/// than the 100 µs control-plane period, so periodic ticks land in the
/// wheel, not the overflow heap.
pub const DEFAULT_SLOTS: usize = 2048;

/// Timing-wheel event queue. See the module docs for the invariants.
pub struct CalendarQueue<E> {
    /// Bucket width in picoseconds.
    width: Time,
    /// Per-bucket min-heaps; index = bucket number % slots.len().
    slots: Vec<BinaryHeap<Entry<E>>>,
    /// Absolute bucket number the cursor is parked on (monotone).
    cursor: u64,
    /// Events at or beyond the wheel horizon, ordered by `(time, seq)`.
    overflow: BinaryHeap<Entry<E>>,
    /// Events currently in wheel buckets.
    in_wheel: usize,
    /// Total pending events (wheel + overflow).
    len: usize,
}

impl<E> Default for CalendarQueue<E> {
    fn default() -> Self {
        Self::with_geometry(DEFAULT_WIDTH, DEFAULT_SLOTS)
    }
}

impl<E> CalendarQueue<E> {
    /// A wheel of `slots` buckets, each `width` ps wide.
    pub fn with_geometry(width: Time, slots: usize) -> Self {
        assert!(width > 0, "bucket width must be positive");
        assert!(slots > 1, "wheel needs at least two buckets");
        CalendarQueue {
            width,
            slots: (0..slots).map(|_| BinaryHeap::new()).collect(),
            cursor: 0,
            overflow: BinaryHeap::new(),
            in_wheel: 0,
            len: 0,
        }
    }

    #[inline]
    fn nslots(&self) -> u64 {
        self.slots.len() as u64
    }

    /// Absolute bucket number of a timestamp.
    #[inline]
    fn bucket_of(&self, time: Time) -> u64 {
        time / self.width
    }

    /// Place an entry whose bucket number is known to be below the horizon.
    #[inline]
    fn place(&mut self, entry: Entry<E>) {
        // Events for already-passed windows (possible when the clock was
        // pinned forward by `run_until` and the cursor seeked ahead) join
        // the cursor bucket; its heap keeps them ahead of later times.
        let bucket = self.bucket_of(entry.time).max(self.cursor);
        let slot = (bucket % self.nslots()) as usize;
        self.slots[slot].push(entry);
        self.in_wheel += 1;
    }

    /// Move overflow events whose bucket fell inside the horizon into the
    /// wheel. Called whenever the cursor advances.
    fn migrate(&mut self) {
        let horizon_bucket = self.cursor.saturating_add(self.nslots());
        while let Some(top) = self.overflow.peek() {
            if self.bucket_of(top.time) >= horizon_bucket {
                break;
            }
            let entry = self.overflow.pop().unwrap();
            self.place(entry);
        }
    }

    /// Park the cursor on the bucket holding the global minimum event.
    /// Returns that minimum's time (None when empty).
    fn seek(&mut self) -> Option<Time> {
        if self.len == 0 {
            return None;
        }
        loop {
            if self.in_wheel == 0 {
                // Only overflow events remain: jump straight to the first
                // one's bucket, then pull everything inside the new horizon.
                let t = self.overflow.peek().expect("len>0, wheel empty").time;
                self.cursor = self.cursor.max(self.bucket_of(t));
                self.migrate();
                debug_assert!(self.in_wheel > 0);
                continue;
            }
            let slot = (self.cursor % self.nslots()) as usize;
            if let Some(e) = self.slots[slot].peek() {
                return Some(e.time);
            }
            self.cursor += 1;
            self.migrate();
        }
    }
}

impl<E> EventQueue<E> for CalendarQueue<E> {
    fn push(&mut self, time: Time, seq: u64, ev: E) {
        let entry = Entry { time, seq, ev };
        if self.bucket_of(time) >= self.cursor.saturating_add(self.nslots()) {
            self.overflow.push(entry);
        } else {
            self.place(entry);
        }
        self.len += 1;
    }

    fn pop(&mut self) -> Option<(Time, u64, E)> {
        self.seek()?;
        let slot = (self.cursor % self.nslots()) as usize;
        let e = self.slots[slot].pop().expect("seek parked on non-empty bucket");
        self.in_wheel -= 1;
        self.len -= 1;
        Some((e.time, e.seq, e.ev))
    }

    fn next_time(&mut self) -> Option<Time> {
        self.seek()
    }

    fn len(&self) -> usize {
        self.len
    }

    fn name(&self) -> &'static str {
        "calendar"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(q: &mut CalendarQueue<u32>) -> Vec<(Time, u64)> {
        let mut out = Vec::new();
        while let Some((t, s, _)) = q.pop() {
            out.push((t, s));
        }
        out
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut q: CalendarQueue<u32> = CalendarQueue::with_geometry(100, 8);
        q.push(500, 2, 0);
        q.push(500, 1, 0);
        q.push(10, 3, 0);
        q.push(5000, 0, 0); // beyond the 800-ps horizon → overflow
        assert_eq!(drain(&mut q), vec![(10, 3), (500, 1), (500, 2), (5000, 0)]);
    }

    #[test]
    fn rollover_reuses_slots_without_mixing_windows() {
        // Span many full rotations of a tiny wheel; every event maps to a
        // reused slot at some point.
        let mut q: CalendarQueue<u32> = CalendarQueue::with_geometry(10, 4);
        let mut seq = 0;
        let mut expect = Vec::new();
        for rot in 0..50u64 {
            for off in [3u64, 7, 9] {
                let t = rot * 40 + off; // 40 ps = one full wheel span
                q.push(t, seq, 0);
                expect.push((t, seq));
                seq += 1;
            }
        }
        expect.sort();
        assert_eq!(drain(&mut q), expect);
    }

    #[test]
    fn interleaved_push_pop_respects_monotone_clock() {
        // Mimic the simulator: after popping time t, pushes never go below
        // t. Events pushed for the current (partially drained) bucket must
        // still come out in order.
        let mut q: CalendarQueue<u32> = CalendarQueue::with_geometry(100, 4);
        q.push(50, 0, 0);
        q.push(120, 1, 0);
        assert_eq!(q.pop(), Some((50, 0, 0)));
        // Now = 50: push into the current bucket and the next one.
        q.push(60, 2, 0);
        q.push(130, 3, 0);
        q.push(10_000, 4, 0); // overflow
        assert_eq!(q.pop(), Some((60, 2, 0)));
        assert_eq!(q.pop(), Some((120, 1, 0)));
        assert_eq!(q.pop(), Some((130, 3, 0)));
        // Cursor seeked far ahead for the overflow event; a push at a time
        // whose window already passed still pops (straggler clamping).
        assert_eq!(q.next_time(), Some(10_000));
        q.push(9_999, 5, 0);
        assert_eq!(q.pop(), Some((9_999, 5, 0)));
        assert_eq!(q.pop(), Some((10_000, 4, 0)));
        assert!(q.pop().is_none());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn overflow_migrates_in_order_across_horizon() {
        let mut q: CalendarQueue<u32> = CalendarQueue::with_geometry(10, 4);
        // All far beyond the initial 40-ps horizon, shuffled.
        for (i, t) in [900u64, 410, 555, 1200, 402, 90].iter().enumerate() {
            q.push(*t, i as u64, 0);
        }
        let got = drain(&mut q);
        let times: Vec<Time> = got.iter().map(|&(t, _)| t).collect();
        assert_eq!(times, vec![90, 402, 410, 555, 900, 1200]);
    }

    #[test]
    fn len_tracks_wheel_and_overflow() {
        let mut q: CalendarQueue<u32> = CalendarQueue::with_geometry(10, 4);
        q.push(5, 0, 0);
        q.push(5_000, 1, 0);
        assert_eq!(q.len(), 2);
        let _ = q.pop();
        assert_eq!(q.len(), 1);
        let _ = q.pop();
        assert!(q.is_empty());
    }
}
