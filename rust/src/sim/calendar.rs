//! Hierarchical calendar queue (timing wheel) for the DES core.
//!
//! The engine's event distribution is bimodal: a dense cloud of near-future
//! events (TLP completions every ~40 ns, shaper refill-edge wakeups every
//! ~256 ns, accelerator finishes) and a sparse tail (control-plane ticks at
//! 100 µs, long `RetryAt` horizons from deeply throttled flows). A single
//! binary heap pays O(log n) on the whole pending set for every operation;
//! a calendar queue pays O(log b) on one *bucket* — and buckets in the
//! dense region hold a handful of events.
//!
//! Design: a wheel of `slots` buckets, each `width` picoseconds wide, with
//! each bucket an inline min-heap ordered by `(time, seq)`. Events beyond
//! the wheel's horizon (`slots × width` ahead of the cursor) wait in an
//! overflow heap and migrate into the wheel as the cursor advances — a lazy
//! second hierarchy level. The cursor only ever moves forward (simulation
//! time is monotone), so each event is touched at most twice: once on push
//! (or migration) and once on pop.
//!
//! `seek` consults an occupancy bitmap (one bit per slot, `u64` words +
//! `trailing_zeros`) to jump straight to the next non-empty bucket instead
//! of probing empty buckets one at a time — the original cursor walk cost
//! ~1,560 probes (each with a pointless overflow-heap check) per 100 µs
//! control-tick gap. The jump is gated on the overflow head: if its bucket
//! is at or before the next occupied wheel bucket, the queue migrates
//! first, both to avoid skipping it and to merge same-bucket overflow
//! entries into the bucket heap before anything pops from it. For
//! genuinely deep horizons (fault windows milliseconds out) the single
//! overflow heap still degrades toward the reference heap; the
//! [`HierWheel`](super::HierWheel) discipline replaces it with cascading
//! coarse levels.
//!
//! Determinism: the pop order is exactly ascending `(time, seq)` — the same
//! total order the reference [`BinaryHeapQueue`](super::BinaryHeapQueue)
//! produces — because every bucket is itself `(time, seq)`-ordered, buckets
//! are drained in window order, and the overflow heap only feeds buckets
//! *ahead* of the cursor. Wheel rollover (bucket reuse after `slots`
//! advances) cannot reorder: an event is only placed in a slot when its
//! bucket number lies within `[cursor, cursor + slots)`, so a slot never
//! holds two rotations at once. Property tests in
//! `rust/tests/determinism.rs` drive random schedules across many rollovers
//! and assert byte-identical pop sequences against the reference heap.

use std::collections::BinaryHeap;

use super::{Entry, EventQueue};
use crate::util::units::{Time, NANOS};

/// Default bucket width: 64 ns — a few TLP times, a quarter of the minimum
/// shaper refill interval. Dense-phase buckets stay small (tens of events).
pub const DEFAULT_WIDTH: Time = 64 * NANOS;

/// Default wheel size: 2048 buckets × 64 ns ≈ 131 µs of horizon — wider
/// than the 100 µs control-plane period, so periodic ticks land in the
/// wheel, not the overflow heap.
pub const DEFAULT_SLOTS: usize = 2048;

/// Timing-wheel event queue. See the module docs for the invariants.
pub struct CalendarQueue<E> {
    /// Bucket width in picoseconds.
    width: Time,
    /// Per-bucket min-heaps; index = bucket number % slots.len().
    slots: Vec<BinaryHeap<Entry<E>>>,
    /// Occupancy bitmap, one bit per slot (`u64` words): `seek` jumps to
    /// the next non-empty bucket instead of probing empties one by one.
    occupancy: Vec<u64>,
    /// Absolute bucket number the cursor is parked on (monotone).
    cursor: u64,
    /// Events at or beyond the wheel horizon, ordered by `(time, seq)`.
    overflow: BinaryHeap<Entry<E>>,
    /// Events currently in wheel buckets.
    in_wheel: usize,
    /// Total pending events (wheel + overflow).
    len: usize,
}

impl<E> Default for CalendarQueue<E> {
    fn default() -> Self {
        Self::with_geometry(DEFAULT_WIDTH, DEFAULT_SLOTS)
    }
}

impl<E> CalendarQueue<E> {
    /// A wheel of `slots` buckets, each `width` ps wide.
    pub fn with_geometry(width: Time, slots: usize) -> Self {
        assert!(width > 0, "bucket width must be positive");
        assert!(slots > 1, "wheel needs at least two buckets");
        CalendarQueue {
            width,
            slots: (0..slots).map(|_| BinaryHeap::new()).collect(),
            occupancy: vec![0; slots.div_ceil(64)],
            cursor: 0,
            overflow: BinaryHeap::new(),
            in_wheel: 0,
            len: 0,
        }
    }

    #[inline]
    fn nslots(&self) -> u64 {
        self.slots.len() as u64
    }

    /// Absolute bucket number of a timestamp.
    #[inline]
    fn bucket_of(&self, time: Time) -> u64 {
        time / self.width
    }

    /// Place an entry whose bucket number is known to be below the horizon.
    #[inline]
    fn place(&mut self, entry: Entry<E>) {
        // Events for already-passed windows (possible when the clock was
        // pinned forward by `run_until` and the cursor seeked ahead) join
        // the cursor bucket; its heap keeps them ahead of later times.
        let bucket = self.bucket_of(entry.time).max(self.cursor);
        let slot = (bucket % self.nslots()) as usize;
        self.slots[slot].push(entry);
        self.occupancy[slot >> 6] |= 1u64 << (slot & 63);
        self.in_wheel += 1;
    }

    /// Next occupied absolute bucket in `[cursor, cursor + nslots)`, or
    /// None when the wheel is empty. One rotation of the bitmap: the tail
    /// `[cursor_slot, nslots)` belongs to the current window, the wrapped
    /// head `[0, cursor_slot)` to the next one.
    fn next_occupied(&self) -> Option<u64> {
        let n = self.nslots();
        let p = (self.cursor % n) as usize;
        if let Some(j) = self.scan_bits(p, self.slots.len()) {
            return Some(self.cursor + (j - p) as u64);
        }
        if let Some(j) = self.scan_bits(0, p) {
            return Some(self.cursor + (n - p as u64) + j as u64);
        }
        None
    }

    /// First set occupancy bit in slot range `[from, to)`.
    fn scan_bits(&self, from: usize, to: usize) -> Option<usize> {
        if from >= to {
            return None;
        }
        let last_word = (to - 1) >> 6;
        let mut word = from >> 6;
        let mut bits = self.occupancy[word] & (!0u64 << (from & 63));
        loop {
            if bits != 0 {
                let j = (word << 6) + bits.trailing_zeros() as usize;
                return if j < to { Some(j) } else { None };
            }
            if word >= last_word {
                return None;
            }
            word += 1;
            bits = self.occupancy[word];
        }
    }

    /// Move overflow events whose bucket fell inside the horizon into the
    /// wheel. Called whenever the cursor advances.
    fn migrate(&mut self) {
        let horizon_bucket = self.cursor.saturating_add(self.nslots());
        while let Some(top) = self.overflow.peek() {
            if self.bucket_of(top.time) >= horizon_bucket {
                break;
            }
            let entry = self.overflow.pop().unwrap();
            self.place(entry);
        }
    }

    /// Park the cursor on the bucket holding the global minimum event.
    /// Returns that minimum's time (None when empty).
    fn seek(&mut self) -> Option<Time> {
        if self.len == 0 {
            return None;
        }
        loop {
            if self.in_wheel == 0 {
                // Only overflow events remain: jump straight to the first
                // one's bucket, then pull everything inside the new horizon.
                let t = self.overflow.peek().expect("len>0, wheel empty").time;
                self.cursor = self.cursor.max(self.bucket_of(t));
                self.migrate();
                debug_assert!(self.in_wheel > 0);
                continue;
            }
            let b = self.next_occupied().expect("in_wheel > 0");
            if let Some(top) = self.overflow.peek() {
                let ob = self.bucket_of(top.time);
                if ob <= b {
                    // The overflow head belongs at or before bucket `b` —
                    // at: same-bucket entries must merge into the bucket
                    // heap before popping; before: jumping to `b` would
                    // skip it. Advance only as far as its bucket, migrate,
                    // and re-scan. (`ob <= b < cursor + nslots`, so the
                    // migrate horizon covers it.)
                    self.cursor = self.cursor.max(ob);
                    self.migrate();
                    continue;
                }
            }
            // Safe to jump: every overflow entry's bucket is ahead of `b`
            // (entries overflowed because their bucket was ≥ some earlier
            // cursor + nslots, and the cursor never passes the overflow
            // head without migrating), so no event sorts before bucket
            // `b`'s minimum.
            self.cursor = b;
            let slot = (b % self.nslots()) as usize;
            return Some(self.slots[slot].peek().expect("occupancy bit set").time);
        }
    }
}

impl<E> EventQueue<E> for CalendarQueue<E> {
    fn push(&mut self, time: Time, seq: u64, ev: E) {
        let entry = Entry { time, seq, ev };
        if self.bucket_of(time) >= self.cursor.saturating_add(self.nslots()) {
            self.overflow.push(entry);
        } else {
            self.place(entry);
        }
        self.len += 1;
    }

    fn pop(&mut self) -> Option<(Time, u64, E)> {
        self.seek()?;
        let slot = (self.cursor % self.nslots()) as usize;
        let e = self.slots[slot].pop().expect("seek parked on non-empty bucket");
        if self.slots[slot].is_empty() {
            self.occupancy[slot >> 6] &= !(1u64 << (slot & 63));
        }
        self.in_wheel -= 1;
        self.len -= 1;
        Some((e.time, e.seq, e.ev))
    }

    fn next_time(&mut self) -> Option<Time> {
        self.seek()
    }

    fn len(&self) -> usize {
        self.len
    }

    fn name(&self) -> &'static str {
        "calendar"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(q: &mut CalendarQueue<u32>) -> Vec<(Time, u64)> {
        let mut out = Vec::new();
        while let Some((t, s, _)) = q.pop() {
            out.push((t, s));
        }
        out
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut q: CalendarQueue<u32> = CalendarQueue::with_geometry(100, 8);
        q.push(500, 2, 0);
        q.push(500, 1, 0);
        q.push(10, 3, 0);
        q.push(5000, 0, 0); // beyond the 800-ps horizon → overflow
        assert_eq!(drain(&mut q), vec![(10, 3), (500, 1), (500, 2), (5000, 0)]);
    }

    #[test]
    fn rollover_reuses_slots_without_mixing_windows() {
        // Span many full rotations of a tiny wheel; every event maps to a
        // reused slot at some point.
        let mut q: CalendarQueue<u32> = CalendarQueue::with_geometry(10, 4);
        let mut seq = 0;
        let mut expect = Vec::new();
        for rot in 0..50u64 {
            for off in [3u64, 7, 9] {
                let t = rot * 40 + off; // 40 ps = one full wheel span
                q.push(t, seq, 0);
                expect.push((t, seq));
                seq += 1;
            }
        }
        expect.sort();
        assert_eq!(drain(&mut q), expect);
    }

    #[test]
    fn interleaved_push_pop_respects_monotone_clock() {
        // Mimic the simulator: after popping time t, pushes never go below
        // t. Events pushed for the current (partially drained) bucket must
        // still come out in order.
        let mut q: CalendarQueue<u32> = CalendarQueue::with_geometry(100, 4);
        q.push(50, 0, 0);
        q.push(120, 1, 0);
        assert_eq!(q.pop(), Some((50, 0, 0)));
        // Now = 50: push into the current bucket and the next one.
        q.push(60, 2, 0);
        q.push(130, 3, 0);
        q.push(10_000, 4, 0); // overflow
        assert_eq!(q.pop(), Some((60, 2, 0)));
        assert_eq!(q.pop(), Some((120, 1, 0)));
        assert_eq!(q.pop(), Some((130, 3, 0)));
        // Cursor seeked far ahead for the overflow event; a push at a time
        // whose window already passed still pops (straggler clamping).
        assert_eq!(q.next_time(), Some(10_000));
        q.push(9_999, 5, 0);
        assert_eq!(q.pop(), Some((9_999, 5, 0)));
        assert_eq!(q.pop(), Some((10_000, 4, 0)));
        assert!(q.pop().is_none());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn overflow_migrates_in_order_across_horizon() {
        let mut q: CalendarQueue<u32> = CalendarQueue::with_geometry(10, 4);
        // All far beyond the initial 40-ps horizon, shuffled.
        for (i, t) in [900u64, 410, 555, 1200, 402, 90].iter().enumerate() {
            q.push(*t, i as u64, 0);
        }
        let got = drain(&mut q);
        let times: Vec<Time> = got.iter().map(|&(t, _)| t).collect();
        assert_eq!(times, vec![90, 402, 410, 555, 900, 1200]);
    }

    #[test]
    fn overflow_merges_into_shared_bucket_before_popping() {
        // Regression for the bitmap-skip seek: an overflow entry whose
        // bucket equals the next occupied wheel bucket must migrate into
        // that bucket's heap before anything pops from it, or a later
        // in-wheel time pops first.
        let mut q: CalendarQueue<u32> = CalendarQueue::with_geometry(10, 4);
        q.push(505, 0, 0); // bucket 50 → overflow
        q.push(5, 1, 0);
        assert_eq!(q.pop(), Some((5, 1, 0)));
        q.push(460, 2, 0); // bucket 46 → overflow; pop jumps the cursor there
        assert_eq!(q.pop(), Some((460, 2, 0)));
        q.push(470, 3, 0);
        assert_eq!(q.pop(), Some((470, 3, 0))); // cursor now 47: 50 is in-window
        q.push(501, 4, 0); // bucket 50, in wheel — shared with overflow's 505
        q.push(509, 5, 0);
        assert_eq!(q.pop(), Some((501, 4, 0)));
        assert_eq!(q.pop(), Some((505, 0, 0)), "overflow entry must merge");
        assert_eq!(q.pop(), Some((509, 5, 0)));
        assert!(q.pop().is_none());
    }

    #[test]
    fn seek_skips_sparse_gaps_directly() {
        // A sparse phase: single events separated by hundreds of empty
        // buckets (the 100 µs control-tick shape). Correctness is pinned
        // here; the perf win (no per-bucket probing) shows in `arcus
        // bench --preset xlarge`.
        let mut q: CalendarQueue<u32> = CalendarQueue::with_geometry(10, 512);
        for i in 0..16u64 {
            q.push(i * 3_000, i, 0); // 300 buckets apart, inside the window
        }
        let got: Vec<Time> = drain(&mut q).iter().map(|&(t, _)| t).collect();
        assert_eq!(got, (0..16u64).map(|i| i * 3_000).collect::<Vec<_>>());
    }

    #[test]
    fn len_tracks_wheel_and_overflow() {
        let mut q: CalendarQueue<u32> = CalendarQueue::with_geometry(10, 4);
        q.push(5, 0, 0);
        q.push(5_000, 1, 0);
        assert_eq!(q.len(), 2);
        let _ = q.pop();
        assert_eq!(q.len(), 1);
        let _ = q.pop();
        assert!(q.is_empty());
    }
}
