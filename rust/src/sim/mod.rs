//! Discrete-event simulation core.
//!
//! The Arcus prototype is a host–FPGA system; we reproduce it as a
//! cycle-granular discrete-event simulation. The core is deliberately small:
//! a virtual clock in picoseconds, a binary-heap event queue with
//! deterministic FIFO tie-breaking, and events that are boxed closures over a
//! user-supplied world type `W` (the component graph). Components are plain
//! structs inside `W`; the wiring code in `system/` schedules closures that
//! mutate them and schedule follow-up events.
//!
//! Determinism contract: given the same world, seed, and schedule calls, two
//! runs produce identical event orders — ties at equal timestamps are broken
//! by insertion sequence number, never by heap internals.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::util::units::Time;

/// An event action: runs against the world and may schedule more events.
pub type Action<W> = Box<dyn FnOnce(&mut W, &mut Sim<W>)>;

struct Entry<W> {
    time: Time,
    seq: u64,
    action: Action<W>,
}

impl<W> PartialEq for Entry<W> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<W> Eq for Entry<W> {}
impl<W> PartialOrd for Entry<W> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<W> Ord for Entry<W> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse order: BinaryHeap is a max-heap, we want earliest first.
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The simulator: virtual clock + event queue.
pub struct Sim<W> {
    now: Time,
    seq: u64,
    queue: BinaryHeap<Entry<W>>,
    executed: u64,
}

impl<W> Default for Sim<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W> Sim<W> {
    pub fn new() -> Self {
        Sim {
            now: 0,
            seq: 0,
            queue: BinaryHeap::new(),
            executed: 0,
        }
    }

    /// Current virtual time (ps).
    #[inline]
    pub fn now(&self) -> Time {
        self.now
    }

    /// Number of events executed so far (perf accounting).
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Number of pending events.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Schedule an action at absolute virtual time `t` (>= now).
    pub fn at<F>(&mut self, t: Time, action: F)
    where
        F: FnOnce(&mut W, &mut Sim<W>) + 'static,
    {
        debug_assert!(t >= self.now, "scheduling into the past: {t} < {}", self.now);
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Entry {
            time: t.max(self.now),
            seq,
            action: Box::new(action),
        });
    }

    /// Schedule an action `delay` picoseconds from now. A `Time::MAX` delay
    /// (e.g. serialization over a stalled zero-rate link) is dropped: the
    /// event would never fire.
    pub fn after<F>(&mut self, delay: Time, action: F)
    where
        F: FnOnce(&mut W, &mut Sim<W>) + 'static,
    {
        if delay == Time::MAX {
            return;
        }
        self.at(self.now.saturating_add(delay), action);
    }

    /// Run a single event; returns false when the queue is empty.
    pub fn step(&mut self, world: &mut W) -> bool {
        match self.queue.pop() {
            Some(e) => {
                debug_assert!(e.time >= self.now);
                self.now = e.time;
                self.executed += 1;
                (e.action)(world, self);
                true
            }
            None => false,
        }
    }

    /// Run until the queue drains or virtual time would exceed `until`.
    /// Events strictly after `until` stay queued; `now` advances to `until`.
    pub fn run_until(&mut self, world: &mut W, until: Time) {
        while let Some(head) = self.queue.peek() {
            if head.time > until {
                break;
            }
            // Unwrap is safe: peeked non-empty, no other pops in between.
            let e = self.queue.pop().unwrap();
            self.now = e.time;
            self.executed += 1;
            (e.action)(world, self);
        }
        self.now = self.now.max(until);
    }

    /// Run to queue exhaustion (or `max_events` as a runaway guard).
    pub fn run(&mut self, world: &mut W, max_events: u64) {
        let limit = self.executed + max_events;
        while self.executed < limit && self.step(world) {}
    }
}

/// A periodic ticker: reschedules itself every `period` until `world` says
/// stop. Used for the control-plane loop (Algorithm 1 runs periodically) and
/// for monitors.
pub fn every<W, F>(sim: &mut Sim<W>, period: Time, mut f: F)
where
    W: 'static,
    F: FnMut(&mut W, &mut Sim<W>) -> bool + 'static,
{
    fn tick<W, F>(period: Time, mut f: F) -> Action<W>
    where
        W: 'static,
        F: FnMut(&mut W, &mut Sim<W>) -> bool + 'static,
    {
        Box::new(move |w, sim| {
            if f(w, sim) {
                let next = tick(period, f);
                sim.after(period, move |w, s| next(w, s));
            }
        })
    }
    let action = tick(period, move |w: &mut W, s: &mut Sim<W>| f(w, s));
    sim.after(period, move |w, s| action(w, s));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{MICROS, NANOS};
    use std::cell::RefCell;
    use std::rc::Rc;

    #[derive(Default)]
    struct World {
        log: Vec<(Time, u32)>,
        count: u64,
    }

    #[test]
    fn events_fire_in_time_order() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        sim.at(30, |w, s| w.log.push((s.now(), 3)));
        sim.at(10, |w, s| w.log.push((s.now(), 1)));
        sim.at(20, |w, s| w.log.push((s.now(), 2)));
        sim.run(&mut w, 100);
        assert_eq!(w.log, vec![(10, 1), (20, 2), (30, 3)]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        for i in 0..50u32 {
            sim.at(100, move |w, _| w.log.push((100, i)));
        }
        sim.run(&mut w, 1000);
        let ids: Vec<u32> = w.log.iter().map(|&(_, i)| i).collect();
        assert_eq!(ids, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn events_can_schedule_events() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        sim.at(5, |w, s| {
            w.log.push((s.now(), 0));
            s.after(7, |w, s| w.log.push((s.now(), 1)));
        });
        sim.run(&mut w, 100);
        assert_eq!(w.log, vec![(5, 0), (12, 1)]);
    }

    #[test]
    fn run_until_stops_at_boundary() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        for i in 1..=10u64 {
            sim.at(i * MICROS, |w, _| w.count += 1);
        }
        sim.run_until(&mut w, 5 * MICROS);
        assert_eq!(w.count, 5);
        assert_eq!(sim.now(), 5 * MICROS);
        sim.run_until(&mut w, 20 * MICROS);
        assert_eq!(w.count, 10);
        assert_eq!(sim.now(), 20 * MICROS);
    }

    #[test]
    fn periodic_ticker_runs_until_false() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        every(&mut sim, 100 * NANOS, |w, _| {
            w.count += 1;
            w.count < 5
        });
        sim.run(&mut w, 1000);
        assert_eq!(w.count, 5);
        assert_eq!(sim.now(), 500 * NANOS);
    }

    #[test]
    fn max_delay_event_is_dropped() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        sim.after(Time::MAX, |w, _| w.count += 1);
        sim.run(&mut w, 10);
        assert_eq!(w.count, 0);
        assert_eq!(sim.pending(), 0);
    }

    #[test]
    fn determinism_two_identical_runs() {
        fn run_once() -> Vec<(Time, u32)> {
            let mut sim: Sim<World> = Sim::new();
            let mut w = World::default();
            let mut rng = crate::util::Rng::new(99);
            for i in 0..200u32 {
                let t = rng.range_u64(0, 1000) * NANOS;
                sim.at(t, move |w, s| w.log.push((s.now(), i)));
            }
            sim.run(&mut w, 10_000);
            w.log
        }
        assert_eq!(run_once(), run_once());
    }

    #[test]
    fn executed_counter_counts() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        for i in 0..7u64 {
            sim.at(i, |_, _| {});
        }
        sim.run(&mut w, 100);
        assert_eq!(sim.executed(), 7);
    }

    #[test]
    fn rc_refcell_worlds_compose() {
        // Components sometimes need shared handles; make sure the pattern
        // works through the closure-based event type.
        let shared = Rc::new(RefCell::new(0u64));
        struct W2 {
            shared: Rc<RefCell<u64>>,
        }
        let mut sim: Sim<W2> = Sim::new();
        let mut w = W2 {
            shared: shared.clone(),
        };
        sim.at(1, |w, _| *w.shared.borrow_mut() += 41);
        sim.run(&mut w, 10);
        assert_eq!(*shared.borrow(), 41);
    }
}
