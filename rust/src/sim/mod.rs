//! Discrete-event simulation core.
//!
//! The Arcus prototype is a host–FPGA system; we reproduce it as a
//! cycle-granular discrete-event simulation. The core is deliberately small:
//! a virtual clock in picoseconds, a pluggable event queue, and **typed
//! events** — each world `W` defines one event enum and dispatches it with a
//! single `match` ([`Handler::handle`]). Events live inline in the queue:
//! scheduling costs a queue insert, not a heap allocation, and dispatch is a
//! jump table, not a virtual call through `Box<dyn FnOnce>`.
//!
//! Three queue disciplines implement [`EventQueue`]:
//!
//! - [`BinaryHeapQueue`] — the reference implementation; O(log n) per
//!   operation on one `BinaryHeap`.
//! - [`CalendarQueue`] — a flat timing wheel with per-bucket heaps plus an
//!   overflow heap, tuned for the shaper-tick-heavy event distribution the
//!   engine produces (dense clusters of near-future wakeups, a sparse tail
//!   of control-plane ticks). Kept as a comparison discipline.
//! - [`HierWheel`] — a hierarchical timer wheel: the same fine-grained L0
//!   backed by three ×64-coarser levels that cascade events downward on
//!   demand, with per-level occupancy bitmaps. This removes the calendar's
//!   single-overflow-heap degradation on long-horizon schedules (fault
//!   windows, deep `RetryAt` wakeups) and is the default fast discipline.
//!
//! Determinism contract: given the same world, seed, and schedule calls, two
//! runs — and three *queue implementations* — produce identical event
//! orders.
//! Ties at equal timestamps are broken by insertion sequence number, never
//! by queue internals. `rust/tests/determinism.rs` pins this with a golden
//! scenario run on all three queues.
//!
//! `run_until` boundary contract: events at exactly `until` execute —
//! *including* events an executing event schedules at that same timestamp —
//! before the clock is pinned to `until`. Events strictly after `until`
//! stay queued.

pub mod calendar;
pub mod wheel;

pub use calendar::CalendarQueue;
pub use wheel::HierWheel;

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::marker::PhantomData;

use crate::util::units::Time;

/// A world that can execute the events of type `E` it scheduled.
///
/// One `match` over the event enum replaces the former boxed-closure
/// dispatch; handlers may schedule follow-up events through the simulator.
pub trait Handler<E> {
    fn handle<Q: EventQueue<E>>(&mut self, sim: &mut Sim<E, Q>, ev: E);
}

/// A pending-event set ordered by `(time, seq)`.
///
/// Implementations must pop in strictly increasing `(time, seq)` order over
/// the current contents — the determinism contract. `seq` values are unique
/// and monotone (assigned by [`Sim`]), so the order is total.
pub trait EventQueue<E> {
    /// Insert an event. `time` is never less than the last popped time.
    fn push(&mut self, time: Time, seq: u64, ev: E);

    /// Remove and return the minimum-`(time, seq)` event.
    fn pop(&mut self) -> Option<(Time, u64, E)>;

    /// Earliest pending event time. May advance internal cursors but must
    /// not change the pop order.
    fn next_time(&mut self) -> Option<Time>;

    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Discipline name for reports and bench output.
    fn name(&self) -> &'static str;
}

/// One queued event. Shared by every queue implementation; ordered by
/// `(time, seq)` with the comparison reversed so `BinaryHeap` (a max-heap)
/// yields the earliest entry first.
pub(crate) struct Entry<E> {
    pub(crate) time: Time,
    pub(crate) seq: u64,
    pub(crate) ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Reference queue: one binary heap over all pending events.
pub struct BinaryHeapQueue<E> {
    heap: BinaryHeap<Entry<E>>,
}

impl<E> Default for BinaryHeapQueue<E> {
    fn default() -> Self {
        BinaryHeapQueue {
            heap: BinaryHeap::new(),
        }
    }
}

impl<E> EventQueue<E> for BinaryHeapQueue<E> {
    fn push(&mut self, time: Time, seq: u64, ev: E) {
        self.heap.push(Entry { time, seq, ev });
    }

    fn pop(&mut self) -> Option<(Time, u64, E)> {
        self.heap.pop().map(|e| (e.time, e.seq, e.ev))
    }

    fn next_time(&mut self) -> Option<Time> {
        self.heap.peek().map(|e| e.time)
    }

    fn len(&self) -> usize {
        self.heap.len()
    }

    fn name(&self) -> &'static str {
        "binary_heap"
    }
}

/// The simulator: virtual clock + event queue.
pub struct Sim<E, Q: EventQueue<E> = BinaryHeapQueue<E>> {
    now: Time,
    seq: u64,
    queue: Q,
    executed: u64,
    peak_pending: usize,
    _ev: PhantomData<fn(E)>,
}

impl<E, Q: EventQueue<E> + Default> Default for Sim<E, Q> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E, Q: EventQueue<E> + Default> Sim<E, Q> {
    pub fn new() -> Self {
        Self::with_queue(Q::default())
    }
}

impl<E, Q: EventQueue<E>> Sim<E, Q> {
    pub fn with_queue(queue: Q) -> Self {
        Sim {
            now: 0,
            seq: 0,
            queue,
            executed: 0,
            peak_pending: 0,
            _ev: PhantomData,
        }
    }

    /// Current virtual time (ps).
    #[inline]
    pub fn now(&self) -> Time {
        self.now
    }

    /// Number of events executed so far (perf accounting).
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Number of pending events.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// High-water mark of the pending-event set (perf accounting).
    pub fn peak_pending(&self) -> usize {
        self.peak_pending
    }

    /// Queue discipline name (bench/report labeling).
    pub fn queue_name(&self) -> &'static str {
        self.queue.name()
    }

    /// Schedule an event at absolute virtual time `t` (>= now).
    pub fn at(&mut self, t: Time, ev: E) {
        debug_assert!(t >= self.now, "scheduling into the past: {t} < {}", self.now);
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(t.max(self.now), seq, ev);
        if self.queue.len() > self.peak_pending {
            self.peak_pending = self.queue.len();
        }
    }

    /// Schedule an event `delay` picoseconds from now. A `Time::MAX` delay
    /// (e.g. serialization over a stalled zero-rate link) is dropped: the
    /// event would never fire.
    pub fn after(&mut self, delay: Time, ev: E) {
        if delay == Time::MAX {
            return;
        }
        self.at(self.now.saturating_add(delay), ev);
    }

    /// Run a single event; returns false when the queue is empty.
    pub fn step<W: Handler<E>>(&mut self, world: &mut W) -> bool {
        match self.queue.pop() {
            Some((t, _seq, ev)) => {
                debug_assert!(t >= self.now);
                self.now = t;
                self.executed += 1;
                world.handle(self, ev);
                true
            }
            None => false,
        }
    }

    /// Run until the queue drains or virtual time would exceed `until`.
    ///
    /// Boundary: every event with `time <= until` executes — including
    /// events scheduled *at* `until` by the final executed step (the head
    /// is re-examined after each event) — then `now` is pinned to `until`.
    /// Events strictly after `until` stay queued.
    pub fn run_until<W: Handler<E>>(&mut self, world: &mut W, until: Time) {
        loop {
            match self.queue.next_time() {
                Some(t) if t <= until => {
                    self.step(world);
                }
                _ => break,
            }
        }
        self.now = self.now.max(until);
    }

    /// Run to queue exhaustion (or `max_events` as a runaway guard;
    /// `u64::MAX` means no limit, even on a sim that has already run).
    pub fn run<W: Handler<E>>(&mut self, world: &mut W, max_events: u64) {
        let limit = self.executed.saturating_add(max_events);
        while self.executed < limit && self.step(world) {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{MICROS, NANOS};

    /// Typed test events replacing the former closure actions.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    enum TEv {
        /// Append (now, tag) to the log.
        Log(u32),
        /// Log tag 0, then schedule Log(1) seven ps later.
        Spawn,
        /// Log tag 8, then schedule Log(9) at the *same* timestamp
        /// (the run_until boundary case).
        SpawnSameTime,
        /// Increment the counter.
        Count,
        /// Increment the counter and re-arm every 100 ns while below limit.
        Tick,
    }

    #[derive(Default)]
    struct World {
        log: Vec<(Time, u32)>,
        count: u64,
        tick_limit: u64,
    }

    impl Handler<TEv> for World {
        fn handle<Q: EventQueue<TEv>>(&mut self, sim: &mut Sim<TEv, Q>, ev: TEv) {
            match ev {
                TEv::Log(tag) => self.log.push((sim.now(), tag)),
                TEv::Spawn => {
                    self.log.push((sim.now(), 0));
                    sim.after(7, TEv::Log(1));
                }
                TEv::SpawnSameTime => {
                    self.log.push((sim.now(), 8));
                    let now = sim.now();
                    sim.at(now, TEv::Log(9));
                }
                TEv::Count => self.count += 1,
                TEv::Tick => {
                    self.count += 1;
                    if self.count < self.tick_limit {
                        sim.after(100 * NANOS, TEv::Tick);
                    }
                }
            }
        }
    }

    fn events_fire_in_time_order_on<Q: EventQueue<TEv> + Default>() {
        let mut sim: Sim<TEv, Q> = Sim::new();
        let mut w = World::default();
        sim.at(30, TEv::Log(3));
        sim.at(10, TEv::Log(1));
        sim.at(20, TEv::Log(2));
        sim.run(&mut w, 100);
        assert_eq!(w.log, vec![(10, 1), (20, 2), (30, 3)]);
    }

    #[test]
    fn events_fire_in_time_order() {
        events_fire_in_time_order_on::<BinaryHeapQueue<TEv>>();
        events_fire_in_time_order_on::<CalendarQueue<TEv>>();
        events_fire_in_time_order_on::<HierWheel<TEv>>();
    }

    fn ties_break_by_insertion_order_on<Q: EventQueue<TEv> + Default>() {
        let mut sim: Sim<TEv, Q> = Sim::new();
        let mut w = World::default();
        for i in 0..50u32 {
            sim.at(100, TEv::Log(i));
        }
        sim.run(&mut w, 1000);
        let ids: Vec<u32> = w.log.iter().map(|&(_, i)| i).collect();
        assert_eq!(ids, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn ties_break_by_insertion_order() {
        ties_break_by_insertion_order_on::<BinaryHeapQueue<TEv>>();
        ties_break_by_insertion_order_on::<CalendarQueue<TEv>>();
        ties_break_by_insertion_order_on::<HierWheel<TEv>>();
    }

    fn events_can_schedule_events_on<Q: EventQueue<TEv> + Default>() {
        let mut sim: Sim<TEv, Q> = Sim::new();
        let mut w = World::default();
        sim.at(5, TEv::Spawn);
        sim.run(&mut w, 100);
        assert_eq!(w.log, vec![(5, 0), (12, 1)]);
    }

    #[test]
    fn events_can_schedule_events() {
        events_can_schedule_events_on::<BinaryHeapQueue<TEv>>();
        events_can_schedule_events_on::<CalendarQueue<TEv>>();
        events_can_schedule_events_on::<HierWheel<TEv>>();
    }

    fn run_until_stops_at_boundary_on<Q: EventQueue<TEv> + Default>() {
        let mut sim: Sim<TEv, Q> = Sim::new();
        let mut w = World::default();
        for i in 1..=10u64 {
            sim.at(i * MICROS, TEv::Count);
        }
        sim.run_until(&mut w, 5 * MICROS);
        assert_eq!(w.count, 5);
        assert_eq!(sim.now(), 5 * MICROS);
        sim.run_until(&mut w, 20 * MICROS);
        assert_eq!(w.count, 10);
        assert_eq!(sim.now(), 20 * MICROS);
    }

    #[test]
    fn run_until_stops_at_boundary() {
        run_until_stops_at_boundary_on::<BinaryHeapQueue<TEv>>();
        run_until_stops_at_boundary_on::<CalendarQueue<TEv>>();
        run_until_stops_at_boundary_on::<HierWheel<TEv>>();
    }

    fn run_until_boundary_chain_on<Q: EventQueue<TEv> + Default>() {
        // An event at exactly `until` schedules another event at that same
        // timestamp: both must execute before the clock is pinned. This is
        // the boundary the engine depends on — the last shaper wakeup of a
        // run often completes a message whose finish event lands at the
        // same instant.
        let mut sim: Sim<TEv, Q> = Sim::new();
        let mut w = World::default();
        let until = 100 * NANOS;
        sim.at(until, TEv::SpawnSameTime);
        sim.at(until + 1, TEv::Log(7)); // strictly after: must stay queued
        sim.run_until(&mut w, until);
        assert_eq!(w.log, vec![(until, 8), (until, 9)]);
        assert_eq!(sim.now(), until);
        assert_eq!(sim.pending(), 1, "event after `until` stays queued");
        sim.run_until(&mut w, until + 1);
        assert_eq!(w.log.last(), Some(&(until + 1, 7)));
    }

    #[test]
    fn run_until_executes_equal_time_events_scheduled_by_final_step() {
        run_until_boundary_chain_on::<BinaryHeapQueue<TEv>>();
        run_until_boundary_chain_on::<CalendarQueue<TEv>>();
        run_until_boundary_chain_on::<HierWheel<TEv>>();
    }

    #[test]
    fn periodic_ticker_runs_until_limit() {
        let mut sim: Sim<TEv> = Sim::new();
        let mut w = World {
            tick_limit: 5,
            ..World::default()
        };
        sim.after(100 * NANOS, TEv::Tick);
        sim.run(&mut w, 1000);
        assert_eq!(w.count, 5);
        assert_eq!(sim.now(), 500 * NANOS);
    }

    #[test]
    fn max_delay_event_is_dropped() {
        let mut sim: Sim<TEv> = Sim::new();
        let mut w = World::default();
        sim.after(Time::MAX, TEv::Count);
        sim.run(&mut w, 10);
        assert_eq!(w.count, 0);
        assert_eq!(sim.pending(), 0);
    }

    fn determinism_two_identical_runs_on<Q: EventQueue<TEv> + Default>() -> Vec<(Time, u32)> {
        let mut sim: Sim<TEv, Q> = Sim::new();
        let mut w = World::default();
        let mut rng = crate::util::Rng::new(99);
        for i in 0..200u32 {
            let t = rng.range_u64(0, 1000) * NANOS;
            sim.at(t, TEv::Log(i));
        }
        sim.run(&mut w, 10_000);
        w.log
    }

    #[test]
    fn determinism_two_identical_runs() {
        let heap_a = determinism_two_identical_runs_on::<BinaryHeapQueue<TEv>>();
        let heap_b = determinism_two_identical_runs_on::<BinaryHeapQueue<TEv>>();
        assert_eq!(heap_a, heap_b);
        // And the calendar queue produces the *same* order as the heap.
        let cal = determinism_two_identical_runs_on::<CalendarQueue<TEv>>();
        assert_eq!(heap_a, cal);
        // ... and so does the hierarchical wheel.
        let wheel = determinism_two_identical_runs_on::<HierWheel<TEv>>();
        assert_eq!(heap_a, wheel);
    }

    #[test]
    fn executed_counter_counts() {
        let mut sim: Sim<TEv> = Sim::new();
        let mut w = World::default();
        for i in 0..7u64 {
            sim.at(i, TEv::Count);
        }
        sim.run(&mut w, 100);
        assert_eq!(sim.executed(), 7);
    }

    #[test]
    fn peak_pending_tracks_high_water_mark() {
        let mut sim: Sim<TEv> = Sim::new();
        let mut w = World::default();
        for i in 0..9u64 {
            sim.at(i, TEv::Count);
        }
        assert_eq!(sim.peak_pending(), 9);
        sim.run(&mut w, 100);
        assert_eq!(sim.peak_pending(), 9, "draining does not lower the mark");
    }
}
