//! PCIe interconnect model.
//!
//! The paper's communication-induced SLO violations come from contention on
//! "root complex, PCIe interconnects, buffers, and queues" — resources with
//! no tenant-level isolation ("VMs' traffic is not isolated across PCIe
//! lanes but allocated by credits"). This module models what matters for
//! those effects at TLP granularity:
//!
//! - **Full-duplex serialization**: each direction (host→device "Down",
//!   device→host "Up") is an independent serialized resource — the source of
//!   the CaseP_same_path vs CaseP_multi_path gap (Fig 3f): same-path flows
//!   fight over one direction while multi-path flows use both.
//! - **TLP framing**: payloads split into MaxPayload-sized TLPs with header
//!   overhead; DMA reads cost a request TLP one way plus completion TLPs
//!   the other way, so "read-heavy" traffic loads both directions.
//! - **Per-TLP round-robin arbitration** across requesters: hardware
//!   arbiters are message-blind, so a 4 KB flow (16 TLPs/message) beats a
//!   64 B flow (1 TLP/message) ~4× in bandwidth — the paper's observed
//!   unfairness in CaseP_same_path.
//! - **Outstanding-read tags and completion credits**: a bounded number of
//!   in-flight DMA reads per engine (running out = the paper's "PCIe credit"
//!   stall).
//!
//! [`fabric::Fabric`] exposes DMA read/write operations and is pumped by the
//! simulation wiring; [`link::DuplexLink`] is the underlying serializer.

pub mod fabric;
pub mod link;

pub use fabric::{Fabric, FabricConfig, OpKind};
pub use link::{Dir, DuplexLink, LinkConfig};

use crate::util::units::Rate;

/// PCIe generation/width presets (effective data rate per direction after
/// 128b/130b encoding; protocol overhead is modeled per-TLP, not here).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PcieGen {
    Gen3,
    Gen4,
    Gen5,
}

impl PcieGen {
    /// Per-lane effective rate.
    pub fn lane_rate(self) -> Rate {
        match self {
            // 8 GT/s * 128/130
            PcieGen::Gen3 => Rate::bits_per_sec(8e9 * 128.0 / 130.0),
            PcieGen::Gen4 => Rate::bits_per_sec(16e9 * 128.0 / 130.0),
            PcieGen::Gen5 => Rate::bits_per_sec(32e9 * 128.0 / 130.0),
        }
    }

    /// Effective per-direction rate for an xN link.
    pub fn link_rate(self, lanes: u32) -> Rate {
        Rate(self.lane_rate().0 * lanes as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen3_x8_is_about_63gbps() {
        let r = PcieGen::Gen3.link_rate(8);
        assert!((r.as_gbps() - 63.0).abs() < 0.1, "rate={r}");
    }

    #[test]
    fn gen_scaling() {
        assert!(
            (PcieGen::Gen4.link_rate(4).as_gbps() - PcieGen::Gen3.link_rate(8).as_gbps())
                .abs()
                < 0.01
        );
    }
}
