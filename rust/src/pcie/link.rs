//! Full-duplex PCIe link serializer with TLP splitting and per-TLP
//! round-robin arbitration across sources.

use crate::util::units::{Rate, Time};
use std::collections::VecDeque;

/// Transfer direction over the link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    /// Host → device (DMA read completions, MMIO writes, descriptors).
    Down = 0,
    /// Device → host (DMA writes, read requests, interrupts).
    Up = 1,
}

/// Physical-layer configuration.
#[derive(Debug, Clone, Copy)]
pub struct LinkConfig {
    /// Effective serialized rate per direction (after line coding).
    pub rate: Rate,
    /// MaxPayload per TLP (256 B on the paper's platform).
    pub max_payload: u64,
    /// Wire overhead per data TLP: TLP header + DLLP + framing.
    pub tlp_overhead: u64,
    /// Wire size of a read-request TLP (no payload).
    pub read_req_bytes: u64,
    /// Minimum per-TLP occupancy: root-complex / DMA-engine header
    /// processing caps the TLP *rate* regardless of payload size — the
    /// effect that makes 64 B traffic collapse over PCIe (Neugebauer et
    /// al., SIGCOMM'18; the paper's "PCIe contention" references). 40 ns
    /// ≈ 25 M TLP/s per direction, typical for Gen3-era root complexes.
    pub min_tlp_time: Time,
}

impl LinkConfig {
    /// The paper's platform: PCIe Gen 3.0 x8.
    pub fn gen3_x8() -> Self {
        LinkConfig {
            rate: super::PcieGen::Gen3.link_rate(8),
            max_payload: 256,
            tlp_overhead: 24, // 4B framing + 2B seq + 12-16B header + 4B LCRC
            read_req_bytes: 28,
            min_tlp_time: 40_000, // 40 ns
        }
    }

    /// Time one TLP of `wire_bytes` occupies the direction.
    #[inline]
    pub fn tlp_time(&self, wire_bytes: u64) -> Time {
        self.rate.serialize_time(wire_bytes).max(self.min_tlp_time)
    }

    /// Sustainable payload bandwidth (bits/s) for messages of `msg_bytes`:
    /// min(wire efficiency, TLP-rate ceiling). The capacity profiler uses
    /// this as the per-direction communication budget.
    pub fn effective_payload_rate(&self, msg_bytes: u64) -> Rate {
        let msg_bytes = msg_bytes.max(1);
        let full = msg_bytes / self.max_payload;
        let tail = msg_bytes % self.max_payload;
        let mut time = full * self.tlp_time(self.max_payload + self.tlp_overhead);
        if tail > 0 {
            time += self.tlp_time(tail + self.tlp_overhead);
        }
        Rate(msg_bytes as f64 * 8.0 / time as f64 * crate::util::units::SECONDS as f64)
    }
}

/// One queued TLP.
#[derive(Debug, Clone, Copy)]
struct Tlp {
    /// Wire bytes (payload + overhead).
    wire_bytes: u64,
    /// Opaque message id; the fabric maps these back to operations.
    msg: u64,
    /// TLPs remaining for this message *after* this one (0 = final).
    last: bool,
}

/// Per-direction state: per-source FIFO queues + RR pointer + in-flight TLP.
#[derive(Debug)]
struct DirState {
    queues: Vec<VecDeque<Tlp>>,
    rr_next: usize,
    /// Currently serializing TLP and its finish time.
    current: Option<(Tlp, Time)>,
    /// Total bytes ever serialized (utilization accounting).
    bytes_serialized: u64,
    busy_time: Time,
}

impl DirState {
    fn new(sources: usize) -> Self {
        DirState {
            queues: (0..sources).map(|_| VecDeque::new()).collect(),
            rr_next: 0,
            current: None,
            bytes_serialized: 0,
            busy_time: 0,
        }
    }

    fn queued(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Pick the next TLP by round-robin over non-empty source queues.
    fn next_tlp(&mut self) -> Option<Tlp> {
        let n = self.queues.len();
        for i in 0..n {
            let idx = (self.rr_next + i) % n;
            if let Some(tlp) = self.queues[idx].pop_front() {
                self.rr_next = (idx + 1) % n;
                return Some(tlp);
            }
        }
        None
    }
}

/// Completed message notification from [`DuplexLink::pump`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Delivered {
    pub msg: u64,
    pub dir: Dir,
    pub at: Time,
}

/// Stretch a serialization time under a bandwidth cut (`factor` ∈ (0, 1];
/// 1.0 = healthy wire).
#[inline]
fn scale_time(t: Time, factor: f64) -> Time {
    if factor >= 1.0 {
        t
    } else {
        (t as f64 / factor).round() as Time
    }
}

/// The full-duplex link. Owned by the fabric; pumped by the simulation.
#[derive(Debug)]
pub struct DuplexLink {
    cfg: LinkConfig,
    dirs: [DirState; 2],
    /// Fault-injection bandwidth multiplier in (0, 1]; 1.0 = healthy. TLP
    /// serialization stretches by `1/degrade` — a link flap is a short
    /// window with a deep factor. The TLP already on the wire keeps its
    /// finish time (injection never rewrites the past).
    degrade: f64,
}

impl DuplexLink {
    pub fn new(cfg: LinkConfig, sources: usize) -> Self {
        DuplexLink {
            cfg,
            dirs: [DirState::new(sources), DirState::new(sources)],
            degrade: 1.0,
        }
    }

    pub fn config(&self) -> &LinkConfig {
        &self.cfg
    }

    /// Fault injection: scale per-direction bandwidth by `factor` ∈ (0, 1]
    /// (1.0 restores full health). See [`crate::faults`].
    pub fn set_degradation(&mut self, factor: f64) {
        debug_assert!(factor > 0.0 && factor <= 1.0, "link degrade factor {factor}");
        self.degrade = factor.clamp(f64::MIN_POSITIVE, 1.0);
    }

    /// Current fault-injection bandwidth multiplier (1.0 = healthy).
    pub fn degradation(&self) -> f64 {
        self.degrade
    }

    /// Enqueue a data transfer of `payload_bytes` for message `msg` from
    /// `source`; it is split into MaxPayload TLPs.
    pub fn enqueue_data(&mut self, dir: Dir, source: usize, payload_bytes: u64, msg: u64) {
        let d = &mut self.dirs[dir as usize];
        let mut remaining = payload_bytes.max(1);
        while remaining > 0 {
            let chunk = remaining.min(self.cfg.max_payload);
            remaining -= chunk;
            d.queues[source].push_back(Tlp {
                wire_bytes: chunk + self.cfg.tlp_overhead,
                msg,
                last: remaining == 0,
            });
        }
    }

    /// Enqueue a read-request TLP (no payload) for message `msg`.
    pub fn enqueue_read_req(&mut self, dir: Dir, source: usize, msg: u64) {
        let d = &mut self.dirs[dir as usize];
        d.queues[source].push_back(Tlp {
            wire_bytes: self.cfg.read_req_bytes,
            msg,
            last: true,
        });
    }

    /// Advance the serializer at `now`: complete any due TLP, start the next
    /// queued one. Returns messages whose final TLP finished, plus the next
    /// time this direction needs pumping (None = idle).
    ///
    /// Allocates a fresh `Vec` per call; the simulation hot path uses
    /// [`Self::pump_into`] with a reused buffer instead.
    pub fn pump(&mut self, now: Time, dir: Dir) -> (Vec<Delivered>, Option<Time>) {
        let mut done = Vec::new();
        let next = self.pump_into(now, dir, &mut done);
        (done, next)
    }

    /// Allocation-free pump: appends completed messages to `done` (which
    /// the caller reuses across calls) and returns the next wake time.
    pub fn pump_into(&mut self, now: Time, dir: Dir, done: &mut Vec<Delivered>) -> Option<Time> {
        let cfg = self.cfg;
        let degrade = self.degrade;
        let d = &mut self.dirs[dir as usize];
        // Loop: multiple TLPs may have finished if pumping was lazy.
        loop {
            match d.current {
                Some((tlp, fin)) if fin <= now => {
                    d.current = None;
                    d.bytes_serialized += tlp.wire_bytes;
                    if tlp.last {
                        done.push(Delivered {
                            msg: tlp.msg,
                            dir,
                            at: fin,
                        });
                    }
                    // fall through to start the next TLP at `fin`
                    if let Some(next) = d.next_tlp() {
                        let t = scale_time(cfg.tlp_time(next.wire_bytes), degrade);
                        d.busy_time += t;
                        d.current = Some((next, fin + t));
                    }
                }
                Some((_, fin)) => return Some(fin),
                None => {
                    match d.next_tlp() {
                        Some(next) => {
                            let t = scale_time(cfg.tlp_time(next.wire_bytes), degrade);
                            d.busy_time += t;
                            d.current = Some((next, now + t));
                        }
                        None => return None,
                    }
                }
            }
        }
    }

    /// Bytes serialized so far in a direction (wire bytes incl. overhead).
    pub fn bytes_serialized(&self, dir: Dir) -> u64 {
        self.dirs[dir as usize].bytes_serialized
    }

    /// Busy time accumulated in a direction.
    pub fn busy_time(&self, dir: Dir) -> Time {
        self.dirs[dir as usize].busy_time
    }

    /// Queued TLPs in a direction (diagnostics / backpressure).
    pub fn queue_depth(&self, dir: Dir) -> usize {
        self.dirs[dir as usize].queued()
    }

    /// True if a direction has nothing queued or in flight.
    pub fn idle(&self, dir: Dir) -> bool {
        let d = &self.dirs[dir as usize];
        d.current.is_none() && d.queued() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{MICROS, SECONDS};

    fn drain(link: &mut DuplexLink, dir: Dir) -> Vec<Delivered> {
        let mut out = Vec::new();
        let mut now = 0;
        loop {
            let (done, next) = link.pump(now, dir);
            out.extend(done);
            match next {
                Some(t) => now = t,
                None => break,
            }
        }
        out
    }

    #[test]
    fn single_transfer_serialization_time() {
        let mut link = DuplexLink::new(LinkConfig::gen3_x8(), 1);
        link.enqueue_data(Dir::Up, 0, 4096, 1);
        let done = drain(&mut link, Dir::Up);
        assert_eq!(done.len(), 1);
        // 4096 B = 16 TLPs of 256+24 B = 4480 wire bytes at ~63 Gbps.
        let expect = LinkConfig::gen3_x8().tlp_time(280) * 16;
        let got = done[0].at;
        assert!(
            (got as i64 - expect as i64).unsigned_abs() <= 16,
            "got={got} expect={expect}"
        );
    }

    #[test]
    fn directions_are_independent() {
        let mut link = DuplexLink::new(LinkConfig::gen3_x8(), 1);
        link.enqueue_data(Dir::Up, 0, 1_000_000, 1);
        link.enqueue_data(Dir::Down, 0, 1_000_000, 2);
        let up = drain(&mut link, Dir::Up);
        let down = drain(&mut link, Dir::Down);
        // Both complete in one direction's serialization time (full duplex).
        assert_eq!(up.len(), 1);
        assert_eq!(down.len(), 1);
        let dt = (up[0].at as i64 - down[0].at as i64).unsigned_abs();
        assert!(dt <= 16, "duplex skew {dt}");
    }

    #[test]
    fn per_tlp_rr_gives_bandwidth_by_tlp_size() {
        // Source 0 sends 4 KB messages (16 TLPs each), source 1 sends 64 B
        // messages (1 TLP each). Per-TLP RR interleaves one TLP each, so
        // byte share is (256+24):(64+24) ≈ 3.2:1 — the paper's ~4x
        // same-path unfairness (CaseP_same_path).
        let mut link = DuplexLink::new(LinkConfig::gen3_x8(), 2);
        let n = 500;
        for i in 0..n {
            link.enqueue_data(Dir::Up, 0, 4096, i);
        }
        for i in 0..n * 64 {
            link.enqueue_data(Dir::Up, 1, 64, 10_000 + i);
        }
        // Pump for a fixed window, then compare completed bytes.
        let mut now = 0;
        let horizon = 200 * MICROS;
        let mut bytes = [0u64; 2];
        loop {
            let (done, next) = link.pump(now, Dir::Up);
            for d in done {
                if d.at > horizon {
                    continue;
                }
                if d.msg < 10_000 {
                    bytes[0] += 4096;
                } else {
                    bytes[1] += 64;
                }
            }
            match next {
                Some(t) if t <= horizon => now = t,
                _ => break,
            }
        }
        let ratio = bytes[0] as f64 / bytes[1] as f64;
        assert!(
            (2.5..5.0).contains(&ratio),
            "large/small byte ratio {ratio:.2} (bytes {bytes:?})"
        );
    }

    #[test]
    fn aggregate_rate_matches_link_rate() {
        let cfg = LinkConfig::gen3_x8();
        let mut link = DuplexLink::new(cfg, 1);
        let total: u64 = 10_000_000;
        for i in 0..total / 4096 {
            link.enqueue_data(Dir::Up, 0, 4096, i);
        }
        let done = drain(&mut link, Dir::Up);
        let last = done.last().unwrap().at;
        let goodput = (total as f64 * 8.0) * SECONDS as f64 / last as f64;
        // Goodput = 256 B payload per max(wire time, TLP floor).
        let expect = 256.0 * 8.0 / cfg.tlp_time(280) as f64 * SECONDS as f64;
        assert!(
            ((goodput - expect) / expect).abs() < 0.01,
            "goodput={:.2}Gbps expect={:.2}Gbps",
            goodput / 1e9,
            expect / 1e9
        );
    }

    #[test]
    fn read_request_is_small() {
        let mut link = DuplexLink::new(LinkConfig::gen3_x8(), 1);
        link.enqueue_read_req(Dir::Up, 0, 7);
        let done = drain(&mut link, Dir::Up);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].at, LinkConfig::gen3_x8().min_tlp_time); // floor-bound
    }

    #[test]
    fn degraded_link_halves_goodput_and_heals() {
        // Same transfer drained healthy vs at factor 0.5: the degraded wire
        // takes 2x as long; healing restores the native rate.
        let drain_time = |factor: f64| {
            let mut link = DuplexLink::new(LinkConfig::gen3_x8(), 1);
            link.set_degradation(factor);
            for i in 0..100 {
                link.enqueue_data(Dir::Up, 0, 4096, i);
            }
            drain(&mut link, Dir::Up).last().unwrap().at
        };
        let healthy = drain_time(1.0);
        let cut = drain_time(0.5);
        let ratio = cut as f64 / healthy as f64;
        assert!((ratio - 2.0).abs() < 0.01, "ratio {ratio:.3}");
        // A healed link is indistinguishable from one never degraded.
        let mut link = DuplexLink::new(LinkConfig::gen3_x8(), 1);
        link.set_degradation(0.25);
        link.set_degradation(1.0);
        for i in 0..100 {
            link.enqueue_data(Dir::Up, 0, 4096, i);
        }
        assert_eq!(drain(&mut link, Dir::Up).last().unwrap().at, healthy);
    }

    #[test]
    fn lazy_pumping_catches_up() {
        // Start the pipe at t=0, then pump far in the future: all queued
        // TLPs complete at their correct serialized times, not at `now`.
        let mut link = DuplexLink::new(LinkConfig::gen3_x8(), 1);
        for i in 0..10 {
            link.enqueue_data(Dir::Up, 0, 256, i);
        }
        let (started, _) = link.pump(0, Dir::Up);
        assert!(started.is_empty());
        let (done, next) = link.pump(SECONDS, Dir::Up);
        assert_eq!(done.len(), 10);
        assert!(next.is_none());
        // Completion stamps are increasing and spaced by one TLP time.
        for w in done.windows(2) {
            assert!(w[1].at > w[0].at);
        }
        assert!(done.last().unwrap().at < MICROS);
    }
}
