//! DMA operation layer over the duplex link: read round-trips, writes,
//! outstanding-tag limits, and root-complex turnaround latency.
//!
//! A DMA **read** of S bytes from host memory (the accelerator fetching a
//! payload) costs: one read-request TLP Up, root-complex turnaround, then
//! ⌈S/MaxPayload⌉ completion TLPs Down. A DMA **write** (pushing results or
//! inline RX data to the host) costs data TLPs Up. The asymmetry is the
//! whole point: function-call-mode ingress loads the *Down* direction while
//! everything else loads *Up*, which is why mixing paths recovers the
//! full-duplex bandwidth (Fig 3f).
//!
//! Tag limit: real DMA engines support a bounded number of outstanding
//! non-posted reads (we default to 32, typical for FPGA hard IP). When tags
//! are exhausted further reads queue — the paper's "running out of PCIe
//! credits" stall.

use super::link::{Delivered, Dir, DuplexLink, LinkConfig};
use crate::util::units::{Time, NANOS};
use std::collections::{HashMap, VecDeque};

/// Kind of a completed DMA operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    Read,
    Write,
}

/// A completed DMA operation, surfaced to the simulation wiring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpComplete {
    pub op: u64,
    pub kind: OpKind,
    pub at: Time,
}

/// Fabric configuration.
#[derive(Debug, Clone, Copy)]
pub struct FabricConfig {
    pub link: LinkConfig,
    /// Outstanding read tags per source (DMA engine).
    pub read_tags: usize,
    /// Root-complex turnaround: request arrival → first completion queued.
    pub rc_latency: Time,
}

impl FabricConfig {
    pub fn gen3_x8() -> Self {
        FabricConfig {
            link: LinkConfig::gen3_x8(),
            read_tags: 32,
            rc_latency: 250 * NANOS, // typical host memory + RC pipeline
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct PendingRead {
    op: u64,
    bytes: u64,
}

/// Internal message-id namespace: reads use two link messages (request +
/// completion), writes one. We tag the phase in the low bits.
const PHASE_READ_REQ: u64 = 0;
const PHASE_READ_DATA: u64 = 1;
const PHASE_WRITE: u64 = 2;

fn msg_id(op: u64, phase: u64) -> u64 {
    op << 2 | phase
}
fn msg_op(msg: u64) -> u64 {
    msg >> 2
}
fn msg_phase(msg: u64) -> u64 {
    msg & 0b11
}

/// DMA fabric shared by all sources on one PCIe link.
#[derive(Debug)]
pub struct Fabric {
    cfg: FabricConfig,
    link: DuplexLink,
    /// Per-source FIFO of reads waiting for a free tag.
    read_waiting: Vec<VecDeque<PendingRead>>,
    /// Per-source count of in-flight reads (tag usage).
    read_inflight: Vec<usize>,
    /// op → (source, bytes) for reads whose completions are pending.
    read_ctx: HashMap<u64, (usize, u64)>,
    /// Reads whose request TLP arrived; completion data queued after
    /// rc_latency. (ready_time, op)
    rc_pipe: VecDeque<(Time, u64)>,
    /// Reused scratch for link deliveries (allocation-free pumping).
    scratch: Vec<Delivered>,
}

impl Fabric {
    pub fn new(cfg: FabricConfig, sources: usize) -> Self {
        Fabric {
            cfg,
            link: DuplexLink::new(cfg.link, sources),
            read_waiting: (0..sources).map(|_| VecDeque::new()).collect(),
            read_inflight: vec![0; sources],
            read_ctx: HashMap::new(),
            rc_pipe: VecDeque::new(),
            scratch: Vec::new(),
        }
    }

    pub fn config(&self) -> &FabricConfig {
        &self.cfg
    }

    pub fn link(&self) -> &DuplexLink {
        &self.link
    }

    /// Fault injection: scale the link's per-direction bandwidth by
    /// `factor` ∈ (0, 1] (1.0 restores full health). See [`crate::faults`].
    pub fn set_link_degradation(&mut self, factor: f64) {
        self.link.set_degradation(factor);
    }

    /// Issue a DMA read of `bytes` host-memory bytes for `source`.
    pub fn read(&mut self, source: usize, bytes: u64, op: u64) {
        debug_assert!(!self.read_ctx.contains_key(&op), "duplicate op id {op}");
        if self.read_inflight[source] < self.cfg.read_tags {
            self.start_read(source, bytes, op);
        } else {
            self.read_waiting[source].push_back(PendingRead { op, bytes });
        }
    }

    fn start_read(&mut self, source: usize, bytes: u64, op: u64) {
        self.read_inflight[source] += 1;
        self.read_ctx.insert(op, (source, bytes));
        self.link
            .enqueue_read_req(Dir::Up, source, msg_id(op, PHASE_READ_REQ));
    }

    /// Issue a DMA write of `bytes` to host memory for `source`.
    pub fn write(&mut self, source: usize, bytes: u64, op: u64) {
        self.link
            .enqueue_data(Dir::Up, source, bytes, msg_id(op, PHASE_WRITE));
    }

    /// Issue a host→device transfer (e.g. MMIO/descriptor push) — data TLPs
    /// in the Down direction. Completion surfaces as a Write completion.
    pub fn push_down(&mut self, source: usize, bytes: u64, op: u64) {
        self.link
            .enqueue_data(Dir::Down, source, bytes, msg_id(op, PHASE_WRITE));
    }

    fn handle_delivery(&mut self, d: Delivered, out: &mut Vec<OpComplete>) {
        let op = msg_op(d.msg);
        match msg_phase(d.msg) {
            PHASE_READ_REQ => {
                // Request reached the host; data flows back after RC latency.
                self.rc_pipe.push_back((d.at + self.cfg.rc_latency, op));
            }
            PHASE_READ_DATA => {
                let (source, _) = self.read_ctx.remove(&op).expect("unknown read op");
                self.read_inflight[source] -= 1;
                // A waiting read can now take the freed tag.
                if let Some(next) = self.read_waiting[source].pop_front() {
                    self.start_read(source, next.bytes, next.op);
                }
                out.push(OpComplete {
                    op,
                    kind: OpKind::Read,
                    at: d.at,
                });
            }
            PHASE_WRITE => {
                out.push(OpComplete {
                    op,
                    kind: OpKind::Write,
                    at: d.at,
                });
            }
            _ => unreachable!(),
        }
    }

    /// Advance everything to `now`; returns completed ops and the earliest
    /// future time the fabric needs pumping again (None = fully idle).
    ///
    /// Allocates a fresh `Vec` per call; the simulation hot path uses
    /// [`Self::pump_into`] with a reused buffer instead.
    pub fn pump(&mut self, now: Time) -> (Vec<OpComplete>, Option<Time>) {
        let mut done = Vec::new();
        let next = self.pump_into(now, &mut done);
        (done, next)
    }

    /// Allocation-free pump: appends completed ops to `out` (which the
    /// caller reuses across calls) and returns the next wake time.
    pub fn pump_into(&mut self, now: Time, out: &mut Vec<OpComplete>) -> Option<Time> {
        // Iterate because link completions can enqueue new TLPs (rc_pipe →
        // completion data) that may themselves complete by `now`.
        let mut deliveries = std::mem::take(&mut self.scratch);
        loop {
            let mut progressed = false;
            for dir in [Dir::Up, Dir::Down] {
                deliveries.clear();
                let _ = self.link.pump_into(now, dir, &mut deliveries);
                for d in deliveries.drain(..) {
                    progressed = true;
                    self.handle_delivery(d, out);
                }
            }
            // Release read completions whose RC latency has elapsed.
            while let Some(&(ready, op)) = self.rc_pipe.front() {
                if ready <= now {
                    self.rc_pipe.pop_front();
                    let (source, bytes) = self.read_ctx[&op];
                    self.link.enqueue_data(
                        Dir::Down,
                        source,
                        bytes,
                        msg_id(op, PHASE_READ_DATA),
                    );
                    progressed = true;
                } else {
                    break;
                }
            }
            if !progressed {
                break;
            }
        }
        // Next wake: earliest of in-flight TLP finishes and RC releases.
        // (The pumps below deliver nothing — the loop above ran to a
        // fixpoint — so the scratch stays empty.)
        let mut next: Option<Time> = None;
        for dir in [Dir::Up, Dir::Down] {
            deliveries.clear();
            let t = self.link.pump_into(now, dir, &mut deliveries);
            debug_assert!(deliveries.is_empty());
            next = merge_min(next, t);
        }
        self.scratch = deliveries;
        if let Some(&(ready, _)) = self.rc_pipe.front() {
            next = merge_min(next, Some(ready));
        }
        next
    }

    /// True when no work is queued or in flight anywhere.
    pub fn idle(&self) -> bool {
        self.link.idle(Dir::Up)
            && self.link.idle(Dir::Down)
            && self.rc_pipe.is_empty()
            && self.read_ctx.is_empty()
    }
}

fn merge_min(a: Option<Time>, b: Option<Time>) -> Option<Time> {
    match (a, b) {
        (Some(x), Some(y)) => Some(x.min(y)),
        (x, None) => x,
        (None, y) => y,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{Rate, MICROS, SECONDS};

    /// Drive the fabric to completion, returning all op completions.
    fn drain(fab: &mut Fabric) -> Vec<OpComplete> {
        let mut out = Vec::new();
        let mut now = 0;
        loop {
            let (done, next) = fab.pump(now);
            out.extend(done);
            match next {
                Some(t) => now = t.max(now + 1),
                None => break,
            }
        }
        out
    }

    #[test]
    fn read_round_trip_latency() {
        let cfg = FabricConfig::gen3_x8();
        let mut fab = Fabric::new(cfg, 1);
        fab.read(0, 4096, 1);
        let done = drain(&mut fab);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].kind, OpKind::Read);
        // request (~28 B, floor-bound) + 250 ns RC + 16 completion TLPs.
        let req = cfg.link.tlp_time(28);
        let data = cfg.link.tlp_time(280) * 16;
        let expect = req + cfg.rc_latency + data;
        let got = done[0].at;
        assert!(
            (got as i64 - expect as i64).unsigned_abs() < 100,
            "got={got} expect={expect}"
        );
    }

    #[test]
    fn writes_only_load_up_direction() {
        let mut fab = Fabric::new(FabricConfig::gen3_x8(), 1);
        for i in 0..100 {
            fab.write(0, 4096, i);
        }
        let done = drain(&mut fab);
        assert_eq!(done.len(), 100);
        assert_eq!(fab.link.bytes_serialized(Dir::Down), 0);
        assert!(fab.link.bytes_serialized(Dir::Up) > 100 * 4096);
    }

    #[test]
    fn reads_load_mostly_down_direction() {
        let mut fab = Fabric::new(FabricConfig::gen3_x8(), 1);
        for i in 0..100 {
            fab.read(0, 4096, i);
        }
        let done = drain(&mut fab);
        assert_eq!(done.len(), 100);
        let up = fab.link.bytes_serialized(Dir::Up);
        let down = fab.link.bytes_serialized(Dir::Down);
        assert!(up < 100 * 64, "up={up} (requests only)");
        assert!(down > 100 * 4096, "down={down} (completion data)");
    }

    #[test]
    fn tag_limit_throttles_read_issue() {
        let mut cfg = FabricConfig::gen3_x8();
        cfg.read_tags = 2;
        cfg.rc_latency = 10 * MICROS; // long RC latency exposes the limit
        let mut fab = Fabric::new(cfg, 1);
        for i in 0..8 {
            fab.read(0, 256, i);
        }
        let done = drain(&mut fab);
        assert_eq!(done.len(), 8);
        // With 2 tags and 10us RC latency, 8 reads need ≥ 4 RC "generations":
        // total time must exceed 3 full RC latencies.
        assert!(
            done.last().unwrap().at > 3 * 10 * MICROS,
            "last={}",
            done.last().unwrap().at
        );
    }

    #[test]
    fn duplex_reads_and_writes_overlap() {
        // Same aggregate bytes, (a) all writes (Up only) vs (b) half reads +
        // half writes (both directions): (b) finishes materially earlier.
        let total_msgs = 400;
        let mut all_writes = Fabric::new(FabricConfig::gen3_x8(), 2);
        for i in 0..total_msgs {
            all_writes.write(i as usize % 2, 4096, i);
        }
        let t_writes = drain(&mut all_writes).last().unwrap().at;

        let mut mixed = Fabric::new(FabricConfig::gen3_x8(), 2);
        for i in 0..total_msgs {
            if i % 2 == 0 {
                mixed.write(0, 4096, i);
            } else {
                mixed.read(1, 4096, i);
            }
        }
        let t_mixed = drain(&mut mixed).last().unwrap().at;
        assert!(
            (t_mixed as f64) < 0.65 * t_writes as f64,
            "mixed={t_mixed} writes={t_writes}"
        );
    }

    #[test]
    fn aggregate_read_bandwidth_near_line_rate() {
        let cfg = FabricConfig::gen3_x8();
        let mut fab = Fabric::new(cfg, 1);
        let n: u64 = 2000;
        for i in 0..n {
            fab.read(0, 4096, i);
        }
        let done = drain(&mut fab);
        let last = done.last().unwrap().at;
        let goodput = Rate((n * 4096) as f64 * 8.0 * SECONDS as f64 / last as f64);
        // Ceiling: 256 B payload per max(wire, TLP-floor) occupancy.
        let ceiling = cfg.link.effective_payload_rate(4096).as_gbps();
        assert!(
            goodput.as_gbps() > 0.95 * ceiling,
            "goodput={} ceiling={ceiling:.1}",
            goodput
        );
    }
}
