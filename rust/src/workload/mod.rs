//! Application workload models (§5.4's end-to-end evaluations).
//!
//! Each builder turns an application description into the [`FlowSpec`]s the
//! system engine runs:
//!
//! - [`mica`] — low-latency key-value serving (MICA): 50/50 GET/SET over
//!   small values; each user's traffic invokes the AES (encryption) and
//!   SHA1-HMAC (authentication) engines of a secure network application
//!   (Fig 11a).
//! - [`live_migration`] — the provider's background bulk stream: MTU-sized
//!   messages through the cipher engine, best-effort (harvests leftover
//!   capacity under Arcus; tramples tenants without it).
//! - [`fio`] — storage benchmark patterns (Fig 6, Fig 11b): random reads
//!   and sequential writes at configurable sizes/depths.
//! - [`rocksdb`] — the LSM engine's flush+compaction I/O with offloaded
//!   checksum+compression (Table 4); modeled as function-call accelerator
//!   flows sized like SST blocks.
//! - [`gen`] — the population workload layer: N users with Zipf popularity,
//!   Pareto sizes, a diurnal envelope, and correlated flash-crowd epochs,
//!   multiplexed deterministically onto the configured flows.
//! - [`trace`] — the compact varint binary arrival-trace format behind
//!   `arcus trace record`/`replay`.

pub mod fio;
pub mod gen;
pub mod lsm;
pub mod mica;
pub mod trace;

pub use fio::{fio_read_flow, fio_write_flow, FioJob};
pub use gen::{
    build_population, record_trace, user_block, BurstEpoch, FairnessReport, PopAccounting,
    PopArrival, PopArrivals, PopTables, PopulationConfig,
};
pub use lsm::{LsmConfig, LsmTraffic};
pub use mica::{live_migration_flow, mica_flows, MicaUser};
pub use trace::{TraceData, TraceRecord};

use crate::flow::FlowSpec;

/// Re-number flow ids sequentially (builders produce ids starting at 0; use
/// this after concatenating several builders' outputs).
pub fn renumber(mut flows: Vec<FlowSpec>) -> Vec<FlowSpec> {
    for (i, f) in flows.iter_mut().enumerate() {
        f.id = i;
    }
    flows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{Path, Slo, TrafficPattern};
    use crate::util::units::Rate;

    #[test]
    fn renumber_assigns_sequential_ids() {
        let mk = |id| {
            FlowSpec::new(
                id,
                0,
                Path::FunctionCall,
                TrafficPattern::fixed(64, 0.1, Rate::gbps(1.0)),
                Slo::BestEffort,
                0,
            )
        };
        let flows = renumber(vec![mk(5), mk(5), mk(0)]);
        assert_eq!(flows.iter().map(|f| f.id).collect::<Vec<_>>(), vec![0, 1, 2]);
    }
}
