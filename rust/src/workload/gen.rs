//! Deterministic population workload generator: N users multiplexed onto the
//! configured flows.
//!
//! The paper motivates Arcus with traffic that is "diverse, hard to predict,
//! and mixed across users" — this module models that population explicitly
//! instead of one synthetic pattern per tenant. Each flow carries a
//! contiguous block of users; per-arrival draws compose four classic
//! ingredients:
//!
//! - **Zipf user popularity** — which user issues the next op (rank 0 is the
//!   flow's hottest user), sampled by binary search over one shared
//!   cumulative-weight table.
//! - **Pareto message sizes** — heavy-tailed op sizes via
//!   [`crate::util::Rng::pareto`], clamped to `[pareto_xm, max_bytes]`.
//! - **Diurnal rate envelope** — `1 + depth·sin(2πt/period)` scales the
//!   arrival rate over the run.
//! - **Correlated burst epochs** — flash crowds: pre-scheduled windows in
//!   which *every* flow of one tenant multiplies its rate, so users within a
//!   tenant surge together.
//!
//! Determinism: every stochastic choice comes from a per-flow RNG stream
//! keyed by `(seed, flow id)` plus one shared stream for the epoch schedule,
//! all derived before the first event fires. Nothing depends on event-queue
//! discipline, thread count, or wall time, so population runs produce
//! byte-identical [`canonical()`](crate::system::SystemReport::canonical)
//! reports across queue implementations — the same gate the rest of the
//! system is held to.
//!
//! Flyweight state: per-user accounting is a struct-of-arrays of a few
//! machine words ([`PopAccounting`]) — `u32` op count, `u64` byte count, and
//! one `u64` packing eight saturating log₂ latency-bucket counters — so a
//! million users cost ~20 MB and the per-event hot path allocates nothing.

use std::sync::Arc;

use super::trace::{TraceRecord, OP_INJECT};
use crate::util::units::{Rate, Time, MICROS};
use crate::util::Rng;

/// RNG stream id base for per-flow population generators (distinct from
/// `TrafficGen`'s `0x7F0 + flow` so a population run never replays a
/// pattern run's draws).
const POP_FLOW_STREAM: u64 = 0xBEE0_0000;
/// RNG stream id for the shared flash-crowd epoch schedule.
const POP_EPOCH_STREAM: u64 = 0xEB0C;

/// Number of packed per-user latency buckets (log₂ microseconds).
const LAT_BUCKETS: u32 = 8;

/// Configuration for the population workload layer (`[population]` table).
#[derive(Debug, Clone, PartialEq)]
pub struct PopulationConfig {
    /// Total users across all flows (each flow gets a contiguous block).
    pub users: usize,
    /// Zipf exponent for user popularity within a flow (0 = uniform).
    pub zipf_s: f64,
    /// Pareto shape for message sizes; must exceed 1 so the mean is finite.
    pub pareto_alpha: f64,
    /// Pareto scale = minimum message size (bytes).
    pub pareto_xm: u64,
    /// Clamp for tail draws (bytes); keeps one draw from eating the run.
    pub max_bytes: u64,
    /// Diurnal envelope period (ps); 0 disables the envelope.
    pub diurnal_period: Time,
    /// Diurnal envelope depth in [0, 1).
    pub diurnal_depth: f64,
    /// Number of flash-crowd epochs scheduled across the run.
    pub burst_epochs: usize,
    /// Rate multiplier inside an epoch (≥ 1).
    pub burst_factor: f64,
    /// Length of each epoch (ps).
    pub burst_span: Time,
}

impl Default for PopulationConfig {
    fn default() -> Self {
        PopulationConfig {
            users: 10_000,
            zipf_s: 1.1,
            pareto_alpha: 1.3,
            pareto_xm: 64,
            max_bytes: 64 * 1024,
            diurnal_period: 0,
            diurnal_depth: 0.0,
            burst_epochs: 0,
            burst_factor: 3.0,
            burst_span: MICROS * 500,
        }
    }
}

impl PopulationConfig {
    /// Validate the configuration against `n_flows` flows.
    pub fn validate(&self, n_flows: usize) -> Result<(), String> {
        if self.users == 0 {
            return Err("population users must be ≥ 1".into());
        }
        if self.users > 4_000_000 {
            return Err(format!(
                "population of {} users exceeds the 4M cap (per-user state is \
                 ~20 bytes; raise the cap deliberately if you have the memory)",
                self.users
            ));
        }
        if n_flows > 0 && self.users < n_flows {
            return Err(format!(
                "population of {} users cannot cover {} flows — every flow \
                 carries a contiguous user block, so raise `users` to at \
                 least the flow count or drop flows",
                self.users, n_flows
            ));
        }
        if !self.zipf_s.is_finite() || !(0.0..=8.0).contains(&self.zipf_s) {
            return Err(format!("zipf_s must be in [0, 8] (got {})", self.zipf_s));
        }
        if !self.pareto_alpha.is_finite() || self.pareto_alpha <= 1.0 || self.pareto_alpha > 16.0 {
            return Err(format!(
                "pareto_alpha must be in (1, 16] — α ≤ 1 has no finite mean \
                 size, so no arrival rate can track a byte load (got {})",
                self.pareto_alpha
            ));
        }
        if self.pareto_xm == 0 || self.max_bytes < self.pareto_xm {
            return Err(format!(
                "need pareto_xm ≥ 1 and max_bytes ≥ pareto_xm (got {}/{})",
                self.pareto_xm, self.max_bytes
            ));
        }
        if self.max_bytes > 16 * 1024 * 1024 {
            return Err(format!("max_bytes {} exceeds 16 MiB", self.max_bytes));
        }
        if !(0.0..1.0).contains(&self.diurnal_depth) {
            return Err(format!(
                "diurnal_depth must be in [0, 1) so the envelope stays \
                 positive (got {})",
                self.diurnal_depth
            ));
        }
        if self.diurnal_period > 0 && self.diurnal_period < MICROS {
            return Err("diurnal_period under 1 µs would alias with per-arrival gaps".into());
        }
        if self.burst_epochs > 64 {
            return Err(format!("burst_epochs {} exceeds 64", self.burst_epochs));
        }
        if !self.burst_factor.is_finite() || !(1.0..=64.0).contains(&self.burst_factor) {
            return Err(format!("burst_factor must be in [1, 64] (got {})", self.burst_factor));
        }
        if self.burst_epochs > 0 && self.burst_span < MICROS {
            return Err("burst_span must be ≥ 1 µs when epochs are scheduled".into());
        }
        Ok(())
    }

    /// Mean message size implied by the (untruncated) Pareto; the clamp to
    /// `max_bytes` pulls the true mean slightly below this, which the
    /// conformance tolerances absorb.
    pub fn mean_bytes(&self) -> f64 {
        let m = self.pareto_alpha * self.pareto_xm as f64 / (self.pareto_alpha - 1.0);
        m.min(self.max_bytes as f64)
    }
}

/// The contiguous user block `(base, count)` that flow `flow` of `n_flows`
/// owns out of `users` total. Blocks tile the population exactly; the first
/// `users % n_flows` flows carry one extra user.
pub fn user_block(users: usize, n_flows: usize, flow: usize) -> (u32, u32) {
    debug_assert!(flow < n_flows && users >= n_flows);
    let base_cnt = users / n_flows;
    let extra = users % n_flows;
    let base = flow * base_cnt + flow.min(extra);
    let count = base_cnt + usize::from(flow < extra);
    (base as u32, count as u32)
}

/// One flash-crowd epoch: every flow of `tenant` multiplies its arrival rate
/// by the configured factor while `start ≤ t < end`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BurstEpoch {
    pub start: Time,
    pub end: Time,
    pub tenant: u32,
}

/// Shared, immutable tables built once per run: the Zipf cumulative-weight
/// prefix table (sized for the largest per-flow block; a smaller block
/// samples from its prefix) and the flash-crowd epoch schedule.
#[derive(Debug)]
pub struct PopTables {
    zipf_cum: Vec<f64>,
    epochs: Vec<BurstEpoch>,
}

impl PopTables {
    /// Build the shared tables. `max_block` is the largest per-flow user
    /// count ([`user_block`]'s maximum); `n_tenants` round-robins epochs.
    pub fn build(
        cfg: &PopulationConfig,
        seed: u64,
        n_tenants: usize,
        duration: Time,
        max_block: u32,
    ) -> Self {
        let mut zipf_cum = Vec::with_capacity(max_block as usize);
        let mut cum = 0.0f64;
        for rank in 0..max_block as u64 {
            cum += 1.0 / ((rank + 1) as f64).powf(cfg.zipf_s);
            zipf_cum.push(cum);
        }
        let mut epochs = Vec::with_capacity(cfg.burst_epochs);
        let mut rng = Rng::for_stream(seed, POP_EPOCH_STREAM);
        for e in 0..cfg.burst_epochs {
            let span = cfg.burst_span.min(duration);
            let start = rng.range_u64(0, duration.saturating_sub(span));
            epochs.push(BurstEpoch {
                start,
                end: start + span,
                tenant: (e % n_tenants.max(1)) as u32,
            });
        }
        PopTables { zipf_cum, epochs }
    }

    /// Whether tenant `tenant` is inside a flash-crowd epoch at `at`.
    #[inline]
    pub fn in_burst(&self, at: Time, tenant: u32) -> bool {
        self.epochs
            .iter()
            .any(|e| e.tenant == tenant && e.start <= at && at < e.end)
    }

    pub fn epochs(&self) -> &[BurstEpoch] {
        &self.epochs
    }
}

/// One generated population arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PopArrival {
    pub at: Time,
    pub user: u32,
    pub bytes: u64,
}

/// Stateful per-flow arrival generator over the flow's user block.
///
/// Pull discipline matches [`crate::flow::TrafficGen`]: `next()` is an
/// unbounded stream of nondecreasing arrival times; the engine stops pulling
/// when a returned arrival lands at/after the run's duration.
#[derive(Debug, Clone)]
pub struct PopArrivals {
    tables: Arc<PopTables>,
    rng: Rng,
    tenant: u32,
    user_base: u32,
    user_count: u32,
    xm: f64,
    alpha: f64,
    min_bytes: u64,
    max_bytes: u64,
    diurnal_period: Time,
    diurnal_depth: f64,
    burst_factor: f64,
    /// Mean inter-arrival gap (ps) at envelope 1.0; `f64::INFINITY` for a
    /// zero offered rate (the stream then never produces an arrival).
    mean_gap: f64,
    next_at: Time,
}

impl PopArrivals {
    pub fn new(
        cfg: &PopulationConfig,
        tables: Arc<PopTables>,
        seed: u64,
        flow: u64,
        tenant: u32,
        user_base: u32,
        user_count: u32,
        offered: Rate,
    ) -> Self {
        debug_assert!(user_count >= 1);
        debug_assert!(user_count as usize <= tables.zipf_cum.len());
        let bpp = offered.bytes_per_ps();
        let mean_gap = if bpp > 0.0 { cfg.mean_bytes() / bpp } else { f64::INFINITY };
        PopArrivals {
            tables,
            rng: Rng::for_stream(seed, POP_FLOW_STREAM + flow),
            tenant,
            user_base,
            user_count,
            xm: cfg.pareto_xm as f64,
            alpha: cfg.pareto_alpha,
            min_bytes: cfg.pareto_xm,
            max_bytes: cfg.max_bytes,
            diurnal_period: cfg.diurnal_period,
            diurnal_depth: cfg.diurnal_depth,
            burst_factor: cfg.burst_factor,
            mean_gap,
            next_at: 0,
        }
    }

    /// Instantaneous rate multiplier at `at`: diurnal × flash-crowd.
    #[inline]
    pub fn envelope(&self, at: Time) -> f64 {
        let mut e = 1.0;
        if self.diurnal_period > 0 {
            let phase = (at % self.diurnal_period) as f64 / self.diurnal_period as f64;
            e *= 1.0 + self.diurnal_depth * (std::f64::consts::TAU * phase).sin();
        }
        if self.tables.in_burst(at, self.tenant) {
            e *= self.burst_factor;
        }
        e
    }

    /// Produce the next arrival at or after the previous one. Allocation-free.
    pub fn next(&mut self) -> PopArrival {
        let at = self.next_at;
        if self.mean_gap.is_infinite() {
            return PopArrival { at: Time::MAX, user: self.user_base, bytes: self.min_bytes };
        }
        // Draw order is part of the format: rank, size, gap. Reordering
        // changes every downstream byte-identity golden.
        let cum = &self.tables.zipf_cum[..self.user_count as usize];
        let u = self.rng.f64() * cum[cum.len() - 1];
        let rank = (cum.partition_point(|&c| c <= u) as u32).min(self.user_count - 1);
        let bytes =
            (self.rng.pareto(self.xm, self.alpha) as u64).clamp(self.min_bytes, self.max_bytes);
        // Exponential inter-arrival with the rate scaled by the envelope at
        // the interval's start — a deterministic piecewise approximation of
        // the inhomogeneous process that is exact whenever gaps are short
        // relative to the envelope period.
        let gap = self.rng.exponential(self.mean_gap / self.envelope(at));
        self.next_at = at.saturating_add(gap.round().max(0.0) as Time);
        PopArrival { at, user: self.user_base + rank, bytes }
    }

    /// Generate all arrivals with `at < until` (test/trace-record helper).
    pub fn take_until(&mut self, until: Time) -> Vec<PopArrival> {
        let mut out = Vec::new();
        loop {
            let a = self.next();
            if a.at >= until {
                return out;
            }
            out.push(a);
        }
    }
}

/// Build one arrival generator per flow from `(tenant, offered rate)` pairs —
/// the single constructor shared by the engine and `arcus trace record`, so a
/// recorded trace enumerates exactly the sequence the engine would generate.
///
/// The caller is responsible for [`PopulationConfig::validate`] against the
/// flow count first; the per-flow constructors only debug-assert.
pub fn build_population(
    cfg: &PopulationConfig,
    seed: u64,
    duration: Time,
    flows: &[(u32, Rate)],
) -> Vec<PopArrivals> {
    let n = flows.len();
    let n_tenants = flows.iter().map(|&(t, _)| t as usize + 1).max().unwrap_or(0);
    let max_block = if n == 0 { 0 } else { user_block(cfg.users, n, 0).1 };
    let tables = Arc::new(PopTables::build(cfg, seed, n_tenants, duration, max_block));
    flows
        .iter()
        .enumerate()
        .map(|(i, &(tenant, offered))| {
            let (base, count) = user_block(cfg.users, n, i);
            PopArrivals::new(cfg, tables.clone(), seed, i as u64, tenant, base, count, offered)
        })
        .collect()
}

/// Enumerate every arrival with `at < duration` across all flows as one
/// time-sorted trace (`arcus trace record` — no engine run needed: the
/// engine pulls each flow's generator in exactly this per-flow order, so
/// replaying these records through per-flow cursors reproduces the run).
pub fn record_trace(
    cfg: &PopulationConfig,
    seed: u64,
    duration: Time,
    flows: &[(u32, Rate)],
) -> Vec<TraceRecord> {
    let mut gens = build_population(cfg, seed, duration, flows);
    let mut out = Vec::new();
    for (f, g) in gens.iter_mut().enumerate() {
        for a in g.take_until(duration) {
            out.push(TraceRecord {
                at: a.at,
                user: a.user,
                flow: f as u32,
                op: OP_INJECT,
                bytes: a.bytes,
            });
        }
    }
    // Stable sort: per-flow order is preserved within equal (at, flow) keys,
    // which is what the per-flow replay cursors re-partition by.
    out.sort_by_key(|r| (r.at, r.flow));
    out
}

/// Per-user fairness summary, printed verbatim (Debug) on the report's
/// `fairness=` canonical line.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FairnessReport {
    /// Configured population size.
    pub users: u64,
    /// Users with ≥ 1 completed op inside the measured window.
    pub active_users: u64,
    /// Jain's fairness index ×10⁶ over per-user attained bytes (attained
    /// rate shares a common span, so bytes and rate give the same index).
    /// 0 when no user completed an op.
    pub jain_ppm: u64,
    /// Worst per-user p99 latency (ps), as the upper bound of the log₂
    /// histogram bucket where that user's 99th percentile falls.
    pub worst_user_p99_ps: u64,
    /// Bytes attained by the single best-served user.
    pub top_user_bytes: u64,
    /// Total bytes attained across the population.
    pub total_bytes: u64,
}

/// Flyweight per-user accounting: struct-of-arrays, a few words per user,
/// no allocation after construction.
#[derive(Debug)]
pub struct PopAccounting {
    ops: Vec<u32>,
    bytes: Vec<u64>,
    /// Eight log₂-µs latency buckets packed as saturating u8 counters.
    lat_hist: Vec<u64>,
}

/// Bucket index for a completion latency: `floor(log₂(max(µs, 1)))`, capped
/// at the last bucket. Bucket `i` spans `[2^i, 2^(i+1))` µs; bucket 0 also
/// absorbs sub-µs completions, bucket 7 everything ≥ 128 µs.
#[inline]
fn lat_bucket(lat: Time) -> u32 {
    ((lat / MICROS).max(1)).ilog2().min(LAT_BUCKETS - 1)
}

/// Upper bound (ps) of latency bucket `b`.
#[inline]
fn bucket_bound(b: u32) -> Time {
    (1u64 << (b + 1)) * MICROS
}

impl PopAccounting {
    pub fn new(users: usize) -> Self {
        PopAccounting {
            ops: vec![0; users],
            bytes: vec![0; users],
            lat_hist: vec![0; users],
        }
    }

    /// Record one completed op for `user`. Allocation-free.
    #[inline]
    pub fn on_complete(&mut self, user: u32, latency: Time, bytes: u64) {
        let u = user as usize;
        debug_assert!(u < self.ops.len());
        self.ops[u] = self.ops[u].saturating_add(1);
        self.bytes[u] = self.bytes[u].saturating_add(bytes);
        let shift = lat_bucket(latency) * 8;
        if (self.lat_hist[u] >> shift) & 0xff != 0xff {
            self.lat_hist[u] += 1u64 << shift;
        }
    }

    /// A user's p99 latency bound from their packed histogram; `None` if the
    /// user completed nothing.
    fn user_p99(hist: u64) -> Option<Time> {
        let total: u64 = (0..LAT_BUCKETS).map(|b| (hist >> (b * 8)) & 0xff).sum();
        if total == 0 {
            return None;
        }
        let target = (total * 99).div_ceil(100);
        let mut cum = 0u64;
        for b in 0..LAT_BUCKETS {
            cum += (hist >> (b * 8)) & 0xff;
            if cum >= target {
                return Some(bucket_bound(b));
            }
        }
        unreachable!("cumulative count reaches total");
    }

    /// Fold the population into its fairness summary, iterating users in
    /// index order so the result is deterministic.
    pub fn report(&self) -> FairnessReport {
        let mut active = 0u64;
        let mut sum = 0.0f64;
        let mut sum_sq = 0.0f64;
        let mut top = 0u64;
        let mut total = 0u64;
        let mut worst_p99 = 0u64;
        for u in 0..self.ops.len() {
            if self.ops[u] == 0 {
                continue;
            }
            active += 1;
            let b = self.bytes[u];
            total = total.saturating_add(b);
            top = top.max(b);
            sum += b as f64;
            sum_sq += (b as f64) * (b as f64);
            if let Some(p99) = Self::user_p99(self.lat_hist[u]) {
                worst_p99 = worst_p99.max(p99);
            }
        }
        let jain_ppm = if active == 0 || sum_sq == 0.0 {
            0
        } else {
            (sum * sum / (active as f64 * sum_sq) * 1e6).round() as u64
        };
        FairnessReport {
            users: self.ops.len() as u64,
            active_users: active,
            jain_ppm,
            worst_user_p99_ps: worst_p99,
            top_user_bytes: top,
            total_bytes: total,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::MILLIS;

    fn cfg() -> PopulationConfig {
        PopulationConfig { users: 1000, ..Default::default() }
    }

    fn gen_for(cfg: &PopulationConfig, seed: u64, flow: u64, tenant: u32) -> PopArrivals {
        let (base, count) = user_block(cfg.users, 4, flow as usize);
        let tables = Arc::new(PopTables::build(cfg, seed, 2, 10 * MILLIS, count + 1));
        PopArrivals::new(cfg, tables, seed, flow, tenant, base, count, Rate::gbps(5.0))
    }

    #[test]
    fn validates_each_field() {
        let ok = cfg();
        assert!(ok.validate(4).is_ok());
        for (bad, needle) in [
            (PopulationConfig { users: 0, ..cfg() }, "users"),
            (PopulationConfig { users: 3, ..cfg() }, "cannot cover"),
            (PopulationConfig { zipf_s: -1.0, ..cfg() }, "zipf_s"),
            (PopulationConfig { pareto_alpha: 1.0, ..cfg() }, "pareto_alpha"),
            (PopulationConfig { pareto_xm: 0, ..cfg() }, "pareto_xm"),
            (PopulationConfig { max_bytes: 8, ..cfg() }, "max_bytes"),
            (PopulationConfig { diurnal_depth: 1.0, ..cfg() }, "diurnal_depth"),
            (PopulationConfig { diurnal_period: 10, ..cfg() }, "diurnal_period"),
            (PopulationConfig { burst_factor: 0.5, ..cfg() }, "burst_factor"),
            (PopulationConfig { burst_epochs: 2, burst_span: 10, ..cfg() }, "burst_span"),
        ] {
            let err = bad.validate(4).unwrap_err();
            assert!(err.contains(needle), "{err} should mention {needle}");
        }
    }

    #[test]
    fn user_blocks_tile_the_population() {
        for (users, flows) in [(10, 3), (1000, 7), (7, 7), (100_000, 64)] {
            let mut next = 0u32;
            for f in 0..flows {
                let (base, count) = user_block(users, flows, f);
                assert_eq!(base, next, "users={users} flows={flows} f={f}");
                assert!(count >= 1);
                next = base + count;
            }
            assert_eq!(next as usize, users);
        }
    }

    #[test]
    fn zipf_concentrates_on_low_ranks() {
        let c = cfg();
        let mut g = gen_for(&c, 7, 0, 0);
        let (base, _) = user_block(c.users, 4, 0);
        let mut counts = vec![0u32; 300];
        for _ in 0..50_000 {
            let a = g.next();
            let rank = (a.user - base) as usize;
            if rank < counts.len() {
                counts[rank] += 1;
            }
        }
        assert!(counts[0] > counts[9] * 3, "rank0={} rank9={}", counts[0], counts[9]);
        assert!(counts[0] > counts[99] * 20, "rank0={} rank99={}", counts[0], counts[99]);
    }

    #[test]
    fn arrivals_deterministic_and_per_flow_decorrelated() {
        let c = cfg();
        let a: Vec<_> = gen_for(&c, 42, 1, 0).take_until(2 * MILLIS);
        let b: Vec<_> = gen_for(&c, 42, 1, 0).take_until(2 * MILLIS);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        let other: Vec<_> = gen_for(&c, 42, 2, 0).take_until(2 * MILLIS);
        assert_ne!(a, other);
    }

    #[test]
    fn sizes_respect_clamp_and_times_are_monotonic() {
        let c = PopulationConfig { max_bytes: 4096, ..cfg() };
        let arrivals = gen_for(&c, 3, 0, 0).take_until(5 * MILLIS);
        let mut prev = 0;
        for a in &arrivals {
            assert!(a.bytes >= c.pareto_xm && a.bytes <= c.max_bytes, "{}", a.bytes);
            assert!(a.at >= prev);
            prev = a.at;
        }
    }

    #[test]
    fn epochs_land_inside_the_run_and_round_robin_tenants() {
        let c = PopulationConfig { burst_epochs: 6, ..cfg() };
        let t = PopTables::build(&c, 11, 3, 10 * MILLIS, 16);
        assert_eq!(t.epochs().len(), 6);
        for (i, e) in t.epochs().iter().enumerate() {
            assert!(e.start < e.end && e.end <= 10 * MILLIS + c.burst_span);
            assert_eq!(e.tenant, (i % 3) as u32);
        }
        // Same-tenant flows see the same epochs; the in_burst probe agrees.
        let e0 = t.epochs()[0];
        assert!(t.in_burst(e0.start, e0.tenant));
        assert!(!t.in_burst(e0.end, e0.tenant));
    }

    #[test]
    fn envelope_composes_diurnal_and_burst() {
        let c = PopulationConfig {
            diurnal_period: 4 * MILLIS,
            diurnal_depth: 0.5,
            burst_epochs: 1,
            burst_factor: 4.0,
            ..cfg()
        };
        let (base, count) = user_block(c.users, 4, 0);
        let tables = Arc::new(PopTables::build(&c, 5, 1, 10 * MILLIS, count));
        // All epochs belong to tenant 0 (n_tenants = 1); a tenant-1 flow sees
        // the pure diurnal envelope, whose sine peaks a quarter period in.
        let calm = PopArrivals::new(&c, tables.clone(), 5, 0, 1, base, count, Rate::gbps(5.0));
        let peak = calm.envelope(MILLIS);
        let trough = calm.envelope(3 * MILLIS);
        assert!((peak / trough - 3.0).abs() < 1e-9, "peak={peak} trough={trough}");
        // A tenant-0 flow is additionally boosted ×4 inside the epoch; even
        // at the diurnal trough that leaves the envelope ≥ 0.5 × 4.
        let hot = PopArrivals::new(&c, tables.clone(), 5, 1, 0, base, count, Rate::gbps(5.0));
        let e = tables.epochs()[0];
        assert!(hot.envelope(e.start) >= 2.0 - 1e-9);
        assert!((hot.envelope(e.start) / calm.envelope(e.start) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn accounting_jain_and_p99() {
        let mut acc = PopAccounting::new(4);
        // Two equally-served users → Jain = 1.0.
        acc.on_complete(0, 3 * MICROS, 1000);
        acc.on_complete(1, 70 * MICROS, 1000);
        let r = acc.report();
        assert_eq!(r.active_users, 2);
        assert_eq!(r.jain_ppm, 1_000_000);
        assert_eq!(r.total_bytes, 2000);
        assert_eq!(r.top_user_bytes, 1000);
        // 70 µs lands in bucket [64,128) → bound 128 µs.
        assert_eq!(r.worst_user_p99_ps, 128 * MICROS);
        // A third user hogging bytes drags the index down.
        acc.on_complete(2, MICROS, 98_000);
        let r = acc.report();
        assert!(r.jain_ppm < 400_000, "jain={}", r.jain_ppm);
        assert_eq!(r.users, 4);
        assert_eq!(r.top_user_bytes, 98_000);
    }

    #[test]
    fn p99_tracks_the_heavy_bucket() {
        let mut acc = PopAccounting::new(1);
        for _ in 0..99 {
            acc.on_complete(0, MICROS, 1); // bucket 0
        }
        acc.on_complete(0, 40 * MICROS, 1); // bucket [32,64)
        // 100 samples: p99 target is the 99th — still in bucket 0.
        assert_eq!(acc.report().worst_user_p99_ps, 2 * MICROS);
        acc.on_complete(0, 40 * MICROS, 1);
        acc.on_complete(0, 40 * MICROS, 1);
        // Now >1% of mass sits high; p99 moves to the hot bucket's bound.
        assert_eq!(acc.report().worst_user_p99_ps, 64 * MICROS);
    }

    #[test]
    fn saturating_histogram_never_overflows_neighbours() {
        let mut acc = PopAccounting::new(1);
        for _ in 0..1000 {
            acc.on_complete(0, MICROS, 1);
        }
        // Bucket 0 saturates at 255; bucket 1 stays empty.
        assert_eq!(acc.lat_hist[0] & 0xff, 0xff);
        assert_eq!((acc.lat_hist[0] >> 8) & 0xff, 0);
    }

    #[test]
    fn zero_rate_flow_never_fires() {
        let c = cfg();
        let (base, count) = user_block(c.users, 4, 0);
        let tables = Arc::new(PopTables::build(&c, 1, 1, MILLIS, count));
        let mut g = PopArrivals::new(&c, tables, 1, 0, 0, base, count, Rate::ZERO);
        assert_eq!(g.next().at, Time::MAX);
    }
}
