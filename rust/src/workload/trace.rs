//! Compact binary arrival-trace format (`arcus trace record` / `replay`).
//!
//! Layout (all integers LEB128 varints via [`crate::util::varint`], same
//! loud-error decode discipline as `obs::dump`):
//!
//! ```text
//! "ARCT"            4-byte magic
//! u16 LE            format version (1)
//! varint            population size (users)
//! varint            flow count
//! varint            record count
//! per record (time-sorted):
//!   varint          time delta from the previous record (ps)
//!   varint          user id
//!   varint          flow id
//!   varint          op (0 = inject; others reserved, rejected on decode)
//!   varint          bytes
//! ```
//!
//! Delta-coded times keep steady-state records at a handful of bytes. A
//! recorded trace replays through the engine to a byte-identical
//! `SystemReport::canonical()`, and real accelerator traces can be converted
//! into this format to drive the simulator with production arrival streams.

use crate::util::units::Time;
use crate::util::varint::{get_varint, put_varint};

const MAGIC: &[u8; 4] = b"ARCT";
const VERSION: u16 = 1;

/// The only operation defined by format version 1: inject one message.
pub const OP_INJECT: u8 = 0;

/// One trace record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceRecord {
    /// Absolute virtual time (ps); encoded as a delta from the previous record.
    pub at: Time,
    pub user: u32,
    pub flow: u32,
    pub op: u8,
    pub bytes: u64,
}

/// A decoded trace: header context plus time-sorted records.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceData {
    /// Population size the trace was recorded against.
    pub users: u64,
    /// Flow count the trace was recorded against.
    pub flows: u64,
    pub records: Vec<TraceRecord>,
}

/// Serialize a trace. Records must be sorted by time (delta coding cannot
/// represent a rewind) and reference users/flows inside the header bounds —
/// violations fail loudly here rather than producing a dump that decodes to
/// something else.
pub fn write(users: u64, flows: u64, records: &[TraceRecord]) -> Result<Vec<u8>, String> {
    let mut out = Vec::with_capacity(16 + records.len() * 6);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    put_varint(&mut out, users);
    put_varint(&mut out, flows);
    put_varint(&mut out, records.len() as u64);
    let mut prev = 0u64;
    for (i, r) in records.iter().enumerate() {
        if r.at < prev {
            return Err(format!(
                "record {i} rewinds time ({} < {prev}) — sort records before encoding",
                r.at
            ));
        }
        if u64::from(r.user) >= users || u64::from(r.flow) >= flows {
            return Err(format!(
                "record {i} references user {}/flow {} outside the header's \
                 {users} users / {flows} flows",
                r.user, r.flow
            ));
        }
        put_varint(&mut out, r.at - prev);
        put_varint(&mut out, u64::from(r.user));
        put_varint(&mut out, u64::from(r.flow));
        put_varint(&mut out, u64::from(r.op));
        put_varint(&mut out, r.bytes);
        prev = r.at;
    }
    Ok(out)
}

/// Decode a trace produced by [`write`] (or converted from a real capture).
pub fn read(buf: &[u8]) -> Result<TraceData, String> {
    if buf.len() < 6 || &buf[0..4] != MAGIC {
        return Err("not an arcus trace (bad magic)".into());
    }
    let version = u16::from_le_bytes([buf[4], buf[5]]);
    if version != VERSION {
        return Err(format!("unsupported trace version {version}"));
    }
    let mut pos = 6usize;
    let users = get_varint(buf, &mut pos)?;
    let flows = get_varint(buf, &mut pos)?;
    let n = get_varint(buf, &mut pos)? as usize;
    // Every record is at least five one-byte varints, so a well-formed count
    // can never exceed remaining/5 — the same remaining-bytes discipline as
    // the series dump keeps an inflated count from over-allocating before
    // the record loop notices the truncation.
    if n > buf.len().saturating_sub(pos) / 5 {
        return Err("record count exceeds trace size".into());
    }
    let mut records = Vec::with_capacity(n);
    let mut at = 0u64;
    for i in 0..n {
        let dt = get_varint(buf, &mut pos)?;
        at = at
            .checked_add(dt)
            .ok_or_else(|| format!("record {i}: time overflows u64"))?;
        let user = get_varint(buf, &mut pos)?;
        let flow = get_varint(buf, &mut pos)?;
        let op = get_varint(buf, &mut pos)?;
        let bytes = get_varint(buf, &mut pos)?;
        if user >= users || flow >= flows {
            return Err(format!(
                "record {i} references user {user}/flow {flow} outside the \
                 header's {users} users / {flows} flows"
            ));
        }
        if op != u64::from(OP_INJECT) {
            return Err(format!("record {i}: unknown op {op} (version 1 defines op 0 only)"));
        }
        records.push(TraceRecord {
            at,
            user: user as u32,
            flow: flow as u32,
            op: op as u8,
            bytes,
        });
    }
    if pos != buf.len() {
        return Err(format!("{} trailing bytes after the last record", buf.len() - pos));
    }
    Ok(TraceData { users, flows, records })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<TraceRecord> {
        let mut out = Vec::new();
        let mut at = 0u64;
        for i in 0..40u64 {
            at += i * 131 % 977;
            out.push(TraceRecord {
                at,
                user: (i * 7 % 50) as u32,
                flow: (i % 4) as u32,
                op: OP_INJECT,
                bytes: 64 + i * 313 % 9000,
            });
        }
        out
    }

    #[test]
    fn round_trips() {
        let records = sample();
        let buf = write(50, 4, &records).unwrap();
        let data = read(&buf).expect("round trip");
        assert_eq!(data.users, 50);
        assert_eq!(data.flows, 4);
        assert_eq!(data.records, records);
    }

    #[test]
    fn rejects_unsorted_and_out_of_bounds_on_encode() {
        let mut records = sample();
        records.swap(0, 39);
        assert!(write(50, 4, &records).unwrap_err().contains("rewinds"));
        let records = vec![TraceRecord { at: 0, user: 50, flow: 0, op: OP_INJECT, bytes: 1 }];
        assert!(write(50, 4, &records).unwrap_err().contains("outside"));
    }

    #[test]
    fn every_prefix_truncation_errors_never_panics() {
        let buf = write(50, 4, &sample()).unwrap();
        for cut in 0..buf.len() {
            assert!(
                read(&buf[..cut]).is_err(),
                "prefix of {cut}/{} bytes must fail loudly",
                buf.len()
            );
        }
        assert!(read(&buf).is_ok());
    }

    #[test]
    fn rejects_unknown_op_and_bounds_violations_on_decode() {
        let one = |op: u8, user: u32| {
            let mut buf = Vec::new();
            buf.extend_from_slice(MAGIC);
            buf.extend_from_slice(&VERSION.to_le_bytes());
            for v in [2u64, 1, 1, 0, u64::from(user), 0, u64::from(op), 9] {
                crate::util::varint::put_varint(&mut buf, v);
            }
            buf
        };
        assert!(read(&one(1, 0)).unwrap_err().contains("unknown op"));
        assert!(read(&one(0, 5)).unwrap_err().contains("outside"));
        assert!(read(&one(0, 0)).is_ok());
    }

    #[test]
    fn record_count_bounded_by_remaining_bytes() {
        let mut buf = write(50, 4, &sample()[..2]).unwrap();
        // Claim far more records than bytes remain (count varint is one byte
        // here: 2 → 120), then pad so a whole-buffer check would still pass.
        let count_pos = 8; // magic(4) + version(2) + users(1) + flows(1)
        assert_eq!(buf[count_pos], 2);
        buf[count_pos] = 120;
        buf.resize(140, 0);
        assert_eq!(
            read(&buf).err(),
            Some("record count exceeds trace size".to_string()),
            "count must be bounded by bytes remaining"
        );
    }

    #[test]
    fn rejects_garbage_and_trailing_bytes() {
        assert!(read(b"nope").is_err());
        assert!(read(b"ARCT\x02\x00").is_err()); // wrong version
        let mut buf = write(50, 4, &sample()).unwrap();
        buf.push(0);
        assert!(read(&buf).unwrap_err().contains("trailing"));
    }
}
