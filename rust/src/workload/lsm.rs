//! RocksDB-style LSM traffic (Table 4's checksum+compression offload).
//!
//! An LSM engine writes SST files during flush and compaction; every block
//! (typically 4–32 KB) is compressed and checksummed before hitting the
//! filesystem. Offloading both (function-call mode) is the paper's Table 4
//! experiment. This module models the *traffic* an LSM instance generates
//! toward those two engines; the real end-to-end app (with actual
//! compression and PJRT checksums) lives in `apps/`.

use crate::flow::pattern::{Burstiness, SizeDist};
use crate::flow::{FlowKind, FlowSpec, Path, Slo, TrafficPattern};
use crate::util::units::Rate;

/// LSM instance parameters.
#[derive(Debug, Clone, Copy)]
pub struct LsmConfig {
    pub vm: usize,
    /// SST block size (RocksDB default 4 KB; compaction reads bigger).
    pub block_bytes: u64,
    /// Sustained flush+compaction byte rate (MB/s).
    pub write_mbps: f64,
    /// Write amplification from compaction re-writes (each logical byte is
    /// re-compressed/checksummed this many times).
    pub write_amp: f64,
    /// Accelerator SLO for the offload streams.
    pub slo: Slo,
}

impl Default for LsmConfig {
    fn default() -> Self {
        LsmConfig {
            vm: 0,
            block_bytes: 4096,
            write_mbps: 200.0,
            write_amp: 3.0,
            slo: Slo::gbps(5.0),
        }
    }
}

/// The flows an LSM instance drives into the checksum + compression engines.
#[derive(Debug)]
pub struct LsmTraffic {
    pub checksum: FlowSpec,
    pub compress: FlowSpec,
}

impl LsmConfig {
    /// Physical byte rate after write amplification.
    pub fn physical_rate(&self) -> Rate {
        Rate(self.write_mbps * 1e6 * 8.0 * self.write_amp)
    }

    /// Build the two offload flows (ids 0 and 1; renumber when combining).
    pub fn flows(&self, checksum_idx: usize, compress_idx: usize) -> LsmTraffic {
        let line = Rate::gbps(50.0);
        // Compaction produces bursts of back-to-back blocks.
        let pattern = TrafficPattern {
            sizes: SizeDist::Fixed(self.block_bytes),
            load: self.physical_rate().as_bits_per_sec() / line.as_bits_per_sec(),
            line_rate: line,
            burst: Burstiness::OnOff { burst_len: 32 },
        };
        let mk = |id: usize, accel: usize| FlowSpec {
            id,
            vm: self.vm,
            path: Path::FunctionCall,
            pattern: pattern.clone(),
            slo: self.slo,
            accel,
            kind: FlowKind::Accel,
            priority: 1,
        };
        LsmTraffic {
            checksum: mk(0, checksum_idx),
            compress: mk(1, compress_idx),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn physical_rate_includes_amplification() {
        let cfg = LsmConfig { write_mbps: 100.0, write_amp: 3.0, ..Default::default() };
        // 100 MB/s × 3 = 2.4 Gbps.
        assert!((cfg.physical_rate().as_gbps() - 2.4).abs() < 1e-9);
    }

    #[test]
    fn flows_target_both_engines() {
        let t = LsmConfig::default().flows(2, 3);
        assert_eq!(t.checksum.accel, 2);
        assert_eq!(t.compress.accel, 3);
        assert_eq!(t.checksum.path, Path::FunctionCall);
        assert!(matches!(t.compress.pattern.burst, Burstiness::OnOff { .. }));
    }
}
