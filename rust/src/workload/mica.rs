//! MICA-style key-value serving + live-migration workloads (Fig 11a).
//!
//! "Two users run low-latency MICA, each with 50/50 GET/SET. The value
//! sizes are 64 B and 256 B for user1 and user2. Two users share two
//! accelerators, SHA1-HMAC and AES-128-CBC, required by secure network
//! applications. In addition, another live migration (LM) is co-running,
//! contending for the AES accelerator. The LM job sends MTU-sized large
//! messages, i.e. 1500 B."
//!
//! A secure-KV request touches *both* engines (encrypt the value, MAC the
//! message); we model each user as one flow per engine carrying the user's
//! full request stream — the same engine-side load, and contention on both
//! engines, without cross-engine chaining in the DES.

use crate::flow::{FlowKind, FlowSpec, Path, Slo, TrafficPattern};
use crate::flow::pattern::{Burstiness, SizeDist};
use crate::util::units::{Rate, MTU};

/// One MICA tenant.
#[derive(Debug, Clone, Copy)]
pub struct MicaUser {
    pub vm: usize,
    /// Value size (64 B for user1, 256 B for user2 in the paper).
    pub value_bytes: u64,
    /// Offered request rate in Mops.
    pub mops: f64,
    /// Accelerator-throughput SLO per engine.
    pub slo: Slo,
}

impl MicaUser {
    /// The request message on the wire: key (16 B) + header (24 B) + value.
    pub fn message_bytes(&self) -> u64 {
        self.value_bytes + 40
    }

    /// Offered byte rate implied by the op rate.
    pub fn offered(&self) -> Rate {
        Rate(self.mops * 1e6 * self.message_bytes() as f64 * 8.0)
    }
}

/// Flows for a set of MICA users sharing `aes_idx` and `sha_idx` engines on
/// the inline-NIC RX path. Flow ids are assigned sequentially from 0 in
/// (user, engine) order; renumber after combining with other builders.
pub fn mica_flows(users: &[MicaUser], aes_idx: usize, sha_idx: usize) -> Vec<FlowSpec> {
    let mut flows = Vec::new();
    for u in users {
        // 50/50 GET/SET: GETs return the value (engine work on the egress),
        // SETs carry it inbound. Engine-side both directions see the same
        // message mix, so the pattern is a single fixed-size stream.
        let pattern = TrafficPattern {
            sizes: SizeDist::Fixed(u.message_bytes()),
            load: u.offered().as_bits_per_sec() / Rate::gbps(50.0).as_bits_per_sec(),
            line_rate: Rate::gbps(50.0),
            burst: Burstiness::Poisson,
        };
        for &accel in &[aes_idx, sha_idx] {
            flows.push(FlowSpec {
                id: flows.len(),
                vm: u.vm,
                path: Path::InlineNicRx,
                pattern: pattern.clone(),
                slo: u.slo,
                accel,
                kind: FlowKind::Accel,
                priority: 0, // latency-critical class (PANIC priority)
            });
        }
    }
    flows
}

/// The live-migration background stream: MTU messages into the AES engine,
/// best-effort class ("remaining throughput can be harvested by background
/// tasks such as LM", §5.4), low priority under PANIC.
pub fn live_migration_flow(id: usize, vm: usize, aes_idx: usize, gbps: f64) -> FlowSpec {
    FlowSpec {
        id,
        vm,
        path: Path::InlineNicRx,
        pattern: TrafficPattern {
            sizes: SizeDist::Fixed(MTU),
            load: gbps / 50.0,
            line_rate: Rate::gbps(50.0),
            burst: Burstiness::Paced,
        },
        slo: Slo::BestEffort,
        accel: aes_idx,
        kind: FlowKind::Accel,
        priority: 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn message_sizes_include_header() {
        let u = MicaUser { vm: 0, value_bytes: 64, mops: 1.0, slo: Slo::gbps(1.0) };
        assert_eq!(u.message_bytes(), 104);
        // 1 Mops of 104 B messages = 832 Mbps.
        assert!((u.offered().as_gbps() - 0.832).abs() < 1e-9);
    }

    #[test]
    fn two_users_make_four_flows() {
        let users = [
            MicaUser { vm: 0, value_bytes: 64, mops: 2.0, slo: Slo::gbps(2.0) },
            MicaUser { vm: 1, value_bytes: 256, mops: 1.0, slo: Slo::gbps(3.0) },
        ];
        let flows = mica_flows(&users, 0, 1);
        assert_eq!(flows.len(), 4);
        assert_eq!(flows.iter().filter(|f| f.accel == 0).count(), 2);
        assert_eq!(flows.iter().filter(|f| f.accel == 1).count(), 2);
        assert!(flows.iter().all(|f| f.path == Path::InlineNicRx));
        assert!(flows.iter().all(|f| f.priority == 0));
    }

    #[test]
    fn lm_is_best_effort_low_priority() {
        let lm = live_migration_flow(4, 2, 0, 20.0);
        assert_eq!(lm.slo, Slo::BestEffort);
        assert!(lm.priority > 0);
        assert!((lm.pattern.offered().as_gbps() - 20.0).abs() < 1e-9);
    }
}
