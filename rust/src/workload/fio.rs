//! FIO-style storage workloads (Fig 6, Fig 11b).
//!
//! Fig 6: "two users simultaneously send 4 KB random read requests to the
//! SSD", SLOs 300 K / 200 K IOPS under 99th% guarantee.
//!
//! Fig 11b: "two users run reads and writes … reads are 1 KB random reads;
//! writes are 4 KB sequential writes", SLO 2 M read IOPS / 25 K write IOPS,
//! shared RAID-0 of four drives.

use crate::flow::pattern::{Burstiness, SizeDist};
use crate::flow::{FlowKind, FlowSpec, Slo, TrafficPattern};
use crate::util::units::Rate;

/// One FIO job description.
#[derive(Debug, Clone, Copy)]
pub struct FioJob {
    pub vm: usize,
    /// I/O size in bytes.
    pub bs: u64,
    /// Offered rate in IOPS.
    pub offered_iops: f64,
    /// The per-flow SLO.
    pub slo_iops: f64,
}

fn pattern(job: &FioJob, burst: Burstiness) -> TrafficPattern {
    let line = Rate::gbps(50.0);
    let offered_bps = job.offered_iops * job.bs as f64 * 8.0;
    TrafficPattern {
        sizes: SizeDist::Fixed(job.bs),
        load: offered_bps / line.as_bits_per_sec(),
        line_rate: line,
        burst,
    }
}

/// A random-read job (Poisson arrivals: open-loop load generator).
pub fn fio_read_flow(id: usize, job: FioJob) -> FlowSpec {
    FlowSpec {
        id,
        vm: job.vm,
        path: crate::flow::Path::InlineP2p,
        pattern: pattern(&job, Burstiness::Poisson),
        slo: Slo::iops(job.slo_iops),
        accel: 0,
        kind: FlowKind::StorageRead,
        priority: 1,
    }
}

/// A sequential-write job (paced arrivals: the writer streams).
pub fn fio_write_flow(id: usize, job: FioJob) -> FlowSpec {
    FlowSpec {
        id,
        vm: job.vm,
        path: crate::flow::Path::InlineP2p,
        pattern: pattern(&job, Burstiness::Paced),
        slo: Slo::iops(job.slo_iops),
        accel: 0,
        kind: FlowKind::StorageWrite,
        priority: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offered_rate_matches_iops() {
        let job = FioJob { vm: 0, bs: 4096, offered_iops: 360_000.0, slo_iops: 300_000.0 };
        let f = fio_read_flow(0, job);
        // 360K × 4KB × 8 = 11.8 Gbps offered.
        let offered = f.pattern.offered().as_bits_per_sec();
        assert!((offered - 360_000.0 * 4096.0 * 8.0).abs() < 1.0);
        // Mean message rate equals the IOPS.
        assert!((f.pattern.mean_mps() - 360_000.0).abs() < 1.0);
        assert_eq!(f.kind, FlowKind::StorageRead);
    }

    #[test]
    fn write_flow_is_paced_storage_write() {
        let job = FioJob { vm: 1, bs: 4096, offered_iops: 50_000.0, slo_iops: 25_000.0 };
        let f = fio_write_flow(1, job);
        assert_eq!(f.kind, FlowKind::StorageWrite);
        assert_eq!(f.pattern.burst, Burstiness::Paced);
        assert!(matches!(f.slo, Slo::Iops { target, .. } if target == 25_000.0));
    }
}
