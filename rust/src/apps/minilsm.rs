//! Mini LSM storage engine with offloadable checksum + compression
//! (the Table 4 RocksDB experiment).
//!
//! Writes go to a memtable; when it fills, it flushes to an SST: entries
//! packed into blocks, each block **compressed** then **checksummed**.
//! Level-0 SSTs compact into level-1 by merge. Reads check the memtable,
//! then search SSTs newest-first, verifying the block checksum and
//! decompressing on hit.
//!
//! Two backends implement the block pipeline:
//! - [`Backend::Cpu`] — the ext4 baseline: deflate + Fletcher on the
//!   calling (application) thread.
//! - [`Backend::Offload`] — the Arcus path: compression on the offload
//!   pool, checksum through the PJRT accelerator server; the application
//!   thread only coordinates.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::runtime::{fletcher_native, pack_bytes};
use crate::server::{Output, Server, Work};

use super::offload::{compress_cpu, decompress_cpu, CompressorPool};

/// Where block compression/checksum work runs.
pub enum Backend {
    /// On the application thread (the paper's ext4 baseline).
    Cpu,
    /// Offloaded: checksum via the accelerator server, compression via the
    /// offload pool.
    Offload { server: Arc<Server>, tenant: usize, pool: Arc<CompressorPool> },
}

/// Engine configuration.
pub struct MiniLsmConfig {
    /// Flush the memtable when it holds this many bytes.
    pub memtable_bytes: usize,
    /// Target uncompressed SST block size.
    pub block_bytes: usize,
    /// Compact level-0 when it holds this many SSTs.
    pub l0_compact_at: usize,
}

impl Default for MiniLsmConfig {
    fn default() -> Self {
        MiniLsmConfig { memtable_bytes: 256 * 1024, block_bytes: 4096, l0_compact_at: 4 }
    }
}

/// One SST block: compressed entries + checksum.
struct Block {
    /// First key in the block (for binary search).
    first_key: Vec<u8>,
    compressed: Vec<u8>,
    checksum: (u32, u32),
    uncompressed_len: usize,
}

/// A sorted string table.
struct Sst {
    blocks: Vec<Block>,
}

/// Write/compaction statistics (the Table 4 measurements).
#[derive(Debug, Clone, Copy, Default)]
pub struct LsmStats {
    pub puts: u64,
    pub gets: u64,
    pub flushes: u64,
    pub compactions: u64,
    /// Logical bytes written by the application.
    pub logical_bytes: u64,
    /// Physical uncompressed bytes pushed through the block pipeline
    /// (flush + compaction re-writes — the write amplification).
    pub pipeline_bytes: u64,
    /// Bytes after compression.
    pub compressed_bytes: u64,
    /// Checksum verification failures observed on reads.
    pub checksum_failures: u64,
}

/// The engine. Single-writer (wrap in a mutex to share).
pub struct MiniLsm {
    cfg: MiniLsmConfig,
    backend: Backend,
    memtable: BTreeMap<Vec<u8>, Vec<u8>>,
    memtable_bytes: usize,
    /// levels[0] = newest flushes; levels[1] = compacted.
    levels: Vec<Vec<Sst>>,
    pub stats: LsmStats,
}

impl MiniLsm {
    pub fn new(cfg: MiniLsmConfig, backend: Backend) -> Self {
        MiniLsm {
            cfg,
            backend,
            memtable: BTreeMap::new(),
            memtable_bytes: 0,
            levels: vec![Vec::new(), Vec::new()],
            stats: LsmStats::default(),
        }
    }

    pub fn put(&mut self, k: &[u8], v: &[u8]) {
        self.stats.puts += 1;
        self.stats.logical_bytes += (k.len() + v.len()) as u64;
        self.memtable_bytes += k.len() + v.len();
        self.memtable.insert(k.to_vec(), v.to_vec());
        if self.memtable_bytes >= self.cfg.memtable_bytes {
            self.flush();
        }
    }

    pub fn get(&mut self, k: &[u8]) -> Option<Vec<u8>> {
        self.stats.gets += 1;
        if let Some(v) = self.memtable.get(k) {
            return Some(v.clone());
        }
        // Newest-first: level 0 back-to-front, then level 1.
        let mut failures = 0u64;
        let mut found = None;
        'outer: for level in &self.levels {
            for sst in level.iter().rev() {
                if let Some(r) = Self::sst_get(&self.backend, sst, k, &mut failures) {
                    found = Some(r);
                    break 'outer;
                }
            }
        }
        self.stats.checksum_failures += failures;
        found
    }

    /// Force a memtable flush (also used at shutdown).
    pub fn flush(&mut self) {
        if self.memtable.is_empty() {
            return;
        }
        let entries = std::mem::take(&mut self.memtable);
        self.memtable_bytes = 0;
        let sst = self.build_sst(entries.into_iter().collect());
        self.levels[0].push(sst);
        self.stats.flushes += 1;
        if self.levels[0].len() >= self.cfg.l0_compact_at {
            self.compact();
        }
    }

    /// Merge all of L0 (+ existing L1) into one L1 SST.
    fn compact(&mut self) {
        let mut merged: BTreeMap<Vec<u8>, Vec<u8>> = BTreeMap::new();
        // Oldest first so newer SSTs overwrite.
        let l1 = std::mem::take(&mut self.levels[1]);
        let l0 = std::mem::take(&mut self.levels[0]);
        for sst in l1.into_iter().chain(l0.into_iter()) {
            for data in Self::open_blocks(&self.backend, &sst.blocks) {
                let data = data.expect("compaction read: checksum failure");
                for (k, v) in decode_entries(&data) {
                    merged.insert(k, v);
                }
            }
        }
        let sst = self.build_sst(merged.into_iter().collect());
        self.levels[1] = vec![sst];
        self.stats.compactions += 1;
    }

    /// Pack sorted entries into checksummed, compressed blocks.
    fn build_sst(&mut self, entries: Vec<(Vec<u8>, Vec<u8>)>) -> Sst {
        let mut blocks = Vec::new();
        let mut buf = Vec::with_capacity(self.cfg.block_bytes * 2);
        let mut first_key: Option<Vec<u8>> = None;
        // Stage raw blocks first so the offload backend can pipeline them.
        let mut raw: Vec<(Vec<u8>, Vec<u8>)> = Vec::new(); // (first_key, data)
        for (k, v) in entries {
            if first_key.is_none() {
                first_key = Some(k.clone());
            }
            encode_entry(&mut buf, &k, &v);
            if buf.len() >= self.cfg.block_bytes {
                raw.push((first_key.take().unwrap(), std::mem::take(&mut buf)));
            }
        }
        if !buf.is_empty() {
            raw.push((first_key.take().unwrap_or_default(), buf));
        }
        match &self.backend {
            Backend::Cpu => {
                for (first_key, data) in raw {
                    self.stats.pipeline_bytes += data.len() as u64;
                    let compressed = compress_cpu(&data);
                    let checksum = fletcher_native(&pack_bytes(&compressed));
                    self.stats.compressed_bytes += compressed.len() as u64;
                    blocks.push(Block {
                        first_key,
                        compressed,
                        checksum,
                        uncompressed_len: data.len(),
                    });
                }
            }
            Backend::Offload { server, tenant, pool } => {
                // Pipeline: fan all blocks into the compressor pool, then
                // checksum the compressed outputs through the server (which
                // batches them into grouped executable calls).
                let lens: Vec<usize> = raw.iter().map(|(_, d)| d.len()).collect();
                let comp_rxs: Vec<_> = raw
                    .iter()
                    .map(|(_, d)| pool.compress(d.clone()))
                    .collect();
                let compressed: Vec<Vec<u8>> =
                    comp_rxs.into_iter().map(|rx| rx.recv().expect("pool")).collect();
                let sum_rxs: Vec<_> = compressed
                    .iter()
                    .map(|c| server.submit(*tenant, Work::Checksum { data: c.clone() }))
                    .collect();
                for (((first_key, data), c), (rx, len)) in raw
                    .into_iter()
                    .zip(compressed.into_iter())
                    .zip(sum_rxs.into_iter().zip(lens.into_iter()))
                {
                    self.stats.pipeline_bytes += data.len() as u64;
                    self.stats.compressed_bytes += c.len() as u64;
                    let resp = rx.recv().expect("server");
                    let checksum = match resp.output {
                        Output::Checksum { s1, s2 } => (s1, s2),
                        other => panic!("checksum offload failed: {other:?}"),
                    };
                    blocks.push(Block {
                        first_key,
                        compressed: c,
                        checksum,
                        uncompressed_len: len,
                    });
                }
            }
        }
        Sst { blocks }
    }

    /// Verify + decompress one block.
    fn open_block(backend: &Backend, block: &Block) -> Option<Vec<u8>> {
        Self::open_blocks(backend, std::slice::from_ref(block)).pop()?
    }

    /// Verify + decompress a batch of blocks, pipelining the offload path
    /// (all checksums fan into the server — which groups them into batched
    /// executable calls — while the pool decompresses concurrently).
    fn open_blocks(backend: &Backend, blocks: &[Block]) -> Vec<Option<Vec<u8>>> {
        let sums: Vec<(u32, u32)> = match backend {
            Backend::Cpu => blocks
                .iter()
                .map(|b| fletcher_native(&pack_bytes(&b.compressed)))
                .collect(),
            Backend::Offload { server, tenant, .. } => {
                let rxs: Vec<_> = blocks
                    .iter()
                    .map(|b| {
                        server.submit(*tenant, Work::Checksum { data: b.compressed.clone() })
                    })
                    .collect();
                rxs.into_iter()
                    .map(|rx| match rx.recv().expect("server").output {
                        Output::Checksum { s1, s2 } => (s1, s2),
                        _ => (0, 0),
                    })
                    .collect()
            }
        };
        let datas: Vec<Option<Vec<u8>>> = match backend {
            Backend::Cpu => blocks
                .iter()
                .zip(&sums)
                .map(|(b, &s)| (s == b.checksum).then(|| decompress_cpu(&b.compressed)))
                .collect(),
            Backend::Offload { pool, .. } => {
                let rxs: Vec<_> = blocks
                    .iter()
                    .zip(&sums)
                    .map(|(b, &s)| {
                        (s == b.checksum).then(|| pool.decompress(b.compressed.clone()))
                    })
                    .collect();
                rxs.into_iter()
                    .map(|rx| rx.map(|rx| rx.recv().expect("pool")))
                    .collect()
            }
        };
        for (b, d) in blocks.iter().zip(&datas) {
            if let Some(d) = d {
                debug_assert_eq!(d.len(), b.uncompressed_len);
            }
        }
        datas
    }

    fn sst_get(backend: &Backend, sst: &Sst, k: &[u8], failures: &mut u64) -> Option<Vec<u8>> {
        // Binary search the candidate block by first_key.
        let idx = match sst.blocks.binary_search_by(|b| b.first_key.as_slice().cmp(k)) {
            Ok(i) => i,
            Err(0) => return None,
            Err(i) => i - 1,
        };
        let Some(data) = Self::open_block(backend, &sst.blocks[idx]) else {
            *failures += 1;
            return None;
        };
        decode_entries(&data)
            .into_iter()
            .find(|(key, _)| key.as_slice() == k)
            .map(|(_, v)| v)
    }

    /// Total SSTs across levels.
    pub fn n_ssts(&self) -> usize {
        self.levels.iter().map(|l| l.len()).sum()
    }

    /// Compression ratio achieved so far.
    pub fn compression_ratio(&self) -> f64 {
        if self.stats.compressed_bytes == 0 {
            1.0
        } else {
            self.stats.pipeline_bytes as f64 / self.stats.compressed_bytes as f64
        }
    }
}

fn encode_entry(buf: &mut Vec<u8>, k: &[u8], v: &[u8]) {
    buf.extend_from_slice(&(k.len() as u32).to_le_bytes());
    buf.extend_from_slice(&(v.len() as u32).to_le_bytes());
    buf.extend_from_slice(k);
    buf.extend_from_slice(v);
}

fn decode_entries(mut data: &[u8]) -> Vec<(Vec<u8>, Vec<u8>)> {
    let mut out = Vec::new();
    while data.len() >= 8 {
        let kl = u32::from_le_bytes([data[0], data[1], data[2], data[3]]) as usize;
        let vl = u32::from_le_bytes([data[4], data[5], data[6], data[7]]) as usize;
        if data.len() < 8 + kl + vl {
            break;
        }
        out.push((data[8..8 + kl].to_vec(), data[8 + kl..8 + kl + vl].to_vec()));
        data = &data[8 + kl + vl..];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn value(i: u32) -> Vec<u8> {
        // Mildly compressible values, like real serialized rows.
        format!("value-{i:08}-{}", "x".repeat(80 + (i % 40) as usize)).into_bytes()
    }

    #[test]
    fn cpu_backend_put_get_across_flushes() {
        let mut lsm = MiniLsm::new(
            MiniLsmConfig { memtable_bytes: 8 * 1024, block_bytes: 2048, l0_compact_at: 3 },
            Backend::Cpu,
        );
        for i in 0..500u32 {
            lsm.put(format!("key-{i:06}").as_bytes(), &value(i));
        }
        assert!(lsm.stats.flushes > 3, "flushes={}", lsm.stats.flushes);
        assert!(lsm.stats.compactions >= 1);
        for i in (0..500u32).step_by(17) {
            let got = lsm.get(format!("key-{i:06}").as_bytes());
            assert_eq!(got, Some(value(i)), "key {i}");
        }
        assert_eq!(lsm.get(b"missing"), None);
        assert_eq!(lsm.stats.checksum_failures, 0);
        assert!(lsm.compression_ratio() > 2.0, "ratio={}", lsm.compression_ratio());
    }

    #[test]
    fn overwrites_visible_after_compaction() {
        let mut lsm = MiniLsm::new(
            MiniLsmConfig { memtable_bytes: 4 * 1024, block_bytes: 1024, l0_compact_at: 2 },
            Backend::Cpu,
        );
        for round in 0..4u32 {
            for i in 0..100u32 {
                lsm.put(
                    format!("k{i:04}").as_bytes(),
                    format!("round-{round}-{}", "y".repeat(64)).as_bytes(),
                );
            }
        }
        lsm.flush();
        for i in (0..100).step_by(13) {
            let v = lsm.get(format!("k{i:04}").as_bytes()).unwrap();
            assert!(v.starts_with(b"round-3-"), "stale value for k{i}");
        }
    }

    #[test]
    fn write_amplification_tracked() {
        let mut lsm = MiniLsm::new(
            MiniLsmConfig { memtable_bytes: 4 * 1024, block_bytes: 1024, l0_compact_at: 2 },
            Backend::Cpu,
        );
        for i in 0..400u32 {
            lsm.put(format!("key-{i:06}").as_bytes(), &value(i));
        }
        lsm.flush();
        // Compaction re-writes data: physical > logical.
        assert!(
            lsm.stats.pipeline_bytes > lsm.stats.logical_bytes,
            "pipeline {} <= logical {}",
            lsm.stats.pipeline_bytes,
            lsm.stats.logical_bytes
        );
    }
}
