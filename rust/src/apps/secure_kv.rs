//! Secure key-value store (the Fig 11a MICA-with-crypto application).
//!
//! Values are encrypted and authenticated through the accelerator server
//! (encrypt-then-MAC): PUT sends the value through `encrypt_digest`, stores
//! ciphertext + tag + counter; GET re-runs the cipher on the ciphertext
//! (counter-mode involution) *after* recomputing and checking the tag.
//! Tampered ciphertext is detected and the read rejected.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use crate::runtime::Digest;
use crate::server::{Output, Server, Work};

struct Entry {
    cipher: Vec<u8>,
    tag: Digest,
    counter0: u32,
}

/// Read errors.
#[derive(Debug, PartialEq, Eq)]
pub enum KvError {
    NotFound,
    /// Tag mismatch: the stored ciphertext was corrupted or forged.
    AuthFailed,
    Rejected,
}

/// The store: one tenant on the shared accelerator server.
pub struct SecureKv {
    server: Arc<Server>,
    tenant: usize,
    key: [u32; 8],
    nonce: [u32; 3],
    counter: AtomicU32,
    map: std::sync::Mutex<HashMap<Vec<u8>, Entry>>,
}

impl SecureKv {
    pub fn new(server: Arc<Server>, tenant: usize, key: [u32; 8], nonce: [u32; 3]) -> Self {
        SecureKv {
            server,
            tenant,
            key,
            nonce,
            counter: AtomicU32::new(1),
            map: std::sync::Mutex::new(HashMap::new()),
        }
    }

    /// Unique counter range for a value of `blocks` 64 B blocks (counters
    /// must never repeat under one (key, nonce) pair).
    fn alloc_counters(&self, blocks: u32) -> u32 {
        self.counter.fetch_add(blocks.max(1), Ordering::Relaxed)
    }

    /// Encrypt-then-MAC PUT.
    pub fn put(&self, k: &[u8], v: &[u8]) -> Result<(), KvError> {
        let blocks = (v.len().div_ceil(64)).max(1) as u32;
        let counter0 = self.alloc_counters(blocks);
        let r = self.server.submit_blocking(
            self.tenant,
            Work::EncryptDigest {
                data: v.to_vec(),
                key: self.key,
                nonce: self.nonce,
                counter0,
            },
        );
        match r.output {
            Output::Encrypted { cipher, tag } => {
                self.map
                    .lock()
                    .unwrap()
                    .insert(k.to_vec(), Entry { cipher, tag, counter0 });
                Ok(())
            }
            _ => Err(KvError::Rejected),
        }
    }

    /// Verify-then-decrypt GET.
    pub fn get(&self, k: &[u8]) -> Result<Vec<u8>, KvError> {
        let (cipher, tag, counter0) = {
            let map = self.map.lock().unwrap();
            let e = map.get(k).ok_or(KvError::NotFound)?;
            (e.cipher.clone(), e.tag, e.counter0)
        };
        // Decrypt = encrypt on the ciphertext; the engine also recomputes
        // the tag over what we handed it. Because the stored tag was taken
        // over the *ciphertext*, we check it against a digest of the stored
        // bytes: run the cipher call and compare tags computed over the
        // same ciphertext. The encrypt_digest artifact MACs its *output*,
        // so to verify we MAC the stored ciphertext explicitly first.
        let verify = self.server.submit_blocking(
            self.tenant,
            Work::EncryptDigest {
                data: cipher.clone(),
                key: self.key,
                nonce: self.nonce,
                counter0,
            },
        );
        match verify.output {
            Output::Encrypted { cipher: plain, tag: _plain_tag } => {
                // Recompute the storage tag: MAC(cipher). Encrypting the
                // plaintext again reproduces (cipher, tag) deterministically.
                let recheck = self.server.submit_blocking(
                    self.tenant,
                    Work::EncryptDigest {
                        data: plain.clone(),
                        key: self.key,
                        nonce: self.nonce,
                        counter0,
                    },
                );
                match recheck.output {
                    Output::Encrypted { cipher: c2, tag: t2 } => {
                        if c2 != cipher || t2 != tag {
                            Err(KvError::AuthFailed)
                        } else {
                            Ok(plain)
                        }
                    }
                    _ => Err(KvError::Rejected),
                }
            }
            _ => Err(KvError::Rejected),
        }
    }

    /// Corrupt a stored value in place (test/bench hook for the tamper
    /// detection path).
    pub fn tamper(&self, k: &[u8], byte: usize) -> bool {
        let mut map = self.map.lock().unwrap();
        match map.get_mut(k) {
            Some(e) if byte < e.cipher.len() => {
                e.cipher[byte] ^= 0x01;
                true
            }
            _ => false,
        }
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::ServerConfig;
    use std::path::Path;

    fn server() -> Option<Arc<Server>> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Arc::new(
            Server::start(ServerConfig::new(dir).tenant("kv", None)).unwrap(),
        ))
    }

    #[test]
    fn put_get_roundtrip_and_tamper_detection() {
        let Some(server) = server() else { return };
        let kv = SecureKv::new(server, 0, [11; 8], [1, 2, 3]);
        kv.put(b"alpha", b"the quick brown fox").unwrap();
        kv.put(b"beta", &[0xEE; 300]).unwrap();
        assert_eq!(kv.get(b"alpha").unwrap(), b"the quick brown fox");
        assert_eq!(kv.get(b"beta").unwrap(), vec![0xEE; 300]);
        assert_eq!(kv.get(b"gamma"), Err(KvError::NotFound));
        // Flip one ciphertext byte: authentication must fail.
        assert!(kv.tamper(b"beta", 17));
        assert_eq!(kv.get(b"beta"), Err(KvError::AuthFailed));
        // alpha untouched.
        assert_eq!(kv.get(b"alpha").unwrap(), b"the quick brown fox");
    }

    #[test]
    fn distinct_values_distinct_ciphertexts() {
        let Some(server) = server() else { return };
        let kv = SecureKv::new(server, 0, [7; 8], [9, 9, 9]);
        kv.put(b"k1", &[0xAA; 64]).unwrap();
        kv.put(b"k2", &[0xAA; 64]).unwrap();
        let (c1, c2) = {
            let map = kv.map.lock().unwrap();
            (map[b"k1".as_slice()].cipher.clone(), map[b"k2".as_slice()].cipher.clone())
        };
        // Same plaintext, different counters → different ciphertexts.
        assert_ne!(c1, c2);
    }
}
