//! End-to-end applications over the serving runtime (§5.4).
//!
//! - [`secure_kv`] — the MICA-style secure key-value store of Fig 11a:
//!   values are encrypted and authenticated through the accelerator server
//!   (encrypt-then-MAC), GETs verify the tag before decrypting.
//! - [`minilsm`] — the RocksDB-style LSM engine of Table 4: SST blocks are
//!   compressed and checksummed on write; checksum (and compression) can
//!   run on the VM's CPU (the ext4 baseline) or be offloaded to the
//!   accelerator runtime, freeing application cores.
//! - [`offload`] — the compression offload pool (the "(de)compressor
//!   engine" of Table 5) plus thread/process CPU accounting used to
//!   measure the paper's core-savings claims.

pub mod minilsm;
pub mod offload;
pub mod secure_kv;

pub use minilsm::{Backend, LsmStats, MiniLsm, MiniLsmConfig};
pub use offload::{thread_cpu_seconds, CompressorPool};
pub use secure_kv::SecureKv;
