//! Compression offload pool + CPU-time accounting.
//!
//! The paper's Table 4 measures CPU cores saved by moving checksum and
//! compression off the VM's cores onto the device. In this reproduction the
//! "device" is a dedicated offload thread pool: the application thread
//! hands a block over and is free to do application work; the pool burns
//! the compression cycles. CPU savings are measured per thread via
//! `/proc/thread-self/stat` ([`thread_cpu_seconds`]) — the application
//! thread's CPU time drops by the offloaded share even though the process
//! total stays similar (exactly the paper's "more cores for applications").

use std::io::Write;
use std::sync::mpsc;

use flate2::write::{DeflateDecoder, DeflateEncoder};
use flate2::Compression;

/// CPU time (user+system) consumed by the *calling thread*, in seconds.
/// Linux-only (reads `/proc/thread-self/stat`); returns 0.0 elsewhere.
pub fn thread_cpu_seconds() -> f64 {
    let Ok(stat) = std::fs::read_to_string("/proc/thread-self/stat") else {
        return 0.0;
    };
    // Fields after the parenthesized comm (which may contain spaces).
    let Some(rest) = stat.rsplit(national_paren).next() else { return 0.0 };
    let fields: Vec<&str> = rest.split_whitespace().collect();
    // utime and stime are fields 14 and 15 overall; after ") " they are at
    // indices 11 and 12 (state is index 0).
    let (Some(ut), Some(st)) = (fields.get(11), fields.get(12)) else {
        return 0.0;
    };
    let ticks: f64 = ut.parse::<f64>().unwrap_or(0.0) + st.parse::<f64>().unwrap_or(0.0);
    ticks / clk_tck()
}

fn national_paren(c: char) -> bool {
    c == ')'
}

fn clk_tck() -> f64 {
    // _SC_CLK_TCK is 100 on every mainstream Linux config.
    100.0
}

/// Compress a block (the CPU baseline path).
pub fn compress_cpu(data: &[u8]) -> Vec<u8> {
    let mut enc = DeflateEncoder::new(Vec::new(), Compression::fast());
    enc.write_all(data).expect("deflate write");
    enc.finish().expect("deflate finish")
}

/// Decompress a block.
pub fn decompress_cpu(data: &[u8]) -> Vec<u8> {
    let mut dec = DeflateDecoder::new(Vec::new());
    dec.write_all(data).expect("inflate write");
    dec.finish().expect("inflate finish")
}

enum Job {
    Compress(Vec<u8>, mpsc::Sender<Vec<u8>>),
    Decompress(Vec<u8>, mpsc::Sender<Vec<u8>>),
}

/// A pool of offload threads running the (de)compression engine.
pub struct CompressorPool {
    tx: mpsc::Sender<Job>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl CompressorPool {
    pub fn new(threads: usize) -> Self {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = std::sync::Arc::new(std::sync::Mutex::new(rx));
        let workers = (0..threads.max(1))
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("arcus-compress-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(Job::Compress(data, tx)) => {
                                let _ = tx.send(compress_cpu(&data));
                            }
                            Ok(Job::Decompress(data, tx)) => {
                                let _ = tx.send(decompress_cpu(&data));
                            }
                            Err(_) => return,
                        }
                    })
                    .expect("spawn compressor")
            })
            .collect();
        CompressorPool { tx, workers }
    }

    /// Submit a block for compression; recv on the returned channel.
    pub fn compress(&self, data: Vec<u8>) -> mpsc::Receiver<Vec<u8>> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Job::Compress(data, tx)).expect("pool alive");
        rx
    }

    pub fn decompress(&self, data: Vec<u8>) -> mpsc::Receiver<Vec<u8>> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Job::Decompress(data, tx)).expect("pool alive");
        rx
    }
}

impl Drop for CompressorPool {
    fn drop(&mut self) {
        // Close the channel; workers exit on Err.
        let (tx, _) = mpsc::channel();
        drop(std::mem::replace(&mut self.tx, tx));
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compress_roundtrip_cpu() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        let c = compress_cpu(&data);
        assert!(c.len() < data.len(), "repetitive data must compress");
        assert_eq!(decompress_cpu(&c), data);
    }

    #[test]
    fn pool_roundtrip() {
        let pool = CompressorPool::new(2);
        let data = vec![42u8; 4096];
        let c = pool.compress(data.clone()).recv().unwrap();
        assert!(c.len() < data.len());
        let d = pool.decompress(c).recv().unwrap();
        assert_eq!(d, data);
    }

    #[test]
    fn pool_parallel_jobs() {
        let pool = CompressorPool::new(2);
        let rxs: Vec<_> = (0..16)
            .map(|i| pool.compress(vec![i as u8; 8192]))
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let c = rx.recv().unwrap();
            assert_eq!(decompress_cpu(&c), vec![i as u8; 8192]);
        }
    }

    #[test]
    fn thread_cpu_time_increases_with_work() {
        let t0 = thread_cpu_seconds();
        // Burn some CPU on this thread.
        let mut x = 0u64;
        for i in 0..400_000_000u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        std::hint::black_box(x);
        let t1 = thread_cpu_seconds();
        assert!(t1 >= t0, "cpu time went backwards: {t0} -> {t1}");
        // On Linux this must have registered at least one tick.
        if std::path::Path::new("/proc/thread-self/stat").exists() {
            assert!(t1 > 0.0);
        }
    }
}
