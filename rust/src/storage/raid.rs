//! RAID-0 striping over N SSDs (the paper's 4-drive array, Fig 11b).

use super::nvme::{Io, IoDone, Ssd, SsdConfig};
use crate::util::units::Time;

/// RAID-0: stripes I/Os round-robin (random-access workloads distribute
/// uniformly, which round-robin reproduces deterministically).
#[derive(Debug)]
pub struct Raid0 {
    drives: Vec<Ssd>,
    next: usize,
}

impl Raid0 {
    pub fn new(n: usize, cfg: SsdConfig, seed: u64) -> Self {
        Raid0 {
            drives: (0..n).map(|i| Ssd::new(cfg, seed ^ (i as u64) << 32)).collect(),
            next: 0,
        }
    }

    pub fn n_drives(&self) -> usize {
        self.drives.len()
    }

    /// Fault injection: inflate every drive's service latency by `factor`
    /// ≥ 1 (1.0 restores datasheet health). See [`crate::faults`].
    pub fn set_latency_factor(&mut self, factor: f64) {
        for d in &mut self.drives {
            d.set_latency_factor(factor);
        }
    }

    pub fn submit(&mut self, io: Io) {
        self.drives[self.next].submit(io);
        self.next = (self.next + 1) % self.drives.len();
    }

    /// Submit to the drive owning a specific stripe (LBA-addressed I/O).
    pub fn submit_at(&mut self, stripe: u64, io: Io) {
        let d = (stripe as usize) % self.drives.len();
        self.drives[d].submit(io);
    }

    /// Allocates a fresh `Vec` per call; the simulation hot path uses
    /// [`Self::pump_into`] with a reused buffer instead.
    pub fn pump(&mut self, now: Time) -> (Vec<IoDone>, Option<Time>) {
        let mut done = Vec::new();
        let next = self.pump_into(now, &mut done);
        (done, next)
    }

    /// Allocation-free pump: appends completions to `done` (which the
    /// caller reuses across calls) and returns the next wake time.
    pub fn pump_into(&mut self, now: Time, done: &mut Vec<IoDone>) -> Option<Time> {
        let start = done.len();
        let mut next: Option<Time> = None;
        for d in &mut self.drives {
            let n = d.pump_into(now, done);
            next = match (next, n) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, None) => a,
                (None, b) => b,
            };
        }
        // Completions from different drives arrive unordered; sort (stably,
        // so equal times keep drive order) for deterministic downstream
        // processing. Only this call's suffix is sorted.
        done[start..].sort_by_key(|d| d.at);
        next
    }

    pub fn idle(&self) -> bool {
        self.drives.iter().all(Ssd::idle)
    }

    /// Aggregate (reads, writes) completed.
    pub fn completed(&self) -> (u64, u64) {
        self.drives
            .iter()
            .map(Ssd::completed)
            .fold((0, 0), |(r, w), (dr, dw)| (r + dr, w + dw))
    }

    pub fn queue_depth(&self) -> usize {
        self.drives.iter().map(Ssd::queue_depth).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::nvme::IoKind;
    use crate::util::units::SECONDS;

    fn drain(raid: &mut Raid0) -> Vec<IoDone> {
        let mut out = Vec::new();
        let mut now = 0;
        loop {
            let (done, next) = raid.pump(now);
            out.extend(done);
            match next {
                Some(t) => now = t,
                None => break,
            }
        }
        out
    }

    #[test]
    fn four_drives_scale_read_iops() {
        let mut raid = Raid0::new(4, SsdConfig::samsung_983dct(), 1);
        let n = 100_000u64;
        for i in 0..n {
            raid.submit(Io {
                id: i,
                kind: IoKind::Read,
                bytes: 1024,
            });
        }
        let done = drain(&mut raid);
        let iops = n as f64 * SECONDS as f64 / done.last().unwrap().at as f64;
        // 4 drives × ~2M 1KB-read IOPS/drive-class ⇒ paper's 2M+ aggregate.
        assert!(iops > 2_000_000.0, "raid read iops={iops:.0}");
    }

    #[test]
    fn striping_balances_drives() {
        let mut raid = Raid0::new(4, SsdConfig::samsung_983dct(), 2);
        for i in 0..10_000u64 {
            raid.submit(Io {
                id: i,
                kind: IoKind::Read,
                bytes: 4096,
            });
        }
        let _ = drain(&mut raid);
        let counts: Vec<u64> = raid.drives.iter().map(|d| d.completed().0).collect();
        for &c in &counts {
            assert_eq!(c, 2500);
        }
    }

    #[test]
    fn completions_sorted_by_time() {
        let mut raid = Raid0::new(4, SsdConfig::samsung_983dct(), 3);
        for i in 0..1000u64 {
            raid.submit(Io {
                id: i,
                kind: IoKind::Read,
                bytes: 4096,
            });
        }
        let done = drain(&mut raid);
        for w in done.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
    }
}
