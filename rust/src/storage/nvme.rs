//! Single-SSD model with channel parallelism and read/write interference.

use crate::util::units::{Time, MICROS};
use crate::util::Rng;
use std::collections::VecDeque;

/// Operation kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoKind {
    Read,
    Write,
}

/// One I/O request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Io {
    pub id: u64,
    pub kind: IoKind,
    pub bytes: u64,
}

/// A completed I/O.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IoDone {
    pub io: Io,
    pub at: Time,
}

/// Datasheet-style SSD parameters (defaults model a Samsung 983 DCT-class
/// enterprise NVMe drive).
#[derive(Debug, Clone, Copy)]
pub struct SsdConfig {
    /// Independent flash channels (concurrent ops).
    pub channels: usize,
    /// 4 KB random-read service time per channel at QD=channels.
    pub read_service: Time,
    /// 4 KB write (program) service time per channel.
    pub write_service: Time,
    /// Multiplier applied to read service per in-flight write — FTL and
    /// flash-die contention (program suspends reads on the same die).
    pub write_read_penalty: f64,
    /// Service-time jitter spread (uniform ±).
    pub jitter: f64,
}

impl SsdConfig {
    pub fn samsung_983dct() -> Self {
        SsdConfig {
            channels: 8,
            // ~540K read IOPS: 8 channels / 14.8 µs
            read_service: 14_800_000 / 1000 * 1000, // 14.8 µs in ps
            // ~48K write IOPS: 8 channels / 165 µs
            write_service: 165 * MICROS,
            write_read_penalty: 0.55,
            jitter: 0.08,
        }
    }
}

/// The SSD: a channel pool + FIFO queue (the NVMe SQ after arbitration).
#[derive(Debug)]
pub struct Ssd {
    cfg: SsdConfig,
    queue: VecDeque<Io>,
    /// Per-channel: finish time of the op in service (None = idle), plus
    /// whether it is a write (for interference accounting).
    channels: Vec<Option<(Io, Time)>>,
    rng: Rng,
    completed_reads: u64,
    completed_writes: u64,
    /// Fault-injection latency multiplier ≥ 1; 1.0 = healthy (a GC storm
    /// inflates service times; ops already in flight keep their finish
    /// times).
    latency_factor: f64,
}

impl Ssd {
    pub fn new(cfg: SsdConfig, seed: u64) -> Self {
        Ssd {
            channels: vec![None; cfg.channels],
            cfg,
            queue: VecDeque::new(),
            rng: Rng::for_stream(seed, 0x55D),
            completed_reads: 0,
            completed_writes: 0,
            latency_factor: 1.0,
        }
    }

    /// Fault injection: inflate service latency by `factor` ≥ 1 (1.0
    /// restores datasheet health). See [`crate::faults`].
    pub fn set_latency_factor(&mut self, factor: f64) {
        debug_assert!(factor >= 1.0, "ssd latency factor {factor}");
        self.latency_factor = factor.max(1.0);
    }

    pub fn submit(&mut self, io: Io) {
        self.queue.push_back(io);
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    fn writes_in_flight(&self) -> usize {
        self.channels
            .iter()
            .flatten()
            .filter(|(io, _)| io.kind == IoKind::Write)
            .count()
    }

    fn service_time(&mut self, io: Io) -> Time {
        let base = match io.kind {
            IoKind::Read => {
                // Reads slow down per in-flight write.
                let w = self.writes_in_flight() as f64;
                self.cfg.read_service as f64
                    * (1.0 + w * self.cfg.write_read_penalty)
                    * (io.bytes as f64 / 4096.0).max(0.25).min(64.0)
            }
            IoKind::Write => {
                self.cfg.write_service as f64 * (io.bytes as f64 / 4096.0).max(0.25)
            }
        };
        let jit = self.rng.range_f64(1.0 - self.cfg.jitter, 1.0 + self.cfg.jitter);
        (base * jit * self.latency_factor).round() as Time
    }

    /// Advance to `now`: retire due ops, dispatch queued ops to free
    /// channels. Returns completions and the next wake time.
    ///
    /// Allocates a fresh `Vec` per call; the simulation hot path uses
    /// [`Self::pump_into`] with a reused buffer instead.
    pub fn pump(&mut self, now: Time) -> (Vec<IoDone>, Option<Time>) {
        let mut done = Vec::new();
        let next = self.pump_into(now, &mut done);
        (done, next)
    }

    /// Allocation-free pump: appends completions to `done` (which the
    /// caller reuses across calls) and returns the next wake time.
    pub fn pump_into(&mut self, now: Time, done: &mut Vec<IoDone>) -> Option<Time> {
        loop {
            let mut progressed = false;
            // Retire.
            for ch in self.channels.iter_mut() {
                if let Some((io, fin)) = *ch {
                    if fin <= now {
                        *ch = None;
                        match io.kind {
                            IoKind::Read => self.completed_reads += 1,
                            IoKind::Write => self.completed_writes += 1,
                        }
                        done.push(IoDone { io, at: fin });
                        progressed = true;
                    }
                }
            }
            // Dispatch (interference depends on current in-flight mix, so
            // recompute per dispatch).
            for i in 0..self.channels.len() {
                if self.channels[i].is_none() {
                    if let Some(io) = self.queue.pop_front() {
                        let t = self.service_time(io);
                        self.channels[i] = Some((io, now + t));
                        progressed = true;
                    }
                }
            }
            if !progressed {
                break;
            }
        }
        self.channels
            .iter()
            .flatten()
            .map(|&(_, fin)| fin)
            .min()
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.channels.iter().all(Option::is_none)
    }

    pub fn completed(&self) -> (u64, u64) {
        (self.completed_reads, self.completed_writes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::SECONDS;

    fn drain(ssd: &mut Ssd) -> Vec<IoDone> {
        let mut out = Vec::new();
        let mut now = 0;
        loop {
            let (done, next) = ssd.pump(now);
            out.extend(done);
            match next {
                Some(t) => now = t,
                None => break,
            }
        }
        out
    }

    #[test]
    fn read_iops_near_datasheet() {
        let mut ssd = Ssd::new(SsdConfig::samsung_983dct(), 1);
        let n = 50_000u64;
        for i in 0..n {
            ssd.submit(Io {
                id: i,
                kind: IoKind::Read,
                bytes: 4096,
            });
        }
        let done = drain(&mut ssd);
        let last = done.last().unwrap().at;
        let iops = n as f64 * SECONDS as f64 / last as f64;
        assert!(
            (480_000.0..600_000.0).contains(&iops),
            "read iops={iops:.0}"
        );
    }

    #[test]
    fn write_iops_near_datasheet() {
        let mut ssd = Ssd::new(SsdConfig::samsung_983dct(), 2);
        let n = 5_000u64;
        for i in 0..n {
            ssd.submit(Io {
                id: i,
                kind: IoKind::Write,
                bytes: 4096,
            });
        }
        let done = drain(&mut ssd);
        let iops = n as f64 * SECONDS as f64 / done.last().unwrap().at as f64;
        assert!((42_000.0..56_000.0).contains(&iops), "write iops={iops:.0}");
    }

    #[test]
    fn writes_degrade_concurrent_reads() {
        // Pure-read IOPS vs reads mixed with a write stream.
        let run = |write_every: Option<u64>| {
            let mut ssd = Ssd::new(SsdConfig::samsung_983dct(), 3);
            let mut id = 0;
            for i in 0..40_000u64 {
                ssd.submit(Io {
                    id,
                    kind: IoKind::Read,
                    bytes: 4096,
                });
                id += 1;
                if let Some(k) = write_every {
                    if i % k == 0 {
                        ssd.submit(Io {
                            id,
                            kind: IoKind::Write,
                            bytes: 4096,
                        });
                        id += 1;
                    }
                }
            }
            let done = drain(&mut ssd);
            let reads = done
                .iter()
                .filter(|d| d.io.kind == IoKind::Read)
                .count() as f64;
            reads * SECONDS as f64 / done.last().unwrap().at as f64
        };
        let pure = run(None);
        let mixed = run(Some(20)); // 5% writes
        assert!(
            mixed < 0.75 * pure,
            "mixed={mixed:.0} should be well below pure={pure:.0}"
        );
    }

    #[test]
    fn small_reads_faster_than_4k() {
        let cfg = SsdConfig::samsung_983dct();
        let mut ssd = Ssd::new(cfg, 4);
        let n = 20_000u64;
        for i in 0..n {
            ssd.submit(Io {
                id: i,
                kind: IoKind::Read,
                bytes: 1024,
            });
        }
        let done = drain(&mut ssd);
        let iops_1k = n as f64 * SECONDS as f64 / done.last().unwrap().at as f64;
        // 1KB reads quantize to 0.25 of the 4K service time.
        assert!(iops_1k > 1_500_000.0, "1k iops={iops_1k:.0}");
    }

    #[test]
    fn deterministic() {
        let run = || {
            let mut ssd = Ssd::new(SsdConfig::samsung_983dct(), 9);
            for i in 0..1000 {
                ssd.submit(Io {
                    id: i,
                    kind: if i % 10 == 0 {
                        IoKind::Write
                    } else {
                        IoKind::Read
                    },
                    bytes: 4096,
                });
            }
            drain(&mut ssd).iter().map(|d| d.at).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
