//! NVMe SSD and RAID-0 models for the inline-P2P experiments (Fig 11b).
//!
//! The paper's storage prototype is four Samsung 983 DCT SSDs in RAID-0
//! behind an FVM-style NVMe stack. The SLO-relevant behaviour is **internal
//! read/write interference**: SSD writes occupy the flash channel and the
//! FTL long enough to starve reads ("the root cause is internal read-write
//! interference in SSD sub-systems", §5.4), which is why unshaped write
//! over-provisioning degrades overall RAID throughput by 2.2×.

pub mod nvme;
pub mod raid;

pub use nvme::{Ssd, SsdConfig};
pub use raid::Raid0;
