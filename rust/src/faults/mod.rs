//! Deterministic fault & adversary injection (the "SLO beyond healthy
//! hardware" axis).
//!
//! Every scenario the sweep engine ran before this module assumed healthy
//! links, honest tenants, and perfectly accurate accelerator profiles —
//! so the control plane's *reaction* paths (renegotiation directives,
//! reshape, BE refresh) were never stressed. A [`FaultPlan`] (the
//! `faults` field of [`crate::system::ExperimentSpec`], i.e. a list of
//! [`FaultSpec`]s) schedules typed faults on the DES clock:
//!
//! - [`FaultKind::AccelSlowdown`] — an accelerator's throughput curve is
//!   scaled down (thermal throttling, partial pipeline degradation);
//! - [`FaultKind::LinkDegrade`] — the PCIe link loses bandwidth (lane
//!   renegotiation / flap; a *flap* is a short window with a deep factor);
//! - [`FaultKind::SsdSlowdown`] — SSD service latency inflates (GC storm);
//! - [`FaultKind::ProfileSkew`] — the control plane's Capacity(t, X, N)
//!   table is mis-estimated by a factor, making the planner over- or
//!   under-commit until re-profiling heals the table;
//! - [`FaultKind::RogueTenant`] — an adversarial tenant stops honoring its
//!   shaper program (submits unshaped) until the interface clamps it;
//! - [`FaultKind::ControlOutage`] — Algorithm-1 ticks are lost for the
//!   window (a wedged/partitioned control plane).
//!
//! Injection is itself deterministic: faults are ordinary typed
//! [`crate::system::EngineEvent`]s (`FaultStart`/`FaultEnd`) on the same
//! `(time, seq)`-ordered queue as the dataplane, so the golden
//! fault-conformance test (`rust/tests/faults.rs`) can require
//! byte-identical reports across all three event-queue disciplines.
//!
//! The *fault window* — `[min start, max end)` over every injected fault —
//! splits a run into three eras (pre / during / post); the engine measures
//! attainment, p99, and post-fault recovery time per era (see
//! [`crate::system::report::FaultReport`]).

use crate::util::units::{Time, MILLIS};

/// Which physical (or logical) component a fault occupies. Validation
/// rejects overlapping windows on the same target: two simultaneous faults
/// on one component have no physical meaning and would make restore order
/// ambiguous.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultTarget {
    /// One accelerator unit (by device-list index).
    Accel(usize),
    /// The shared PCIe link (both directions).
    PcieLink,
    /// The NVMe subsystem (all RAID drives).
    Ssd,
    /// The control plane's profile table for one accelerator.
    Profile(usize),
    /// One tenant's interface shaper.
    Flow(usize),
    /// The Algorithm-1 ticker.
    ControlPlane,
}

/// One typed fault. Factors are explicit about their direction:
/// throughput-style factors live in `(0, 1]` (1.0 = healthy), latency-style
/// factors are `>= 1` (1.0 = healthy), and profile skews are any positive
/// mis-estimate (`> 1` = over-estimate, the over-commit direction).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Scale accelerator `unit`'s sustained throughput by `factor` ∈ (0, 1]
    /// (service times stretch by `1/factor`).
    AccelSlowdown { unit: usize, factor: f64 },
    /// Scale the PCIe link's per-direction bandwidth by `factor` ∈ (0, 1].
    LinkDegrade { factor: f64 },
    /// Inflate SSD service latency by `factor` ≥ 1.
    SsdSlowdown { factor: f64 },
    /// Scale the control plane's belief about accelerator `accel`'s
    /// capacity by `factor` > 0. The hardware is untouched — only the
    /// planner's table lies.
    ProfileSkew { accel: usize, factor: f64 },
    /// Tenant `flow` stops honoring its shaper program: it submits
    /// unshaped until the control plane's next directive clamps it.
    RogueTenant { flow: usize },
    /// Algorithm-1 control ticks are lost during the window.
    ControlOutage,
}

impl FaultKind {
    /// The component this fault occupies (overlap-exclusion key).
    pub fn target(&self) -> FaultTarget {
        match *self {
            FaultKind::AccelSlowdown { unit, .. } => FaultTarget::Accel(unit),
            FaultKind::LinkDegrade { .. } => FaultTarget::PcieLink,
            FaultKind::SsdSlowdown { .. } => FaultTarget::Ssd,
            FaultKind::ProfileSkew { accel, .. } => FaultTarget::Profile(accel),
            FaultKind::RogueTenant { flow } => FaultTarget::Flow(flow),
            FaultKind::ControlOutage => FaultTarget::ControlPlane,
        }
    }

    /// Config / report name.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::AccelSlowdown { .. } => "accel_slowdown",
            FaultKind::LinkDegrade { .. } => "link_degrade",
            FaultKind::SsdSlowdown { .. } => "ssd_slowdown",
            FaultKind::ProfileSkew { .. } => "profile_skew",
            FaultKind::RogueTenant { .. } => "rogue_tenant",
            FaultKind::ControlOutage => "control_outage",
        }
    }
}

/// One scheduled fault: `kind` holds during `[at, until)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    pub kind: FaultKind,
    /// Injection time (virtual).
    pub at: Time,
    /// Restore time (virtual); the component heals here.
    pub until: Time,
}

impl FaultSpec {
    pub fn new(kind: FaultKind, at: Time, until: Time) -> Self {
        FaultSpec { kind, at, until }
    }
}

/// The union fault window `[min start, max end)` over a plan — the era
/// boundary the per-era metrics are measured against. `None` for an empty
/// plan.
pub fn fault_window(faults: &[FaultSpec]) -> Option<(Time, Time)> {
    let start = faults.iter().map(|f| f.at).min()?;
    let end = faults.iter().map(|f| f.until).max()?;
    Some((start, end))
}

fn ms(t: Time) -> f64 {
    t as f64 / MILLIS as f64
}

/// Validate a fault plan against a run's shape, with actionable errors:
/// windows must lie inside the *measured* run (`warmup ≤ at < until ≤
/// duration` — a fault starting at/after the end would silently never
/// fire, and one starting inside the warmup would have its damage
/// discarded while still diluting the during-era rate), factors must point
/// in their documented direction, component indices must exist, and no two
/// faults may overlap on one component.
pub fn validate_faults(
    faults: &[FaultSpec],
    duration: Time,
    warmup: Time,
    n_flows: usize,
    n_accels: usize,
    has_raid: bool,
) -> Result<(), String> {
    for (i, f) in faults.iter().enumerate() {
        if f.at >= f.until {
            return Err(format!(
                "fault {i} ({}): window [{:.3}, {:.3}) ms is empty or inverted",
                f.kind.name(),
                ms(f.at),
                ms(f.until)
            ));
        }
        if f.at < warmup {
            return Err(format!(
                "fault {i} ({}): starts at {:.3} ms, inside the warmup \
                 ({:.3} ms) — metrics are discarded there, so the fault era \
                 would be mis-measured; start it at/after the warmup",
                f.kind.name(),
                ms(f.at),
                ms(warmup)
            ));
        }
        if f.at >= duration {
            return Err(format!(
                "fault {i} ({}): starts at {:.3} ms, at/after the run's duration \
                 ({:.3} ms) — it would never fire",
                f.kind.name(),
                ms(f.at),
                ms(duration)
            ));
        }
        if f.until > duration {
            return Err(format!(
                "fault {i} ({}): ends at {:.3} ms, after the run's duration \
                 ({:.3} ms) — the component would never heal inside the run",
                f.kind.name(),
                ms(f.until),
                ms(duration)
            ));
        }
        match f.kind {
            FaultKind::AccelSlowdown { unit, factor } => {
                if !(factor > 0.0 && factor <= 1.0) {
                    return Err(format!(
                        "fault {i}: accel_slowdown factor must be in (0, 1] \
                         (got {factor}; it scales throughput *down*)"
                    ));
                }
                if unit >= n_accels {
                    return Err(format!(
                        "fault {i}: accel unit {unit} out of range ({n_accels} defined)"
                    ));
                }
            }
            FaultKind::LinkDegrade { factor } => {
                if !(factor > 0.0 && factor <= 1.0) {
                    return Err(format!(
                        "fault {i}: link_degrade factor must be in (0, 1] (got {factor})"
                    ));
                }
            }
            FaultKind::SsdSlowdown { factor } => {
                if factor.is_nan() || factor < 1.0 {
                    return Err(format!(
                        "fault {i}: ssd_slowdown factor must be ≥ 1 \
                         (got {factor}; it inflates latency)"
                    ));
                }
                if !has_raid {
                    return Err(format!(
                        "fault {i}: ssd_slowdown needs a [raid] array in the experiment"
                    ));
                }
            }
            FaultKind::ProfileSkew { accel, factor } => {
                if !factor.is_finite() || factor <= 0.0 {
                    return Err(format!(
                        "fault {i}: profile_skew factor must be positive and finite \
                         (got {factor})"
                    ));
                }
                if accel >= n_accels {
                    return Err(format!(
                        "fault {i}: profile_skew accel {accel} out of range \
                         ({n_accels} defined)"
                    ));
                }
            }
            FaultKind::RogueTenant { flow } => {
                if flow >= n_flows {
                    return Err(format!(
                        "fault {i}: rogue_tenant flow {flow} out of range ({n_flows} flows)"
                    ));
                }
            }
            FaultKind::ControlOutage => {}
        }
    }
    // Overlap exclusion per component: O(n²) is fine for config-sized plans.
    for (i, a) in faults.iter().enumerate() {
        for (j, b) in faults.iter().enumerate().skip(i + 1) {
            if a.kind.target() == b.kind.target() && a.at < b.until && b.at < a.until {
                return Err(format!(
                    "faults {i} ({}) and {j} ({}) overlap on the same component \
                     ({:?}): windows [{:.3}, {:.3}) and [{:.3}, {:.3}) ms — \
                     restore order would be ambiguous",
                    a.kind.name(),
                    b.kind.name(),
                    a.kind.target(),
                    ms(a.at),
                    ms(a.until),
                    ms(b.at),
                    ms(b.until)
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slow(at: Time, until: Time) -> FaultSpec {
        FaultSpec::new(FaultKind::AccelSlowdown { unit: 0, factor: 0.5 }, at, until)
    }

    #[test]
    fn window_is_union_of_all_faults() {
        assert_eq!(fault_window(&[]), None);
        let plan = [
            slow(2 * MILLIS, 4 * MILLIS),
            FaultSpec::new(FaultKind::ControlOutage, 3 * MILLIS, 6 * MILLIS),
        ];
        assert_eq!(fault_window(&plan), Some((2 * MILLIS, 6 * MILLIS)));
    }

    #[test]
    fn validate_accepts_well_formed_plans() {
        let plan = [
            slow(2 * MILLIS, 4 * MILLIS),
            FaultSpec::new(FaultKind::LinkDegrade { factor: 0.5 }, 2 * MILLIS, 5 * MILLIS),
            FaultSpec::new(FaultKind::RogueTenant { flow: 1 }, 5 * MILLIS, 7 * MILLIS),
        ];
        assert!(validate_faults(&plan, 10 * MILLIS, 0, 2, 1, false).is_ok());
    }

    #[test]
    fn validate_rejects_windows_outside_the_measured_run() {
        // Start at/after duration: would silently never fire.
        let plan = [slow(10 * MILLIS, 12 * MILLIS)];
        let e = validate_faults(&plan, 10 * MILLIS, 0, 1, 1, false).unwrap_err();
        assert!(e.contains("never fire"), "{e}");
        // End after duration: would never heal.
        let plan = [slow(2 * MILLIS, 12 * MILLIS)];
        let e = validate_faults(&plan, 10 * MILLIS, 0, 1, 1, false).unwrap_err();
        assert!(e.contains("heal"), "{e}");
        // Empty / inverted window.
        let plan = [slow(3 * MILLIS, 3 * MILLIS)];
        let e = validate_faults(&plan, 10 * MILLIS, 0, 1, 1, false).unwrap_err();
        assert!(e.contains("empty or inverted"), "{e}");
        // Start inside the warmup: the fault era would be mis-measured.
        let plan = [slow(MILLIS, 4 * MILLIS)];
        let e = validate_faults(&plan, 10 * MILLIS, 2 * MILLIS, 1, 1, false).unwrap_err();
        assert!(e.contains("warmup"), "{e}");
        // Starting exactly at the warmup boundary is fine.
        let plan = [slow(2 * MILLIS, 4 * MILLIS)];
        assert!(validate_faults(&plan, 10 * MILLIS, 2 * MILLIS, 1, 1, false).is_ok());
    }

    #[test]
    fn validate_rejects_bad_factors_and_indices() {
        let d = 10 * MILLIS;
        let bad = FaultSpec::new(
            FaultKind::AccelSlowdown { unit: 0, factor: 1.5 },
            MILLIS,
            2 * MILLIS,
        );
        assert!(validate_faults(&[bad], d, 0, 1, 1, false).is_err());
        let bad = FaultSpec::new(
            FaultKind::SsdSlowdown { factor: 0.5 },
            MILLIS,
            2 * MILLIS,
        );
        assert!(validate_faults(&[bad], d, 0, 1, 1, true).is_err());
        let ok = FaultSpec::new(FaultKind::SsdSlowdown { factor: 3.0 }, MILLIS, 2 * MILLIS);
        assert!(validate_faults(&[ok], d, 0, 1, 1, true).is_ok());
        let e = validate_faults(&[ok], d, 0, 1, 1, false).unwrap_err();
        assert!(e.contains("raid"), "{e}");
        let bad = FaultSpec::new(
            FaultKind::RogueTenant { flow: 5 },
            MILLIS,
            2 * MILLIS,
        );
        let e = validate_faults(&[bad], d, 0, 2, 1, false).unwrap_err();
        assert!(e.contains("out of range"), "{e}");
        let bad = FaultSpec::new(
            FaultKind::ProfileSkew { accel: 3, factor: 1.5 },
            MILLIS,
            2 * MILLIS,
        );
        assert!(validate_faults(&[bad], d, 0, 1, 1, false).is_err());
    }

    #[test]
    fn validate_rejects_overlap_on_one_component_only() {
        let d = 10 * MILLIS;
        // Same accelerator, overlapping windows: rejected.
        let e = validate_faults(
            &[slow(2 * MILLIS, 5 * MILLIS), slow(4 * MILLIS, 6 * MILLIS)],
            d,
            0,
            1,
            1,
            false,
        )
        .unwrap_err();
        assert!(e.contains("overlap"), "{e}");
        // Back-to-back windows on one component are fine ([at, until) is
        // half-open).
        assert!(validate_faults(
            &[slow(2 * MILLIS, 4 * MILLIS), slow(4 * MILLIS, 6 * MILLIS)],
            d,
            0,
            1,
            1,
            false,
        )
        .is_ok());
        // Overlap across *different* components is fine.
        let plan = [
            slow(2 * MILLIS, 5 * MILLIS),
            FaultSpec::new(FaultKind::LinkDegrade { factor: 0.5 }, 3 * MILLIS, 6 * MILLIS),
        ];
        assert!(validate_faults(&plan, d, 0, 1, 1, false).is_ok());
    }

    #[test]
    fn targets_distinguish_components() {
        assert_eq!(
            FaultKind::AccelSlowdown { unit: 1, factor: 0.5 }.target(),
            FaultTarget::Accel(1)
        );
        assert_ne!(
            FaultKind::AccelSlowdown { unit: 0, factor: 0.5 }.target(),
            FaultKind::AccelSlowdown { unit: 1, factor: 0.5 }.target()
        );
        assert_eq!(FaultKind::ControlOutage.target(), FaultTarget::ControlPlane);
        assert_eq!(FaultKind::ControlOutage.name(), "control_outage");
    }
}
