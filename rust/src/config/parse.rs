//! TOML-subset tokenizer/parser for [`super::Document`].
//!
//! Supported grammar (a strict subset of TOML 1.0):
//!
//! ```text
//! document   := line*
//! line       := ws (comment | header | arrayheader | pair)? ws
//! header     := '[' dotted ']'
//! arrayheader:= '[[' dotted ']]'
//! pair       := key ws '=' ws value
//! value      := string | float | int | bool | array
//! array      := '[' (value (',' value)* ','?)? ']'
//! ```
//!
//! Strings are double-quoted with `\"`, `\\`, `\n`, `\t` escapes. Unsupported
//! TOML features (multi-line strings, dates, inline tables) produce errors
//! rather than silent misparses.

use super::{Document, Table, Value};

/// Parse error with line number context.
///
/// (Display/Error are hand-implemented: `thiserror` is a proc-macro crate
/// the offline build environment cannot provide.)
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

fn err<T>(line: usize, msg: impl Into<String>) -> Result<T, ParseError> {
    Err(ParseError {
        line,
        msg: msg.into(),
    })
}

/// Parse a complete document.
pub fn parse_document(text: &str) -> Result<Document, ParseError> {
    let mut doc = Document::default();
    // Current insertion target: either a named table or the latest entry of
    // an array-of-tables.
    enum Target {
        Table(String),
        ArrayEntry(String),
    }
    let mut target = Target::Table(String::new());
    doc.tables.insert(String::new(), Table::new());

    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            let name = name.trim();
            if name.is_empty() {
                return err(lineno, "empty [[table]] name");
            }
            validate_key_path(name, lineno)?;
            doc.table_arrays
                .entry(name.to_string())
                .or_default()
                .push(Table::new());
            target = Target::ArrayEntry(name.to_string());
        } else if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let name = name.trim();
            if name.is_empty() {
                return err(lineno, "empty [table] name");
            }
            validate_key_path(name, lineno)?;
            doc.tables.entry(name.to_string()).or_default();
            target = Target::Table(name.to_string());
        } else if let Some(eq) = find_top_level_eq(line) {
            let key = line[..eq].trim();
            if key.is_empty() {
                return err(lineno, "empty key");
            }
            validate_key_path(key, lineno)?;
            let (value, rest) = parse_value(line[eq + 1..].trim(), lineno)?;
            if !rest.trim().is_empty() {
                return err(lineno, format!("trailing characters: `{rest}`"));
            }
            let table = match &target {
                Target::Table(name) => doc.tables.get_mut(name).unwrap(),
                Target::ArrayEntry(name) => {
                    doc.table_arrays.get_mut(name).unwrap().last_mut().unwrap()
                }
            };
            if table.insert(key.to_string(), value).is_some() {
                return err(lineno, format!("duplicate key `{key}`"));
            }
        } else {
            return err(lineno, format!("unrecognized line: `{line}`"));
        }
    }
    Ok(doc)
}

/// Strip a `#` comment unless it is inside a quoted string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '\\' if in_str => {
                escaped = !escaped;
                continue;
            }
            '"' if !escaped => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
        escaped = false;
    }
    line
}

/// Find the first `=` outside of quotes.
fn find_top_level_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '\\' if in_str => {
                escaped = !escaped;
                continue;
            }
            '"' if !escaped => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
        escaped = false;
    }
    None
}

fn validate_key_path(key: &str, lineno: usize) -> Result<(), ParseError> {
    for part in key.split('.') {
        if part.is_empty()
            || !part
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
        {
            return err(lineno, format!("invalid key `{key}`"));
        }
    }
    Ok(())
}

/// Parse a value from the front of `s`; return (value, unconsumed rest).
fn parse_value<'a>(s: &'a str, lineno: usize) -> Result<(Value, &'a str), ParseError> {
    let s = s.trim_start();
    if s.is_empty() {
        return err(lineno, "missing value");
    }
    if let Some(rest) = s.strip_prefix('"') {
        return parse_string(rest, lineno);
    }
    if let Some(rest) = s.strip_prefix('[') {
        return parse_array(rest, lineno);
    }
    // Scalar token: up to a delimiter.
    let end = s
        .find(|c: char| c == ',' || c == ']' || c.is_whitespace())
        .unwrap_or(s.len());
    let (tok, rest) = s.split_at(end);
    let value = match tok {
        "true" => Value::Bool(true),
        "false" => Value::Bool(false),
        _ => {
            // TOML allows underscores in numbers.
            let clean: String = tok.chars().filter(|&c| c != '_').collect();
            if let Ok(i) = clean.parse::<i64>() {
                Value::Int(i)
            } else if let Ok(f) = clean.parse::<f64>() {
                Value::Float(f)
            } else {
                return err(lineno, format!("cannot parse value `{tok}`"));
            }
        }
    };
    Ok((value, rest))
}

fn parse_string<'a>(s: &'a str, lineno: usize) -> Result<(Value, &'a str), ParseError> {
    let mut out = String::new();
    let mut chars = s.char_indices();
    while let Some((i, c)) = chars.next() {
        match c {
            '"' => return Ok((Value::Str(out), &s[i + 1..])),
            '\\' => match chars.next() {
                Some((_, 'n')) => out.push('\n'),
                Some((_, 't')) => out.push('\t'),
                Some((_, '"')) => out.push('"'),
                Some((_, '\\')) => out.push('\\'),
                other => {
                    return err(lineno, format!("bad escape: {other:?}"));
                }
            },
            _ => out.push(c),
        }
    }
    err(lineno, "unterminated string")
}

fn parse_array<'a>(mut s: &'a str, lineno: usize) -> Result<(Value, &'a str), ParseError> {
    let mut items = Vec::new();
    loop {
        s = s.trim_start();
        if let Some(rest) = s.strip_prefix(']') {
            return Ok((Value::Array(items), rest));
        }
        if s.is_empty() {
            return err(lineno, "unterminated array");
        }
        let (v, rest) = parse_value(s, lineno)?;
        items.push(v);
        s = rest.trim_start();
        if let Some(rest) = s.strip_prefix(',') {
            s = rest;
        } else if !s.starts_with(']') {
            return err(lineno, "expected `,` or `]` in array");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_types() {
        let doc = parse_document("a = 1\nb = 2.5\nc = true\nd = \"hi\"\n").unwrap();
        let root = &doc.tables[""];
        assert_eq!(root["a"], Value::Int(1));
        assert_eq!(root["b"], Value::Float(2.5));
        assert_eq!(root["c"], Value::Bool(true));
        assert_eq!(root["d"], Value::Str("hi".into()));
    }

    #[test]
    fn negative_and_underscored_numbers() {
        let doc = parse_document("a = -3\nb = 1_000_000\nc = -2.5e3\n").unwrap();
        let root = &doc.tables[""];
        assert_eq!(root["a"], Value::Int(-3));
        assert_eq!(root["b"], Value::Int(1_000_000));
        assert_eq!(root["c"], Value::Float(-2500.0));
    }

    #[test]
    fn string_escapes() {
        let doc = parse_document(r#"s = "a\"b\\c\nd""#).unwrap();
        assert_eq!(doc.tables[""]["s"], Value::Str("a\"b\\c\nd".into()));
    }

    #[test]
    fn comments_stripped_not_in_strings() {
        let doc = parse_document("a = \"x # y\" # real comment\nb = 2\n").unwrap();
        assert_eq!(doc.tables[""]["a"], Value::Str("x # y".into()));
        assert_eq!(doc.tables[""]["b"], Value::Int(2));
    }

    #[test]
    fn nested_arrays() {
        let doc = parse_document("a = [[1, 2], [3]]\n").unwrap();
        match &doc.tables[""]["a"] {
            Value::Array(outer) => {
                assert_eq!(outer.len(), 2);
                assert_eq!(outer[0], Value::Array(vec![Value::Int(1), Value::Int(2)]));
            }
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn trailing_comma_allowed() {
        let doc = parse_document("a = [1, 2,]\n").unwrap();
        assert_eq!(
            doc.tables[""]["a"],
            Value::Array(vec![Value::Int(1), Value::Int(2)])
        );
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse_document("good = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse_document("x = \"unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse_document("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn dotted_sections() {
        let doc = parse_document("[a.b-c]\nx = 1\n").unwrap();
        assert_eq!(doc.tables["a.b-c"]["x"], Value::Int(1));
        assert!(parse_document("[a..b]\n").is_err());
        assert!(parse_document("[a b]\n").is_err());
    }
}
