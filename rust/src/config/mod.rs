//! Configuration system.
//!
//! `serde`/`toml` are not available in the offline registry, so this module
//! implements a TOML-subset parser sufficient for experiment and deployment
//! configs: `[section]` / `[section.sub]` headers, `key = value` pairs with
//! string / integer / float / boolean / homogeneous-array values, `#`
//! comments, and repeated `[[array-of-tables]]` sections (used for flow
//! lists). Typed experiment structs live in `system::spec`; this layer is the
//! untyped document plus typed accessors with good error messages.

pub mod experiment;
pub mod parse;

pub use experiment::{fleet_from_document, spec_from_document};
pub use parse::{parse_document, ParseError};

use std::collections::BTreeMap;

/// A scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// One `[section]`: ordered key/value map.
pub type Table = BTreeMap<String, Value>;

/// A parsed document: named tables plus arrays-of-tables.
#[derive(Debug, Clone, Default)]
pub struct Document {
    /// `[a.b]` sections, keyed by dotted path; root keys land under "".
    pub tables: BTreeMap<String, Table>,
    /// `[[a.b]]` repeated sections, in file order.
    pub table_arrays: BTreeMap<String, Vec<Table>>,
}

impl Document {
    pub fn from_str(text: &str) -> Result<Self, ParseError> {
        parse_document(text)
    }

    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading config {}: {e}", path.display()))?;
        Ok(Self::from_str(&text)?)
    }

    /// Look up `section` (dotted) then `key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.tables.get(section).and_then(|t| t.get(key))
    }

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(Value::as_str).unwrap_or(default)
    }
    pub fn int_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(Value::as_int).unwrap_or(default)
    }
    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_float).unwrap_or(default)
    }
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Required typed accessors with contextual errors.
    pub fn require_str(&self, section: &str, key: &str) -> anyhow::Result<&str> {
        self.get(section, key)
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing string `{key}` in [{section}]"))
    }
    pub fn require_float(&self, section: &str, key: &str) -> anyhow::Result<f64> {
        self.get(section, key)
            .and_then(Value::as_float)
            .ok_or_else(|| anyhow::anyhow!("missing number `{key}` in [{section}]"))
    }
    pub fn require_int(&self, section: &str, key: &str) -> anyhow::Result<i64> {
        self.get(section, key)
            .and_then(Value::as_int)
            .ok_or_else(|| anyhow::anyhow!("missing integer `{key}` in [{section}]"))
    }

    /// All tables of a `[[name]]` array, empty slice if absent.
    pub fn array_of(&self, name: &str) -> &[Table] {
        self.table_arrays
            .get(name)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }
}

/// Helper for typed reads out of a [`Table`] (array-of-tables entries).
pub trait TableExt {
    fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str;
    fn int_or(&self, key: &str, default: i64) -> i64;
    fn float_or(&self, key: &str, default: f64) -> f64;
    fn bool_or(&self, key: &str, default: bool) -> bool;
}

impl TableExt for Table {
    fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }
    fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_int).unwrap_or(default)
    }
    fn float_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_float).unwrap_or(default)
    }
    fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
title = "fig3"

[pcie]
gen = 3
lanes = 8
efficiency = 0.85
duplex = true

[accelerator]
kind = "ipsec"
peak_gbps = 32.0

[[flows]]
vm = 1
size = 256
load = 0.1

[[flows]]
vm = 2
size = 64
load = 0.5
sizes = [64, 256, 1500]
"#;

    #[test]
    fn parses_sections_and_root() {
        let doc = Document::from_str(SAMPLE).unwrap();
        assert_eq!(doc.str_or("", "title", "?"), "fig3");
        assert_eq!(doc.int_or("pcie", "gen", 0), 3);
        assert_eq!(doc.int_or("pcie", "lanes", 0), 8);
        assert!((doc.float_or("pcie", "efficiency", 0.0) - 0.85).abs() < 1e-12);
        assert!(doc.bool_or("pcie", "duplex", false));
        assert_eq!(doc.str_or("accelerator", "kind", "?"), "ipsec");
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = Document::from_str("[a]\nx = 3\n").unwrap();
        assert_eq!(doc.float_or("a", "x", 0.0), 3.0);
    }

    #[test]
    fn array_of_tables_in_order() {
        let doc = Document::from_str(SAMPLE).unwrap();
        let flows = doc.array_of("flows");
        assert_eq!(flows.len(), 2);
        assert_eq!(flows[0].int_or("vm", 0), 1);
        assert_eq!(flows[1].int_or("vm", 0), 2);
        let sizes = flows[1].get("sizes").unwrap().as_array().unwrap();
        assert_eq!(sizes.len(), 3);
        assert_eq!(sizes[2].as_int(), Some(1500));
    }

    #[test]
    fn missing_required_key_errors() {
        let doc = Document::from_str(SAMPLE).unwrap();
        assert!(doc.require_str("pcie", "nope").is_err());
        assert!(doc.require_float("accelerator", "peak_gbps").is_ok());
    }
}
