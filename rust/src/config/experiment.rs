//! Typed experiment construction from a parsed config document.
//!
//! Schema (TOML subset; see `configs/` for examples):
//!
//! ```toml
//! [experiment]
//! mode = "arcus"            # arcus | host_no_ts | host_ts_reflex |
//!                           # host_ts_firecracker | bypassed_panic
//! duration_ms = 20
//! warmup_ms = 2
//! seed = 1
//! shared_port = false
//! hierarchy = false         # hierarchical shaper tree (Arcus mode; see
//!                           # crate::shaping::hierarchy)
//! obs_retention = 256       # samples kept per observability series ring
//!                           # (crate::obs; 0 disables series sampling)
//! obs_sample_every = 1      # sample the series every Nth control tick
//!
//! [[accels]]
//! kind = "ipsec"            # or "synthetic" with peak_gbps = 50.0
//!
//! [raid]                    # optional: enables storage flows
//! drives = 4
//!
//! [adaptive]                # optional: closed-loop adaptive control
//! increase_step = 0.02      # (Arcus mode; crate::api::AdaptiveControlPlane)
//! decrease_factor = 0.85    # fast-tier AIMD gains
//! max_ceiling = 1.25        # shaped-rate cap as a multiple of the SLO
//! replan_every = 10         # slow-tier aggregate re-plan period (ticks)
//! deadband_ppm = 20000      # attainment dead-band around 1.0
//! backlog_depth = 64        # queue depth that counts as backlog
//!
//! [population]              # optional: population workload layer
//! users = 100000            # N users multiplexed onto the flows
//! zipf_s = 1.1              # user-popularity exponent (0 = uniform)
//! pareto_alpha = 1.3        # message-size tail index (must be > 1)
//! pareto_xm = 64            # minimum message size (bytes)
//! max_bytes = 65536         # tail clamp (bytes)
//! diurnal_period_ms = 0.0   # rate-envelope period (0 = flat)
//! diurnal_depth = 0.0       # envelope depth in [0, 1)
//! burst_epochs = 0          # flash-crowd windows across the run
//! burst_factor = 3.0        # rate multiplier inside a window
//! burst_span_us = 500.0     # window length
//!
//! [fleet]                   # optional: multi-host fleet tier
//! hosts = 2                 # shard flows by vm % hosts (crate::fleet)
//! threads = 0               # advance threads (0 = one per host, 1 = serial)
//! propagation_delay_us = 0.0  # directive publish → delivery delay
//! drop_from_ms = 0.0        # one optional delivery drop window
//! drop_until_ms = 0.0       # (equal bounds = no window)
//! interchange_every = 1     # barriers every N control periods
//! tight_ceiling = 1.05      # tenant envelope factors over the SLO sum
//! boost_ceiling = 2.0
//! attainment_floor_ppm = 970000
//! clear_rounds = 3
//! refresh_every = 16
//!
//! [[flows]]
//! vm = 0
//! path = "function_call"    # function_call | inline_nic_rx | inline_nic_tx | inline_p2p
//! size = 1500               # fixed message size (bytes)
//! load = 0.5                # fraction of line_gbps
//! line_gbps = 32.0
//! burst = "paced"           # paced | poisson | onoff
//! burst_len = 16            # for onoff
//! slo_gbps = 10.0           # or slo_kiops = 300.0, slo_latency_us = 1.0,
//!                           # or slo = "best_effort"
//! accel = 0                 # index into [[accels]]
//! kind = "accel"            # accel | storage_read | storage_write
//! priority = 1
//!
//! [[lifecycle]]             # optional tenant-churn schedule
//! flow = 2                  # index into [[flows]]
//! event = "arrive"          # arrive | depart | renegotiate
//! at_ms = 3.0
//! slo_gbps = 12.0           # renegotiate only (slo_kiops also accepted;
//!                           # neither = drop to best_effort)
//!
//! [[faults]]                # optional fault-injection plan (crate::faults)
//! kind = "accel_slowdown"   # accel_slowdown | link_degrade | ssd_slowdown |
//!                           # profile_skew | rogue_tenant | control_outage
//! at_ms = 4.0               # window [at_ms, until_ms)
//! until_ms = 8.0
//! factor = 0.5              # throughput multiplier (accel/link, in (0,1]),
//!                           # latency multiplier (ssd, ≥ 1), or capacity
//!                           # mis-estimate (profile_skew, > 0)
//! unit = 0                  # accel_slowdown: [[accels]] index
//! accel = 0                 # profile_skew: [[accels]] index
//! flow = 2                  # rogue_tenant: [[flows]] index
//! ```

use anyhow::{bail, Context, Result};

use crate::accel::AccelModel;
use crate::api::AdaptiveConfig;
use crate::faults::{validate_faults, FaultKind, FaultSpec};
use crate::flow::pattern::{Burstiness, SizeDist};
use crate::flow::{FlowKind, FlowSpec, Path, Slo, TrafficPattern};
use crate::storage::SsdConfig;
use crate::system::{ExperimentSpec, LifecycleEvent, Mode};
use crate::util::units::{Rate, MICROS, MILLIS};

use super::{Document, Table, TableExt};

/// Build an [`ExperimentSpec`] from a parsed document.
pub fn spec_from_document(doc: &Document) -> Result<ExperimentSpec> {
    let mode_name = doc.str_or("experiment", "mode", "arcus");
    let mode = Mode::parse(mode_name).map_err(|e| anyhow::anyhow!("{e}"))?;

    let mut accels = Vec::new();
    for t in doc.array_of("accels") {
        accels.push(accel_from_table(t)?);
    }

    let mut flows = Vec::new();
    for (i, t) in doc.array_of("flows").iter().enumerate() {
        flows.push(flow_from_table(i, t, accels.len())?);
    }
    if flows.is_empty() {
        bail!("config defines no [[flows]]");
    }

    let mut spec = ExperimentSpec::new(mode, accels, flows)
        .with_duration(doc.float_or("experiment", "duration_ms", 20.0) as u64 * MILLIS)
        .with_warmup(doc.float_or("experiment", "warmup_ms", 2.0) as u64 * MILLIS)
        .with_seed(doc.int_or("experiment", "seed", 1) as u64);
    if doc.bool_or("experiment", "shared_port", false) {
        spec = spec.with_shared_port();
    }
    if doc.bool_or("experiment", "trace", false) {
        spec = spec.with_trace();
    }
    if doc.bool_or("experiment", "hierarchy", false) {
        spec = spec.with_hierarchy();
    }
    if doc.tables.contains_key("raid") {
        let drives = doc.int_or("raid", "drives", 4) as usize;
        spec = spec.with_raid(drives, SsdConfig::samsung_983dct());
    }
    if doc.tables.contains_key("adaptive") {
        let d = AdaptiveConfig::default();
        let replan_every = doc.int_or("adaptive", "replan_every", d.replan_every as i64);
        let deadband_ppm = doc.int_or("adaptive", "deadband_ppm", d.deadband_ppm as i64);
        let backlog_depth = doc.int_or("adaptive", "backlog_depth", d.backlog_depth as i64);
        // Reject negatives before the u64 casts below silently wrap them
        // into huge values that would pass AdaptiveConfig::validate.
        if replan_every < 0 || deadband_ppm < 0 || backlog_depth < 0 {
            bail!(
                "[adaptive]: replan_every/deadband_ppm/backlog_depth must be \
                 non-negative (got {replan_every}/{deadband_ppm}/{backlog_depth})"
            );
        }
        let cfg = AdaptiveConfig {
            increase_step: doc.float_or("adaptive", "increase_step", d.increase_step),
            decrease_factor: doc.float_or("adaptive", "decrease_factor", d.decrease_factor),
            max_ceiling: doc.float_or("adaptive", "max_ceiling", d.max_ceiling),
            replan_every: replan_every as u64,
            deadband_ppm: deadband_ppm as u64,
            backlog_depth: backlog_depth as u64,
        };
        cfg.validate().map_err(|e| anyhow::anyhow!("[adaptive]: {e}"))?;
        spec = spec.with_adaptive(cfg);
    }
    if doc.tables.contains_key("population") {
        if doc.tables.contains_key("fleet") {
            bail!(
                "[population] cannot combine with [fleet]: per-user accounting \
                 lives in the single-world engine — run the population on one \
                 host or drop the fleet table"
            );
        }
        let d = crate::workload::PopulationConfig::default();
        let users = doc.int_or("population", "users", d.users as i64);
        let pareto_xm = doc.int_or("population", "pareto_xm", d.pareto_xm as i64);
        let max_bytes = doc.int_or("population", "max_bytes", d.max_bytes as i64);
        let burst_epochs = doc.int_or("population", "burst_epochs", d.burst_epochs as i64);
        // Reject negatives before the unsigned casts silently wrap them.
        if users < 1 || pareto_xm < 0 || max_bytes < 0 || burst_epochs < 0 {
            bail!(
                "[population]: users must be ≥ 1 and pareto_xm/max_bytes/\
                 burst_epochs non-negative (got {users}/{pareto_xm}/\
                 {max_bytes}/{burst_epochs})"
            );
        }
        let diurnal_period_ms = doc.float_or("population", "diurnal_period_ms", 0.0);
        let burst_span_us =
            doc.float_or("population", "burst_span_us", d.burst_span as f64 / MICROS as f64);
        if diurnal_period_ms < 0.0 || burst_span_us < 0.0 {
            bail!(
                "[population]: diurnal_period_ms/burst_span_us must be \
                 non-negative (got {diurnal_period_ms}/{burst_span_us})"
            );
        }
        let cfg = crate::workload::PopulationConfig {
            users: users as usize,
            zipf_s: doc.float_or("population", "zipf_s", d.zipf_s),
            pareto_alpha: doc.float_or("population", "pareto_alpha", d.pareto_alpha),
            pareto_xm: pareto_xm as u64,
            max_bytes: max_bytes as u64,
            diurnal_period: (diurnal_period_ms * MILLIS as f64) as u64,
            diurnal_depth: doc.float_or("population", "diurnal_depth", d.diurnal_depth),
            burst_epochs: burst_epochs as usize,
            burst_factor: doc.float_or("population", "burst_factor", d.burst_factor),
            burst_span: (burst_span_us * MICROS as f64) as u64,
        };
        cfg.validate(spec.flows.len())
            .map_err(|e| anyhow::anyhow!("[population]: {e}"))?;
        spec = spec.with_population(cfg);
    }
    spec.control_period = (doc.float_or("experiment", "control_period_us", 100.0) * MICROS as f64) as u64;
    spec.queue_cap = doc.int_or("experiment", "queue_cap", 4096) as usize;
    let retention = doc.int_or("experiment", "obs_retention", 256);
    let sample_every = doc.int_or("experiment", "obs_sample_every", 1);
    if retention < 0 || sample_every < 1 {
        bail!(
            "obs_retention must be >= 0 and obs_sample_every >= 1 \
             (got {retention}/{sample_every})"
        );
    }
    spec = spec.with_obs(retention as usize, sample_every as u64);
    for (i, t) in doc.array_of("lifecycle").iter().enumerate() {
        spec.lifecycle
            .push(lifecycle_from_table(i, t, spec.flows.len(), spec.duration)?);
    }
    for (i, t) in doc.array_of("faults").iter().enumerate() {
        spec.faults.push(fault_from_table(i, t)?);
    }
    if !spec.faults.is_empty() {
        // Real accel count, not max(1): an accel fault on a storage-only
        // config (no [[accels]]) must fail here, not panic mid-run.
        validate_faults(
            &spec.faults,
            spec.duration,
            spec.warmup,
            spec.flows.len(),
            spec.accels.len(),
            spec.raid.is_some(),
        )
        .map_err(|e| anyhow::anyhow!("[[faults]]: {e}"))?;
        // The control plane applies profile skews by accelerator *name*:
        // overlapping skews on same-named units would alias even though
        // their indices differ, so the generic per-index overlap check
        // above cannot catch them.
        for (i, a) in spec.faults.iter().enumerate() {
            let FaultKind::ProfileSkew { accel: ai, .. } = a.kind else { continue };
            for (j, b) in spec.faults.iter().enumerate().skip(i + 1) {
                let FaultKind::ProfileSkew { accel: bi, .. } = b.kind else { continue };
                if ai != bi
                    && spec.accels[ai].name == spec.accels[bi].name
                    && a.at < b.until
                    && b.at < a.until
                {
                    bail!(
                        "[[faults]]: profile_skew faults {i} and {j} overlap on \
                         accelerators {ai} and {bi}, which share the name \
                         `{}` — skews apply by name and would alias; stagger \
                         the windows or use distinct accelerator kinds",
                        spec.accels[ai].name
                    );
                }
            }
        }
    }
    Ok(spec)
}

/// Optional `[fleet]` table → the multi-host fleet tier's configuration
/// ([`crate::fleet::FleetConfig`]). `Ok(None)` when the config carries no
/// fleet table (the single-world engine runs the spec directly).
pub fn fleet_from_document(doc: &Document) -> Result<Option<crate::fleet::FleetConfig>> {
    if !doc.tables.contains_key("fleet") {
        return Ok(None);
    }
    let d = crate::fleet::FleetConfig::default();
    let hosts = doc.int_or("fleet", "hosts", d.hosts as i64);
    let threads = doc.int_or("fleet", "threads", d.threads as i64);
    let interchange_every = doc.int_or("fleet", "interchange_every", d.interchange_every as i64);
    let clear_rounds = doc.int_or("fleet", "clear_rounds", d.clear_rounds as i64);
    let refresh_every = doc.int_or("fleet", "refresh_every", d.refresh_every as i64);
    let floor_ppm =
        doc.int_or("fleet", "attainment_floor_ppm", d.attainment_floor_ppm as i64);
    // Reject negatives before the unsigned casts silently wrap them.
    if hosts < 1 || threads < 0 || interchange_every < 1 || clear_rounds < 0
        || refresh_every < 0 || floor_ppm < 0
    {
        bail!(
            "[fleet]: hosts/interchange_every must be ≥ 1 and \
             threads/clear_rounds/refresh_every/attainment_floor_ppm \
             non-negative (got {hosts}/{interchange_every}/{threads}/\
             {clear_rounds}/{refresh_every}/{floor_ppm})"
        );
    }
    let delay_us = doc.float_or("fleet", "propagation_delay_us", 0.0);
    let drop_from_ms = doc.float_or("fleet", "drop_from_ms", 0.0);
    let drop_until_ms = doc.float_or("fleet", "drop_until_ms", 0.0);
    if delay_us < 0.0 || drop_from_ms < 0.0 || drop_until_ms < drop_from_ms {
        bail!(
            "[fleet]: propagation_delay_us must be non-negative and \
             drop_from_ms ≤ drop_until_ms (got {delay_us}/{drop_from_ms}/\
             {drop_until_ms})"
        );
    }
    let mut drop_windows = Vec::new();
    if drop_until_ms > drop_from_ms {
        drop_windows.push((
            (drop_from_ms * MILLIS as f64) as u64,
            (drop_until_ms * MILLIS as f64) as u64,
        ));
    }
    let cfg = crate::fleet::FleetConfig {
        hosts: hosts as usize,
        threads: threads as usize,
        propagation_delay: (delay_us * MICROS as f64) as u64,
        interchange_every: interchange_every as u64,
        drop_windows,
        tight_ceiling: doc.float_or("fleet", "tight_ceiling", d.tight_ceiling),
        boost_ceiling: doc.float_or("fleet", "boost_ceiling", d.boost_ceiling),
        attainment_floor_ppm: floor_ppm as u64,
        clear_rounds: clear_rounds as u32,
        refresh_every: refresh_every as u64,
    };
    cfg.validate().map_err(|e| anyhow::anyhow!("[fleet]: {e}"))?;
    Ok(Some(cfg))
}

fn fault_from_table(i: usize, t: &Table) -> Result<FaultSpec> {
    let at_ms = t.float_or("at_ms", 0.0);
    let until_ms = t.float_or("until_ms", 0.0);
    if at_ms < 0.0 || until_ms < 0.0 {
        bail!("fault {i}: at_ms/until_ms must be non-negative (got {at_ms}/{until_ms})");
    }
    let at = (at_ms * MILLIS as f64) as u64;
    let until = (until_ms * MILLIS as f64) as u64;
    let kind = match t.str_or("kind", "") {
        "accel_slowdown" => FaultKind::AccelSlowdown {
            unit: t.int_or("unit", 0) as usize,
            factor: t.float_or("factor", 0.5),
        },
        "link_degrade" => FaultKind::LinkDegrade { factor: t.float_or("factor", 0.5) },
        "ssd_slowdown" => FaultKind::SsdSlowdown { factor: t.float_or("factor", 2.0) },
        "profile_skew" => FaultKind::ProfileSkew {
            accel: t.int_or("accel", 0) as usize,
            factor: t.float_or("factor", 1.5),
        },
        "rogue_tenant" => {
            let flow = t.int_or("flow", -1);
            if flow < 0 {
                bail!("fault {i}: rogue_tenant needs `flow` (a [[flows]] index)");
            }
            FaultKind::RogueTenant { flow: flow as usize }
        }
        "control_outage" => FaultKind::ControlOutage,
        other => bail!(
            "fault {i}: unknown kind `{other}` (accel_slowdown|link_degrade|\
             ssd_slowdown|profile_skew|rogue_tenant|control_outage)"
        ),
    };
    Ok(FaultSpec::new(kind, at, until))
}

fn lifecycle_from_table(
    i: usize,
    t: &Table,
    n_flows: usize,
    duration: crate::util::units::Time,
) -> Result<LifecycleEvent> {
    let flow = t.int_or("flow", -1);
    if flow < 0 || flow as usize >= n_flows {
        bail!("lifecycle {i}: `flow` must index a [[flows]] entry (0..{n_flows})");
    }
    let flow = flow as usize;
    let at_ms = t.float_or("at_ms", 0.0);
    if at_ms < 0.0 {
        bail!("lifecycle {i}: `at_ms` must be non-negative (got {at_ms})");
    }
    let at = (at_ms * MILLIS as f64) as u64;
    if at >= duration {
        bail!(
            "lifecycle {i}: at_ms {at_ms} is at/after the run's duration \
             ({} ms) — the event would never fire",
            duration as f64 / MILLIS as f64
        );
    }
    match t.str_or("event", "") {
        "arrive" => Ok(LifecycleEvent::Arrive { flow, at }),
        "depart" => Ok(LifecycleEvent::Depart { flow, at }),
        "renegotiate" => {
            let slo = if let Some(g) = t.get("slo_gbps").and_then(super::Value::as_float) {
                Slo::gbps(g)
            } else if let Some(k) = t.get("slo_kiops").and_then(super::Value::as_float) {
                Slo::iops(k * 1e3)
            } else {
                Slo::BestEffort
            };
            Ok(LifecycleEvent::Renegotiate { flow, at, slo })
        }
        other => bail!("lifecycle {i}: unknown event `{other}` (arrive|depart|renegotiate)"),
    }
}

fn accel_from_table(t: &Table) -> Result<AccelModel> {
    let kind = t.str_or("kind", "synthetic");
    if kind == "synthetic" {
        let peak = t.float_or("peak_gbps", 50.0);
        return Ok(AccelModel::synthetic(Rate::gbps(peak)));
    }
    AccelModel::by_name(kind).with_context(|| format!("unknown accelerator `{kind}`"))
}

fn flow_from_table(i: usize, t: &Table, n_accels: usize) -> Result<FlowSpec> {
    let path_name = t.str_or("path", "function_call");
    let path = Path::by_name(path_name)
        .with_context(|| format!("flow {i}: unknown path `{path_name}`"))?;
    let size = t.int_or("size", 1500) as u64;
    let load = t.float_or("load", 0.5);
    let line = Rate::gbps(t.float_or("line_gbps", 50.0));
    let burst = match t.str_or("burst", "paced") {
        "paced" => Burstiness::Paced,
        "poisson" => Burstiness::Poisson,
        "onoff" => Burstiness::OnOff { burst_len: t.int_or("burst_len", 16) as u32 },
        other => bail!("flow {i}: unknown burst `{other}`"),
    };
    let pattern = TrafficPattern { sizes: SizeDist::Fixed(size), load, line_rate: line, burst };

    let slo = if let Some(g) = t.get("slo_gbps").and_then(super::Value::as_float) {
        Slo::gbps(g)
    } else if let Some(k) = t.get("slo_kiops").and_then(super::Value::as_float) {
        Slo::iops(k * 1e3)
    } else if let Some(us) = t.get("slo_latency_us").and_then(super::Value::as_float) {
        Slo::Latency { max_ps: (us * MICROS as f64) as u64, percentile: 99.0 }
    } else {
        Slo::BestEffort
    };

    let kind = match t.str_or("kind", "accel") {
        "accel" => FlowKind::Accel,
        "storage_read" => FlowKind::StorageRead,
        "storage_write" => FlowKind::StorageWrite,
        other => bail!("flow {i}: unknown kind `{other}`"),
    };
    let accel = t.int_or("accel", 0) as usize;
    if kind == FlowKind::Accel && accel >= n_accels.max(1) {
        bail!("flow {i}: accel index {accel} out of range ({n_accels} defined)");
    }

    Ok(FlowSpec {
        id: i,
        vm: t.int_or("vm", i as i64) as usize,
        path,
        pattern,
        slo,
        accel,
        kind,
        priority: t.int_or("priority", 1) as u32,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
[experiment]
mode = "arcus"
duration_ms = 5
warmup_ms = 1
seed = 7

[[accels]]
kind = "ipsec"

[[accels]]
kind = "synthetic"
peak_gbps = 50.0

[[flows]]
vm = 0
path = "function_call"
size = 1500
load = 0.5
line_gbps = 32.0
slo_gbps = 10.0
accel = 0

[[flows]]
vm = 1
path = "inline_nic_rx"
size = 64
load = 0.2
burst = "poisson"
slo_latency_us = 1.0
accel = 1
"#;

    #[test]
    fn builds_spec_from_document() {
        let doc = Document::from_str(SAMPLE).unwrap();
        let spec = spec_from_document(&doc).unwrap();
        assert_eq!(spec.mode, Mode::Arcus);
        assert_eq!(spec.duration, 5 * MILLIS);
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.accels.len(), 2);
        assert_eq!(spec.accels[0].name, "ipsec");
        assert_eq!(spec.flows.len(), 2);
        assert_eq!(spec.flows[0].slo, Slo::gbps(10.0));
        assert!(matches!(spec.flows[1].slo, Slo::Latency { .. }));
        assert_eq!(spec.flows[1].path, Path::InlineNicRx);
        // Observability knobs default on.
        assert_eq!(spec.obs_retention, 256);
        assert_eq!(spec.obs_sample_every, 1);
    }

    #[test]
    fn parses_and_validates_obs_knobs() {
        let base = "[[accels]]\nkind = \"ipsec\"\n[[flows]]\nvm = 0\nslo_gbps = 8.0\n";
        let text = format!("[experiment]\nobs_retention = 64\nobs_sample_every = 4\n{base}");
        let spec = spec_from_document(&Document::from_str(&text).unwrap()).unwrap();
        assert_eq!(spec.obs_retention, 64);
        assert_eq!(spec.obs_sample_every, 4);
        let text = format!("[experiment]\nobs_sample_every = 0\n{base}");
        let err = spec_from_document(&Document::from_str(&text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("obs_sample_every"), "{err:#}");
    }

    #[test]
    fn parses_and_validates_adaptive_table() {
        let base = "[[accels]]\nkind = \"ipsec\"\n[[flows]]\nvm = 0\nslo_gbps = 8.0\n";
        // No [adaptive] table → the static planner runs alone.
        let spec = spec_from_document(&Document::from_str(base).unwrap()).unwrap();
        assert!(spec.adaptive.is_none());
        // An empty table enables the defaults.
        let text = format!("[adaptive]\n{base}");
        let spec = spec_from_document(&Document::from_str(&text).unwrap()).unwrap();
        assert_eq!(spec.adaptive, Some(AdaptiveConfig::default()));
        // Overrides are honored.
        let text = format!(
            "[adaptive]\nincrease_step = 0.05\nreplan_every = 4\nbacklog_depth = 32\n{base}"
        );
        let spec = spec_from_document(&Document::from_str(&text).unwrap()).unwrap();
        let cfg = spec.adaptive.unwrap();
        assert!((cfg.increase_step - 0.05).abs() < 1e-12);
        assert_eq!(cfg.replan_every, 4);
        assert_eq!(cfg.backlog_depth, 32);
        assert!((cfg.decrease_factor - AdaptiveConfig::default().decrease_factor).abs() < 1e-12);
        // Out-of-range gains surface the validator's complaint verbatim.
        let text = format!("[adaptive]\ndecrease_factor = 1.5\n{base}");
        let err = spec_from_document(&Document::from_str(&text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("decrease_factor"), "{err:#}");
        // Negative ints are rejected, not wrapped into huge u64s.
        let text = format!("[adaptive]\nreplan_every = -1\n{base}");
        let err = spec_from_document(&Document::from_str(&text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("non-negative"), "{err:#}");
    }

    #[test]
    fn parses_and_validates_fleet_table() {
        let base = "[[accels]]\nkind = \"ipsec\"\n[[flows]]\nvm = 0\nslo_gbps = 8.0\n";
        // No [fleet] table → single-world engine.
        let doc = Document::from_str(base).unwrap();
        assert!(fleet_from_document(&doc).unwrap().is_none());
        // An empty table enables the defaults.
        let doc = Document::from_str(&format!("[fleet]\n{base}")).unwrap();
        let cfg = fleet_from_document(&doc).unwrap().unwrap();
        assert_eq!(cfg.hosts, crate::fleet::FleetConfig::default().hosts);
        assert!(cfg.drop_windows.is_empty());
        // Overrides are honored, times convert to picoseconds.
        let text = format!(
            "[fleet]\nhosts = 4\nthreads = 1\npropagation_delay_us = 250.0\n\
             drop_from_ms = 2.0\ndrop_until_ms = 3.5\nboost_ceiling = 3.0\n{base}"
        );
        let cfg = fleet_from_document(&Document::from_str(&text).unwrap())
            .unwrap()
            .unwrap();
        assert_eq!(cfg.hosts, 4);
        assert_eq!(cfg.threads, 1);
        assert_eq!(cfg.propagation_delay, 250 * MICROS);
        assert_eq!(cfg.drop_windows, vec![(2 * MILLIS, 3 * MILLIS + MILLIS / 2)]);
        assert!((cfg.boost_ceiling - 3.0).abs() < 1e-12);
        // Zero hosts and inverted drop windows are rejected loudly.
        let doc = Document::from_str(&format!("[fleet]\nhosts = 0\n{base}")).unwrap();
        let err = fleet_from_document(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("hosts"), "{err:#}");
        let doc = Document::from_str(&format!(
            "[fleet]\ndrop_from_ms = 5.0\ndrop_until_ms = 2.0\n{base}"
        ))
        .unwrap();
        let err = fleet_from_document(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("drop_from_ms"), "{err:#}");
        // A boost ceiling under the tight ceiling fails FleetConfig's own
        // validator, surfaced verbatim.
        let doc = Document::from_str(&format!("[fleet]\nboost_ceiling = 0.5\n{base}")).unwrap();
        let err = fleet_from_document(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("boost_ceiling"), "{err:#}");
    }

    #[test]
    fn parses_and_validates_population_table() {
        let base = "[[accels]]\nkind = \"ipsec\"\n[[flows]]\nvm = 0\nslo_gbps = 8.0\n";
        // No [population] table → legacy pattern generators.
        let spec = spec_from_document(&Document::from_str(base).unwrap()).unwrap();
        assert!(spec.population.is_none());
        // An empty table enables the defaults.
        let text = format!("[population]\n{base}");
        let spec = spec_from_document(&Document::from_str(&text).unwrap()).unwrap();
        let d = crate::workload::PopulationConfig::default();
        assert_eq!(spec.population, Some(d.clone()));
        // Overrides are honored; times convert to picoseconds.
        let text = format!(
            "[population]\nusers = 5000\nzipf_s = 0.9\ndiurnal_period_ms = 4.0\n\
             diurnal_depth = 0.3\nburst_epochs = 2\nburst_span_us = 250.0\n{base}"
        );
        let spec = spec_from_document(&Document::from_str(&text).unwrap()).unwrap();
        let cfg = spec.population.unwrap();
        assert_eq!(cfg.users, 5000);
        assert!((cfg.zipf_s - 0.9).abs() < 1e-12);
        assert_eq!(cfg.diurnal_period, 4 * MILLIS);
        assert_eq!(cfg.burst_epochs, 2);
        assert_eq!(cfg.burst_span, 250 * MICROS);
        assert!((cfg.pareto_alpha - d.pareto_alpha).abs() < 1e-12);
        // The validator's complaint surfaces verbatim, tagged [population].
        let text = format!("[population]\npareto_alpha = 0.9\n{base}");
        let err = spec_from_document(&Document::from_str(&text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("pareto_alpha"), "{err:#}");
        // Negative ints are rejected, not wrapped into huge u64s.
        let text = format!("[population]\nusers = -5\n{base}");
        let err = spec_from_document(&Document::from_str(&text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("users"), "{err:#}");
        // Fewer users than flows cannot tile the blocks.
        let text = format!("[population]\nusers = 1\n{base}[[flows]]\nvm = 1\nslo_gbps = 2.0\n");
        let err = spec_from_document(&Document::from_str(&text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("cannot cover"), "{err:#}");
        // Population × fleet is rejected: per-user accounting is per-world.
        let text = format!("[population]\n[fleet]\nhosts = 2\n{base}");
        let err = spec_from_document(&Document::from_str(&text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("fleet"), "{err:#}");
    }

    #[test]
    fn storage_flow_requires_kind() {
        let text = r#"
[experiment]
mode = "host_no_ts"
[raid]
drives = 4
[[flows]]
kind = "storage_read"
path = "inline_p2p"
size = 4096
slo_kiops = 300.0
"#;
        let doc = Document::from_str(text).unwrap();
        let spec = spec_from_document(&doc).unwrap();
        assert!(spec.raid.is_some());
        assert_eq!(spec.flows[0].kind, FlowKind::StorageRead);
        assert!(matches!(spec.flows[0].slo, Slo::Iops { target, .. } if target == 300_000.0));
    }

    #[test]
    fn parses_lifecycle_schedule() {
        let text = r#"
[experiment]
mode = "arcus"
[[accels]]
kind = "ipsec"
[[flows]]
vm = 0
slo_gbps = 8.0
[[flows]]
vm = 1
slo_gbps = 7.0
[[lifecycle]]
flow = 1
event = "arrive"
at_ms = 3.0
[[lifecycle]]
flow = 0
event = "renegotiate"
at_ms = 5.0
slo_gbps = 11.0
[[lifecycle]]
flow = 0
event = "depart"
at_ms = 7.0
"#;
        let doc = Document::from_str(text).unwrap();
        let spec = spec_from_document(&doc).unwrap();
        assert_eq!(spec.lifecycle.len(), 3);
        assert_eq!(spec.lifecycle[0], LifecycleEvent::Arrive { flow: 1, at: 3 * MILLIS });
        assert_eq!(
            spec.lifecycle[1],
            LifecycleEvent::Renegotiate { flow: 0, at: 5 * MILLIS, slo: Slo::gbps(11.0) }
        );
        assert_eq!(spec.lifecycle[2], LifecycleEvent::Depart { flow: 0, at: 7 * MILLIS });
        assert_eq!(spec.arrival_time(1), 3 * MILLIS);
    }

    #[test]
    fn rejects_bad_lifecycle_entries() {
        // Flow index out of range.
        let text = "[[flows]]\nvm = 0\n[[lifecycle]]\nflow = 5\nevent = \"arrive\"\n";
        let doc = Document::from_str(text).unwrap();
        assert!(spec_from_document(&doc).is_err());
        // Unknown event name.
        let text = "[[flows]]\nvm = 0\n[[lifecycle]]\nflow = 0\nevent = \"vanish\"\n";
        let doc = Document::from_str(text).unwrap();
        let err = spec_from_document(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("vanish"), "{err:#}");
        // Event at/after the run's end would silently never fire.
        let text = "[experiment]\nduration_ms = 10\n[[flows]]\nvm = 0\n\
                    [[lifecycle]]\nflow = 0\nevent = \"depart\"\nat_ms = 15.0\n";
        let doc = Document::from_str(text).unwrap();
        let err = spec_from_document(&doc).unwrap_err();
        assert!(format!("{err:#}").contains("never fire"), "{err:#}");
        // Negative times are rejected, not saturated to zero.
        let text = "[[flows]]\nvm = 0\n\
                    [[lifecycle]]\nflow = 0\nevent = \"arrive\"\nat_ms = -1.0\n";
        let doc = Document::from_str(text).unwrap();
        assert!(spec_from_document(&doc).is_err());
    }

    #[test]
    fn parses_fault_plan() {
        let text = r#"
[experiment]
mode = "arcus"
duration_ms = 10
[[accels]]
kind = "ipsec"
[[flows]]
vm = 0
slo_gbps = 8.0
[[flows]]
vm = 1
slo_gbps = 7.0
[[faults]]
kind = "accel_slowdown"
at_ms = 3.0
until_ms = 6.0
unit = 0
factor = 0.5
[[faults]]
kind = "rogue_tenant"
flow = 1
at_ms = 7.0
until_ms = 9.0
"#;
        let doc = Document::from_str(text).unwrap();
        let spec = spec_from_document(&doc).unwrap();
        assert_eq!(spec.faults.len(), 2);
        assert_eq!(
            spec.faults[0],
            FaultSpec::new(
                FaultKind::AccelSlowdown { unit: 0, factor: 0.5 },
                3 * MILLIS,
                6 * MILLIS
            )
        );
        assert_eq!(
            spec.faults[1],
            FaultSpec::new(FaultKind::RogueTenant { flow: 1 }, 7 * MILLIS, 9 * MILLIS)
        );
    }

    #[test]
    fn rejects_bad_fault_plans() {
        let base = "[experiment]\nduration_ms = 10\nwarmup_ms = 0\n\
                    [[accels]]\nkind = \"ipsec\"\n\
                    [[flows]]\nvm = 0\nslo_gbps = 8.0\n";
        // Window starting at/after the run's end.
        let text = format!(
            "{base}[[faults]]\nkind = \"link_degrade\"\nat_ms = 10.0\nuntil_ms = 12.0\n"
        );
        let err = spec_from_document(&Document::from_str(&text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("never fire"), "{err:#}");
        // Unknown kind names the menu.
        let text = format!("{base}[[faults]]\nkind = \"gremlin\"\nat_ms = 1.0\nuntil_ms = 2.0\n");
        let err = spec_from_document(&Document::from_str(&text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("rogue_tenant"), "{err:#}");
        // SSD fault without a [raid] array.
        let text = format!(
            "{base}[[faults]]\nkind = \"ssd_slowdown\"\nat_ms = 1.0\nuntil_ms = 2.0\n"
        );
        let err = spec_from_document(&Document::from_str(&text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("raid"), "{err:#}");
        // Overlapping windows on one component.
        let text = format!(
            "{base}[[faults]]\nkind = \"link_degrade\"\nat_ms = 1.0\nuntil_ms = 4.0\n\
             [[faults]]\nkind = \"link_degrade\"\nat_ms = 3.0\nuntil_ms = 6.0\nfactor = 0.2\n"
        );
        let err = spec_from_document(&Document::from_str(&text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("overlap"), "{err:#}");
        // Rogue tenant must name a flow.
        let text = format!(
            "{base}[[faults]]\nkind = \"rogue_tenant\"\nat_ms = 1.0\nuntil_ms = 2.0\n"
        );
        assert!(spec_from_document(&Document::from_str(&text).unwrap()).is_err());
        // A window starting inside the warmup would be mis-measured.
        let text = "[experiment]\nduration_ms = 10\nwarmup_ms = 2\n\
                    [[accels]]\nkind = \"ipsec\"\n[[flows]]\nvm = 0\nslo_gbps = 8.0\n\
                    [[faults]]\nkind = \"link_degrade\"\nat_ms = 1.0\nuntil_ms = 4.0\n";
        let err = spec_from_document(&Document::from_str(text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("warmup"), "{err:#}");
        // An accel fault on a config with zero [[accels]] must fail at
        // parse, not panic mid-run.
        let text = "[experiment]\nduration_ms = 10\nwarmup_ms = 0\n[raid]\ndrives = 4\n\
                    [[flows]]\nkind = \"storage_read\"\nsize = 4096\nslo_kiops = 300.0\n\
                    [[faults]]\nkind = \"accel_slowdown\"\nat_ms = 3.0\nuntil_ms = 5.0\n";
        let err = spec_from_document(&Document::from_str(text).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    }

    #[test]
    fn rejects_bad_mode_and_path() {
        let doc = Document::from_str("[experiment]\nmode = \"bogus\"\n[[flows]]\nvm = 0\n").unwrap();
        let err = spec_from_document(&doc).unwrap_err();
        // The error names the valid menu, not just the bad value.
        assert!(format!("{err:#}").contains("arcus"), "{err:#}");
        let doc =
            Document::from_str("[[flows]]\npath = \"teleport\"\n").unwrap();
        assert!(spec_from_document(&doc).is_err());
    }

    #[test]
    fn rejects_out_of_range_accel() {
        let doc = Document::from_str("[[flows]]\naccel = 3\n").unwrap();
        assert!(spec_from_document(&doc).is_err());
    }
}
