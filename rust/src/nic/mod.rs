//! NIC model: Ethernet ports with RX/TX buffers (inline-NIC paths, Fig 2 ③).
//!
//! The paper's FPGA carries two 50 Gbps Ethernet ports; inline-mode flows
//! traverse the on-NIC receive buffer, which Arcus drains "in pull-based
//! fashion" with a shaped fetch pattern (§4.1). The SLO-relevant behaviour
//! is: (1) the port serializes at line rate, (2) the RX buffer is finite —
//! an unshaped large-message flow can congest it and cause drops or
//! head-of-line blocking for a co-located tiny-message flow (Fig 9 / Fig
//! 11a's live-migration interference).

use crate::util::units::{Rate, Time};
use std::collections::VecDeque;

/// One Ethernet port with an RX buffer.
#[derive(Debug)]
pub struct NicPort {
    rate: Rate,
    /// RX buffer capacity in bytes.
    rx_capacity: u64,
    rx_buffered: u64,
    rx_queue: VecDeque<Frame>,
    /// Per-flow buffer quota in bytes (Arcus's per-flow SRAM queues +
    /// backpressure: one flow's backlog cannot evict another's frames).
    /// None = single shared FIFO budget (the baselines).
    flow_quota: Option<u64>,
    per_flow_bytes: std::collections::HashMap<usize, u64>,
    /// Wire serialization horizon (frames arrive back-to-back at line rate).
    wire_busy_until: Time,
    /// TX wire horizon (independent full-duplex direction).
    tx_busy_until: Time,
    pub rx_dropped: u64,
    pub rx_drop_bytes: u64,
}

/// A frame sitting in the RX buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Frame {
    pub id: u64,
    /// Source flow.
    pub flow: usize,
    pub bytes: u64,
    /// Time the frame started onto the wire (latency accounting origin).
    /// Carried in the frame so the engine needs no side table.
    pub born: Time,
    /// Time fully received off the wire.
    pub arrived: Time,
    /// Population user that issued the op (0 on pattern-generator runs).
    /// Carried like `born` so per-user accounting needs no side table.
    pub user: u32,
}

impl NicPort {
    pub fn new(rate: Rate, rx_capacity: u64) -> Self {
        NicPort {
            rate,
            rx_capacity,
            rx_buffered: 0,
            rx_queue: VecDeque::new(),
            flow_quota: None,
            per_flow_bytes: std::collections::HashMap::new(),
            wire_busy_until: 0,
            tx_busy_until: 0,
            rx_dropped: 0,
            rx_drop_bytes: 0,
        }
    }

    /// The paper's ports: 50 Gbps, 512 KB RX buffer (typical FPGA MAC FIFO).
    pub fn port_50g() -> Self {
        NicPort::new(Rate::gbps(50.0), 512 * 1024)
    }

    pub fn rate(&self) -> Rate {
        self.rate
    }

    /// Partition the buffer into per-flow quotas of `bytes` each.
    pub fn set_flow_quota(&mut self, bytes: u64) {
        self.flow_quota = Some(bytes);
    }

    /// A frame begins arriving at `now` (or when the wire frees up): wire
    /// serialization only — returns the time the last bit lands. The caller
    /// must call [`Self::rx_deliver`] at that time; the buffer-occupancy
    /// decision belongs to delivery, not to the wire (a frame still on the
    /// wire occupies no SRAM).
    pub fn rx_begin(&mut self, now: Time, bytes: u64) -> Time {
        // Ethernet overhead: preamble+SFD (8) + FCS (4) + IFG (12).
        let wire_bytes = bytes + 24;
        let start = now.max(self.wire_busy_until);
        let done = start + self.rate.serialize_time(wire_bytes);
        self.wire_busy_until = done;
        done
    }

    /// Deliver a fully-received frame into the RX buffer at `arrived`
    /// (`born` = when it started onto the wire, for latency accounting);
    /// returns false (and counts a drop) when the buffer — or, with
    /// per-flow quotas, the flow's share of it — is full.
    pub fn rx_deliver(
        &mut self,
        id: u64,
        flow: usize,
        bytes: u64,
        born: Time,
        arrived: Time,
        user: u32,
    ) -> bool {
        let flow_ok = match self.flow_quota {
            Some(q) => self.per_flow_bytes.get(&flow).copied().unwrap_or(0) + bytes <= q,
            None => true,
        };
        if flow_ok && self.rx_buffered + bytes <= self.rx_capacity {
            self.rx_buffered += bytes;
            *self.per_flow_bytes.entry(flow).or_insert(0) += bytes;
            self.rx_queue.push_back(Frame { id, flow, bytes, born, arrived, user });
            true
        } else {
            self.rx_dropped += 1;
            self.rx_drop_bytes += bytes;
            false
        }
    }

    /// Wire + immediate delivery (tests and senders that do not model the
    /// in-flight gap): returns (arrival time, dropped).
    pub fn rx_frame(&mut self, now: Time, id: u64, flow: usize, bytes: u64) -> (Time, bool) {
        let done = self.rx_begin(now, bytes);
        let dropped = !self.rx_deliver(id, flow, bytes, now, done, 0);
        (done, dropped)
    }

    /// Transmit a frame out the wire (TX direction, full duplex with RX):
    /// returns the time the last bit leaves.
    pub fn tx_frame(&mut self, now: Time, bytes: u64) -> Time {
        let wire_bytes = bytes + 24;
        let start = now.max(self.tx_busy_until);
        let done = start + self.rate.serialize_time(wire_bytes);
        self.tx_busy_until = done;
        done
    }

    /// Pull-based drain (the Arcus interface fetches at its shaped pace):
    /// pop the head frame if it has fully arrived by `now`.
    pub fn rx_pull(&mut self, now: Time) -> Option<Frame> {
        match self.rx_queue.front() {
            Some(f) if f.arrived <= now => {
                let f = *f;
                self.rx_queue.pop_front();
                self.rx_buffered -= f.bytes;
                if let Some(b) = self.per_flow_bytes.get_mut(&f.flow) {
                    *b -= f.bytes;
                }
                Some(f)
            }
            _ => None,
        }
    }

    /// Peek the first fully-arrived frame belonging to `flow` without
    /// popping it (the shaper decides on its size before the pull).
    pub fn rx_flow_head(&self, now: Time, flow: usize) -> Option<Frame> {
        self.rx_queue
            .iter()
            .find(|f| f.flow == flow && f.arrived <= now)
            .copied()
    }

    /// Per-flow pull: pop the first fully-arrived frame belonging to `flow`
    /// (the Arcus interface parses headers into per-flow SRAM queues; this
    /// models that demux without a separate copy).
    pub fn rx_pull_flow(&mut self, now: Time, flow: usize) -> Option<Frame> {
        let idx = self
            .rx_queue
            .iter()
            .position(|f| f.flow == flow && f.arrived <= now)?;
        let f = self.rx_queue.remove(idx).unwrap();
        self.rx_buffered -= f.bytes;
        if let Some(b) = self.per_flow_bytes.get_mut(&f.flow) {
            *b -= f.bytes;
        }
        Some(f)
    }

    /// Earliest arrival time among buffered frames of `flow`.
    pub fn rx_flow_head_ready(&self, flow: usize) -> Option<Time> {
        self.rx_queue
            .iter()
            .filter(|f| f.flow == flow)
            .map(|f| f.arrived)
            .min()
    }

    /// Buffered frame count for one flow.
    pub fn rx_flow_depth(&self, flow: usize) -> usize {
        self.rx_queue.iter().filter(|f| f.flow == flow).count()
    }

    /// Peek the FIFO head frame (single-ring interfaces drain in order —
    /// the bypassed baseline's head-of-line blocking).
    pub fn rx_head(&self) -> Option<Frame> {
        self.rx_queue.front().copied()
    }

    /// Peek the head frame's arrival time (when a puller should wake).
    pub fn rx_head_ready(&self) -> Option<Time> {
        self.rx_queue.front().map(|f| f.arrived)
    }

    pub fn rx_buffered_bytes(&self) -> u64 {
        self.rx_buffered
    }

    pub fn rx_depth(&self) -> usize {
        self.rx_queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{MICROS, NANOS, SECONDS};

    #[test]
    fn wire_serialization_at_line_rate() {
        let mut port = NicPort::port_50g();
        // 1500 B + 24 overhead at 50 Gbps = 243.84 ns
        let (done, _) = port.rx_frame(0, 0, 0, 1500);
        assert_eq!(done, ((1524 * 8) as f64 / 50e9 * SECONDS as f64).ceil() as u64);
        // Second frame queues behind the first on the wire.
        let (done2, _) = port.rx_frame(0, 1, 0, 1500);
        assert_eq!(done2, 2 * done);
    }

    #[test]
    fn buffer_overflow_drops() {
        let mut port = NicPort::new(Rate::gbps(50.0), 4096);
        let mut t = 0;
        for i in 0..10 {
            t = port.rx_frame(t, i, 0, 1500).0;
        }
        // Nothing pulled: only 2 frames fit (3000 B ≤ 4096 < 4500).
        assert_eq!(port.rx_depth(), 2);
        assert_eq!(port.rx_dropped, 8);
    }

    #[test]
    fn pull_respects_arrival_time() {
        let mut port = NicPort::port_50g();
        let (done, _) = port.rx_frame(0, 7, 1, 4096);
        assert!(port.rx_pull(done - NANOS).is_none());
        let f = port.rx_pull(done).unwrap();
        assert_eq!(f.id, 7);
        assert_eq!(f.flow, 1);
        assert!(port.rx_pull(done).is_none());
    }

    #[test]
    fn per_flow_quota_isolates_backlogs() {
        let mut port = NicPort::new(Rate::gbps(50.0), 16 * 1024);
        port.set_flow_quota(4096);
        // Flow 0 floods: only its quota's worth is buffered.
        let mut t = 0;
        for i in 0..10 {
            t = port.rx_frame(t, i, 0, 1500).0;
        }
        assert_eq!(port.rx_flow_depth(0), 2); // 3000 B ≤ 4096 < 4500
        assert_eq!(port.rx_dropped, 8);
        // Flow 1 still has room despite flow 0's backlog.
        let (_, dropped) = port.rx_frame(t, 100, 1, 1500);
        assert!(!dropped);
        assert_eq!(port.rx_flow_depth(1), 1);
        // Pulling flow 0 frees its quota.
        let _ = port.rx_pull_flow(t + 1, 0).unwrap();
        let (_, dropped) = port.rx_frame(t, 101, 0, 1500);
        assert!(!dropped);
    }

    #[test]
    fn fifo_head_vs_per_flow_pull() {
        let mut port = NicPort::port_50g();
        let (t1, _) = port.rx_frame(0, 0, 0, 1500);
        let (t2, _) = port.rx_frame(0, 1, 1, 64);
        // FIFO head is flow 0's frame; flow 1 cannot pull it via rx_pull.
        assert_eq!(port.rx_head().unwrap().flow, 0);
        // Per-flow pull (Arcus) reaches past the head.
        let f = port.rx_pull_flow(t2, 1).unwrap();
        assert_eq!(f.flow, 1);
        // FIFO pull then yields flow 0.
        assert_eq!(port.rx_pull(t1).unwrap().flow, 0);
    }

    #[test]
    fn tx_is_full_duplex_with_rx() {
        let mut port = NicPort::port_50g();
        let (rx_done, _) = port.rx_frame(0, 0, 0, 1500);
        let tx_done = port.tx_frame(0, 1500);
        // Same serialization time, independent directions.
        assert_eq!(rx_done, tx_done);
        // Back-to-back TX queues on the TX horizon only.
        let tx2 = port.tx_frame(0, 1500);
        assert_eq!(tx2, 2 * tx_done);
    }

    #[test]
    fn draining_frees_buffer_space() {
        let mut port = NicPort::new(Rate::gbps(50.0), 3000);
        let (t1, _) = port.rx_frame(0, 0, 0, 1500);
        let _ = port.rx_frame(0, 1, 0, 1500);
        assert_eq!(port.rx_buffered_bytes(), 3000);
        let _ = port.rx_pull(t1).unwrap();
        assert_eq!(port.rx_buffered_bytes(), 1500);
        // Space for one more now.
        let _ = port.rx_frame(10 * MICROS, 2, 0, 1500);
        assert_eq!(port.rx_dropped, 0);
    }
}
