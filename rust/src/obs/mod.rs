//! The streaming observability plane: deterministic in-run metrics.
//!
//! Everything the repo measured used to be assembled *after* the run into
//! a [`crate::system::SystemReport`]. This module adds the in-run plane
//! the ROADMAP names as the unlock for the adaptive controller and the
//! fleet plane:
//!
//! - [`SeriesRing`] — fixed-capacity, power-of-two ring buffers of
//!   counter/gauge samples indexed by **control tick** (sim time divided
//!   by the control period), never wall clock.
//! - [`ObsPlane`] — the live recorder owned by the simulation `World`.
//!   It samples per-flow / per-tenant / per-engine signals (bytes, ops,
//!   drops, queue depth, window attainment, window p99, directive counts)
//!   on the *existing* `ControlTick` event, folds completion latencies
//!   into mergeable histograms up the tenant→engine hierarchy, and owns
//!   the fault-era + recovery accounting that `FlowReport.fault` is
//!   derived from.
//! - [`ObsSnapshot`] — the frozen end-of-run view carried on
//!   `SystemReport`, with an FNV-1a [`digest`](ObsSnapshot::digest) that
//!   is part of the canonical report: the determinism suite asserts the
//!   entire observable surface is byte-identical across the binary-heap,
//!   calendar, and timer-wheel event queues.
//! - [`prom`] — Prometheus text-exposition export (`arcus simulate
//!   --prom-out`, `arcus sweep --prom-out`).
//! - [`dump`] + [`top`] — a compact binary series dump and the `arcus
//!   top` terminal view of the worst flows/tenants by attainment and p99.
//!
//! Determinism argument: the plane consumes only values computed by the
//! simulation schedule (completion events and control-tick measurement
//! windows) and indexes them by tick; it samples nothing of its own and
//! adds no events. Its state is therefore a pure function of the spec and
//! seed, and identical across event-queue disciplines whenever the
//! schedule itself is.

#[warn(missing_docs)]
pub mod dump;
#[warn(missing_docs)]
pub mod plane;
#[warn(missing_docs)]
pub mod prom;
#[warn(missing_docs)]
pub mod series;
#[warn(missing_docs)]
pub mod top;

pub use plane::{
    EngineObs, FlowSeries, ObsConfig, ObsPlane, ObsSnapshot, TenantObs, FLOW_SIGNALS,
    GAUGE_NONE, RECOVERY_FRACTION,
};
pub use series::SeriesRing;
