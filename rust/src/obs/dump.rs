//! Compact binary series dump (`arcus simulate --series-out`), consumed by
//! `arcus top`.
//!
//! Layout (all integers LEB128 varints unless noted):
//!
//! ```text
//! "ARCS"            4-byte magic
//! u16 LE            format version (1)
//! varint            control period (ps per tick)
//! varint            sample_every (ticks per sample)
//! varint            flow count
//! per flow:
//!   varint × 3      flow id, vm, engine
//!   per signal (FLOW_SIGNALS order, 7 of them):
//!     varint        first tick index
//!     varint        sample count
//!     varint × n    samples
//! ```
//!
//! Values are raw (not delta-coded): gauge series use `u64::MAX` as the
//! "absent" sentinel, which would blow up any signed-delta scheme, and the
//! dumps are small (a handful of KB per flow) either way.

use crate::util::units::Time;
use crate::util::varint::{get_varint, put_varint};

use super::plane::{FlowSeries, ObsSnapshot};
use super::series::SeriesRing;

const MAGIC: &[u8; 4] = b"ARCS";
const VERSION: u16 = 1;

fn put_ring(out: &mut Vec<u8>, r: &SeriesRing) {
    if r.is_empty() {
        put_varint(out, 0);
        put_varint(out, 0);
        return;
    }
    put_varint(out, r.first_tick());
    put_varint(out, r.len() as u64);
    for (_, v) in r.iter() {
        put_varint(out, v);
    }
}

fn get_ring(buf: &[u8], pos: &mut usize) -> Result<SeriesRing, String> {
    let first = get_varint(buf, pos)?;
    let len = get_varint(buf, pos)? as usize;
    // Each sample is at least one byte, so a well-formed count can never
    // exceed the bytes *remaining* — checking against the whole buffer would
    // let an inflated count near the tail over-allocate before the sample
    // loop ever notices the truncation.
    if len > buf.len().saturating_sub(*pos) {
        return Err("series length exceeds dump size".into());
    }
    let mut samples = Vec::with_capacity(len);
    for _ in 0..len {
        samples.push(get_varint(buf, pos)?);
    }
    Ok(SeriesRing::from_samples(first, &samples))
}

/// The decoded contents of a series dump.
#[derive(Debug)]
pub struct DumpData {
    /// Sampling clock (ps per control tick).
    pub control_period: Time,
    /// Every Nth tick sampled.
    pub sample_every: u64,
    /// Per-flow series, in flow-id order.
    pub flows: Vec<FlowSeries>,
}

/// Serialize a snapshot's per-flow series.
pub fn write(snap: &ObsSnapshot) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    put_varint(&mut out, snap.control_period);
    put_varint(&mut out, snap.sample_every);
    put_varint(&mut out, snap.flows.len() as u64);
    for f in &snap.flows {
        put_varint(&mut out, f.flow as u64);
        put_varint(&mut out, f.vm as u64);
        put_varint(&mut out, f.engine as u64);
        for ring in f.signals() {
            put_ring(&mut out, ring);
        }
    }
    out
}

/// Decode a dump produced by [`write`].
pub fn read(buf: &[u8]) -> Result<DumpData, String> {
    if buf.len() < 6 || &buf[0..4] != MAGIC {
        return Err("not an arcus series dump (bad magic)".into());
    }
    let version = u16::from_le_bytes([buf[4], buf[5]]);
    if version != VERSION {
        return Err(format!("unsupported dump version {version}"));
    }
    let mut pos = 6usize;
    let control_period = get_varint(buf, &mut pos)?;
    let sample_every = get_varint(buf, &mut pos)?;
    let n_flows = get_varint(buf, &mut pos)? as usize;
    // Same remaining-bytes bound as `get_ring`: every flow record is at
    // least 17 bytes (three id varints + seven empty rings), but ≥ 1 byte
    // is all the guard needs to keep `with_capacity` honest.
    if n_flows > buf.len().saturating_sub(pos) {
        return Err("flow count exceeds dump size".into());
    }
    let mut flows = Vec::with_capacity(n_flows);
    for _ in 0..n_flows {
        let flow = get_varint(buf, &mut pos)? as usize;
        let vm = get_varint(buf, &mut pos)? as usize;
        let engine = get_varint(buf, &mut pos)? as usize;
        let bytes = get_ring(buf, &mut pos)?;
        let ops = get_ring(buf, &mut pos)?;
        let dropped = get_ring(buf, &mut pos)?;
        let queue_depth = get_ring(buf, &mut pos)?;
        let attainment_ppm = get_ring(buf, &mut pos)?;
        let p99_ps = get_ring(buf, &mut pos)?;
        let directives = get_ring(buf, &mut pos)?;
        flows.push(FlowSeries {
            flow,
            vm,
            engine,
            bytes,
            ops,
            dropped,
            queue_depth,
            attainment_ppm,
            p99_ps,
            directives,
        });
    }
    Ok(DumpData {
        control_period,
        sample_every,
        flows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dump_round_trips_flow_series() {
        let mut snap = ObsSnapshot {
            control_period: 100_000_000,
            sample_every: 1,
            ..Default::default()
        };
        let mut f = FlowSeries {
            flow: 3,
            vm: 1,
            engine: 0,
            bytes: SeriesRing::new(8),
            ops: SeriesRing::new(8),
            dropped: SeriesRing::new(8),
            queue_depth: SeriesRing::new(8),
            attainment_ppm: SeriesRing::new(8),
            p99_ps: SeriesRing::new(8),
            directives: SeriesRing::new(8),
        };
        for t in 2..7u64 {
            f.bytes.push_at(t, t * 1000);
            f.attainment_ppm.push_at(t, if t == 4 { u64::MAX } else { 990_000 });
        }
        snap.flows.push(f);
        let buf = write(&snap);
        let data = read(&buf).expect("round trip");
        assert_eq!(data.control_period, 100_000_000);
        assert_eq!(data.flows.len(), 1);
        let g = &data.flows[0];
        assert_eq!((g.flow, g.vm, g.engine), (3, 1, 0));
        assert_eq!(g.bytes.first_tick(), 2);
        assert_eq!(g.bytes.get(6), Some(6000));
        assert_eq!(g.attainment_ppm.get(4), Some(u64::MAX));
        assert!(g.ops.is_empty());
    }

    #[test]
    fn ring_length_bounded_by_remaining_bytes() {
        // 80-byte buffer whose ring record sits near the tail: first_tick 0,
        // claimed length 75. 75 ≤ buf.len() so the pre-fix check (against
        // the whole buffer) passed and the decoder allocated 75 slots before
        // tripping over the truncation; the fixed check rejects up front
        // because only 2 bytes remain after the header.
        let mut buf = vec![0u8; 80];
        let tail = 76;
        buf[tail] = 0x00; // first_tick
        buf[tail + 1] = 75; // sample count
        let mut pos = tail;
        assert_eq!(
            get_ring(&buf, &mut pos).err(),
            Some("series length exceeds dump size".to_string()),
            "count must be bounded by bytes remaining, not dump size"
        );
    }

    #[test]
    fn flow_count_bounded_by_remaining_bytes() {
        let snap = ObsSnapshot {
            control_period: 1,
            sample_every: 1,
            ..Default::default()
        };
        let mut buf = write(&snap);
        // Overwrite the flow-count varint (last header byte) to claim more
        // flows than there are bytes left, then pad so the claim still fits
        // within the *total* size the pre-fix check compared against.
        let count_pos = buf.len() - 1;
        buf[count_pos] = 40;
        // Total size 48 ≥ the claimed 40 flows, so the pre-fix whole-buffer
        // check sailed through and the decoder only failed later (with a
        // misleading "truncated varint") while chewing the zero padding.
        buf.resize(48, 0);
        assert_eq!(
            read(&buf).err(),
            Some("flow count exceeds dump size".to_string()),
            "flow count must be bounded by bytes remaining"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(read(b"nope").is_err());
        assert!(read(b"ARCS\x02\x00").is_err()); // wrong version
        let snap = ObsSnapshot {
            control_period: 1,
            ..Default::default()
        };
        let mut buf = write(&snap);
        buf.truncate(7);
        assert!(read(&buf).is_err());
    }
}
