//! Prometheus text-exposition writer.
//!
//! Renders one or more labeled [`SystemReport`]s (a single `arcus
//! simulate` run, or every scenario of an `arcus sweep`) into the
//! Prometheus text format: one `# HELP` + `# TYPE` header per metric
//! family, then all samples of that family grouped together. Counter
//! families use the `_total` suffix and export cumulative values, so
//! successive scrapes of successive runs are monotone; label values are
//! escaped per the exposition spec (`\\`, `\"`, `\n`).

use crate::system::SystemReport;
use crate::util::units::SECONDS;

/// Escape a label value for the text exposition format.
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

struct Family<'a> {
    name: &'a str,
    kind: &'a str,
    help: &'a str,
    samples: Vec<(String, String)>, // (label set incl. braces, value)
}

impl<'a> Family<'a> {
    fn new(name: &'a str, kind: &'a str, help: &'a str) -> Self {
        Family {
            name,
            kind,
            help,
            samples: Vec::new(),
        }
    }

    fn push(&mut self, labels: String, value: String) {
        self.samples.push((labels, value));
    }

    fn render(&self, out: &mut String) {
        if self.samples.is_empty() {
            return;
        }
        out.push_str(&format!("# HELP {} {}\n", self.name, self.help));
        out.push_str(&format!("# TYPE {} {}\n", self.name, self.kind));
        for (labels, value) in &self.samples {
            out.push_str(&format!("{}{{{}}} {}\n", self.name, labels, value));
        }
    }
}

fn f(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "NaN".to_string()
    }
}

fn secs(ps: u64) -> String {
    f(ps as f64 / SECONDS as f64)
}

/// Render `(scenario label, report)` pairs into one exposition document.
pub fn render(scenarios: &[(String, &SystemReport)]) -> String {
    let mut flow_bytes = Family::new(
        "arcus_flow_bytes_total",
        "counter",
        "Payload bytes completed per flow (post-warmup).",
    );
    let mut flow_ops = Family::new(
        "arcus_flow_ops_total",
        "counter",
        "Requests completed per flow (post-warmup).",
    );
    let mut flow_dropped = Family::new(
        "arcus_flow_dropped_total",
        "counter",
        "Requests dropped or rejected per flow.",
    );
    let mut flow_reconfigs = Family::new(
        "arcus_flow_reconfigs_total",
        "counter",
        "Control-plane reconfigurations applied per flow.",
    );
    let mut flow_att = Family::new(
        "arcus_flow_attainment",
        "gauge",
        "Achieved / SLO-target ratio per flow (1.0 = exactly the SLO).",
    );
    let mut flow_p99 = Family::new(
        "arcus_flow_p99_seconds",
        "gauge",
        "Per-flow p99 completion latency.",
    );
    let mut tenant_bytes = Family::new(
        "arcus_tenant_bytes_total",
        "counter",
        "Payload bytes completed per tenant (flows folded up).",
    );
    let mut tenant_p99 = Family::new(
        "arcus_tenant_p99_seconds",
        "gauge",
        "p99 completion latency over a tenant's merged histogram.",
    );
    let mut engine_bytes = Family::new(
        "arcus_engine_bytes_total",
        "counter",
        "Payload bytes completed per engine (tenants folded up).",
    );
    let mut engine_p99 = Family::new(
        "arcus_engine_p99_seconds",
        "gauge",
        "p99 completion latency over an engine's merged histogram.",
    );
    let mut engine_util = Family::new(
        "arcus_engine_util",
        "gauge",
        "Accelerator busy fraction over the run.",
    );
    let mut events = Family::new(
        "arcus_events_total",
        "counter",
        "DES events executed by the run.",
    );
    let mut nic_dropped = Family::new(
        "arcus_nic_rx_dropped_total",
        "counter",
        "NIC RX drops across ports.",
    );

    for (label, r) in scenarios {
        let sc = escape_label(label);
        let base = |extra: &str| -> String {
            if extra.is_empty() {
                format!("scenario=\"{sc}\"")
            } else {
                format!("scenario=\"{sc}\",{extra}")
            }
        };
        for fr in &r.per_flow {
            let l = base(&format!("flow=\"{}\",vm=\"{}\"", fr.flow, fr.vm));
            flow_bytes.push(l.clone(), fr.bytes.to_string());
            flow_ops.push(l.clone(), fr.completed.to_string());
            flow_dropped.push(l.clone(), fr.dropped.to_string());
            flow_reconfigs.push(l.clone(), fr.reconfigs.to_string());
            if let Some(a) = fr.slo_attainment() {
                flow_att.push(l.clone(), f(a));
            }
            flow_p99.push(l, secs(fr.lat_p99));
        }
        for t in &r.obs.tenants {
            let l = base(&format!("vm=\"{}\"", t.vm));
            tenant_bytes.push(l.clone(), t.bytes.to_string());
            if !t.lat.is_empty() {
                tenant_p99.push(l, secs(t.lat.percentile(99.0)));
            }
        }
        for e in &r.obs.engines {
            let l = base(&format!("engine=\"{}\"", e.engine));
            engine_bytes.push(l.clone(), e.bytes.to_string());
            if !e.lat.is_empty() {
                engine_p99.push(l, secs(e.lat.percentile(99.0)));
            }
        }
        for (i, u) in r.accel_util.iter().enumerate() {
            engine_util.push(base(&format!("engine=\"{i}\"")), f(*u));
        }
        events.push(base(""), r.events.to_string());
        nic_dropped.push(base(""), r.nic_rx_dropped.to_string());
    }

    let mut out = String::new();
    for fam in [
        &flow_bytes,
        &flow_ops,
        &flow_dropped,
        &flow_reconfigs,
        &flow_att,
        &flow_p99,
        &tenant_bytes,
        &tenant_p99,
        &engine_bytes,
        &engine_p99,
        &engine_util,
        &events,
        &nic_dropped,
    ] {
        fam.render(&mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label("plain"), "plain");
        assert_eq!(escape_label("a\\b"), "a\\\\b");
        assert_eq!(escape_label("say \"hi\""), "say \\\"hi\\\"");
        assert_eq!(escape_label("line\nbreak"), "line\\nbreak");
    }

    #[test]
    fn empty_families_render_nothing() {
        let fam = Family::new("x_total", "counter", "nothing");
        let mut out = String::new();
        fam.render(&mut out);
        assert!(out.is_empty());
    }
}
